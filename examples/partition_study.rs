//! Partition-strategy study (paper §IV-C): how `obj_map` affects BI→DP
//! fan-out, traffic volume, and load balance on clustered data.
//!
//! ```bash
//! cargo run --release --example partition_study
//! ```

use parlsh::config::{Config, ObjMapStrategy};
use parlsh::coordinator::{build_index, search};
use parlsh::data::recall::recall_at_k;
use parlsh::experiments::{backends, env_usize, world};
use parlsh::metrics::Table;
use parlsh::partition::imbalance;

fn main() {
    let mut cfg = Config::default();
    cfg.data.n = env_usize("PARLSH_N", 100_000);
    cfg.data.queries = env_usize("PARLSH_Q", 300);
    cfg.data.clusters = (cfg.data.n / 100).max(100);
    cfg.lsh.t = 60; // the paper's fig-6 setting

    let w = world(&cfg);
    let mut table = Table::new(&[
        "obj_map",
        "logical msgs",
        "packets",
        "MB",
        "BI->DP msgs/query",
        "imbalance %",
        "recall",
    ]);
    for strat in [ObjMapStrategy::Mod, ObjMapStrategy::ZOrder, ObjMapStrategy::Lsh] {
        cfg.stream.obj_map = strat;
        let b = backends(&cfg, w.data.dim);
        let mut cluster = build_index(&cfg, &w.data, b.hasher.as_ref());
        let out = search(&mut cluster, &w.queries, b.hasher.as_ref(), b.ranker.as_ref());
        let recall = recall_at_k(&out.retrieved_ids(), &w.gt);
        let imb = imbalance(&cluster.dp_object_counts());
        // LocalTopK message count == BI→DP requests
        let dp_msgs: u64 = out
            .work
            .iter()
            .filter(|(s, _, _)| *s == parlsh::dataflow::message::StageKind::Ag)
            .map(|(_, _, w)| w.reduce_pushes)
            .sum::<u64>()
            .max(1);
        table.row(&[
            strat.name().into(),
            format!("{}", out.meter.logical_msgs),
            format!("{}", out.meter.total_packets()),
            format!("{:.2}", out.meter.payload_bytes as f64 / 1e6),
            format!("{:.1}", dp_msgs as f64 / w.queries.len() as f64),
            format!("{:.2}", imb.max_over_mean_pct),
            format!("{recall:.3}"),
        ]);
    }
    println!("partition strategies on clustered data (L={} M={} T={}):", cfg.lsh.l, cfg.lsh.m, cfg.lsh.t);
    table.print();
    println!(
        "\nexpected shape (paper fig. 6): identical recall; LSH obj_map cuts \
         messages vs mod/zorder at a small imbalance cost."
    );
}
