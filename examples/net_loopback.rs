//! Real multi-process distribution demo: the same build + search pipeline
//! the other examples run in-process, here spread across OS processes over
//! loopback TCP (DESIGN.md §Transports) — one `parlsh worker` per BI/DP
//! node, this process as the paper's head node.
//!
//! Needs the `parlsh` binary for the workers, so build it first:
//!
//! ```bash
//! cargo build --release && cargo run --release --example net_loopback
//! ```

use parlsh::config::Config;
use parlsh::coordinator::session::IndexSession;
use parlsh::coordinator::Cluster;
use parlsh::data::recall::recall_at_k;
use parlsh::experiments::{backends, env_usize, world};
use parlsh::net::NetSession;
use parlsh::util::timer::Timer;

fn main() {
    let mut cfg = Config::default();
    // 1 BI node + 2 DP nodes = 3 worker processes + this driver.
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 2;
    cfg.lsh.t = 16;
    cfg.stream.inflight = 8; // closed-loop admission over the wire
    cfg.data.n = env_usize("PARLSH_N", 20_000);
    cfg.data.queries = env_usize("PARLSH_Q", 100);
    cfg.data.clusters = (cfg.data.n / 100).max(50);

    let w = world(&cfg);
    let b = backends(&cfg, w.data.dim);

    // Examples are their own binaries, so point the launcher at `parlsh`
    // (built into the same target directory) unless the caller already set
    // PARLSH_WORKER_BIN.
    if std::env::var("PARLSH_WORKER_BIN").is_err() {
        let bin = std::env::current_exe()
            .ok()
            .and_then(|p| Some(p.parent()?.parent()?.join("parlsh")))
            .filter(|p| p.exists());
        match bin {
            Some(p) => std::env::set_var("PARLSH_WORKER_BIN", p),
            None => {
                eprintln!("parlsh binary not found next to this example;");
                eprintln!("run `cargo build --release` first (or set PARLSH_WORKER_BIN)");
                std::process::exit(2);
            }
        }
    }

    let sess = NetSession::launch(&cfg, w.data.dim).expect("launch workers");
    println!(
        "cluster up: {} worker processes + driver (head node)",
        cfg.cluster.bi_nodes + cfg.cluster.dp_nodes
    );

    // One persistent session over the socket executor: build, grow the
    // index mid-session, and serve — all against the same worker processes,
    // with a single handshake at launch (DESIGN.md §Service API).
    let mut cluster = Cluster::empty(&cfg, w.data.dim);
    let session = IndexSession::attach(
        sess.executor(),
        &mut cluster,
        b.hasher.as_ref(),
        Some(b.ranker.clone()),
    );

    let t = Timer::start();
    let (head, tail) = {
        // hold the last 1000 vectors back so the post-build insert is real
        let split = w.data.len().saturating_sub(1_000).max(1);
        let mut head = parlsh::data::Dataset::with_capacity(w.data.dim, split);
        let mut tail = parlsh::data::Dataset::with_capacity(w.data.dim, w.data.len() - split);
        for i in 0..split {
            head.push(w.data.get(i));
        }
        for i in split..w.data.len() {
            tail.push(w.data.get(i));
        }
        (head, tail)
    };
    session.insert(&head);
    println!(
        "built {} vectors across the wire in {:.2}s",
        head.len(),
        t.secs(),
    );
    let grown = session.insert(&tail);
    println!(
        "grew the live index by {} vectors (ids {}..{}) — no re-handshake, same workers",
        tail.len(),
        grown.start,
        grown.end
    );

    let mut retrieved: Vec<Vec<u32>> = vec![Vec::new(); w.queries.len()];
    for qi in 0..w.queries.len() {
        session.submit(w.queries.get(qi));
    }
    for (ticket, hits) in session.drain() {
        retrieved[ticket.0 as usize] = hits.iter().map(|&(_, id)| id).collect();
    }
    let stats = session.close();
    let recall = recall_at_k(&retrieved, &w.gt);
    println!(
        "searched {} queries: recall@{} = {recall:.3}, {:.3} MB on the wire ({} tcp packets)",
        w.queries.len(),
        cfg.lsh.k,
        stats.search_meter.total_bytes() as f64 / 1e6,
        stats.search_meter.total_packets(),
    );
    print!("{}", stats.search_meter.link_report());

    sess.shutdown().expect("clean shutdown");
    println!("all workers exited cleanly");
}
