//! Real multi-process distribution demo: the same build + search pipeline
//! the other examples run in-process, here spread across OS processes over
//! loopback TCP (DESIGN.md §Transports) — one `parlsh worker` per BI/DP
//! node, this process as the paper's head node.
//!
//! Needs the `parlsh` binary for the workers, so build it first:
//!
//! ```bash
//! cargo build --release && cargo run --release --example net_loopback
//! ```

use parlsh::config::Config;
use parlsh::coordinator::{build_index_on, search_on};
use parlsh::data::recall::recall_at_k;
use parlsh::experiments::{backends, env_usize, world};
use parlsh::net::NetSession;

fn main() {
    let mut cfg = Config::default();
    // 1 BI node + 2 DP nodes = 3 worker processes + this driver.
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 2;
    cfg.lsh.t = 16;
    cfg.stream.inflight = 8; // closed-loop admission over the wire
    cfg.data.n = env_usize("PARLSH_N", 20_000);
    cfg.data.queries = env_usize("PARLSH_Q", 100);
    cfg.data.clusters = (cfg.data.n / 100).max(50);

    let w = world(&cfg);
    let b = backends(&cfg, w.data.dim);

    // Examples are their own binaries, so point the launcher at `parlsh`
    // (built into the same target directory) unless the caller already set
    // PARLSH_WORKER_BIN.
    if std::env::var("PARLSH_WORKER_BIN").is_err() {
        let bin = std::env::current_exe()
            .ok()
            .and_then(|p| Some(p.parent()?.parent()?.join("parlsh")))
            .filter(|p| p.exists());
        match bin {
            Some(p) => std::env::set_var("PARLSH_WORKER_BIN", p),
            None => {
                eprintln!("parlsh binary not found next to this example;");
                eprintln!("run `cargo build --release` first (or set PARLSH_WORKER_BIN)");
                std::process::exit(2);
            }
        }
    }

    let sess = NetSession::launch(&cfg, w.data.dim).expect("launch workers");
    println!(
        "cluster up: {} worker processes + driver (head node)",
        cfg.cluster.bi_nodes + cfg.cluster.dp_nodes
    );

    let mut cluster = build_index_on(sess.executor(), &cfg, &w.data, b.hasher.as_ref());
    println!(
        "built {} vectors across the wire in {:.2}s — {:.3} MB of real frames",
        w.data.len(),
        cluster.build_wall_secs,
        cluster.build_meter.total_bytes() as f64 / 1e6,
    );

    let out = search_on(
        sess.executor(),
        &mut cluster,
        &w.queries,
        b.hasher.as_ref(),
        b.ranker.as_ref(),
    );
    let recall = recall_at_k(&out.retrieved_ids(), &w.gt);
    println!(
        "searched {} queries: recall@{} = {recall:.3}, {:.3} MB on the wire ({} tcp packets)",
        w.queries.len(),
        cfg.lsh.k,
        out.meter.total_bytes() as f64 / 1e6,
        out.meter.total_packets(),
    );
    print!("{}", out.meter.link_report());

    sess.shutdown().expect("clean shutdown");
    println!("all workers exited cleanly");
}
