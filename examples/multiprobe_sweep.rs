//! Multi-probe trade-off demo (paper fig. 4): recall vs work as the number
//! of probes per table grows — with a fixed, small memory footprint.
//!
//! ```bash
//! cargo run --release --example multiprobe_sweep
//! ```

use parlsh::config::Config;
use parlsh::coordinator::{build_index, search};
use parlsh::data::recall::recall_at_k;
use parlsh::experiments::{backends, env_usize, world};
use parlsh::metrics::Table;
use parlsh::util::timer::Timer;

fn main() {
    let mut cfg = Config::default();
    cfg.data.n = env_usize("PARLSH_N", 80_000);
    cfg.data.queries = env_usize("PARLSH_Q", 200);
    cfg.data.clusters = (cfg.data.n / 100).max(100);

    let w = world(&cfg);
    let mut table = Table::new(&[
        "T",
        "recall@10",
        "dists/query",
        "host secs",
        "logical msgs",
    ]);
    for t in [1usize, 5, 15, 30, 60, 120] {
        cfg.lsh.t = t;
        let b = backends(&cfg, w.data.dim);
        let mut cluster = build_index(&cfg, &w.data, b.hasher.as_ref());
        let timer = Timer::start();
        let out = search(&mut cluster, &w.queries, b.hasher.as_ref(), b.ranker.as_ref());
        let secs = timer.secs();
        let recall = recall_at_k(&out.retrieved_ids(), &w.gt);
        let dists: u64 = out.work.iter().map(|(_, _, w)| w.dists_computed).sum();
        table.row(&[
            format!("{t}"),
            format!("{recall:.3}"),
            format!("{:.0}", dists as f64 / w.queries.len() as f64),
            format!("{secs:.2}"),
            format!("{}", out.meter.logical_msgs),
        ]);
    }
    println!(
        "multi-probe sweep (L={} M={}, {} vectors):",
        cfg.lsh.l, cfg.lsh.m, cfg.data.n
    );
    table.print();
    println!("\nexpected shape (paper fig. 4): recall rises with T while cost grows sublinearly.");
}
