//! Quickstart: build a distributed multi-probe LSH index over a synthetic
//! SIFT-like dataset and answer a few queries.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use parlsh::config::Config;
use parlsh::coordinator::{build_index, search};
use parlsh::data::recall::recall_at_k;
use parlsh::experiments::{backends, world};
use parlsh::util::timer::Timer;

fn main() {
    // 1. Configure. Defaults follow the paper: L=6 tables, M=32 hash
    //    functions/table, T=30 probes/table, k=10 neighbors, and the
    //    paper's 51-node topology (10 BI nodes : 40 DP nodes : 1 head).
    let mut cfg = Config::default();
    cfg.data.n = 50_000; // keep the quickstart snappy
    cfg.data.queries = 100;

    // 2. Data: a clustered synthetic stand-in for BIGANN SIFT descriptors
    //    plus distorted queries and cached exact ground truth.
    let w = world(&cfg);
    println!("dataset: {} x {}d, {} queries", w.data.len(), w.data.dim, w.queries.len());

    // 3. Compute backends: the AOT-compiled JAX/Pallas artifacts via PJRT
    //    when `artifacts/` exists, pure-rust scalar fallback otherwise.
    let b = backends(&cfg, w.data.dim);
    println!("compute path: {}", if b.engine_path { "PJRT artifacts" } else { "scalar" });

    // 4. Build the distributed index (IR → BI/DP dataflow).
    let t = Timer::start();
    let mut cluster = build_index(&cfg, &w.data, b.hasher.as_ref());
    println!(
        "index built in {:.2}s: {} objects on {} DP copies, {} refs on {} BI copies",
        t.secs(),
        cluster.stored_objects(),
        cluster.dps.len(),
        cluster.bucket_references(),
        cluster.bis.len()
    );

    // 5. Search (QR → BI → DP → AG dataflow) and score recall.
    let t = Timer::start();
    let out = search(&mut cluster, &w.queries, b.hasher.as_ref(), b.ranker.as_ref());
    let recall = recall_at_k(&out.retrieved_ids(), &w.gt);
    println!(
        "searched {} queries in {:.2}s — recall@{} = {:.3}",
        w.queries.len(),
        t.secs(),
        cfg.lsh.k,
        recall
    );
    println!(
        "traffic: {} logical messages, {} packets after aggregation, {:.2} MB",
        out.meter.logical_msgs,
        out.meter.total_packets(),
        out.meter.payload_bytes as f64 / 1e6
    );

    // 6. Inspect one answer.
    let q0 = &out.results[0];
    println!("query 0 nearest neighbors (sqdist, id): {:?}", &q0[..q0.len().min(5)]);
}
