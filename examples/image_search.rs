//! End-to-end CBMR serving driver (the paper's motivating application): an
//! image search engine answering descriptor queries against a large
//! reference collection.
//!
//! Exercises every layer of the stack on a real small workload:
//!   * synthetic "Web image" SIFT corpus (clustered 128-d, [0,255]);
//!   * distorted-query workload (the Yahoo dataset protocol);
//!   * distributed index build through the IR→BI/DP dataflow;
//!   * **session-oriented** serving (DESIGN.md §Service API): the index
//!     stays resident in an `IndexSession` on the threaded executor while
//!     queries stream in one at a time and completions stream back out by
//!     ticket — the paper's continuously-running asynchronous design;
//!   * PJRT-compiled JAX/Pallas kernels on the hash + rank hot paths;
//!   * recall@10 against exact ground truth, latency percentiles,
//!     throughput, and communication metrics.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example image_search
//! ```

use parlsh::config::Config;
use parlsh::coordinator::build_index;
use parlsh::coordinator::session::IndexSession;
use parlsh::data::recall::recall_at_k;
use parlsh::dataflow::exec::ThreadedExecutor;
use parlsh::experiments::{backends, env_usize, world};
use parlsh::util::timer::Timer;

fn main() {
    let mut cfg = Config::default();
    cfg.data.n = env_usize("PARLSH_N", 200_000);
    cfg.data.queries = env_usize("PARLSH_Q", 500);
    cfg.data.clusters = (cfg.data.n / 100).max(100);
    cfg.lsh.t = 30;

    println!("== image-search e2e ==");
    println!(
        "corpus: {} SIFT-like descriptors; workload: {} distorted queries",
        cfg.data.n, cfg.data.queries
    );
    let w = world(&cfg);
    let b = backends(&cfg, w.data.dim);
    println!(
        "compute path: {} | LSH L={} M={} T={} w={}",
        if b.engine_path { "PJRT artifacts (JAX/Pallas AOT)" } else { "scalar fallback" },
        cfg.lsh.l,
        cfg.lsh.m,
        cfg.lsh.t,
        cfg.lsh.w
    );

    // Build.
    let t = Timer::start();
    let mut cluster = build_index(&cfg, &w.data, b.hasher.as_ref());
    let build_secs = t.secs();
    println!(
        "index: built in {:.1}s ({:.0} vec/s) — {} BI copies / {} DP copies",
        build_secs,
        w.data.len() as f64 / build_secs,
        cluster.bis.len(),
        cluster.dps.len()
    );
    let imb = parlsh::partition::imbalance(&cluster.dp_object_counts());
    println!(
        "partition ({}): imbalance {:.2}%",
        cfg.stream.obj_map.name(),
        imb.max_over_mean_pct
    );

    // Serve: a persistent session on the threaded executor — submit each
    // descriptor query as it "arrives", collect completions by ticket.
    let session = IndexSession::attach(
        &ThreadedExecutor,
        &mut cluster,
        b.hasher.as_ref(),
        Some(b.ranker.clone()),
    );
    let t = Timer::start();
    let mut results: Vec<Vec<(f32, u32)>> = vec![Vec::new(); w.queries.len()];
    for qi in 0..w.queries.len() {
        session.submit(w.queries.get(qi));
    }
    for (ticket, hits) in session.drain() {
        results[ticket.0 as usize] = hits; // tickets are dense: 0..n
    }
    let stats = session.close();
    let secs = t.secs();

    let retrieved: Vec<Vec<u32>> = results
        .iter()
        .map(|r| r.iter().map(|&(_, id)| id).collect())
        .collect();
    let recall = recall_at_k(&retrieved, &w.gt);
    let lat = stats.latency.stats();

    println!("== serving results ==");
    println!(
        "throughput: {:.1} queries/s ({} queries in {:.2}s, IndexSession on the threaded executor)",
        w.queries.len() as f64 / secs,
        w.queries.len(),
        secs
    );
    println!("recall@{}: {:.3}", cfg.lsh.k, recall);
    println!(
        "completion latency ms (open loop): mean {:.1} p50 {:.1} p90 {:.1} p99 {:.1}",
        lat.mean_ms, lat.p50_ms, lat.p90_ms, lat.p99_ms
    );
    println!(
        "traffic: {} logical msgs ({} intra-node), {} packets, {:.2} MB",
        stats.search_meter.logical_msgs,
        stats.search_meter.local_msgs,
        stats.search_meter.total_packets(),
        stats.search_meter.payload_bytes as f64 / 1e6
    );
    let dists: u64 = stats.work.iter().map(|(_, _, w)| w.dists_computed).sum();
    let dups: u64 = stats.work.iter().map(|(_, _, w)| w.dup_skipped).sum();
    println!(
        "work: {:.0} distance computations/query, {} duplicate candidates eliminated",
        dists as f64 / w.queries.len() as f64,
        dups
    );

    // A couple of qualitative answers.
    for qi in 0..2usize {
        let r = &results[qi];
        println!(
            "query {qi}: top-3 = {:?}",
            &r[..r.len().min(3)]
        );
    }

    // Per-query search plans (DESIGN.md §Service API): the same resident
    // index serves a cheap low-latency request and a deep high-recall one
    // back to back — no rebuild, no second session.
    let mut cluster2 = cluster;
    let session = parlsh::coordinator::session::IndexSession::attach(
        &ThreadedExecutor,
        &mut cluster2,
        b.hasher.as_ref(),
        Some(b.ranker.clone()),
    );
    use parlsh::QueryOptions;
    let q = w.queries.get(0);
    session.submit_with(q, QueryOptions { k: 3, probes: 1, tables: 2, tag: 1 });
    session.submit_with(q, QueryOptions { probes: 2 * cfg.lsh.t as u32, tag: 2, ..Default::default() });
    for (ticket, opts, hits, secs) in session.drain_full() {
        println!(
            "plan tag={} (k={} T={} L'={}): {} hits in {:.2} ms (ticket {})",
            opts.tag,
            opts.k,
            opts.probes,
            opts.tables,
            hits.len(),
            secs * 1e3,
            ticket.0
        );
    }
    session.close();
}
