//! Weak-scaling demo (paper fig. 3): the functional pipeline runs at each
//! scale point and the calibrated cluster model converts its measured work
//! and traffic into modeled time on the paper's testbed shape.
//!
//! ```bash
//! cargo run --release --example weak_scaling
//! ```

use parlsh::experiments::fig3_weak_scaling;

fn main() {
    println!("weak scaling: dataset grows proportionally with nodes (BI:DP = 1:4, AG = 1 core)");
    fig3_weak_scaling().print();
    println!("\nexpected shape (paper fig. 3): efficiency stays high (~0.9) out to the largest scale;");
    println!("the loss comes from the serial AG core and head-node hashing, not BI/DP work.");
}
