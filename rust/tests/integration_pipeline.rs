//! Differential test: the distributed pipeline must return *identical*
//! results to the sequential multi-probe LSH baseline (same family, same
//! probes, same tie-breaks), and recall must be sane against ground truth.

use parlsh::baseline::SequentialLsh;
use parlsh::config::Config;
use parlsh::coordinator::{build_index, build_index_on, search, search_on};
use parlsh::dataflow::exec::ThreadedExecutor;
use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::data::groundtruth::ground_truth_scalar;
use parlsh::data::recall::recall_at_k;
use parlsh::data::synth::{distorted_queries, synthesize, SynthSpec};
use parlsh::runtime::{ScalarHasher, ScalarRanker};

fn config(l: usize, m: usize, t: usize) -> Config {
    let mut cfg = Config::default();
    cfg.lsh = LshParams { l, m, w: 700.0, k: 10, t, seed: 9 };
    cfg.cluster.bi_nodes = 3;
    cfg.cluster.dp_nodes = 5;
    cfg
}

#[test]
fn distributed_equals_sequential() {
    let cfg = config(4, 8, 12);
    let ds = synthesize(SynthSpec { n: 4_000, clusters: 80, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 40, 6.0, 3);

    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let hasher = ScalarHasher { family };
    let ranker = ScalarRanker { dim: ds.dim };
    let mut cluster = build_index(&cfg, &ds, &hasher);
    let out = search(&mut cluster, &qs, &hasher, &ranker);

    let seq = SequentialLsh::build(&ds, cfg.lsh);
    for qi in 0..qs.len() {
        let (seq_res, _) = seq.search(qs.get(qi), cfg.lsh.t, cfg.lsh.k);
        let dist_res = &out.results[qi];
        assert_eq!(
            dist_res.len(),
            seq_res.len(),
            "query {qi}: result count differs"
        );
        for (a, b) in dist_res.iter().zip(&seq_res) {
            assert_eq!(a.1, b.1, "query {qi}: ids differ");
            assert!((a.0 - b.0).abs() <= 1e-3 * a.0.max(1.0), "query {qi}: dists differ");
        }
    }
}

#[test]
fn distributed_candidates_equal_sequential_distance_count() {
    // Duplicate elimination must make the distributed pipeline compute
    // exactly as many distances as the sequential dedup does.
    let cfg = config(4, 8, 16);
    let ds = synthesize(SynthSpec { n: 3_000, clusters: 60, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 25, 5.0, 17);
    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let hasher = ScalarHasher { family };
    let ranker = ScalarRanker { dim: ds.dim };
    let mut cluster = build_index(&cfg, &ds, &hasher);
    let out = search(&mut cluster, &qs, &hasher, &ranker);
    let dist_total: u64 = out.work.iter().map(|(_, _, w)| w.dists_computed).sum();

    let seq = SequentialLsh::build(&ds, cfg.lsh);
    let seq_total: usize = (0..qs.len())
        .map(|qi| seq.search(qs.get(qi), cfg.lsh.t, cfg.lsh.k).1)
        .sum();
    assert_eq!(dist_total, seq_total as u64);
}

#[test]
fn recall_improves_with_probes_and_reaches_target() {
    let ds = synthesize(SynthSpec { n: 6_000, clusters: 120, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 40, 6.0, 5);
    let gt = ground_truth_scalar(&ds, &qs, 10, 2);

    let mut recalls = Vec::new();
    for t in [1usize, 8, 32] {
        let cfg = config(6, 8, t);
        let family = HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let ranker = ScalarRanker { dim: ds.dim };
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        recalls.push(recall_at_k(&out.retrieved_ids(), &gt));
    }
    assert!(recalls[1] >= recalls[0], "recall fell with more probes: {recalls:?}");
    assert!(recalls[2] >= recalls[1], "recall fell with more probes: {recalls:?}");
    assert!(recalls[2] > 0.5, "T=32 recall too low: {recalls:?}");
}

#[test]
fn threaded_executor_differential() {
    let cfg = config(3, 8, 8);
    let ds = synthesize(SynthSpec { n: 2_000, clusters: 40, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 20, 5.0, 21);
    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let hasher = ScalarHasher { family };
    let ranker = ScalarRanker { dim: ds.dim };

    let mut cluster = build_index(&cfg, &ds, &hasher);
    let out = search_on(&ThreadedExecutor, &mut cluster, &qs, &hasher, &ranker);

    let seq = SequentialLsh::build(&ds, cfg.lsh);
    for qi in 0..qs.len() {
        let (seq_res, _) = seq.search(qs.get(qi), cfg.lsh.t, cfg.lsh.k);
        let ids: Vec<u32> = out.results[qi].iter().map(|&(_, id)| id).collect();
        let want: Vec<u32> = seq_res.iter().map(|&(_, id)| id).collect();
        assert_eq!(ids, want, "query {qi}");
    }
}

#[test]
fn threaded_build_and_batched_search_equal_sequential() {
    // The whole pipeline on the threaded executor — build *and* search —
    // with closed-loop admission and multiple aggregators must still equal
    // the sequential oracle.
    let mut cfg = config(3, 8, 8);
    cfg.cluster.ag_copies = 2;
    cfg.stream.inflight = 4;
    let ds = synthesize(SynthSpec { n: 2_000, clusters: 40, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 20, 5.0, 21);
    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let hasher = ScalarHasher { family };
    let ranker = ScalarRanker { dim: ds.dim };

    let mut cluster = build_index_on(&ThreadedExecutor, &cfg, &ds, &hasher);
    assert_eq!(cluster.stored_objects(), ds.len());
    assert_eq!(cluster.bucket_references(), ds.len() * cfg.lsh.l);
    let out = search_on(&ThreadedExecutor, &mut cluster, &qs, &hasher, &ranker);

    let seq = SequentialLsh::build(&ds, cfg.lsh);
    for qi in 0..qs.len() {
        let (seq_res, _) = seq.search(qs.get(qi), cfg.lsh.t, cfg.lsh.k);
        let ids: Vec<u32> = out.results[qi].iter().map(|&(_, id)| id).collect();
        let want: Vec<u32> = seq_res.iter().map(|&(_, id)| id).collect();
        assert_eq!(ids, want, "query {qi}");
    }
    // every query got a completion latency
    assert!(out.per_query_secs.iter().all(|&s| s > 0.0));
}

#[test]
fn no_replication_invariants() {
    let cfg = config(5, 6, 4);
    let ds = synthesize(SynthSpec { n: 3_500, clusters: 70, ..Default::default() });
    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let hasher = ScalarHasher { family };
    let cluster = build_index(&cfg, &ds, &hasher);
    // every object stored exactly once across DPs
    assert_eq!(cluster.stored_objects(), ds.len());
    // every object referenced exactly L times across BIs
    assert_eq!(cluster.bucket_references(), ds.len() * cfg.lsh.l);
}

#[test]
fn results_survive_multiple_search_phases() {
    // The index is reusable: two search phases over the same cluster give
    // identical answers (state isn't corrupted by a pass).
    let cfg = config(4, 8, 8);
    let ds = synthesize(SynthSpec { n: 2_000, clusters: 40, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 10, 5.0, 2);
    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let hasher = ScalarHasher { family };
    let ranker = ScalarRanker { dim: ds.dim };
    let mut cluster = build_index(&cfg, &ds, &hasher);
    let out1 = search(&mut cluster, &qs, &hasher, &ranker);
    let out2 = search(&mut cluster, &qs, &hasher, &ranker);
    assert_eq!(out1.results, out2.results);
    assert_eq!(out1.meter.logical_msgs, out2.meter.logical_msgs);
}

#[test]
fn multiprobe_beats_entropy_probing_at_equal_budget() {
    // Paper §III-C: multi-probe LSH "typically results, for the same
    // recall, in less bucket accesses per hash table" than entropy-based
    // probing. Equivalent statement at a fixed probe budget: multi-probe's
    // recall is at least competitive. Reproduced here against ground truth.
    use parlsh::baseline::EntropyProber;
    use parlsh::core::lsh::HashFamily;

    let params = LshParams { l: 4, m: 8, w: 700.0, k: 10, t: 12, seed: 9 };
    let ds = synthesize(SynthSpec { n: 6_000, clusters: 120, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 50, 6.0, 5);
    let gt = ground_truth_scalar(&ds, &qs, 10, 2);
    let index = SequentialLsh::build(&ds, params);
    let family = HashFamily::sample(ds.dim, params);
    // Entropy samples at the distortion radius (a favorable setting for it).
    let prober = EntropyProber::new(&family, 6.0);

    let mut mp_hits = Vec::new();
    let mut en_hits = Vec::new();
    for qi in 0..qs.len() {
        let q = qs.get(qi);
        let (mp, _) = index.search(q, params.t, params.k);
        mp_hits.push(mp.into_iter().map(|(_, id)| id).collect::<Vec<_>>());
        let probes = prober.probes(q, params.t, qi as u64);
        let (en, _) = index.search_with_probes(q, &probes, params.k);
        en_hits.push(en.into_iter().map(|(_, id)| id).collect::<Vec<_>>());
    }
    let mp_recall = parlsh::data::recall::recall_at_k(&mp_hits, &gt);
    let en_recall = parlsh::data::recall::recall_at_k(&en_hits, &gt);
    assert!(
        mp_recall >= en_recall - 0.02,
        "multi-probe {mp_recall:.3} should not lose to entropy {en_recall:.3}"
    );
    assert!(mp_recall > 0.3, "multi-probe recall implausibly low: {mp_recall}");
}
