//! SIMD-kernel differential: the full pipeline on the SIMD tier vs the
//! scalar oracle backends must be **bit-identical** — not ≥95% agreement
//! like the PJRT artifact path, exact equality of every (dist, id) pair
//! (DESIGN.md §Kernels).
//!
//! CI runs this twice: once on the detected tier (AVX2 on the hosted
//! runners) and once with `PARLSH_FORCE_SCALAR=1` pinning the dispatcher
//! to its scalar fallback, so both sides of the dispatch are exercised.

use parlsh::config::Config;
use parlsh::coordinator::{build_index, search};
use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::data::synth::{distorted_queries, synthesize, SynthSpec};
use parlsh::runtime::{kernels, ScalarHasher, ScalarRanker, SimdHasher, SimdRanker};

#[test]
fn kernels_full_pipeline_simd_equals_scalar_bit_exact() {
    let mut cfg = Config::default();
    cfg.lsh = LshParams { l: 4, m: 16, w: 900.0, k: 10, t: 8, seed: 5 };
    cfg.cluster.bi_nodes = 2;
    cfg.cluster.dp_nodes = 4;
    let ds = synthesize(SynthSpec { n: 3_000, clusters: 60, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 15, 5.0, 3);

    let fam = HashFamily::sample(ds.dim, cfg.lsh);
    let simd_hasher = SimdHasher::new(fam.clone());
    let simd_ranker = SimdRanker { dim: ds.dim };
    let mut c_simd = build_index(&cfg, &ds, &simd_hasher);
    let out_simd = search(&mut c_simd, &qs, &simd_hasher, &simd_ranker);

    let sc_hasher = ScalarHasher { family: fam };
    let sc_ranker = ScalarRanker { dim: ds.dim };
    let mut c_sc = build_index(&cfg, &ds, &sc_hasher);
    let out_sc = search(&mut c_sc, &qs, &sc_hasher, &sc_ranker);

    // Bit-identity, not tolerance: identical hashing means identical
    // buckets and candidates; identical + pruning-safe ranking means
    // identical (dist, id) results, on every tier.
    eprintln!("dispatch tier: {}", kernels::tier().name());
    assert_eq!(out_simd.results, out_sc.results);

    let dists_simd: u64 = out_simd.work.iter().map(|(_, _, w)| w.dists_computed).sum();
    let dists_sc: u64 = out_sc.work.iter().map(|(_, _, w)| w.dists_computed).sum();
    assert_eq!(dists_simd, dists_sc);
    let dups_simd: u64 = out_simd.work.iter().map(|(_, _, w)| w.dup_skipped).sum();
    let dups_sc: u64 = out_sc.work.iter().map(|(_, _, w)| w.dup_skipped).sum();
    assert_eq!(dups_simd, dups_sc);
    // The oracle never prunes (default rank_pruned); the SIMD ranker may,
    // but never more than it computed.
    let pruned_sc: u64 = out_sc.work.iter().map(|(_, _, w)| w.dists_pruned).sum();
    assert_eq!(pruned_sc, 0);
    let pruned_simd: u64 = out_simd.work.iter().map(|(_, _, w)| w.dists_pruned).sum();
    assert!(pruned_simd <= dists_simd);
}

#[test]
fn kernels_pruning_engages_and_surfaces_in_work_stats() {
    // k=1 on a single DP copy: after the first candidate of each request
    // the bound is a real distance, and 128-d candidate batches give the
    // partial-sum check 8 block boundaries to fire on — the pruned
    // counter must actually move (and flow into SearchOutput::work).
    let mut cfg = Config::default();
    cfg.lsh = LshParams { l: 4, m: 16, w: 900.0, k: 1, t: 16, seed: 7 };
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 1;
    let ds = synthesize(SynthSpec { n: 2_000, clusters: 40, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 10, 5.0, 11);

    let fam = HashFamily::sample(ds.dim, cfg.lsh);
    let hasher = SimdHasher::new(fam);
    let ranker = SimdRanker { dim: ds.dim };
    let mut cluster = build_index(&cfg, &ds, &hasher);
    let out = search(&mut cluster, &qs, &hasher, &ranker);

    let computed: u64 = out.work.iter().map(|(_, _, w)| w.dists_computed).sum();
    let pruned: u64 = out.work.iter().map(|(_, _, w)| w.dists_pruned).sum();
    assert!(computed > 0);
    assert!(
        pruned > 0,
        "k=1 over {computed} candidate distances never pruned — bound threading broken?"
    );
    assert!(pruned <= computed);
}
