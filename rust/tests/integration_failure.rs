//! Failure injection: what happens when the dataflow's invariants are
//! violated — messages lost, state corrupted, configs out of range. The
//! system must fail loudly (panic with a diagnostic or report stuck
//! queries), never silently return wrong answers.

use parlsh::config::Config;
use parlsh::core::lsh::LshParams;
use parlsh::stages::{AgState, BiState, DpState};
use parlsh::runtime::ScalarRanker;
use std::sync::Arc;

#[test]
fn lost_dp_message_leaves_query_stuck_not_wrong() {
    // Simulate a lost LocalTopK: AG knows (via BiMeta counts) that a DP
    // message is missing and keeps the query pending instead of emitting a
    // partial result.
    let mut ag = AgState::new(0);
    ag.on_query_meta(1, 1, 10);
    ag.on_bi_meta(1, 2); // two DP messages expected
    ag.on_local_topk(1, &[(1.0, 5)]);
    // second LocalTopK "lost"
    assert!(ag.results.is_empty(), "AG emitted a partial result");
    assert_eq!(ag.stuck_queries(), vec![1]);
}

#[test]
fn lost_bi_message_detected() {
    let mut ag = AgState::new(0);
    ag.on_query_meta(7, 3, 10); // three BIs contacted
    ag.on_bi_meta(7, 0);
    ag.on_bi_meta(7, 0);
    // third BiMeta lost
    assert!(ag.results.is_empty());
    assert_eq!(ag.stuck_queries(), vec![7]);
}

#[test]
#[should_panic(expected = "unknown object")]
fn misrouted_candidate_panics() {
    // A BI routing a candidate to the wrong DP is a partition-invariant
    // violation and must crash loudly.
    let mut dp = DpState::new(0, 4, 1, true);
    dp.on_store(1, &[0.0; 4]);
    let ranker = ScalarRanker { dim: 4 };
    let q: Arc<[f32]> = vec![0f32; 4].into();
    let mut out = Vec::new();
    dp.on_candidates(0, &[999], &q, 5, &ranker, &mut out);
}

#[test]
#[should_panic(expected = "stored twice")]
fn replicated_store_panics() {
    let mut dp = DpState::new(0, 4, 1, true);
    dp.on_store(1, &[0.0; 4]);
    dp.on_store(1, &[1.0; 4]);
}

#[test]
fn oversized_projection_bank_rejected() {
    let doc = parlsh::util::configfile::Doc::parse("[lsh]\nl = 16\nm = 32\n").unwrap();
    assert!(Config::from_doc(&doc).is_err());
}

#[test]
fn empty_bucket_index_answers_gracefully() {
    // Query against a BI with no buckets: zero candidates, empty results,
    // completion still reached.
    let mut bi = BiState::new(0, 1, 0);
    let mut ag = AgState::new(0);
    let q: Arc<[f32]> = vec![0f32; 4].into();
    let mut out = Vec::new();
    bi.on_query(0, &[(0, 12345)], &q, 10, &mut out);
    // forward only AG messages
    ag.on_query_meta(0, 1, 10);
    for (_, msg) in out {
        if let parlsh::dataflow::message::Msg::BiMeta { qid, n_dp } = msg {
            ag.on_bi_meta(qid, n_dp);
        }
    }
    assert_eq!(ag.results.len(), 1);
    assert!(ag.results[0].1.is_empty());
}

#[test]
fn bad_config_values_surface_errors() {
    use parlsh::util::cli::Args;
    // malformed config file
    let dir = std::env::temp_dir().join("parlsh_failure_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.toml");
    std::fs::write(&path, "lsh.l = = 3").unwrap();
    let args = Args::parse(vec![
        "search".to_string(),
        format!("--config={}", path.display()),
    ])
    .unwrap();
    assert!(Config::load(&args).is_err());
    // unknown strategy
    let doc =
        parlsh::util::configfile::Doc::parse("[stream]\nobj_map = \"fancy\"\n").unwrap();
    assert!(Config::from_doc(&doc).is_err());
}

#[test]
fn ranker_on_zero_candidates_is_empty() {
    let ranker = ScalarRanker { dim: 4 };
    use parlsh::runtime::Ranker;
    assert!(ranker.rank(&[0.0; 4], &[], 0, 5).is_empty());
}
