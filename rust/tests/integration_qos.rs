//! Multi-tenant QoS scheduler contracts (DESIGN.md §QoS scheduler):
//!
//! * mixed-tag differential — queries spread across gold/silver/catch-all
//!   tag classes (WFQ gates engaged) produce per-ticket results and option
//!   echoes bit-identical to the inline oracle on the threaded AND socket
//!   transports, and the per-tag SLO rows account for every query;
//! * starvation prevention — a flooding tag submitting concurrently with a
//!   light tag cannot zero the light tag's share: both drain completely
//!   (liveness) and the per-tag stats say so;
//! * adaptive probing — with `[qos] adaptive_probes` on, per-query budgets
//!   are resolved at submission and stamped into the wire plan, so the
//!   socket transport replays the inline oracle exactly, echoes included.

use parlsh::config::Config;
use parlsh::coordinator::session::IndexSession;
use parlsh::coordinator::{build_index, build_index_on, search};
use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::data::synth::{distorted_queries, synthesize, SynthSpec};
use parlsh::data::Dataset;
use parlsh::dataflow::exec::{Executor, InlineExecutor, ThreadedExecutor};
use parlsh::net::NetSession;
use parlsh::runtime::{Ranker, ScalarHasher, ScalarRanker};
use parlsh::QueryOptions;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

fn qos_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.lsh = LshParams { l: 4, m: 8, w: 600.0, k: 5, t: 8, seed: 3 };
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 2;
    cfg.cluster.ag_copies = 2;
    cfg.data.n = 1_000;
    cfg.stream.pending_cap = 6;
    cfg.qos.tags = "gold:4,silver:2,*:1".into();
    cfg
}

fn small_world(
    cfg: &Config,
    queries: usize,
) -> (Dataset, Dataset, ScalarHasher, Arc<dyn Ranker>) {
    let ds = synthesize(SynthSpec { n: cfg.data.n, clusters: 40, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, queries, 4.0, 7);
    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let ranker: Arc<dyn Ranker> = Arc::new(ScalarRanker { dim: ds.dim });
    (ds, qs, ScalarHasher { family }, ranker)
}

/// Heterogeneous plans spread over the tag classes: gold (1), silver (2),
/// the catch-all (0), and an unknown id (99 → catch-all).
fn tagged_plan(qi: usize) -> QueryOptions {
    QueryOptions {
        k: [0u32, 3][qi % 2],
        probes: [0u32, 1, 12][qi % 3],
        tables: 0,
        tag: [1u32, 2, 0, 99][qi % 4],
    }
}

type FullRow = (QueryOptions, Vec<(f32, u32)>);

/// Stream every query through one session on `exec` (submit in order,
/// claim as they arrive), returning per-query rows plus the close stats.
fn run_tagged_stream(
    exec: &dyn Executor,
    cfg: &Config,
    ds: &Dataset,
    qs: &Dataset,
    hasher: &ScalarHasher,
    ranker: &Arc<dyn Ranker>,
) -> (Vec<FullRow>, parlsh::coordinator::session::SessionStats) {
    let mut cluster = build_index_on(exec, cfg, ds, hasher);
    let session = IndexSession::attach(exec, &mut cluster, hasher, Some(ranker.clone()));
    let mut got: Vec<Option<FullRow>> = vec![None; qs.len()];
    for qi in 0..qs.len() {
        session.submit_with(qs.get(qi), tagged_plan(qi));
        while let Some((t, o, h, _)) = session.try_recv_full() {
            got[t.0 as usize] = Some((o, h));
        }
    }
    for (t, o, h, _) in session.drain_full() {
        got[t.0 as usize] = Some((o, h));
    }
    let stats = session.close();
    (got.into_iter().map(|r| r.expect("query completed")).collect(), stats)
}

/// The mixed-tag differential: `exec` must replay the inline oracle per
/// ticket (results AND option echoes, tags included) with the WFQ gates
/// engaged, and the per-tag SLO rows must account for every query.
fn assert_tagged_stream_matches_inline(exec: &dyn Executor, cfg: &Config) {
    let (ds, qs, hasher, ranker) = small_world(cfg, 16);
    let (oracle, _) = run_tagged_stream(&InlineExecutor, cfg, &ds, &qs, &hasher, &ranker);
    let (got, stats) = run_tagged_stream(exec, cfg, &ds, &qs, &hasher, &ranker);
    for (qi, (want, have)) in oracle.iter().zip(&got).enumerate() {
        assert_eq!(have.0, want.0, "option echo diverged for query {qi}");
        assert_eq!(have.1, want.1, "tagged query {qi} diverged from the inline oracle");
        assert_eq!(have.0.tag, tagged_plan(qi).tag, "tag echo lost for query {qi}");
    }

    // 16 queries cycle the tags [gold, silver, *, unknown→*]: 4 + 4 + 8.
    let rows: HashMap<&str, _> =
        stats.per_tag.iter().map(|r| (r.name.as_str(), r)).collect();
    assert_eq!(stats.per_tag.len(), 3, "gold, silver and the catch-all");
    for (name, want) in [("gold", 4u64), ("silver", 4), ("*", 8)] {
        let r = rows[name];
        assert_eq!((r.submitted, r.completed), (want, want), "class {name} miscounted");
        assert_eq!(r.outstanding, 0, "class {name} left queries in flight");
        assert_eq!(r.latency.count, want, "class {name} latency rows miscounted");
    }
    assert!(rows["gold"].weight == 4 && rows["silver"].weight == 2 && rows["*"].weight == 1);
}

#[test]
fn mixed_tags_match_inline_oracle_threaded() {
    assert_tagged_stream_matches_inline(&ThreadedExecutor, &qos_cfg());
}

#[test]
fn mixed_tags_match_inline_oracle_socket() {
    let cfg = qos_cfg();
    let bin = env!("CARGO_BIN_EXE_parlsh");
    let net = NetSession::launch_with_bin(Path::new(bin), &cfg, 128).expect("launch workers");
    assert_tagged_stream_matches_inline(net.executor(), &cfg);
    net.shutdown().expect("clean shutdown");
}

#[test]
fn flooding_tag_cannot_starve_light_tag() {
    // A flooder (32 queries, tag `flood`) and a light tenant (8 queries,
    // tag `light`) submit concurrently against a tight pending cap. WFQ
    // caps the flooder at its share, so the light tag always finds room:
    // the test completing at all is the liveness assertion, and the
    // per-tag rows prove nobody's work was dropped. Results still match
    // the inline oracle per ticket — fairness never changes answers.
    let mut cfg = qos_cfg();
    cfg.qos.tags = "flood:1,light:1".into();
    cfg.stream.pending_cap = 2;
    let (ds, qs, hasher, ranker) = small_world(&cfg, 40);
    let mut oracle_cluster = build_index(&cfg, &ds, &hasher);
    let oracle = search(&mut oracle_cluster, &qs, &hasher, &ranker);

    let mut cluster = build_index(&cfg, &ds, &hasher);
    let session =
        IndexSession::attach(&ThreadedExecutor, &mut cluster, &hasher, Some(ranker.clone()));
    let assignments: Vec<(usize, parlsh::QueryTicket)> = std::thread::scope(|s| {
        let submit_range = |range: std::ops::Range<usize>, tag: u32| {
            let session = &session;
            let qs = &qs;
            move || -> Vec<(usize, parlsh::QueryTicket)> {
                range
                    .map(|qi| {
                        let opts = QueryOptions { tag, ..Default::default() };
                        (qi, session.submit_with(qs.get(qi), opts))
                    })
                    .collect()
            }
        };
        let flood = s.spawn(submit_range(0..32, 1));
        let light = s.spawn(submit_range(32..40, 2));
        let mut v = flood.join().expect("flooder");
        v.extend(light.join().expect("light tenant"));
        v
    });

    let by_ticket: HashMap<u64, Vec<(f32, u32)>> = session
        .drain_full()
        .into_iter()
        .map(|(t, _, hits, _)| (t.0, hits))
        .collect();
    for (qi, t) in &assignments {
        assert_eq!(by_ticket[&t.0], oracle.results[*qi], "query {qi} diverged under WFQ");
    }
    let stats = session.close();
    let rows: HashMap<&str, _> =
        stats.per_tag.iter().map(|r| (r.name.as_str(), r)).collect();
    assert_eq!((rows["flood"].submitted, rows["flood"].completed), (32, 32));
    assert_eq!((rows["light"].submitted, rows["light"].completed), (8, 8));
    assert_eq!(rows["light"].outstanding, 0);
    assert_eq!(rows["*"].submitted, 0, "untagged class saw traffic from nowhere");
}

#[test]
fn adaptive_budgets_replay_identically_over_the_wire() {
    // `[qos] adaptive_probes` resolves each query's probe budget at
    // submission and stamps it into the wire plan, so the socket workers
    // replay the inline oracle bit-identically — echoes included — and
    // every echoed budget sits inside [1, adaptive_max], well under the
    // config's T (proof the adaptive policy, not the default, picked it).
    let mut cfg = qos_cfg();
    cfg.lsh.t = 30;
    cfg.qos.adaptive_probes = true;
    cfg.qos.adaptive_quantile = 0.5;
    cfg.qos.adaptive_max = 8;
    let (ds, qs, hasher, ranker) = small_world(&cfg, 12);

    let run = |exec: &dyn Executor| -> Vec<FullRow> {
        let mut cluster = build_index_on(exec, &cfg, &ds, &hasher);
        let session = IndexSession::attach(exec, &mut cluster, &hasher, Some(ranker.clone()));
        for qi in 0..qs.len() {
            session.submit_with(qs.get(qi), QueryOptions::default());
        }
        let mut got: Vec<Option<FullRow>> = vec![None; qs.len()];
        for (t, o, h, _) in session.drain_full() {
            got[t.0 as usize] = Some((o, h));
        }
        session.close();
        got.into_iter().map(|r| r.expect("query completed")).collect()
    };

    let inline = run(&InlineExecutor);
    for (qi, (o, _)) in inline.iter().enumerate() {
        assert!(
            (1..=8).contains(&o.probes),
            "query {qi}: adaptive budget {} outside [1, adaptive_max]",
            o.probes
        );
    }

    let bin = env!("CARGO_BIN_EXE_parlsh");
    let net = NetSession::launch_with_bin(Path::new(bin), &cfg, 128).expect("launch workers");
    let socket = run(net.executor());
    net.shutdown().expect("clean shutdown");
    assert_eq!(inline, socket, "adaptive plans diverged between inline and socket");
}
