//! Front-door contracts (DESIGN.md §Front door):
//!
//! * 64 concurrent TCP clients with mixed per-query plans get per-qid
//!   results and option echoes bit-identical to the inline oracle, on
//!   the threaded AND the socket (two-tier) backing;
//! * fairness — a flooding client cannot starve a light one: the light
//!   client's queries complete (bounded wait) while the hog saturates
//!   the backpressure window, and both match the oracle;
//! * QoS tags — with `[qos] tags` classes configured, a flooding *tag*
//!   cannot starve a light tag either (WFQ at session admission, under
//!   the per-conn fairness), on the threaded AND socket backings, with
//!   the per-tag SLO rows in `FrontStats` accounting for every query;
//! * disconnect robustness — a client killed mid-burst is evicted
//!   (counted, in-flight work orphaned) and the survivors' results stay
//!   bit-identical; the session keeps serving;
//! * hostile inputs over real TCP — garbage bytes, a v2 frame, a
//!   tampered handshake digest, an oversized length prefix, a corrupted
//!   checksum: each gets a *typed* `Stopped` reason and the server keeps
//!   serving a well-behaved client correctly; a truncated-then-closed
//!   frame is cleaned up without wedging;
//! * admission control — accepts over `front.max_conns` are refused
//!   with a typed notice and counted, and slots free on disconnect.
//!
//! The server runs on the test thread (the executor seam is borrowed,
//! not `Send`); every client is a plain TCP peer on a scoped thread.
//! Client failures and panics are funneled past the shutdown request so
//! a broken client turns into a test failure, never a wedged `serve`.

use parlsh::config::Config;
use parlsh::coordinator::session::IndexSession;
use parlsh::coordinator::Cluster;
use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::data::synth::{distorted_queries, synthesize, SynthSpec};
use parlsh::data::Dataset;
use parlsh::dataflow::exec::{Executor, InlineExecutor, ThreadedExecutor};
use parlsh::dataflow::message::{Dest, Msg, StageKind};
use parlsh::net::front::{self, Client};
use parlsh::net::{wire, NetSession};
use parlsh::runtime::{Ranker, ScalarHasher, ScalarRanker};
use parlsh::QueryOptions;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

const CLAIM_TIMEOUT: Duration = Duration::from_secs(30);

/// `(qid-derived query index, hits)` pairs one client claimed.
type Claimed = Vec<(usize, Vec<(f32, u32)>)>;

fn front_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.lsh = LshParams { l: 4, m: 8, w: 600.0, k: 5, t: 8, seed: 3 };
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 2;
    cfg.cluster.ag_copies = 2;
    cfg.stream.inflight = 2;
    cfg.stream.pending_cap = 16;
    cfg.data.n = 1_500;
    cfg
}

fn small_world(
    cfg: &Config,
    queries: usize,
) -> (Dataset, Dataset, ScalarHasher, Arc<dyn Ranker>) {
    let ds = synthesize(SynthSpec { n: cfg.data.n, clusters: 40, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, queries, 4.0, 7);
    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let ranker: Arc<dyn Ranker> = Arc::new(ScalarRanker { dim: ds.dim });
    (ds, qs, ScalarHasher { family }, ranker)
}

/// Expected `(option echo, hits)` per query index, from an inline
/// session grown by the same `insert` path the front server uses.
fn inline_oracle(
    cfg: &Config,
    ds: &Dataset,
    qs: &Dataset,
    hasher: &ScalarHasher,
    ranker: &Arc<dyn Ranker>,
    plans: &[QueryOptions],
) -> Vec<(QueryOptions, Vec<(f32, u32)>)> {
    let mut cfg = cfg.clone();
    cfg.stream.pending_cap = 0; // the oracle needs no backpressure window
    let mut cluster = Cluster::empty(&cfg, ds.dim);
    let session = IndexSession::attach(&InlineExecutor, &mut cluster, hasher, Some(ranker.clone()));
    session.insert(ds);
    for (qi, &p) in plans.iter().enumerate() {
        session.submit_with(qs.get(qi), p);
    }
    let mut out: Vec<Option<(QueryOptions, Vec<(f32, u32)>)>> = vec![None; plans.len()];
    for (t, o, h, _) in session.drain_full() {
        out[t.0 as usize] = Some((o, h));
    }
    session.close();
    out.into_iter().map(|x| x.expect("oracle query completed")).collect()
}

/// Stand up a front server over `exec` on a loopback listener, run
/// `drive(addr)` on a spawned thread, and return the serve-loop stats
/// plus drive's value. The server runs on the calling thread (the
/// executor seam is borrowed). A `Shutdown` request is always sent after
/// `drive` returns or panics, so `serve` cannot be left wedged; a drive
/// panic resurfaces after the server exits.
fn serve_with<T, F>(
    exec: &dyn Executor,
    cfg: &Config,
    ds: &Dataset,
    hasher: &ScalarHasher,
    ranker: &Arc<dyn Ranker>,
    drive: F,
) -> (front::FrontStats, T)
where
    T: Send,
    F: FnOnce(&str) -> T + Send,
{
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let mut cluster = Cluster::empty(cfg, ds.dim);
    let session = IndexSession::attach(exec, &mut cluster, hasher, Some(ranker.clone()));
    session.insert(ds);
    let (stats, out) = std::thread::scope(|s| {
        let h = s.spawn(|| {
            let out = catch_unwind(AssertUnwindSafe(|| drive(&addr)));
            Client::connect(&addr)
                .and_then(|c| c.shutdown_server())
                .expect("shutdown request");
            out
        });
        let stats = front::serve(listener, &session, cfg, ds.dim).expect("serve loop");
        (stats, h.join().expect("drive thread"))
    });
    session.close();
    let out = match out {
        Ok(v) => v,
        Err(p) => resume_unwind(p),
    };
    (stats, out)
}

/// The heterogeneous plan mix from the session tests: inherited and
/// explicit `k`, probe budgets across the range, truncated table sets,
/// every query tagged.
fn mixed_plan(qi: usize) -> QueryOptions {
    QueryOptions {
        k: [0u32, 1, 3][qi % 3],
        probes: [0u32, 1, 4, 12][qi % 4],
        tables: [0u32, 2][qi % 2],
        tag: 9000 + qi as u32,
    }
}

// ------------------------------------------------ 64-client differential

/// N concurrent clients, each pipelining its own slice of the query set
/// under its own plans, must see results and option echoes bit-identical
/// to the inline oracle — matched by qid, not arrival order.
fn assert_front_matches_oracle(exec: &dyn Executor, cfg: &Config) {
    const CLIENTS: usize = 64;
    const PER: usize = 2;
    let (ds, qs, hasher, ranker) = small_world(cfg, CLIENTS * PER);
    let plans: Vec<QueryOptions> = (0..qs.len()).map(mixed_plan).collect();
    let oracle = inline_oracle(cfg, &ds, &qs, &hasher, &ranker, &plans);

    type ClientOut = anyhow::Result<Vec<(usize, front::Completed)>>;
    let (stats, joined) = serve_with(exec, cfg, &ds, &hasher, &ranker, |addr: &str| {
        let joined: Vec<std::thread::Result<ClientOut>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..CLIENTS)
                .map(|ci| {
                    let (qs, plans) = (&qs, &plans);
                    s.spawn(move || -> ClientOut {
                        let mut client = Client::connect(addr)?;
                        client.set_read_timeout(Some(CLAIM_TIMEOUT))?;
                        assert_eq!(client.dim(), qs.dim, "handshake dim");
                        let mut sent = Vec::new();
                        for j in 0..PER {
                            let qi = ci * PER + j;
                            sent.push((client.submit(qs.get(qi), plans[qi])?, qi));
                        }
                        let mut out = Vec::new();
                        for _ in 0..PER {
                            let c = client.recv()?;
                            let &(_, qi) = sent
                                .iter()
                                .find(|&&(qid, _)| qid == c.qid)
                                .expect("completion for an unknown qid");
                            out.push((qi, c));
                        }
                        Ok(out)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        });
        joined
    });

    let mut seen = 0usize;
    for res in joined {
        let claimed = res.expect("client thread panicked").expect("client ran clean");
        for (qi, c) in claimed {
            let (want_o, want_h) = &oracle[qi];
            assert_eq!(&c.opts, want_o, "option echo diverged for query {qi}");
            assert_eq!(&c.hits, want_h, "query {qi} diverged from the inline oracle");
            assert_eq!(c.opts.tag, 9000 + qi as u32, "tag echo lost for query {qi}");
            seen += 1;
        }
    }
    assert_eq!(seen, CLIENTS * PER, "not every query completed");
    assert_eq!(stats.accepted, CLIENTS as u64 + 1, "64 clients + the stopper");
    assert_eq!(stats.queries, (CLIENTS * PER) as u64);
    assert_eq!(stats.completions, (CLIENTS * PER) as u64);
    assert_eq!(stats.evictions, 0, "a clean run evicted someone");
    assert_eq!(stats.refused, 0);
}

#[test]
fn front_64_clients_match_inline_oracle_threaded() {
    let cfg = front_cfg();
    assert_front_matches_oracle(&ThreadedExecutor, &cfg);
}

#[test]
fn front_64_clients_match_inline_oracle_socket() {
    // Two-tier topology: the front event loop fans external clients into
    // a session whose stages live in real worker processes.
    let cfg = front_cfg();
    let bin = env!("CARGO_BIN_EXE_parlsh");
    let net = NetSession::launch_with_bin(Path::new(bin), &cfg, 128).expect("launch workers");
    assert_front_matches_oracle(net.executor(), &cfg);
    net.shutdown().expect("clean worker shutdown");
}

// ------------------------------------------------------------- fairness

#[test]
fn flooding_client_does_not_starve_a_light_one() {
    // The hog pipelines 48 queries into a pending_cap=4 window and does
    // not claim anything until the light client is done. The light
    // client's 5 queries must complete (within the read timeout — a
    // starved client turns into a typed failure, not a hang) and both
    // clients' results must match the oracle.
    const HOG: usize = 48;
    const LIGHT: usize = 5;
    let mut cfg = front_cfg();
    cfg.stream.pending_cap = 4;
    let (ds, qs, hasher, ranker) = small_world(&cfg, HOG + LIGHT);
    let plans: Vec<QueryOptions> = (0..HOG + LIGHT)
        .map(|qi| QueryOptions { tag: 100 + qi as u32, ..Default::default() })
        .collect();
    let oracle = inline_oracle(&cfg, &ds, &qs, &hasher, &ranker, &plans);

    let (stats, (hog_res, light_res)) =
        serve_with(&ThreadedExecutor, &cfg, &ds, &hasher, &ranker, |addr: &str| {
            // Two generations: (1) the hog's flood is in, (2) the light
            // client is done. Waits sit outside every fallible section so
            // an error on one side can never deadlock the other.
            let gate = Barrier::new(2);
            std::thread::scope(|s| {
                let hog = s.spawn(|| -> anyhow::Result<Claimed> {
                    let flood = || -> anyhow::Result<Client> {
                        let mut c = Client::connect(addr)?;
                        c.set_read_timeout(Some(CLAIM_TIMEOUT))?;
                        for qi in 0..HOG {
                            c.submit(qs.get(qi), plans[qi])?;
                        }
                        Ok(c)
                    };
                    let flooded = flood();
                    gate.wait(); // flood is in; let the light client run
                    gate.wait(); // light client finished
                    let mut c = flooded?;
                    let mut got = Vec::new();
                    for _ in 0..HOG {
                        let done = c.recv()?;
                        got.push((done.qid as usize, done.hits));
                    }
                    Ok(got)
                });
                let light = s.spawn(|| -> anyhow::Result<Claimed> {
                    gate.wait();
                    let run = || -> anyhow::Result<Claimed> {
                        let mut c = Client::connect(addr)?;
                        c.set_read_timeout(Some(CLAIM_TIMEOUT))?;
                        for qi in HOG..HOG + LIGHT {
                            c.submit(qs.get(qi), plans[qi])?;
                        }
                        let mut got = Vec::new();
                        for _ in 0..LIGHT {
                            let done = c.recv()?;
                            got.push((HOG + done.qid as usize, done.hits));
                        }
                        Ok(got)
                    };
                    let res = run();
                    gate.wait();
                    res
                });
                (hog.join().expect("hog thread"), light.join().expect("light thread"))
            })
        });

    let light = light_res.expect("light client starved or failed");
    assert_eq!(light.len(), LIGHT);
    for (qi, hits) in &light {
        assert_eq!(hits, &oracle[*qi].1, "light client query {qi} diverged");
    }
    let hog = hog_res.expect("hog client failed");
    assert_eq!(hog.len(), HOG);
    for (qi, hits) in &hog {
        assert_eq!(hits, &oracle[*qi].1, "hog query {qi} diverged");
    }
    assert_eq!(stats.queries, (HOG + LIGHT) as u64);
    assert_eq!(stats.completions, (HOG + LIGHT) as u64);
    assert_eq!(stats.evictions, 0);
}

/// The QoS-tagged variant of the fairness scenario: the hog floods under
/// the `flood` tag class and sits on its completions; the light tenant
/// runs its queries under the `light` tag. WFQ at session admission caps
/// the flooder at its share of `pending_cap`, so the light tag always
/// finds room — asserted by the light client completing inside the read
/// timeout and by the per-tag SLO rows in [`front::FrontStats`].
fn assert_flooding_tag_does_not_starve_light_tag(exec: &dyn Executor, cfg: &Config) {
    const HOG: usize = 32;
    const LIGHT: usize = 5;
    let (ds, qs, hasher, ranker) = small_world(cfg, HOG + LIGHT);
    let plans: Vec<QueryOptions> = (0..HOG + LIGHT)
        .map(|qi| QueryOptions {
            tag: if qi < HOG { 1 } else { 2 },
            ..Default::default()
        })
        .collect();
    let oracle = inline_oracle(cfg, &ds, &qs, &hasher, &ranker, &plans);

    let (stats, (hog_res, light_res)) =
        serve_with(exec, cfg, &ds, &hasher, &ranker, |addr: &str| {
            let gate = Barrier::new(2);
            std::thread::scope(|s| {
                let hog = s.spawn(|| -> anyhow::Result<Claimed> {
                    let flood = || -> anyhow::Result<Client> {
                        let mut c = Client::connect(addr)?;
                        c.set_read_timeout(Some(CLAIM_TIMEOUT))?;
                        for qi in 0..HOG {
                            c.submit(qs.get(qi), plans[qi])?;
                        }
                        Ok(c)
                    };
                    let flooded = flood();
                    gate.wait(); // flood is in; let the light tenant run
                    gate.wait(); // light tenant finished
                    let mut c = flooded?;
                    let mut got = Vec::new();
                    for _ in 0..HOG {
                        let done = c.recv()?;
                        got.push((done.qid as usize, done.hits));
                    }
                    Ok(got)
                });
                let light = s.spawn(|| -> anyhow::Result<Claimed> {
                    gate.wait();
                    let run = || -> anyhow::Result<Claimed> {
                        let mut c = Client::connect(addr)?;
                        c.set_read_timeout(Some(CLAIM_TIMEOUT))?;
                        for qi in HOG..HOG + LIGHT {
                            c.submit(qs.get(qi), plans[qi])?;
                        }
                        let mut got = Vec::new();
                        for _ in 0..LIGHT {
                            let done = c.recv()?;
                            got.push((HOG + done.qid as usize, done.hits));
                        }
                        Ok(got)
                    };
                    let res = run();
                    gate.wait();
                    res
                });
                (hog.join().expect("hog thread"), light.join().expect("light thread"))
            })
        });

    let light = light_res.expect("light tag starved or failed");
    assert_eq!(light.len(), LIGHT);
    for (qi, hits) in &light {
        assert_eq!(hits, &oracle[*qi].1, "light-tag query {qi} diverged");
    }
    let hog = hog_res.expect("flooding tag failed");
    assert_eq!(hog.len(), HOG);
    for (qi, hits) in &hog {
        assert_eq!(hits, &oracle[*qi].1, "flood-tag query {qi} diverged");
    }
    assert_eq!(stats.completions, (HOG + LIGHT) as u64);
    assert_eq!(stats.evictions, 0);

    // The per-tag SLO rows surfaced through FrontStats account for every
    // query by class, nothing left outstanding, nothing bled into `*`.
    let rows: std::collections::HashMap<&str, _> =
        stats.per_tag.iter().map(|r| (r.name.as_str(), r)).collect();
    assert_eq!(stats.per_tag.len(), 3, "flood, light and the catch-all");
    assert_eq!((rows["flood"].submitted, rows["flood"].completed), (HOG as u64, HOG as u64));
    assert_eq!((rows["light"].submitted, rows["light"].completed), (LIGHT as u64, LIGHT as u64));
    assert_eq!(rows["light"].latency.count, LIGHT as u64);
    assert_eq!(rows["flood"].outstanding + rows["light"].outstanding, 0);
    assert_eq!(rows["*"].submitted, 0, "untagged class saw traffic from nowhere");
}

#[test]
fn front_flooding_tag_does_not_starve_light_tag_threaded() {
    let mut cfg = front_cfg();
    cfg.qos.tags = "flood:1,light:1".into();
    cfg.stream.pending_cap = 4;
    assert_flooding_tag_does_not_starve_light_tag(&ThreadedExecutor, &cfg);
}

#[test]
fn front_flooding_tag_does_not_starve_light_tag_socket() {
    let mut cfg = front_cfg();
    cfg.qos.tags = "flood:1,light:1".into();
    cfg.stream.pending_cap = 4;
    let bin = env!("CARGO_BIN_EXE_parlsh");
    let net = NetSession::launch_with_bin(Path::new(bin), &cfg, 128).expect("launch workers");
    assert_flooding_tag_does_not_starve_light_tag(net.executor(), &cfg);
    net.shutdown().expect("clean worker shutdown");
}

// -------------------------------------------------- disconnect mid-burst

#[test]
fn killed_client_mid_burst_is_evicted_and_survivors_stay_correct() {
    // A floods 56 queries, claims 2, and drops its socket with dozens
    // still parked/in flight. The server must evict it (logged, counted),
    // reclaim its window share, drain the orphans, and keep answering B
    // and C bit-identically to the oracle.
    const FLOOD: usize = 56;
    const SURV: usize = 10; // per survivor
    let mut cfg = front_cfg();
    cfg.stream.pending_cap = 4;
    let (ds, qs, hasher, ranker) = small_world(&cfg, FLOOD + 2 * SURV);
    let plans: Vec<QueryOptions> = (0..FLOOD + 2 * SURV).map(mixed_plan).collect();
    let oracle = inline_oracle(&cfg, &ds, &qs, &hasher, &ranker, &plans);

    let (stats, results) = serve_with(&ThreadedExecutor, &cfg, &ds, &hasher, &ranker, |addr: &str| {
        let dead = Barrier::new(3); // A has dropped; survivors proceed
        std::thread::scope(|s| {
            let a = s.spawn(|| {
                let burst = || -> anyhow::Result<Claimed> {
                    let mut c = Client::connect(addr)?;
                    c.set_read_timeout(Some(CLAIM_TIMEOUT))?;
                    for qi in 0..FLOOD {
                        c.submit(qs.get(qi), plans[qi])?;
                    }
                    // prove the burst is being served, then die mid-way
                    let mut got = Vec::new();
                    for _ in 0..2 {
                        let done = c.recv()?;
                        got.push((done.qid as usize, done.hits));
                    }
                    drop(c); // kill the socket with ~54 queries outstanding
                    Ok(got)
                };
                let res = burst();
                dead.wait();
                res
            });
            let survivor = |base: usize| {
                let (qs, plans, dead) = (&qs, &plans, &dead);
                move || -> anyhow::Result<Claimed> {
                    // a few queries while A is alive and flooding
                    let warmup = || -> anyhow::Result<(Client, Claimed)> {
                        let mut c = Client::connect(addr)?;
                        c.set_read_timeout(Some(CLAIM_TIMEOUT))?;
                        let mut got = Vec::new();
                        for qi in base..base + 3 {
                            c.submit(qs.get(qi), plans[qi])?;
                            let done = c.recv()?;
                            got.push((base + done.qid as usize, done.hits));
                        }
                        Ok((c, got))
                    };
                    let before = warmup();
                    dead.wait(); // A is gone; the survivor keeps going
                    let (mut c, mut got) = before?;
                    for qi in base + 3..base + SURV {
                        c.submit(qs.get(qi), plans[qi])?;
                        let done = c.recv()?;
                        got.push((base + done.qid as usize, done.hits));
                    }
                    Ok(got)
                }
            };
            let b = s.spawn(survivor(FLOOD));
            let c = s.spawn(survivor(FLOOD + SURV));
            (
                a.join().expect("client A"),
                b.join().expect("client B"),
                c.join().expect("client C"),
            )
        })
    });

    let (a_res, b_res, c_res) = results;
    for got in [
        a_res.expect("A's claimed prefix"),
        b_res.expect("survivor B"),
        c_res.expect("survivor C"),
    ] {
        for (qi, hits) in got {
            assert_eq!(hits, oracle[qi].1, "query {qi} diverged around the eviction");
        }
    }
    assert!(
        stats.evictions >= 1,
        "killing a client mid-burst was not recorded as an eviction: {stats:?}"
    );
    // A's 2 claims plus both survivors' full runs were delivered
    assert!(stats.completions >= (2 + 2 * SURV) as u64, "{stats:?}");
}

// ------------------------------------------------------- hostile inputs

/// Read frames off a raw socket until the typed `Stopped` goodbye
/// arrives (skipping the server `Hello` and any late completions).
fn read_goodbye(stream: &mut TcpStream) -> String {
    stream.set_read_timeout(Some(CLAIM_TIMEOUT)).expect("set timeout");
    loop {
        match wire::read_frame(stream, 64 << 20) {
            Ok(f) if f.kind == wire::FrameKind::Stopped => {
                return wire::decode_stopped(&f.payload).expect("stopped payload")
            }
            Ok(_) => continue,
            Err(e) => panic!("expected a typed Stopped goodbye, got: {e}"),
        }
    }
}

/// Complete a valid handshake on a raw socket; returns the stream.
fn raw_handshake(addr: &str) -> TcpStream {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(CLAIM_TIMEOUT)).expect("set timeout");
    let f = wire::read_frame(&mut s, 64 << 20).expect("server hello");
    assert_eq!(f.kind, wire::FrameKind::Hello);
    let hello = wire::decode_hello(&f.payload).expect("decode hello");
    let ok = wire::encode_frame(
        wire::FrameKind::HelloOk,
        &wire::encode_hello_ok(hello.node, hello.digest, hello.epoch),
    );
    s.write_all(&ok).expect("send HelloOk");
    s
}

/// A hand-built frame header: magic, version, kind, length. The crc
/// stays zero — every case built with this is rejected before the
/// checksum runs.
fn raw_header(version: u8, kind: u8, len: u32) -> [u8; 12] {
    let mut h = [0u8; 12];
    h[0..2].copy_from_slice(&wire::MAGIC.to_le_bytes());
    h[2] = version;
    h[3] = kind;
    h[4..8].copy_from_slice(&len.to_le_bytes());
    h
}

#[test]
fn hostile_frames_get_typed_rejections_and_the_server_keeps_serving() {
    let cfg = front_cfg();
    let (ds, qs, hasher, ranker) = small_world(&cfg, 4);
    let plans: Vec<QueryOptions> = (0..qs.len()).map(mixed_plan).collect();
    let oracle = inline_oracle(&cfg, &ds, &qs, &hasher, &ranker, &plans);

    let (stats, ()) = serve_with(&ThreadedExecutor, &cfg, &ds, &hasher, &ranker, |addr: &str| {
        // (a) not our protocol at all
        {
            let mut s = TcpStream::connect(addr).expect("connect");
            s.write_all(b"GET /index HTTP/1.1\r\nHost: parlsh\r\n\r\n").expect("write");
            let reason = read_goodbye(&mut s);
            assert!(reason.contains("bad frame magic"), "got: {reason}");
        }
        // (b) right magic, wrong wire version (a v2 peer)
        {
            let mut s = TcpStream::connect(addr).expect("connect");
            s.write_all(&raw_header(2, 1, 0)).expect("write");
            let reason = read_goodbye(&mut s);
            assert!(reason.contains("wire version 2"), "got: {reason}");
        }
        // (c) valid codec, tampered handshake digest
        {
            let mut s = TcpStream::connect(addr).expect("connect");
            s.set_read_timeout(Some(CLAIM_TIMEOUT)).expect("set timeout");
            let f = wire::read_frame(&mut s, 64 << 20).expect("server hello");
            let hello = wire::decode_hello(&f.payload).expect("decode hello");
            let ok = wire::encode_frame(
                wire::FrameKind::HelloOk,
                &wire::encode_hello_ok(hello.node, hello.digest ^ 1, hello.epoch),
            );
            s.write_all(&ok).expect("send tampered HelloOk");
            let reason = read_goodbye(&mut s);
            assert!(reason.contains("handshake digest mismatch"), "got: {reason}");
        }
        // (d) oversized length prefix after a clean handshake: rejected
        // from the header alone, before any payload is buffered
        {
            let mut s = raw_handshake(addr);
            s.write_all(&raw_header(wire::WIRE_VERSION, 3, u32::MAX)).expect("write");
            let reason = read_goodbye(&mut s);
            assert!(reason.contains("exceeds cap"), "got: {reason}");
        }
        // (e) corrupted payload: checksum mismatch
        {
            let mut s = raw_handshake(addr);
            let mut frame = wire::encode_frame(wire::FrameKind::Shutdown, b"x");
            let last = frame.len() - 1;
            frame[last] ^= 0xFF;
            s.write_all(&frame).expect("write");
            let reason = read_goodbye(&mut s);
            assert!(reason.contains("checksum mismatch"), "got: {reason}");
        }
        // (f) truncated frame, then a vanished peer: no goodbye possible,
        // but the connection must be cleaned up without wedging the loop
        {
            let mut s = raw_handshake(addr);
            let frame = wire::stage_frame(
                Dest { stage: StageKind::Qr, copy: 0 },
                &Msg::QueryVec {
                    qid: 0,
                    raw: Vec::new().into(),
                    v: qs.get(0).into(),
                    opts: QueryOptions::default(),
                },
            );
            s.write_all(&frame[..20]).expect("write prefix");
            drop(s);
        }
        // After all of that, a well-behaved client still gets exact
        // results with its option echoes.
        {
            let mut c = Client::connect(addr).expect("good client connect");
            c.set_read_timeout(Some(CLAIM_TIMEOUT)).expect("set timeout");
            let mut sent = Vec::new();
            for qi in 0..qs.len() {
                sent.push((c.submit(qs.get(qi), plans[qi]).expect("submit"), qi));
            }
            for _ in 0..qs.len() {
                let done = c.recv().expect("completion");
                let &(_, qi) =
                    sent.iter().find(|&&(qid, _)| qid == done.qid).expect("known qid");
                assert_eq!(done.opts, oracle[qi].0, "option echo diverged");
                assert_eq!(done.hits, oracle[qi].1, "good client diverged after hostiles");
            }
        }
    });

    // a..e are typed evictions; the truncated case (f) is a plain
    // disconnect with nothing admitted — cleaned up, not counted.
    assert_eq!(stats.evictions, 5, "typed rejections miscounted: {stats:?}");
    assert_eq!(stats.queries, 4);
    assert_eq!(stats.completions, 4);
    assert_eq!(stats.refused, 0);
}

// ------------------------------------------------------ admission limit

#[test]
fn accepts_over_max_conns_are_refused_with_a_typed_notice() {
    let mut cfg = front_cfg();
    cfg.front.max_conns = 2;
    let (ds, _, hasher, ranker) = small_world(&cfg, 1);

    let (stats, ()) = serve_with(&ThreadedExecutor, &cfg, &ds, &hasher, &ranker, |addr: &str| {
        {
            // two clients fill the table (receiving Hello proves the
            // server registered them)
            let _c1 = Client::connect(addr).expect("client 1");
            let _c2 = Client::connect(addr).expect("client 2");
            // the third is refused with a typed notice instead of a Hello
            let mut s = TcpStream::connect(addr).expect("connect");
            let reason = read_goodbye(&mut s);
            assert!(reason.contains("front server full"), "got: {reason}");
            // _c1/_c2 drop here: slots free on disconnect
        }
        // a new client (serve_with's stopper rides on this too) gets in
        // once the server notices the disconnects
        let deadline = Instant::now() + CLAIM_TIMEOUT;
        loop {
            match Client::connect(addr) {
                Ok(_) => break,
                Err(_) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(20))
                }
                Err(e) => panic!("slot never freed after disconnect: {e}"),
            }
        }
    });
    // at least the typed refusal above; retry probes racing the server's
    // EOF cleanup may have been refused a few more times
    assert!(stats.refused >= 1, "{stats:?}");
    // clients 1+2, the successful probe, and the stopper
    assert_eq!(stats.accepted, 4);
}
