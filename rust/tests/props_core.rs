//! Cross-module property tests (mini-proptest harness): randomized configs
//! and data, invariants that must hold for any of them.

use parlsh::baseline::SequentialLsh;
use parlsh::config::{Config, ObjMapStrategy};
use parlsh::coordinator::{build_index, search};
use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::data::synth::{distorted_queries, synthesize, SynthSpec};
use parlsh::runtime::{ScalarHasher, ScalarRanker};
use parlsh::util::minitest::{check, Gen};

fn random_cfg(g: &mut Gen) -> Config {
    let mut cfg = Config::default();
    cfg.lsh = LshParams {
        l: g.usize_in(1, 6),
        m: g.usize_in(2, 12),
        w: g.f32_in(200.0, 1500.0),
        k: g.usize_in(1, 10),
        t: g.usize_in(1, 24),
        seed: g.rng.next_u64(),
    };
    cfg.cluster.bi_nodes = g.usize_in(1, 4);
    cfg.cluster.dp_nodes = g.usize_in(1, 6);
    cfg.cluster.ag_copies = g.usize_in(1, 3);
    cfg.stream.obj_map = *g.pick(&[
        ObjMapStrategy::Mod,
        ObjMapStrategy::ZOrder,
        ObjMapStrategy::Lsh,
    ]);
    cfg.stream.agg_bytes = *g.pick(&[0usize, 1024, 65536]);
    cfg
}

#[test]
fn pipeline_equals_sequential_for_random_configs() {
    check("pipeline-vs-sequential", 8, |g| {
        let cfg = random_cfg(g);
        let n = g.usize_in(300, 1500);
        let ds = synthesize(SynthSpec {
            n,
            clusters: g.usize_in(5, 50),
            cluster_std: g.f32_in(4.0, 20.0),
            seed: g.rng.next_u64(),
            ..Default::default()
        });
        let (qs, _) = distorted_queries(&ds, 8, 5.0, g.rng.next_u64());
        let family = HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let ranker = ScalarRanker { dim: ds.dim };
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        let seq = SequentialLsh::build(&ds, cfg.lsh);
        for qi in 0..qs.len() {
            let (want, _) = seq.search(qs.get(qi), cfg.lsh.t, cfg.lsh.k);
            let got: Vec<u32> = out.results[qi].iter().map(|&(_, id)| id).collect();
            let want_ids: Vec<u32> = want.iter().map(|&(_, id)| id).collect();
            assert_eq!(got, want_ids, "cfg={:?}", cfg.lsh);
        }
    });
}

#[test]
fn traffic_accounting_conserved() {
    // logical = 2*(Query msgs) + 2*(CandidateReq msgs) minus local
    // deliveries is hard to predict exactly, but conservation holds:
    // packets <= logical, payload > 0 iff logical > 0, and aggregation
    // never changes logical/payload.
    check("traffic-conservation", 6, |g| {
        let mut cfg = random_cfg(g);
        let ds = synthesize(SynthSpec {
            n: g.usize_in(200, 800),
            clusters: 20,
            seed: g.rng.next_u64(),
            ..Default::default()
        });
        let (qs, _) = distorted_queries(&ds, 5, 5.0, 3);
        let family = HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let ranker = ScalarRanker { dim: ds.dim };

        cfg.stream.agg_bytes = 0;
        let mut c1 = build_index(&cfg, &ds, &hasher);
        let o1 = search(&mut c1, &qs, &hasher, &ranker);
        cfg.stream.agg_bytes = 32 * 1024;
        let mut c2 = build_index(&cfg, &ds, &hasher);
        let o2 = search(&mut c2, &qs, &hasher, &ranker);

        assert_eq!(o1.meter.logical_msgs, o2.meter.logical_msgs);
        assert_eq!(o1.meter.payload_bytes, o2.meter.payload_bytes);
        assert!(o2.meter.total_packets() <= o1.meter.total_packets());
        assert_eq!(o1.meter.total_packets(), o1.meter.logical_msgs);
        if o1.meter.logical_msgs > 0 {
            assert!(o1.meter.payload_bytes > 0);
        }
    });
}

#[test]
fn results_sorted_unique_and_within_k() {
    check("results-wellformed", 6, |g| {
        let cfg = random_cfg(g);
        let ds = synthesize(SynthSpec {
            n: g.usize_in(200, 1000),
            clusters: 10,
            seed: g.rng.next_u64(),
            ..Default::default()
        });
        let (qs, _) = distorted_queries(&ds, 6, 6.0, g.rng.next_u64());
        let family = HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let ranker = ScalarRanker { dim: ds.dim };
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        for r in &out.results {
            assert!(r.len() <= cfg.lsh.k);
            for w in r.windows(2) {
                assert!(w[0].0 <= w[1].0, "unsorted results");
            }
            let ids: std::collections::HashSet<u32> =
                r.iter().map(|&(_, id)| id).collect();
            assert_eq!(ids.len(), r.len(), "duplicate ids");
            for &(d, id) in r {
                assert!(d >= 0.0 && (id as usize) < ds.len());
            }
        }
    });
}

#[test]
fn per_core_topology_same_results_more_messages() {
    check("per-core-ablation", 4, |g| {
        let mut cfg = random_cfg(g);
        cfg.cluster.cores_per_node = 4;
        cfg.lsh.t = g.usize_in(4, 16);
        let ds = synthesize(SynthSpec {
            n: 800,
            clusters: 20,
            seed: g.rng.next_u64(),
            ..Default::default()
        });
        let (qs, _) = distorted_queries(&ds, 6, 5.0, 3);
        let family = HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let ranker = ScalarRanker { dim: ds.dim };

        cfg.cluster.per_core_copies = false;
        let mut c1 = build_index(&cfg, &ds, &hasher);
        let o1 = search(&mut c1, &qs, &hasher, &ranker);
        cfg.cluster.per_core_copies = true;
        let mut c2 = build_index(&cfg, &ds, &hasher);
        let o2 = search(&mut c2, &qs, &hasher, &ranker);

        // identical answers
        assert_eq!(o1.results, o2.results);
        // per-core topology partitions state 4x finer => never fewer
        // messages (usually many more).
        assert!(o2.meter.logical_msgs >= o1.meter.logical_msgs);
    });
}
