//! The multi-process differential contract (DESIGN.md §Transports): the
//! full build + search pipeline across real OS processes on loopback TCP
//! must be indistinguishable from the deterministic inline executor —
//! BI/DP state identical per bucket after build, top-k identical per query
//! after search — while reporting *measured* wire bytes and shutting every
//! worker down cleanly.
//!
//! Topology: 1 BI node + 2 DP nodes = 3 `parlsh worker` processes plus
//! this test process as the head node (4 OS processes total). Search runs
//! under closed-loop admission (`stream.inflight = 2`) with two AG copies,
//! the satellite cases of ISSUE 2. Cargo builds the `parlsh` binary for
//! integration tests and hands us its path via `CARGO_BIN_EXE_parlsh`.

use parlsh::config::Config;
use parlsh::coordinator::{build_index, build_index_on, search, search_on};
use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::data::synth::{distorted_queries, synthesize, SynthSpec};
use parlsh::data::Dataset;
use parlsh::dataflow::message::StageKind;
use parlsh::net::NetSession;
use parlsh::runtime::{ScalarHasher, ScalarRanker};
use std::collections::BTreeMap;
use std::path::Path;

fn net_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.lsh = LshParams { l: 4, m: 8, w: 600.0, k: 5, t: 8, seed: 3 };
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 2;
    cfg.cluster.ag_copies = 2;
    cfg.stream.inflight = 2;
    cfg.data.n = 1_500;
    cfg
}

fn small_world(cfg: &Config, queries: usize) -> (Dataset, Dataset, ScalarHasher, ScalarRanker) {
    let ds = synthesize(SynthSpec { n: cfg.data.n, clusters: 40, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, queries, 4.0, 7);
    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let ranker = ScalarRanker { dim: ds.dim };
    (ds, qs, ScalarHasher { family }, ranker)
}

#[test]
fn loopback_multiprocess_build_and_search_match_inline() {
    let cfg = net_cfg();
    let (ds, qs, hasher, ranker) = small_world(&cfg, 15);

    // The oracle: deterministic inline executor, in-process.
    let mut inline_cluster = build_index(&cfg, &ds, &hasher);
    let inline_out = search(&mut inline_cluster, &qs, &hasher, &ranker);

    // The system under test: 3 worker processes + this driver.
    let bin = env!("CARGO_BIN_EXE_parlsh");
    let sess = NetSession::launch_with_bin(Path::new(bin), &cfg, ds.dim).expect("launch workers");
    let mut net_cluster = build_index_on(sess.executor(), &cfg, &ds, &hasher);

    // --- build: state-identical per bucket, across process boundaries ---
    let state = sess.fetch_state().expect("fetch worker state");
    assert_eq!(state.len(), 3, "one dump per worker");
    let mut remote_bis: BTreeMap<u16, Vec<(u64, Vec<(u32, u16)>)>> = BTreeMap::new();
    let mut remote_dps: BTreeMap<u16, Vec<(u32, Vec<f32>)>> = BTreeMap::new();
    for (_node, ns) in state {
        for (copy, buckets) in ns.bis {
            assert!(remote_bis.insert(copy, buckets).is_none(), "BI copy hosted twice");
        }
        for (copy, objs) in ns.dps {
            assert!(remote_dps.insert(copy, objs).is_none(), "DP copy hosted twice");
        }
    }
    assert_eq!(remote_bis.len(), inline_cluster.bis.len());
    assert_eq!(remote_dps.len(), inline_cluster.dps.len());
    let mut stored = 0usize;
    for bi in &inline_cluster.bis {
        let want: Vec<(u64, Vec<(u32, u16)>)> = bi
            .buckets_snapshot()
            .into_iter()
            .map(|(k, v)| (k, v.clone()))
            .collect();
        assert_eq!(
            remote_bis[&bi.copy], want,
            "BI copy {} diverged across the wire",
            bi.copy
        );
    }
    for dp in &inline_cluster.dps {
        let want: Vec<(u32, Vec<f32>)> = dp
            .objects_snapshot()
            .into_iter()
            .map(|(id, v)| (id, v.to_vec()))
            .collect();
        assert_eq!(remote_dps[&dp.copy], want, "DP copy {} diverged across the wire", dp.copy);
        stored += want.len();
    }
    assert_eq!(stored, ds.len(), "no-replication invariant across processes");

    // Build traffic: message-for-message the same flow, but measured frame
    // bytes strictly exceed the wire_size model (headers + length prefixes).
    assert_eq!(
        net_cluster.build_meter.logical_msgs,
        inline_cluster.build_meter.logical_msgs
    );
    assert!(
        net_cluster.build_meter.payload_bytes > inline_cluster.build_meter.payload_bytes,
        "socket meter should carry real codec bytes"
    );

    // --- search: identical top-k under inflight=2 and ag_copies=2 ---
    let net_out = search_on(sess.executor(), &mut net_cluster, &qs, &hasher, &ranker);
    assert_eq!(inline_out.results, net_out.results, "top-k diverged across the wire");
    assert_eq!(inline_out.meter.logical_msgs, net_out.meter.logical_msgs);
    assert_eq!(inline_out.meter.local_msgs, net_out.meter.local_msgs);

    // Work accounting is complete over the socket (FlushAck ships per-copy
    // WorkStats), not head-only: remote DP copies report real distance
    // counts, and the totals match the inline oracle exactly — DP dedup is
    // set-based per (query, copy), so the counts are arrival-order-free.
    let dists = |work: &[(StageKind, u16, parlsh::dataflow::metrics::WorkStats)]| -> u64 {
        work.iter().map(|(_, _, w)| w.dists_computed).sum()
    };
    assert!(
        net_out
            .work
            .iter()
            .any(|(s, _, w)| *s == StageKind::Dp && w.dists_computed > 0),
        "socket work stats are still head-only"
    );
    assert_eq!(dists(&net_out.work), dists(&inline_out.work), "socket dists diverged");
    let dups = |work: &[(StageKind, u16, parlsh::dataflow::metrics::WorkStats)]| -> u64 {
        work.iter().map(|(_, _, w)| w.dup_skipped).sum()
    };
    assert_eq!(dups(&net_out.work), dups(&inline_out.work), "socket dedup diverged");
    assert!(net_out.meter.payload_bytes > inline_out.meter.payload_bytes);
    assert!(net_out.meter.total_packets() > 0);
    // Per-link accounting covers both driver->worker and worker->driver
    // directions (QR fan-out and DP/BI results), with real bytes on each.
    let head = net_cluster.placement.head_node;
    let links = net_out.meter.links();
    assert!(
        links.keys().any(|&(src, _)| src == head),
        "no metered driver->worker link"
    );
    assert!(
        links.keys().any(|&(_, dst)| dst == head),
        "no metered worker->driver link"
    );
    for l in links.values() {
        assert!(l.bytes > 0 && l.packets > 0);
    }
    assert!(net_out.per_query_secs.iter().all(|&s| s > 0.0));

    // --- clean, typed shutdown: every worker exits with status 0 ---
    sess.shutdown().expect("clean shutdown");
}

#[test]
fn open_loop_single_ag_also_matches_inline() {
    // The default serving shape: open loop, one aggregator.
    let mut cfg = net_cfg();
    cfg.stream.inflight = 0;
    cfg.cluster.ag_copies = 1;
    cfg.data.n = 1_000;
    let (ds, qs, hasher, ranker) = small_world(&cfg, 10);

    let mut inline_cluster = build_index(&cfg, &ds, &hasher);
    let inline_out = search(&mut inline_cluster, &qs, &hasher, &ranker);

    let bin = env!("CARGO_BIN_EXE_parlsh");
    let sess = NetSession::launch_with_bin(Path::new(bin), &cfg, ds.dim).expect("launch workers");
    let mut net_cluster = build_index_on(sess.executor(), &cfg, &ds, &hasher);
    let net_out = search_on(sess.executor(), &mut net_cluster, &qs, &hasher, &ranker);
    assert_eq!(inline_out.results, net_out.results);
    sess.shutdown().expect("clean shutdown");
}
