//! Partition-strategy integration (paper §IV-C/§V-E): all three `obj_map`
//! strategies must return identical search *results* while differing in
//! where objects live — and the locality-aware strategies must cut BI→DP
//! fan-out on clustered data.

use parlsh::config::{Config, ObjMapStrategy};
use parlsh::coordinator::{build_index, search};
use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::data::synth::{distorted_queries, synthesize, SynthSpec};
use parlsh::partition::imbalance;
use parlsh::runtime::{ScalarHasher, ScalarRanker};

fn cfg_with(strategy: ObjMapStrategy) -> Config {
    let mut cfg = Config::default();
    cfg.lsh = LshParams { l: 4, m: 8, w: 700.0, k: 10, t: 16, seed: 9 };
    cfg.cluster.bi_nodes = 3;
    cfg.cluster.dp_nodes = 6;
    cfg.stream.obj_map = strategy;
    cfg
}

struct Run {
    results: Vec<Vec<(f32, u32)>>,
    logical_msgs: u64,
    payload: u64,
    dp_counts: Vec<usize>,
}

fn run(strategy: ObjMapStrategy, ds: &parlsh::data::Dataset, qs: &parlsh::data::Dataset) -> Run {
    let cfg = cfg_with(strategy);
    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let hasher = ScalarHasher { family };
    let ranker = ScalarRanker { dim: ds.dim };
    let mut cluster = build_index(&cfg, ds, &hasher);
    let out = search(&mut cluster, qs, &hasher, &ranker);
    Run {
        results: out.results,
        logical_msgs: out.meter.logical_msgs,
        payload: out.meter.payload_bytes,
        dp_counts: cluster.dp_object_counts(),
    }
}

#[test]
fn strategies_return_identical_results() {
    let ds = synthesize(SynthSpec { n: 5_000, clusters: 100, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 30, 5.0, 11);
    let m = run(ObjMapStrategy::Mod, &ds, &qs);
    let z = run(ObjMapStrategy::ZOrder, &ds, &qs);
    let l = run(ObjMapStrategy::Lsh, &ds, &qs);
    assert_eq!(m.results, z.results, "zorder changed search results");
    assert_eq!(m.results, l.results, "lsh partition changed search results");
    let _ = (m.payload, z.payload, l.payload);
}

#[test]
fn lsh_partition_reduces_messages_on_clustered_data() {
    let ds = synthesize(SynthSpec { n: 8_000, clusters: 80, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 50, 4.0, 13);
    let m = run(ObjMapStrategy::Mod, &ds, &qs);
    let l = run(ObjMapStrategy::Lsh, &ds, &qs);
    assert!(
        l.logical_msgs < m.logical_msgs,
        "lsh partition did not reduce messages: {} vs {}",
        l.logical_msgs,
        m.logical_msgs
    );
}

#[test]
fn mod_is_balanced_lsh_is_modest() {
    let ds = synthesize(SynthSpec { n: 8_000, clusters: 200, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 5, 4.0, 1);
    let m = run(ObjMapStrategy::Mod, &ds, &qs);
    let z = run(ObjMapStrategy::ZOrder, &ds, &qs);
    let l = run(ObjMapStrategy::Lsh, &ds, &qs);
    let im = imbalance(&m.dp_counts);
    let iz = imbalance(&z.dp_counts);
    let il = imbalance(&l.dp_counts);
    // mod: near-perfect balance (round-robin ids)
    assert!(im.max_over_mean_pct < 0.1, "mod imbalance {}", im.max_over_mean_pct);
    // LSH pays a bounded imbalance (paper: 1.8% at 10^9 points; the
    // relative deviation shrinks with points-per-partition, so it is much
    // larger at this scale but must stay within one order of the mean).
    assert!(il.max_over_mean_pct < 200.0, "lsh imbalance {}", il.max_over_mean_pct);
    // Z-order over sparse descriptors collapses (its fixed dimension
    // subsample lands on inactive bins) — the paper's real-SIFT behaviour;
    // we only require it to be *worse* than LSH here.
    assert!(
        iz.max_over_mean_pct > il.max_over_mean_pct,
        "zorder {} should be more imbalanced than lsh {}",
        iz.max_over_mean_pct,
        il.max_over_mean_pct
    );
}

#[test]
fn all_objects_stored_under_every_strategy() {
    let ds = synthesize(SynthSpec { n: 3_000, clusters: 30, ..Default::default() });
    for strategy in [ObjMapStrategy::Mod, ObjMapStrategy::ZOrder, ObjMapStrategy::Lsh] {
        let cfg = cfg_with(strategy);
        let family = HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let cluster = build_index(&cfg, &ds, &hasher);
        assert_eq!(cluster.stored_objects(), ds.len(), "{strategy:?}");
        let counts = cluster.dp_object_counts();
        assert_eq!(counts.iter().sum::<usize>(), ds.len());
    }
}
