//! Session-API contracts (DESIGN.md §Service API):
//!
//! * concurrent submitters — two threads interleaving `submit()` on one
//!   `IndexSession` get per-ticket results identical to the inline oracle,
//!   on the threaded and the socket executor;
//! * post-build `insert()` — growing the index through a session is
//!   state-identical to building over the concatenated dataset;
//! * the acceptance path — build → insert → search in ONE session over ONE
//!   worker launch (no re-handshake), answers matching the oracle and
//!   worker state matching the inline build per bucket;
//! * the storage-engine differential — query, insert mid-stream, query
//!   again, each round bit-identical to a fresh build over the dataset the
//!   index held at that point, on the inline, threaded AND socket
//!   transports (the arena/overlay re-compaction contract).

use parlsh::config::Config;
use parlsh::coordinator::session::IndexSession;
use parlsh::coordinator::{build_index, search, Cluster};
use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::data::synth::{distorted_queries, synthesize, SynthSpec};
use parlsh::data::Dataset;
use parlsh::dataflow::exec::{Executor, InlineExecutor, ThreadedExecutor};
use parlsh::dataflow::message::StageKind;
use parlsh::net::NetSession;
use parlsh::runtime::{Ranker, ScalarHasher, ScalarRanker};
use parlsh::QueryOptions;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

fn session_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.lsh = LshParams { l: 4, m: 8, w: 600.0, k: 5, t: 8, seed: 3 };
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 2;
    cfg.cluster.ag_copies = 2;
    cfg.stream.inflight = 2;
    cfg.data.n = 1_200;
    cfg
}

fn small_world(
    cfg: &Config,
    queries: usize,
) -> (Dataset, Dataset, ScalarHasher, Arc<dyn Ranker>) {
    let ds = synthesize(SynthSpec { n: cfg.data.n, clusters: 40, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, queries, 4.0, 7);
    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let ranker: Arc<dyn Ranker> = Arc::new(ScalarRanker { dim: ds.dim });
    (ds, qs, ScalarHasher { family }, ranker)
}

fn concat(a: &Dataset, b: &Dataset) -> Dataset {
    let mut out = Dataset::with_capacity(a.dim, a.len() + b.len());
    for i in 0..a.len() {
        out.push(a.get(i));
    }
    for i in 0..b.len() {
        out.push(b.get(i));
    }
    out
}

/// Two threads interleave submissions on one session; every ticket's
/// result must equal the inline oracle for the vector that thread
/// submitted — matched by ticket, not by arrival order.
fn assert_concurrent_submitters_match_oracle(exec: &dyn Executor, cfg: &Config) {
    let (ds, qs, hasher, ranker) = small_world(cfg, 16);
    let mut oracle_cluster = build_index(cfg, &ds, &hasher);
    let oracle = search(&mut oracle_cluster, &qs, &hasher, &ranker);

    // Build through the executor under test (under the socket transport
    // the index must land in the workers, not in this process).
    let mut cluster = parlsh::coordinator::build_index_on(exec, cfg, &ds, &hasher);
    let session = IndexSession::attach(exec, &mut cluster, &hasher, Some(ranker.clone()));
    let assignments: Vec<(usize, parlsh::QueryTicket)> = std::thread::scope(|s| {
        let submit_half = |start: usize| {
            let session = &session;
            let qs = &qs;
            move || -> Vec<(usize, parlsh::QueryTicket)> {
                (start..qs.len())
                    .step_by(2)
                    .map(|qi| (qi, session.submit(qs.get(qi))))
                    .collect()
            }
        };
        let even = s.spawn(submit_half(0));
        let odd = s.spawn(submit_half(1));
        let mut v = even.join().expect("even submitter");
        v.extend(odd.join().expect("odd submitter"));
        v
    });
    assert_eq!(assignments.len(), qs.len());

    let done = session.drain();
    assert_eq!(done.len(), qs.len());
    let by_ticket: HashMap<u64, Vec<(f32, u32)>> =
        done.into_iter().map(|(t, hits)| (t.0, hits)).collect();
    for (qi, ticket) in &assignments {
        assert_eq!(
            by_ticket[&ticket.0], oracle.results[*qi],
            "query {qi} (ticket {}) diverged from the inline oracle",
            ticket.0
        );
    }
    let stats = session.close();
    assert_eq!(stats.queries_submitted, qs.len() as u64);
    assert_eq!(stats.queries_completed, qs.len() as u64);
}

#[test]
fn concurrent_submitters_match_inline_oracle_threaded() {
    let cfg = session_cfg();
    assert_concurrent_submitters_match_oracle(&ThreadedExecutor, &cfg);
}

#[test]
fn concurrent_submitters_match_inline_oracle_socket() {
    let cfg = session_cfg();
    let bin = env!("CARGO_BIN_EXE_parlsh");
    let net = NetSession::launch_with_bin(Path::new(bin), &cfg, 128).expect("launch workers");
    assert_concurrent_submitters_match_oracle(net.executor(), &cfg);
    net.shutdown().expect("clean shutdown");
}

/// A deterministic heterogeneous plan mix: inherited and explicit `k`,
/// probe budgets from 1 to beyond the config T, full and truncated table
/// sets, tagged — the "two differently-shaped requests on one index"
/// scenario the per-query-plan redesign exists for.
fn mixed_plan(qi: usize) -> QueryOptions {
    QueryOptions {
        k: [0u32, 1, 3][qi % 3],
        probes: [0u32, 1, 4, 12][qi % 4],
        tables: [0u32, 2][qi % 2],
        tag: 7000 + qi as u32,
    }
}

/// Mixed-`QueryOptions` differential: interleaved queries with distinct
/// plans through `exec` must produce per-ticket results (and option
/// echoes) identical to the deterministic inline streaming oracle.
fn assert_mixed_options_match_inline(exec: &dyn Executor, cfg: &Config) {
    let (ds, qs, hasher, ranker) = small_world(cfg, 16);

    // Oracle: the same plans through the inline per-item-drain stream.
    let mut oracle_cluster = build_index(cfg, &ds, &hasher);
    let oracle = {
        let session = IndexSession::attach(
            &InlineExecutor,
            &mut oracle_cluster,
            &hasher,
            Some(ranker.clone()),
        );
        for qi in 0..qs.len() {
            session.submit_with(qs.get(qi), mixed_plan(qi));
        }
        let out = session.drain_full();
        session.close();
        out
    };
    assert_eq!(oracle.len(), qs.len());

    // Under test: same plans, interleaved submit/claim, through `exec`.
    let mut cluster = parlsh::coordinator::build_index_on(exec, cfg, &ds, &hasher);
    let session = IndexSession::attach(exec, &mut cluster, &hasher, Some(ranker.clone()));
    let mut got: Vec<Option<(QueryOptions, Vec<(f32, u32)>)>> = vec![None; qs.len()];
    for qi in 0..qs.len() {
        session.submit_with(qs.get(qi), mixed_plan(qi));
        while let Some((t, o, h, _)) = session.try_recv_full() {
            got[t.0 as usize] = Some((o, h));
        }
    }
    for (t, o, h, _) in session.drain_full() {
        got[t.0 as usize] = Some((o, h));
    }
    session.close();
    for (qi, (want_t, want_o, want_h, _)) in oracle.iter().enumerate() {
        assert_eq!(want_t.0 as usize, qi);
        let (o, h) = got[qi].as_ref().expect("query completed");
        assert_eq!(o, want_o, "option echo diverged for query {qi}");
        assert_eq!(h, want_h, "mixed-plan query {qi} diverged");
        assert!(h.len() <= o.k as usize, "query {qi} overflowed its k");
        assert_eq!(o.tag, 7000 + qi as u32, "tag echo lost");
    }
}

#[test]
fn mixed_options_match_inline_oracle_threaded() {
    let cfg = session_cfg();
    assert_mixed_options_match_inline(&ThreadedExecutor, &cfg);
}

#[test]
fn mixed_options_match_inline_oracle_socket() {
    // Distinct k and probes interleaved in one stream over real worker
    // processes (wire v3 carries the plan) — the acceptance scenario.
    let cfg = session_cfg();
    let bin = env!("CARGO_BIN_EXE_parlsh");
    let net = NetSession::launch_with_bin(Path::new(bin), &cfg, 128).expect("launch workers");
    assert_mixed_options_match_inline(net.executor(), &cfg);
    net.shutdown().expect("clean shutdown");
}

#[test]
fn submit_with_defaults_is_bit_identical_to_submit() {
    // `submit` must remain bit-identical to its pre-redesign behavior —
    // asserted against the pumped `search_on` oracle — and
    // `submit_with(default_from(cfg))` must match `submit` exactly.
    let cfg = session_cfg();
    let (ds, qs, hasher, ranker) = small_world(&cfg, 10);
    let mut c0 = build_index(&cfg, &ds, &hasher);
    let pumped = search(&mut c0, &qs, &hasher, &ranker);

    let run = |use_with: bool| -> Vec<Vec<(f32, u32)>> {
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let session =
            IndexSession::attach(&ThreadedExecutor, &mut cluster, &hasher, Some(ranker.clone()));
        for qi in 0..qs.len() {
            if use_with {
                session.submit_with(qs.get(qi), QueryOptions::default_from(&cfg));
            } else {
                session.submit(qs.get(qi));
            }
        }
        let mut out = vec![Vec::new(); qs.len()];
        for (t, hits) in session.drain() {
            out[t.0 as usize] = hits;
        }
        session.close();
        out
    };
    assert_eq!(run(false), pumped.results, "submit diverged from the pumped oracle");
    assert_eq!(run(true), pumped.results, "submit_with(defaults) diverged from submit");
}

#[test]
fn post_build_insert_matches_concatenated_build() {
    // build(ds1) then session.insert(ds2) must be state-identical — per
    // bucket, per insertion order — to build(ds1 ++ ds2).
    let cfg = session_cfg();
    let (ds1, _, hasher, ranker) = small_world(&cfg, 1);
    let ds2 = synthesize(SynthSpec { n: 300, clusters: 10, seed: 77, ..Default::default() });
    let both = concat(&ds1, &ds2);
    let want = build_index(&cfg, &both, &hasher);

    let mut cluster = build_index(&cfg, &ds1, &hasher);
    {
        let session = IndexSession::attach(&ThreadedExecutor, &mut cluster, &hasher, None);
        let range = session.insert(&ds2);
        assert_eq!(range, ds1.len() as u32..both.len() as u32);
        session.close();
    }
    let _ = ranker;

    assert_eq!(cluster.stored_objects(), both.len());
    assert_eq!(cluster.indexed_objects as usize, both.len());
    assert_eq!(cluster.bucket_references(), both.len() * cfg.lsh.l);
    for (a, b) in want.bis.iter().zip(&cluster.bis) {
        assert_eq!(
            a.buckets_snapshot(),
            b.buckets_snapshot(),
            "BI copy {} diverged from the concatenated build",
            a.copy
        );
    }
    for (a, b) in want.dps.iter().zip(&cluster.dps) {
        assert_eq!(
            a.objects_snapshot(),
            b.objects_snapshot(),
            "DP copy {} diverged from the concatenated build",
            a.copy
        );
    }
}

#[test]
fn socket_session_build_insert_search_without_rehandshake() {
    // The acceptance path: ONE worker launch, ONE session — build, then
    // post-build insert, then search, with no re-handshake in between.
    let cfg = session_cfg();
    let (ds1, _, hasher, ranker) = small_world(&cfg, 1);
    let ds2 = synthesize(SynthSpec { n: 300, clusters: 10, seed: 77, ..Default::default() });
    let both = concat(&ds1, &ds2);
    let (qs, _) = distorted_queries(&both, 12, 3.0, 5);

    let mut oracle_cluster = build_index(&cfg, &both, &hasher);
    let oracle = search(&mut oracle_cluster, &qs, &hasher, &ranker);

    let bin = env!("CARGO_BIN_EXE_parlsh");
    let net = NetSession::launch_with_bin(Path::new(bin), &cfg, both.dim).expect("launch workers");
    let mut cluster = Cluster::empty(&cfg, both.dim);
    {
        let session = IndexSession::attach(
            net.executor(),
            &mut cluster,
            &hasher,
            Some(ranker.clone()),
        );
        assert_eq!(session.insert(&ds1), 0..ds1.len() as u32);
        assert_eq!(session.insert(&ds2), ds1.len() as u32..both.len() as u32);

        let tickets: Vec<parlsh::QueryTicket> =
            (0..qs.len()).map(|qi| session.submit(qs.get(qi))).collect();
        let mut got: HashMap<u64, Vec<(f32, u32)>> = HashMap::new();
        while let Some((t, hits)) = session.recv() {
            got.insert(t.0, hits);
        }
        assert_eq!(got.len(), qs.len());
        for (qi, t) in tickets.iter().enumerate() {
            assert_eq!(got[&t.0], oracle.results[qi], "query {qi} diverged over the wire");
        }

        // Final accounting comes from close(): under the socket transport
        // the remote per-copy work arrives at the stream-finish barrier,
        // so a mid-stream stats() snapshot would not include it yet.
        let stats = session.close();
        assert_eq!(stats.objects_indexed as usize, both.len());
        assert_eq!(stats.queries_completed, qs.len() as u64);
        assert!(stats.build_meter.logical_msgs > 0);
        assert!(stats.search_meter.payload_bytes > 0);
        // work accounting is complete: remote DP copies reported theirs
        assert!(
            stats
                .work
                .iter()
                .any(|(s, _, w)| *s == StageKind::Dp && w.dists_computed > 0),
            "session work stats are head-only under the socket transport"
        );
    }

    // Worker-side state after build + insert == the inline concatenated
    // build, per bucket (the index really grew in the running workers).
    let state = net.fetch_state().expect("fetch worker state");
    let mut remote_bis = HashMap::new();
    let mut remote_dps = HashMap::new();
    for (_node, ns) in state {
        for (copy, buckets) in ns.bis {
            remote_bis.insert(copy, buckets);
        }
        for (copy, objs) in ns.dps {
            remote_dps.insert(copy, objs);
        }
    }
    for bi in &oracle_cluster.bis {
        assert_eq!(
            remote_bis[&bi.copy],
            bi.buckets_snapshot(),
            "BI copy {} diverged",
            bi.copy
        );
    }
    let mut stored = 0usize;
    for dp in &oracle_cluster.dps {
        let want: Vec<(u32, Vec<f32>)> = dp
            .objects_snapshot()
            .into_iter()
            .map(|(id, v)| (id, v.to_vec()))
            .collect();
        assert_eq!(remote_dps[&dp.copy], want, "DP copy {} diverged", dp.copy);
        stored += want.len();
    }
    assert_eq!(stored, both.len(), "no-replication invariant after insert");

    net.shutdown().expect("clean shutdown");
}

#[test]
fn socket_streaming_admission_matches_oracle_interleaved() {
    // Streaming admission over the wire: one worker launch, one session,
    // queries submitted one at a time with completions claimed as they
    // arrive (submit → recv → submit ...), under a pipeline window and a
    // session backpressure cap. Results must match the inline oracle per
    // ticket, and a second stream on the same session (after an insert
    // barrier) must see the grown index.
    let mut cfg = session_cfg();
    cfg.stream.pending_cap = 4;
    let (ds, qs, hasher, ranker) = small_world(&cfg, 12);
    let mut oracle_cluster = build_index(&cfg, &ds, &hasher);
    let oracle = search(&mut oracle_cluster, &qs, &hasher, &ranker);

    let bin = env!("CARGO_BIN_EXE_parlsh");
    let net = NetSession::launch_with_bin(Path::new(bin), &cfg, 128).expect("launch workers");
    let mut cluster = parlsh::coordinator::build_index_on(net.executor(), &cfg, &ds, &hasher);
    {
        let session = IndexSession::attach(
            net.executor(),
            &mut cluster,
            &hasher,
            Some(ranker.clone()),
        );
        for qi in 0..qs.len() {
            let t = session.submit(qs.get(qi));
            let (got_t, hits) = session.recv().expect("completion for the one in flight");
            assert_eq!(got_t, t);
            assert_eq!(hits, oracle.results[qi], "query {qi} diverged over the wire");
        }
        assert!(session.recv().is_none());

        // insert acts as a stream barrier; the next submit reopens a
        // stream against the same hot worker connections
        let (dup, _) = distorted_queries(&ds, 1, 0.0, 3);
        let range = session.insert(&dup);
        let after = session.submit(dup.get(0));
        let (t, hits) = session.recv().expect("post-insert completion");
        assert_eq!(t, after);
        assert!(
            hits.iter().any(|&(_, id)| id == range.start),
            "post-insert streaming query missed the inserted object: {hits:?}"
        );

        let stats = session.close();
        assert_eq!(stats.queries_completed, qs.len() as u64 + 1);
        assert_eq!(stats.latency.count, qs.len() as u64 + 1);
        assert!(stats.search_meter.total_bytes() > 0, "no real wire bytes metered");
        // remote DP work came back through the stream barrier
        assert!(
            stats
                .work
                .iter()
                .any(|(s, _, w)| *s == StageKind::Dp && w.dists_computed > 0),
            "stream barrier lost the remote work counters"
        );
    }
    net.shutdown().expect("clean shutdown");
}

/// The storage-engine differential (DESIGN.md §Storage engine): the first
/// query round compacts the arena directory and the DP row index; the
/// insert then lands refs in the mutable overlay and rows in the staged
/// tail; the second round forces the lazy re-compaction merge on every
/// copy. Each round must be bit-identical to a fresh build over the
/// dataset the index held at that point.
fn assert_insert_mid_stream_matches_fresh_builds(exec: &dyn Executor, cfg: &Config) {
    let (ds1, _, hasher, ranker) = small_world(cfg, 1);
    let ds2 = synthesize(SynthSpec { n: 250, clusters: 10, seed: 55, ..Default::default() });
    let both = concat(&ds1, &ds2);
    let (qs, _) = distorted_queries(&both, 10, 3.0, 11);

    let mut pre_cluster = build_index(cfg, &ds1, &hasher);
    let pre = search(&mut pre_cluster, &qs, &hasher, &ranker);
    let mut post_cluster = build_index(cfg, &both, &hasher);
    let post = search(&mut post_cluster, &qs, &hasher, &ranker);

    let mut cluster = parlsh::coordinator::build_index_on(exec, cfg, &ds1, &hasher);
    let session = IndexSession::attach(exec, &mut cluster, &hasher, Some(ranker.clone()));
    let check_round = |oracle: &[Vec<(f32, u32)>], label: &str| {
        let tickets: Vec<parlsh::QueryTicket> =
            (0..qs.len()).map(|qi| session.submit(qs.get(qi))).collect();
        let by_ticket: HashMap<u64, Vec<(f32, u32)>> =
            session.drain().into_iter().map(|(t, hits)| (t.0, hits)).collect();
        for (qi, t) in tickets.iter().enumerate() {
            assert_eq!(by_ticket[&t.0], oracle[qi], "{label}: query {qi} diverged");
        }
    };
    check_round(&pre.results, "pre-insert round");
    assert_eq!(session.insert(&ds2), ds1.len() as u32..both.len() as u32);
    check_round(&post.results, "post-insert round");
    session.close();
}

#[test]
fn insert_mid_stream_compaction_differential_inline() {
    assert_insert_mid_stream_matches_fresh_builds(&InlineExecutor, &session_cfg());
}

#[test]
fn insert_mid_stream_compaction_differential_threaded() {
    assert_insert_mid_stream_matches_fresh_builds(&ThreadedExecutor, &session_cfg());
}

#[test]
fn insert_mid_stream_compaction_differential_socket() {
    let cfg = session_cfg();
    let bin = env!("CARGO_BIN_EXE_parlsh");
    let net = NetSession::launch_with_bin(Path::new(bin), &cfg, 128).expect("launch workers");
    assert_insert_mid_stream_matches_fresh_builds(net.executor(), &cfg);
    net.shutdown().expect("clean shutdown");
}
