//! PJRT artifact-path integration: compiled HLO vs the scalar oracle.
//!
//! These tests need `make artifacts` to have run; they are skipped (with a
//! loud message) when `artifacts/manifest.txt` is absent so `cargo test`
//! stays runnable from a fresh checkout.

use parlsh::config::Config;
use parlsh::coordinator::{build_index, search};
use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::data::synth::{distorted_queries, synthesize, SynthSpec};
use parlsh::runtime::engine::{Engine, EngineHasher, EngineRanker};
use parlsh::runtime::{Hasher, Ranker, ScalarHasher, ScalarRanker};
use parlsh::util::rng::Rng;
use std::sync::Arc;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("PARLSH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/manifest.txt")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir}/ (run `make artifacts`)");
        None
    }
}

fn engine() -> Option<Arc<Engine>> {
    artifacts_dir().map(|d| Arc::new(Engine::load(&d).expect("engine load")))
}

fn family() -> HashFamily {
    HashFamily::sample(
        128,
        LshParams { l: 6, m: 32, w: 900.0, k: 10, t: 8, seed: 5 },
    )
}

#[test]
fn engine_hash_matches_scalar() {
    let Some(e) = engine() else { return };
    let fam = family();
    e.set_family(&fam).unwrap();
    let hasher = EngineHasher { engine: e, p_used: fam.params.projections() };
    let scalar = ScalarHasher { family: fam.clone() };

    let mut rng = Rng::new(7);
    for rows in [1usize, 3, 64, 200] {
        let x: Vec<f32> = (0..rows * 128)
            .map(|_| rng.range_f32(0.0, 255.0))
            .collect();
        let got = hasher.hash_batch(&x, rows);
        let want = scalar.hash_batch(&x, rows);
        assert_eq!(got.len(), want.len());
        let mismatches = got.iter().zip(&want).filter(|(a, b)| a != b).count();
        // f32 boundary ties only
        assert!(
            mismatches * 1000 < got.len(),
            "rows={rows}: {mismatches}/{} coords differ",
            got.len()
        );
    }
}

#[test]
fn engine_proj_matches_scalar() {
    let Some(e) = engine() else { return };
    let fam = family();
    e.set_family(&fam).unwrap();
    let hasher = EngineHasher { engine: e, p_used: fam.params.projections() };
    let scalar = ScalarHasher { family: fam.clone() };
    let mut rng = Rng::new(9);
    let x: Vec<f32> = (0..5 * 128).map(|_| rng.range_f32(0.0, 255.0)).collect();
    let got = hasher.proj_batch(&x, 5);
    let want = scalar.proj_batch(&x, 5);
    for (g, w) in got.iter().zip(&want) {
        assert!(
            (g - w).abs() <= 1e-3 * w.abs().max(1.0),
            "proj diverged: {g} vs {w}"
        );
    }
}

#[test]
fn engine_rank_matches_scalar() {
    let Some(e) = engine() else { return };
    let fam = family();
    e.set_family(&fam).unwrap();
    let ranker = EngineRanker { engine: e };
    let scalar = ScalarRanker { dim: 128 };
    let mut rng = Rng::new(11);
    for n in [1usize, 10, 255, 256, 300, 1024, 5000] {
        let q: Vec<f32> = (0..128).map(|_| rng.range_f32(0.0, 255.0)).collect();
        let c: Vec<f32> = (0..n * 128).map(|_| rng.range_f32(0.0, 255.0)).collect();
        let got = ranker.rank(&q, &c, n, 10);
        let want = scalar.rank(&q, &c, n, 10);
        assert_eq!(got.len(), want.len(), "n={n}");
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.1, w.1, "n={n}: ids differ ({got:?} vs {want:?})");
            assert!((g.0 - w.0).abs() <= 1e-2 * w.0.max(1.0), "n={n}: dist differs");
        }
    }
}

#[test]
fn engine_rank_handles_fewer_candidates_than_k() {
    let Some(e) = engine() else { return };
    let fam = family();
    e.set_family(&fam).unwrap();
    let ranker = EngineRanker { engine: e };
    let mut rng = Rng::new(13);
    let q: Vec<f32> = (0..128).map(|_| rng.range_f32(0.0, 255.0)).collect();
    let c: Vec<f32> = (0..3 * 128).map(|_| rng.range_f32(0.0, 255.0)).collect();
    let got = ranker.rank(&q, &c, 3, 10);
    assert_eq!(got.len(), 3);
}

#[test]
fn full_pipeline_engine_equals_scalar_path() {
    let Some(e) = engine() else { return };
    let mut cfg = Config::default();
    cfg.lsh = LshParams { l: 4, m: 16, w: 900.0, k: 10, t: 8, seed: 5 };
    cfg.cluster.bi_nodes = 2;
    cfg.cluster.dp_nodes = 4;
    let ds = synthesize(SynthSpec { n: 3_000, clusters: 60, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, 15, 5.0, 3);

    let fam = HashFamily::sample(ds.dim, cfg.lsh);
    e.set_family(&fam).unwrap();
    let eng_hasher = EngineHasher { engine: e.clone(), p_used: cfg.lsh.projections() };
    let eng_ranker = EngineRanker { engine: e };
    let mut c_eng = build_index(&cfg, &ds, &eng_hasher);
    let out_eng = search(&mut c_eng, &qs, &eng_hasher, &eng_ranker);

    let sc_hasher = ScalarHasher { family: fam };
    let sc_ranker = ScalarRanker { dim: ds.dim };
    let mut c_sc = build_index(&cfg, &ds, &sc_hasher);
    let out_sc = search(&mut c_sc, &qs, &sc_hasher, &sc_ranker);

    // Hash boundary ties can move an object to a neighboring bucket, so a
    // tiny per-query result divergence is tolerated; require >=95% id
    // agreement overall and identical result counts.
    let mut agree = 0usize;
    let mut total = 0usize;
    for (a, b) in out_eng.results.iter().zip(&out_sc.results) {
        let bs: std::collections::HashSet<u32> = b.iter().map(|&(_, id)| id).collect();
        total += b.len();
        agree += a.iter().filter(|&&(_, id)| bs.contains(&id)).count();
    }
    assert!(
        agree * 100 >= total * 95,
        "engine/scalar agreement too low: {agree}/{total}"
    );
}

#[test]
fn engine_stats_track_calls() {
    let Some(e) = engine() else { return };
    let fam = family();
    e.set_family(&fam).unwrap();
    let before = *e.stats.lock().unwrap();
    let hasher = EngineHasher { engine: e.clone(), p_used: fam.params.projections() };
    let x = vec![1.0f32; 10 * 128];
    let _ = hasher.hash_batch(&x, 10);
    let after = *e.stats.lock().unwrap();
    assert!(after.hash_calls > before.hash_calls);
    assert_eq!(after.hash_rows - before.hash_rows, 10);
}
