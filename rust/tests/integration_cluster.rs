//! Replicated, self-healing worker topology (DESIGN.md §Cluster topology):
//! the chaos differential. With `cluster.replication = 2` every logical
//! BI/DP node is served by two worker processes; killing one mid-stream
//! must leave the answer stream bit-identical to the inline oracle (the
//! driver retargets in-flight queries to the surviving replica), and the
//! dead slot must rejoin the *same* session afterwards — restored from a
//! live sibling's `StateDump`, or fast-pathed from a persisted shard
//! (`coordinator/persist`), with stale shards fenced by epoch as a typed
//! [`WireError`].
//!
//! Topology: 1 BI node + 2 DP nodes, replication 2 → 6 worker slots plus
//! this test process as the head node (7 OS processes). The discovery test
//! starts its own `parlsh worker --join` fleet out of band and hands the
//! session a `[net] hosts` table instead of letting it spawn children.

use parlsh::config::{Config, ReplicaRoute};
use parlsh::coordinator::session::IndexSession;
use parlsh::coordinator::{build_index, build_index_on, search, search_on};
use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::data::synth::{distorted_queries, synthesize, SynthSpec};
use parlsh::data::Dataset;
use parlsh::net::wire::{self, FrameKind, WireError};
use parlsh::net::NetSession;
use parlsh::runtime::{Ranker, ScalarHasher, ScalarRanker};
use std::collections::HashMap;
use std::io::Write as _;
use std::net::TcpListener;
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;

fn cluster_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.lsh = LshParams { l: 4, m: 8, w: 600.0, k: 5, t: 8, seed: 3 };
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 2;
    cfg.cluster.ag_copies = 1;
    cfg.cluster.replication = 2;
    cfg.cluster.replica_route = ReplicaRoute::RoundRobin;
    cfg.stream.inflight = 0;
    cfg.data.n = 1_200;
    cfg
}

fn small_world(cfg: &Config, queries: usize) -> (Dataset, Dataset, ScalarHasher, ScalarRanker) {
    let ds = synthesize(SynthSpec { n: cfg.data.n, clusters: 40, ..Default::default() });
    let (qs, _) = distorted_queries(&ds, queries, 4.0, 7);
    let family = HashFamily::sample(ds.dim, cfg.lsh);
    let ranker = ScalarRanker { dim: ds.dim };
    (ds, qs, ScalarHasher { family }, ranker)
}

/// The replication oracle runs inline with `replication = 1`: replicas
/// hold byte-identical shards and a query only ever consults one replica
/// per logical node, so the replicated answer must match it exactly.
fn oracle_cfg(cfg: &Config) -> Config {
    let mut c = cfg.clone();
    c.cluster.replication = 1;
    c
}

/// Kill one replica mid-stream: every submitted query still completes,
/// bit-identical to the inline oracle, with at least one query retargeted;
/// the dead slot then rejoins the same session via a sibling `StateDump`.
#[test]
fn kill_replica_mid_stream_differential_and_rejoin() {
    let cfg = cluster_cfg();
    let (ds, qs, hasher, ranker) = small_world(&cfg, 120);
    let ranker: Arc<dyn Ranker> = Arc::new(ranker);

    let mut oracle_cluster = build_index(&oracle_cfg(&cfg), &ds, &hasher);
    let oracle = search(&mut oracle_cluster, &qs, &hasher, ranker.as_ref());
    let want: HashMap<u32, &Vec<(f32, u32)>> =
        oracle.results.iter().map(|(qid, hits)| (*qid, hits)).collect();

    let bin = env!("CARGO_BIN_EXE_parlsh");
    let sess = NetSession::launch_with_bin(Path::new(bin), &cfg, ds.dim).expect("launch");
    let mut net_cluster = build_index_on(sess.executor(), &cfg, &ds, &hasher);

    // Open-loop serving stream: submit half the load, kill one replica of
    // logical node 1 (slot 1; its sibling is slot 4), submit the rest.
    // The first 60 queries' ingress precedes the socket-close event in the
    // driver's FIFO, and any of them whose candidate hop targeted slot 1
    // can only complete through a retarget — so at least one must retry.
    {
        let session =
            IndexSession::attach(sess.executor(), &mut net_cluster, &hasher, Some(ranker.clone()));
        for qi in 0..60 {
            session.submit(qs.get(qi));
        }
        sess.kill_worker(1).expect("kill replica slot 1");
        for qi in 60..qs.len() {
            session.submit(qs.get(qi));
        }
        let got = session.drain();
        assert_eq!(got.len(), qs.len(), "every query must survive the replica loss");
        for (ticket, hits) in &got {
            assert_eq!(
                Some(&hits),
                want.get(&(ticket.0 as u32)),
                "query {} diverged from the oracle after the kill",
                ticket.0
            );
        }
        let stats = session.close();
        assert_eq!(stats.queries_completed, qs.len() as u64);
        assert!(
            stats.queries_retargeted >= 1,
            "the kill landed mid-stream; some in-flight query must have been retargeted"
        );
    }
    assert!(!sess.is_live(1), "the stream must have detected the death");
    assert_eq!(sess.n_dead(), 1);

    // Self-healing rejoin: no shard on disk, so the fresh worker joins at
    // epoch 0 and is restored from its live sibling's StateDump.
    sess.heal_worker(1).expect("heal slot 1");
    assert!(sess.is_live(1));
    assert_eq!(sess.n_dead(), 0);

    // The restored replica is byte-identical to its sibling (slots 1 and 4
    // serve the same logical node in the replica-major layout).
    let state = sess.fetch_state().expect("fetch state");
    assert_eq!(state.len(), 6, "one dump per live slot");
    let by_slot: HashMap<u16, &wire::NodeState> =
        state.iter().map(|(slot, ns)| (*slot, ns)).collect();
    assert_eq!(by_slot[&1].bis, by_slot[&4].bis, "restored BI state diverged");
    assert_eq!(by_slot[&1].dps, by_slot[&4].dps, "restored DP state diverged");

    // And the healed fleet still answers exactly like the oracle.
    let again = search_on(sess.executor(), &mut net_cluster, &qs, &hasher, ranker.as_ref());
    assert_eq!(oracle.results, again.results, "post-heal search diverged");

    sess.shutdown().expect("clean shutdown");
}

/// Persist-aware rejoin: a current shard fast-paths the handshake, a stale
/// shard is fenced as a typed `WireError::EpochFenced` (and the session
/// keeps serving on the survivor), and deleting it falls back to restore.
#[test]
fn persisted_shard_fast_path_and_stale_epoch_fence() {
    let mut cfg = cluster_cfg();
    let shard_dir = std::env::temp_dir()
        .join(format!("parlsh-shards-{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    cfg.sock.shard_dir = shard_dir.clone();
    let (ds, qs, hasher, ranker) = small_world(&cfg, 12);

    let mut oracle_cluster = build_index(&oracle_cfg(&cfg), &ds, &hasher);
    let oracle = search(&mut oracle_cluster, &qs, &hasher, &ranker);

    let bin = env!("CARGO_BIN_EXE_parlsh");
    let sess = NetSession::launch_with_bin(Path::new(bin), &cfg, ds.dim).expect("launch");
    let mut net_cluster = build_index_on(sess.executor(), &cfg, &ds, &hasher);
    let built_epoch = sess.epoch();
    assert!(built_epoch >= 1, "the build is a completed write phase");

    let paths = sess.persist_shards().expect("persist shards");
    assert_eq!(paths.len(), 6, "one shard file per live slot");
    for p in &paths {
        assert!(Path::new(p).exists(), "missing shard file {p}");
    }

    // Fast path: the respawned worker reloads its shard, answers with the
    // current epoch, and rejoins without a state transfer.
    sess.kill_worker(1).expect("kill");
    sess.heal_worker(1).expect("fast-path heal");
    assert!(sess.is_live(1));
    let out = search_on(sess.executor(), &mut net_cluster, &qs, &hasher, &ranker);
    assert_eq!(oracle.results, out.results, "fast-path rejoin diverged");

    // Grow the index: a second completed write phase bumps the epoch, so
    // the shard files on disk are now one epoch behind.
    let ds2 = synthesize(SynthSpec {
        n: 300,
        clusters: 40,
        seed: 99,
        ..Default::default()
    });
    let r1 = net_cluster.insert_objects_on(sess.executor(), ds2.as_flat(), ds2.len(), &hasher);
    let r2 = oracle_cluster.insert_objects_on(
        &parlsh::dataflow::exec::InlineExecutor,
        ds2.as_flat(),
        ds2.len(),
        &hasher,
    );
    assert_eq!(r1, r2, "inline and socket inserts must assign the same ids");
    assert!(sess.epoch() > built_epoch, "insert must bump the session epoch");

    // Stale-shard rejoin is fenced: typed rejection, slot stays dead,
    // session keeps serving on the surviving replica.
    sess.kill_worker(1).expect("kill again");
    let err = sess.heal_worker(1).expect_err("stale shard must be fenced");
    assert!(
        format!("{err:#}").contains("rejoin rejected"),
        "unexpected heal error: {err:#}"
    );
    assert!(
        matches!(err.downcast_ref::<WireError>(), Some(WireError::EpochFenced { .. })),
        "fencing must surface as a typed WireError: {err:#}"
    );
    assert!(!sess.is_live(1));
    let oracle2 = search(&mut oracle_cluster, &qs, &hasher, &ranker);
    let degraded = search_on(sess.executor(), &mut net_cluster, &qs, &hasher, &ranker);
    assert_eq!(oracle2.results, degraded.results, "degraded serving diverged");

    // Without the stale file the worker joins empty (epoch 0) and takes
    // the restore path instead.
    std::fs::remove_file(&paths[1]).expect("drop stale shard");
    sess.heal_worker(1).expect("restore-path heal");
    assert!(sess.is_live(1));
    assert_eq!(sess.n_dead(), 0);
    let healed = search_on(sess.executor(), &mut net_cluster, &qs, &hasher, &ranker);
    assert_eq!(oracle2.results, healed.results, "post-restore search diverged");

    sess.shutdown().expect("clean shutdown");
    std::fs::remove_dir_all(&shard_dir).ok();
}

/// Spawn one out-of-band `parlsh worker --join` process bound on loopback
/// and return it plus its announced address.
fn spawn_join_worker(bin: &str) -> (Child, String) {
    let mut child = Command::new(bin)
        .arg("worker")
        .arg("--join=127.0.0.1:0")
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn joined worker");
    let stdout = child.stdout.take().expect("worker stdout");
    let mut line = String::new();
    std::io::BufRead::read_line(&mut std::io::BufReader::new(stdout), &mut line)
        .expect("read announce");
    let addr = line
        .trim()
        .strip_prefix("PARLSH_WORKER_LISTEN ")
        .expect("announce line")
        .to_string();
    (child, addr)
}

/// Discovery membership: workers started out of band (`--join`) are found
/// through the `[net] hosts` table, the full build+search differential
/// holds, and every externally-owned process still exits 0 on shutdown.
#[test]
fn hosts_table_discovers_out_of_band_workers() {
    let cfg_shape = cluster_cfg();
    let bin = env!("CARGO_BIN_EXE_parlsh");
    let mut fleet: Vec<Child> = Vec::new();
    let mut addrs: Vec<String> = Vec::new();
    for _ in 0..6 {
        let (child, addr) = spawn_join_worker(bin);
        fleet.push(child);
        addrs.push(addr);
    }
    let mut cfg = cfg_shape;
    cfg.sock.hosts = addrs.join(",");
    let (ds, qs, hasher, ranker) = small_world(&cfg, 10);

    let mut oracle_cluster = build_index(&oracle_cfg(&cfg), &ds, &hasher);
    let oracle = search(&mut oracle_cluster, &qs, &hasher, &ranker);

    let sess = NetSession::launch_with_bin(Path::new(bin), &cfg, ds.dim).expect("discover fleet");
    assert!(
        sess.kill_worker(0).is_err(),
        "hosts mode owns no processes; chaos kills are the operator's job"
    );
    let mut net_cluster = build_index_on(sess.executor(), &cfg, &ds, &hasher);
    let out = search_on(sess.executor(), &mut net_cluster, &qs, &hasher, &ranker);
    assert_eq!(oracle.results, out.results, "discovered fleet diverged");
    sess.shutdown().expect("clean shutdown");

    // The session sent Shutdown but the processes are ours: every joined
    // worker must exit 0.
    for (slot, mut child) in fleet.into_iter().enumerate() {
        let status = child.wait().expect("join worker");
        assert!(status.success(), "joined worker {slot} exited with {status}");
    }
}

/// A hostile (or misconfigured) host that answers the handshake with the
/// wrong config digest is rejected at launch — the typed digest check, at
/// the wire level, against a fake worker this test scripts by hand.
#[test]
fn hostile_digest_rejected_at_launch() {
    let bin = env!("CARGO_BIN_EXE_parlsh");
    let (real_child, real_addr) = spawn_join_worker(bin);

    // The impostor: accepts the driver's connection, reads its Hello, and
    // echoes a HelloOk whose digest disagrees by one bit.
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind impostor");
    let hostile_addr = listener.local_addr().expect("impostor addr").to_string();
    let impostor = std::thread::spawn(move || {
        let (mut conn, _) = listener.accept().expect("accept driver");
        let frame = wire::read_frame(&mut conn, 64 << 20).expect("read hello");
        assert_eq!(frame.kind, FrameKind::Hello);
        let hello = wire::decode_hello(&frame.payload).expect("decode hello");
        let ok = wire::encode_frame(
            FrameKind::HelloOk,
            &wire::encode_hello_ok(hello.node, hello.digest ^ 1, 0),
        );
        conn.write_all(&ok).expect("send tampered ack");
        conn.flush().ok();
    });

    let mut cfg = Config::default();
    cfg.lsh = LshParams { l: 4, m: 8, w: 600.0, k: 5, t: 8, seed: 3 };
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 1;
    cfg.cluster.ag_copies = 1;
    cfg.sock.hosts = format!("{real_addr},{hostile_addr}");

    let err = NetSession::launch_with_bin(Path::new(bin), &cfg, 128)
        .err()
        .expect("tampered digest must fail the launch");
    assert!(
        format!("{err:#}").contains("rejected at launch"),
        "unexpected launch error: {err:#}"
    );
    impostor.join().expect("impostor thread");

    // The genuine worker is ours to reap; the failed launch never adopted it.
    let mut real_child = real_child;
    real_child.kill().ok();
    real_child.wait().ok();
}
