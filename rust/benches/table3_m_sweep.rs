//! Bench: regenerate paper Table III (hash-function count M vs time/recall).
//! Run via `cargo bench --bench table3_m_sweep`.

fn main() {
    println!("== Table III: M sweep (T=30, L=6) ==");
    println!("(paper: M=28 → 3463s/.80, M=30 → 265s/.73, M=32 → 262s/.66)");
    let t = std::time::Instant::now();
    parlsh::experiments::table3_m_sweep(&[28, 30, 32]).print();
    println!("[bench wall time: {:.1}s]", t.elapsed().as_secs_f64());
}
