//! Microbenchmarks of the serving hot paths (the criterion substitute):
//! scalar vs PJRT-artifact hashing and ranking, bucket lookups, probe
//! generation, top-k. Used by the §Perf optimization pass.
//! Run via `cargo bench --bench hotpath_micro`.

use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::core::multiprobe::probe_sequence;
use parlsh::core::topk::TopK;
use parlsh::data::sqdist;
use parlsh::metrics::Table;
use parlsh::runtime::{Hasher, Ranker, ScalarHasher, ScalarRanker};
use parlsh::util::rng::Rng;
use parlsh::util::timer::bench_loop;

fn main() {
    let mut rng = Rng::new(42);
    let dim = 128;
    let mut table = Table::new(&["op", "batch", "ns/item", "items/s"]);
    let mut row = |op: &str, batch: usize, secs_per_iter: f64, items: usize| {
        let ns = secs_per_iter * 1e9 / items as f64;
        table.row(&[
            op.into(),
            format!("{batch}"),
            format!("{ns:.0}"),
            format!("{:.2e}", 1e9 / ns),
        ]);
    };

    // --- scalar distance ---
    let pool: Vec<f32> = (0..1024 * dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
    let q: Vec<f32> = (0..dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
    let mut acc = 0f32;
    let mut i = 0usize;
    let per = bench_loop(0.3, 16, || {
        for c in 0..1024 {
            acc += sqdist(&q, &pool[((i + c) % 1024) * dim..((i + c) % 1024 + 1) * dim]);
        }
        i += 7;
    });
    std::hint::black_box(acc);
    row("sqdist (scalar)", 1024, per, 1024);

    // --- hashing: scalar vs engine ---
    let params = LshParams { l: 6, m: 32, w: 900.0, k: 10, t: 30, seed: 1 };
    let family = HashFamily::sample(dim, params);
    let scalar_hasher = ScalarHasher { family: family.clone() };
    for rows in [64usize, 1024] {
        let x: Vec<f32> = (0..rows * dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
        let per = bench_loop(0.3, 4, || {
            std::hint::black_box(scalar_hasher.hash_batch(&x, rows));
        });
        row("hash_batch (scalar)", rows, per, rows);
    }

    let engine = parlsh::experiments::engine();
    if let Some(e) = &engine {
        e.set_family(&family).unwrap();
        let hasher = parlsh::runtime::engine::EngineHasher {
            engine: e.clone(),
            p_used: params.projections(),
        };
        for rows in [64usize, 1024, 4096] {
            let x: Vec<f32> =
                (0..rows * dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
            let per = bench_loop(0.3, 4, || {
                std::hint::black_box(hasher.hash_batch(&x, rows));
            });
            row("hash_batch (PJRT)", rows, per, rows);
        }
    } else {
        println!("(no artifacts: engine rows skipped)");
    }

    // --- ranking: scalar vs engine ---
    let scalar_ranker = ScalarRanker { dim };
    for n in [256usize, 4096] {
        let c: Vec<f32> = (0..n * dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
        let per = bench_loop(0.3, 4, || {
            std::hint::black_box(scalar_ranker.rank(&q, &c, n, 10));
        });
        row("rank (scalar)", n, per, n);
    }
    if let Some(e) = &engine {
        let ranker = parlsh::runtime::engine::EngineRanker { engine: e.clone() };
        for n in [256usize, 4096] {
            let c: Vec<f32> = (0..n * dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
            let per = bench_loop(0.3, 4, || {
                std::hint::black_box(ranker.rank(&q, &c, n, 10));
            });
            row("rank (PJRT)", n, per, n);
        }
    }

    // --- probe-sequence generation ---
    let fracs: Vec<f32> = (0..32).map(|_| rng.f32()).collect();
    for t in [30usize, 120] {
        let per = bench_loop(0.2, 16, || {
            std::hint::black_box(probe_sequence(&fracs, t));
        });
        row("probe_sequence", t, per, 1);
    }

    // --- top-k ---
    let vals: Vec<f32> = (0..10_000).map(|_| rng.f32()).collect();
    let per = bench_loop(0.2, 8, || {
        let mut tk = TopK::new(10);
        for (i, &v) in vals.iter().enumerate() {
            tk.push(v, i as u32);
        }
        std::hint::black_box(tk.len());
    });
    row("topk push", 10_000, per, 10_000);

    println!("== hot-path microbenchmarks ==");
    table.print();
}
