//! Microbenchmarks of the serving hot paths (the criterion substitute):
//! scalar vs SIMD vs PJRT-artifact hashing and ranking, bucket lookups,
//! probe generation, top-k. Used by the §Perf optimization pass.
//! Run via `cargo bench --bench hotpath_micro`.
//!
//! Emits `BENCH_hotpath.json` and archives it under `bench_history/`
//! (git-SHA-stamped), so `parlsh experiment history` tracks the hot-path
//! trajectory across PRs. SIMD rows carry the detected dispatch tier in
//! the op label (e.g. `sqdist (simd/avx2)`); set `PARLSH_FORCE_SCALAR=1`
//! to pin the dispatcher to the scalar tier, and `PARLSH_BENCH_SECS` to
//! scale the per-op measurement window (CI smoke uses a small value).

use parlsh::core::lsh::{HashFamily, LshParams};
use parlsh::core::multiprobe::probe_sequence;
use parlsh::core::topk::TopK;
use parlsh::data::sqdist;
use parlsh::dataflow::message::{Dest, Msg};
use parlsh::metrics::Table;
use parlsh::runtime::{kernels, Hasher, Ranker, ScalarHasher, ScalarRanker, SimdHasher, SimdRanker};
use parlsh::stages::BiState;
use parlsh::store::{BucketDirectory, SeenFilter};
use parlsh::util::rng::Rng;
use parlsh::util::timer::bench_loop;
use std::collections::HashMap;

fn main() {
    let mut rng = Rng::new(42);
    let dim = 128;
    let secs: f64 = std::env::var("PARLSH_BENCH_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.3);
    let tier = kernels::tier().name();
    let mut table = Table::new(&["op", "batch", "ns/item", "items/s"]);
    let mut row = |op: &str, batch: usize, secs_per_iter: f64, items: usize| {
        let ns = secs_per_iter * 1e9 / items as f64;
        table.row(&[
            op.into(),
            format!("{batch}"),
            format!("{ns:.0}"),
            format!("{:.2e}", 1e9 / ns),
        ]);
    };

    // --- distance: scalar oracle vs dispatched SIMD ---
    let pool: Vec<f32> = (0..1024 * dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
    let q: Vec<f32> = (0..dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
    for batch in [64usize, 1024] {
        let mut acc = 0f32;
        let mut i = 0usize;
        let per = bench_loop(secs, 16, || {
            for c in 0..batch {
                let r = (i + c) % 1024;
                acc += sqdist(&q, &pool[r * dim..(r + 1) * dim]);
            }
            i += 7;
        });
        std::hint::black_box(acc);
        row("sqdist (scalar)", batch, per, batch);

        let mut acc = 0f32;
        let mut i = 0usize;
        let per = bench_loop(secs, 16, || {
            for c in 0..batch {
                let r = (i + c) % 1024;
                acc += kernels::sqdist(&q, &pool[r * dim..(r + 1) * dim]);
            }
            i += 7;
        });
        std::hint::black_box(acc);
        row(&format!("sqdist (simd/{tier})"), batch, per, batch);
    }

    // --- hashing: scalar vs SIMD vs engine ---
    let params = LshParams { l: 6, m: 32, w: 900.0, k: 10, t: 30, seed: 1 };
    let family = HashFamily::sample(dim, params);
    let scalar_hasher = ScalarHasher { family: family.clone() };
    let simd_hasher = SimdHasher::new(family.clone());
    for rows in [64usize, 1024] {
        let x: Vec<f32> = (0..rows * dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
        let per = bench_loop(secs, 4, || {
            std::hint::black_box(scalar_hasher.hash_batch(&x, rows));
        });
        row("hash_batch (scalar)", rows, per, rows);
        let per = bench_loop(secs, 4, || {
            std::hint::black_box(simd_hasher.hash_batch(&x, rows));
        });
        row(&format!("hash_batch (simd/{tier})"), rows, per, rows);
        let per = bench_loop(secs, 4, || {
            std::hint::black_box(scalar_hasher.proj_batch(&x, rows));
        });
        row("proj_batch (scalar)", rows, per, rows);
        let per = bench_loop(secs, 4, || {
            std::hint::black_box(simd_hasher.proj_batch(&x, rows));
        });
        row(&format!("proj_batch (simd/{tier})"), rows, per, rows);
    }

    let engine = parlsh::experiments::engine();
    if let Some(e) = &engine {
        e.set_family(&family).unwrap();
        let hasher = parlsh::runtime::engine::EngineHasher {
            engine: e.clone(),
            p_used: params.projections(),
        };
        for rows in [64usize, 1024, 4096] {
            let x: Vec<f32> =
                (0..rows * dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
            let per = bench_loop(secs, 4, || {
                std::hint::black_box(hasher.hash_batch(&x, rows));
            });
            row("hash_batch (PJRT)", rows, per, rows);
        }
    } else {
        println!("(no artifacts: engine rows skipped)");
    }

    // --- ranking: scalar vs SIMD+pruning vs engine ---
    let scalar_ranker = ScalarRanker { dim };
    let simd_ranker = SimdRanker { dim };
    for n in [256usize, 4096] {
        let c: Vec<f32> = (0..n * dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
        let per = bench_loop(secs, 4, || {
            std::hint::black_box(scalar_ranker.rank(&q, &c, n, 10));
        });
        row("rank (scalar)", n, per, n);
        let per = bench_loop(secs, 4, || {
            std::hint::black_box(simd_ranker.rank_pruned(&q, &c, n, 10));
        });
        row(&format!("rank (simd+prune/{tier})"), n, per, n);
    }
    if let Some(e) = &engine {
        let ranker = parlsh::runtime::engine::EngineRanker { engine: e.clone() };
        for n in [256usize, 4096] {
            let c: Vec<f32> = (0..n * dim).map(|_| rng.range_f32(0.0, 255.0)).collect();
            let per = bench_loop(secs, 4, || {
                std::hint::black_box(ranker.rank(&q, &c, n, 10));
            });
            row("rank (PJRT)", n, per, n);
        }
    }

    // --- probe-sequence generation ---
    let fracs: Vec<f32> = (0..32).map(|_| rng.f32()).collect();
    for t in [30usize, 120] {
        let per = bench_loop(secs.min(0.2), 16, || {
            std::hint::black_box(probe_sequence(&fracs, t));
        });
        row("probe_sequence", t, per, 1);
    }

    // --- top-k ---
    let vals: Vec<f32> = (0..10_000).map(|_| rng.f32()).collect();
    let per = bench_loop(secs.min(0.2), 8, || {
        let mut tk = TopK::new(10);
        for (i, &v) in vals.iter().enumerate() {
            tk.push(v, i as u32);
        }
        std::hint::black_box(tk.len());
    });
    row("topk push", 10_000, per, 10_000);

    // --- bucket lookup+scan: scattered HashMap vs arena directory ---
    // The storage-engine claim (DESIGN.md §Storage engine): binary search
    // on a sorted key table + a contiguous slice scan vs hashing into
    // scattered per-bucket heap allocations, on a BI-sized shard.
    let n_buckets = 1usize << 15;
    let refs_per = 8usize;
    let bkey = |b: usize| (b as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut map: HashMap<u64, Vec<(u32, u16)>> = HashMap::new();
    let mut dir = BucketDirectory::new();
    for b in 0..n_buckets {
        for r in 0..refs_per {
            let id = (b * refs_per + r) as u32;
            map.entry(bkey(b)).or_default().push((id, 0));
            dir.insert(bkey(b), id, 0);
        }
    }
    dir.compact();
    let batch = 1024usize;
    let mut acc = 0u64;
    let mut i = 0usize;
    let per = bench_loop(secs, 8, || {
        for c in 0..batch {
            let key = bkey((i + c * 7919) % n_buckets);
            if let Some(refs) = map.get(&key) {
                for &(id, _) in refs {
                    acc += id as u64;
                }
            }
        }
        i += 13;
    });
    std::hint::black_box(acc);
    row("bucket lookup+scan (hashmap)", batch, per, batch);
    let mut acc = 0u64;
    let mut i = 0usize;
    let per = bench_loop(secs, 8, || {
        for c in 0..batch {
            let key = bkey((i + c * 7919) % n_buckets);
            if let Some((refs, _summary)) = dir.lookup(key) {
                for &(id, _) in refs {
                    acc += id as u64;
                }
            }
        }
        i += 13;
    });
    std::hint::black_box(acc);
    row("bucket lookup+scan (arena)", batch, per, batch);

    // --- per-query seen-bitmap (the HashSet-dedup replacement) ---
    let mut filter = SeenFilter::default();
    filter.configure(dir.id_space(), dir.chunk_shift(), dir.chunk_caps());
    let n_ids = 8192usize;
    let ids: Vec<u32> = (0..n_ids)
        .map(|_| rng.below((n_buckets * refs_per) as u64) as u32)
        .collect();
    let per = bench_loop(secs.min(0.2), 8, || {
        filter.begin_query();
        let mut fresh = 0usize;
        for &id in &ids {
            fresh += filter.insert(id) as usize;
        }
        std::hint::black_box(fresh);
    });
    row("bitmap filter insert", n_ids, per, n_ids);

    // --- BI multiprobe with bucket-level pruning engaged ---
    // 512 ids shared by 64 probed buckets: after the first bucket's scan
    // every id chunk saturates, so the remaining 63 probes skip whole —
    // the archived row's op label carries the measured skip count (the
    // bucket_skipped > 0 acceptance evidence).
    let mut bi = BiState::new(0, 1, 0);
    for id in 0..512u32 {
        for b in 0..64u64 {
            bi.on_index_ref(b, id, 0);
        }
    }
    let probes: Vec<(u8, u64)> = (0..64).map(|b| (0u8, b as u64)).collect();
    let qv: std::sync::Arc<[f32]> = vec![0f32; dim].into();
    let mut emitted: Vec<(Dest, Msg)> = Vec::new();
    let mut qid = 0u32;
    bi.on_query(qid, &probes, &qv, 10, &mut emitted);
    let skipped_per_query = bi.work.bucket_skipped;
    let per = bench_loop(secs.min(0.2), 8, || {
        qid += 1;
        emitted.clear();
        bi.on_query(qid, &probes, &qv, 10, &mut emitted);
        std::hint::black_box(emitted.len());
    });
    row(
        &format!("bi multiprobe (bucket_skipped={skipped_per_query}/query)"),
        probes.len(),
        per,
        probes.len(),
    );

    println!("== hot-path microbenchmarks (dispatch tier: {tier}) ==");
    table.print();
    match table.write_json("BENCH_hotpath.json", "hotpath") {
        Ok(()) => match parlsh::experiments::archive_bench("BENCH_hotpath.json") {
            Ok(archived) => println!("(wrote BENCH_hotpath.json; archived {archived})"),
            Err(err) => println!("(wrote BENCH_hotpath.json; archive failed: {err})"),
        },
        Err(err) => println!("(BENCH_hotpath.json write failed: {err})"),
    }
}
