//! Bench: regenerate paper Figure 4 (execution time & recall vs probes T).
//! Run via `cargo bench --bench fig4_multiprobe`.

fn main() {
    println!("== Fig. 4: multi-probe trade-off (time & recall vs T) ==");
    println!("(paper: T 60→120 costs only 1.35x time; recall keeps rising)");
    let t = std::time::Instant::now();
    let pts = parlsh::experiments::multiprobe_sweep(&[1, 30, 60, 90, 120]);
    parlsh::experiments::fig4_table(&pts).print();
    println!("[bench wall time: {:.1}s]", t.elapsed().as_secs_f64());
}
