//! Bench: regenerate paper Figure 6 + §V-E (partition strategies).
//! Run via `cargo bench --bench fig6_partition`.

fn main() {
    println!("== Fig. 6: partition strategies (L=6 M=32 T=60) ==");
    println!("(paper: mod 246s ≈ zorder 242s; LSH ≥1.68x faster, fewer msgs;");
    println!(" imbalance: mod 0%, zorder 0.01%, lsh 1.80%)");
    let t = std::time::Instant::now();
    parlsh::experiments::fig6_partition().print();
    println!("[bench wall time: {:.1}s]", t.elapsed().as_secs_f64());
}
