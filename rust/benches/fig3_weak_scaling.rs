//! Bench: regenerate paper Figure 3 (weak-scaling efficiency).
//! Run via `cargo bench --bench fig3_weak_scaling`.

fn main() {
    println!("== Fig. 3: weak-scaling efficiency (modeled 51-node cluster) ==");
    println!("(paper: ~0.90 efficiency at 801 cores / 51 nodes)");
    let t = std::time::Instant::now();
    parlsh::experiments::fig3_weak_scaling().print();
    println!("[bench wall time: {:.1}s]", t.elapsed().as_secs_f64());
}
