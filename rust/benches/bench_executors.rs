//! Bench: the executor seam — inline FIFO vs threaded (open loop) vs
//! threaded with closed-loop batched admission, same build + search
//! workload on each. Scale with PARLSH_N / PARLSH_Q; the admission window
//! with PARLSH_INFLIGHT. Run via `cargo bench --bench bench_executors`.

fn main() {
    println!("== Executor comparison (DESIGN.md §Executor seam) ==");
    println!("(results identical across rows by the differential tests; only");
    println!(" build wall time, throughput and completion latency move)");
    let t = std::time::Instant::now();
    parlsh::experiments::executor_comparison().print();
    println!("[bench wall time: {:.1}s]", t.elapsed().as_secs_f64());
}
