//! Bench: regenerate paper Figure 5 (hash-table count L at iso-recall).
//! Run via `cargo bench --bench fig5_l_sweep`.

fn main() {
    println!("== Fig. 5: L sweep at iso-recall (~0.74) ==");
    println!("(paper: more tables → lower time at matched recall, more memory)");
    let t = std::time::Instant::now();
    parlsh::experiments::fig5_l_sweep(&[4, 6, 8], 0.74).print();
    println!("[bench wall time: {:.1}s]", t.elapsed().as_secs_f64());
}
