//! Bench: regenerate paper Table II (data volume & messages vs probes T).
//! Run via `cargo bench --bench table2_comm`.

fn main() {
    println!("== Table II: communication vs T ==");
    println!("(paper: T 60→120 grows volume 1.22x and messages 1.29x — sublinear)");
    let t = std::time::Instant::now();
    let pts = parlsh::experiments::multiprobe_sweep(&[1, 30, 60, 90, 120]);
    parlsh::experiments::table2(&pts).print();
    // the paper's headline ratios
    if pts.len() >= 2 {
        let t60 = pts.iter().find(|p| p.t == 60);
        let t120 = pts.iter().find(|p| p.t == 120);
        if let (Some(a), Some(b)) = (t60, t120) {
            println!(
                "T 60→120: volume x{:.2}, messages x{:.2} (paper: x1.22, x1.29)",
                b.payload_gb / a.payload_gb,
                b.logical_msgs as f64 / a.logical_msgs as f64
            );
        }
    }
    println!("[bench wall time: {:.1}s]", t.elapsed().as_secs_f64());
}
