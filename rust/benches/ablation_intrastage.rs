//! Bench: §V-B ablation — intra-stage parallelism (one multithreaded copy
//! per node) vs classic one-process-per-core MPI topology.
//! Run via `cargo bench --bench ablation_intrastage`.

fn main() {
    println!("== Ablation: intra-stage parallelism (paper §V-B) ==");
    println!("(paper: per-node copies exchange >6x fewer messages than per-core)");
    let t = std::time::Instant::now();
    parlsh::experiments::ablation_intrastage().print();
    println!();
    println!("== Ablation: labeled-stream message aggregation ==");
    parlsh::experiments::ablation_aggregation().print();
    println!();
    println!("== Ablation: async comm/compute overlap (cluster model) ==");
    parlsh::experiments::ablation_async().print();
    println!("[bench wall time: {:.1}s]", t.elapsed().as_secs_f64());
}
