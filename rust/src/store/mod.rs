//! Cache-conscious storage engine for the index-owning stages (DESIGN.md
//! §Storage engine).
//!
//! The paper's BI/DP decoupling exists because LSH's referential locality
//! is terrible; this module gives each stage a layout that makes the most
//! of what locality remains:
//!
//! * [`BucketDirectory`] — the BI bucket store: a sorted key table plus one
//!   contiguous `(id, dp)` refs arena addressed by `(offset, len)` spans,
//!   with a mutable overlay for live inserts that compacts into the arena
//!   at the insert/finish barriers. A probe is a binary search plus a
//!   contiguous slice scan — zero per-bucket `Vec`s, zero pointer chasing.
//! * [`SeenFilter`] — the per-query candidate bitmap behind the BI-side
//!   bucket pruning (Jafari et al., arXiv 1912.07101): an *exact*
//!   generation-stamped seen-bitmap over the dense id space, plus
//!   per-chunk saturation tracking that lets whole probed buckets be
//!   skipped when every reference is provably already seen
//!   (`WorkStats::bucket_skipped`). No false positives, by construction —
//!   results stay bit-identical to the unfiltered scan.
//! * [`RowIndex`] — the DP id→row map as a sorted SoA index over the flat
//!   `Dataset` rows (no per-id `HashMap` nodes), with an O(1) dense-id
//!   presence bitmap for eager duplicate detection.
//!
//! All three follow the same lifecycle: cheap appends while an index phase
//! is open, one compaction at the phase barrier (lazily, on the first
//! probe after the barrier), read-optimized layout in between. Snapshots
//! (`persist`, `StateDump`) merge the overlay on the fly so they are valid
//! in *any* phase and keep the historical orderings bit-for-bit.

pub mod bitmap;
pub mod directory;
pub mod rows;

pub use bitmap::SeenFilter;
pub use directory::BucketDirectory;
pub use rows::RowIndex;

use std::fmt;

/// Typed storage-contract violations, surfaced through the transports'
/// existing `Stopped` paths instead of crashing a worker process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StoreError {
    /// The same object id was routed to one DP copy twice — a replica
    /// fan-out / partitioning bug upstream (the paper's no-replication
    /// invariant: each object lives on exactly one DP copy).
    DuplicateObject { dp: u16, id: u32 },
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::DuplicateObject { dp, id } => {
                write!(f, "object {id} stored twice at DP {dp} (replication bug)")
            }
        }
    }
}

impl std::error::Error for StoreError {}
