//! Arena bucket directory: the BI bucket store as three parallel flat
//! arrays instead of a `HashMap<u64, Vec<(u32, u16)>>` of heap nodes.
//!
//! Layout (DESIGN.md §Storage engine):
//!
//! ```text
//! keys:      [ k0 | k1 | k2 | ... ]          sorted u64 bucket keys
//! spans:     [ (0,3) | (3,1) | (4,2) | ...]  (offset, len) into the arena
//! summaries: [ s0 | s1 | s2 | ... ]          per-bucket id-chunk bitmaps
//! arena:     [ r r r | r | r r | ... ]       one contiguous (id, dp) pool
//! ```
//!
//! A probe is `keys.binary_search` + one contiguous `arena` slice scan —
//! no per-bucket allocations, no pointer chasing. Live inserts go to a
//! mutable `overlay` map and are merged into the arena by [`compact`]
//! (called lazily at the first lookup after an insert/finish barrier, so
//! the read path always sees the flat layout). Per-bucket *insertion
//! order* — the ordering every snapshot consumer (PLSH/PLSD persist,
//! `StateDump` wire frames, the differential tests) asserts — is
//! preserved across compactions because the arena is append-ordered and
//! overlay refs are strictly newer than arena refs.
//!
//! Each bucket also carries a `u64` *chunk summary*: bit `c` is set iff
//! the bucket references an id in chunk `c` of the dense id space
//! (`id >> chunk_shift`). Together with the per-chunk distinct-id
//! capacities ([`chunk_caps`]) recomputed at compaction, this is the
//! bucket-level metadata behind the exact skip test in
//! [`crate::store::SeenFilter::all_seen`].
//!
//! [`compact`]: BucketDirectory::compact
//! [`chunk_caps`]: BucketDirectory::chunk_caps

use std::collections::HashMap;
use std::mem::size_of;

/// Sorted-key + refs-arena bucket store with an insert overlay. See the
/// module docs for the layout.
#[derive(Clone, Debug, Default)]
pub struct BucketDirectory {
    /// Sorted bucket keys, parallel to `spans` and `summaries`.
    keys: Vec<u64>,
    /// `(offset, len)` of each bucket's refs inside `arena`.
    spans: Vec<(u32, u32)>,
    /// Per-bucket id-chunk bitmaps (`1 << (id >> chunk_shift)` OR-ed over
    /// the bucket's refs).
    summaries: Vec<u64>,
    /// One contiguous `(object id, DP copy)` pool, bucket-major in key
    /// order, insertion-ordered within a bucket.
    arena: Vec<(u32, u16)>,
    /// Refs inserted since the last compaction, insertion-ordered per key.
    overlay: HashMap<u64, Vec<(u32, u16)>>,
    overlay_refs: usize,
    /// Distinct ids this directory references per id chunk — the
    /// saturation capacities for [`crate::store::SeenFilter`].
    chunk_caps: Vec<u32>,
    /// Chunk width exponent: ids map to chunk `id >> chunk_shift`; chosen
    /// at compaction so at most 64 chunks cover the id space.
    chunk_shift: u32,
    /// One past the largest id in the arena (0 when empty).
    id_space: u32,
}

impl BucketDirectory {
    pub fn new() -> BucketDirectory {
        BucketDirectory::default()
    }

    /// Insert one reference (index-build / live-insert path). Goes to the
    /// overlay; [`Self::compact`] folds it into the arena at the barrier.
    pub fn insert(&mut self, key: u64, id: u32, dp: u16) {
        self.overlay.entry(key).or_default().push((id, dp));
        self.overlay_refs += 1;
    }

    /// True when inserts are pending and lookups would miss them — the
    /// caller must [`Self::compact`] before probing.
    pub fn needs_compact(&self) -> bool {
        !self.overlay.is_empty()
    }

    /// Distinct bucket keys (arena + overlay).
    pub fn bucket_count(&self) -> usize {
        self.keys.len()
            + self
                .overlay
                .keys()
                .filter(|k| self.keys.binary_search(k).is_err())
                .count()
    }

    /// Total references held (arena + overlay).
    pub fn reference_count(&self) -> usize {
        self.arena.len() + self.overlay_refs
    }

    /// One past the largest id in the arena (0 when empty); the bitmap
    /// width for [`crate::store::SeenFilter::configure`].
    pub fn id_space(&self) -> u32 {
        self.id_space
    }

    pub fn chunk_shift(&self) -> u32 {
        self.chunk_shift
    }

    /// Distinct-id capacity of each chunk (recomputed at compaction).
    pub fn chunk_caps(&self) -> &[u32] {
        &self.chunk_caps
    }

    /// Probe one bucket: binary search + contiguous slice. Returns the
    /// refs span and the bucket's chunk summary. Only valid on a
    /// compacted directory (the overlay would be invisible here).
    #[inline]
    pub fn lookup(&self, key: u64) -> Option<(&[(u32, u16)], u64)> {
        debug_assert!(
            self.overlay.is_empty(),
            "lookup on a dirty directory (compact at the barrier first)"
        );
        let i = self.keys.binary_search(&key).ok()?;
        let (off, len) = self.spans[i];
        Some((&self.arena[off as usize..(off + len) as usize], self.summaries[i]))
    }

    /// Owned snapshot of every bucket, sorted by key, refs in insertion
    /// order — valid in any phase (merges the overlay on the fly without
    /// mutating, so mid-build persist/`StateDump` calls see live inserts).
    pub fn snapshot(&self) -> Vec<(u64, Vec<(u32, u16)>)> {
        let mut extra: Vec<u64> = self.overlay.keys().copied().collect();
        extra.sort_unstable();
        let mut out = Vec::with_capacity(self.keys.len() + extra.len());
        let (mut i, mut j) = (0, 0);
        while i < self.keys.len() || j < extra.len() {
            let key = match (self.keys.get(i), extra.get(j)) {
                (Some(&a), Some(&b)) => a.min(b),
                (Some(&a), None) => a,
                (None, Some(&b)) => b,
                (None, None) => break,
            };
            let mut refs: Vec<(u32, u16)> = Vec::new();
            if i < self.keys.len() && self.keys[i] == key {
                let (off, len) = self.spans[i];
                refs.extend_from_slice(&self.arena[off as usize..(off + len) as usize]);
                i += 1;
            }
            if j < extra.len() && extra[j] == key {
                // Overlay refs are strictly newer than arena refs, so
                // arena-then-overlay is insertion order.
                refs.extend_from_slice(&self.overlay[&key]);
                j += 1;
            }
            out.push((key, refs));
        }
        out
    }

    /// Merge the overlay into the arena and rebuild the chunk metadata.
    /// Returns whether anything changed. O(refs) plus one sort over the
    /// overlay's keys — a barrier-time cost, never on the probe path.
    pub fn compact(&mut self) -> bool {
        if self.overlay.is_empty() {
            return false;
        }
        let snap = self.snapshot();
        self.keys.clear();
        self.spans.clear();
        self.arena.clear();
        self.arena.reserve(snap.iter().map(|(_, r)| r.len()).sum());
        for (key, refs) in &snap {
            let off = self.arena.len() as u32;
            self.arena.extend_from_slice(refs);
            self.keys.push(*key);
            self.spans.push((off, refs.len() as u32));
        }
        self.overlay.clear();
        self.overlay_refs = 0;
        self.rebuild_chunks();
        true
    }

    /// Recompute `id_space`, `chunk_shift`, `chunk_caps`, and every
    /// bucket's summary from the (freshly compacted) arena.
    fn rebuild_chunks(&mut self) {
        let max_id = self.arena.iter().map(|&(id, _)| id).max();
        self.id_space = max_id.map_or(0, |m| m + 1);
        // Smallest shift with at most 64 chunks over [0, id_space).
        let mut shift = 0u32;
        while self.id_space > 0 && ((self.id_space - 1) >> shift) >= 64 {
            shift += 1;
        }
        self.chunk_shift = shift;
        let n_chunks = if self.id_space == 0 {
            0
        } else {
            (((self.id_space - 1) >> shift) + 1) as usize
        };
        self.chunk_caps.clear();
        self.chunk_caps.resize(n_chunks, 0);
        let mut distinct = vec![0u64; self.id_space as usize / 64 + 1];
        for &(id, _) in &self.arena {
            let (w, bit) = ((id / 64) as usize, 1u64 << (id % 64));
            if distinct[w] & bit == 0 {
                distinct[w] |= bit;
                self.chunk_caps[(id >> shift) as usize] += 1;
            }
        }
        self.summaries.clear();
        self.summaries.reserve(self.keys.len());
        for &(off, len) in &self.spans {
            let mut s = 0u64;
            for &(id, _) in &self.arena[off as usize..(off + len) as usize] {
                s |= 1u64 << (id >> shift);
            }
            self.summaries.push(s);
        }
    }

    /// Exact bytes resident in this directory (arena, tables, overlay).
    pub fn bytes_resident(&self) -> usize {
        let mut b = self.keys.len() * size_of::<u64>()
            + self.spans.len() * size_of::<(u32, u32)>()
            + self.summaries.len() * size_of::<u64>()
            + self.arena.len() * size_of::<(u32, u16)>()
            + self.chunk_caps.len() * size_of::<u32>();
        for refs in self.overlay.values() {
            b += size_of::<u64>() + refs.len() * size_of::<(u32, u16)>();
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::check;

    /// The reference model the arena layout must match bit-for-bit: the
    /// HashMap-of-Vecs store `BiState` used before the refactor.
    #[derive(Default)]
    struct ModelStore {
        buckets: HashMap<u64, Vec<(u32, u16)>>,
    }

    impl ModelStore {
        fn insert(&mut self, key: u64, id: u32, dp: u16) {
            self.buckets.entry(key).or_default().push((id, dp));
        }
        fn snapshot(&self) -> Vec<(u64, Vec<(u32, u16)>)> {
            let mut out: Vec<_> =
                self.buckets.iter().map(|(&k, v)| (k, v.clone())).collect();
            out.sort_by_key(|(k, _)| *k);
            out
        }
    }

    #[test]
    fn empty_directory() {
        let mut d = BucketDirectory::new();
        assert_eq!(d.bucket_count(), 0);
        assert_eq!(d.reference_count(), 0);
        assert!(!d.needs_compact());
        assert!(!d.compact());
        assert_eq!(d.lookup(42), None);
        assert!(d.snapshot().is_empty());
        assert_eq!(d.id_space(), 0);
    }

    #[test]
    fn insertion_order_survives_compaction_rounds() {
        let mut d = BucketDirectory::new();
        d.insert(7, 3, 0);
        d.insert(7, 1, 1);
        d.compact();
        // a second round appends *after* the arena refs of round one
        d.insert(7, 2, 0);
        d.insert(3, 9, 2);
        let snap = d.snapshot(); // dirty snapshot sees the overlay
        assert_eq!(snap, vec![(3, vec![(9, 2)]), (7, vec![(3, 0), (1, 1), (2, 0)])]);
        d.compact();
        assert_eq!(d.snapshot(), snap);
        let (refs, _) = d.lookup(7).unwrap();
        assert_eq!(refs, &[(3, 0), (1, 1), (2, 0)]);
    }

    #[test]
    fn snapshots_bit_identical_to_hashmap_model() {
        // The tentpole property: under random insert/compact/probe
        // sequences the directory's snapshot equals the HashMap reference
        // model's — same keys, same per-bucket insertion order.
        check("store-directory-vs-model", 60, |g| {
            let mut dir = BucketDirectory::new();
            let mut model = ModelStore::default();
            let n_keys = g.usize_in(1, 12);
            let n_ops = g.usize_in(0, 120);
            let mut next_id = 0u32;
            let mut inserted = 0usize;
            for _ in 0..n_ops {
                match g.usize_in(0, 9) {
                    // bias toward inserts; compact at random interior points
                    0 => {
                        dir.compact();
                    }
                    _ => {
                        let key = (g.usize_in(0, n_keys - 1) as u64) * 1_000_003;
                        let id = if g.bool() && next_id > 0 {
                            // duplicate ids across buckets are legal
                            g.usize_in(0, next_id as usize - 1) as u32
                        } else {
                            next_id += 1;
                            next_id - 1
                        };
                        let dp = g.usize_in(0, 3) as u16;
                        dir.insert(key, id, dp);
                        model.insert(key, id, dp);
                        inserted += 1;
                    }
                }
                assert_eq!(dir.snapshot(), model.snapshot());
            }
            assert_eq!(dir.reference_count(), inserted);
            assert_eq!(dir.bucket_count(), model.buckets.len());
            // after the final compaction every lookup equals the model
            dir.compact();
            assert_eq!(dir.snapshot(), model.snapshot());
            for (key, refs) in model.snapshot() {
                let (got, _) = dir.lookup(key).unwrap();
                assert_eq!(got, refs.as_slice());
            }
            assert_eq!(dir.lookup(u64::MAX), None);
        });
    }

    #[test]
    fn summaries_and_caps_describe_the_arena_exactly() {
        check("store-directory-chunks", 40, |g| {
            let mut dir = BucketDirectory::new();
            let n = g.usize_in(1, 200);
            let id_top = g.usize_in(1, 5000) as u32;
            for _ in 0..n {
                dir.insert(
                    g.usize_in(0, 6) as u64 * 17,
                    g.usize_in(0, id_top as usize) as u32,
                    0,
                );
            }
            dir.compact();
            let shift = dir.chunk_shift();
            let space = dir.id_space();
            assert!(space >= 1);
            // at most 64 chunks, and the shift is minimal
            assert!(((space - 1) >> shift) < 64);
            assert!(shift == 0 || ((space - 1) >> (shift - 1)) >= 64);
            // caps: distinct ids per chunk over the whole arena
            let mut distinct: Vec<std::collections::HashSet<u32>> =
                vec![Default::default(); 64];
            for (_, refs) in dir.snapshot() {
                for (id, _) in refs {
                    distinct[(id >> shift) as usize].insert(id);
                }
            }
            for (c, &cap) in dir.chunk_caps().iter().enumerate() {
                assert_eq!(cap as usize, distinct[c].len(), "chunk {c}");
            }
            // summaries: exactly the chunks each bucket touches
            for (key, refs) in dir.snapshot() {
                let (_, summary) = dir.lookup(key).unwrap();
                let want = refs
                    .iter()
                    .fold(0u64, |s, &(id, _)| s | 1u64 << (id >> shift));
                assert_eq!(summary, want, "key {key}");
            }
        });
    }

    #[test]
    fn bytes_resident_tracks_growth() {
        let mut d = BucketDirectory::new();
        let empty = d.bytes_resident();
        for i in 0..100 {
            d.insert(i % 7, i as u32, 0);
        }
        let dirty = d.bytes_resident();
        assert!(dirty > empty);
        d.compact();
        let compacted = d.bytes_resident();
        // the arena share: 100 refs at 8 bytes each must be accounted
        assert!(compacted >= 100 * size_of::<(u32, u16)>());
    }
}
