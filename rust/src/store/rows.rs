//! SoA row index for the DP object store: global object id → local row of
//! the flat `Dataset`, as two parallel sorted arrays instead of a
//! `HashMap<u32, u32>` of heap nodes.
//!
//! Same lifecycle as the bucket directory: stores append to a staged tail
//! in O(1), one merge-compaction at the phase barrier (lazily, at the
//! first candidate request after a build/insert), binary-search lookups on
//! the sorted arrays in between. Duplicate detection must stay *eager* —
//! a double store is a replication bug the transports surface as a typed
//! [`crate::store::StoreError`] the moment it happens — so membership is
//! tracked in an O(1) dense-id presence bitmap, independent of the sorted
//! arrays' compaction state.

use std::mem::size_of;

/// Sorted id→row index with an append-staged tail and an O(1) presence
/// bitmap over the dense id space.
#[derive(Clone, Debug, Default)]
pub struct RowIndex {
    /// Sorted object ids, parallel to `rows` (the compacted part).
    ids: Vec<u32>,
    rows: Vec<u32>,
    /// `(id, row)` pairs appended since the last compaction.
    staged: Vec<(u32, u32)>,
    /// Presence bitmap over `0..=max stored id` — eager duplicate checks.
    present: Vec<u64>,
}

impl RowIndex {
    pub fn new() -> RowIndex {
        RowIndex::default()
    }

    pub fn len(&self) -> usize {
        self.ids.len() + self.staged.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// O(1): is `id` stored here (compacted or staged)?
    #[inline]
    pub fn contains(&self, id: u32) -> bool {
        let w = (id / 64) as usize;
        w < self.present.len() && self.present[w] & (1u64 << (id % 64)) != 0
    }

    /// Record `id` at `row`. Returns false — and stores nothing — if the
    /// id is already present (the caller surfaces the typed error).
    pub fn insert(&mut self, id: u32, row: u32) -> bool {
        let (w, bit) = ((id / 64) as usize, 1u64 << (id % 64));
        if w >= self.present.len() {
            self.present.resize(w + 1, 0);
        }
        if self.present[w] & bit != 0 {
            return false;
        }
        self.present[w] |= bit;
        self.staged.push((id, row));
        true
    }

    /// True when staged entries are pending: lookups still work (they fall
    /// back to scanning the staged tail) but the caller should
    /// [`Self::compact`] at the barrier to restore O(log n) lookups.
    pub fn needs_compact(&self) -> bool {
        !self.staged.is_empty()
    }

    /// Merge the staged tail into the sorted arrays: one sort of the tail
    /// plus a linear two-way merge — a barrier-time cost.
    pub fn compact(&mut self) {
        if self.staged.is_empty() {
            return;
        }
        let mut tail = std::mem::take(&mut self.staged);
        tail.sort_unstable_by_key(|&(id, _)| id);
        let mut ids = Vec::with_capacity(self.ids.len() + tail.len());
        let mut rows = Vec::with_capacity(ids.capacity());
        let (mut i, mut j) = (0, 0);
        while i < self.ids.len() || j < tail.len() {
            let take_old = match (self.ids.get(i), tail.get(j)) {
                (Some(&a), Some(&(b, _))) => a < b, // ids are unique
                (Some(_), None) => true,
                _ => false,
            };
            if take_old {
                ids.push(self.ids[i]);
                rows.push(self.rows[i]);
                i += 1;
            } else {
                ids.push(tail[j].0);
                rows.push(tail[j].1);
                j += 1;
            }
        }
        self.ids = ids;
        self.rows = rows;
    }

    /// The row storing `id`: binary search on the compacted arrays, then a
    /// staged-tail scan (empty on the hot path — compaction runs at the
    /// phase barrier before queries).
    #[inline]
    pub fn row_of(&self, id: u32) -> Option<u32> {
        if let Ok(i) = self.ids.binary_search(&id) {
            return Some(self.rows[i]);
        }
        self.staged
            .iter()
            .find(|&&(sid, _)| sid == id)
            .map(|&(_, row)| row)
    }

    /// Owned `(id, row)` entries sorted by id, valid in any phase (merges
    /// the staged tail on the fly) — the snapshot/persist ordering.
    pub fn entries(&self) -> Vec<(u32, u32)> {
        let mut out: Vec<(u32, u32)> = self
            .ids
            .iter()
            .copied()
            .zip(self.rows.iter().copied())
            .chain(self.staged.iter().copied())
            .collect();
        out.sort_unstable_by_key(|&(id, _)| id);
        out
    }

    /// Exact bytes resident in the index (arrays, staged tail, bitmap).
    pub fn bytes_resident(&self) -> usize {
        (self.ids.len() + self.rows.len()) * size_of::<u32>()
            + self.staged.len() * size_of::<(u32, u32)>()
            + self.present.len() * size_of::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::check;
    use std::collections::HashMap;

    #[test]
    fn empty_index() {
        let r = RowIndex::new();
        assert!(r.is_empty());
        assert!(!r.contains(0));
        assert_eq!(r.row_of(7), None);
        assert!(r.entries().is_empty());
    }

    #[test]
    fn duplicate_insert_is_rejected_eagerly() {
        let mut r = RowIndex::new();
        assert!(r.insert(9, 0));
        assert!(!r.insert(9, 1), "staged duplicate must be caught");
        r.compact();
        assert!(!r.insert(9, 2), "compacted duplicate must be caught");
        assert_eq!(r.row_of(9), Some(0));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn matches_hashmap_model_under_random_ops() {
        // The reference model: the HashMap<u32, u32> DpState carried
        // before the refactor. Lookups must agree in every compaction
        // state; entries() must be the id-sorted snapshot ordering.
        check("store-rows-vs-model", 60, |g| {
            let mut idx = RowIndex::new();
            let mut model: HashMap<u32, u32> = HashMap::new();
            let mut next_row = 0u32;
            for _ in 0..g.usize_in(0, 150) {
                if g.usize_in(0, 9) == 0 {
                    idx.compact();
                } else {
                    let id = g.usize_in(0, 300) as u32;
                    let fresh = idx.insert(id, next_row);
                    assert_eq!(fresh, !model.contains_key(&id), "id {id}");
                    if fresh {
                        model.insert(id, next_row);
                        next_row += 1;
                    }
                }
                // membership + lookups agree in dirty AND compacted states
                let probe = g.usize_in(0, 310) as u32;
                assert_eq!(idx.contains(probe), model.contains_key(&probe));
                assert_eq!(idx.row_of(probe), model.get(&probe).copied());
            }
            assert_eq!(idx.len(), model.len());
            let mut want: Vec<(u32, u32)> =
                model.iter().map(|(&id, &row)| (id, row)).collect();
            want.sort_unstable_by_key(|&(id, _)| id);
            assert_eq!(idx.entries(), want);
            idx.compact();
            assert_eq!(idx.entries(), want);
            for (id, row) in want {
                assert_eq!(idx.row_of(id), Some(row));
            }
        });
    }
}
