//! Per-query candidate bitmap: the BI-side half of bucket-level pruning
//! (Jafari et al., arXiv 1912.07101), made *exact* so results never change.
//!
//! Object ids are dense (`0..indexed_objects`), so a per-query seen-set
//! can be a flat bitmap instead of a `HashSet`. Each 64-bit word carries a
//! generation stamp — `begin_query` is O(1), not O(words): a word whose
//! stamp is stale reads as all-unseen and is lazily reset on first touch.
//!
//! On top of the bitmap sits *chunk saturation*: the id space is split
//! into at most 64 chunks (the same chunking as
//! [`crate::store::BucketDirectory`]'s per-bucket summaries), and the
//! filter counts distinct seen ids per chunk against the directory's
//! per-chunk capacities. Once a chunk's count reaches its capacity, every
//! id the directory could reference in that chunk has been seen, and the
//! chunk's bit sets in `saturated`. A probed bucket whose summary is
//! covered by `saturated` ([`SeenFilter::all_seen`]) can then be skipped
//! *whole*: every one of its references is provably already seen this
//! query, so the skip drops no candidate the scan would have kept and the
//! scan's `dup_skipped` accounting can be charged exactly. No false
//! positives, no probabilistic argument — see DESIGN.md §Storage engine.

use std::mem::size_of;

/// Generation-stamped exact seen-bitmap with chunk-saturation tracking.
/// Configure it from the owning directory after every compaction
/// (capacities change when the arena does), call [`Self::begin_query`] per
/// query, then [`Self::insert`] per scanned reference.
#[derive(Clone, Debug, Default)]
pub struct SeenFilter {
    /// Seen bits, valid only where `word_gen` matches `gen`.
    words: Vec<u64>,
    word_gen: Vec<u32>,
    /// Distinct seen ids per chunk, valid only where `chunk_gen` matches.
    chunk_seen: Vec<u32>,
    chunk_gen: Vec<u32>,
    /// Distinct ids the directory references per chunk (from
    /// `BucketDirectory::chunk_caps` at the last compaction).
    chunk_caps: Vec<u32>,
    /// Chunks whose every referencable id has been seen this query.
    saturated: u64,
    chunk_shift: u32,
    gen: u32,
}

impl SeenFilter {
    /// (Re)size for a directory's id space and adopt its chunk geometry
    /// and capacities. Invalidates all per-query state — call only at
    /// compaction barriers, never mid-query.
    pub fn configure(&mut self, id_space: u32, chunk_shift: u32, caps: &[u32]) {
        let words = (id_space as usize).div_ceil(64);
        self.words.clear();
        self.words.resize(words, 0);
        self.word_gen.clear();
        self.word_gen.resize(words, 0);
        self.chunk_seen.clear();
        self.chunk_seen.resize(caps.len(), 0);
        self.chunk_gen.clear();
        self.chunk_gen.resize(caps.len(), 0);
        self.chunk_caps.clear();
        self.chunk_caps.extend_from_slice(caps);
        self.chunk_shift = chunk_shift;
        self.saturated = 0;
        self.gen = 0;
    }

    /// Start a fresh query: O(1) — bump the generation instead of zeroing
    /// the bitmap (with a full re-stamp on the rare u32 wrap).
    pub fn begin_query(&mut self) {
        if self.gen == u32::MAX {
            self.word_gen.fill(0);
            self.chunk_gen.fill(0);
            self.gen = 0;
        }
        self.gen += 1;
        self.saturated = 0;
    }

    /// Mark `id` seen; returns true iff it was NOT seen before this query
    /// (`HashSet::insert` semantics). `id` must lie inside the configured
    /// id space — bucket references always do.
    #[inline]
    pub fn insert(&mut self, id: u32) -> bool {
        let (w, bit) = ((id / 64) as usize, 1u64 << (id % 64));
        if self.word_gen[w] != self.gen {
            self.word_gen[w] = self.gen;
            self.words[w] = 0;
        }
        if self.words[w] & bit != 0 {
            return false;
        }
        self.words[w] |= bit;
        let c = (id >> self.chunk_shift) as usize;
        if self.chunk_gen[c] != self.gen {
            self.chunk_gen[c] = self.gen;
            self.chunk_seen[c] = 0;
        }
        self.chunk_seen[c] += 1;
        // A distinct-seen count can never exceed the chunk's capacity:
        // every insertable id is referenced by the directory and therefore
        // counted in the capacity.
        if self.chunk_seen[c] == self.chunk_caps[c] {
            self.saturated |= 1u64 << c;
        }
        true
    }

    /// True iff every id a bucket with this chunk `summary` can reference
    /// has already been seen this query (all its chunks are saturated) —
    /// the whole bucket may be skipped without scanning.
    #[inline]
    pub fn all_seen(&self, summary: u64) -> bool {
        summary != 0 && summary & !self.saturated == 0
    }

    /// Exact bytes resident in the filter's bitmaps and counters.
    pub fn bytes_resident(&self) -> usize {
        self.words.len() * size_of::<u64>()
            + self.word_gen.len() * size_of::<u32>()
            + (self.chunk_seen.len() + self.chunk_gen.len() + self.chunk_caps.len())
                * size_of::<u32>()
    }

    #[cfg(test)]
    fn force_gen(&mut self, g: u32) {
        self.gen = g;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::BucketDirectory;
    use crate::util::minitest::check;
    use std::collections::HashSet;

    /// Configure a filter straight from a compacted directory.
    fn from_dir(dir: &BucketDirectory) -> SeenFilter {
        let mut f = SeenFilter::default();
        f.configure(dir.id_space(), dir.chunk_shift(), dir.chunk_caps());
        f
    }

    #[test]
    fn insert_matches_hashset_across_generations() {
        check("store-bitmap-vs-hashset", 60, |g| {
            let space = g.usize_in(1, 800) as u32;
            let mut f = SeenFilter::default();
            // a synthetic geometry: every id in one chunk-per-64 layout,
            // capacities = full chunks so saturation can engage
            let shift = 4u32;
            let n_chunks = ((space - 1) >> shift) as usize + 1;
            f.configure(space, shift, &vec![u32::MAX; n_chunks]);
            for _query in 0..g.usize_in(1, 4) {
                f.begin_query();
                let mut model: HashSet<u32> = HashSet::new();
                for _ in 0..g.usize_in(0, 120) {
                    let id = g.usize_in(0, space as usize - 1) as u32;
                    assert_eq!(f.insert(id), model.insert(id), "id {id}");
                }
            }
        });
    }

    #[test]
    fn generation_wrap_resets_cleanly() {
        let mut f = SeenFilter::default();
        f.configure(100, 1, &[u32::MAX; 64]);
        f.begin_query();
        assert!(f.insert(5));
        f.force_gen(u32::MAX);
        // the wrap path must re-stamp, not leak old bits into gen 1
        f.begin_query();
        assert!(f.insert(5), "seen bit leaked across a generation wrap");
        assert!(!f.insert(5));
    }

    #[test]
    fn saturation_skip_is_exact_never_a_false_positive() {
        // The safety property behind WorkStats::bucket_skipped: whenever
        // all_seen(summary) says a bucket may be skipped, every id in that
        // bucket is ALREADY in the seen set — the skip can never drop a
        // candidate the scan would have routed.
        check("store-bitmap-saturation-safety", 60, |g| {
            let mut dir = BucketDirectory::new();
            let n_refs = g.usize_in(1, 300);
            let id_top = g.usize_in(1, 400);
            for _ in 0..n_refs {
                dir.insert(
                    g.usize_in(0, 9) as u64,
                    g.usize_in(0, id_top) as u32,
                    (g.usize_in(0, 3)) as u16,
                );
            }
            dir.compact();
            let mut f = from_dir(&dir);
            f.begin_query();
            let snap = dir.snapshot();
            let mut seen: HashSet<u32> = HashSet::new();
            // insert a random prefix of a random traversal of the refs
            for (key, refs) in &snap {
                if g.bool() {
                    for &(id, _) in refs {
                        f.insert(id);
                        seen.insert(id);
                    }
                }
                let (_, summary) = dir.lookup(*key).unwrap();
                if f.all_seen(summary) {
                    for &(id, _) in refs {
                        assert!(
                            seen.contains(&id),
                            "skip would drop unseen id {id} in bucket {key}"
                        );
                    }
                }
            }
            // completeness: after inserting EVERY referenced id, every
            // non-empty bucket is skippable
            for (_, refs) in &snap {
                for &(id, _) in refs {
                    f.insert(id);
                }
            }
            for (key, refs) in &snap {
                let (_, summary) = dir.lookup(*key).unwrap();
                assert!(!refs.is_empty());
                assert!(f.all_seen(summary), "fully-seen bucket {key} not skippable");
            }
        });
    }

    #[test]
    fn empty_summary_never_skips() {
        let mut f = SeenFilter::default();
        f.configure(10, 0, &[1; 10]);
        f.begin_query();
        assert!(!f.all_seen(0));
    }
}
