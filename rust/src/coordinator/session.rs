//! The session-oriented serving API (DESIGN.md §Service API).
//!
//! The paper's dataflow is a *continuously running* service: the index
//! stays resident across the whole experiment while queries stream into QR
//! one at a time. [`IndexSession`] is that regime as an API — a persistent
//! handle over a [`Cluster`]'s stage states and one live [`Executor`]
//! (inline, threaded, or the multi-process `SocketExecutor`), on which
//! build, incremental insert and search phases run back-to-back without
//! tearing anything down (under the socket transport: without
//! re-handshaking workers — their BI/DP state persists between phases).
//!
//! Lifecycle:
//!
//! ```text
//! Cluster::empty / build_index ──▶ IndexSession::attach
//!        ┌─────────────────────────────┴──────────────────────────┐
//!        │   insert(&Dataset)      grow the resident index        │
//!        │   submit(q) → ticket    admit one query                │
//!        │   recv() → (ticket,topk) stream completions out        │
//!        │   stats()               merged traffic + per-copy work │
//!        └─────────────────────────────┬──────────────────────────┘
//!                                 close() → SessionStats
//! ```
//!
//! Admission: submissions buffer in the session and are *pumped* through
//! the executor under the closed-loop `Config::stream.inflight` window
//! (0 = open loop) whenever a caller needs completions — `recv` with
//! nothing buffered, `drain`, `close`, or an `insert` (which acts as a
//! barrier: queries submitted before it complete against the pre-insert
//! index). Each pump admits the whole buffered backlog as one workload, so
//! phase-call wrappers ([`super::search_on`]) pump exactly once and stay
//! bit-identical to the pre-session API.
//!
//! Tickets: [`QueryTicket`]s are issued in submission order (a dense `u64`
//! sequence per session) and every completion carries its ticket, so
//! concurrent submitters can interleave freely — results are matched by
//! ticket, never by position. The session is `Sync`; `submit` hashes on
//! the calling thread before taking the session lock.

use crate::coordinator::Cluster;
use crate::data::Dataset;
use crate::dataflow::exec::{bind_stages, Executor, QrHandler, Workload};
use crate::dataflow::message::{Msg, StageKind};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::runtime::{Hasher, Ranker};
use crate::stages::QueryReceiver;
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::{Arc, Mutex, MutexGuard};

/// Handle for one submitted query: a dense per-session sequence number.
/// Completions ([`IndexSession::recv`]) are matched by ticket, not by
/// arrival order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct QueryTicket(pub u64);

/// A submitted query waiting for a pump: its ticket, the precomputed raw
/// projections (hashed on the submitting thread), and the query vector.
struct PendingQuery {
    ticket: u64,
    raw: Arc<[f32]>,
    v: Arc<[f32]>,
}

/// Session-lifetime accounting, returned by [`IndexSession::stats`] (live
/// snapshot) and [`IndexSession::close`] (final).
#[derive(Clone, Debug)]
pub struct SessionStats {
    /// Index-build traffic of the underlying cluster to date (all insert
    /// phases, including any build that happened before `attach`).
    pub build_meter: TrafficMeter,
    /// Search traffic of this session's query pumps.
    pub search_meter: TrafficMeter,
    /// Per-copy work since the last reset: `(stage, copy, counters)`, head
    /// QR first. Complete on every transport — remote copies report theirs
    /// through the socket executor's `FlushAck` barriers.
    pub work: Vec<(StageKind, u16, WorkStats)>,
    /// Admission-to-completion seconds, indexed by ticket number.
    pub per_query_secs: Vec<f64>,
    pub queries_submitted: u64,
    pub queries_completed: u64,
    /// Objects in the index (maintained by the coordinator, so it is
    /// correct even when the stores live in worker processes).
    pub objects_indexed: u64,
}

struct Inner<'c> {
    cluster: &'c mut Cluster,
    next_ticket: u64,
    pending: VecDeque<PendingQuery>,
    done: VecDeque<(QueryTicket, Vec<(f32, u32)>)>,
    per_query_secs: Vec<f64>,
    /// Head-node (QR) work across this session's pumps. Per-copy BI/DP/AG
    /// work lives in the cluster's stage states on every transport —
    /// remote counters are absorbed there after each pump
    /// ([`Cluster::absorb_remote_work`]).
    head_work: WorkStats,
    search_meter: TrafficMeter,
    completed: u64,
}

/// A persistent serving session: one live executor + one cluster's stage
/// states, bound for the session's lifetime (see the module docs for the
/// lifecycle). Create with [`IndexSession::attach`]; the borrowed
/// [`Cluster`] is usable again after [`IndexSession::close`].
pub struct IndexSession<'s> {
    exec: &'s dyn Executor,
    hasher: &'s dyn Hasher,
    ranker: Option<&'s dyn Ranker>,
    inner: Mutex<Inner<'s>>,
}

impl<'s> IndexSession<'s> {
    /// Open a session over `cluster` on `exec`. Pass `ranker: None` only
    /// for build-only sessions (insert without search) — `submit` needs a
    /// ranker and will panic without one.
    pub fn attach(
        exec: &'s dyn Executor,
        cluster: &'s mut Cluster,
        hasher: &'s dyn Hasher,
        ranker: Option<&'s dyn Ranker>,
    ) -> IndexSession<'s> {
        let agg = cluster.cfg.stream.agg_bytes;
        IndexSession {
            exec,
            hasher,
            ranker,
            inner: Mutex::new(Inner {
                cluster,
                next_ticket: 0,
                pending: VecDeque::new(),
                done: VecDeque::new(),
                per_query_secs: Vec::new(),
                head_work: WorkStats::default(),
                search_meter: TrafficMeter::new(agg),
                completed: 0,
            }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Inner<'s>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Index `dataset` incrementally (paper §IV-A: indexing and searching
    /// may overlap across a session). Acts as a barrier: queries submitted
    /// before the insert complete against the pre-insert index. Returns
    /// the assigned id range.
    pub fn insert(&self, dataset: &Dataset) -> Range<u32> {
        let mut inner = self.lock();
        self.pump(&mut inner);
        let inner = &mut *inner;
        inner
            .cluster
            .insert_objects_on(self.exec, dataset.as_flat(), dataset.len(), self.hasher)
    }

    /// Admit one query. Hashing happens on the calling thread; the ticket
    /// is issued under the session lock, in submission order.
    pub fn submit(&self, q: &[f32]) -> QueryTicket {
        assert!(
            self.ranker.is_some(),
            "IndexSession::submit on a session attached without a ranker"
        );
        let raw: Arc<[f32]> = self.hasher.proj_batch(q, 1).into();
        self.lock().enqueue(raw, q.into())
    }

    /// Admit a whole query set through one batched hash call (the phase
    /// drivers' §Perf path). Returns the contiguous ticket range.
    pub fn submit_batch(&self, queries: &Dataset) -> Range<u64> {
        assert!(
            self.ranker.is_some(),
            "IndexSession::submit_batch on a session attached without a ranker"
        );
        let p = self.hasher.p();
        let raws = self.hasher.proj_batch(queries.as_flat(), queries.len());
        let mut inner = self.lock();
        let start = inner.next_ticket;
        for i in 0..queries.len() {
            let raw: Arc<[f32]> = raws[i * p..(i + 1) * p].into();
            inner.enqueue(raw, queries.get(i).into());
        }
        start..inner.next_ticket
    }

    /// Pop a buffered completion without driving the pipeline.
    pub fn try_recv(&self) -> Option<(QueryTicket, Vec<(f32, u32)>)> {
        self.lock().done.pop_front()
    }

    /// Next completion: buffered if available, else pump the pending
    /// backlog through the executor. `None` means the session is idle
    /// (nothing buffered, nothing pending).
    pub fn recv(&self) -> Option<(QueryTicket, Vec<(f32, u32)>)> {
        let mut inner = self.lock();
        loop {
            if let Some(r) = inner.done.pop_front() {
                return Some(r);
            }
            if inner.pending.is_empty() {
                return None;
            }
            self.pump(&mut inner);
        }
    }

    /// Complete everything outstanding and return all unclaimed
    /// completions, ticket-ordered.
    pub fn drain(&self) -> Vec<(QueryTicket, Vec<(f32, u32)>)> {
        let mut inner = self.lock();
        self.pump(&mut inner);
        let mut out: Vec<_> = inner.done.drain(..).collect();
        out.sort_by_key(|e| e.0);
        out
    }

    /// Queries admitted but not yet delivered through `recv`/`drain`.
    pub fn in_flight(&self) -> usize {
        let inner = self.lock();
        inner.pending.len() + inner.done.len()
    }

    /// Live accounting snapshot (does not reset any counter).
    pub fn stats(&self) -> SessionStats {
        let inner = self.lock();
        let c: &Cluster = &*inner.cluster;
        let mut work = vec![(StageKind::Qr, 0u16, inner.head_work)];
        for bi in &c.bis {
            work.push((StageKind::Bi, bi.copy, bi.work));
        }
        for dp in &c.dps {
            work.push((StageKind::Dp, dp.copy, dp.work));
        }
        for ag in &c.ags {
            work.push((StageKind::Ag, ag.copy, ag.work));
        }
        SessionStats {
            build_meter: c.build_meter.clone(),
            search_meter: inner.search_meter.clone(),
            work,
            per_query_secs: inner.per_query_secs.clone(),
            queries_submitted: inner.next_ticket,
            queries_completed: inner.completed,
            objects_indexed: c.indexed_objects as u64,
        }
    }

    /// Take (and reset) the per-copy work counters accumulated since the
    /// last reset — phase accounting, the session rendition of
    /// [`Cluster::take_work`]. Complete on every transport.
    pub fn take_work(&self) -> Vec<(StageKind, u16, WorkStats)> {
        let mut inner = self.lock();
        let inner = &mut *inner;
        let head = std::mem::take(&mut inner.head_work);
        inner.cluster.take_work(&head)
    }

    /// Typed end of session: completes any still-pending queries (so
    /// per-query teardown reaches every transport) and returns the final
    /// stats. Unclaimed completions are discarded — `drain` first if you
    /// want them. The borrowed `Cluster` is usable again afterwards; under
    /// the socket transport the workers stay up (they belong to the
    /// `NetSession`), ready for the next session.
    pub fn close(self) -> SessionStats {
        {
            let mut inner = self.lock();
            self.pump(&mut inner);
        }
        self.stats()
    }

    /// Run the buffered backlog through the executor as one search
    /// workload under the `stream.inflight` admission window, and buffer
    /// the completions.
    fn pump(&self, inner: &mut Inner<'s>) {
        if inner.pending.is_empty() {
            return;
        }
        let ranker = self
            .ranker
            .expect("IndexSession pump without a ranker (attach with Some(ranker))");
        let batch: Vec<PendingQuery> = inner.pending.drain(..).collect();
        let inner = &mut *inner;
        let cluster: &mut Cluster = &mut *inner.cluster;
        let placement = cluster.placement.clone();
        let agg = cluster.cfg.stream.agg_bytes;
        let window = cluster.cfg.stream.inflight;
        let family = cluster.family.clone();
        let mut qr = QueryReceiver::new(&family, placement.bi_copies, placement.ag_copies);
        let report = {
            let stages = bind_stages(
                Box::new(QrHandler { qr: &mut qr }),
                &mut cluster.bis,
                &mut cluster.dps,
                &mut cluster.ags,
                Some(ranker),
            );
            let mut items = batch.iter().enumerate().map(|(i, pq)| Msg::QueryVec {
                qid: i as u32,
                raw: pq.raw.clone(),
                v: pq.v.clone(),
            });
            self.exec.run(
                &placement,
                stages,
                Workload {
                    items: &mut items,
                    n_queries: batch.len(),
                    window,
                    agg_bytes: agg,
                },
            )
        };
        inner.head_work.add(&qr.work);
        inner.search_meter.merge(&report.meter);
        inner.cluster.absorb_remote_work(&report.work);
        for (i, (hits, secs)) in report
            .results
            .into_iter()
            .zip(report.per_query_secs)
            .enumerate()
        {
            let ticket = batch[i].ticket;
            inner.per_query_secs[ticket as usize] = secs;
            inner.done.push_back((QueryTicket(ticket), hits));
            inner.completed += 1;
        }
    }
}

impl Inner<'_> {
    fn enqueue(&mut self, raw: Arc<[f32]>, v: Arc<[f32]>) -> QueryTicket {
        let t = self.next_ticket;
        self.next_ticket += 1;
        self.per_query_secs.push(0.0);
        self.pending.push_back(PendingQuery { ticket: t, raw, v });
        QueryTicket(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::{build_index, build_index_on, search, search_on, small_test_cfg};
    use crate::data::synth::{distorted_queries, synthesize, SynthSpec};
    use crate::dataflow::exec::{InlineExecutor, ThreadedExecutor};
    use crate::runtime::{ScalarHasher, ScalarRanker};

    fn world(
        cfg: &Config,
        n: usize,
        queries: usize,
    ) -> (Dataset, Dataset, ScalarHasher, ScalarRanker) {
        let ds = synthesize(SynthSpec { n, clusters: 40, ..Default::default() });
        let (qs, _) = distorted_queries(&ds, queries, 4.0, 7);
        let family = crate::core::lsh::HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let ranker = ScalarRanker { dim: ds.dim };
        (ds, qs, hasher, ranker)
    }

    /// The inline-vs-threaded differential contract, now flowing through
    /// the session path (search_on is a session wrapper).
    fn assert_matches_inline(cfg: &Config, n: usize, queries: usize) {
        let (ds, qs, hasher, ranker) = world(cfg, n, queries);
        let mut c1 = build_index(cfg, &ds, &hasher);
        let inline_out = search(&mut c1, &qs, &hasher, &ranker);
        let mut c2 = build_index(cfg, &ds, &hasher);
        let threaded_out = search_on(&ThreadedExecutor, &mut c2, &qs, &hasher, &ranker);

        assert_eq!(inline_out.results, threaded_out.results);
        // traffic counters agree (logical messages & payload bytes are
        // aggregation-independent).
        assert_eq!(
            inline_out.meter.logical_msgs,
            threaded_out.meter.logical_msgs
        );
        // payload agrees within 1%: DP dedup depends on cross-BI arrival
        // order, which can shift a few hits between LocalTopK messages
        // (the merged result set is identical — asserted above).
        let (a, b) = (
            inline_out.meter.payload_bytes as f64,
            threaded_out.meter.payload_bytes as f64,
        );
        assert!((a - b).abs() / a < 0.01, "payload diverged: {a} vs {b}");
        // states returned intact
        assert_eq!(c2.bis.len(), cfg.cluster.bi_copies());
        assert_eq!(c2.dps.len(), cfg.cluster.dp_copies());
        assert_eq!(c2.ags.len(), cfg.cluster.ag_copies);
        assert!(threaded_out.per_query_secs.iter().all(|&s| s > 0.0));
    }

    fn small_cfg() -> Config {
        small_test_cfg()
    }

    #[test]
    fn threaded_matches_inline_results() {
        assert_matches_inline(&small_cfg(), 1_500, 15);
    }

    #[test]
    fn threaded_matches_inline_under_batched_admission() {
        for window in [1usize, 3] {
            let mut cfg = small_cfg();
            cfg.stream.inflight = window;
            assert_matches_inline(&cfg, 1_500, 15);
        }
    }

    #[test]
    fn threaded_matches_inline_with_multiple_aggregators() {
        let mut cfg = small_cfg();
        cfg.cluster.ag_copies = 3;
        assert_matches_inline(&cfg, 1_500, 20);
        let mut cfg = small_cfg();
        cfg.cluster.ag_copies = 2;
        cfg.stream.inflight = 2;
        assert_matches_inline(&cfg, 1_200, 18);
    }

    #[test]
    fn threaded_build_then_threaded_search_matches_inline_pipeline() {
        let mut cfg = small_cfg();
        cfg.stream.inflight = 4;
        let (ds, qs, hasher, ranker) = world(&cfg, 1_500, 15);

        let mut inline_cluster = build_index(&cfg, &ds, &hasher);
        let inline_out = search(&mut inline_cluster, &qs, &hasher, &ranker);

        let mut threaded_cluster = build_index_on(&ThreadedExecutor, &cfg, &ds, &hasher);
        let threaded_out =
            search_on(&ThreadedExecutor, &mut threaded_cluster, &qs, &hasher, &ranker);

        assert_eq!(inline_out.results, threaded_out.results);
        assert_eq!(
            inline_cluster.build_meter.logical_msgs,
            threaded_cluster.build_meter.logical_msgs
        );
    }

    #[test]
    fn streaming_submit_recv_matches_phase_call() {
        // One query at a time — submit, wait for its completion, submit the
        // next — must give the same answers as the one-shot phase call.
        let cfg = small_cfg();
        let (ds, qs, hasher, ranker) = world(&cfg, 1_200, 10);
        let mut oracle_cluster = build_index(&cfg, &ds, &hasher);
        let oracle = search(&mut oracle_cluster, &qs, &hasher, &ranker);

        for exec in [&InlineExecutor as &dyn Executor, &ThreadedExecutor] {
            let mut cluster = build_index(&cfg, &ds, &hasher);
            let session = IndexSession::attach(exec, &mut cluster, &hasher, Some(&ranker));
            for qi in 0..qs.len() {
                let ticket = session.submit(qs.get(qi));
                assert_eq!(ticket, QueryTicket(qi as u64));
                let (t, hits) = session.recv().expect("one in flight");
                assert_eq!(t, ticket);
                assert_eq!(hits, oracle.results[qi], "query {qi}");
            }
            assert!(session.recv().is_none(), "idle session must report None");
            let stats = session.close();
            assert_eq!(stats.queries_submitted, qs.len() as u64);
            assert_eq!(stats.queries_completed, qs.len() as u64);
            assert!(stats.search_meter.logical_msgs > 0);
            assert!(stats.per_query_secs.iter().all(|&s| s > 0.0));
        }
    }

    #[test]
    fn session_build_insert_search_in_one_lifetime() {
        // The full lifecycle on one session: open empty, insert twice,
        // then serve — identical to building over the concatenation.
        let cfg = small_cfg();
        let (ds, _, hasher, ranker) = world(&cfg, 1_500, 10);
        let (extra, _) = distorted_queries(&ds, 40, 1.0, 99);
        let mut concat = Dataset::new(ds.dim);
        for i in 0..ds.len() {
            concat.push(ds.get(i));
        }
        for i in 0..extra.len() {
            concat.push(extra.get(i));
        }
        let (qs, _) = distorted_queries(&concat, 12, 3.0, 5);
        let mut oracle_cluster = build_index(&cfg, &concat, &hasher);
        let oracle = search(&mut oracle_cluster, &qs, &hasher, &ranker);

        let mut cluster = Cluster::empty(&cfg, ds.dim);
        {
            let session =
                IndexSession::attach(&ThreadedExecutor, &mut cluster, &hasher, Some(&ranker));
            assert_eq!(session.insert(&ds), 0..ds.len() as u32);
            assert_eq!(
                session.insert(&extra),
                ds.len() as u32..concat.len() as u32
            );
            let tickets = session.submit_batch(&qs);
            assert_eq!(tickets, 0..qs.len() as u64);
            let done = session.drain();
            assert_eq!(done.len(), qs.len());
            for (i, (t, hits)) in done.iter().enumerate() {
                assert_eq!(t.0, i as u64);
                assert_eq!(hits, &oracle.results[i], "query {i}");
            }
            let stats = session.close();
            assert_eq!(stats.objects_indexed as usize, concat.len());
            assert!(stats.build_meter.logical_msgs > 0);
        }
        assert_eq!(cluster.stored_objects(), concat.len());
        assert_eq!(cluster.bucket_references(), concat.len() * cfg.lsh.l);
    }

    #[test]
    fn insert_is_a_barrier_for_earlier_submissions() {
        // A query submitted before an insert must be answered against the
        // pre-insert index even though it is only pumped by the insert.
        let cfg = small_cfg();
        let (ds, _, hasher, ranker) = world(&cfg, 1_200, 5);
        // Query = an exact duplicate of a vector we insert *after*
        // submitting it: distance-0 hit exists only post-insert.
        let (dup, _) = distorted_queries(&ds, 1, 0.0, 3);
        let mut pre_cluster = build_index(&cfg, &ds, &hasher);
        let pre = search(&mut pre_cluster, &dup, &hasher, &ranker);

        let mut cluster = build_index(&cfg, &ds, &hasher);
        let session = IndexSession::attach(&InlineExecutor, &mut cluster, &hasher, Some(&ranker));
        let before = session.submit(dup.get(0));
        session.insert(&dup);
        let after = session.submit(dup.get(0));
        let mut got: Vec<_> = session.drain();
        got.sort_by_key(|e| e.0);
        assert_eq!(got[0].0, before);
        assert_eq!(got[0].1, pre.results[0], "pre-insert query saw the insert");
        assert_eq!(got[1].0, after);
        // the post-insert query must retrieve the inserted duplicate (its
        // base vector ties at distance 0, so assert membership, not rank)
        assert!(
            got[1].1.iter().any(|&(_, id)| id == ds.len() as u32),
            "post-insert query missed the insert: {:?}",
            got[1].1
        );
    }

    #[test]
    fn take_work_resets_like_phase_accounting() {
        let cfg = small_cfg();
        let (ds, qs, hasher, ranker) = world(&cfg, 1_200, 8);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let session = IndexSession::attach(&InlineExecutor, &mut cluster, &hasher, Some(&ranker));
        session.submit_batch(&qs);
        let _ = session.drain();
        let work = session.take_work();
        let dists: u64 = work.iter().map(|(_, _, w)| w.dists_computed).sum();
        assert!(dists > 0);
        let again = session.take_work();
        assert!(again.iter().all(|(_, _, w)| w.dists_computed == 0));
        session.close();
    }
}
