//! The session-oriented serving API (DESIGN.md §Service API).
//!
//! The paper's dataflow is a *continuously running* service: the index
//! stays resident across the whole experiment while queries stream into QR
//! one at a time. [`IndexSession`] is that regime as an API — a persistent
//! handle over a [`Cluster`]'s stage states and one live [`Executor`]
//! (inline, threaded, or the multi-process `SocketExecutor`), on which
//! build, incremental insert and search phases run back-to-back without
//! tearing anything down (under the socket transport: without
//! re-handshaking workers — their BI/DP state persists between phases).
//!
//! Lifecycle:
//!
//! ```text
//! Cluster::empty / build_index ──▶ IndexSession::attach
//!        ┌─────────────────────────────┴──────────────────────────┐
//!        │   insert(&Dataset)            grow the resident index  │
//!        │   submit(q) → ticket          admit, default plan      │
//!        │   submit_with(q, QueryOptions) admit, per-query plan   │
//!        │   recv() → (ticket,topk)      stream completions out   │
//!        │   recv_full() → (.., opts, ..) with the option echo    │
//!        │   stats()               merged traffic + per-copy work │
//!        └─────────────────────────────┬──────────────────────────┘
//!                                 close() → SessionStats
//! ```
//!
//! Admission is *streaming* ([`Executor::open_stream`]): the first
//! `submit` opens a long-lived [`StreamRun`] on the executor, and every
//! submission enters the pipeline the moment it arrives — no buffering
//! until the next pump. The closed-loop `Config::stream.inflight` window
//! still bounds queries in flight *inside* the pipeline, and
//! `Config::stream.pending_cap` adds session-level backpressure: at the
//! cap, `submit` blocks (and [`IndexSession::try_submit`] declines) until
//! completions drain. `insert` is a barrier: it finishes the open stream
//! (waiting for outstanding queries, which therefore answer against the
//! pre-insert index), runs the index phase, and the next `submit` reopens
//! a fresh stream.
//!
//! Tickets: [`QueryTicket`]s are issued in submission order (a dense `u64`
//! sequence per session) and every completion carries its ticket, so
//! concurrent submitters can interleave freely — results are matched by
//! ticket, never by position. The session is `Sync`; `submit` hashes on
//! the calling thread before taking the session lock.
//!
//! Per-query plans: [`IndexSession::submit_with`] attaches a
//! [`QueryOptions`] — per-request `k`, probe budget `T`, table count `L'`
//! and an opaque `tag` — to one submission. Options are resolved against
//! the session's configured `LshParams` at submit time (0 = inherit), the
//! resolved plan rides the ingress message through every stage, and the
//! session echoes it per ticket on the `recv` side
//! ([`IndexSession::recv_full`]/[`IndexSession::try_recv_full`]).
//! `submit(q)` is exactly `submit_with(q, QueryOptions::default_from(&cfg))`,
//! so default traffic is bit-identical to the pre-plan behavior (the
//! pumped `search_on` oracle).
//!
//! Admission lanes: the poll-based front door (`net::front`) multiplexes
//! many external clients onto one session. Each connection gets a *lane*
//! ([`IndexSession::open_lane`]) — a fair share of the `pending_cap`
//! window (`ceil(cap / lanes)`), enforced by
//! [`IndexSession::try_submit_lane`] so no client starves the others —
//! and completions are claimed lane-tagged
//! ([`IndexSession::try_recv_lane`]) for routing back to the right
//! connection. [`IndexSession::close_lane`] (disconnect) orphans the
//! lane's in-flight tickets: the pipeline completes them, the session
//! discards them on arrival, and the window share returns immediately.
//!
//! Multi-tenant QoS (DESIGN.md §QoS scheduler): when `[qos] tags`
//! configures weight classes, *every* submit path — plain, batch, and
//! lanes — additionally gates on the submitting tag's weighted-fair
//! share of the `pending_cap` window ([`crate::qos::TagTable::share`]).
//! Idle classes' shares are borrowed (work-conserving — a lone class
//! gets the whole window), and a saturating class parks at its share
//! while lighter classes keep their reserved slice. Per-class
//! submitted/completed counts, latency, and attributed work land in
//! [`SessionStats::per_tag`]. With `[qos] adaptive_probes`, a plan that
//! leaves `probes = 0` resolves its per-table budget from the query's
//! own perturbation-score profile at submit time
//! ([`crate::qos::adaptive_probes`], after mmLSH) and stamps it into
//! the wire plan as an explicit value — transports stay bit-identical
//! to the inline oracle because the resolved budget, not the policy,
//! rides the wire.
//!
//! Memory stays bounded on a resident session: per-query latency is
//! folded into a [`LatencySummary`] (exact mean/max + fixed reservoir for
//! percentiles) instead of a per-ticket vector, the in-flight ticket map
//! shrinks on every completion, and completions buffer in the session
//! only until the caller claims them (`recv`/`try_recv`/`drain`) — a
//! serving loop that claims as it submits holds O(pending) state.

use crate::coordinator::Cluster;
use crate::core::lsh::{HashFamily, LshParams};
use crate::data::Dataset;
use crate::dataflow::exec::{
    AgHandler, BiHandler, DpHandler, Executor, StageHandler, StageHandlers, StreamCompletion,
    StreamConfig, StreamRun,
};
use crate::dataflow::message::{Msg, QueryOptions, StageKind, MAX_QUERY_PROBES};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::metrics::LatencySummary;
use crate::qos::{self, TagAccount, TagStats, TagTable};
use crate::runtime::{Hasher, Ranker};
use crate::stages::aggregator::QueryResult;
use crate::stages::{AgState, BiState, DpState, Emit, QueryReceiver};
use std::collections::{HashMap, VecDeque};
use std::ops::Range;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Handle for one submitted query: a dense per-session sequence number.
/// Completions ([`IndexSession::recv`]) are matched by ticket, not by
/// arrival order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct QueryTicket(pub u64);

/// How long one wait on the stream egress lasts before the session
/// *releases and re-acquires its lock*: a claimer parked on the egress
/// must not hold the session mutex long, or concurrent `submit` calls —
/// the enters-the-pipeline-immediately path — would stall behind it.
/// This bounds a submitter's worst-case wait behind a claimer.
const RECV_TICK: Duration = Duration::from_millis(10);

/// How long a submitter parks (without the session lock) between
/// attempts while the backpressure window is full. Only paid at
/// saturation, where completion latency — not the park — dominates.
const SUBMIT_TICK: Duration = Duration::from_millis(1);

/// Session-lifetime accounting, returned by [`IndexSession::stats`] (live
/// snapshot) and [`IndexSession::close`] (final).
#[derive(Clone, Debug)]
pub struct SessionStats {
    /// Index-build traffic of the underlying cluster to date (all insert
    /// phases, including any build that happened before `attach`).
    pub build_meter: TrafficMeter,
    /// Search traffic of this session's streaming runs.
    pub search_meter: TrafficMeter,
    /// Per-copy work since the last reset: `(stage, copy, counters)`, head
    /// QR first. Complete on every transport — remote copies report theirs
    /// through the socket executor's stream barriers.
    pub work: Vec<(StageKind, u16, WorkStats)>,
    /// Bounded admission-to-completion latency accounting (exact count,
    /// mean, min/max; reservoir percentiles) — O(1) per query served.
    pub latency: LatencySummary,
    pub queries_submitted: u64,
    pub queries_completed: u64,
    /// Completions discarded because their admission lane closed (the
    /// external client disconnected) while they were in flight. Counted
    /// in `queries_completed` (the pipeline did the work) but excluded
    /// from `latency`.
    pub queries_evicted: u64,
    /// Objects in the index (maintained by the coordinator, so it is
    /// correct even when the stores live in worker processes).
    pub objects_indexed: u64,
    /// Queries cancelled and re-dispatched to a surviving replica after a
    /// mid-stream worker death (socket transport with replication > 1;
    /// always 0 elsewhere). Folded in at stream barriers.
    pub queries_retargeted: u64,
    /// Per-tag-class QoS rows (DESIGN.md §QoS scheduler): one row per
    /// configured `[qos] tags` class plus the trailing `*` catch-all —
    /// the catch-all alone when QoS is unconfigured (then it simply
    /// restates the session totals). Latency is pipeline service time
    /// per class; `work` is delta-attributed at completion (exact under
    /// the inline oracle, arrival-order approximate under concurrency)
    /// and only collected when `[qos] tags` is configured.
    pub per_tag: Vec<TagStats>,
}

// ---------------------------------------------------- owned stage handlers

/// QR bound to an owned family `Arc` — the streaming head stage must be
/// `'static` so the executor can park it on a long-lived thread. Work
/// counters accumulate into a shared slot the session reads back.
struct SharedQr {
    family: Arc<HashFamily>,
    n_bi: usize,
    n_ag: usize,
    work: Arc<Mutex<WorkStats>>,
}

impl StageHandler for SharedQr {
    fn on_msg(&mut self, msg: Msg, out: Emit) {
        match msg {
            Msg::QueryVec { qid, raw, v, opts } => {
                let mut qr = QueryReceiver::new(&self.family, self.n_bi, self.n_ag);
                // The submitting thread hashed this vector; account for it
                // here so work totals match the pumped phase path.
                qr.work.hash_vectors += 1;
                qr.dispatch_query_arc(&raw, qid, v, opts, out);
                let mut w = self.work.lock().unwrap_or_else(|p| p.into_inner());
                w.add(&qr.work);
            }
            other => panic!("QR got unexpected {other:?}"),
        }
    }
}

/// Stage state checked out of the cluster into a shared slot for the
/// stream's lifetime: the handler (on a stage thread) holds one `Arc`, the
/// session keeps the other to read live stats and to reclaim the state at
/// the stream barrier. Exactly one side touches the state at a time, so
/// the per-message lock is uncontended.
struct SharedBi {
    bi: Arc<Mutex<BiState>>,
}

impl StageHandler for SharedBi {
    fn on_msg(&mut self, msg: Msg, out: Emit) {
        let mut bi = self.bi.lock().unwrap_or_else(|p| p.into_inner());
        BiHandler { bi: &mut *bi }.on_msg(msg, out);
    }
}

struct SharedDp {
    dp: Arc<Mutex<DpState>>,
    ranker: Arc<dyn Ranker>,
}

impl StageHandler for SharedDp {
    fn on_msg(&mut self, msg: Msg, out: Emit) {
        let mut dp = self.dp.lock().unwrap_or_else(|p| p.into_inner());
        DpHandler { dp: &mut *dp, ranker: Some(self.ranker.as_ref()) }.on_msg(msg, out);
    }

    fn on_query_done(&mut self, qid: u32) {
        let mut dp = self.dp.lock().unwrap_or_else(|p| p.into_inner());
        dp.finish_query(qid);
    }
}

struct SharedAg {
    ag: Arc<Mutex<AgState>>,
}

impl StageHandler for SharedAg {
    fn on_msg(&mut self, msg: Msg, out: Emit) {
        let mut ag = self.ag.lock().unwrap_or_else(|p| p.into_inner());
        AgHandler { ag: &mut *ag }.on_msg(msg, out);
    }

    fn take_completions(&mut self, out: &mut Vec<QueryResult>) {
        let mut ag = self.ag.lock().unwrap_or_else(|p| p.into_inner());
        out.append(&mut ag.results);
    }

    fn abort_query(&mut self, qid: u32) {
        let mut ag = self.ag.lock().unwrap_or_else(|p| p.into_inner());
        ag.abort_query(qid);
    }
}

/// Take the sole remaining `Arc` handle apart to reclaim the state. The
/// executor dropped its handler boxes at the stream barrier, so the
/// session's handle is the last one by construction.
fn reclaim<T>(slot: Arc<Mutex<T>>) -> T {
    match Arc::try_unwrap(slot) {
        Ok(m) => m.into_inner().unwrap_or_else(|p| p.into_inner()),
        Err(_) => panic!("stage state still shared after the stream barrier"),
    }
}

/// An open streaming run plus the session's handles onto the checked-out
/// stage state (returned to the cluster when the stream finishes).
struct OpenStream<'s> {
    run: Box<dyn StreamRun + 's>,
    bis: Vec<Arc<Mutex<BiState>>>,
    dps: Vec<Arc<Mutex<DpState>>>,
    ags: Vec<Arc<Mutex<AgState>>>,
    qr_work: Arc<Mutex<WorkStats>>,
}

/// One delivered completion with its full context: ticket, the resolved
/// per-query plan it ran under (option echo), the global top-k, and the
/// admission-to-completion seconds.
pub type Completion = (QueryTicket, QueryOptions, Vec<(f32, u32)>, f64);

struct Inner<'c> {
    cluster: &'c mut Cluster,
    /// The live streaming run, opened lazily by the first `submit` and
    /// finished (stage state reclaimed into `cluster`) by `insert`/`close`.
    stream: Option<OpenStream<'c>>,
    next_ticket: u64,
    /// qid → (ticket, resolved options, lane) for queries admitted but
    /// not yet claimed — the recv-side option echo. Bounded by the number
    /// outstanding; qids are the ticket truncated to `u32` (unique while
    /// fewer than 2^32 are in flight — i.e. always). The lane is 0 for
    /// the plain submit APIs, or the admission lane of an external client
    /// ([`IndexSession::open_lane`]).
    tickets: HashMap<u32, (u64, QueryOptions, u32)>,
    /// Completions claimed from the stream but not yet delivered to a
    /// caller (barrier leftovers, and `drain`'s staging area), tagged
    /// with their admission lane.
    done: VecDeque<(u32, Completion)>,
    /// Open admission lanes: lane id → outstanding (submitted, not yet
    /// claimed) count. The plain submit APIs use the implicit lane 0,
    /// which is never in this map and is bounded only by the global
    /// window. Lane ids are never reused, so a ticket whose lane is
    /// non-zero and absent here belongs to a *closed* lane (orphaned).
    lanes: HashMap<u32, usize>,
    next_lane: u32,
    /// Completions discarded because their lane closed while they were
    /// in flight (the client disconnected mid-stream).
    evicted: u64,
    latency: LatencySummary,
    /// Head-node (QR) work across this session's streams. Per-copy
    /// BI/DP/AG work lives in the cluster's stage states (or their
    /// checked-out slots while a stream is open) on every transport.
    head_work: WorkStats,
    search_meter: TrafficMeter,
    completed: u64,
    /// Queries re-dispatched to a surviving replica after a mid-stream
    /// worker death (socket transport; folded in at stream barriers).
    retargeted: u64,
    /// Parsed `[qos] tags` classes, frozen at attach (inert when the
    /// spec is empty — every gate degenerates to a no-op comparison).
    qos: TagTable,
    /// Per-class serving accounts, indexed by class (catch-all last).
    tag_accounts: Vec<TagAccount>,
    /// Per-class outstanding (admitted, not yet completed) counts — the
    /// live input to the weighted-fair [`TagTable::share`] rule.
    tag_outstanding: Vec<u64>,
    /// Merged live work at the last completion: the base against which
    /// the next completion's work delta is attributed to its class. A
    /// `take_work` reset can drop the live totals below this base; the
    /// delta then saturates to zero until work catches up (per-tag work
    /// is an attribution aid — session totals stay authoritative).
    tag_work_base: WorkStats,
}

impl Inner<'_> {
    /// Bookkeep one completion claimed from the stream. `None` means the
    /// completion was *orphaned* — its admission lane closed (the client
    /// disconnected) while it was in flight, so there is nobody to
    /// deliver it to: it is discarded here, its window share already
    /// returned when the lane closed. Orphans still count toward
    /// `queries_completed` (the pipeline did the work) but not toward the
    /// latency summary (an evicted client's tail is not serving latency).
    fn note_completion(&mut self, c: StreamCompletion) -> Option<(u32, Completion)> {
        let (t, opts, lane) = self
            .tickets
            .remove(&c.qid)
            .expect("stream completion for an unknown qid");
        debug_assert!(
            c.hits.len() <= opts.k as usize,
            "completion overflowed its plan's k"
        );
        self.completed += 1;
        // Per-tag accounting: return the class's window share and charge
        // everything the pipeline did since the previous completion to
        // this ticket's class — exact under the inline oracle (one query
        // in flight), an arrival-order approximation under concurrency;
        // socket-remote counters only land at stream barriers.
        let class = self.qos.class_of(opts.tag);
        self.tag_outstanding[class] = self.tag_outstanding[class].saturating_sub(1);
        if self.qos.is_enabled() {
            // Only pay the per-completion slot sweep when `[qos] tags`
            // is configured — without classes the catch-all row would
            // just restate the session-wide work totals.
            let live = self.merged_live_work();
            let delta = live.delta_since(&self.tag_work_base);
            self.tag_accounts[class].work.add(&delta);
            self.tag_work_base = live;
        }
        self.tag_accounts[class].completed += 1;
        if lane != 0 {
            match self.lanes.get_mut(&lane) {
                Some(held) => *held = held.saturating_sub(1),
                None => {
                    self.evicted += 1;
                    return None;
                }
            }
        }
        self.latency.record(c.secs);
        self.tag_accounts[class].latency.record(c.secs);
        Some((lane, (QueryTicket(t), opts, c.hits, c.secs)))
    }

    /// Sum of all work done so far as visible from this session right
    /// now: head QR work plus every per-copy counter (live stream slots
    /// while a stream is open, the cluster's stage states otherwise).
    /// The per-tag attribution base — see `tag_work_base`.
    fn merged_live_work(&self) -> WorkStats {
        let mut w = self.head_work;
        match &self.stream {
            Some(os) => {
                {
                    let qw = os.qr_work.lock().unwrap_or_else(|p| p.into_inner());
                    w.add(&qw);
                }
                for slot in &os.bis {
                    let s = slot.lock().unwrap_or_else(|p| p.into_inner());
                    w.add(&s.work);
                }
                for slot in &os.dps {
                    let s = slot.lock().unwrap_or_else(|p| p.into_inner());
                    w.add(&s.work);
                }
                for slot in &os.ags {
                    let s = slot.lock().unwrap_or_else(|p| p.into_inner());
                    w.add(&s.work);
                }
            }
            None => {
                for bi in &self.cluster.bis {
                    w.add(&bi.work);
                }
                for dp in &self.cluster.dps {
                    w.add(&dp.work);
                }
                for ag in &self.cluster.ags {
                    w.add(&ag.work);
                }
            }
        }
        w
    }

    /// Does `tag`'s class have room under its weighted-fair share of
    /// the backpressure window right now? Always true without `[qos]
    /// tags` (inert table) or without a `pending_cap`.
    fn tag_has_room(&self, tag: u32) -> bool {
        let class = self.qos.class_of(tag);
        let cap = self.cluster.cfg.stream.pending_cap;
        (self.tag_outstanding[class] as usize) < self.qos.share(cap, class, &self.tag_outstanding)
    }

    /// Issue the next ticket and admit the query into the open stream —
    /// if the backpressure window has room. `None` means the window is
    /// full (nothing was consumed; the caller may retry with the same
    /// `raw`/`v`). `opts` is the *caller's* plan, stamped on the wire
    /// as-is so default-elision stays live (QR resolves it against the
    /// same params); `echo` is the pre-resolved copy kept for the
    /// recv-side option echo. Never blocks: callers that want blocking
    /// semantics park *outside* the session lock
    /// ([`IndexSession::submit`]), so the documented non-blocking calls
    /// (`try_recv`, `stats`, `in_flight`) are never stuck behind a gated
    /// submitter.
    fn try_submit_one(
        &mut self,
        raw: Arc<[f32]>,
        v: Arc<[f32]>,
        opts: QueryOptions,
        echo: QueryOptions,
        lane: u32,
    ) -> Option<QueryTicket> {
        // The tag's weighted-fair share gates admission before the
        // executor window does: a saturating class is declined here while
        // lighter classes keep their reserved slice of `pending_cap`.
        // Nothing is consumed on decline — same retry contract as a full
        // window.
        if !self.tag_has_room(echo.tag) {
            return None;
        }
        let t = self.next_ticket;
        let qid = t as u32;
        let msg = Msg::QueryVec { qid, raw, v, opts };
        let os = self.stream.as_mut().expect("submit without an open stream");
        match os.run.try_submit(msg) {
            Ok(()) => {
                self.next_ticket += 1;
                self.tickets.insert(qid, (t, echo, lane));
                if lane != 0 {
                    *self.lanes.get_mut(&lane).expect("submit on a closed lane") += 1;
                }
                let class = self.qos.class_of(echo.tag);
                self.tag_outstanding[class] += 1;
                self.tag_accounts[class].submitted += 1;
                Some(QueryTicket(t))
            }
            Err(_) => None,
        }
    }

    /// Fair-share bound for one admission lane right now: with the
    /// backpressure window at `pending_cap` and `n` lanes open, each lane
    /// may hold `ceil(pending_cap / n)` (min 1) outstanding submissions.
    /// `usize::MAX` = unbounded (no cap configured).
    fn lane_share(&self) -> usize {
        let cap = self.cluster.cfg.stream.pending_cap;
        if cap == 0 || self.lanes.is_empty() {
            return usize::MAX;
        }
        cap.div_ceil(self.lanes.len()).max(1)
    }
}

/// A persistent serving session: one live executor + one cluster's stage
/// states, bound for the session's lifetime (see the module docs for the
/// lifecycle). Create with [`IndexSession::attach`]; the borrowed
/// [`Cluster`] is usable again after [`IndexSession::close`].
pub struct IndexSession<'s> {
    exec: &'s dyn Executor,
    hasher: &'s dyn Hasher,
    /// `Arc` rather than a borrow: the streaming DP handlers move onto
    /// executor-owned threads, which requires `'static` ownership.
    ranker: Option<Arc<dyn Ranker>>,
    /// The index's LSH params, frozen at attach — the defaulting source
    /// for per-query [`QueryOptions`] resolution.
    lsh: LshParams,
    /// mmLSH adaptive probing policy, frozen at attach:
    /// `Some((quantile, t_max))` when `[qos] adaptive_probes` is set.
    adaptive: Option<(f64, usize)>,
    inner: Mutex<Inner<'s>>,
}

impl<'s> IndexSession<'s> {
    /// Open a session over `cluster` on `exec`. Pass `ranker: None` only
    /// for build-only sessions (insert without search) — `submit` needs a
    /// ranker and will panic without one.
    pub fn attach(
        exec: &'s dyn Executor,
        cluster: &'s mut Cluster,
        hasher: &'s dyn Hasher,
        ranker: Option<Arc<dyn Ranker>>,
    ) -> IndexSession<'s> {
        let agg = cluster.cfg.stream.agg_bytes;
        let lsh = cluster.cfg.lsh;
        // `Config::from_doc` validated the spec; a hand-built config with
        // a broken spec degrades to the inert table (QoS off), never a
        // panic inside attach.
        let tag_table = TagTable::parse(&cluster.cfg.qos.tags).unwrap_or_default();
        let n_classes = tag_table.n_classes();
        let adaptive = cluster.cfg.qos.adaptive_probes.then(|| {
            (
                cluster.cfg.qos.adaptive_quantile,
                cluster.cfg.qos.adaptive_max.min(MAX_QUERY_PROBES),
            )
        });
        IndexSession {
            exec,
            hasher,
            ranker,
            lsh,
            adaptive,
            inner: Mutex::new(Inner {
                cluster,
                stream: None,
                next_ticket: 0,
                tickets: HashMap::new(),
                done: VecDeque::new(),
                lanes: HashMap::new(),
                next_lane: 1,
                evicted: 0,
                latency: LatencySummary::new(),
                head_work: WorkStats::default(),
                search_meter: TrafficMeter::new(agg),
                completed: 0,
                retargeted: 0,
                qos: tag_table,
                tag_accounts: vec![TagAccount::default(); n_classes],
                tag_outstanding: vec![0; n_classes],
                tag_work_base: WorkStats::default(),
            }),
        }
    }

    /// The session's default per-query plan — the config values this
    /// index was attached with. `submit(q)` uses exactly this.
    pub fn default_options(&self) -> QueryOptions {
        QueryOptions::from_params(&self.lsh)
    }

    /// Resolve a caller's options against the session's params: zero
    /// fields inherit, `tables` clamps into `1..=L`. This mirrors the
    /// Query Receiver's own resolution (same `k_or`/`probes_or`/
    /// `tables_in` helpers over the same params) — the *caller's* plan is
    /// what rides the wire (so default-elision stays live); the resolved
    /// copy is what the completion echoes.
    fn resolve(&self, opts: QueryOptions) -> QueryOptions {
        QueryOptions {
            k: opts.k_or(self.lsh.k) as u32,
            probes: opts.probes_or(self.lsh.t) as u32,
            tables: opts.tables_in(self.lsh.l) as u32,
            tag: opts.tag,
        }
    }

    /// Resolve an mmLSH-style adaptive probe budget (DESIGN.md §QoS
    /// scheduler) for one hashed query: when `[qos] adaptive_probes` is
    /// on and the caller left `probes = 0` (inherit), the per-table
    /// budget comes from the query's own perturbation-score profile
    /// ([`qos::adaptive_probes`]) instead of the configured `lsh.t`. The
    /// budget is stamped into BOTH the wire plan and the recv-side echo
    /// as an explicit value, so the Query Receiver's resolution — and
    /// with it every transport — replays the same plan bit-identically.
    /// Explicit budgets (`probes != 0`) are always honored unchanged.
    fn stamp_adaptive(&self, raw: &[f32], opts: &mut QueryOptions, echo: &mut QueryOptions) {
        let Some((quantile, t_max)) = self.adaptive else { return };
        if opts.probes != 0 {
            return;
        }
        let t = qos::adaptive_probes(raw, self.lsh.m, echo.tables as usize, t_max, quantile);
        opts.probes = t as u32;
        echo.probes = t as u32;
    }

    fn lock(&self) -> MutexGuard<'_, Inner<'s>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Open the streaming run if none is live: check the stage states out
    /// of the cluster into shared slots (so the handlers are owned and can
    /// cross onto executor threads) and hand them to the executor.
    fn open_stream_locked(&self, inner: &mut Inner<'s>) {
        if inner.stream.is_some() {
            return;
        }
        let ranker = self
            .ranker
            .clone()
            .expect("IndexSession streaming requires a ranker (attach with Some(ranker))");
        let c: &mut Cluster = &mut *inner.cluster;
        let placement = c.placement.clone();
        let cfg = StreamConfig {
            window: c.cfg.stream.inflight,
            agg_bytes: c.cfg.stream.agg_bytes,
            pending_cap: c.cfg.stream.pending_cap,
        };
        let family = c.family.clone();
        let qr_work = Arc::new(Mutex::new(WorkStats::default()));
        let bis: Vec<Arc<Mutex<BiState>>> = std::mem::take(&mut c.bis)
            .into_iter()
            .map(|s| Arc::new(Mutex::new(s)))
            .collect();
        let dps: Vec<Arc<Mutex<DpState>>> = std::mem::take(&mut c.dps)
            .into_iter()
            .map(|s| Arc::new(Mutex::new(s)))
            .collect();
        let ags: Vec<Arc<Mutex<AgState>>> = std::mem::take(&mut c.ags)
            .into_iter()
            .map(|s| Arc::new(Mutex::new(s)))
            .collect();
        let stages = StageHandlers {
            head: Box::new(SharedQr {
                family,
                n_bi: placement.bi_copies,
                n_ag: placement.ag_copies,
                work: qr_work.clone(),
            }),
            bis: bis
                .iter()
                .map(|s| Box::new(SharedBi { bi: s.clone() }) as Box<dyn StageHandler>)
                .collect(),
            dps: dps
                .iter()
                .map(|s| {
                    Box::new(SharedDp { dp: s.clone(), ranker: ranker.clone() })
                        as Box<dyn StageHandler>
                })
                .collect(),
            ags: ags
                .iter()
                .map(|s| Box::new(SharedAg { ag: s.clone() }) as Box<dyn StageHandler>)
                .collect(),
        };
        let run = self.exec.open_stream(&placement, stages, cfg);
        inner.stream = Some(OpenStream { run, bis, dps, ags, qr_work });
    }

    /// Finish the open stream (if any): barrier on quiescence, buffer the
    /// unclaimed completions, fold the run's accounting into the session,
    /// and return the stage states to the cluster.
    fn finish_stream_locked(&self, inner: &mut Inner<'s>) {
        let Some(os) = inner.stream.take() else { return };
        let OpenStream { run, bis, dps, ags, qr_work } = os;
        let report = run.finish();
        inner.search_meter.merge(&report.meter);
        inner.retargeted += report.retargeted;
        let qw = {
            let mut w = qr_work.lock().unwrap_or_else(|p| p.into_inner());
            std::mem::take(&mut *w)
        };
        inner.head_work.add(&qw);
        // Reclaim the stage states FIRST: `absorb_remote_work` folds the
        // socket barrier's per-copy counters into `cluster.bis`/`dps`,
        // which are empty until the slots return.
        inner.cluster.bis = bis.into_iter().map(reclaim).collect();
        inner.cluster.dps = dps.into_iter().map(reclaim).collect();
        inner.cluster.ags = ags.into_iter().map(reclaim).collect();
        inner.cluster.absorb_remote_work(&report.work);
        // Account the barrier's unclaimed completions only now, with the
        // states (and any socket-remote counters) back in the cluster, so
        // per-tag work attribution sees the full barrier totals.
        for c in report.unclaimed {
            if let Some(e) = inner.note_completion(c) {
                inner.done.push_back(e);
            }
        }
        debug_assert!(
            inner.tickets.is_empty(),
            "stream barrier left tickets outstanding"
        );
    }

    /// Index `dataset` incrementally (paper §IV-A: indexing and searching
    /// may overlap across a session). Acts as a barrier: the open stream
    /// is finished first, so queries submitted before the insert complete
    /// against the pre-insert index; the next `submit` reopens a stream.
    /// Returns the assigned id range.
    pub fn insert(&self, dataset: &Dataset) -> Range<u32> {
        let mut inner = self.lock();
        self.finish_stream_locked(&mut inner);
        let inner = &mut *inner;
        inner
            .cluster
            .insert_objects_on(self.exec, dataset.as_flat(), dataset.len(), self.hasher)
    }

    /// Admit one query under the session's default plan — shorthand for
    /// `submit_with(q, QueryOptions::default_from(&cfg))`, bit-identical
    /// to the pre-plan behavior (the pumped `search_on` oracle).
    pub fn submit(&self, q: &[f32]) -> QueryTicket {
        self.submit_with(q, QueryOptions::default())
    }

    /// Admit one query with a per-query search plan — it enters the
    /// executor pipeline immediately. `opts` fields left at 0 inherit the
    /// session's configured values; `tables` clamps into `1..=L`. Hashing
    /// happens on the calling thread; the ticket is issued under the
    /// session lock, in admission order. Blocks while
    /// `stream.pending_cap` submissions are outstanding (0 = never) —
    /// parking happens *between* lock acquisitions, so concurrent
    /// claimers and non-blocking calls keep running while a submitter
    /// waits out the backpressure window.
    pub fn submit_with(&self, q: &[f32], opts: QueryOptions) -> QueryTicket {
        assert!(
            self.ranker.is_some(),
            "IndexSession::submit on a session attached without a ranker"
        );
        let mut opts = opts;
        let mut echo = self.resolve(opts);
        let raw: Arc<[f32]> = self.hasher.proj_batch(q, 1).into();
        self.stamp_adaptive(&raw, &mut opts, &mut echo);
        let v: Arc<[f32]> = q.into();
        loop {
            {
                let mut inner = self.lock();
                self.open_stream_locked(&mut inner);
                if let Some(t) = inner.try_submit_one(raw.clone(), v.clone(), opts, echo, 0) {
                    return t;
                }
            }
            // Window full: park without the session lock. A dead run is
            // detected inside try_submit_one (loud panic), so this loop
            // cannot spin on a broken pipeline.
            std::thread::sleep(SUBMIT_TICK);
        }
    }

    /// Non-blocking [`IndexSession::submit`]: `None` when the
    /// backpressure window (`stream.pending_cap`) is full.
    pub fn try_submit(&self, q: &[f32]) -> Option<QueryTicket> {
        self.try_submit_with(q, QueryOptions::default())
    }

    /// Non-blocking [`IndexSession::submit_with`]: `None` when the
    /// backpressure window (`stream.pending_cap`) is full.
    pub fn try_submit_with(&self, q: &[f32], opts: QueryOptions) -> Option<QueryTicket> {
        assert!(
            self.ranker.is_some(),
            "IndexSession::try_submit on a session attached without a ranker"
        );
        let mut opts = opts;
        let mut echo = self.resolve(opts);
        // Probe the window (and the tag's weighted-fair share) before
        // paying for the hash: a caller polling try_submit against a full
        // window must not recompute projections on every declined
        // attempt. The probe is advisory — the final try_submit below
        // still decides.
        {
            let mut inner = self.lock();
            self.open_stream_locked(&mut inner);
            if !inner.tag_has_room(echo.tag) {
                return None;
            }
            let os = inner.stream.as_mut().expect("stream just opened");
            if !os.run.can_submit() {
                return None;
            }
        }
        let raw: Arc<[f32]> = self.hasher.proj_batch(q, 1).into();
        self.stamp_adaptive(&raw, &mut opts, &mut echo);
        let v: Arc<[f32]> = q.into();
        let mut inner = self.lock();
        self.open_stream_locked(&mut inner);
        inner.try_submit_one(raw, v, opts, echo, 0)
    }

    /// Admit a whole query set under the default plan — see
    /// [`IndexSession::submit_batch_with`].
    pub fn submit_batch(&self, queries: &Dataset) -> Range<u64> {
        self.submit_batch_with(queries, QueryOptions::default())
    }

    /// Admit a whole query set, every query under the same plan `opts`,
    /// through one batched hash call (the phase drivers' §Perf path).
    /// Returns the ticket range. Each query streams into the pipeline as
    /// it is enqueued; with a `pending_cap` set the batch parks (between
    /// lock acquisitions, like [`IndexSession::submit`]) whenever the
    /// window fills — if other threads submit concurrently during such a
    /// park, the returned range can include their tickets, so concurrent
    /// callers should match results by ticket, not offset.
    pub fn submit_batch_with(&self, queries: &Dataset, opts: QueryOptions) -> Range<u64> {
        assert!(
            self.ranker.is_some(),
            "IndexSession::submit_batch on a session attached without a ranker"
        );
        let echo = self.resolve(opts);
        let p = self.hasher.p();
        let raws = self.hasher.proj_batch(queries.as_flat(), queries.len());
        let mut start = 0u64;
        let mut end = 0u64;
        let mut first = true;
        let mut i = 0usize;
        loop {
            {
                let mut inner = self.lock();
                self.open_stream_locked(&mut inner);
                if first {
                    start = inner.next_ticket;
                    first = false;
                }
                while i < queries.len() {
                    let raw: Arc<[f32]> = raws[i * p..(i + 1) * p].into();
                    let v: Arc<[f32]> = queries.get(i).into();
                    // adaptive budgets are per *query*, so each item of
                    // the batch stamps its own copy of the shared plan
                    let (mut q_opts, mut q_echo) = (opts, echo);
                    self.stamp_adaptive(&raw, &mut q_opts, &mut q_echo);
                    if inner.try_submit_one(raw, v, q_opts, q_echo, 0).is_none() {
                        break;
                    }
                    i += 1;
                }
                end = inner.next_ticket;
            }
            if i >= queries.len() {
                return start..end;
            }
            std::thread::sleep(SUBMIT_TICK);
        }
    }

    // ------------------------------------------------- admission lanes

    /// Open an admission lane: a named share of the backpressure window
    /// for one external client (the `net::front` server opens one per
    /// connection). While `stream.pending_cap` is set, each open lane may
    /// hold at most `ceil(pending_cap / open_lanes)` outstanding
    /// submissions — per-client fairness at the admission gate: no lane
    /// can occupy the whole window while another waits. Lane ids are
    /// never reused within a session.
    pub fn open_lane(&self) -> u32 {
        let mut inner = self.lock();
        let lane = inner.next_lane;
        inner.next_lane += 1;
        inner.lanes.insert(lane, 0);
        lane
    }

    /// Close a lane (its client disconnected). Submissions still in
    /// flight on the lane are *orphaned*: the pipeline completes them as
    /// usual — the stream barrier stays sound — but their completions are
    /// discarded on arrival instead of delivered, and the lane's window
    /// share returns to the remaining lanes immediately. Returns the
    /// number of tickets orphaned (callers log the eviction).
    pub fn close_lane(&self, lane: u32) -> usize {
        let mut inner = self.lock();
        inner.lanes.remove(&lane);
        // Drop any already-claimed-but-undelivered completions too: the
        // connection they belong to is gone.
        let before = inner.done.len();
        inner.done.retain(|(l, _)| *l != lane);
        let buffered = before - inner.done.len();
        inner.evicted += buffered as u64;
        inner.tickets.values().filter(|(_, _, l)| *l == lane).count() + buffered
    }

    /// Non-blocking submit on an admission lane —
    /// [`IndexSession::try_submit_with`] plus the lane's fair-share
    /// bound: declines when the lane already holds its share of the
    /// backpressure window, even if the global window still has room.
    /// Panics if `lane` was not opened (or was already closed); like the
    /// other submit paths, the query hashes on the calling thread, and
    /// only after a cheap window probe.
    pub fn try_submit_lane(&self, lane: u32, q: &[f32], opts: QueryOptions) -> Option<QueryTicket> {
        assert!(
            self.ranker.is_some(),
            "IndexSession::try_submit_lane on a session attached without a ranker"
        );
        let mut opts = opts;
        let mut echo = self.resolve(opts);
        // Probe lane share + tag share + window before paying for the
        // hash (advisory; the final try_submit_one below still decides).
        {
            let mut inner = self.lock();
            self.open_stream_locked(&mut inner);
            let held = *inner.lanes.get(&lane).expect("submit on an unopened lane");
            if held >= inner.lane_share() {
                return None;
            }
            if !inner.tag_has_room(echo.tag) {
                return None;
            }
            let os = inner.stream.as_mut().expect("stream just opened");
            if !os.run.can_submit() {
                return None;
            }
        }
        let raw: Arc<[f32]> = self.hasher.proj_batch(q, 1).into();
        self.stamp_adaptive(&raw, &mut opts, &mut echo);
        let v: Arc<[f32]> = q.into();
        let mut inner = self.lock();
        self.open_stream_locked(&mut inner);
        let held = *inner.lanes.get(&lane).expect("submit on an unopened lane");
        if held >= inner.lane_share() {
            return None;
        }
        inner.try_submit_one(raw, v, opts, echo, lane)
    }

    /// Outstanding (submitted, unclaimed) queries on one lane.
    pub fn lane_in_flight(&self, lane: u32) -> usize {
        let inner = self.lock();
        inner.lanes.get(&lane).copied().unwrap_or(0)
    }

    /// Pop a completion without waiting. `None` means nothing has
    /// completed yet (the pipeline keeps working in the background).
    pub fn try_recv(&self) -> Option<(QueryTicket, Vec<(f32, u32)>)> {
        self.try_recv_full().map(|(t, _, h, _)| (t, h))
    }

    /// [`IndexSession::try_recv`] with the admission-to-completion seconds.
    pub fn try_recv_timed(&self) -> Option<(QueryTicket, Vec<(f32, u32)>, f64)> {
        self.try_recv_full().map(|(t, _, h, s)| (t, h, s))
    }

    /// [`IndexSession::try_recv`] with the full completion context: the
    /// ticket, the resolved [`QueryOptions`] the query ran under (the
    /// option echo — including the caller's `tag`), the top-k, and the
    /// admission-to-completion seconds.
    pub fn try_recv_full(&self) -> Option<Completion> {
        self.try_recv_lane().map(|(_, e)| e)
    }

    /// [`IndexSession::try_recv_full`] with the admission lane the query
    /// was submitted on (0 for the plain submit APIs) — the front door's
    /// claim path, which routes each completion back to the connection
    /// whose lane admitted it. Orphaned completions (lanes closed by a
    /// disconnect) are discarded in passing, never returned.
    pub fn try_recv_lane(&self) -> Option<(u32, Completion)> {
        let mut inner = self.lock();
        if let Some(e) = inner.done.pop_front() {
            return Some(e);
        }
        loop {
            let c = {
                let os = inner.stream.as_mut()?;
                os.run.try_recv()
            }?;
            if let Some(e) = inner.note_completion(c) {
                return Some(e);
            }
        }
    }

    /// Next completion, waiting for the pipeline if necessary. `None`
    /// means the session is idle (nothing outstanding, nothing buffered).
    pub fn recv(&self) -> Option<(QueryTicket, Vec<(f32, u32)>)> {
        self.recv_full().map(|(t, _, h, _)| (t, h))
    }

    /// [`IndexSession::recv`] with the admission-to-completion seconds.
    pub fn recv_timed(&self) -> Option<(QueryTicket, Vec<(f32, u32)>, f64)> {
        self.recv_full().map(|(t, _, h, s)| (t, h, s))
    }

    /// [`IndexSession::recv`] with the full completion context (see
    /// [`IndexSession::try_recv_full`]).
    pub fn recv_full(&self) -> Option<Completion> {
        loop {
            let mut inner = self.lock();
            if let Some((_lane, e)) = inner.done.pop_front() {
                return Some(e);
            }
            if inner.tickets.is_empty() {
                return None;
            }
            let c = {
                let os = inner
                    .stream
                    .as_mut()
                    .expect("in-flight tickets without an open stream");
                os.run.recv(RECV_TICK)
            };
            if let Some(c) = c {
                if let Some((_lane, e)) = inner.note_completion(c) {
                    return Some(e);
                }
                // Orphaned completion discarded: go around again.
                continue;
            }
            // Nothing completed within the tick: release the session lock
            // before waiting again so concurrent submitters can get in.
            drop(inner);
            std::thread::yield_now();
        }
    }

    /// Wait for everything outstanding and return all unclaimed
    /// completions, ticket-ordered. Like `recv`, the wait releases the
    /// session lock between egress ticks so submitters are not stalled.
    pub fn drain(&self) -> Vec<(QueryTicket, Vec<(f32, u32)>)> {
        self.drain_full().into_iter().map(|(t, _, h, _)| (t, h)).collect()
    }

    /// [`IndexSession::drain`] with the full completion context per
    /// ticket (option echo included), ticket-ordered.
    pub fn drain_full(&self) -> Vec<Completion> {
        let mut out: Vec<Completion> = Vec::new();
        loop {
            let mut inner = self.lock();
            while let Some((_lane, e)) = inner.done.pop_front() {
                out.push(e);
            }
            if inner.tickets.is_empty() {
                break;
            }
            let c = {
                let os = inner
                    .stream
                    .as_mut()
                    .expect("in-flight tickets without an open stream");
                os.run.recv(RECV_TICK)
            };
            if let Some(c) = c {
                if let Some((_lane, e)) = inner.note_completion(c) {
                    out.push(e);
                }
            } else {
                drop(inner);
                std::thread::yield_now();
            }
        }
        out.sort_by_key(|e| e.0);
        out
    }

    /// Queries admitted but not yet delivered through `recv`/`drain`.
    pub fn in_flight(&self) -> usize {
        let inner = self.lock();
        inner.tickets.len() + inner.done.len()
    }

    /// Live accounting snapshot (does not reset any counter). Works with
    /// a stream open — per-copy counters are read through the shared
    /// slots the stream's handlers write into. Caveat (socket transport):
    /// remote BI/DP counters travel in the stream-*finish* barrier, so a
    /// mid-stream snapshot reflects only work absorbed at earlier
    /// barriers; `close()` returns the complete final accounting.
    pub fn stats(&self) -> SessionStats {
        let inner = self.lock();
        let c: &Cluster = &*inner.cluster;
        let mut work = Vec::new();
        match &inner.stream {
            Some(os) => {
                let mut head = inner.head_work;
                {
                    let qw = os.qr_work.lock().unwrap_or_else(|p| p.into_inner());
                    head.add(&qw);
                }
                work.push((StageKind::Qr, 0u16, head));
                for slot in &os.bis {
                    let s = slot.lock().unwrap_or_else(|p| p.into_inner());
                    // The memory gauge reads current state at snapshot
                    // time (max keeps any remote gauge absorbed earlier).
                    let mut w = s.work;
                    w.bytes_resident = w.bytes_resident.max(s.bytes_resident());
                    work.push((StageKind::Bi, s.copy, w));
                }
                for slot in &os.dps {
                    let s = slot.lock().unwrap_or_else(|p| p.into_inner());
                    let mut w = s.work;
                    w.bytes_resident = w.bytes_resident.max(s.bytes_resident());
                    work.push((StageKind::Dp, s.copy, w));
                }
                for slot in &os.ags {
                    let s = slot.lock().unwrap_or_else(|p| p.into_inner());
                    work.push((StageKind::Ag, s.copy, s.work));
                }
            }
            None => {
                work.push((StageKind::Qr, 0u16, inner.head_work));
                for bi in &c.bis {
                    let mut w = bi.work;
                    w.bytes_resident = w.bytes_resident.max(bi.bytes_resident());
                    work.push((StageKind::Bi, bi.copy, w));
                }
                for dp in &c.dps {
                    let mut w = dp.work;
                    w.bytes_resident = w.bytes_resident.max(dp.bytes_resident());
                    work.push((StageKind::Dp, dp.copy, w));
                }
                for ag in &c.ags {
                    work.push((StageKind::Ag, ag.copy, ag.work));
                }
            }
        }
        let per_tag = inner
            .tag_accounts
            .iter()
            .enumerate()
            .map(|(class, a)| TagStats {
                name: inner.qos.class_name(class).to_string(),
                tag: inner.qos.canonical_tag(class),
                weight: inner.qos.weight(class),
                submitted: a.submitted,
                completed: a.completed,
                outstanding: inner.tag_outstanding[class],
                latency: a.latency.clone(),
                work: a.work,
            })
            .collect();
        SessionStats {
            build_meter: c.build_meter.clone(),
            search_meter: inner.search_meter.clone(),
            work,
            latency: inner.latency.clone(),
            queries_submitted: inner.next_ticket,
            queries_completed: inner.completed,
            queries_evicted: inner.evicted,
            objects_indexed: c.indexed_objects as u64,
            queries_retargeted: inner.retargeted,
            per_tag,
        }
    }

    /// Take (and reset) the per-copy work counters accumulated since the
    /// last reset — phase accounting, the session rendition of
    /// [`Cluster::take_work`]. Complete on the in-process transports with
    /// or without an open stream; under the socket transport remote
    /// counters are collected at stream barriers (`insert`/`close`), so
    /// take phase accounting at a barrier for complete remote numbers.
    pub fn take_work(&self) -> Vec<(StageKind, u16, WorkStats)> {
        let mut inner = self.lock();
        let inner = &mut *inner;
        let mut head = std::mem::take(&mut inner.head_work);
        match &inner.stream {
            Some(os) => {
                {
                    let mut qw = os.qr_work.lock().unwrap_or_else(|p| p.into_inner());
                    head.add(&std::mem::take(&mut *qw));
                }
                let mut out = vec![(StageKind::Qr, 0u16, head)];
                for slot in &os.bis {
                    let mut s = slot.lock().unwrap_or_else(|p| p.into_inner());
                    // refresh the memory gauge at the take point
                    s.work.bytes_resident =
                        s.work.bytes_resident.max(s.bytes_resident());
                    out.push((StageKind::Bi, s.copy, std::mem::take(&mut s.work)));
                }
                for slot in &os.dps {
                    let mut s = slot.lock().unwrap_or_else(|p| p.into_inner());
                    s.work.bytes_resident =
                        s.work.bytes_resident.max(s.bytes_resident());
                    out.push((StageKind::Dp, s.copy, std::mem::take(&mut s.work)));
                }
                for slot in &os.ags {
                    let mut s = slot.lock().unwrap_or_else(|p| p.into_inner());
                    out.push((StageKind::Ag, s.copy, std::mem::take(&mut s.work)));
                }
                out
            }
            None => inner.cluster.take_work(&head),
        }
    }

    /// Typed end of session: finishes the open stream (completing any
    /// still-pending queries, so per-query teardown reaches every
    /// transport) and returns the final stats. Unclaimed completions are
    /// discarded — `drain` first if you want them. The borrowed `Cluster`
    /// is usable again afterwards; under the socket transport the workers
    /// stay up (they belong to the `NetSession`), ready for the next
    /// session.
    pub fn close(self) -> SessionStats {
        {
            let mut inner = self.lock();
            self.finish_stream_locked(&mut inner);
        }
        self.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::{build_index, build_index_on, search, search_on, small_test_cfg};
    use crate::data::synth::{distorted_queries, synthesize, SynthSpec};
    use crate::dataflow::exec::{InlineExecutor, ThreadedExecutor};
    use crate::runtime::{ScalarHasher, ScalarRanker};
    use std::sync::Condvar;

    fn world(
        cfg: &Config,
        n: usize,
        queries: usize,
    ) -> (Dataset, Dataset, ScalarHasher, Arc<dyn Ranker>) {
        let ds = synthesize(SynthSpec { n, clusters: 40, ..Default::default() });
        let (qs, _) = distorted_queries(&ds, queries, 4.0, 7);
        let family = crate::core::lsh::HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let ranker: Arc<dyn Ranker> = Arc::new(ScalarRanker { dim: ds.dim });
        (ds, qs, hasher, ranker)
    }

    /// The inline-vs-threaded differential contract on the pumped phase
    /// path (search_on), which streaming results are compared against in
    /// the streaming tests below.
    fn assert_matches_inline(cfg: &Config, n: usize, queries: usize) {
        let (ds, qs, hasher, ranker) = world(cfg, n, queries);
        let mut c1 = build_index(cfg, &ds, &hasher);
        let inline_out = search(&mut c1, &qs, &hasher, ranker.as_ref());
        let mut c2 = build_index(cfg, &ds, &hasher);
        let threaded_out =
            search_on(&ThreadedExecutor, &mut c2, &qs, &hasher, ranker.as_ref());

        assert_eq!(inline_out.results, threaded_out.results);
        // traffic counters agree (logical messages & payload bytes are
        // aggregation-independent).
        assert_eq!(
            inline_out.meter.logical_msgs,
            threaded_out.meter.logical_msgs
        );
        // payload agrees within 1%: DP dedup depends on cross-BI arrival
        // order, which can shift a few hits between LocalTopK messages
        // (the merged result set is identical — asserted above).
        let (a, b) = (
            inline_out.meter.payload_bytes as f64,
            threaded_out.meter.payload_bytes as f64,
        );
        assert!((a - b).abs() / a < 0.01, "payload diverged: {a} vs {b}");
        // states returned intact
        assert_eq!(c2.bis.len(), cfg.cluster.bi_copies());
        assert_eq!(c2.dps.len(), cfg.cluster.dp_copies());
        assert_eq!(c2.ags.len(), cfg.cluster.ag_copies);
        assert!(threaded_out.per_query_secs.iter().all(|&s| s > 0.0));
    }

    fn small_cfg() -> Config {
        small_test_cfg()
    }

    #[test]
    fn threaded_matches_inline_results() {
        assert_matches_inline(&small_cfg(), 1_500, 15);
    }

    #[test]
    fn threaded_matches_inline_under_batched_admission() {
        for window in [1usize, 3] {
            let mut cfg = small_cfg();
            cfg.stream.inflight = window;
            assert_matches_inline(&cfg, 1_500, 15);
        }
    }

    #[test]
    fn threaded_matches_inline_with_multiple_aggregators() {
        let mut cfg = small_cfg();
        cfg.cluster.ag_copies = 3;
        assert_matches_inline(&cfg, 1_500, 20);
        let mut cfg = small_cfg();
        cfg.cluster.ag_copies = 2;
        cfg.stream.inflight = 2;
        assert_matches_inline(&cfg, 1_200, 18);
    }

    #[test]
    fn threaded_build_then_threaded_search_matches_inline_pipeline() {
        let mut cfg = small_cfg();
        cfg.stream.inflight = 4;
        let (ds, qs, hasher, ranker) = world(&cfg, 1_500, 15);

        let mut inline_cluster = build_index(&cfg, &ds, &hasher);
        let inline_out = search(&mut inline_cluster, &qs, &hasher, ranker.as_ref());

        let mut threaded_cluster = build_index_on(&ThreadedExecutor, &cfg, &ds, &hasher);
        let threaded_out = search_on(
            &ThreadedExecutor,
            &mut threaded_cluster,
            &qs,
            &hasher,
            ranker.as_ref(),
        );

        assert_eq!(inline_out.results, threaded_out.results);
        assert_eq!(
            inline_cluster.build_meter.logical_msgs,
            threaded_cluster.build_meter.logical_msgs
        );
    }

    #[test]
    fn streaming_submit_recv_matches_pumped_search() {
        // One query at a time — submit, wait for its completion, submit the
        // next — must give the same answers as the pumped phase call, on
        // the per-item-drain (inline) and the threaded streaming runs.
        let cfg = small_cfg();
        let (ds, qs, hasher, ranker) = world(&cfg, 1_200, 10);
        let mut oracle_cluster = build_index(&cfg, &ds, &hasher);
        let oracle = search(&mut oracle_cluster, &qs, &hasher, ranker.as_ref());

        for exec in [&InlineExecutor as &dyn Executor, &ThreadedExecutor] {
            let mut cluster = build_index(&cfg, &ds, &hasher);
            let session =
                IndexSession::attach(exec, &mut cluster, &hasher, Some(ranker.clone()));
            for qi in 0..qs.len() {
                let ticket = session.submit(qs.get(qi));
                assert_eq!(ticket, QueryTicket(qi as u64));
                let (t, hits) = session.recv().expect("one in flight");
                assert_eq!(t, ticket);
                assert_eq!(hits, oracle.results[qi], "query {qi}");
            }
            assert!(session.recv().is_none(), "idle session must report None");
            let stats = session.close();
            assert_eq!(stats.queries_submitted, qs.len() as u64);
            assert_eq!(stats.queries_completed, qs.len() as u64);
            assert!(stats.search_meter.logical_msgs > 0);
            assert_eq!(stats.latency.count, qs.len() as u64);
            assert!(stats.latency.min_secs > 0.0);
        }
    }

    #[test]
    fn interleaved_streaming_matches_pumped_search() {
        // Streaming admission with interleaved claims under a window and
        // multiple AGs must return exactly the pumped path's results,
        // matched by ticket.
        let mut cfg = small_cfg();
        cfg.stream.inflight = 2;
        cfg.cluster.ag_copies = 2;
        let (ds, qs, hasher, ranker) = world(&cfg, 1_500, 20);
        let mut oracle_cluster = build_index(&cfg, &ds, &hasher);
        let oracle = search(&mut oracle_cluster, &qs, &hasher, ranker.as_ref());

        let mut cluster = build_index(&cfg, &ds, &hasher);
        let session = IndexSession::attach(
            &ThreadedExecutor,
            &mut cluster,
            &hasher,
            Some(ranker.clone()),
        );
        let mut got: Vec<Option<Vec<(f32, u32)>>> = vec![None; qs.len()];
        for qi in 0..qs.len() {
            session.submit(qs.get(qi));
            while let Some((t, hits)) = session.try_recv() {
                got[t.0 as usize] = Some(hits);
            }
        }
        for (t, hits) in session.drain() {
            got[t.0 as usize] = Some(hits);
        }
        for (qi, g) in got.iter().enumerate() {
            assert_eq!(
                g.as_ref().expect("completed"),
                &oracle.results[qi],
                "query {qi}"
            );
        }
        // bounded accounting: the in-flight map drained as queries completed
        assert_eq!(session.in_flight(), 0);
        let stats = session.close();
        assert_eq!(stats.queries_completed, qs.len() as u64);
        assert_eq!(stats.latency.count, qs.len() as u64);
    }

    #[test]
    fn session_build_insert_search_in_one_lifetime() {
        // The full lifecycle on one session: open empty, insert twice,
        // then serve — identical to building over the concatenation.
        let cfg = small_cfg();
        let (ds, _, hasher, ranker) = world(&cfg, 1_500, 10);
        let (extra, _) = distorted_queries(&ds, 40, 1.0, 99);
        let mut concat = Dataset::new(ds.dim);
        for i in 0..ds.len() {
            concat.push(ds.get(i));
        }
        for i in 0..extra.len() {
            concat.push(extra.get(i));
        }
        let (qs, _) = distorted_queries(&concat, 12, 3.0, 5);
        let mut oracle_cluster = build_index(&cfg, &concat, &hasher);
        let oracle = search(&mut oracle_cluster, &qs, &hasher, ranker.as_ref());

        let mut cluster = Cluster::empty(&cfg, ds.dim);
        {
            let session = IndexSession::attach(
                &ThreadedExecutor,
                &mut cluster,
                &hasher,
                Some(ranker.clone()),
            );
            assert_eq!(session.insert(&ds), 0..ds.len() as u32);
            assert_eq!(
                session.insert(&extra),
                ds.len() as u32..concat.len() as u32
            );
            let tickets = session.submit_batch(&qs);
            assert_eq!(tickets, 0..qs.len() as u64);
            let done = session.drain();
            assert_eq!(done.len(), qs.len());
            for (i, (t, hits)) in done.iter().enumerate() {
                assert_eq!(t.0, i as u64);
                assert_eq!(hits, &oracle.results[i], "query {i}");
            }
            let stats = session.close();
            assert_eq!(stats.objects_indexed as usize, concat.len());
            assert!(stats.build_meter.logical_msgs > 0);
        }
        assert_eq!(cluster.stored_objects(), concat.len());
        assert_eq!(cluster.bucket_references(), concat.len() * cfg.lsh.l);
    }

    #[test]
    fn insert_is_a_barrier_for_earlier_submissions() {
        // A query submitted before an insert must be answered against the
        // pre-insert index: the insert finishes the open stream (waiting
        // for the query) before any new object is indexed — on the
        // per-item drain and on the threaded streaming run alike.
        let cfg = small_cfg();
        let (ds, _, hasher, ranker) = world(&cfg, 1_200, 5);
        // Query = an exact duplicate of a vector we insert *after*
        // submitting it: distance-0 hit exists only post-insert.
        let (dup, _) = distorted_queries(&ds, 1, 0.0, 3);
        let mut pre_cluster = build_index(&cfg, &ds, &hasher);
        let pre = search(&mut pre_cluster, &dup, &hasher, ranker.as_ref());

        for exec in [&InlineExecutor as &dyn Executor, &ThreadedExecutor] {
            let mut cluster = build_index(&cfg, &ds, &hasher);
            let session =
                IndexSession::attach(exec, &mut cluster, &hasher, Some(ranker.clone()));
            let before = session.submit(dup.get(0));
            session.insert(&dup);
            let after = session.submit(dup.get(0));
            let mut got: Vec<_> = session.drain();
            got.sort_by_key(|e| e.0);
            assert_eq!(got[0].0, before);
            assert_eq!(got[0].1, pre.results[0], "pre-insert query saw the insert");
            assert_eq!(got[1].0, after);
            // the post-insert query must retrieve the inserted duplicate
            // (its base vector ties at distance 0 → assert membership)
            assert!(
                got[1].1.iter().any(|&(_, id)| id == ds.len() as u32),
                "post-insert query missed the insert: {:?}",
                got[1].1
            );
            session.close();
        }
    }

    #[test]
    fn take_work_resets_like_phase_accounting() {
        let cfg = small_cfg();
        let (ds, qs, hasher, ranker) = world(&cfg, 1_200, 8);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let session =
            IndexSession::attach(&InlineExecutor, &mut cluster, &hasher, Some(ranker));
        session.submit_batch(&qs);
        let _ = session.drain();
        // the stream is still open here: take_work reads the shared slots
        let work = session.take_work();
        let dists: u64 = work.iter().map(|(_, _, w)| w.dists_computed).sum();
        assert!(dists > 0);
        let again = session.take_work();
        assert!(again.iter().all(|(_, _, w)| w.dists_computed == 0));
        session.close();
    }

    #[test]
    fn submit_with_mixed_plans_matches_across_executors_and_echoes_options() {
        let cfg = small_cfg();
        let (ds, qs, hasher, ranker) = world(&cfg, 1_200, 8);
        let plan = |qi: usize| QueryOptions {
            k: 1 + (qi as u32 % 3),
            probes: 1 + 2 * (qi as u32 % 4),
            tables: if qi % 2 == 0 { 0 } else { 2 },
            tag: 1000 + qi as u32,
        };
        let run = |exec: &dyn Executor| -> Vec<Completion> {
            let mut cluster = build_index(&cfg, &ds, &hasher);
            let session =
                IndexSession::attach(exec, &mut cluster, &hasher, Some(ranker.clone()));
            for qi in 0..qs.len() {
                session.submit_with(qs.get(qi), plan(qi));
            }
            let out = session.drain_full();
            session.close();
            out
        };
        let inline = run(&InlineExecutor);
        let threaded = run(&ThreadedExecutor);
        assert_eq!(inline.len(), qs.len());
        for (qi, (t, opts, hits, _)) in inline.iter().enumerate() {
            assert_eq!(t.0 as usize, qi);
            // echoed options are the *resolved* plan: zero fields filled in
            let want = plan(qi);
            assert_eq!(opts.tag, want.tag);
            assert_eq!(opts.k, want.k);
            assert_eq!(opts.probes, want.probes);
            assert_eq!(
                opts.tables,
                if want.tables == 0 { cfg.lsh.l as u32 } else { want.tables }
            );
            assert!(hits.len() <= opts.k as usize, "hits overflow the plan's k");
        }
        // transport-independent: identical per-ticket results and echoes
        let strip = |v: &[Completion]| {
            v.iter()
                .map(|(t, o, h, _)| (t.0, *o, h.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(strip(&inline), strip(&threaded));
    }

    /// A ranker whose `rank` parks on a latch — holds queries in flight
    /// deterministically so backpressure is observable without timing
    /// probes.
    struct LatchRanker {
        inner: ScalarRanker,
        open: Arc<(Mutex<bool>, Condvar)>,
    }

    impl Ranker for LatchRanker {
        fn rank(&self, q: &[f32], cands: &[f32], n: usize, k: usize) -> Vec<(f32, u32)> {
            let (m, cv) = &*self.open;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            drop(open);
            self.inner.rank(q, cands, n, k)
        }
    }

    #[test]
    fn submit_blocks_at_pending_cap_and_unblocks_as_completions_drain() {
        let mut cfg = small_cfg();
        cfg.stream.pending_cap = 2;
        let (ds, _, hasher, _) = world(&cfg, 1_200, 1);
        // exact duplicates of indexed vectors: every query reaches a DP
        // rank call, so the latch reliably holds them in flight
        let (qs, _) = distorted_queries(&ds, 3, 0.0, 21);
        let open = Arc::new((Mutex::new(false), Condvar::new()));
        let ranker: Arc<dyn Ranker> = Arc::new(LatchRanker {
            inner: ScalarRanker { dim: ds.dim },
            open: open.clone(),
        });
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let session =
            IndexSession::attach(&ThreadedExecutor, &mut cluster, &hasher, Some(ranker));
        session.submit(qs.get(0));
        session.submit(qs.get(1));
        // both queries are parked in the latched ranker: the window is full
        assert!(
            session.try_submit(qs.get(2)).is_none(),
            "try_submit ignored stream.pending_cap"
        );
        // a blocking submitter parks on the gate; opening the latch lets
        // completions drain, which must wake it (liveness, no timing probe)
        let waited = std::thread::scope(|s| {
            let h = s.spawn(|| session.submit(qs.get(2)));
            {
                let (m, cv) = &*open;
                *m.lock().unwrap() = true;
                cv.notify_all();
            }
            h.join().expect("blocked submitter finished")
        });
        assert_eq!(waited, QueryTicket(2));
        let done = session.drain();
        assert_eq!(done.len(), 3);
        let stats = session.close();
        assert_eq!(stats.queries_completed, 3);
    }

    #[test]
    fn admission_lanes_bound_each_client_and_orphan_on_close() {
        let mut cfg = small_cfg();
        cfg.stream.pending_cap = 4;
        let (ds, _, hasher, _) = world(&cfg, 1_200, 1);
        // exact duplicates: every query reaches a DP rank call, so the
        // latch reliably holds them in flight
        let (qs, _) = distorted_queries(&ds, 8, 0.0, 33);
        let open = Arc::new((Mutex::new(false), Condvar::new()));
        let ranker: Arc<dyn Ranker> = Arc::new(LatchRanker {
            inner: ScalarRanker { dim: ds.dim },
            open: open.clone(),
        });
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let session =
            IndexSession::attach(&ThreadedExecutor, &mut cluster, &hasher, Some(ranker));
        let a = session.open_lane();
        let b = session.open_lane();
        // share = ceil(4 / 2) = 2: lane A holds two and is declined on
        // the third, even though the global window (4) still has room...
        let o = QueryOptions::default();
        assert!(session.try_submit_lane(a, qs.get(0), o).is_some());
        assert!(session.try_submit_lane(a, qs.get(1), o).is_some());
        assert!(
            session.try_submit_lane(a, qs.get(2), o).is_none(),
            "lane A exceeded its fair share of pending_cap"
        );
        // ...while lane B still gets its own share
        assert!(session.try_submit_lane(b, qs.get(3), o).is_some());
        assert_eq!(session.lane_in_flight(a), 2);
        assert_eq!(session.lane_in_flight(b), 1);
        // client A disconnects mid-burst: its in-flight tickets orphan
        assert_eq!(session.close_lane(a), 2);
        // open the latch; the pipeline finishes everything outstanding
        {
            let (m, cv) = &*open;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        // only lane B's completion is deliverable; A's are discarded as
        // they arrive (and the survivor's result is a real top-k)
        let deadline = std::time::Instant::now() + Duration::from_secs(60);
        let mut delivered = Vec::new();
        while session.in_flight() > 0 {
            if let Some((lane, (_t, _opts, hits, _secs))) = session.try_recv_lane() {
                delivered.push((lane, hits));
            } else {
                assert!(std::time::Instant::now() < deadline, "pipeline stalled");
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        assert_eq!(delivered.len(), 1, "orphaned completions were delivered");
        assert_eq!(delivered[0].0, b);
        assert!(!delivered[0].1.is_empty(), "survivor lost its results");
        let stats = session.close();
        assert_eq!(stats.queries_completed, 3);
        assert_eq!(stats.queries_evicted, 2);
    }

    #[test]
    fn wfq_admission_reserves_share_for_light_tags() {
        let mut cfg = small_cfg();
        cfg.stream.pending_cap = 4;
        cfg.qos.tags = "gold:1,silver:1".to_string();
        let (ds, _, hasher, _) = world(&cfg, 1_200, 1);
        // exact duplicates: every query reaches a DP rank call, so the
        // latch deterministically holds them in flight
        let (qs, _) = distorted_queries(&ds, 8, 0.0, 21);
        let open = Arc::new((Mutex::new(false), Condvar::new()));
        let ranker: Arc<dyn Ranker> = Arc::new(LatchRanker {
            inner: ScalarRanker { dim: ds.dim },
            open: open.clone(),
        });
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let session =
            IndexSession::attach(&ThreadedExecutor, &mut cluster, &hasher, Some(ranker));
        let gold = QueryOptions { tag: 1, ..QueryOptions::default() };
        let silver = QueryOptions { tag: 2, ..QueryOptions::default() };
        // gold is the only active class: it borrows silver's idle weight
        // (share = the whole window) and admits
        assert!(session.try_submit_with(qs.get(0), gold).is_some());
        // both classes active: equal weights repartition to ceil(4/2) = 2
        assert!(session.try_submit_with(qs.get(1), silver).is_some());
        assert!(session.try_submit_with(qs.get(2), silver).is_some());
        // the flooding class parks at ITS share while the global window
        // still has room (3 of 4 held) — the WFQ gate, not pending_cap
        assert!(
            session.try_submit_with(qs.get(3), silver).is_none(),
            "silver overran its weighted-fair share"
        );
        // ...and the light class still has its reserved slice
        assert!(session.try_submit_with(qs.get(4), gold).is_some());
        {
            let (m, cv) = &*open;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        let done = session.drain_full();
        assert_eq!(done.len(), 4);
        let stats = session.close();
        let row = |name: &str| {
            stats
                .per_tag
                .iter()
                .find(|r| r.name == name)
                .expect("per-tag row")
                .clone()
        };
        assert_eq!((row("gold").submitted, row("gold").completed), (2, 2));
        assert_eq!((row("silver").submitted, row("silver").completed), (2, 2));
        assert_eq!(row("gold").latency.count, 2);
        assert_eq!(row("silver").outstanding, 0);
        assert_eq!(row("*").submitted, 0);
        let attributed: u64 =
            stats.per_tag.iter().map(|r| r.work.dists_computed).sum();
        assert!(attributed > 0, "per-tag work attribution recorded nothing");
    }

    #[test]
    fn adaptive_probe_budgets_echo_and_replay_identically() {
        // With [qos] adaptive_probes on, a probes = 0 plan resolves per
        // query from its perturbation-score profile; the echoed budget is
        // an explicit plan that must (a) agree across transports and (b)
        // replay bit-identically with the policy off.
        let mut cfg = small_cfg();
        cfg.qos.adaptive_probes = true;
        cfg.qos.adaptive_quantile = 0.5;
        cfg.qos.adaptive_max = 8;
        cfg.lsh.t = 30; // a budget the adaptive clamp can never emit
        let (ds, qs, hasher, ranker) = world(&cfg, 1_200, 10);
        let run = |cfg: &Config, plan: &dyn Fn(usize) -> QueryOptions, exec: &dyn Executor| {
            let mut cluster = build_index(cfg, &ds, &hasher);
            let session =
                IndexSession::attach(exec, &mut cluster, &hasher, Some(ranker.clone()));
            for qi in 0..qs.len() {
                session.submit_with(qs.get(qi), plan(qi));
            }
            let out = session.drain_full();
            session.close();
            out
        };
        let inline = run(&cfg, &|_| QueryOptions::default(), &InlineExecutor);
        let threaded = run(&cfg, &|_| QueryOptions::default(), &ThreadedExecutor);
        // every budget resolved into [1, adaptive_max], below the config
        // default — proof the adaptive path (not lsh.t) decided
        for (_, opts, _, _) in &inline {
            assert!(
                (1..=8).contains(&opts.probes),
                "budget {} escaped the adaptive clamp",
                opts.probes
            );
        }
        let strip = |v: &[Completion]| {
            v.iter()
                .map(|(t, o, h, _)| (t.0, *o, h.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(
            strip(&inline),
            strip(&threaded),
            "adaptive budgets broke transport identity"
        );
        // replay the echoed budgets as explicit plans with adaptive OFF:
        // the stamped wire value is the whole policy
        let mut fixed_cfg = cfg.clone();
        fixed_cfg.qos.adaptive_probes = false;
        let budgets: Vec<u32> = inline.iter().map(|(_, o, _, _)| o.probes).collect();
        let replay = run(
            &fixed_cfg,
            &|qi| QueryOptions { probes: budgets[qi], ..QueryOptions::default() },
            &InlineExecutor,
        );
        assert_eq!(
            strip(&inline),
            strip(&replay),
            "echoed adaptive budget failed to replay as a fixed plan"
        );
    }
}
