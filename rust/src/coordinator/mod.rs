//! The coordinator: wires the five stages into the index-build and search
//! pipelines (paper §IV-A) and drives them through the transport-agnostic
//! executor seam (DESIGN.md §Executor seam).
//!
//! The primary API is session-oriented (DESIGN.md §Service API): a
//! [`session::IndexSession`] holds a [`Cluster`]'s stage states live on one
//! [`Executor`] — inline, threaded, or the multi-process socket executor
//! (`crate::net::SocketExecutor`) — and runs build, incremental
//! [`insert`](session::IndexSession::insert) and streaming
//! [`submit`](session::IndexSession::submit)/[`recv`](session::IndexSession::recv)
//! phases back-to-back without re-handshaking anything — and, since the
//! streaming-admission rework, `submit`/`recv` ride a long-lived
//! [`Executor::open_stream`] run: a query enters the pipeline the moment
//! it is submitted. The historical phase calls remain the *pumped* batch
//! path: [`build_index_on`] opens a build-only session over an empty
//! cluster, inserts, and closes; [`search_on`] admits the whole query set
//! as one `Executor::run` workload (the differential oracle the streaming
//! path is held identical to). [`build_index`]/[`search`] pin the
//! deterministic [`InlineExecutor`] (FIFO delivery, results bit-identical
//! to the sequential baseline — the differential-testing contract in
//! `rust/tests/integration_pipeline.rs`).
//!
//! Under the socket transport the placement handed to each phase is the
//! launch-time placement: BI/DP state lives in the worker processes, so
//! this `Cluster`'s `bis`/`dps` stay empty — snapshot workers with
//! `NetSession::fetch_state` instead (`rust/tests/integration_net.rs` is
//! that differential contract). Work accounting is still complete: workers
//! ship their per-copy [`WorkStats`] back in every `FlushAck` barrier.
//! Network traffic is attributed by the executor via [`TrafficMeter`] using
//! the stage placement — same-node deliveries are free, which is exactly how
//! intra-stage parallelism cuts message counts.

pub mod persist;
pub mod session;

use crate::config::Config;
use crate::core::lsh::HashFamily;
use crate::data::Dataset;
use crate::dataflow::exec::{
    bind_stages, Executor, InlineExecutor, IrHandler, QrHandler, Workload,
};
use crate::dataflow::message::{Msg, QueryOptions, StageKind};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::dataflow::Placement;
use crate::partition::ObjMapper;
use crate::runtime::{Hasher, Ranker};
use crate::stages::{AgState, BiState, DpState, InputReader, QueryReceiver};
use crate::util::timer::Timer;
use session::IndexSession;
use std::sync::Arc;

/// A distributed index: stage states + accounting. Create empty with
/// [`Cluster::empty`] (then grow it through a session) or built with
/// [`build_index`]/[`build_index_on`].
pub struct Cluster {
    pub cfg: Config,
    pub family: Arc<HashFamily>,
    pub mapper: ObjMapper,
    pub placement: Placement,
    pub bis: Vec<BiState>,
    pub dps: Vec<DpState>,
    pub ags: Vec<AgState>,
    /// Traffic of the index-build phase (including later inserts).
    pub build_meter: TrafficMeter,
    /// Head-node (IR) work during build.
    pub build_head_work: WorkStats,
    pub build_wall_secs: f64,
    /// Objects indexed so far — the id watermark for incremental inserts.
    /// Maintained by the coordinator (not derived from `dps`) so it is
    /// correct even when the stores live in worker processes.
    pub indexed_objects: u32,
}

/// Output of a search phase.
pub struct SearchOutput {
    /// Per query (in input order): global top-k `(sqdist, id)` ascending.
    pub results: Vec<Vec<(f32, u32)>>,
    /// Traffic of the search phase.
    pub meter: TrafficMeter,
    /// Per-copy work: (stage, copy, work) — cost-model input. Complete on
    /// every transport (socket workers report theirs via `FlushAck`).
    pub work: Vec<(StageKind, u16, WorkStats)>,
    /// Wall-clock admission-to-completion per query.
    pub per_query_secs: Vec<f64>,
    pub wall_secs: f64,
}

impl SearchOutput {
    /// Retrieved neighbor ids per query (for recall scoring).
    pub fn retrieved_ids(&self) -> Vec<Vec<u32>> {
        self.results
            .iter()
            .map(|r| r.iter().map(|&(_, id)| id).collect())
            .collect()
    }
}

/// IR ingest block size: streamed so build memory stays bounded and the
/// threaded executor can overlap hashing with BI/DP insertion.
const BUILD_BLOCK: usize = 8192;

/// Ingress workload for an index phase: one [`Msg::IndexBlock`] per
/// `BUILD_BLOCK` rows of `flat`.
///
/// Each block is copied into its own `Arc` (~`BUILD_BLOCK`·dim·4 bytes
/// transient). That is one extra memcpy pass over the dataset per build —
/// deliberate: it keeps `Msg` `'static` (required to cross executor
/// threads) without restructuring `Dataset`'s owned storage, and it is
/// noise next to the hashing matmul that reads the same bytes.
fn index_block_items(
    flat: &[f32],
    rows: usize,
    dim: usize,
    id_base: u32,
) -> impl Iterator<Item = Msg> + '_ {
    let block = BUILD_BLOCK.min(rows.max(1));
    let mut off = 0usize;
    std::iter::from_fn(move || {
        if off >= rows {
            return None;
        }
        let take = (rows - off).min(block);
        let chunk: Arc<[f32]> = flat[off * dim..(off + take) * dim].into();
        let msg = Msg::IndexBlock {
            id_base: id_base + off as u32,
            rows: take as u32,
            flat: chunk,
        };
        off += take;
        Some(msg)
    })
}

/// Build the distributed index over `dataset` with the deterministic inline
/// executor (paper's index-build phase).
pub fn build_index(cfg: &Config, dataset: &Dataset, hasher: &dyn Hasher) -> Cluster {
    build_index_on(&InlineExecutor, cfg, dataset, hasher)
}

/// Build the distributed index on any [`Executor`] — a thin wrapper over a
/// build-only [`IndexSession`]: open over an empty cluster, insert the
/// dataset, close. IR streams the dataset in blocks; BI/DP consume. Stage
/// state is executor-independent: BI/DP copies receive their messages from
/// the single IR source in emission order on every transport.
pub fn build_index_on(
    exec: &dyn Executor,
    cfg: &Config,
    dataset: &Dataset,
    hasher: &dyn Hasher,
) -> Cluster {
    let timer = Timer::start();
    let mut cluster = Cluster::empty(cfg, dataset.dim);
    {
        let session = IndexSession::attach(exec, &mut cluster, hasher, None);
        session.insert(dataset);
        session.close();
    }
    cluster.build_wall_secs = timer.secs();
    cluster
}

impl Cluster {
    /// A fresh, empty index for `cfg` over `dim`-dimensional data: stage
    /// states allocated, nothing stored. Grow it through a session's
    /// [`insert`](session::IndexSession::insert).
    pub fn empty(cfg: &Config, dim: usize) -> Cluster {
        let family = Arc::new(HashFamily::sample(dim, cfg.lsh));
        let placement = Placement::new(&cfg.cluster);
        let mapper = ObjMapper::new(
            cfg.stream.obj_map,
            placement.dp_copies,
            dim,
            cfg.lsh.seed,
        );
        let bis = (0..placement.bi_copies)
            .map(|c| BiState::new(c as u16, placement.ag_copies, cfg.stream.max_candidates))
            .collect();
        let dps = (0..placement.dp_copies)
            .map(|c| DpState::new(c as u16, dim, placement.ag_copies, cfg.stream.dedup))
            .collect();
        let ags = (0..placement.ag_copies)
            .map(|c| AgState::new(c as u16))
            .collect();
        Cluster {
            cfg: cfg.clone(),
            family,
            mapper,
            placement,
            bis,
            dps,
            ags,
            build_meter: TrafficMeter::new(cfg.stream.agg_bytes),
            build_head_work: WorkStats::default(),
            build_wall_secs: 0.0,
            indexed_objects: 0,
        }
    }

    /// Total objects stored across DP copies (must equal dataset size —
    /// the no-replication invariant). Counts *local* state only; under the
    /// socket transport the stores live in workers (use `indexed_objects`).
    pub fn stored_objects(&self) -> usize {
        self.dps.iter().map(|d| d.object_count()).sum()
    }

    /// Total bucket references across BI copies (= n · L).
    pub fn bucket_references(&self) -> usize {
        self.bis.iter().map(|b| b.reference_count()).sum()
    }

    /// Per-DP object counts (load-imbalance reporting, paper §V-E).
    pub fn dp_object_counts(&self) -> Vec<usize> {
        self.dps.iter().map(|d| d.object_count()).collect()
    }

    /// Online insert with the inline executor (paper §IV-A: indexing and
    /// searching may overlap, e.g. during an index update).
    pub fn insert_objects(
        &mut self,
        flat: &[f32],
        rows: usize,
        hasher: &dyn Hasher,
    ) -> std::ops::Range<u32> {
        self.insert_objects_on(&InlineExecutor, flat, rows, hasher)
    }

    /// Online insert on any [`Executor`]: index `rows` new vectors,
    /// assigning ids from the `indexed_objects` watermark. On the socket
    /// transport this streams index traffic to the already-running workers
    /// — no re-handshake. Returns the assigned id range.
    pub fn insert_objects_on(
        &mut self,
        exec: &dyn Executor,
        flat: &[f32],
        rows: usize,
        hasher: &dyn Hasher,
    ) -> std::ops::Range<u32> {
        let id_base = self.indexed_objects;
        let placement = self.placement.clone();
        let family = self.family.clone();
        let dim = family.dim;
        let agg_bytes = self.cfg.stream.agg_bytes;
        let mut ir = InputReader::new(&family, &self.mapper, placement.bi_copies);
        let report = {
            let stages = bind_stages(
                Box::new(IrHandler { ir: &mut ir, hasher }),
                &mut self.bis,
                &mut self.dps,
                &mut self.ags,
                None,
            );
            let mut items = index_block_items(flat, rows, dim, id_base);
            exec.run(
                &placement,
                stages,
                Workload {
                    items: &mut items,
                    n_queries: 0,
                    window: 0,
                    agg_bytes,
                },
            )
        };
        // `ir` borrows `self.mapper`; read its counters first so the
        // whole-`self` call below is the only outstanding borrow.
        let head_work = ir.work;
        self.absorb_remote_work(&report.work);
        self.build_meter.merge(&report.meter);
        self.build_head_work.add(&head_work);
        self.indexed_objects += rows as u32;
        id_base..id_base + rows as u32
    }

    /// Fold per-copy work reported by a remote transport (the socket
    /// executor decodes it from `FlushAck` barriers, where workers take —
    /// and reset — their counters) into the local stage states. The local
    /// states are thereby the single accumulation point on every
    /// transport, so [`Cluster::take_work`] and session stats read
    /// identically whether a copy ran in-process or in a worker.
    pub fn absorb_remote_work(&mut self, remote: &[(StageKind, u16, WorkStats)]) {
        for (stage, copy, w) in remote {
            let i = *copy as usize;
            match stage {
                StageKind::Bi => {
                    if let Some(s) = self.bis.get_mut(i) {
                        s.work.add(w);
                    }
                }
                StageKind::Dp => {
                    if let Some(s) = self.dps.get_mut(i) {
                        s.work.add(w);
                    }
                }
                StageKind::Ag => {
                    if let Some(s) = self.ags.get_mut(i) {
                        s.work.add(w);
                    }
                }
                // head stages never run remotely
                StageKind::Ir | StageKind::Qr => {}
            }
        }
    }

    /// Snapshot per-copy work counters and reset them (phase accounting).
    pub fn take_work(&mut self, head_extra: &WorkStats) -> Vec<(StageKind, u16, WorkStats)> {
        let mut out = Vec::new();
        out.push((StageKind::Qr, 0, *head_extra));
        for bi in &mut self.bis {
            // bytes_resident is a gauge over current state, not a phase
            // delta: refresh it at the take point. On the socket transport
            // the local copy is empty and the worker's FlushAck gauge (max-
            // merged by absorb_remote_work) already sits in `bi.work`.
            bi.work.bytes_resident = bi.work.bytes_resident.max(bi.bytes_resident());
            out.push((StageKind::Bi, bi.copy, std::mem::take(&mut bi.work)));
        }
        for dp in &mut self.dps {
            dp.work.bytes_resident = dp.work.bytes_resident.max(dp.bytes_resident());
            out.push((StageKind::Dp, dp.copy, std::mem::take(&mut dp.work)));
        }
        for ag in &mut self.ags {
            out.push((StageKind::Ag, ag.copy, std::mem::take(&mut ag.work)));
        }
        out
    }
}

/// Run the search phase over `queries` with the deterministic inline
/// executor (paper's search pipeline iii→v), returning per-query global
/// top-k plus exact traffic and work accounting.
pub fn search(
    cluster: &mut Cluster,
    queries: &Dataset,
    hasher: &dyn Hasher,
    ranker: &dyn Ranker,
) -> SearchOutput {
    search_on(&InlineExecutor, cluster, queries, hasher, ranker)
}

/// Run the search phase on any [`Executor`] — the *pumped* phase path:
/// the whole query set is hashed in one batched call and admitted as one
/// [`Executor::run`] workload under the `Config::stream.inflight` window
/// (0 = open loop; the inline executor is sequential regardless). This is
/// the one-shot batch API and the differential oracle the streaming
/// session path ([`IndexSession::submit`]/[`IndexSession::recv`] over
/// [`Executor::open_stream`]) is held bit-identical to — see the
/// streaming-vs-pumped tests in [`session`].
pub fn search_on(
    exec: &dyn Executor,
    cluster: &mut Cluster,
    queries: &Dataset,
    hasher: &dyn Hasher,
    ranker: &dyn Ranker,
) -> SearchOutput {
    let wall = Timer::start();
    let placement = cluster.placement.clone();
    let family = cluster.family.clone();
    let agg = cluster.cfg.stream.agg_bytes;
    let window = cluster.cfg.stream.inflight;
    let p = hasher.p();
    let raws = hasher.proj_batch(queries.as_flat(), queries.len());
    // `QrHandler` accounts one hashed vector per delivered `QueryVec`, so
    // the batched proj call above needs no extra work accounting here.
    let mut qr = QueryReceiver::new(&family, placement.bi_copies, placement.ag_copies);
    let report = {
        let stages = bind_stages(
            Box::new(QrHandler { qr: &mut qr }),
            &mut cluster.bis,
            &mut cluster.dps,
            &mut cluster.ags,
            Some(ranker),
        );
        // Every query inherits the config plan (`QueryOptions::default()`
        // resolves to `cfg.lsh` at QR) — the pumped phase path stays the
        // bit-identical pre-redesign oracle.
        let mut items = (0..queries.len()).map(|i| Msg::QueryVec {
            qid: i as u32,
            raw: raws[i * p..(i + 1) * p].into(),
            v: queries.get(i).into(),
            opts: QueryOptions::default(),
        });
        exec.run(
            &placement,
            stages,
            Workload {
                items: &mut items,
                n_queries: queries.len(),
                window,
                agg_bytes: agg,
            },
        )
    };
    let head_work = qr.work;
    cluster.absorb_remote_work(&report.work);
    let work = cluster.take_work(&head_work);
    SearchOutput {
        results: report.results,
        meter: report.meter,
        work,
        per_query_secs: report.per_query_secs,
        wall_secs: wall.secs(),
    }
}

/// Shared differential-test fixture (small world: 2 BI / 4 DP nodes),
/// used by this module's tests and by `session`'s — tune it in one place.
#[cfg(test)]
pub(crate) fn small_test_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.lsh = crate::core::lsh::LshParams {
        l: 4,
        m: 8,
        w: 600.0,
        k: 5,
        t: 8,
        seed: 3,
    };
    cfg.cluster.bi_nodes = 2;
    cfg.cluster.dp_nodes = 4;
    cfg.data.n = 2_000;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{distorted_queries, synthesize, SynthSpec};
    use crate::runtime::{ScalarHasher, ScalarRanker};

    fn small_cfg() -> Config {
        small_test_cfg()
    }

    fn small_world(cfg: &Config) -> (Dataset, Dataset, ScalarHasher) {
        let ds = synthesize(SynthSpec {
            n: cfg.data.n,
            clusters: 50,
            ..Default::default()
        });
        let (qs, _) = distorted_queries(&ds, 20, 4.0, 7);
        let family = HashFamily::sample(ds.dim, cfg.lsh);
        (ds, qs, ScalarHasher { family })
    }

    #[test]
    fn build_stores_every_object_exactly_once() {
        let cfg = small_cfg();
        let (ds, _, hasher) = small_world(&cfg);
        let cluster = build_index(&cfg, &ds, &hasher);
        assert_eq!(cluster.stored_objects(), ds.len());
        assert_eq!(cluster.indexed_objects as usize, ds.len());
        assert_eq!(cluster.bucket_references(), ds.len() * cfg.lsh.l);
    }

    #[test]
    fn search_returns_k_results_per_query() {
        let cfg = small_cfg();
        let (ds, qs, hasher) = small_world(&cfg);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        assert_eq!(out.results.len(), qs.len());
        for r in &out.results {
            assert!(r.len() <= cfg.lsh.k);
            // ascending distances
            for w in r.windows(2) {
                assert!(w[0].0 <= w[1].0);
            }
        }
        // no query left pending
        for ag in &cluster.ags {
            assert_eq!(ag.pending_count(), 0);
        }
        // traffic flowed
        assert!(out.meter.logical_msgs > 0);
        assert!(out.meter.payload_bytes > 0);
    }

    #[test]
    fn distorted_queries_find_their_base() {
        // end-to-end sanity: with generous T, most distorted queries must
        // retrieve their base point among the k nearest.
        let cfg = small_cfg();
        let (ds, _, hasher) = small_world(&cfg);
        let (qs, bases) = distorted_queries(&ds, 30, 2.0, 11);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        let hits = out
            .retrieved_ids()
            .iter()
            .zip(&bases)
            .filter(|(r, b)| r.contains(b))
            .count();
        assert!(hits >= 20, "only {hits}/30 queries found their base point");
    }

    #[test]
    fn online_insert_is_searchable() {
        let cfg = small_cfg();
        let (ds, _, hasher) = small_world(&cfg);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let n0 = cluster.stored_objects();

        // Insert fresh near-duplicates of existing rows; they must become
        // retrievable without a rebuild.
        let (extra, bases) =
            crate::data::synth::distorted_queries(&ds, 25, 1.0, 99);
        let range = cluster.insert_objects(extra.as_flat(), extra.len(), &hasher);
        assert_eq!(range, n0 as u32..(n0 + 25) as u32);
        assert_eq!(cluster.stored_objects(), n0 + 25);
        assert_eq!(cluster.indexed_objects as usize, n0 + 25);
        assert_eq!(cluster.bucket_references(), (n0 + 25) * cfg.lsh.l);

        // Querying with the *same* vectors must now find the inserted ids
        // (distance 0 → always ranked first when retrieved at all).
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &extra, &hasher, &ranker);
        let hits = out
            .results
            .iter()
            .enumerate()
            .filter(|(i, r)| r.iter().any(|&(_, id)| id == n0 as u32 + *i as u32))
            .count();
        assert!(hits >= 24, "only {hits}/25 inserted objects retrievable");
        let _ = bases;
    }

    #[test]
    fn work_accounting_resets() {
        let cfg = small_cfg();
        let (ds, qs, hasher) = small_world(&cfg);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        let total_dists: u64 = out
            .work
            .iter()
            .map(|(_, _, w)| w.dists_computed)
            .sum();
        assert!(total_dists > 0);
        // second snapshot is zeroed
        let again = cluster.take_work(&WorkStats::default());
        assert!(again.iter().all(|(_, _, w)| w.dists_computed == 0));
    }

    #[test]
    fn absorb_remote_work_lands_in_matching_copies() {
        let cfg = small_cfg();
        let mut cluster = Cluster::empty(&cfg, 16);
        let remote = vec![
            (StageKind::Dp, 2u16, WorkStats { dists_computed: 9, ..Default::default() }),
            (StageKind::Bi, 1u16, WorkStats { bucket_lookups: 4, ..Default::default() }),
            // head stages and out-of-range copies are ignored, not panicked on
            (StageKind::Qr, 0u16, WorkStats { hash_vectors: 7, ..Default::default() }),
            (StageKind::Dp, 999u16, WorkStats { dists_computed: 1, ..Default::default() }),
        ];
        cluster.absorb_remote_work(&remote);
        cluster.absorb_remote_work(&remote); // accumulates
        assert_eq!(cluster.dps[2].work.dists_computed, 18);
        assert_eq!(cluster.bis[1].work.bucket_lookups, 8);
        let taken = cluster.take_work(&WorkStats::default());
        let dists: u64 = taken.iter().map(|(_, _, w)| w.dists_computed).sum();
        assert_eq!(dists, 18);
    }

    #[test]
    fn build_on_both_executors_yields_identical_state() {
        use crate::dataflow::exec::ThreadedExecutor;
        let cfg = small_cfg();
        let (ds, _, hasher) = small_world(&cfg);
        let inline_cluster = build_index(&cfg, &ds, &hasher);
        let threaded_cluster = build_index_on(&ThreadedExecutor, &cfg, &ds, &hasher);

        assert_eq!(
            inline_cluster.stored_objects(),
            threaded_cluster.stored_objects()
        );
        assert_eq!(
            inline_cluster.bucket_references(),
            threaded_cluster.bucket_references()
        );
        // Bucket-level identity, including per-bucket insertion order: each
        // BI copy consumes the single IR source in emission order on either
        // transport.
        for (a, b) in inline_cluster.bis.iter().zip(&threaded_cluster.bis) {
            assert_eq!(
                a.buckets_snapshot(),
                b.buckets_snapshot(),
                "BI copy {} diverged",
                a.copy
            );
        }
        for (a, b) in inline_cluster.dps.iter().zip(&threaded_cluster.dps) {
            assert_eq!(
                a.objects_snapshot(),
                b.objects_snapshot(),
                "DP copy {} diverged",
                a.copy
            );
        }
        // Traffic counters agree exactly: build messages flow from the
        // single IR thread on either executor.
        assert_eq!(
            inline_cluster.build_meter.logical_msgs,
            threaded_cluster.build_meter.logical_msgs
        );
        assert_eq!(
            inline_cluster.build_meter.payload_bytes,
            threaded_cluster.build_meter.payload_bytes
        );
    }
}
