//! The coordinator: wires the five stages into the index-build and search
//! pipelines (paper §IV-A) and drives them through the transport-agnostic
//! executor seam (DESIGN.md §Executor seam).
//!
//! Both phases run on *any* [`Executor`]: [`build_index`]/[`search`] use the
//! deterministic [`InlineExecutor`] (FIFO delivery, results bit-identical to
//! the sequential baseline — the differential-testing contract in
//! `rust/tests/integration_pipeline.rs`), while [`build_index_on`]/
//! [`search_on`] accept the threaded executor or the multi-process socket
//! executor (`crate::net::SocketExecutor`). Under the socket transport the
//! placement handed to each phase is the launch-time placement: BI/DP state
//! lives in the worker processes, so this `Cluster`'s `bis`/`dps` stay
//! empty — snapshot workers with `NetSession::fetch_state` instead
//! (`rust/tests/integration_net.rs` is that differential contract).
//! Network traffic is attributed by the executor via [`TrafficMeter`] using
//! the stage placement — same-node deliveries are free, which is exactly how
//! intra-stage parallelism cuts message counts.

pub mod persist;
pub mod threaded;

use crate::config::Config;
use crate::core::lsh::HashFamily;
use crate::data::Dataset;
use crate::dataflow::exec::{
    bind_stages, Executor, InlineExecutor, IrHandler, QrHandler, Workload,
};
use crate::dataflow::message::{Msg, StageKind};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::dataflow::Placement;
use crate::partition::ObjMapper;
use crate::runtime::{Hasher, Ranker};
use crate::stages::{AgState, BiState, DpState, InputReader, QueryReceiver};
use crate::util::timer::Timer;
use std::sync::Arc;

/// A built distributed index: stage states + accounting.
pub struct Cluster {
    pub cfg: Config,
    pub family: Arc<HashFamily>,
    pub mapper: ObjMapper,
    pub placement: Placement,
    pub bis: Vec<BiState>,
    pub dps: Vec<DpState>,
    pub ags: Vec<AgState>,
    /// Traffic of the index-build phase.
    pub build_meter: TrafficMeter,
    /// Head-node (IR) work during build.
    pub build_head_work: WorkStats,
    pub build_wall_secs: f64,
}

/// Output of a search phase.
pub struct SearchOutput {
    /// Per query (in input order): global top-k `(sqdist, id)` ascending.
    pub results: Vec<Vec<(f32, u32)>>,
    /// Traffic of the search phase.
    pub meter: TrafficMeter,
    /// Per-copy work: (stage, copy, work) — cost-model input.
    pub work: Vec<(StageKind, u16, WorkStats)>,
    /// Wall-clock admission-to-completion per query.
    pub per_query_secs: Vec<f64>,
    pub wall_secs: f64,
}

impl SearchOutput {
    /// Retrieved neighbor ids per query (for recall scoring).
    pub fn retrieved_ids(&self) -> Vec<Vec<u32>> {
        self.results
            .iter()
            .map(|r| r.iter().map(|&(_, id)| id).collect())
            .collect()
    }
}

/// IR ingest block size: streamed so build memory stays bounded and the
/// threaded executor can overlap hashing with BI/DP insertion.
const BUILD_BLOCK: usize = 8192;

/// Ingress workload for an index phase: one [`Msg::IndexBlock`] per block.
///
/// Each block is copied into its own `Arc` (~`BUILD_BLOCK`·dim·4 bytes
/// transient). That is one extra memcpy pass over the dataset per build —
/// deliberate: it keeps `Msg` `'static` (required to cross executor
/// threads) without restructuring `Dataset`'s owned storage, and it is
/// noise next to the hashing matmul that reads the same bytes.
fn build_items<'a>(
    dataset: &'a Dataset,
    id_base: u32,
) -> impl Iterator<Item = Msg> + 'a {
    let len = dataset.len();
    let block = BUILD_BLOCK.min(len.max(1));
    let mut off = 0usize;
    std::iter::from_fn(move || {
        if off >= len {
            return None;
        }
        let take = (len - off).min(block);
        let flat: Arc<[f32]> = dataset.slice_flat(off, off + take).into();
        let msg = Msg::IndexBlock {
            id_base: id_base + off as u32,
            rows: take as u32,
            flat,
        };
        off += take;
        Some(msg)
    })
}

/// Build the distributed index over `dataset` with the deterministic inline
/// executor (paper's index-build phase).
pub fn build_index(cfg: &Config, dataset: &Dataset, hasher: &dyn Hasher) -> Cluster {
    build_index_on(&InlineExecutor, cfg, dataset, hasher)
}

/// Build the distributed index on any [`Executor`]. IR streams the dataset
/// in blocks; BI/DP consume (they emit nothing during build, so routing is
/// single-hop). Stage state is executor-independent: BI/DP copies receive
/// their messages from the single IR source in emission order either way.
pub fn build_index_on(
    exec: &dyn Executor,
    cfg: &Config,
    dataset: &Dataset,
    hasher: &dyn Hasher,
) -> Cluster {
    let timer = Timer::start();
    let family = Arc::new(HashFamily::sample(dataset.dim, cfg.lsh));
    let placement = Placement::new(&cfg.cluster);
    let mapper = ObjMapper::new(
        cfg.stream.obj_map,
        placement.dp_copies,
        dataset.dim,
        cfg.lsh.seed,
    );
    let mut bis: Vec<BiState> = (0..placement.bi_copies)
        .map(|c| BiState::new(c as u16, placement.ag_copies, cfg.stream.max_candidates))
        .collect();
    let mut dps: Vec<DpState> = (0..placement.dp_copies)
        .map(|c| {
            DpState::new(
                c as u16,
                dataset.dim,
                cfg.lsh.k,
                placement.ag_copies,
                cfg.stream.dedup,
            )
        })
        .collect();
    let mut ags: Vec<AgState> = (0..placement.ag_copies)
        .map(|c| AgState::new(c as u16, cfg.lsh.k))
        .collect();

    let mut ir = InputReader::new(&family, &mapper, placement.bi_copies);
    let report = {
        let stages = bind_stages(
            Box::new(IrHandler { ir: &mut ir, hasher }),
            &mut bis,
            &mut dps,
            &mut ags,
            None,
        );
        let mut items = build_items(dataset, 0);
        exec.run(
            &placement,
            stages,
            Workload {
                items: &mut items,
                n_queries: 0,
                window: 0,
                agg_bytes: cfg.stream.agg_bytes,
            },
        )
    };

    // `ir` borrows `family`/`mapper`; read its counters before moving them.
    let build_head_work = ir.work;
    Cluster {
        cfg: cfg.clone(),
        family,
        mapper,
        placement,
        bis,
        dps,
        ags,
        build_meter: report.meter,
        build_head_work,
        build_wall_secs: timer.secs(),
    }
}

impl Cluster {
    /// Total objects stored across DP copies (must equal dataset size —
    /// the no-replication invariant).
    pub fn stored_objects(&self) -> usize {
        self.dps.iter().map(|d| d.object_count()).sum()
    }

    /// Total bucket references across BI copies (= n · L).
    pub fn bucket_references(&self) -> usize {
        self.bis.iter().map(|b| b.reference_count()).sum()
    }

    /// Per-DP object counts (load-imbalance reporting, paper §V-E).
    pub fn dp_object_counts(&self) -> Vec<usize> {
        self.dps.iter().map(|d| d.object_count()).collect()
    }

    /// Online insert (paper §IV-A: indexing and searching may overlap, e.g.
    /// during an index update): index `rows` new vectors, assigning them
    /// ids following the current maximum. Returns the assigned id range.
    pub fn insert_objects(
        &mut self,
        flat: &[f32],
        rows: usize,
        hasher: &dyn Hasher,
    ) -> std::ops::Range<u32> {
        let id_base = self.stored_objects() as u32;
        let placement = self.placement.clone();
        let family = self.family.clone();
        let agg_bytes = self.cfg.stream.agg_bytes;
        let mut ir = InputReader::new(&family, &self.mapper, placement.bi_copies);
        let report = {
            let stages = bind_stages(
                Box::new(IrHandler { ir: &mut ir, hasher }),
                &mut self.bis,
                &mut self.dps,
                &mut self.ags,
                None,
            );
            let mut items = std::iter::once(Msg::IndexBlock {
                id_base,
                rows: rows as u32,
                flat: flat.into(),
            });
            InlineExecutor.run(
                &placement,
                stages,
                Workload {
                    items: &mut items,
                    n_queries: 0,
                    window: 0,
                    agg_bytes,
                },
            )
        };
        self.build_meter.merge(&report.meter);
        self.build_head_work.add(&ir.work);
        id_base..id_base + rows as u32
    }

    /// Snapshot per-copy work counters and reset them (phase accounting).
    pub fn take_work(&mut self, head_extra: &WorkStats) -> Vec<(StageKind, u16, WorkStats)> {
        let mut out = Vec::new();
        out.push((StageKind::Qr, 0, *head_extra));
        for bi in &mut self.bis {
            out.push((StageKind::Bi, bi.copy, std::mem::take(&mut bi.work)));
        }
        for dp in &mut self.dps {
            out.push((StageKind::Dp, dp.copy, std::mem::take(&mut dp.work)));
        }
        for ag in &mut self.ags {
            out.push((StageKind::Ag, ag.copy, std::mem::take(&mut ag.work)));
        }
        out
    }
}

/// Run the search phase over `queries` with the deterministic inline
/// executor (paper's search pipeline iii→v), returning per-query global
/// top-k plus exact traffic and work accounting.
pub fn search(
    cluster: &mut Cluster,
    queries: &Dataset,
    hasher: &dyn Hasher,
    ranker: &dyn Ranker,
) -> SearchOutput {
    search_on(&InlineExecutor, cluster, queries, hasher, ranker)
}

/// Run the search phase on any [`Executor`]. The admission window comes
/// from `Config::stream.inflight` (0 = open loop); the inline executor is
/// sequential regardless, so the knob only shapes threaded serving.
pub fn search_on(
    exec: &dyn Executor,
    cluster: &mut Cluster,
    queries: &Dataset,
    hasher: &dyn Hasher,
    ranker: &dyn Ranker,
) -> SearchOutput {
    let wall = Timer::start();
    let placement = cluster.placement.clone();
    let agg_bytes = cluster.cfg.stream.agg_bytes;
    let window = cluster.cfg.stream.inflight;
    let family = cluster.family.clone();
    let mut qr = QueryReceiver::new(&family, placement.bi_copies, placement.ag_copies);

    // §Perf: hash the whole query batch through one artifact call instead
    // of one padded call per query (the QR handler accounts per query).
    let p = hasher.p();
    let raws = hasher.proj_batch(queries.as_flat(), queries.len());

    let report = {
        let stages = bind_stages(
            Box::new(QrHandler { qr: &mut qr }),
            &mut cluster.bis,
            &mut cluster.dps,
            &mut cluster.ags,
            Some(ranker),
        );
        let mut items = (0..queries.len() as u32).map(|qid| {
            let raw: Arc<[f32]> = raws[qid as usize * p..(qid as usize + 1) * p].into();
            let v: Arc<[f32]> = queries.get(qid as usize).into();
            Msg::QueryVec { qid, raw, v }
        });
        exec.run(
            &placement,
            stages,
            Workload {
                items: &mut items,
                n_queries: queries.len(),
                window,
                agg_bytes,
            },
        )
    };

    let work = cluster.take_work(&std::mem::take(&mut qr.work));
    SearchOutput {
        results: report.results,
        meter: report.meter,
        work,
        per_query_secs: report.per_query_secs,
        wall_secs: wall.secs(),
    }
}

/// Shared differential-test fixture (small world: 2 BI / 4 DP nodes),
/// used by this module's tests and by `threaded`'s — tune it in one place.
#[cfg(test)]
pub(crate) fn small_test_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.lsh = crate::core::lsh::LshParams {
        l: 4,
        m: 8,
        w: 600.0,
        k: 5,
        t: 8,
        seed: 3,
    };
    cfg.cluster.bi_nodes = 2;
    cfg.cluster.dp_nodes = 4;
    cfg.data.n = 2_000;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{distorted_queries, synthesize, SynthSpec};
    use crate::runtime::{ScalarHasher, ScalarRanker};

    fn small_cfg() -> Config {
        small_test_cfg()
    }

    fn small_world(cfg: &Config) -> (Dataset, Dataset, ScalarHasher) {
        let ds = synthesize(SynthSpec {
            n: cfg.data.n,
            clusters: 50,
            ..Default::default()
        });
        let (qs, _) = distorted_queries(&ds, 20, 4.0, 7);
        let family = HashFamily::sample(ds.dim, cfg.lsh);
        (ds, qs, ScalarHasher { family })
    }

    #[test]
    fn build_stores_every_object_exactly_once() {
        let cfg = small_cfg();
        let (ds, _, hasher) = small_world(&cfg);
        let cluster = build_index(&cfg, &ds, &hasher);
        assert_eq!(cluster.stored_objects(), ds.len());
        assert_eq!(cluster.bucket_references(), ds.len() * cfg.lsh.l);
    }

    #[test]
    fn search_returns_k_results_per_query() {
        let cfg = small_cfg();
        let (ds, qs, hasher) = small_world(&cfg);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        assert_eq!(out.results.len(), qs.len());
        for r in &out.results {
            assert!(r.len() <= cfg.lsh.k);
            // ascending distances
            for w in r.windows(2) {
                assert!(w[0].0 <= w[1].0);
            }
        }
        // no query left pending
        for ag in &cluster.ags {
            assert_eq!(ag.pending_count(), 0);
        }
        // traffic flowed
        assert!(out.meter.logical_msgs > 0);
        assert!(out.meter.payload_bytes > 0);
    }

    #[test]
    fn distorted_queries_find_their_base() {
        // end-to-end sanity: with generous T, most distorted queries must
        // retrieve their base point among the k nearest.
        let cfg = small_cfg();
        let (ds, _, hasher) = small_world(&cfg);
        let (qs, bases) = distorted_queries(&ds, 30, 2.0, 11);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        let hits = out
            .retrieved_ids()
            .iter()
            .zip(&bases)
            .filter(|(r, b)| r.contains(b))
            .count();
        assert!(hits >= 20, "only {hits}/30 queries found their base point");
    }

    #[test]
    fn online_insert_is_searchable() {
        let cfg = small_cfg();
        let (ds, _, hasher) = small_world(&cfg);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let n0 = cluster.stored_objects();

        // Insert fresh near-duplicates of existing rows; they must become
        // retrievable without a rebuild.
        let (extra, bases) =
            crate::data::synth::distorted_queries(&ds, 25, 1.0, 99);
        let range = cluster.insert_objects(extra.as_flat(), extra.len(), &hasher);
        assert_eq!(range, n0 as u32..(n0 + 25) as u32);
        assert_eq!(cluster.stored_objects(), n0 + 25);
        assert_eq!(cluster.bucket_references(), (n0 + 25) * cfg.lsh.l);

        // Querying with the *same* vectors must now find the inserted ids
        // (distance 0 → always ranked first when retrieved at all).
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &extra, &hasher, &ranker);
        let hits = out
            .results
            .iter()
            .enumerate()
            .filter(|(i, r)| r.iter().any(|&(_, id)| id == n0 as u32 + *i as u32))
            .count();
        assert!(hits >= 24, "only {hits}/25 inserted objects retrievable");
        let _ = bases;
    }

    #[test]
    fn work_accounting_resets() {
        let cfg = small_cfg();
        let (ds, qs, hasher) = small_world(&cfg);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        let total_dists: u64 = out
            .work
            .iter()
            .map(|(_, _, w)| w.dists_computed)
            .sum();
        assert!(total_dists > 0);
        // second snapshot is zeroed
        let again = cluster.take_work(&WorkStats::default());
        assert!(again.iter().all(|(_, _, w)| w.dists_computed == 0));
    }

    #[test]
    fn build_on_both_executors_yields_identical_state() {
        use crate::dataflow::exec::ThreadedExecutor;
        let cfg = small_cfg();
        let (ds, _, hasher) = small_world(&cfg);
        let inline_cluster = build_index(&cfg, &ds, &hasher);
        let threaded_cluster = build_index_on(&ThreadedExecutor, &cfg, &ds, &hasher);

        assert_eq!(
            inline_cluster.stored_objects(),
            threaded_cluster.stored_objects()
        );
        assert_eq!(
            inline_cluster.bucket_references(),
            threaded_cluster.bucket_references()
        );
        // Bucket-level identity, including per-bucket insertion order: each
        // BI copy consumes the single IR source in emission order on either
        // transport.
        for (a, b) in inline_cluster.bis.iter().zip(&threaded_cluster.bis) {
            let sa: Vec<(u64, Vec<(u32, u16)>)> = a
                .buckets_snapshot()
                .into_iter()
                .map(|(k, v)| (k, v.clone()))
                .collect();
            let sb: Vec<(u64, Vec<(u32, u16)>)> = b
                .buckets_snapshot()
                .into_iter()
                .map(|(k, v)| (k, v.clone()))
                .collect();
            assert_eq!(sa, sb, "BI copy {} diverged", a.copy);
        }
        for (a, b) in inline_cluster.dps.iter().zip(&threaded_cluster.dps) {
            assert_eq!(
                a.objects_snapshot(),
                b.objects_snapshot(),
                "DP copy {} diverged",
                a.copy
            );
        }
        // Traffic counters agree exactly: build messages flow from the
        // single IR thread on either executor.
        assert_eq!(
            inline_cluster.build_meter.logical_msgs,
            threaded_cluster.build_meter.logical_msgs
        );
        assert_eq!(
            inline_cluster.build_meter.payload_bytes,
            threaded_cluster.build_meter.payload_bytes
        );
    }
}
