//! The coordinator: wires the five stages into the index-build and search
//! pipelines (paper §IV-A) and drives them with the deterministic inline
//! executor.
//!
//! The executor processes messages in FIFO order, attributing network
//! traffic via [`TrafficMeter`] using the stage placement (same-node
//! deliveries are free, which is exactly how intra-stage parallelism cuts
//! message counts). Results are bit-identical to the sequential baseline —
//! that's the differential-testing contract (`rust/tests/
//! integration_pipeline.rs`).

pub mod persist;
pub mod threaded;

use crate::config::Config;
use crate::core::lsh::HashFamily;
use crate::data::Dataset;
use crate::dataflow::message::{Dest, Msg, StageKind};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::dataflow::Placement;
use crate::partition::ObjMapper;
use crate::runtime::{Hasher, Ranker};
use crate::stages::{AgState, BiState, DpState, InputReader, QueryReceiver};
use crate::util::timer::Timer;
use std::collections::VecDeque;
use std::sync::Arc;

/// A built distributed index: stage states + accounting.
pub struct Cluster {
    pub cfg: Config,
    pub family: Arc<HashFamily>,
    pub mapper: ObjMapper,
    pub placement: Placement,
    pub bis: Vec<BiState>,
    pub dps: Vec<DpState>,
    pub ags: Vec<AgState>,
    /// Traffic of the index-build phase.
    pub build_meter: TrafficMeter,
    /// Head-node (IR) work during build.
    pub build_head_work: WorkStats,
    pub build_wall_secs: f64,
}

/// Output of a search phase.
pub struct SearchOutput {
    /// Per query (in input order): global top-k `(sqdist, id)` ascending.
    pub results: Vec<Vec<(f32, u32)>>,
    /// Traffic of the search phase.
    pub meter: TrafficMeter,
    /// Per-copy work: (stage, copy, work) — cost-model input.
    pub work: Vec<(StageKind, u16, WorkStats)>,
    /// Wall-clock per query (inline executor; single host core).
    pub per_query_secs: Vec<f64>,
    pub wall_secs: f64,
}

impl SearchOutput {
    /// Retrieved neighbor ids per query (for recall scoring).
    pub fn retrieved_ids(&self) -> Vec<Vec<u32>> {
        self.results
            .iter()
            .map(|r| r.iter().map(|&(_, id)| id).collect())
            .collect()
    }
}

/// Build the distributed index over `dataset` (paper's index-build phase).
pub fn build_index(cfg: &Config, dataset: &Dataset, hasher: &dyn Hasher) -> Cluster {
    let timer = Timer::start();
    let family = Arc::new(HashFamily::sample(dataset.dim, cfg.lsh));
    let placement = Placement::new(&cfg.cluster);
    let mapper = ObjMapper::new(
        cfg.stream.obj_map,
        placement.dp_copies,
        dataset.dim,
        cfg.lsh.seed,
    );
    let mut bis: Vec<BiState> = (0..placement.bi_copies)
        .map(|c| BiState::new(c as u16, placement.ag_copies, cfg.stream.max_candidates))
        .collect();
    let mut dps: Vec<DpState> = (0..placement.dp_copies)
        .map(|c| {
            DpState::new(
                c as u16,
                dataset.dim,
                cfg.lsh.k,
                placement.ag_copies,
                cfg.stream.dedup,
            )
        })
        .collect();
    let ags: Vec<AgState> = (0..placement.ag_copies)
        .map(|c| AgState::new(c as u16, cfg.lsh.k))
        .collect();

    let mut meter = TrafficMeter::new(cfg.stream.agg_bytes);
    let head = placement.head_node;

    // IR streams the dataset in blocks; BI/DP consume (they emit nothing
    // during build, so routing is single-hop).
    let build_head_work = {
        let mut ir = InputReader::new(&family, &mapper, placement.bi_copies);
        let block = 8192.min(dataset.len().max(1));
        let mut out: Vec<(Dest, Msg)> = Vec::new();
        let mut done = 0usize;
        while done < dataset.len() {
            let take = (dataset.len() - done).min(block);
            ir.index_block(
                hasher,
                dataset.slice_flat(done, done + take),
                take,
                done as u32,
                &mut out,
            );
            for (dest, msg) in out.drain(..) {
                let dst_node = placement.node_of(dest.stage, dest.copy);
                meter.send(head, dst_node, msg.wire_size());
                match (dest.stage, msg) {
                    (StageKind::Bi, Msg::IndexRef { key, id, dp, .. }) => {
                        bis[dest.copy as usize].on_index_ref(key, id, dp);
                    }
                    (StageKind::Dp, Msg::StoreObject { id, v }) => {
                        dps[dest.copy as usize].on_store(id, &v);
                    }
                    (stage, msg) => {
                        panic!("unexpected build message {msg:?} to {stage:?}")
                    }
                }
            }
            done += take;
        }
        ir.work
    };
    meter.flush();

    Cluster {
        cfg: cfg.clone(),
        family,
        mapper,
        placement,
        bis,
        dps,
        ags,
        build_meter: meter,
        build_head_work,
        build_wall_secs: timer.secs(),
    }
}

impl Cluster {
    /// Total objects stored across DP copies (must equal dataset size —
    /// the no-replication invariant).
    pub fn stored_objects(&self) -> usize {
        self.dps.iter().map(|d| d.object_count()).sum()
    }

    /// Total bucket references across BI copies (= n · L).
    pub fn bucket_references(&self) -> usize {
        self.bis.iter().map(|b| b.reference_count()).sum()
    }

    /// Per-DP object counts (load-imbalance reporting, paper §V-E).
    pub fn dp_object_counts(&self) -> Vec<usize> {
        self.dps.iter().map(|d| d.object_count()).collect()
    }

    /// Online insert (paper §IV-A: indexing and searching may overlap, e.g.
    /// during an index update): index `rows` new vectors, assigning them
    /// ids following the current maximum. Returns the assigned id range.
    pub fn insert_objects(
        &mut self,
        flat: &[f32],
        rows: usize,
        hasher: &dyn Hasher,
    ) -> std::ops::Range<u32> {
        let id_base = self.stored_objects() as u32;
        let placement = self.placement.clone();
        let head = placement.head_node;
        let mut ir = InputReader::new(&self.family, &self.mapper, placement.bi_copies);
        let mut out: Vec<(Dest, Msg)> = Vec::new();
        ir.index_block(hasher, flat, rows, id_base, &mut out);
        for (dest, msg) in out.drain(..) {
            let dst_node = placement.node_of(dest.stage, dest.copy);
            self.build_meter.send(head, dst_node, msg.wire_size());
            match (dest.stage, msg) {
                (StageKind::Bi, Msg::IndexRef { key, id, dp, .. }) => {
                    self.bis[dest.copy as usize].on_index_ref(key, id, dp);
                }
                (StageKind::Dp, Msg::StoreObject { id, v }) => {
                    self.dps[dest.copy as usize].on_store(id, &v);
                }
                (stage, msg) => panic!("unexpected insert message {msg:?} to {stage:?}"),
            }
        }
        self.build_meter.flush();
        self.build_head_work.add(&ir.work);
        id_base..id_base + rows as u32
    }

    /// Snapshot per-copy work counters and reset them (phase accounting).
    pub fn take_work(&mut self, head_extra: &WorkStats) -> Vec<(StageKind, u16, WorkStats)> {
        let mut out = Vec::new();
        out.push((StageKind::Qr, 0, *head_extra));
        for bi in &mut self.bis {
            out.push((StageKind::Bi, bi.copy, std::mem::take(&mut bi.work)));
        }
        for dp in &mut self.dps {
            out.push((StageKind::Dp, dp.copy, std::mem::take(&mut dp.work)));
        }
        for ag in &mut self.ags {
            out.push((StageKind::Ag, ag.copy, std::mem::take(&mut ag.work)));
        }
        out
    }
}

/// Run the search phase over `queries` (paper's search pipeline iii→v),
/// returning per-query global top-k plus exact traffic and work accounting.
pub fn search(
    cluster: &mut Cluster,
    queries: &Dataset,
    hasher: &dyn Hasher,
    ranker: &dyn Ranker,
) -> SearchOutput {
    let wall = Timer::start();
    let placement = cluster.placement.clone();
    let mut meter = TrafficMeter::new(cluster.cfg.stream.agg_bytes);
    let family = cluster.family.clone();
    let mut qr = QueryReceiver::new(&family, placement.bi_copies, placement.ag_copies);
    let head = placement.head_node;
    let mut queue: VecDeque<(u16, Dest, Msg)> = VecDeque::new();
    let mut emitted: Vec<(Dest, Msg)> = Vec::new();
    let mut per_query_secs = Vec::with_capacity(queries.len());

    // §Perf: hash the whole query batch through one artifact call instead
    // of one padded call per query.
    let p = hasher.p();
    let raws = hasher.proj_batch(queries.as_flat(), queries.len());
    qr.work.hash_vectors += queries.len() as u64;

    for qid in 0..queries.len() as u32 {
        let qt = Timer::start();
        let raw = &raws[qid as usize * p..(qid as usize + 1) * p];
        qr.dispatch_query_raw(raw, qid, queries.get(qid as usize), &mut emitted);
        for (dest, msg) in emitted.drain(..) {
            let dst = placement.node_of(dest.stage, dest.copy);
            meter.send(head, dst, msg.wire_size());
            queue.push_back((dst, dest, msg));
        }
        // Drain to completion (inline executor: FIFO, deterministic).
        while let Some((_src_node, dest, msg)) = queue.pop_front() {
            // The handler about to run lives on this node; messages it
            // emits are charged from here.
            let handler_node = placement.node_of(dest.stage, dest.copy);
            match (dest.stage, msg) {
                (StageKind::Bi, Msg::Query { qid, probes, v }) => {
                    let bi = &mut cluster.bis[dest.copy as usize];
                    bi.on_query(qid, &probes, &v, &mut emitted);
                }
                (StageKind::Dp, Msg::CandidateReq { qid, ids, v }) => {
                    let dp = &mut cluster.dps[dest.copy as usize];
                    dp.on_candidates(qid, &ids, &v, ranker, &mut emitted);
                }
                (StageKind::Ag, Msg::QueryMeta { qid, n_bi }) => {
                    cluster.ags[dest.copy as usize].on_query_meta(qid, n_bi);
                }
                (StageKind::Ag, Msg::BiMeta { qid, n_dp }) => {
                    cluster.ags[dest.copy as usize].on_bi_meta(qid, n_dp);
                }
                (StageKind::Ag, Msg::LocalTopK { qid, hits }) => {
                    cluster.ags[dest.copy as usize].on_local_topk(qid, &hits);
                }
                (stage, msg) => panic!("unexpected search message {msg:?} to {stage:?}"),
            }
            for (d2, m2) in emitted.drain(..) {
                let dst_node = placement.node_of(d2.stage, d2.copy);
                meter.send(handler_node, dst_node, m2.wire_size());
                queue.push_back((dst_node, d2, m2));
            }
        }
        dps_finish(cluster, qid);
        per_query_secs.push(qt.secs());
    }
    meter.flush();

    // Collect results in qid order.
    let mut results: Vec<Vec<(f32, u32)>> = vec![Vec::new(); queries.len()];
    for ag in &mut cluster.ags {
        for (qid, hits) in ag.results.drain(..) {
            results[qid as usize] = hits;
        }
    }
    let work = cluster.take_work(&std::mem::take(&mut qr.work));
    SearchOutput {
        results,
        meter,
        work,
        per_query_secs,
        wall_secs: wall.secs(),
    }
}

fn dps_finish(cluster: &mut Cluster, qid: u32) {
    for dp in &mut cluster.dps {
        dp.finish_query(qid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{distorted_queries, synthesize, SynthSpec};
    use crate::runtime::{ScalarHasher, ScalarRanker};

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.lsh = crate::core::lsh::LshParams {
            l: 4,
            m: 8,
            w: 600.0,
            k: 5,
            t: 8,
            seed: 3,
        };
        cfg.cluster.bi_nodes = 2;
        cfg.cluster.dp_nodes = 4;
        cfg.data.n = 2_000;
        cfg
    }

    fn small_world(cfg: &Config) -> (Dataset, Dataset, ScalarHasher) {
        let ds = synthesize(SynthSpec {
            n: cfg.data.n,
            clusters: 50,
            ..Default::default()
        });
        let (qs, _) = distorted_queries(&ds, 20, 4.0, 7);
        let family = HashFamily::sample(ds.dim, cfg.lsh);
        (ds, qs, ScalarHasher { family })
    }

    #[test]
    fn build_stores_every_object_exactly_once() {
        let cfg = small_cfg();
        let (ds, _, hasher) = small_world(&cfg);
        let cluster = build_index(&cfg, &ds, &hasher);
        assert_eq!(cluster.stored_objects(), ds.len());
        assert_eq!(cluster.bucket_references(), ds.len() * cfg.lsh.l);
    }

    #[test]
    fn search_returns_k_results_per_query() {
        let cfg = small_cfg();
        let (ds, qs, hasher) = small_world(&cfg);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        assert_eq!(out.results.len(), qs.len());
        for r in &out.results {
            assert!(r.len() <= cfg.lsh.k);
            // ascending distances
            for w in r.windows(2) {
                assert!(w[0].0 <= w[1].0);
            }
        }
        // no query left pending
        for ag in &cluster.ags {
            assert_eq!(ag.pending_count(), 0);
        }
        // traffic flowed
        assert!(out.meter.logical_msgs > 0);
        assert!(out.meter.payload_bytes > 0);
    }

    #[test]
    fn distorted_queries_find_their_base() {
        // end-to-end sanity: with generous T, most distorted queries must
        // retrieve their base point among the k nearest.
        let cfg = small_cfg();
        let (ds, _, hasher) = small_world(&cfg);
        let (qs, bases) = distorted_queries(&ds, 30, 2.0, 11);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        let hits = out
            .retrieved_ids()
            .iter()
            .zip(&bases)
            .filter(|(r, b)| r.contains(b))
            .count();
        assert!(hits >= 20, "only {hits}/30 queries found their base point");
    }

    #[test]
    fn online_insert_is_searchable() {
        let cfg = small_cfg();
        let (ds, _, hasher) = small_world(&cfg);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let n0 = cluster.stored_objects();

        // Insert fresh near-duplicates of existing rows; they must become
        // retrievable without a rebuild.
        let (extra, bases) =
            crate::data::synth::distorted_queries(&ds, 25, 1.0, 99);
        let range = cluster.insert_objects(extra.as_flat(), extra.len(), &hasher);
        assert_eq!(range, n0 as u32..(n0 + 25) as u32);
        assert_eq!(cluster.stored_objects(), n0 + 25);
        assert_eq!(cluster.bucket_references(), (n0 + 25) * cfg.lsh.l);

        // Querying with the *same* vectors must now find the inserted ids
        // (distance 0 → always ranked first when retrieved at all).
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &extra, &hasher, &ranker);
        let hits = out
            .results
            .iter()
            .enumerate()
            .filter(|(i, r)| r.iter().any(|&(_, id)| id == n0 as u32 + *i as u32))
            .count();
        assert!(hits >= 24, "only {hits}/25 inserted objects retrievable");
        let _ = bases;
    }

    #[test]
    fn work_accounting_resets() {
        let cfg = small_cfg();
        let (ds, qs, hasher) = small_world(&cfg);
        let mut cluster = build_index(&cfg, &ds, &hasher);
        let ranker = ScalarRanker { dim: ds.dim };
        let out = search(&mut cluster, &qs, &hasher, &ranker);
        let total_dists: u64 = out
            .work
            .iter()
            .map(|(_, _, w)| w.dists_computed)
            .sum();
        assert!(total_dists > 0);
        // second snapshot is zeroed
        let again = cluster.take_work(&WorkStats::default());
        assert!(again.iter().all(|(_, _, w)| w.dists_computed == 0));
    }
}
