//! Threaded executor: the paper's widely-asynchronous design, for real.
//!
//! Every BI/DP/AG copy runs as its own thread consuming an mpsc channel —
//! task parallelism (QR dispatch overlaps BI lookups), pipeline parallelism
//! (query `n+1` hashes while query `n` is still ranking) and replicated
//! parallelism (copies of a stage run concurrently). Stage *logic* is the
//! same handler code the inline executor drives; only the transport differs.
//!
//! Sender ownership encodes shutdown: main holds the BI and AG senders, BI
//! threads hold DP+AG senders, DP threads hold AG senders. When main drops
//! its senders after dispatching the workload, closure cascades
//! QR→BI→DP→AG and the result channel closes once the last AG exits.
//!
//! Per-thread traffic meters are merged at join, so counters equal the
//! inline executor's (aggregation flush boundaries aside — packets are
//! flushed per thread).

use crate::coordinator::{Cluster, SearchOutput};
use crate::data::Dataset;
use crate::dataflow::message::{Msg, StageKind};
use crate::dataflow::metrics::TrafficMeter;
use crate::runtime::{Hasher, Ranker};
use crate::stages::QueryReceiver;
use crate::util::timer::Timer;
use std::sync::mpsc;
use std::time::Instant;

enum AgIn {
    Meta { qid: u32, n_bi: u32 },
    BiMeta { qid: u32, n_dp: u32 },
    TopK { qid: u32, hits: Vec<(f32, u32)> },
}

/// Search with one thread per stage copy (open-loop dispatch: all queries
/// are submitted up front; per-query latency includes queueing, as in a
/// saturated serving scenario). Accounting matches the inline `search`.
pub fn search_threaded(
    cluster: &mut Cluster,
    queries: &Dataset,
    hasher: &dyn Hasher,
    ranker: &dyn Ranker,
) -> SearchOutput {
    let wall = Timer::start();
    let placement = cluster.placement.clone();
    let agg = cluster.cfg.stream.agg_bytes;
    let n_queries = queries.len();

    // Channels.
    let (mut bi_tx, bi_rx): (Vec<_>, Vec<_>) =
        (0..placement.bi_copies).map(|_| mpsc::channel::<Msg>()).unzip();
    let (dp_tx, dp_rx): (Vec<_>, Vec<_>) =
        (0..placement.dp_copies).map(|_| mpsc::channel::<Msg>()).unzip();
    let (mut ag_tx, ag_rx): (Vec<_>, Vec<_>) =
        (0..placement.ag_copies).map(|_| mpsc::channel::<AgIn>()).unzip();
    let (res_tx, res_rx) = mpsc::channel::<(u32, Vec<(f32, u32)>, Instant)>();

    // Move stage states into threads; they come back at join.
    let bis = std::mem::take(&mut cluster.bis);
    let dps = std::mem::take(&mut cluster.dps);
    let ags = std::mem::take(&mut cluster.ags);
    let family = cluster.family.clone();

    let mut meters: Vec<TrafficMeter> = Vec::new();
    let mut results: Vec<Vec<(f32, u32)>> = vec![Vec::new(); n_queries];
    let mut per_query_secs = vec![0f64; n_queries];
    let mut qr_work = crate::dataflow::metrics::WorkStats::default();

    std::thread::scope(|s| {
        // --- AG threads (hold res_tx clones) ---
        let ag_handles: Vec<_> = ags
            .into_iter()
            .zip(ag_rx)
            .map(|(mut ag, rx)| {
                let res_tx = res_tx.clone();
                s.spawn(move || {
                    while let Ok(m) = rx.recv() {
                        match m {
                            AgIn::Meta { qid, n_bi } => ag.on_query_meta(qid, n_bi),
                            AgIn::BiMeta { qid, n_dp } => ag.on_bi_meta(qid, n_dp),
                            AgIn::TopK { qid, hits } => ag.on_local_topk(qid, &hits),
                        }
                        // Stream completions out as they happen.
                        for (qid, hits) in ag.results.drain(..) {
                            res_tx.send((qid, hits, Instant::now())).expect("channel closed");
                        }
                    }
                    ag
                })
            })
            .collect();
        drop(res_tx);

        // --- DP threads (hold ag_tx clones) ---
        let dp_handles: Vec<_> = dps
            .into_iter()
            .zip(dp_rx)
            .map(|(mut dp, rx)| {
                let ag_tx = ag_tx.clone();
                let placement = placement.clone();
                s.spawn(move || {
                    let mut meter = TrafficMeter::new(agg);
                    let my_node = placement.node_of(StageKind::Dp, dp.copy);
                    let mut out = Vec::new();
                    while let Ok(m) = rx.recv() {
                        match m {
                            Msg::StoreObject { id, v } => dp.on_store(id, &v),
                            Msg::CandidateReq { qid, ids, v } => {
                                dp.on_candidates(qid, &ids, &v, ranker, &mut out);
                                for (dest, msg) in out.drain(..) {
                                    let dst = placement.node_of(dest.stage, dest.copy);
                                    meter.send(my_node, dst, msg.wire_size());
                                    if let Msg::LocalTopK { qid, hits } = msg {
                                        ag_tx[dest.copy as usize]
                                            .send(AgIn::TopK { qid, hits })
                                            .expect("channel closed");
                                    }
                                }
                            }
                            other => panic!("DP got {other:?}"),
                        }
                    }
                    meter.flush();
                    (dp, meter)
                })
            })
            .collect();

        // --- BI threads (hold dp_tx + ag_tx clones) ---
        let bi_handles: Vec<_> = bis
            .into_iter()
            .zip(bi_rx)
            .map(|(mut bi, rx)| {
                let dp_tx = dp_tx.clone();
                let ag_tx = ag_tx.clone();
                let placement = placement.clone();
                s.spawn(move || {
                    let mut meter = TrafficMeter::new(agg);
                    let my_node = placement.node_of(StageKind::Bi, bi.copy);
                    let mut out = Vec::new();
                    while let Ok(m) = rx.recv() {
                        match m {
                            Msg::Query { qid, probes, v } => {
                                bi.on_query(qid, &probes, &v, &mut out);
                                for (dest, msg) in out.drain(..) {
                                    let dst = placement.node_of(dest.stage, dest.copy);
                                    meter.send(my_node, dst, msg.wire_size());
                                    match msg {
                                        Msg::CandidateReq { .. } => {
                                            dp_tx[dest.copy as usize].send(msg).expect("channel closed");
                                        }
                                        Msg::BiMeta { qid, n_dp } => {
                                            ag_tx[dest.copy as usize]
                                                .send(AgIn::BiMeta { qid, n_dp })
                                                .expect("channel closed");
                                        }
                                        other => panic!("BI emitted {other:?}"),
                                    }
                                }
                            }
                            other => panic!("BI got {other:?}"),
                        }
                    }
                    meter.flush();
                    (bi, meter)
                })
            })
            .collect();
        // Main keeps only its own senders alive.
        drop(dp_tx);

        // --- QR on the main thread ---
        let mut qr =
            QueryReceiver::new(&family, placement.bi_copies, placement.ag_copies);
        let mut qr_meter = TrafficMeter::new(agg);
        let head = placement.head_node;
        let mut emitted = Vec::new();
        let mut dispatch_ts: Vec<Instant> = Vec::with_capacity(n_queries);
        // §Perf: one batched artifact call for the whole query set.
        let p = hasher.p();
        let raws = hasher.proj_batch(queries.as_flat(), n_queries);
        qr.work.hash_vectors += n_queries as u64;
        for qid in 0..n_queries as u32 {
            let raw = &raws[qid as usize * p..(qid as usize + 1) * p];
            qr.dispatch_query_raw(raw, qid, queries.get(qid as usize), &mut emitted);
            dispatch_ts.push(Instant::now());
            for (dest, msg) in emitted.drain(..) {
                let dst = placement.node_of(dest.stage, dest.copy);
                qr_meter.send(head, dst, msg.wire_size());
                match (dest.stage, msg) {
                    (StageKind::Bi, msg) => {
                        bi_tx[dest.copy as usize].send(msg).expect("channel closed");
                    }
                    (StageKind::Ag, Msg::QueryMeta { qid, n_bi }) => {
                        ag_tx[dest.copy as usize].send(AgIn::Meta { qid, n_bi }).expect("channel closed");
                    }
                    (stage, msg) => panic!("QR emitted {msg:?} to {stage:?}"),
                }
            }
        }
        qr_meter.flush();
        qr_work = std::mem::take(&mut qr.work);
        // Cascade shutdown.
        bi_tx.clear();
        ag_tx.clear();

        // Collect results until every AG exits.
        while let Ok((qid, hits, done_at)) = res_rx.recv() {
            per_query_secs[qid as usize] =
                done_at.duration_since(dispatch_ts[qid as usize]).as_secs_f64();
            results[qid as usize] = hits;
        }

        meters.push(qr_meter);
        for h in bi_handles {
            let (bi, meter) = h.join().unwrap();
            meters.push(meter);
            cluster.bis.push(bi);
        }
        for h in dp_handles {
            let (dp, meter) = h.join().unwrap();
            meters.push(meter);
            cluster.dps.push(dp);
        }
        for h in ag_handles {
            cluster.ags.push(h.join().unwrap());
        }
    });

    // Restore deterministic copy order (threads joined in spawn order, so
    // this is already sorted, but make it explicit).
    cluster.bis.sort_by_key(|b| b.copy);
    cluster.dps.sort_by_key(|d| d.copy);
    cluster.ags.sort_by_key(|a| a.copy);

    let mut meter = TrafficMeter::new(agg);
    for m in &meters {
        meter.merge(m);
    }
    let work = cluster.take_work(&qr_work);
    SearchOutput {
        results,
        meter,
        work,
        per_query_secs,
        wall_secs: wall.secs(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::{build_index, search};
    use crate::core::lsh::LshParams;
    use crate::data::synth::{distorted_queries, synthesize, SynthSpec};
    use crate::runtime::{ScalarHasher, ScalarRanker};

    #[test]
    fn threaded_matches_inline_results() {
        let mut cfg = Config::default();
        cfg.lsh = LshParams { l: 4, m: 8, w: 600.0, k: 5, t: 8, seed: 3 };
        cfg.cluster.bi_nodes = 2;
        cfg.cluster.dp_nodes = 4;
        let ds = synthesize(SynthSpec { n: 1_500, clusters: 40, ..Default::default() });
        let (qs, _) = distorted_queries(&ds, 15, 4.0, 7);
        let family = crate::core::lsh::HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let ranker = ScalarRanker { dim: ds.dim };

        let mut c1 = build_index(&cfg, &ds, &hasher);
        let inline_out = search(&mut c1, &qs, &hasher, &ranker);

        let mut c2 = build_index(&cfg, &ds, &hasher);
        let threaded_out = search_threaded(&mut c2, &qs, &hasher, &ranker);

        assert_eq!(inline_out.results, threaded_out.results);
        // traffic counters agree (logical messages & payload bytes are
        // aggregation-independent).
        assert_eq!(
            inline_out.meter.logical_msgs,
            threaded_out.meter.logical_msgs
        );
        // payload agrees within 1%: DP dedup depends on cross-BI arrival
        // order, which can shift a few hits between LocalTopK messages
        // (the merged result set is identical — asserted above).
        let (a, b) = (
            inline_out.meter.payload_bytes as f64,
            threaded_out.meter.payload_bytes as f64,
        );
        assert!((a - b).abs() / a < 0.01, "payload diverged: {a} vs {b}");
        // states returned intact
        assert_eq!(c2.bis.len(), 2);
        assert_eq!(c2.dps.len(), 4);
        assert_eq!(c2.ags.len(), 1);
        assert!(threaded_out.per_query_secs.iter().all(|&s| s > 0.0));
    }
}
