//! Threaded serving entry point — a thin shim over the transport-agnostic
//! [`ThreadedExecutor`](crate::dataflow::exec::ThreadedExecutor).
//!
//! The per-stage dispatch logic that used to live here is gone: stage
//! routing, per-thread traffic metering, shutdown cascade and closed-loop
//! admission are all owned by `dataflow::exec`, shared with the inline
//! executor. This module keeps the historical `search_threaded` signature
//! for the serving drivers and hosts the inline-vs-threaded differential
//! tests.
//!
//! Admission policy comes from `Config::stream.inflight`: 0 submits the
//! whole workload up front (open loop — per-query latency includes
//! queueing, as in a saturated serving scenario), W > 0 keeps at most W
//! queries in flight (closed loop — latency reflects pipeline service
//! time).

use crate::coordinator::{search_on, Cluster, SearchOutput};
use crate::data::Dataset;
use crate::dataflow::exec::ThreadedExecutor;
use crate::runtime::{Hasher, Ranker};

/// Search with one thread per stage copy. Accounting matches the inline
/// `search` (per-thread traffic meters are merged at join).
pub fn search_threaded(
    cluster: &mut Cluster,
    queries: &Dataset,
    hasher: &dyn Hasher,
    ranker: &dyn Ranker,
) -> SearchOutput {
    search_on(&ThreadedExecutor, cluster, queries, hasher, ranker)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::{build_index, search, small_test_cfg};
    use crate::data::synth::{distorted_queries, synthesize, SynthSpec};
    use crate::runtime::{ScalarHasher, ScalarRanker};

    fn world(
        cfg: &Config,
        n: usize,
        queries: usize,
    ) -> (Dataset, Dataset, ScalarHasher, ScalarRanker) {
        let ds = synthesize(SynthSpec { n, clusters: 40, ..Default::default() });
        let (qs, _) = distorted_queries(&ds, queries, 4.0, 7);
        let family = crate::core::lsh::HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let ranker = ScalarRanker { dim: ds.dim };
        (ds, qs, hasher, ranker)
    }

    fn assert_matches_inline(cfg: &Config, n: usize, queries: usize) {
        let (ds, qs, hasher, ranker) = world(cfg, n, queries);
        let mut c1 = build_index(cfg, &ds, &hasher);
        let inline_out = search(&mut c1, &qs, &hasher, &ranker);
        let mut c2 = build_index(cfg, &ds, &hasher);
        let threaded_out = search_threaded(&mut c2, &qs, &hasher, &ranker);

        assert_eq!(inline_out.results, threaded_out.results);
        // traffic counters agree (logical messages & payload bytes are
        // aggregation-independent).
        assert_eq!(
            inline_out.meter.logical_msgs,
            threaded_out.meter.logical_msgs
        );
        // payload agrees within 1%: DP dedup depends on cross-BI arrival
        // order, which can shift a few hits between LocalTopK messages
        // (the merged result set is identical — asserted above).
        let (a, b) = (
            inline_out.meter.payload_bytes as f64,
            threaded_out.meter.payload_bytes as f64,
        );
        assert!((a - b).abs() / a < 0.01, "payload diverged: {a} vs {b}");
        // states returned intact
        assert_eq!(c2.bis.len(), cfg.cluster.bi_copies());
        assert_eq!(c2.dps.len(), cfg.cluster.dp_copies());
        assert_eq!(c2.ags.len(), cfg.cluster.ag_copies);
        assert!(threaded_out.per_query_secs.iter().all(|&s| s > 0.0));
    }

    fn small_cfg() -> Config {
        small_test_cfg()
    }

    #[test]
    fn threaded_matches_inline_results() {
        assert_matches_inline(&small_cfg(), 1_500, 15);
    }

    #[test]
    fn threaded_matches_inline_under_batched_admission() {
        for window in [1usize, 3] {
            let mut cfg = small_cfg();
            cfg.stream.inflight = window;
            assert_matches_inline(&cfg, 1_500, 15);
        }
    }

    #[test]
    fn threaded_matches_inline_with_multiple_aggregators() {
        let mut cfg = small_cfg();
        cfg.cluster.ag_copies = 3;
        assert_matches_inline(&cfg, 1_500, 20);
        let mut cfg = small_cfg();
        cfg.cluster.ag_copies = 2;
        cfg.stream.inflight = 2;
        assert_matches_inline(&cfg, 1_200, 18);
    }

    #[test]
    fn threaded_build_then_threaded_search_matches_inline_pipeline() {
        use crate::coordinator::build_index_on;
        use crate::dataflow::exec::ThreadedExecutor;
        let mut cfg = small_cfg();
        cfg.stream.inflight = 4;
        let (ds, qs, hasher, ranker) = world(&cfg, 1_500, 15);

        let mut inline_cluster = build_index(&cfg, &ds, &hasher);
        let inline_out = search(&mut inline_cluster, &qs, &hasher, &ranker);

        let mut threaded_cluster = build_index_on(&ThreadedExecutor, &cfg, &ds, &hasher);
        let threaded_out = search_threaded(&mut threaded_cluster, &qs, &hasher, &ranker);

        assert_eq!(inline_out.results, threaded_out.results);
        assert_eq!(
            inline_cluster.build_meter.logical_msgs,
            threaded_cluster.build_meter.logical_msgs
        );
    }
}
