//! Index persistence: save a built cluster to disk and load it back.
//!
//! Binary little-endian format, versioned:
//!
//! ```text
//! magic "PLSH" | version u32 | lsh{l,m,w,k,t,seed} | dim u32
//! | n_bi u32 | per BI: n_buckets u32, then per bucket:
//!     key u64, n_refs u32, (id u32, dp u16)*
//! | n_dp u32 | per DP: n_objects u32, (id u32, vector f32*dim)*
//! ```
//!
//! The hash family is *not* stored — it is deterministically resampled from
//! the persisted `(dim, seed, params)`, which the loader verifies against
//! the supplied [`Config`].
//!
//! A second, smaller format lives beside it: **worker shard files**
//! ([`save_shard`]/[`load_shard`], magic `PLSD`), one worker slot's BI/DP
//! state wrapped around the wire `StateDump` encoding and stamped with the
//! session epoch + config digest. A restarted `parlsh worker --shard=PATH`
//! reloads its file and announces the stamp in `HelloOk`; the driver fences
//! stale epochs (DESIGN.md §Cluster topology). The whole body is covered by
//! an FNV-1a checksum, so any corrupted byte is a typed rejection.

use crate::config::Config;
use crate::coordinator::Cluster;
use crate::core::lsh::HashFamily;
use crate::dataflow::metrics::TrafficMeter;
use crate::dataflow::Placement;
use crate::net::wire::{self, NodeState};
use crate::partition::ObjMapper;
use crate::stages::{AgState, BiState, DpState};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"PLSH";
const VERSION: u32 = 1;

const SHARD_MAGIC: &[u8; 4] = b"PLSD";
const SHARD_VERSION: u32 = 1;

fn w_u32<W: Write>(w: &mut W, v: u32) -> Result<()> {
    Ok(w.write_all(&v.to_le_bytes())?)
}
fn w_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    Ok(w.write_all(&v.to_le_bytes())?)
}
fn w_f32<W: Write>(w: &mut W, v: f32) -> Result<()> {
    Ok(w.write_all(&v.to_le_bytes())?)
}
fn r_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}
fn r_u16<R: Read>(r: &mut R) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}
fn r_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}
fn r_f32<R: Read>(r: &mut R) -> Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

/// Persist a built index.
pub fn save(cluster: &Cluster, path: &str) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w_u32(&mut w, VERSION)?;
    let p = cluster.family.params;
    for v in [p.l as u32, p.m as u32] {
        w_u32(&mut w, v)?;
    }
    w_f32(&mut w, p.w)?;
    for v in [p.k as u32, p.t as u32] {
        w_u32(&mut w, v)?;
    }
    w_u64(&mut w, p.seed)?;
    w_u32(&mut w, cluster.family.dim as u32)?;

    w_u32(&mut w, cluster.bis.len() as u32)?;
    for bi in &cluster.bis {
        let buckets = bi.buckets_snapshot();
        w_u32(&mut w, buckets.len() as u32)?;
        for (key, refs) in buckets {
            w_u64(&mut w, key)?;
            w_u32(&mut w, refs.len() as u32)?;
            for (id, dp) in refs {
                w_u32(&mut w, id)?;
                w.write_all(&dp.to_le_bytes())?;
            }
        }
    }
    w_u32(&mut w, cluster.dps.len() as u32)?;
    for dp in &cluster.dps {
        let objs = dp.objects_snapshot();
        w_u32(&mut w, objs.len() as u32)?;
        for (id, v) in objs {
            w_u32(&mut w, id)?;
            for &x in v {
                w_f32(&mut w, x)?;
            }
        }
    }
    w.flush()?;
    Ok(())
}

/// Load a persisted index, validating it against `cfg` (topology comes from
/// `cfg.cluster`; LSH params must match what was saved).
pub fn load(path: &str, cfg: &Config) -> Result<Cluster> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path}: not a parlsh index");
    }
    let version = r_u32(&mut r)?;
    if version != VERSION {
        bail!("{path}: unsupported index version {version}");
    }
    let (l, m) = (r_u32(&mut r)? as usize, r_u32(&mut r)? as usize);
    let w = r_f32(&mut r)?;
    let (k, t) = (r_u32(&mut r)? as usize, r_u32(&mut r)? as usize);
    let seed = r_u64(&mut r)?;
    let dim = r_u32(&mut r)? as usize;
    let p = cfg.lsh;
    if (l, m, seed) != (p.l, p.m, p.seed) || (w - p.w).abs() > 1e-6 {
        bail!(
            "{path}: index was built with L={l} M={m} w={w} seed={seed}, \
             config has L={} M={} w={} seed={}",
            p.l,
            p.m,
            p.w,
            p.seed
        );
    }
    let _ = (k, t); // k/t are query-time knobs; cfg wins.

    let placement = Placement::new(&cfg.cluster);
    let n_bi = r_u32(&mut r)? as usize;
    if n_bi != placement.bi_copies {
        bail!("{path}: saved with {n_bi} BI copies, config has {}", placement.bi_copies);
    }
    let mut bis = Vec::with_capacity(n_bi);
    for copy in 0..n_bi {
        let mut bi = BiState::new(copy as u16, placement.ag_copies, cfg.stream.max_candidates);
        let n_buckets = r_u32(&mut r)? as usize;
        for _ in 0..n_buckets {
            let key = r_u64(&mut r)?;
            let n_refs = r_u32(&mut r)? as usize;
            for _ in 0..n_refs {
                let id = r_u32(&mut r)?;
                let dp = r_u16(&mut r)?;
                bi.on_index_ref(key, id, dp);
            }
        }
        bis.push(bi);
    }
    let n_dp = r_u32(&mut r)? as usize;
    if n_dp != placement.dp_copies {
        bail!("{path}: saved with {n_dp} DP copies, config has {}", placement.dp_copies);
    }
    let mut dps = Vec::with_capacity(n_dp);
    let mut buf = vec![0f32; dim];
    for copy in 0..n_dp {
        let mut dp = DpState::new(copy as u16, dim, placement.ag_copies, cfg.stream.dedup);
        let n_objs = r_u32(&mut r)? as usize;
        for _ in 0..n_objs {
            let id = r_u32(&mut r)?;
            for slot in buf.iter_mut() {
                *slot = r_f32(&mut r)?;
            }
            dp.on_store(id, &buf);
        }
        dps.push(dp);
    }

    let family = Arc::new(HashFamily::sample(dim, cfg.lsh));
    let mapper = ObjMapper::new(cfg.stream.obj_map, placement.dp_copies, dim, cfg.lsh.seed);
    let ags = (0..placement.ag_copies)
        .map(|c| AgState::new(c as u16))
        .collect();
    let mut cluster = Cluster {
        cfg: cfg.clone(),
        family,
        mapper,
        placement,
        bis,
        dps,
        ags,
        build_meter: TrafficMeter::new(cfg.stream.agg_bytes),
        build_head_work: Default::default(),
        build_wall_secs: 0.0,
        indexed_objects: 0,
    };
    // Restore the insert watermark from the loaded stores so post-load
    // inserts keep assigning fresh ids.
    cluster.indexed_objects = cluster.stored_objects() as u32;
    Ok(cluster)
}

// ----------------------------------------------------------- shard files

/// Persist one worker slot's hosted stage copies as a shard file:
///
/// ```text
/// magic "PLSD" | version u32 | crc u64 | epoch u64 | digest u64
/// | wire state-dump bytes
/// ```
///
/// `crc` is FNV-1a 64 over everything after itself, so a flipped byte
/// anywhere — epoch, digest, or state — is rejected at load rather than
/// replayed into a live session.
pub fn save_shard(
    path: &str,
    epoch: u64,
    digest: u64,
    bis: &[BiState],
    dps: &[DpState],
) -> Result<()> {
    let mut body = Vec::new();
    body.extend_from_slice(&epoch.to_le_bytes());
    body.extend_from_slice(&digest.to_le_bytes());
    body.extend_from_slice(&wire::encode_state_dump(bis, dps));
    let f = std::fs::File::create(path).with_context(|| format!("create {path}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(SHARD_MAGIC)?;
    w_u32(&mut w, SHARD_VERSION)?;
    w_u64(&mut w, wire::fnv1a64(wire::FNV64_OFFSET, &body))?;
    w.write_all(&body)?;
    w.flush()?;
    Ok(())
}

/// Load a shard file, validating magic, version, checksum, and the config
/// digest against `want_digest` (a shard written under different
/// parameters must never be replayed). Returns the stamped epoch and the
/// decoded per-copy state; the *epoch* is the caller's problem — the
/// driver fences it at rejoin.
pub fn load_shard(path: &str, want_digest: u64) -> Result<(u64, NodeState)> {
    let bytes = std::fs::read(path).with_context(|| format!("read {path}"))?;
    if bytes.len() < 16 || &bytes[0..4] != SHARD_MAGIC {
        bail!("{path}: not a parlsh shard");
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != SHARD_VERSION {
        bail!("{path}: unsupported shard version {version}");
    }
    let crc = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let body = &bytes[16..];
    let want = wire::fnv1a64(wire::FNV64_OFFSET, body);
    if crc != want {
        bail!("{path}: shard checksum mismatch (got {crc:#018x}, want {want:#018x})");
    }
    if body.len() < 16 {
        bail!("{path}: truncated shard header");
    }
    let epoch = u64::from_le_bytes(body[0..8].try_into().unwrap());
    let digest = u64::from_le_bytes(body[8..16].try_into().unwrap());
    if digest != want_digest {
        bail!(
            "{path}: shard config digest {digest:#018x} does not match the \
             session's {want_digest:#018x}"
        );
    }
    let state = wire::decode_state_dump(&body[16..])
        .with_context(|| format!("{path}: shard state dump"))?;
    Ok((epoch, state))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{build_index, search};
    use crate::core::lsh::LshParams;
    use crate::data::synth::{distorted_queries, synthesize, SynthSpec};
    use crate::runtime::{ScalarHasher, ScalarRanker};
    use crate::util::minitest::{check, Gen};

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("parlsh_persist");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    fn cfg() -> Config {
        let mut cfg = Config::default();
        cfg.lsh = LshParams { l: 3, m: 6, w: 600.0, k: 5, t: 6, seed: 4 };
        cfg.cluster.bi_nodes = 2;
        cfg.cluster.dp_nodes = 3;
        cfg
    }

    #[test]
    fn save_load_roundtrip_preserves_results() {
        let cfg = cfg();
        let ds = synthesize(SynthSpec { n: 1_200, clusters: 30, ..Default::default() });
        let (qs, _) = distorted_queries(&ds, 12, 5.0, 9);
        let family = HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let ranker = ScalarRanker { dim: ds.dim };

        let mut built = build_index(&cfg, &ds, &hasher);
        let path = tmp("round.plsh");
        save(&built, &path).unwrap();
        let mut loaded = load(&path, &cfg).unwrap();

        assert_eq!(loaded.stored_objects(), built.stored_objects());
        assert_eq!(loaded.bucket_references(), built.bucket_references());
        let a = search(&mut built, &qs, &hasher, &ranker);
        let b = search(&mut loaded, &qs, &hasher, &ranker);
        assert_eq!(a.results, b.results);
    }

    #[test]
    fn load_rejects_mismatched_params() {
        let cfg1 = cfg();
        let ds = synthesize(SynthSpec { n: 300, clusters: 10, ..Default::default() });
        let family = HashFamily::sample(ds.dim, cfg1.lsh);
        let hasher = ScalarHasher { family };
        let built = build_index(&cfg1, &ds, &hasher);
        let path = tmp("mismatch.plsh");
        save(&built, &path).unwrap();

        let mut cfg2 = cfg1.clone();
        cfg2.lsh.m = 8;
        assert!(load(&path, &cfg2).is_err());
        let mut cfg3 = cfg1.clone();
        cfg3.cluster.dp_nodes = 5;
        assert!(load(&path, &cfg3).is_err());
    }

    #[test]
    fn load_rejects_garbage() {
        let path = tmp("garbage.plsh");
        std::fs::write(&path, b"not an index").unwrap();
        assert!(load(&path, &cfg()).is_err());
    }

    #[test]
    fn watermark_survives_roundtrip_and_inserts_continue() {
        // Property: for any dataset size, the loaded cluster's
        // `indexed_objects` watermark equals the number of stored objects,
        // and a post-load insert assigns fresh ids from there.
        check("persist-watermark", 8, |g| {
            let cfg = cfg();
            let n = g.usize_in(40, 250);
            let ds = synthesize(SynthSpec { n, dim: 24, clusters: 6, ..Default::default() });
            let family = HashFamily::sample(ds.dim, cfg.lsh);
            let hasher = ScalarHasher { family };
            let built = build_index(&cfg, &ds, &hasher);
            assert_eq!(built.indexed_objects, n as u32);

            let path = tmp(&format!("watermark_{n}.plsh"));
            let _ = std::fs::remove_file(&path);
            save(&built, &path).unwrap();
            let mut loaded = load(&path, &cfg).unwrap();
            assert_eq!(loaded.indexed_objects, n as u32);
            assert_eq!(loaded.stored_objects(), n);

            let extra = synthesize(SynthSpec {
                n: 7,
                dim: 24,
                clusters: 2,
                seed: 99,
                ..Default::default()
            });
            let ids = loaded.insert_objects(extra.as_flat(), 7, &hasher);
            assert_eq!(ids, n as u32..n as u32 + 7);
            assert_eq!(loaded.indexed_objects, n as u32 + 7);
            assert_eq!(loaded.stored_objects(), n + 7);
            let _ = std::fs::remove_file(&path);
        });
    }

    #[test]
    fn index_load_rejects_truncation_at_sampled_cuts() {
        let cfg = cfg();
        let ds = synthesize(SynthSpec { n: 60, dim: 8, clusters: 4, ..Default::default() });
        let family = HashFamily::sample(ds.dim, cfg.lsh);
        let hasher = ScalarHasher { family };
        let built = build_index(&cfg, &ds, &hasher);
        let path = tmp("truncate.plsh");
        save(&built, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // the loader consumes the file exactly to its last byte, so every
        // strict prefix must fail; sample cuts densely at the front (the
        // header) and coarsely through the body
        let cut_path = tmp("truncate_cut.plsh");
        let mut cuts: Vec<usize> = (0..40.min(full.len())).collect();
        cuts.extend((40..full.len()).step_by(97));
        for cut in cuts {
            std::fs::write(&cut_path, &full[..cut]).unwrap();
            assert!(load(&cut_path, &cfg).is_err(), "prefix of {cut} bytes loaded");
        }
    }

    fn rand_node_state(g: &mut Gen) -> (Vec<BiState>, Vec<DpState>) {
        let dim = g.usize_in(2, 8);
        let bis = (0..g.usize_in(0, 3))
            .map(|copy| {
                let mut bi = BiState::new(copy as u16, 1, 0);
                for _ in 0..g.usize_in(0, 30) {
                    bi.on_index_ref(
                        g.rng.next_u64() % 50,
                        g.usize_in(0, 1 << 16) as u32,
                        g.usize_in(0, 7) as u16,
                    );
                }
                bi
            })
            .collect();
        let dps = (0..g.usize_in(0, 3))
            .map(|copy| {
                let mut dp = DpState::new(copy as u16, dim, 1, true);
                for id in 0..g.usize_in(0, 20) as u32 {
                    let v = g.vec_f32(dim, -1e4, 1e4);
                    dp.on_store(id, &v);
                }
                dp
            })
            .collect();
        (bis, dps)
    }

    #[test]
    fn shard_roundtrip_preserves_per_copy_slices() {
        // Property: a shard file reproduces each hosted copy's snapshot
        // exactly — copy ids, bucket keys and per-bucket insertion order,
        // object ids and vector bits — plus the epoch stamp.
        check("persist-shard-roundtrip", 40, |g| {
            let (bis, dps) = rand_node_state(g);
            let epoch = g.rng.next_u64() % 1000;
            let digest = g.rng.next_u64();
            let path = tmp("slice.plsd");
            save_shard(&path, epoch, digest, &bis, &dps).unwrap();
            let (e2, st) = load_shard(&path, digest).unwrap();
            assert_eq!(e2, epoch);
            assert_eq!(st.bis.len(), bis.len());
            for (bi, (copy, buckets)) in bis.iter().zip(&st.bis) {
                assert_eq!(bi.copy, *copy);
                assert_eq!(&bi.buckets_snapshot(), buckets);
            }
            assert_eq!(st.dps.len(), dps.len());
            for (dp, (copy, objs)) in dps.iter().zip(&st.dps) {
                assert_eq!(dp.copy, *copy);
                let snap: Vec<(u32, Vec<f32>)> = dp
                    .objects_snapshot()
                    .into_iter()
                    .map(|(id, v)| (id, v.to_vec()))
                    .collect();
                assert_eq!(&snap, objs);
            }
        });
    }

    #[test]
    fn shard_rejects_wrong_digest_and_any_corruption() {
        let mut bi = BiState::new(0, 1, 0);
        bi.on_index_ref(100, 1, 0);
        bi.on_index_ref(7, 3, 1);
        let mut dp = DpState::new(1, 3, 1, true);
        dp.on_store(5, &[1.0, 2.0, 3.0]);
        let path = tmp("fence.plsd");
        save_shard(&path, 4, 0xABCD, &[bi], &[dp]).unwrap();

        // the digest fences a shard written under other parameters
        assert!(load_shard(&path, 0xABCE).is_err());
        let (epoch, st) = load_shard(&path, 0xABCD).unwrap();
        assert_eq!(epoch, 4);
        assert_eq!(st.bis.len(), 1);
        assert_eq!(st.dps.len(), 1);

        // every single-byte corruption and every strict truncation is a
        // typed rejection — the checksum covers epoch, digest, and state
        let full = std::fs::read(&path).unwrap();
        let bad_path = tmp("fence_bad.plsd");
        for i in 0..full.len() {
            let mut bad = full.clone();
            bad[i] ^= 0x40;
            std::fs::write(&bad_path, &bad).unwrap();
            assert!(load_shard(&bad_path, 0xABCD).is_err(), "flip at byte {i} loaded");
        }
        for cut in 0..full.len() {
            std::fs::write(&bad_path, &full[..cut]).unwrap();
            assert!(load_shard(&bad_path, 0xABCD).is_err(), "prefix of {cut} bytes loaded");
        }
    }
}
