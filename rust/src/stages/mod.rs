//! The five dataflow stages (paper Figure 2).
//!
//! Stage logic is written as pure message handlers — `handle(msg, emit)` —
//! so the same code runs under any [`crate::dataflow::exec::Executor`]:
//! the deterministic inline executor and the threaded executor both drive
//! these states through the uniform
//! [`StageHandler`](crate::dataflow::exec::StageHandler) bindings, for
//! index build and search alike. `emit` collects `(Dest, Msg)` pairs; the
//! executor routes them and charges the traffic meter.

pub mod aggregator;
pub mod bucket_index;
pub mod data_points;
pub mod input_reader;
pub mod query_receiver;

pub use aggregator::AgState;
pub use bucket_index::BiState;
pub use data_points::DpState;
pub use input_reader::InputReader;
pub use query_receiver::QueryReceiver;

use crate::dataflow::message::{Dest, Msg};

/// Sink for messages a handler emits.
pub type Emit<'a> = &'a mut Vec<(Dest, Msg)>;
