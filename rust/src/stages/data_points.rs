//! Data Points (DP): stores its partition of the reference dataset (no
//! replication — each object lives on exactly one DP copy), ranks candidate
//! ids against queries, and emits DP-local top-k results — paper message (v).
//!
//! The object store is SoA: vectors live in one flat [`Dataset`] and the
//! global id → local row map is a [`crate::store::RowIndex`] — two sorted
//! parallel arrays plus a dense-id presence bitmap, compacted lazily at
//! the first candidate request after a build/insert barrier (DESIGN.md
//! §Storage engine). A duplicate store is a *typed*
//! [`StoreError`] ([`DpState::try_store`]) so transports can stop cleanly
//! through their existing `Stopped` paths instead of crashing a worker
//! process; [`DpState::on_store`] keeps the panicking contract for the
//! inline oracle.
//!
//! Duplicate elimination (paper §V-C): the same object can be requested by
//! several BI copies (it appears in buckets of different tables that hash to
//! different BIs). A per-query seen-set skips recomputing those distances;
//! entries are evicted FIFO once `seen_cap` queries are tracked.
//!
//! The distance + top-k computation goes through [`Ranker::rank_rows`]:
//! candidate *row indices* are gathered (not the vectors themselves) and
//! the ranker reads rows straight out of the flat store — no intermediate
//! copy ahead of the SIMD kernels. The production
//! [`crate::runtime::SimdRanker`] threads the running k-th-best bound
//! through the distance loop and early-abandons candidates whose partial
//! sum already exceeds it (`dists_pruned` counts those). All tiers return
//! bit-identical hits (DESIGN.md §Kernels).

use crate::data::Dataset;
use crate::dataflow::message::{Dest, Msg};
use crate::dataflow::metrics::WorkStats;
use crate::partition::ag_map;
use crate::runtime::Ranker;
use crate::stages::Emit;
use crate::store::{RowIndex, StoreError};
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;

pub struct DpState {
    pub copy: u16,
    /// Local partition of the reference dataset (flat SoA rows).
    store: Dataset,
    /// Global object id → local row (sorted arrays + presence bitmap).
    index: RowIndex,
    /// Per-query ids already ranked here (duplicate elimination).
    seen: HashMap<u32, HashSet<u32>>,
    seen_order: VecDeque<u32>,
    pub seen_cap: usize,
    pub n_ag: usize,
    pub dedup: bool,
    pub work: WorkStats,
    /// Scratch for gathered candidate rows/ids (hot-path, reused).
    gather_rows: Vec<u32>,
    gather_ids: Vec<u32>,
}

impl DpState {
    pub fn new(copy: u16, dim: usize, n_ag: usize, dedup: bool) -> DpState {
        DpState {
            copy,
            store: Dataset::new(dim),
            index: RowIndex::new(),
            seen: HashMap::new(),
            seen_order: VecDeque::new(),
            seen_cap: 8192,
            n_ag,
            dedup,
            work: WorkStats::default(),
            gather_rows: Vec::new(),
            gather_ids: Vec::new(),
        }
    }

    pub fn object_count(&self) -> usize {
        self.store.len()
    }

    /// Index-build message (i), fallible: a duplicate id is a replica
    /// fan-out / partitioning bug upstream, surfaced as a typed error so
    /// the socket worker can terminate through its `Stopped` path.
    pub fn try_store(&mut self, id: u32, v: &[f32]) -> Result<(), StoreError> {
        let row = self.store.len() as u32;
        if !self.index.insert(id, row) {
            return Err(StoreError::DuplicateObject { dp: self.copy, id });
        }
        self.store.push(v);
        self.work.objects_stored += 1;
        Ok(())
    }

    /// Panicking rendition of [`Self::try_store`] for contexts where a
    /// routing-invariant violation is a programming error to surface
    /// loudly (the inline oracle; the threaded executor converts the
    /// panic into its typed `Stopped` event at join).
    pub fn on_store(&mut self, id: u32, v: &[f32]) {
        if let Err(e) = self.try_store(id, v) {
            panic!("{e}");
        }
    }

    pub fn get_object(&self, id: u32) -> Option<&[f32]> {
        self.index.row_of(id).map(|r| self.store.get(r as usize))
    }

    /// Deterministic snapshot of stored objects (persistence/state dumps);
    /// sorted by id — valid in any phase.
    pub fn objects_snapshot(&self) -> Vec<(u32, &[f32])> {
        self.index
            .entries()
            .into_iter()
            .map(|(id, row)| (id, self.store.get(row as usize)))
            .collect()
    }

    /// Exact bytes resident in this copy's store (flat vectors + row
    /// index) — the `WorkStats::bytes_resident` gauge input.
    pub fn bytes_resident(&self) -> u64 {
        (self.store.as_flat().len() * std::mem::size_of::<f32>()
            + self.index.bytes_resident()) as u64
    }

    /// Search message (iv) → emits (v). `k` is the *query's* resolved
    /// top-k (per-query plan, carried on the `CandidateReq`): the local
    /// result is capped at exactly the depth this query asked for.
    pub fn on_candidates(
        &mut self,
        qid: u32,
        ids: &[u32],
        q: &Arc<[f32]>,
        k: usize,
        ranker: &dyn Ranker,
        out: Emit,
    ) {
        // Lazy barrier compaction (mirrors the BI directory): restore
        // O(log n) row lookups after a build/insert appended rows.
        if self.index.needs_compact() {
            self.index.compact();
        }
        self.gather_rows.clear();
        self.gather_ids.clear();
        if self.dedup {
            if !self.seen.contains_key(&qid) {
                self.seen.insert(qid, HashSet::new());
                self.seen_order.push_back(qid);
                if self.seen_order.len() > self.seen_cap {
                    if let Some(old) = self.seen_order.pop_front() {
                        self.seen.remove(&old);
                    }
                }
            }
            let seen = self.seen.get_mut(&qid).unwrap();
            for &id in ids {
                if !seen.insert(id) {
                    self.work.dup_skipped += 1;
                    continue;
                }
                let Some(row) = self.index.row_of(id) else {
                    // Reference to an object this DP never stored: routing
                    // invariant broken upstream.
                    panic!("DP {} asked for unknown object {id}", self.copy);
                };
                self.gather_rows.push(row);
                self.gather_ids.push(id);
            }
        } else {
            for &id in ids {
                let Some(row) = self.index.row_of(id) else {
                    panic!("DP {} asked for unknown object {id}", self.copy);
                };
                self.gather_rows.push(row);
                self.gather_ids.push(id);
            }
        }
        let n = self.gather_ids.len();
        self.work.dists_computed += n as u64;
        let hits: Vec<(f32, u32)> = if n == 0 {
            Vec::new()
        } else {
            let (hits, pruned) = ranker.rank_rows(
                q,
                self.store.as_flat(),
                self.store.dim,
                &self.gather_rows,
                k,
            );
            self.work.dists_pruned += pruned;
            hits.into_iter()
                .map(|(d, local)| (d, self.gather_ids[local as usize]))
                .collect()
        };
        out.push((
            Dest::ag(ag_map(qid, self.n_ag)),
            Msg::LocalTopK { qid, hits },
        ));
    }

    /// Drop per-query dedup state (query completed).
    pub fn finish_query(&mut self, qid: u32) {
        if self.seen.remove(&qid).is_some() {
            if let Some(pos) = self.seen_order.iter().position(|&q| q == qid) {
                self.seen_order.remove(pos);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ScalarRanker;

    fn dp() -> DpState {
        let mut dp = DpState::new(0, 4, 1, true);
        dp.on_store(10, &[0.0, 0.0, 0.0, 0.0]);
        dp.on_store(11, &[1.0, 0.0, 0.0, 0.0]);
        dp.on_store(12, &[5.0, 0.0, 0.0, 0.0]);
        dp
    }

    fn q() -> Arc<[f32]> {
        vec![0f32; 4].into()
    }

    #[test]
    fn ranks_and_emits_topk() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10, 11, 12], &q(), 2, &ranker, &mut out);
        assert_eq!(out.len(), 1);
        match &out[0].1 {
            Msg::LocalTopK { qid, hits } => {
                assert_eq!(*qid, 1);
                assert_eq!(hits.as_slice(), &[(0.0, 10), (1.0, 11)]);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(dp.work.dists_computed, 3);
    }

    #[test]
    fn duplicate_candidates_skipped() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10, 11], &q(), 2, &ranker, &mut out);
        dp.on_candidates(1, &[10, 12], &q(), 2, &ranker, &mut out);
        assert_eq!(dp.work.dup_skipped, 1);
        assert_eq!(dp.work.dists_computed, 3);
        // second message ranks only id 12
        match &out[1].1 {
            Msg::LocalTopK { hits, .. } => {
                assert_eq!(hits.as_slice(), &[(25.0, 12)]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn per_query_k_caps_local_topk() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        // two queries with different plans over the same candidates
        dp.on_candidates(1, &[10, 11, 12], &q(), 1, &ranker, &mut out);
        dp.on_candidates(2, &[10, 11, 12], &q(), 3, &ranker, &mut out);
        match &out[0].1 {
            Msg::LocalTopK { hits, .. } => assert_eq!(hits.as_slice(), &[(0.0, 10)]),
            other => panic!("{other:?}"),
        }
        match &out[1].1 {
            Msg::LocalTopK { hits, .. } => assert_eq!(hits.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn different_queries_do_not_share_dedup() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        dp.on_candidates(2, &[10], &q(), 2, &ranker, &mut out);
        assert_eq!(dp.work.dup_skipped, 0);
        assert_eq!(dp.work.dists_computed, 2);
    }

    #[test]
    fn dedup_off_recomputes() {
        let mut dp = DpState::new(0, 4, 1, false);
        dp.on_store(10, &[0.0; 4]);
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        assert_eq!(dp.work.dists_computed, 2);
        assert_eq!(dp.work.dup_skipped, 0);
    }

    #[test]
    fn double_store_is_a_typed_error() {
        let mut dp = dp();
        let err = dp.try_store(10, &[0.0; 4]).unwrap_err();
        assert_eq!(err, StoreError::DuplicateObject { dp: 0, id: 10 });
        // nothing was stored; the original object is intact
        assert_eq!(dp.object_count(), 3);
        assert_eq!(dp.get_object(10), Some([0.0f32; 4].as_slice()));
        assert_eq!(dp.work.objects_stored, 3);
    }

    #[test]
    #[should_panic(expected = "stored twice")]
    fn double_store_is_a_replication_bug() {
        let mut dp = dp();
        dp.on_store(10, &[0.0; 4]);
    }

    #[test]
    #[should_panic(expected = "unknown object")]
    fn unknown_candidate_is_a_routing_bug() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[999], &q(), 2, &ranker, &mut out);
    }

    #[test]
    fn insert_mid_stream_rows_visible_after_recompaction() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        // live insert after a query: staged until the next request
        dp.on_store(13, &[2.0, 0.0, 0.0, 0.0]);
        assert_eq!(dp.get_object(13), Some([2.0f32, 0.0, 0.0, 0.0].as_slice()));
        dp.on_candidates(2, &[12, 13], &q(), 2, &ranker, &mut out);
        match &out[1].1 {
            Msg::LocalTopK { hits, .. } => {
                assert_eq!(hits.as_slice(), &[(4.0, 13), (25.0, 12)]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn seen_cap_evicts_oldest() {
        let mut dp = dp();
        dp.seen_cap = 2;
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        dp.on_candidates(2, &[10], &q(), 2, &ranker, &mut out);
        dp.on_candidates(3, &[10], &q(), 2, &ranker, &mut out); // evicts qid 1
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out); // recomputed
        assert_eq!(dp.work.dup_skipped, 0);
        assert_eq!(dp.work.dists_computed, 4);
    }

    #[test]
    fn finish_query_clears_state() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        dp.finish_query(1);
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        assert_eq!(dp.work.dup_skipped, 0);
        assert_eq!(dp.work.dists_computed, 2);
    }

    #[test]
    fn bytes_resident_counts_rows_and_index() {
        let dp = dp();
        // at least the 3 stored 4-dim vectors
        assert!(dp.bytes_resident() >= (3 * 4 * 4) as u64);
    }
}
