//! Data Points (DP): stores its partition of the reference dataset (no
//! replication — each object lives on exactly one DP copy), ranks candidate
//! ids against queries, and emits DP-local top-k results — paper message (v).
//!
//! Duplicate elimination (paper §V-C): the same object can be requested by
//! several BI copies (it appears in buckets of different tables that hash to
//! different BIs). A per-query seen-set skips recomputing those distances;
//! entries are evicted FIFO once `seen_cap` queries are tracked.
//!
//! The distance + top-k computation goes through the [`Ranker`]. Candidate
//! vectors are gathered into one reused contiguous buffer so the ranker
//! scans cache-line-friendly blocks, and ranking goes through
//! [`Ranker::rank_pruned`]: the production [`crate::runtime::SimdRanker`]
//! threads the running k-th-best bound through the distance loop and
//! early-abandons candidates whose partial sum already exceeds it
//! (`dists_pruned` counts those), while the compiled PJRT `rank` artifact
//! (via `HybridRanker`) ranks whole tiles above its size threshold. All
//! tiers return bit-identical hits (DESIGN.md §Kernels).

use crate::data::Dataset;
use crate::dataflow::message::{Dest, Msg};
use crate::dataflow::metrics::WorkStats;
use crate::partition::ag_map;
use crate::runtime::Ranker;
use crate::stages::Emit;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;

pub struct DpState {
    pub copy: u16,
    /// Local partition of the reference dataset.
    store: Dataset,
    /// Global object id → local row.
    rows: HashMap<u32, u32>,
    /// Per-query ids already ranked here (duplicate elimination).
    seen: HashMap<u32, HashSet<u32>>,
    seen_order: VecDeque<u32>,
    pub seen_cap: usize,
    pub n_ag: usize,
    pub dedup: bool,
    pub work: WorkStats,
    /// Scratch buffer for gathered candidate vectors (hot-path, reused).
    gather: Vec<f32>,
    gather_ids: Vec<u32>,
}

impl DpState {
    pub fn new(copy: u16, dim: usize, n_ag: usize, dedup: bool) -> DpState {
        DpState {
            copy,
            store: Dataset::new(dim),
            rows: HashMap::new(),
            seen: HashMap::new(),
            seen_order: VecDeque::new(),
            seen_cap: 8192,
            n_ag,
            dedup,
            work: WorkStats::default(),
            gather: Vec::new(),
            gather_ids: Vec::new(),
        }
    }

    pub fn object_count(&self) -> usize {
        self.store.len()
    }

    /// Index-build message (i).
    pub fn on_store(&mut self, id: u32, v: &[f32]) {
        let row = self.store.len() as u32;
        let prev = self.rows.insert(id, row);
        assert!(prev.is_none(), "object {id} stored twice (replication bug)");
        self.store.push(v);
        self.work.objects_stored += 1;
    }

    pub fn get_object(&self, id: u32) -> Option<&[f32]> {
        self.rows.get(&id).map(|&r| self.store.get(r as usize))
    }

    /// Deterministic snapshot of stored objects (persistence); sorted by id.
    pub fn objects_snapshot(&self) -> Vec<(u32, &[f32])> {
        let mut out: Vec<(u32, &[f32])> = self
            .rows
            .iter()
            .map(|(&id, &row)| (id, self.store.get(row as usize)))
            .collect();
        out.sort_by_key(|(id, _)| *id);
        out
    }

    /// Search message (iv) → emits (v). `k` is the *query's* resolved
    /// top-k (per-query plan, carried on the `CandidateReq`): the local
    /// result is capped at exactly the depth this query asked for.
    pub fn on_candidates(
        &mut self,
        qid: u32,
        ids: &[u32],
        q: &Arc<[f32]>,
        k: usize,
        ranker: &dyn Ranker,
        out: Emit,
    ) {
        let dim = self.store.dim;
        self.gather.clear();
        self.gather_ids.clear();
        if self.dedup {
            if !self.seen.contains_key(&qid) {
                self.seen.insert(qid, HashSet::new());
                self.seen_order.push_back(qid);
                if self.seen_order.len() > self.seen_cap {
                    if let Some(old) = self.seen_order.pop_front() {
                        self.seen.remove(&old);
                    }
                }
            }
            let seen = self.seen.get_mut(&qid).unwrap();
            for &id in ids {
                if !seen.insert(id) {
                    self.work.dup_skipped += 1;
                    continue;
                }
                let Some(&row) = self.rows.get(&id) else {
                    // Reference to an object this DP never stored: routing
                    // invariant broken upstream.
                    panic!("DP {} asked for unknown object {id}", self.copy);
                };
                self.gather
                    .extend_from_slice(self.store.get(row as usize));
                self.gather_ids.push(id);
            }
        } else {
            for &id in ids {
                let Some(&row) = self.rows.get(&id) else {
                    panic!("DP {} asked for unknown object {id}", self.copy);
                };
                self.gather
                    .extend_from_slice(self.store.get(row as usize));
                self.gather_ids.push(id);
            }
        }
        let n = self.gather_ids.len();
        self.work.dists_computed += n as u64;
        let hits: Vec<(f32, u32)> = if n == 0 {
            Vec::new()
        } else {
            debug_assert_eq!(self.gather.len(), n * dim);
            let (hits, pruned) = ranker.rank_pruned(q, &self.gather, n, k);
            self.work.dists_pruned += pruned;
            hits.into_iter()
                .map(|(d, local)| (d, self.gather_ids[local as usize]))
                .collect()
        };
        out.push((
            Dest::ag(ag_map(qid, self.n_ag)),
            Msg::LocalTopK { qid, hits },
        ));
    }

    /// Drop per-query dedup state (query completed).
    pub fn finish_query(&mut self, qid: u32) {
        if self.seen.remove(&qid).is_some() {
            if let Some(pos) = self.seen_order.iter().position(|&q| q == qid) {
                self.seen_order.remove(pos);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ScalarRanker;

    fn dp() -> DpState {
        let mut dp = DpState::new(0, 4, 1, true);
        dp.on_store(10, &[0.0, 0.0, 0.0, 0.0]);
        dp.on_store(11, &[1.0, 0.0, 0.0, 0.0]);
        dp.on_store(12, &[5.0, 0.0, 0.0, 0.0]);
        dp
    }

    fn q() -> Arc<[f32]> {
        vec![0f32; 4].into()
    }

    #[test]
    fn ranks_and_emits_topk() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10, 11, 12], &q(), 2, &ranker, &mut out);
        assert_eq!(out.len(), 1);
        match &out[0].1 {
            Msg::LocalTopK { qid, hits } => {
                assert_eq!(*qid, 1);
                assert_eq!(hits.as_slice(), &[(0.0, 10), (1.0, 11)]);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(dp.work.dists_computed, 3);
    }

    #[test]
    fn duplicate_candidates_skipped() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10, 11], &q(), 2, &ranker, &mut out);
        dp.on_candidates(1, &[10, 12], &q(), 2, &ranker, &mut out);
        assert_eq!(dp.work.dup_skipped, 1);
        assert_eq!(dp.work.dists_computed, 3);
        // second message ranks only id 12
        match &out[1].1 {
            Msg::LocalTopK { hits, .. } => {
                assert_eq!(hits.as_slice(), &[(25.0, 12)]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn per_query_k_caps_local_topk() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        // two queries with different plans over the same candidates
        dp.on_candidates(1, &[10, 11, 12], &q(), 1, &ranker, &mut out);
        dp.on_candidates(2, &[10, 11, 12], &q(), 3, &ranker, &mut out);
        match &out[0].1 {
            Msg::LocalTopK { hits, .. } => assert_eq!(hits.as_slice(), &[(0.0, 10)]),
            other => panic!("{other:?}"),
        }
        match &out[1].1 {
            Msg::LocalTopK { hits, .. } => assert_eq!(hits.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn different_queries_do_not_share_dedup() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        dp.on_candidates(2, &[10], &q(), 2, &ranker, &mut out);
        assert_eq!(dp.work.dup_skipped, 0);
        assert_eq!(dp.work.dists_computed, 2);
    }

    #[test]
    fn dedup_off_recomputes() {
        let mut dp = DpState::new(0, 4, 1, false);
        dp.on_store(10, &[0.0; 4]);
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        assert_eq!(dp.work.dists_computed, 2);
        assert_eq!(dp.work.dup_skipped, 0);
    }

    #[test]
    #[should_panic(expected = "stored twice")]
    fn double_store_is_a_replication_bug() {
        let mut dp = dp();
        dp.on_store(10, &[0.0; 4]);
    }

    #[test]
    #[should_panic(expected = "unknown object")]
    fn unknown_candidate_is_a_routing_bug() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[999], &q(), 2, &ranker, &mut out);
    }

    #[test]
    fn seen_cap_evicts_oldest() {
        let mut dp = dp();
        dp.seen_cap = 2;
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        dp.on_candidates(2, &[10], &q(), 2, &ranker, &mut out);
        dp.on_candidates(3, &[10], &q(), 2, &ranker, &mut out); // evicts qid 1
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out); // recomputed
        assert_eq!(dp.work.dup_skipped, 0);
        assert_eq!(dp.work.dists_computed, 4);
    }

    #[test]
    fn finish_query_clears_state() {
        let mut dp = dp();
        let ranker = ScalarRanker { dim: 4 };
        let mut out = Vec::new();
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        dp.finish_query(1);
        dp.on_candidates(1, &[10], &q(), 2, &ranker, &mut out);
        assert_eq!(dp.work.dup_skipped, 0);
        assert_eq!(dp.work.dists_computed, 2);
    }
}
