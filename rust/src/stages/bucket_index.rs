//! Bucket Index (BI): stores the distributed hash tables as
//! `bucket key → [(object id, DP copy)]` and, per query, turns probe visits
//! into per-DP candidate requests — paper message (iv).
//!
//! Buckets hold *references only* (id + DP copy); the data objects live in
//! exactly one DP copy each, which is the paper's no-replication invariant.
//! Candidate ids retrieved from multiple probed buckets are deduplicated
//! and grouped per DP copy so each DP receives at most one message per
//! (query, BI) pair — the BI-side half of duplicate elimination.

use crate::dataflow::message::{Dest, Msg};
use crate::dataflow::metrics::WorkStats;
use crate::partition::ag_map;
use crate::stages::Emit;
use std::collections::HashMap;
use std::sync::Arc;

#[derive(Default)]
pub struct BiState {
    pub copy: u16,
    /// The shard of every hash table whose bucket keys map here.
    buckets: HashMap<u64, Vec<(u32, u16)>>,
    pub n_ag: usize,
    /// Cap on candidates routed per query at this BI (0 = unlimited).
    pub max_candidates: usize,
    pub work: WorkStats,
    /// §Perf: per-query scratch reused across queries — dedup set plus a
    /// dense per-DP grouping (indexed by DP copy) so the hot path allocates
    /// only the outgoing id vectors it actually sends.
    seen_scratch: std::collections::HashSet<u32>,
    by_dp_scratch: Vec<Vec<u32>>,
    touched_dps: Vec<u16>,
}

impl BiState {
    pub fn new(copy: u16, n_ag: usize, max_candidates: usize) -> BiState {
        BiState {
            copy,
            buckets: HashMap::new(),
            n_ag,
            max_candidates,
            work: WorkStats::default(),
            seen_scratch: std::collections::HashSet::new(),
            by_dp_scratch: Vec::new(),
            touched_dps: Vec::new(),
        }
    }

    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    pub fn reference_count(&self) -> usize {
        self.buckets.values().map(|v| v.len()).sum()
    }

    /// Index-build message (ii).
    pub fn on_index_ref(&mut self, key: u64, id: u32, dp: u16) {
        self.buckets.entry(key).or_default().push((id, dp));
    }

    /// Deterministic snapshot of all buckets (persistence); sorted by key.
    pub fn buckets_snapshot(&self) -> Vec<(u64, &Vec<(u32, u16)>)> {
        let mut out: Vec<_> = self.buckets.iter().map(|(k, v)| (*k, v)).collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }

    /// Search message (iii) → emits (iv) + AG completion meta. `k` is the
    /// query's resolved top-k (per-query plan), forwarded on every
    /// `CandidateReq` so DP ranks to the right depth.
    pub fn on_query(
        &mut self,
        qid: u32,
        probes: &[(u8, u64)],
        v: &Arc<[f32]>,
        k: u32,
        out: Emit,
    ) {
        // Gather candidates over all probed buckets, dedup by id, group by
        // DP copy. Scratch structures are reused across queries (§Perf).
        self.seen_scratch.clear();
        self.touched_dps.clear();
        let mut routed = 0usize;
        'outer: for &(_table, key) in probes {
            self.work.bucket_lookups += 1;
            if let Some(refs) = self.buckets.get(&key) {
                for &(id, dp) in refs {
                    if !self.seen_scratch.insert(id) {
                        self.work.dup_skipped += 1;
                        continue;
                    }
                    let slot = dp as usize;
                    if slot >= self.by_dp_scratch.len() {
                        self.by_dp_scratch.resize_with(slot + 1, Vec::new);
                    }
                    if self.by_dp_scratch[slot].is_empty() {
                        self.touched_dps.push(dp);
                    }
                    self.by_dp_scratch[slot].push(id);
                    routed += 1;
                    if self.max_candidates > 0 && routed >= self.max_candidates {
                        break 'outer;
                    }
                }
            }
        }
        self.work.candidates_routed += routed as u64;
        self.touched_dps.sort_unstable();
        let n_dp = self.touched_dps.len() as u32;
        for &dp in &self.touched_dps {
            let ids = std::mem::take(&mut self.by_dp_scratch[dp as usize]);
            out.push((
                Dest::dp(dp),
                Msg::CandidateReq { qid, ids, v: v.clone(), k },
            ));
        }
        out.push((
            Dest::ag(ag_map(qid, self.n_ag)),
            Msg::BiMeta { qid, n_dp },
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::message::StageKind;

    fn arcv() -> Arc<[f32]> {
        vec![0f32; 8].into()
    }

    #[test]
    fn indexes_and_retrieves() {
        let mut bi = BiState::new(0, 1, 0);
        bi.on_index_ref(100, 1, 0);
        bi.on_index_ref(100, 2, 1);
        bi.on_index_ref(200, 3, 0);
        assert_eq!(bi.bucket_count(), 2);
        assert_eq!(bi.reference_count(), 3);

        let mut out = Vec::new();
        bi.on_query(7, &[(0, 100)], &arcv(), 5, &mut out);
        // two DPs involved → 2 CandidateReq + 1 BiMeta
        assert_eq!(out.len(), 3);
        let reqs: Vec<_> = out
            .iter()
            .filter_map(|(d, m)| match m {
                Msg::CandidateReq { ids, .. } => Some((d.copy, ids.clone())),
                _ => None,
            })
            .collect();
        assert_eq!(reqs, vec![(0, vec![1]), (1, vec![2])]);
        match out.last().unwrap() {
            (d, Msg::BiMeta { qid, n_dp }) => {
                assert_eq!(d.stage, StageKind::Ag);
                assert_eq!((*qid, *n_dp), (7, 2));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn empty_probe_still_reports_meta() {
        let mut bi = BiState::new(0, 1, 0);
        let mut out = Vec::new();
        bi.on_query(1, &[(0, 999)], &arcv(), 5, &mut out);
        assert_eq!(out.len(), 1);
        match &out[0].1 {
            Msg::BiMeta { n_dp, .. } => assert_eq!(*n_dp, 0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dedups_across_probed_buckets() {
        let mut bi = BiState::new(0, 1, 0);
        // same object indexed under two different keys (two tables)
        bi.on_index_ref(100, 9, 2);
        bi.on_index_ref(200, 9, 2);
        let mut out = Vec::new();
        bi.on_query(1, &[(0, 100), (1, 200)], &arcv(), 5, &mut out);
        let ids: Vec<u32> = out
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::CandidateReq { ids, .. } => Some(ids.clone()),
                _ => None,
            })
            .flatten()
            .collect();
        assert_eq!(ids, vec![9]);
        assert_eq!(bi.work.dup_skipped, 1);
        assert_eq!(bi.work.candidates_routed, 1);
    }

    #[test]
    fn max_candidates_caps_routing() {
        let mut bi = BiState::new(0, 1, 3);
        for id in 0..10 {
            bi.on_index_ref(100, id, 0);
        }
        let mut out = Vec::new();
        bi.on_query(1, &[(0, 100)], &arcv(), 5, &mut out);
        let ids: usize = out
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::CandidateReq { ids, .. } => Some(ids.len()),
                _ => None,
            })
            .sum();
        assert_eq!(ids, 3);
    }

    #[test]
    fn work_counters_track_lookups() {
        let mut bi = BiState::new(0, 1, 0);
        bi.on_index_ref(5, 1, 0);
        let mut out = Vec::new();
        bi.on_query(1, &[(0, 5), (1, 6), (2, 7)], &arcv(), 5, &mut out);
        assert_eq!(bi.work.bucket_lookups, 3);
    }
}
