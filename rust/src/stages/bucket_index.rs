//! Bucket Index (BI): stores the distributed hash tables and, per query,
//! turns probe visits into per-DP candidate requests — paper message (iv).
//!
//! Buckets hold *references only* (id + DP copy); the data objects live in
//! exactly one DP copy each, which is the paper's no-replication invariant.
//! The bucket store is a [`crate::store::BucketDirectory`]: a sorted key
//! table over one contiguous refs arena, so a probe is a binary search
//! plus a cache-line-friendly slice scan instead of chasing
//! `HashMap<u64, Vec<_>>` heap nodes. Live inserts land in the
//! directory's overlay and compact at the first query after the barrier
//! (DESIGN.md §Storage engine).
//!
//! Candidate ids retrieved from multiple probed buckets are deduplicated
//! through an exact [`crate::store::SeenFilter`] bitmap and grouped per DP
//! copy so each DP receives at most one message per (query, BI) pair —
//! the BI-side half of duplicate elimination. On top of it rides
//! bucket-level pruning (Jafari et al., arXiv 1912.07101): a probed
//! bucket whose references are *provably* all seen this query — its key
//! was already probed, or every id chunk its summary touches is saturated
//! — is skipped whole (`WorkStats::bucket_skipped`) with its references
//! charged to `dup_skipped` exactly as the scan would have, so routed
//! candidates and work accounting stay bit-identical to the unfiltered
//! scan.

use crate::dataflow::message::{Dest, Msg};
use crate::dataflow::metrics::WorkStats;
use crate::partition::ag_map;
use crate::stages::Emit;
use crate::store::{BucketDirectory, SeenFilter};
use std::sync::Arc;

#[derive(Default)]
pub struct BiState {
    pub copy: u16,
    /// The shard of every hash table whose bucket keys map here.
    dir: BucketDirectory,
    pub n_ag: usize,
    /// Cap on candidates routed per query at this BI (0 = unlimited).
    pub max_candidates: usize,
    pub work: WorkStats,
    /// Per-query exact seen-bitmap + chunk saturation (dedup and
    /// bucket-skip decisions); reconfigured at every compaction.
    seen: SeenFilter,
    /// §Perf: per-query scratch reused across queries — probed-key list
    /// (revisit skips) plus a dense per-DP grouping (indexed by DP copy)
    /// so the hot path allocates only the outgoing id vectors it sends.
    probed_scratch: Vec<u64>,
    by_dp_scratch: Vec<Vec<u32>>,
    touched_dps: Vec<u16>,
}

impl BiState {
    pub fn new(copy: u16, n_ag: usize, max_candidates: usize) -> BiState {
        BiState {
            copy,
            dir: BucketDirectory::new(),
            n_ag,
            max_candidates,
            work: WorkStats::default(),
            seen: SeenFilter::default(),
            probed_scratch: Vec::new(),
            by_dp_scratch: Vec::new(),
            touched_dps: Vec::new(),
        }
    }

    pub fn bucket_count(&self) -> usize {
        self.dir.bucket_count()
    }

    pub fn reference_count(&self) -> usize {
        self.dir.reference_count()
    }

    /// Index-build message (ii).
    pub fn on_index_ref(&mut self, key: u64, id: u32, dp: u16) {
        self.dir.insert(key, id, dp);
    }

    /// Deterministic snapshot of all buckets (persistence/state dumps);
    /// sorted by key, refs in insertion order — valid in any phase.
    pub fn buckets_snapshot(&self) -> Vec<(u64, Vec<(u32, u16)>)> {
        self.dir.snapshot()
    }

    /// Exact bytes resident in this copy's index state (arena directory +
    /// seen bitmaps) — the `WorkStats::bytes_resident` gauge input.
    pub fn bytes_resident(&self) -> u64 {
        (self.dir.bytes_resident() + self.seen.bytes_resident()) as u64
    }

    /// Search message (iii) → emits (iv) + AG completion meta. `k` is the
    /// query's resolved top-k (per-query plan), forwarded on every
    /// `CandidateReq` so DP ranks to the right depth.
    pub fn on_query(
        &mut self,
        qid: u32,
        probes: &[(u8, u64)],
        v: &Arc<[f32]>,
        k: u32,
        out: Emit,
    ) {
        // Lazy barrier compaction: inserts since the last query fold into
        // the arena now, and the seen filter adopts the new chunk
        // capacities. Queries never run against a dirty overlay.
        if self.dir.needs_compact() {
            self.dir.compact();
            self.seen
                .configure(self.dir.id_space(), self.dir.chunk_shift(), self.dir.chunk_caps());
        }
        // Gather candidates over all probed buckets, dedup by id, group by
        // DP copy. Scratch structures are reused across queries (§Perf).
        self.seen.begin_query();
        self.probed_scratch.clear();
        self.touched_dps.clear();
        let mut routed = 0usize;
        'outer: for &(_table, key) in probes {
            self.work.bucket_lookups += 1;
            let Some((refs, summary)) = self.dir.lookup(key) else {
                continue;
            };
            if self.probed_scratch.contains(&key) || self.seen.all_seen(summary) {
                // Bucket-level pruning: every reference here is provably
                // already seen this query (the key was already probed, or
                // all its id chunks are saturated), so skip the scan and
                // charge `dup_skipped` exactly as the scan would have.
                // Sound against the routing cap too: a cap break exits the
                // whole probe loop, so a skippable bucket can only follow
                // fully-scanned ones.
                self.work.bucket_skipped += 1;
                self.work.dup_skipped += refs.len() as u64;
                continue;
            }
            self.probed_scratch.push(key);
            for &(id, dp) in refs {
                if !self.seen.insert(id) {
                    self.work.dup_skipped += 1;
                    continue;
                }
                let slot = dp as usize;
                if slot >= self.by_dp_scratch.len() {
                    self.by_dp_scratch.resize_with(slot + 1, Vec::new);
                }
                if self.by_dp_scratch[slot].is_empty() {
                    self.touched_dps.push(dp);
                }
                self.by_dp_scratch[slot].push(id);
                routed += 1;
                if self.max_candidates > 0 && routed >= self.max_candidates {
                    break 'outer;
                }
            }
        }
        self.work.candidates_routed += routed as u64;
        self.touched_dps.sort_unstable();
        let n_dp = self.touched_dps.len() as u32;
        for &dp in &self.touched_dps {
            let ids = std::mem::take(&mut self.by_dp_scratch[dp as usize]);
            out.push((
                Dest::dp(dp),
                Msg::CandidateReq { qid, ids, v: v.clone(), k },
            ));
        }
        out.push((
            Dest::ag(ag_map(qid, self.n_ag)),
            Msg::BiMeta { qid, n_dp },
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::message::StageKind;

    fn arcv() -> Arc<[f32]> {
        vec![0f32; 8].into()
    }

    #[test]
    fn indexes_and_retrieves() {
        let mut bi = BiState::new(0, 1, 0);
        bi.on_index_ref(100, 1, 0);
        bi.on_index_ref(100, 2, 1);
        bi.on_index_ref(200, 3, 0);
        assert_eq!(bi.bucket_count(), 2);
        assert_eq!(bi.reference_count(), 3);

        let mut out = Vec::new();
        bi.on_query(7, &[(0, 100)], &arcv(), 5, &mut out);
        // two DPs involved → 2 CandidateReq + 1 BiMeta
        assert_eq!(out.len(), 3);
        let reqs: Vec<_> = out
            .iter()
            .filter_map(|(d, m)| match m {
                Msg::CandidateReq { ids, .. } => Some((d.copy, ids.clone())),
                _ => None,
            })
            .collect();
        assert_eq!(reqs, vec![(0, vec![1]), (1, vec![2])]);
        match out.last().unwrap() {
            (d, Msg::BiMeta { qid, n_dp }) => {
                assert_eq!(d.stage, StageKind::Ag);
                assert_eq!((*qid, *n_dp), (7, 2));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn empty_probe_still_reports_meta() {
        let mut bi = BiState::new(0, 1, 0);
        let mut out = Vec::new();
        bi.on_query(1, &[(0, 999)], &arcv(), 5, &mut out);
        assert_eq!(out.len(), 1);
        match &out[0].1 {
            Msg::BiMeta { n_dp, .. } => assert_eq!(*n_dp, 0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dedups_across_probed_buckets() {
        let mut bi = BiState::new(0, 1, 0);
        // same object indexed under two different keys (two tables)
        bi.on_index_ref(100, 9, 2);
        bi.on_index_ref(200, 9, 2);
        let mut out = Vec::new();
        bi.on_query(1, &[(0, 100), (1, 200)], &arcv(), 5, &mut out);
        let ids: Vec<u32> = out
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::CandidateReq { ids, .. } => Some(ids.clone()),
                _ => None,
            })
            .flatten()
            .collect();
        assert_eq!(ids, vec![9]);
        assert_eq!(bi.work.dup_skipped, 1);
        assert_eq!(bi.work.candidates_routed, 1);
        // the second bucket was skipped whole: id 9's chunk saturated
        // after the first bucket's scan
        assert_eq!(bi.work.bucket_skipped, 1);
    }

    #[test]
    fn revisited_probe_key_skips_the_bucket() {
        let mut bi = BiState::new(0, 1, 0);
        bi.on_index_ref(100, 1, 0);
        bi.on_index_ref(100, 2, 0);
        // ids 3 and 64 keep chunk saturation out of play (id 3 shares id
        // 2's chunk but is never seen; id 64 widens the id space so
        // chunks span 2 ids) — the skip below is the revisit rule alone.
        bi.on_index_ref(300, 3, 0);
        bi.on_index_ref(400, 64, 0);
        let mut out = Vec::new();
        // two tables probing the SAME key: the revisit is skipped whole
        bi.on_query(1, &[(0, 100), (1, 100)], &arcv(), 5, &mut out);
        assert_eq!(bi.work.bucket_lookups, 2);
        assert_eq!(bi.work.bucket_skipped, 1);
        // both refs of the revisited bucket charge dup_skipped, exactly
        // like the pre-bitmap scan did
        assert_eq!(bi.work.dup_skipped, 2);
        assert_eq!(bi.work.candidates_routed, 2);
    }

    #[test]
    fn skipping_never_changes_routed_candidates() {
        // Differential: same probe sequence against a store where every
        // bucket holds every id — the skip path engages heavily and the
        // routed id set must equal the unskipped reference (all ids once).
        let mut bi = BiState::new(0, 1, 0);
        for key in 0..8u64 {
            for id in 0..16u32 {
                bi.on_index_ref(key, id, (id % 3) as u16);
            }
        }
        let probes: Vec<(u8, u64)> = (0..8).map(|t| (t as u8, t as u64)).collect();
        let mut out = Vec::new();
        bi.on_query(1, &probes, &arcv(), 5, &mut out);
        let mut ids: Vec<u32> = out
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::CandidateReq { ids, .. } => Some(ids.clone()),
                _ => None,
            })
            .flatten()
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..16).collect::<Vec<u32>>());
        assert_eq!(bi.work.candidates_routed, 16);
        // buckets 1..8 are saturated after bucket 0's scan
        assert_eq!(bi.work.bucket_skipped, 7);
        assert_eq!(bi.work.dup_skipped, 7 * 16);
    }

    #[test]
    fn insert_mid_stream_recompacts_before_the_next_query() {
        let mut bi = BiState::new(0, 1, 0);
        bi.on_index_ref(100, 1, 0);
        let mut out = Vec::new();
        bi.on_query(1, &[(0, 100)], &arcv(), 5, &mut out);
        // live insert after a query: overlay until the next probe
        bi.on_index_ref(100, 2, 0);
        bi.on_index_ref(500, 3, 1);
        out.clear();
        bi.on_query(2, &[(0, 100), (1, 500)], &arcv(), 5, &mut out);
        let mut ids: Vec<u32> = out
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::CandidateReq { ids, .. } => Some(ids.clone()),
                _ => None,
            })
            .flatten()
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn max_candidates_caps_routing() {
        let mut bi = BiState::new(0, 1, 3);
        for id in 0..10 {
            bi.on_index_ref(100, id, 0);
        }
        let mut out = Vec::new();
        bi.on_query(1, &[(0, 100)], &arcv(), 5, &mut out);
        let ids: usize = out
            .iter()
            .filter_map(|(_, m)| match m {
                Msg::CandidateReq { ids, .. } => Some(ids.len()),
                _ => None,
            })
            .sum();
        assert_eq!(ids, 3);
    }

    #[test]
    fn work_counters_track_lookups() {
        let mut bi = BiState::new(0, 1, 0);
        bi.on_index_ref(5, 1, 0);
        let mut out = Vec::new();
        bi.on_query(1, &[(0, 5), (1, 6), (2, 7)], &arcv(), 5, &mut out);
        assert_eq!(bi.work.bucket_lookups, 3);
    }

    #[test]
    fn bytes_resident_is_nonzero_once_indexed() {
        let mut bi = BiState::new(0, 1, 0);
        assert_eq!(bi.bytes_resident(), 0);
        bi.on_index_ref(100, 1, 0);
        assert!(bi.bytes_resident() > 0);
    }
}
