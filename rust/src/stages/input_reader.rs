//! Input Reader (IR): reads reference objects, partitions them onto DP
//! copies (`obj_map`), and emits index references to BI copies
//! (`bucket_map`) — paper messages (i) and (ii).
//!
//! Hashing is batched through the [`Hasher`] (the compiled Pallas projection
//! kernel on the artifact path) so index build is one MXU matmul per batch
//! instead of a per-object scalar loop.

use crate::core::lsh::HashFamily;
use crate::dataflow::message::{Dest, Msg};
use crate::dataflow::metrics::WorkStats;
use crate::partition::{bucket_map, ObjMapper};
use crate::runtime::Hasher;
use crate::stages::Emit;
use std::sync::Arc;

pub struct InputReader<'a> {
    pub family: &'a HashFamily,
    pub mapper: &'a ObjMapper,
    pub n_bi: usize,
    /// Hash batch size (matches an artifact variant for zero padding waste).
    pub batch: usize,
    pub work: WorkStats,
}

impl<'a> InputReader<'a> {
    pub fn new(family: &'a HashFamily, mapper: &'a ObjMapper, n_bi: usize) -> Self {
        InputReader { family, mapper, n_bi, batch: 1024, work: WorkStats::default() }
    }

    /// Index `rows` vectors (flat `[rows*dim]`, global ids starting at
    /// `id_base`), emitting StoreObject + IndexRef messages.
    pub fn index_block(
        &mut self,
        hasher: &dyn Hasher,
        flat: &[f32],
        rows: usize,
        id_base: u32,
        out: Emit,
    ) {
        let dim = self.family.dim;
        let l = self.family.params.l;
        let mut done = 0usize;
        while done < rows {
            let take = (rows - done).min(self.batch);
            let block = &flat[done * dim..(done + take) * dim];
            let coords = hasher.hash_batch(block, take);
            let p = hasher.p();
            self.work.hash_vectors += take as u64;
            for r in 0..take {
                let id = id_base + (done + r) as u32;
                let v: Arc<[f32]> = block[r * dim..(r + 1) * dim].into();
                let dp = self.mapper.map(id, &v);
                out.push((Dest::dp(dp), Msg::StoreObject { id, v }));
                let row_coords = &coords[r * p..r * p + l * self.family.params.m];
                for t in 0..l {
                    let key = self.family.bucket_key(t, row_coords);
                    let bi = bucket_map(key, self.n_bi);
                    out.push((
                        Dest::bi(bi),
                        Msg::IndexRef { table: t as u8, key, id, dp },
                    ));
                }
            }
            done += take;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ObjMapStrategy;
    use crate::core::lsh::LshParams;
    use crate::data::synth::{synthesize, SynthSpec};
    use crate::dataflow::message::StageKind;
    use crate::runtime::ScalarHasher;

    fn setup() -> (HashFamily, ObjMapper, SynthSpec) {
        let params = LshParams { l: 3, m: 4, w: 500.0, k: 5, t: 1, seed: 2 };
        let fam = HashFamily::sample(32, params);
        let mapper = ObjMapper::new(ObjMapStrategy::Mod, 4, 32, 2);
        let spec = SynthSpec { n: 50, dim: 32, clusters: 5, ..Default::default() };
        (fam, mapper, spec)
    }

    #[test]
    fn emits_one_store_and_l_refs_per_object() {
        let (fam, mapper, spec) = setup();
        let ds = synthesize(spec);
        let hasher = ScalarHasher { family: fam.clone() };
        let mut ir = InputReader::new(&fam, &mapper, 3);
        let mut out = Vec::new();
        ir.index_block(&hasher, ds.as_flat(), ds.len(), 0, &mut out);
        let stores = out
            .iter()
            .filter(|(d, _)| d.stage == StageKind::Dp)
            .count();
        let refs = out
            .iter()
            .filter(|(d, _)| d.stage == StageKind::Bi)
            .count();
        assert_eq!(stores, 50);
        assert_eq!(refs, 50 * 3);
        assert_eq!(ir.work.hash_vectors, 50);
    }

    #[test]
    fn refs_carry_consistent_dp_and_key() {
        let (fam, mapper, spec) = setup();
        let ds = synthesize(spec);
        let hasher = ScalarHasher { family: fam.clone() };
        let mut ir = InputReader::new(&fam, &mapper, 3);
        let mut out = Vec::new();
        ir.index_block(&hasher, ds.as_flat(), ds.len(), 100, &mut out);
        for (dest, msg) in &out {
            match msg {
                Msg::StoreObject { id, v } => {
                    assert_eq!(dest.copy, mapper.map(*id, v));
                    assert!((100..150).contains(id));
                }
                Msg::IndexRef { key, id, dp, table } => {
                    // key must equal the family's key for that object/table
                    let v = ds.get((*id - 100) as usize);
                    let coords = fam.hash_coords(v);
                    assert_eq!(*key, fam.bucket_key(*table as usize, &coords));
                    assert_eq!(*dp, mapper.map(*id, v));
                    assert_eq!(dest.copy, bucket_map(*key, 3));
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn batching_is_invisible() {
        let (fam, mapper, spec) = setup();
        let ds = synthesize(spec);
        let hasher = ScalarHasher { family: fam.clone() };
        let collect = |batch: usize| {
            let mut ir = InputReader::new(&fam, &mapper, 3);
            ir.batch = batch;
            let mut out = Vec::new();
            ir.index_block(&hasher, ds.as_flat(), ds.len(), 0, &mut out);
            out.iter()
                .map(|(d, m)| format!("{d:?}|{m:?}"))
                .collect::<Vec<_>>()
        };
        assert_eq!(collect(7), collect(1024));
    }
}
