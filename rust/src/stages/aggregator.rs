//! Aggregator (AG): reduces DP-local top-k results into the global k
//! nearest neighbors per query — where `k` is the *query's own* (the
//! per-query plan carried by `QueryMeta`), not one global.
//!
//! Completion accounting: QR announces how many BI copies a query touched
//! plus its resolved `k` (`QueryMeta`), each BI announces how many DP
//! messages it emitted (`BiMeta`), and the query completes when all
//! announced `LocalTopK` messages arrived. The query id labels every
//! message, so one AG copy sees a query's entire reduction (paper: label =
//! query id).
//!
//! Ordering: on the asynchronous transports `LocalTopK` hits can arrive
//! *before* the `QueryMeta` that carries `k`. Such early hits buffer in a
//! small per-query vector and fold into the bounded [`TopK`] the moment
//! the meta lands — transient memory is bounded by the hits in flight for
//! that query (≤ n_dp · k), exactly what the channels already hold.

use crate::core::topk::TopK;
use crate::dataflow::metrics::WorkStats;
use std::collections::HashMap;

#[derive(Debug)]
struct QueryAgg {
    expect_bi: Option<u32>,
    bi_seen: u32,
    expect_dp: u64,
    dp_seen: u64,
    /// Bounded reducer, sized by the query's `k` — created when the
    /// `QueryMeta` arrives (it carries the plan).
    topk: Option<TopK>,
    /// Hits that arrived before the `QueryMeta` (asynchronous transports).
    early: Vec<(f32, u32)>,
}

/// A finished query: global top-k `(sqdist, id)` ascending.
pub type QueryResult = (u32, Vec<(f32, u32)>);

pub struct AgState {
    pub copy: u16,
    pending: HashMap<u32, QueryAgg>,
    pub results: Vec<QueryResult>,
    pub work: WorkStats,
}

impl AgState {
    pub fn new(copy: u16) -> AgState {
        AgState {
            copy,
            pending: HashMap::new(),
            results: Vec::new(),
            work: WorkStats::default(),
        }
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    fn entry(&mut self, qid: u32) -> &mut QueryAgg {
        self.pending.entry(qid).or_insert_with(|| QueryAgg {
            expect_bi: None,
            bi_seen: 0,
            expect_dp: 0,
            dp_seen: 0,
            topk: None,
            early: Vec::new(),
        })
    }

    /// The QR's announcement for `qid`: how many BI copies will contribute
    /// and the query's resolved top-k depth.
    pub fn on_query_meta(&mut self, qid: u32, n_bi: u32, k: u32) {
        let agg = self.entry(qid);
        assert!(agg.expect_bi.is_none(), "duplicate QueryMeta for {qid}");
        agg.expect_bi = Some(n_bi);
        let mut topk = TopK::new(k as usize);
        for (d, id) in agg.early.drain(..) {
            topk.push(d, id);
        }
        agg.topk = Some(topk);
        self.maybe_complete(qid);
    }

    pub fn on_bi_meta(&mut self, qid: u32, n_dp: u32) {
        let agg = self.entry(qid);
        agg.bi_seen += 1;
        agg.expect_dp += n_dp as u64;
        self.maybe_complete(qid);
    }

    pub fn on_local_topk(&mut self, qid: u32, hits: &[(f32, u32)]) {
        let agg = self.entry(qid);
        match &mut agg.topk {
            Some(topk) => {
                for &(d, id) in hits {
                    topk.push(d, id);
                }
            }
            // QueryMeta (and with it the query's k) not here yet: buffer.
            None => agg.early.extend_from_slice(hits),
        }
        agg.dp_seen += 1;
        self.work.reduce_pushes += hits.len() as u64;
        self.maybe_complete(qid);
    }

    fn maybe_complete(&mut self, qid: u32) {
        let done = {
            let agg = &self.pending[&qid];
            match agg.expect_bi {
                Some(nb) => agg.bi_seen == nb && agg.dp_seen == agg.expect_dp,
                None => false,
            }
        };
        if done {
            let agg = self.pending.remove(&qid).unwrap();
            let topk = agg.topk.expect("completed query without QueryMeta");
            self.results.push((qid, topk.into_sorted()));
        }
    }

    /// Queries stuck waiting (diagnostics / failure injection tests).
    pub fn stuck_queries(&self) -> Vec<u32> {
        self.pending.keys().copied().collect()
    }

    /// Drop any partial reduction state for a cancelled query. The qid
    /// becomes reusable (a later run may legally announce a fresh
    /// `QueryMeta` under it); unknown qids are a no-op, so callers can
    /// purge every AG copy without tracking which one owned the query.
    pub fn abort_query(&mut self, qid: u32) {
        self.pending.remove(&qid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completes_after_all_messages() {
        let mut ag = AgState::new(0);
        ag.on_query_meta(1, 2, 2);
        ag.on_bi_meta(1, 1);
        assert_eq!(ag.results.len(), 0);
        ag.on_bi_meta(1, 2);
        assert_eq!(ag.results.len(), 0);
        ag.on_local_topk(1, &[(4.0, 7)]);
        ag.on_local_topk(1, &[(1.0, 8), (9.0, 9)]);
        assert_eq!(ag.results.len(), 0);
        ag.on_local_topk(1, &[(2.0, 10)]);
        assert_eq!(ag.results.len(), 1);
        let (qid, hits) = &ag.results[0];
        assert_eq!(*qid, 1);
        assert_eq!(hits.as_slice(), &[(1.0, 8), (2.0, 10)]);
        assert_eq!(ag.pending_count(), 0);
    }

    #[test]
    fn out_of_order_messages_ok() {
        let mut ag = AgState::new(0);
        // results can arrive before the metas — the early buffer holds
        // them until QueryMeta brings the query's k
        ag.on_local_topk(5, &[(1.0, 1)]);
        ag.on_bi_meta(5, 1);
        assert!(ag.results.is_empty());
        ag.on_query_meta(5, 1, 3);
        assert_eq!(ag.results.len(), 1);
        assert_eq!(ag.results[0].1, vec![(1.0, 1)]);
    }

    #[test]
    fn per_query_k_is_honored() {
        let mut ag = AgState::new(0);
        // query 1 wants one neighbor, query 2 wants three — same stream
        ag.on_query_meta(1, 1, 1);
        ag.on_query_meta(2, 1, 3);
        ag.on_bi_meta(1, 1);
        ag.on_bi_meta(2, 1);
        ag.on_local_topk(1, &[(3.0, 30), (1.0, 10), (2.0, 20)]);
        ag.on_local_topk(2, &[(3.0, 30), (1.0, 10), (2.0, 20)]);
        assert_eq!(ag.results.len(), 2);
        let by_qid: HashMap<u32, Vec<(f32, u32)>> =
            ag.results.iter().cloned().collect();
        assert_eq!(by_qid[&1], vec![(1.0, 10)]);
        assert_eq!(by_qid[&2], vec![(1.0, 10), (2.0, 20), (3.0, 30)]);
    }

    #[test]
    fn early_hits_respect_the_late_k() {
        let mut ag = AgState::new(0);
        // hits land before the meta; k=2 must still cap the result
        ag.on_local_topk(9, &[(5.0, 50), (1.0, 10), (3.0, 30)]);
        ag.on_bi_meta(9, 1);
        ag.on_query_meta(9, 1, 2);
        assert_eq!(ag.results.len(), 1);
        assert_eq!(ag.results[0].1, vec![(1.0, 10), (3.0, 30)]);
    }

    #[test]
    fn zero_candidate_query_completes() {
        let mut ag = AgState::new(0);
        ag.on_query_meta(2, 1, 3);
        ag.on_bi_meta(2, 0); // BI found nothing
        assert_eq!(ag.results.len(), 1);
        assert!(ag.results[0].1.is_empty());
    }

    #[test]
    fn interleaved_queries_isolated() {
        let mut ag = AgState::new(0);
        ag.on_query_meta(1, 1, 1);
        ag.on_query_meta(2, 1, 1);
        ag.on_bi_meta(1, 1);
        ag.on_bi_meta(2, 1);
        ag.on_local_topk(2, &[(5.0, 50)]);
        assert_eq!(ag.results.len(), 1);
        ag.on_local_topk(1, &[(3.0, 30)]);
        assert_eq!(ag.results.len(), 2);
        let by_qid: HashMap<u32, Vec<(f32, u32)>> =
            ag.results.iter().cloned().collect();
        assert_eq!(by_qid[&1], vec![(3.0, 30)]);
        assert_eq!(by_qid[&2], vec![(5.0, 50)]);
    }

    #[test]
    #[should_panic(expected = "duplicate QueryMeta")]
    fn duplicate_meta_detected() {
        let mut ag = AgState::new(0);
        ag.on_query_meta(1, 1, 1);
        ag.on_query_meta(1, 1, 1);
    }

    #[test]
    fn stuck_queries_reported() {
        let mut ag = AgState::new(0);
        ag.on_query_meta(9, 2, 1);
        ag.on_bi_meta(9, 1);
        assert_eq!(ag.stuck_queries(), vec![9]);
    }
}
