//! Query Receiver (QR): hashes each query, generates the multi-probe
//! sequence (T probes per table), routes probe buckets to the owning BI
//! copies — paper message (iii) — and tells the Aggregator how many BI
//! copies will contribute (completion accounting).
//!
//! Probe-level aggregation (paper §IV-D): all probes of a query that route
//! to the *same* BI copy travel in one `Msg::Query`, so the message count
//! grows sublinearly in T.

use crate::core::lsh::HashFamily;
use crate::dataflow::message::{Dest, Msg};
use crate::dataflow::metrics::WorkStats;
use crate::partition::{ag_map, bucket_map};
use crate::runtime::Hasher;
use crate::stages::Emit;
use std::collections::HashMap;
use std::sync::Arc;

pub struct QueryReceiver<'a> {
    pub family: &'a HashFamily,
    pub n_bi: usize,
    pub n_ag: usize,
    pub work: WorkStats,
}

impl<'a> QueryReceiver<'a> {
    pub fn new(family: &'a HashFamily, n_bi: usize, n_ag: usize) -> Self {
        QueryReceiver { family, n_bi, n_ag, work: WorkStats::default() }
    }

    /// All probe bucket keys of a query: `(table, key)` — home bucket first
    /// per table, then the multi-probe perturbations in score order.
    /// Delegates to [`HashFamily::query_probes`] (shared with the sequential
    /// baseline so both visit exactly the same buckets).
    pub fn probe_keys(&mut self, raw: &[f32]) -> Vec<(u8, u64)> {
        self.work.probe_seqs += self.family.params.l as u64;
        self.family.query_probes(raw, self.family.params.t)
    }

    /// Emit the query to every BI copy owning at least one probe bucket,
    /// plus the AG completion meta. Returns the number of BI copies used.
    pub fn dispatch_query(
        &mut self,
        hasher: &dyn Hasher,
        qid: u32,
        q: &[f32],
        out: Emit,
    ) -> usize {
        debug_assert_eq!(q.len(), self.family.dim);
        let raw = hasher.proj_batch(q, 1);
        self.work.hash_vectors += 1;
        self.dispatch_query_raw(&raw, qid, q, out)
    }

    /// Like [`Self::dispatch_query`] but with the raw projections already
    /// computed — the batched path (§Perf): the search drivers push the
    /// whole query set through one artifact `proj` call instead of one
    /// padded call per query.
    pub fn dispatch_query_raw(
        &mut self,
        raw: &[f32],
        qid: u32,
        q: &[f32],
        out: Emit,
    ) -> usize {
        self.dispatch_query_arc(raw, qid, q.into(), out)
    }

    /// `Arc`-taking variant of [`Self::dispatch_query_raw`]: the executor
    /// workload already carries the query vector behind an `Arc`
    /// ([`Msg::QueryVec`]), so dispatching it re-uses that allocation.
    pub fn dispatch_query_arc(
        &mut self,
        raw: &[f32],
        qid: u32,
        v: Arc<[f32]>,
        out: Emit,
    ) -> usize {
        let probes = self.probe_keys(raw);
        let mut by_bi: HashMap<u16, Vec<(u8, u64)>> = HashMap::new();
        for (table, key) in probes {
            by_bi
                .entry(bucket_map(key, self.n_bi))
                .or_default()
                .push((table, key));
        }
        let n_bi = by_bi.len();
        // Deterministic dispatch order (BTreeMap-like): sort by copy.
        let mut entries: Vec<_> = by_bi.into_iter().collect();
        entries.sort_by_key(|(copy, _)| *copy);
        for (copy, probes) in entries {
            out.push((Dest::bi(copy), Msg::Query { qid, probes, v: v.clone() }));
        }
        out.push((
            Dest::ag(ag_map(qid, self.n_ag)),
            Msg::QueryMeta { qid, n_bi: n_bi as u32 },
        ));
        n_bi
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::lsh::LshParams;
    use crate::dataflow::message::StageKind;
    use crate::runtime::ScalarHasher;
    use crate::util::rng::Rng;

    fn family(t: usize) -> HashFamily {
        HashFamily::sample(
            16,
            LshParams { l: 4, m: 6, w: 8.0, k: 5, t, seed: 11 },
        )
    }

    fn rand_q(seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..16).map(|_| rng.gaussian_f32() * 10.0).collect()
    }

    #[test]
    fn probe_count_is_l_times_t() {
        let fam = family(8);
        let hasher = ScalarHasher { family: fam.clone() };
        let mut qr = QueryReceiver::new(&fam, 3, 1);
        let q = rand_q(5);
        let raw = hasher.proj_batch(&q, 1);
        let probes = qr.probe_keys(&raw);
        // M=6 gives 3^6-1 = 728 >> 8 valid sets, so exactly T per table.
        assert_eq!(probes.len(), 4 * 8);
        // home bucket of each table must be present
        for t in 0..4u8 {
            let home = fam.bucket_key(t as usize, &fam.hash_coords(&q));
            assert!(probes.contains(&(t, home)));
        }
    }

    #[test]
    fn t1_is_home_buckets_only() {
        let fam = family(1);
        let hasher = ScalarHasher { family: fam.clone() };
        let mut qr = QueryReceiver::new(&fam, 3, 1);
        let q = rand_q(6);
        let raw = hasher.proj_batch(&q, 1);
        let probes = qr.probe_keys(&raw);
        assert_eq!(probes.len(), 4);
    }

    #[test]
    fn dispatch_groups_probes_by_bi() {
        let fam = family(16);
        let hasher = ScalarHasher { family: fam.clone() };
        let mut qr = QueryReceiver::new(&fam, 3, 2);
        let q = rand_q(7);
        let mut out = Vec::new();
        let n_bi = qr.dispatch_query(&hasher, 42, &q, &mut out);
        let queries: Vec<_> = out
            .iter()
            .filter(|(d, _)| d.stage == StageKind::Bi)
            .collect();
        assert_eq!(queries.len(), n_bi);
        assert!(n_bi <= 3);
        let mut total_probes = 0;
        for (dest, msg) in &queries {
            if let Msg::Query { probes, qid, .. } = msg {
                assert_eq!(*qid, 42);
                total_probes += probes.len();
                for (_, key) in probes {
                    assert_eq!(bucket_map(*key, 3), dest.copy);
                }
            }
        }
        assert_eq!(total_probes, 4 * 16);
        // exactly one QueryMeta to the AG owning qid 42
        let metas: Vec<_> = out
            .iter()
            .filter(|(d, _)| d.stage == StageKind::Ag)
            .collect();
        assert_eq!(metas.len(), 1);
        match &metas[0].1 {
            Msg::QueryMeta { qid, n_bi: nb } => {
                assert_eq!(*qid, 42);
                assert_eq!(*nb as usize, n_bi);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(metas[0].0.copy, ag_map(42, 2));
    }

    #[test]
    fn larger_t_more_probes_weakly_more_bis() {
        let fam1 = family(1);
        let fam2 = HashFamily::sample(16, LshParams { t: 60, ..fam1.params });
        let hasher = ScalarHasher { family: fam1.clone() };
        let q = rand_q(9);
        let mut qr1 = QueryReceiver::new(&fam1, 5, 1);
        let mut qr60 = QueryReceiver::new(&fam2, 5, 1);
        let mut o1 = Vec::new();
        let mut o60 = Vec::new();
        let b1 = qr1.dispatch_query(&hasher, 0, &q, &mut o1);
        let b60 = qr60.dispatch_query(&hasher, 0, &q, &mut o60);
        assert!(b60 >= b1);
        // message count to BI grows far slower than probe count (probe
        // aggregation): at most n_bi messages regardless of T.
        assert!(b60 <= 5);
    }
}
