//! Query Receiver (QR): hashes each query, resolves its per-query search
//! plan ([`QueryOptions`] → concrete `k`/`T`/`L'` against the family
//! params), generates the multi-probe sequence (T probes over the first L'
//! tables), routes probe buckets to the owning BI copies — paper message
//! (iii) — and tells the Aggregator how many BI copies will contribute
//! plus the query's resolved `k` (completion accounting + per-qid top-k).
//!
//! Probe-level aggregation (paper §IV-D): all probes of a query that route
//! to the *same* BI copy travel in one `Msg::Query`, so the message count
//! grows sublinearly in T.
//!
//! QR is deliberately *policy-free*: the QoS scheduler's adaptive probe
//! budgets (`[qos] adaptive_probes`, DESIGN.md §QoS scheduler) are
//! resolved at session admission and arrive here as an ordinary explicit
//! `opts.probes` value, so this stage's resolution — and with it every
//! transport replaying the same wire plan — stays bit-identical whether
//! the budget came from the config, the caller, or the adaptive policy.

use crate::core::lsh::HashFamily;
use crate::dataflow::message::{Dest, Msg, QueryOptions};
use crate::dataflow::metrics::WorkStats;
use crate::partition::{ag_map, bucket_map};
use crate::runtime::Hasher;
use crate::stages::Emit;
use std::collections::HashMap;
use std::sync::Arc;

pub struct QueryReceiver<'a> {
    pub family: &'a HashFamily,
    pub n_bi: usize,
    pub n_ag: usize,
    pub work: WorkStats,
}

impl<'a> QueryReceiver<'a> {
    pub fn new(family: &'a HashFamily, n_bi: usize, n_ag: usize) -> Self {
        QueryReceiver { family, n_bi, n_ag, work: WorkStats::default() }
    }

    /// All probe bucket keys of a query for a resolved plan: `(table, key)`
    /// — home bucket first per table, then the multi-probe perturbations in
    /// score order, over the first `tables` tables only. Delegates to
    /// [`HashFamily::query_probes`] (shared with the sequential baseline so
    /// both visit exactly the same buckets).
    pub fn probe_keys(&mut self, raw: &[f32], t: usize, tables: usize) -> Vec<(u8, u64)> {
        self.work.probe_seqs += tables as u64;
        self.family.query_probes(raw, t, tables)
    }

    /// Emit the query to every BI copy owning at least one probe bucket,
    /// plus the AG completion meta. Returns the number of BI copies used.
    pub fn dispatch_query(
        &mut self,
        hasher: &dyn Hasher,
        qid: u32,
        q: &[f32],
        opts: QueryOptions,
        out: Emit,
    ) -> usize {
        debug_assert_eq!(q.len(), self.family.dim);
        let raw = hasher.proj_batch(q, 1);
        self.work.hash_vectors += 1;
        self.dispatch_query_raw(&raw, qid, q, opts, out)
    }

    /// Like [`Self::dispatch_query`] but with the raw projections already
    /// computed — the batched path (§Perf): the search drivers push the
    /// whole query set through one artifact `proj` call instead of one
    /// padded call per query.
    pub fn dispatch_query_raw(
        &mut self,
        raw: &[f32],
        qid: u32,
        q: &[f32],
        opts: QueryOptions,
        out: Emit,
    ) -> usize {
        self.dispatch_query_arc(raw, qid, q.into(), opts, out)
    }

    /// `Arc`-taking variant of [`Self::dispatch_query_raw`]: the executor
    /// workload already carries the query vector behind an `Arc`
    /// ([`Msg::QueryVec`]), so dispatching it re-uses that allocation.
    ///
    /// This is where a query's [`QueryOptions`] are resolved: zero fields
    /// inherit `family.params`, `tables` clamps into `1..=L`, and the
    /// resolved `k` rides on every downstream message so BI/DP/AG never
    /// consult a global.
    pub fn dispatch_query_arc(
        &mut self,
        raw: &[f32],
        qid: u32,
        v: Arc<[f32]>,
        opts: QueryOptions,
        out: Emit,
    ) -> usize {
        let p = self.family.params;
        let k = opts.k_or(p.k) as u32;
        let t = opts.probes_or(p.t);
        let tables = opts.tables_in(p.l);
        let probes = self.probe_keys(raw, t, tables);
        let mut by_bi: HashMap<u16, Vec<(u8, u64)>> = HashMap::new();
        for (table, key) in probes {
            by_bi
                .entry(bucket_map(key, self.n_bi))
                .or_default()
                .push((table, key));
        }
        let n_bi = by_bi.len();
        // Deterministic dispatch order (BTreeMap-like): sort by copy.
        let mut entries: Vec<_> = by_bi.into_iter().collect();
        entries.sort_by_key(|(copy, _)| *copy);
        for (copy, probes) in entries {
            out.push((Dest::bi(copy), Msg::Query { qid, probes, v: v.clone(), k }));
        }
        out.push((
            Dest::ag(ag_map(qid, self.n_ag)),
            Msg::QueryMeta { qid, n_bi: n_bi as u32, k },
        ));
        n_bi
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::lsh::LshParams;
    use crate::dataflow::message::StageKind;
    use crate::runtime::ScalarHasher;
    use crate::util::rng::Rng;

    fn family(t: usize) -> HashFamily {
        HashFamily::sample(
            16,
            LshParams { l: 4, m: 6, w: 8.0, k: 5, t, seed: 11 },
        )
    }

    fn rand_q(seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..16).map(|_| rng.gaussian_f32() * 10.0).collect()
    }

    #[test]
    fn probe_count_is_l_times_t() {
        let fam = family(8);
        let hasher = ScalarHasher { family: fam.clone() };
        let mut qr = QueryReceiver::new(&fam, 3, 1);
        let q = rand_q(5);
        let raw = hasher.proj_batch(&q, 1);
        let probes = qr.probe_keys(&raw, fam.params.t, fam.params.l);
        // M=6 gives 3^6-1 = 728 >> 8 valid sets, so exactly T per table.
        assert_eq!(probes.len(), 4 * 8);
        // home bucket of each table must be present
        for t in 0..4u8 {
            let home = fam.bucket_key(t as usize, &fam.hash_coords(&q));
            assert!(probes.contains(&(t, home)));
        }
    }

    #[test]
    fn t1_is_home_buckets_only() {
        let fam = family(1);
        let hasher = ScalarHasher { family: fam.clone() };
        let mut qr = QueryReceiver::new(&fam, 3, 1);
        let q = rand_q(6);
        let raw = hasher.proj_batch(&q, 1);
        let probes = qr.probe_keys(&raw, 1, fam.params.l);
        assert_eq!(probes.len(), 4);
    }

    #[test]
    fn dispatch_groups_probes_by_bi() {
        let fam = family(16);
        let hasher = ScalarHasher { family: fam.clone() };
        let mut qr = QueryReceiver::new(&fam, 3, 2);
        let q = rand_q(7);
        let mut out = Vec::new();
        let n_bi = qr.dispatch_query(&hasher, 42, &q, QueryOptions::default(), &mut out);
        let queries: Vec<_> = out
            .iter()
            .filter(|(d, _)| d.stage == StageKind::Bi)
            .collect();
        assert_eq!(queries.len(), n_bi);
        assert!(n_bi <= 3);
        let mut total_probes = 0;
        for (dest, msg) in &queries {
            if let Msg::Query { probes, qid, k, .. } = msg {
                assert_eq!(*qid, 42);
                assert_eq!(*k, fam.params.k as u32, "inherited k resolved wrong");
                total_probes += probes.len();
                for (_, key) in probes {
                    assert_eq!(bucket_map(*key, 3), dest.copy);
                }
            }
        }
        assert_eq!(total_probes, 4 * 16);
        // exactly one QueryMeta to the AG owning qid 42, carrying k
        let metas: Vec<_> = out
            .iter()
            .filter(|(d, _)| d.stage == StageKind::Ag)
            .collect();
        assert_eq!(metas.len(), 1);
        match &metas[0].1 {
            Msg::QueryMeta { qid, n_bi: nb, k } => {
                assert_eq!(*qid, 42);
                assert_eq!(*nb as usize, n_bi);
                assert_eq!(*k, fam.params.k as u32);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(metas[0].0.copy, ag_map(42, 2));
    }

    #[test]
    fn per_query_options_shrink_the_plan() {
        let fam = family(16);
        let hasher = ScalarHasher { family: fam.clone() };
        let q = rand_q(7);
        // explicit T=1, L'=2, k=2 — a cheap low-recall plan
        let opts = QueryOptions { k: 2, probes: 1, tables: 2, tag: 5 };
        let mut qr = QueryReceiver::new(&fam, 3, 1);
        let mut out = Vec::new();
        qr.dispatch_query(&hasher, 1, &q, opts, &mut out);
        let mut total_probes = 0usize;
        for (_, msg) in &out {
            match msg {
                Msg::Query { probes, k, .. } => {
                    assert_eq!(*k, 2);
                    assert!(probes.iter().all(|&(t, _)| t < 2), "table past L'");
                    total_probes += probes.len();
                }
                Msg::QueryMeta { k, .. } => assert_eq!(*k, 2),
                other => panic!("unexpected {other:?}"),
            }
        }
        // T=1 over 2 tables = exactly the two home buckets
        assert_eq!(total_probes, 2);
        assert_eq!(qr.work.probe_seqs, 2, "probe_seqs must count L', not L");
    }

    #[test]
    fn default_options_match_explicit_config_options() {
        let fam = family(8);
        let hasher = ScalarHasher { family: fam.clone() };
        let q = rand_q(9);
        let explicit = QueryOptions::from_params(&fam.params);
        let mut qr1 = QueryReceiver::new(&fam, 3, 1);
        let mut qr2 = QueryReceiver::new(&fam, 3, 1);
        let mut o1 = Vec::new();
        let mut o2 = Vec::new();
        qr1.dispatch_query(&hasher, 0, &q, QueryOptions::default(), &mut o1);
        qr2.dispatch_query(&hasher, 0, &q, explicit, &mut o2);
        let fmt = |o: &Vec<(Dest, Msg)>| {
            o.iter().map(|(d, m)| format!("{d:?}|{m:?}")).collect::<Vec<_>>()
        };
        assert_eq!(fmt(&o1), fmt(&o2));
    }

    #[test]
    fn larger_t_more_probes_weakly_more_bis() {
        let fam = family(1);
        let hasher = ScalarHasher { family: fam.clone() };
        let q = rand_q(9);
        let mut qr1 = QueryReceiver::new(&fam, 5, 1);
        let mut qr60 = QueryReceiver::new(&fam, 5, 1);
        let mut o1 = Vec::new();
        let mut o60 = Vec::new();
        let b1 = qr1.dispatch_query(&hasher, 0, &q, QueryOptions::default(), &mut o1);
        // the same family serves a T=60 plan per query — no resample needed
        let b60 = qr60.dispatch_query(
            &hasher,
            0,
            &q,
            QueryOptions { probes: 60, ..Default::default() },
            &mut o60,
        );
        assert!(b60 >= b1);
        // message count to BI grows far slower than probe count (probe
        // aggregation): at most n_bi messages regardless of T.
        assert!(b60 <= 5);
    }
}
