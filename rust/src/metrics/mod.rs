//! Serving metrics: latency percentiles and throughput reporting.

use crate::util::timer::percentile;

/// Latency summary over a sample of per-query seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyStats {
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

pub fn latency_stats(samples: &[f64]) -> LatencyStats {
    if samples.is_empty() {
        return LatencyStats::default();
    }
    let mut s: Vec<f64> = samples.to_vec();
    let mean = s.iter().sum::<f64>() / s.len() as f64;
    LatencyStats {
        mean_ms: mean * 1e3,
        p50_ms: percentile(&mut s, 50.0) * 1e3,
        p90_ms: percentile(&mut s, 90.0) * 1e3,
        p99_ms: percentile(&mut s, 99.0) * 1e3,
        max_ms: s.last().copied().unwrap_or(0.0) * 1e3,
    }
}

/// Default reservoir size of [`LatencySummary`] — plenty for stable p99
/// estimates, bounded regardless of how long a serving session lives.
const LATENCY_RESERVOIR: usize = 4096;

/// Bounded per-query latency accounting for resident serving sessions:
/// exact count / mean / max plus a fixed-size uniform reservoir (algorithm
/// R, deterministic xorshift) for percentile estimates. Replaces the
/// grows-forever per-ticket `Vec<f64>` a long-lived `parlsh serve` session
/// would otherwise leak memory into.
#[derive(Clone, Debug)]
pub struct LatencySummary {
    /// Samples recorded over the summary's lifetime (exact).
    pub count: u64,
    /// Sum of all samples, seconds (exact mean = `sum_secs / count`).
    pub sum_secs: f64,
    /// Largest sample, seconds (exact).
    pub max_secs: f64,
    /// Smallest sample, seconds (exact; 0 while empty).
    pub min_secs: f64,
    reservoir: Vec<f64>,
    rng: u64,
}

impl Default for LatencySummary {
    fn default() -> Self {
        LatencySummary::new()
    }
}

impl LatencySummary {
    pub fn new() -> LatencySummary {
        LatencySummary {
            count: 0,
            sum_secs: 0.0,
            max_secs: 0.0,
            min_secs: 0.0,
            reservoir: Vec::new(),
            rng: 0x9E37_79B9_7F4A_7C15,
        }
    }

    fn next_rand(&mut self) -> u64 {
        // xorshift64* — deterministic, dependency-free
        let mut x = self.rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Record one sample (seconds). O(1), bounded memory.
    pub fn record(&mut self, secs: f64) {
        self.count += 1;
        self.sum_secs += secs;
        if secs > self.max_secs {
            self.max_secs = secs;
        }
        if self.count == 1 || secs < self.min_secs {
            self.min_secs = secs;
        }
        if self.reservoir.len() < LATENCY_RESERVOIR {
            self.reservoir.push(secs);
        } else {
            // algorithm R: keep each of the `count` samples with equal
            // probability LATENCY_RESERVOIR / count
            let j = (self.next_rand() % self.count) as usize;
            if j < LATENCY_RESERVOIR {
                self.reservoir[j] = secs;
            }
        }
    }

    /// One reservoir percentile, in **seconds** (the raw unit `record`
    /// takes): `q` in [0, 100], e.g. `quantile(99.0)` for p99. Exact
    /// while `count` ≤ the reservoir size, an estimate beyond. The
    /// single shared percentile primitive — experiment code that needs
    /// p50/p99 off a summary calls this instead of hand-rolling sort +
    /// index math over raw sample vectors.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let mut s = self.reservoir.clone();
        percentile(&mut s, q)
    }

    /// Percentile/mean snapshot: mean and max are exact, percentiles come
    /// from the reservoir (exact too while `count` ≤ the reservoir size).
    pub fn stats(&self) -> LatencyStats {
        if self.count == 0 {
            return LatencyStats::default();
        }
        let mut s = self.reservoir.clone();
        LatencyStats {
            mean_ms: self.sum_secs / self.count as f64 * 1e3,
            p50_ms: percentile(&mut s, 50.0) * 1e3,
            p90_ms: percentile(&mut s, 90.0) * 1e3,
            p99_ms: percentile(&mut s, 99.0) * 1e3,
            max_ms: self.max_secs * 1e3,
        }
    }
}

/// Fixed-width table printer used by the experiment harness so every bench
/// emits the paper's rows in a uniform format.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        print!("{self}");
    }

    /// The table as a JSON object: `{"headers": [...], "rows": [[...]]}`.
    /// Hand-rolled (no serde in the offline-clean build); cells are
    /// escaped, so arbitrary strings are safe.
    pub fn to_json(&self) -> String {
        let cells = |row: &[String]| -> String {
            let quoted: Vec<String> =
                row.iter().map(|c| format!("\"{}\"", json_escape(c))).collect();
            format!("[{}]", quoted.join(","))
        };
        let rows: Vec<String> = self.rows.iter().map(|r| cells(r)).collect();
        format!(
            "{{\"headers\":{},\"rows\":[{}]}}",
            cells(&self.headers),
            rows.join(",")
        )
    }

    /// Write the table as `{"experiment": name, "table": {...}}` — the
    /// machine-readable record the `BENCH_*.json` files keep so bench
    /// trajectories are recorded instead of print-only.
    pub fn write_json(&self, path: &str, experiment: &str) -> std::io::Result<()> {
        let doc = format!(
            "{{\"experiment\":\"{}\",\"table\":{}}}\n",
            json_escape(experiment),
            self.to_json()
        );
        std::fs::write(path, doc)
    }
}

/// The fixed-width rendering (`to_string()` comes via `Display`, so the
/// printer is not an inherent shadow of `ToString`).
impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |f: &mut std::fmt::Formatter<'_>,
                       cells: &[String]|
         -> std::fmt::Result {
            write!(f, "|")?;
            for (c, w) in cells.iter().zip(&widths) {
                write!(f, " {:>w$} |", c, w = w)?;
            }
            writeln!(f)
        };
        fmt_row(&mut *f, &self.headers)?;
        write!(f, "|")?;
        for w in &widths {
            write!(f, "{:-<w$}|", "", w = w + 2)?;
        }
        writeln!(f)?;
        for row in &self.rows {
            fmt_row(&mut *f, row)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------- JSON readback
//
// The `BENCH_*.json` documents this crate writes are read back by
// `parlsh experiment history` to diff bench trajectories across archived
// runs. The build is serde-free (offline-clean), so the readers below are
// hand-rolled against exactly the shape `Table::to_json` emits.

fn skip_ws(b: &[u8], mut i: usize) -> usize {
    while i < b.len() && b[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// Parse one JSON string literal starting at `at` (which must point at the
/// opening quote); returns the unescaped string and the index past the
/// closing quote.
fn parse_json_string(doc: &str, at: usize) -> Option<(String, usize)> {
    let b = doc.as_bytes();
    if b.get(at) != Some(&b'"') {
        return None;
    }
    let mut out = String::new();
    let mut i = at + 1;
    while i < b.len() {
        match b[i] {
            b'"' => return Some((out, i + 1)),
            b'\\' => {
                match *b.get(i + 1)? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hex = doc.get(i + 2..i + 6)?;
                        out.push(char::from_u32(u32::from_str_radix(hex, 16).ok()?)?);
                        i += 4;
                    }
                    _ => return None,
                }
                i += 2;
            }
            _ => {
                let c = doc[i..].chars().next()?;
                out.push(c);
                i += c.len_utf8();
            }
        }
    }
    None
}

/// Parse a JSON array of strings starting at `at` (the opening bracket);
/// returns the strings and the index past the closing bracket.
fn parse_string_array(doc: &str, at: usize) -> Option<(Vec<String>, usize)> {
    let b = doc.as_bytes();
    if b.get(at) != Some(&b'[') {
        return None;
    }
    let mut i = skip_ws(b, at + 1);
    let mut out = Vec::new();
    if b.get(i) == Some(&b']') {
        return Some((out, i + 1));
    }
    loop {
        let (s, next) = parse_json_string(doc, i)?;
        out.push(s);
        i = skip_ws(b, next);
        match b.get(i)? {
            b',' => i = skip_ws(b, i + 1),
            b']' => return Some((out, i + 1)),
            _ => return None,
        }
    }
}

/// Expect `"key":` at `at`; returns the index of the value.
fn expect_key(doc: &str, at: usize, key: &str) -> Option<usize> {
    let b = doc.as_bytes();
    let (name, next) = parse_json_string(doc, at)?;
    if name != key {
        return None;
    }
    let i = skip_ws(b, next);
    if b.get(i) != Some(&b':') {
        return None;
    }
    Some(skip_ws(b, i + 1))
}

/// First `"key":"value"` occurrence anywhere in `doc`.
pub fn json_find_string(doc: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\"");
    let b = doc.as_bytes();
    let mut at = doc.find(&pat)? + pat.len();
    at = skip_ws(b, at);
    if b.get(at) != Some(&b':') {
        return None;
    }
    parse_json_string(doc, skip_ws(b, at + 1)).map(|(s, _)| s)
}

/// First `"key":<number>` occurrence anywhere in `doc`.
pub fn json_find_number(doc: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\"");
    let b = doc.as_bytes();
    let mut at = doc.find(&pat)? + pat.len();
    at = skip_ws(b, at);
    if b.get(at) != Some(&b':') {
        return None;
    }
    at = skip_ws(b, at + 1);
    let end = doc[at..]
        .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
        .map(|o| at + o)
        .unwrap_or(doc.len());
    doc[at..end].parse().ok()
}

/// Parse the `"table":{"headers":[...],"rows":[[...]]}` object out of a
/// `Table::write_json` document. Returns `(headers, rows)`, or None when
/// the document does not contain a table in that exact shape.
#[allow(clippy::type_complexity)]
pub fn table_from_json(doc: &str) -> Option<(Vec<String>, Vec<Vec<String>>)> {
    let b = doc.as_bytes();
    let key = "\"table\"";
    let mut at = doc.find(key)? + key.len();
    at = skip_ws(b, at);
    if b.get(at) != Some(&b':') {
        return None;
    }
    at = skip_ws(b, at + 1);
    if b.get(at) != Some(&b'{') {
        return None;
    }
    at = expect_key(doc, skip_ws(b, at + 1), "headers")?;
    let (headers, next) = parse_string_array(doc, at)?;
    at = skip_ws(b, next);
    if b.get(at) != Some(&b',') {
        return None;
    }
    at = expect_key(doc, skip_ws(b, at + 1), "rows")?;
    if b.get(at) != Some(&b'[') {
        return None;
    }
    at = skip_ws(b, at + 1);
    let mut rows = Vec::new();
    if b.get(at) == Some(&b']') {
        return Some((headers, rows));
    }
    loop {
        let (row, next) = parse_string_array(doc, at)?;
        rows.push(row);
        at = skip_ws(b, next);
        match b.get(at)? {
            b',' => at = skip_ws(b, at + 1),
            b']' => return Some((headers, rows)),
            _ => return None,
        }
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_stats_basic() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 / 1000.0).collect();
        let st = latency_stats(&samples);
        assert!((st.p50_ms - 50.0).abs() < 1.0);
        assert!((st.p99_ms - 99.0).abs() < 1.0);
        assert!((st.max_ms - 100.0).abs() < 1e-9);
        assert!((st.mean_ms - 50.5).abs() < 0.1);
    }

    #[test]
    fn latency_stats_empty() {
        let st = latency_stats(&[]);
        assert_eq!(st.mean_ms, 0.0);
    }

    #[test]
    fn latency_summary_is_exact_below_reservoir_size() {
        let mut s = LatencySummary::new();
        for i in 1..=100 {
            s.record(i as f64 / 1000.0);
        }
        assert_eq!(s.count, 100);
        let st = s.stats();
        let exact = latency_stats(&(1..=100).map(|i| i as f64 / 1000.0).collect::<Vec<_>>());
        assert!((st.p50_ms - exact.p50_ms).abs() < 1e-9);
        assert!((st.p99_ms - exact.p99_ms).abs() < 1e-9);
        assert!((st.mean_ms - exact.mean_ms).abs() < 1e-9);
        assert!((st.max_ms - exact.max_ms).abs() < 1e-9);
        assert!((s.min_secs - 0.001).abs() < 1e-12);
    }

    #[test]
    fn latency_summary_memory_stays_bounded() {
        let mut s = LatencySummary::new();
        for i in 0..100_000u64 {
            s.record((i % 97) as f64 * 1e-4);
        }
        assert_eq!(s.count, 100_000);
        assert!(s.reservoir.len() <= LATENCY_RESERVOIR);
        let st = s.stats();
        // exact counters unaffected by sampling
        assert!((st.max_ms - 9.6).abs() < 1e-9);
        assert!(st.mean_ms > 0.0);
        // the reservoir percentile lands in the sample range
        assert!(st.p50_ms >= 0.0 && st.p50_ms <= st.max_ms);
        assert!(st.p99_ms <= st.max_ms && st.p99_ms >= st.p50_ms);
    }

    #[test]
    fn latency_summary_empty() {
        let s = LatencySummary::new();
        assert_eq!(s.stats().mean_ms, 0.0);
        assert_eq!(s.count, 0);
        assert_eq!(s.quantile(99.0), 0.0);
    }

    #[test]
    fn quantile_matches_stats_and_stays_in_seconds() {
        let mut s = LatencySummary::new();
        for i in 1..=200 {
            s.record(i as f64 / 1000.0);
        }
        let st = s.stats();
        // same reservoir, same percentile math — only the unit differs
        assert!((s.quantile(50.0) * 1e3 - st.p50_ms).abs() < 1e-9);
        assert!((s.quantile(99.0) * 1e3 - st.p99_ms).abs() < 1e-9);
        assert!(s.quantile(0.0) <= s.quantile(100.0));
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new(&["T", "time(s)"]);
        t.row(&["1".into(), "2.5".into()]);
        t.row(&["30".into(), "10.25".into()]);
        let s = t.to_string();
        assert!(s.contains(" T |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn table_to_json() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["with \"quote\"".into(), "1.5".into()]);
        let j = t.to_json();
        assert_eq!(
            j,
            "{\"headers\":[\"name\",\"value\"],\"rows\":[[\"with \\\"quote\\\"\",\"1.5\"]]}"
        );
        assert_eq!(json_escape("a\nb\\"), "a\\nb\\\\");
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn table_json_roundtrips_through_the_readback_parser() {
        let mut t = Table::new(&["executor", "q/s", "with \"quote\""]);
        t.row(&["inline".into(), "120.5".into(), "a\nb".into()]);
        t.row(&["threaded W=8".into(), "410.0".into(), "c\\d".into()]);
        // as archived: extra keys stamped in front of / behind the table
        let doc = format!(
            "{{\"sha\":\"abc123\",\"recorded_unix\":1753,\"experiment\":\"executors\",\"table\":{},\"extra\":{{}}}}",
            t.to_json()
        );
        let (headers, rows) = table_from_json(&doc).expect("parse");
        assert_eq!(headers, vec!["executor", "q/s", "with \"quote\""]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], vec!["inline", "120.5", "a\nb"]);
        assert_eq!(rows[1], vec!["threaded W=8", "410.0", "c\\d"]);
        assert_eq!(json_find_string(&doc, "sha").as_deref(), Some("abc123"));
        assert_eq!(json_find_string(&doc, "experiment").as_deref(), Some("executors"));
        assert_eq!(json_find_number(&doc, "recorded_unix"), Some(1753.0));
    }

    #[test]
    fn table_json_readback_handles_empty_tables() {
        let t = Table::new(&["a"]);
        let doc = format!("{{\"table\":{}}}", t.to_json());
        let (headers, rows) = table_from_json(&doc).expect("parse");
        assert_eq!(headers, vec!["a"]);
        assert!(rows.is_empty());
        assert!(table_from_json("{\"no_table\":1}").is_none());
    }
}
