//! Serving metrics: latency percentiles and throughput reporting.

use crate::util::timer::percentile;

/// Latency summary over a sample of per-query seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyStats {
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

pub fn latency_stats(samples: &[f64]) -> LatencyStats {
    if samples.is_empty() {
        return LatencyStats::default();
    }
    let mut s: Vec<f64> = samples.to_vec();
    let mean = s.iter().sum::<f64>() / s.len() as f64;
    LatencyStats {
        mean_ms: mean * 1e3,
        p50_ms: percentile(&mut s, 50.0) * 1e3,
        p90_ms: percentile(&mut s, 90.0) * 1e3,
        p99_ms: percentile(&mut s, 99.0) * 1e3,
        max_ms: s.last().copied().unwrap_or(0.0) * 1e3,
    }
}

/// Fixed-width table printer used by the experiment harness so every bench
/// emits the paper's rows in a uniform format.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:>w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }

    /// The table as a JSON object: `{"headers": [...], "rows": [[...]]}`.
    /// Hand-rolled (no serde in the offline-clean build); cells are
    /// escaped, so arbitrary strings are safe.
    pub fn to_json(&self) -> String {
        let cells = |row: &[String]| -> String {
            let quoted: Vec<String> =
                row.iter().map(|c| format!("\"{}\"", json_escape(c))).collect();
            format!("[{}]", quoted.join(","))
        };
        let rows: Vec<String> = self.rows.iter().map(|r| cells(r)).collect();
        format!(
            "{{\"headers\":{},\"rows\":[{}]}}",
            cells(&self.headers),
            rows.join(",")
        )
    }

    /// Write the table as `{"experiment": name, "table": {...}}` — the
    /// machine-readable record the `BENCH_*.json` files keep so bench
    /// trajectories are recorded instead of print-only.
    pub fn write_json(&self, path: &str, experiment: &str) -> std::io::Result<()> {
        let doc = format!(
            "{{\"experiment\":\"{}\",\"table\":{}}}\n",
            json_escape(experiment),
            self.to_json()
        );
        std::fs::write(path, doc)
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_stats_basic() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 / 1000.0).collect();
        let st = latency_stats(&samples);
        assert!((st.p50_ms - 50.0).abs() < 1.0);
        assert!((st.p99_ms - 99.0).abs() < 1.0);
        assert!((st.max_ms - 100.0).abs() < 1e-9);
        assert!((st.mean_ms - 50.5).abs() < 0.1);
    }

    #[test]
    fn latency_stats_empty() {
        let st = latency_stats(&[]);
        assert_eq!(st.mean_ms, 0.0);
    }

    #[test]
    fn table_formats() {
        let mut t = Table::new(&["T", "time(s)"]);
        t.row(&["1".into(), "2.5".into()]);
        t.row(&["30".into(), "10.25".into()]);
        let s = t.to_string();
        assert!(s.contains(" T |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn table_to_json() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["with \"quote\"".into(), "1.5".into()]);
        let j = t.to_json();
        assert_eq!(
            j,
            "{\"headers\":[\"name\",\"value\"],\"rows\":[[\"with \\\"quote\\\"\",\"1.5\"]]}"
        );
        assert_eq!(json_escape("a\nb\\"), "a\\nb\\\\");
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into()]);
    }
}
