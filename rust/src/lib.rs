//! # parlsh — distributed multi-probe LSH for billion-scale similarity search
//!
//! Reproduction of *"Scalable Locality-Sensitive Hashing for Similarity Search
//! in High-Dimensional, Large-Scale Multimedia Datasets"* (Teixeira, Teodoro,
//! Valle, Saltz — 2013).
//!
//! The paper parallelizes (multi-probe) LSH over a distributed-memory cluster
//! as an asynchronous dataflow of five stages — Input Reader (IR), Query
//! Receiver (QR), Bucket Index (BI), Data Points (DP), Aggregator (AG) —
//! connected by *labeled streams* whose tags route messages to stage copies.
//! Buckets store `(object id, DP copy)` references only (no data
//! replication); one multithreaded stage copy runs per node (intra-stage
//! parallelism) so the dataset is partitioned per *node*, not per core.
//!
//! This crate implements the full system:
//!
//! * [`core`] — p-stable hashing, bucket keying, multi-probe sequences,
//!   Z-order curves, top-k;
//! * [`data`] — synthetic clustered SIFT-like datasets, BIGANN file IO,
//!   ground truth and recall;
//! * [`dataflow`] — labeled streams, message aggregation, exact per-link
//!   traffic accounting, and the transport-agnostic executor seam
//!   ([`dataflow::exec`]): the same five stage handlers run on the
//!   deterministic inline FIFO executor (the differential-testing oracle)
//!   or the threaded executor (thread per stage copy, typed shutdown,
//!   closed-loop batched query admission via `Config::stream.inflight`) —
//!   for **both** index build and search (DESIGN.md §Executor seam);
//! * [`stages`] + [`coordinator`] — the five paper stages and the serving
//!   API (DESIGN.md §Service API): a persistent [`IndexSession`] holds the
//!   index resident on one executor and exposes incremental `insert`,
//!   streaming `submit`/`recv` query admission with [`QueryTicket`]s —
//!   including per-query search plans via
//!   [`submit_with`](coordinator::session::IndexSession::submit_with) and
//!   [`QueryOptions`] (per-request `k`, probe budget `T`, table count
//!   `L'`, opaque `tag`, echoed per ticket on
//!   [`recv_full`](coordinator::session::IndexSession::recv_full)) — live
//!   `stats` and a typed `close`; the one-shot phase calls
//!   (`build_index[_on]`, `search[_on]`) are thin wrappers over it;
//! * [`partition`] — mod / Z-order / LSH `obj_map` + `bucket_map` strategies;
//! * [`net`] — the socket transport: a `SocketExecutor` running the same
//!   pipeline across real OS processes (`parlsh worker`) over TCP, with a
//!   versioned wire codec and measured (not modeled) per-link bytes
//!   (DESIGN.md §Transports), plus the poll-based serving front door
//!   ([`net::front`]): `parlsh serve --listen` multiplexes external
//!   clients onto one resident session, `parlsh query --connect` (or the
//!   [`net::front::Client`] struct) drives it (DESIGN.md §Front door);
//! * [`store`] — the cache-conscious storage engine under BI and DP: the
//!   arena bucket directory, the exact per-query candidate bitmap behind
//!   bucket-level pruning, and the SoA row index (DESIGN.md §Storage
//!   engine);
//! * [`qos`] — the multi-tenant scheduler (DESIGN.md §QoS scheduler):
//!   `[qos] tags` weight classes with weighted-fair admission shares over
//!   `stream.pending_cap`, per-tag latency/work accounting in
//!   [`SessionStats`], and mmLSH-style adaptive per-query probe budgets;
//! * [`simnet`] — the calibrated cluster cost model standing in for the
//!   paper's 60-node InfiniBand testbed (see DESIGN.md §Substitutions);
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas artifacts
//!   (hashing + candidate ranking) on the serving hot path;
//! * [`baseline`] — sequential LSH and exact search comparators.
//!
//! Python/JAX runs only at build time (`make artifacts`); serving is pure
//! rust + compiled HLO.

pub mod baseline;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod dataflow;
pub mod experiments;
pub mod metrics;
pub mod net;
pub mod partition;
pub mod qos;
pub mod runtime;
pub mod simnet;
pub mod stages;
pub mod store;
pub mod util;

pub use config::Config;
pub use core::lsh::{HashFamily, LshParams};
pub use coordinator::session::{IndexSession, QueryTicket, SessionStats};
pub use coordinator::{build_index, search, Cluster};
pub use data::Dataset;
pub use dataflow::message::QueryOptions;
