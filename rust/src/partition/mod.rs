//! Data partition strategies (paper §IV-C): the labeled-stream mapping
//! functions `obj_map` (object → DP copy) and `bucket_map` (bucket key → BI
//! copy), plus load-imbalance accounting.
//!
//! * `mod` — `obj_id mod n_dp`; perfectly balanced, ignores locality.
//! * `zorder` — Z-order curve key range-scaled onto copies; points close in
//!   space land on the same copy with high probability.
//! * `lsh` — an *independent* LSH g-function (different seed from the index
//!   tables); points that tend to co-occur in index buckets tend to map to
//!   the same DP copy, which is exactly what shrinks BI→DP fan-out.

use crate::config::ObjMapStrategy;
use crate::core::lsh::{HashFamily, LshParams};
use crate::core::zorder::zorder_key;
use crate::util::rng::mix64;

/// Partition function for objects (the `obj_map` of the labeled stream
/// IR→DP, reused by QR→BI routing for probe ownership).
pub struct ObjMapper {
    strategy: ObjMapStrategy,
    n_dp: usize,
    /// Value range for z-order quantization.
    zlo: f32,
    zhi: f32,
    /// Small independent family for the `lsh` strategy.
    part_family: Option<HashFamily>,
}

impl ObjMapper {
    pub fn new(strategy: ObjMapStrategy, n_dp: usize, dim: usize, seed: u64) -> ObjMapper {
        assert!(n_dp > 0);
        let part_family = if strategy == ObjMapStrategy::Lsh {
            // One table whose granularity targets the *cluster* scale: each
            // partition bucket should hold one tight neighborhood (so
            // co-retrieved points share a DP copy) while the number of
            // distinct buckets stays >> n_dp (so `key mod n_dp` balances by
            // the law of large numbers — the paper's 1.8% imbalance at 10^9
            // points is exactly this effect at scale). w ≈ the projection
            // spread of a SIFT neighborhood (σ≈12/coord × √128 ≈ 135,
            // times a few) and m=4 keeps per-bucket populations small
            // without shattering neighborhoods.
            Some(HashFamily::sample(
                dim,
                LshParams { l: 1, m: 6, w: 700.0, k: 0, t: 1, seed: seed ^ 0x9A27_71 },
            ))
        } else {
            None
        };
        ObjMapper { strategy, n_dp, zlo: 0.0, zhi: 256.0, part_family }
    }

    pub fn strategy(&self) -> ObjMapStrategy {
        self.strategy
    }

    /// DP copy for object `(id, v)`.
    #[inline]
    pub fn map(&self, id: u32, v: &[f32]) -> u16 {
        let copy = match self.strategy {
            ObjMapStrategy::Mod => id as usize % self.n_dp,
            ObjMapStrategy::ZOrder => {
                let z = zorder_key(v, self.zlo, self.zhi);
                ((z as u128 * self.n_dp as u128) >> 64) as usize
            }
            ObjMapStrategy::Lsh => {
                let fam = self.part_family.as_ref().unwrap();
                let key = fam.bucket_keys(v)[0];
                (key % self.n_dp as u64) as usize
            }
        };
        copy as u16
    }
}

/// `bucket_map`: bucket key → BI copy. Keys are already uniformly mixed
/// (splitmix64-finalized), so a plain mod is both balanced and deterministic
/// — this matches the paper's `bucket value mod copies`.
#[inline]
pub fn bucket_map(key: u64, n_bi: usize) -> u16 {
    debug_assert!(n_bi > 0);
    (key % n_bi as u64) as u16
}

/// `ag_map`: query id → AG copy (paper: label = query id so all messages of
/// one query reduce at the same copy).
#[inline]
pub fn ag_map(qid: u32, n_ag: usize) -> u16 {
    debug_assert!(n_ag > 0);
    (mix64(qid as u64) % n_ag as u64) as u16
}

/// Load-imbalance report for a partition assignment (paper §V-E: deviation
/// of per-copy object counts from the mean).
#[derive(Clone, Debug)]
pub struct ImbalanceReport {
    pub counts: Vec<usize>,
    /// (max - mean) / mean, in percent — the paper's headline number.
    pub max_over_mean_pct: f64,
    /// Coefficient of variation, percent (stddev / mean).
    pub cv_pct: f64,
}

pub fn imbalance(counts: &[usize]) -> ImbalanceReport {
    assert!(!counts.is_empty());
    let n: usize = counts.iter().sum();
    let mean = n as f64 / counts.len() as f64;
    let max = *counts.iter().max().unwrap() as f64;
    let var = counts
        .iter()
        .map(|&c| (c as f64 - mean).powi(2))
        .sum::<f64>()
        / counts.len() as f64;
    ImbalanceReport {
        counts: counts.to_vec(),
        max_over_mean_pct: if mean > 0.0 { (max - mean) / mean * 100.0 } else { 0.0 },
        cv_pct: if mean > 0.0 { var.sqrt() / mean * 100.0 } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synthesize, SynthSpec};
    use crate::data::sqdist;

    #[test]
    fn mod_is_perfectly_balanced() {
        let mapper = ObjMapper::new(ObjMapStrategy::Mod, 8, 128, 1);
        let v = vec![0f32; 128];
        let mut counts = vec![0usize; 8];
        for id in 0..8000u32 {
            counts[mapper.map(id, &v) as usize] += 1;
        }
        let rep = imbalance(&counts);
        assert_eq!(rep.max_over_mean_pct, 0.0);
    }

    #[test]
    fn all_strategies_in_range() {
        let ds = synthesize(SynthSpec { n: 2_000, ..Default::default() });
        for strat in [ObjMapStrategy::Mod, ObjMapStrategy::ZOrder, ObjMapStrategy::Lsh] {
            let mapper = ObjMapper::new(strat, 7, 128, 3);
            for i in 0..ds.len() {
                let c = mapper.map(i as u32, ds.get(i));
                assert!((c as usize) < 7, "{strat:?} out of range");
            }
        }
    }

    #[test]
    fn locality_strategies_group_near_points() {
        // Near-duplicate pairs should land on the same DP copy far more
        // often under zorder/lsh than under mod.
        let ds = synthesize(SynthSpec { n: 4_000, clusters: 100, ..Default::default() });
        let (qs, bases) = crate::data::synth::distorted_queries(&ds, 400, 2.0, 5);
        let score = |strat: ObjMapStrategy| -> usize {
            let mapper = ObjMapper::new(strat, 8, 128, 3);
            (0..qs.len())
                .filter(|&i| {
                    let b = bases[i] as usize;
                    // sanity: the pair really is near
                    debug_assert!(sqdist(qs.get(i), ds.get(b)) < 1e6);
                    mapper.map(u32::MAX, qs.get(i)) == mapper.map(bases[i], ds.get(b))
                })
                .count()
        };
        let m = score(ObjMapStrategy::Mod);
        let z = score(ObjMapStrategy::ZOrder);
        let l = score(ObjMapStrategy::Lsh);
        // mod: ~1/8 chance (id-based, near-random for random id pairing)
        assert!(z > m, "zorder {z} <= mod {m}");
        assert!(l > m * 2, "lsh {l} <= 2*mod {m}");
    }

    #[test]
    fn bucket_map_balanced_on_mixed_keys() {
        let mut counts = vec![0usize; 10];
        for i in 0..100_000u64 {
            counts[bucket_map(mix64(i), 10) as usize] += 1;
        }
        let rep = imbalance(&counts);
        assert!(rep.max_over_mean_pct < 2.0, "{:?}", rep.max_over_mean_pct);
    }

    #[test]
    fn ag_map_spreads_queries() {
        let mut counts = vec![0usize; 4];
        for q in 0..10_000u32 {
            counts[ag_map(q, 4) as usize] += 1;
        }
        assert!(imbalance(&counts).max_over_mean_pct < 5.0);
    }

    #[test]
    fn imbalance_math() {
        let rep = imbalance(&[10, 10, 10, 10]);
        assert_eq!(rep.max_over_mean_pct, 0.0);
        let rep = imbalance(&[20, 10, 10, 0]);
        assert!((rep.max_over_mean_pct - 100.0).abs() < 1e-9);
    }
}
