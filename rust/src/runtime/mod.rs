//! Compute runtime: the two hot-spots — hashing and candidate ranking —
//! behind the [`Hasher`] / [`Ranker`] traits the stages program against.
//!
//! Three implementations of each trait:
//! * `Scalar*` — pure rust; the differential-testing oracle;
//! * `Simd*` ([`kernels`]) — `std::arch` SIMD with one-time runtime
//!   dispatch (AVX2/SSE2/NEON/scalar), bit-identical to the oracle and
//!   the production default (DESIGN.md §Kernels);
//! * [`engine::Engine`] — AOT-compiled HLO via `PjRtClient::cpu()`;
//!   artifacts come in fixed shape variants (see `python/compile/aot.py`)
//!   and inputs are padded up to the nearest variant.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod engine;
/// Stub engine when built without the `pjrt` feature (no `xla` crate):
/// `Engine::load` always errors, so every driver falls back to the scalar
/// path. The API surface matches the real engine.
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;
pub mod kernels;

pub use kernels::{SimdHasher, SimdRanker, Tier};

use crate::core::lsh::HashFamily;
use crate::core::topk::TopK;
use crate::data::sqdist;

/// Batched LSH projection/quantization.
pub trait Hasher: Send + Sync {
    fn dim(&self) -> usize;
    /// Projection count P.
    fn p(&self) -> usize;
    /// Quantized coordinates for `rows` vectors (flat `[rows*dim]` input,
    /// flat `[rows*P]` output).
    fn hash_batch(&self, x: &[f32], rows: usize) -> Vec<i32>;
    /// Raw projections (the multi-probe path needs fractional parts).
    fn proj_batch(&self, x: &[f32], rows: usize) -> Vec<f32>;
}

/// Candidate ranking: squared distances + top-k.
pub trait Ranker: Send + Sync {
    /// Rank `n` candidate vectors (flat `[n*dim]`) against query `q`;
    /// return up to `k` `(sqdist, local_index)` pairs ascending.
    fn rank(&self, q: &[f32], cands: &[f32], n: usize, k: usize) -> Vec<(f32, u32)>;

    /// Like [`Self::rank`], but implementations may early-abandon
    /// candidates whose partial distance already exceeds the running
    /// k-th-best bound (Jafari et al., arXiv 1912.07101); the second
    /// element counts candidates abandoned early
    /// (`WorkStats::dists_pruned`). Pruning must not change the returned
    /// pairs — [`kernels::SimdRanker`] guarantees this by checking a
    /// strict bound at lane-blocked boundaries only. The default is the
    /// plain non-pruning `rank`, so existing implementations stay valid
    /// oracles.
    fn rank_pruned(
        &self,
        q: &[f32],
        cands: &[f32],
        n: usize,
        k: usize,
    ) -> (Vec<(f32, u32)>, u64) {
        (self.rank(q, cands, n, k), 0)
    }

    /// Rank candidates addressed as *row indices* into a flat SoA `store`
    /// (`rows[i]` names the vector at `store[rows[i]*dim..]`): the DP hot
    /// path after the storage-engine refactor (DESIGN.md §Storage engine),
    /// where candidate vectors are read in place instead of being copied
    /// into a gather buffer first. Returned `(sqdist, local_index)` pairs
    /// index into `rows`; the second element counts early-abandoned
    /// candidates, exactly as in [`Self::rank_pruned`]. Must be
    /// bit-identical to gathering the rows and calling `rank_pruned` — the
    /// default does literally that, so existing implementations stay valid
    /// oracles.
    fn rank_rows(
        &self,
        q: &[f32],
        store: &[f32],
        dim: usize,
        rows: &[u32],
        k: usize,
    ) -> (Vec<(f32, u32)>, u64) {
        let mut gathered = Vec::with_capacity(rows.len() * dim);
        for &r in rows {
            let at = r as usize * dim;
            gathered.extend_from_slice(&store[at..at + dim]);
        }
        self.rank_pruned(q, &gathered, rows.len(), k)
    }
}

/// Scalar hasher backed by the sampled family (same math as the artifact).
pub struct ScalarHasher {
    pub family: HashFamily,
}

impl Hasher for ScalarHasher {
    fn dim(&self) -> usize {
        self.family.dim
    }
    fn p(&self) -> usize {
        self.family.params.projections()
    }
    fn hash_batch(&self, x: &[f32], rows: usize) -> Vec<i32> {
        // Write-into-slice loop: one scratch per *batch*, not a pair of
        // fresh Vecs per row like hash_coords would allocate.
        let dim = self.family.dim;
        let p = self.p();
        let mut out = vec![0i32; rows * p];
        let mut scratch = vec![0f32; p];
        for r in 0..rows {
            self.family.coords_into(
                &x[r * dim..(r + 1) * dim],
                &mut scratch,
                &mut out[r * p..(r + 1) * p],
            );
        }
        out
    }
    fn proj_batch(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let dim = self.family.dim;
        let p = self.p();
        let mut out = vec![0f32; rows * p];
        for r in 0..rows {
            self.family
                .proj_into(&x[r * dim..(r + 1) * dim], &mut out[r * p..(r + 1) * p]);
        }
        out
    }
}

/// Scalar ranker (4-way unrolled sqdist + heap top-k).
pub struct ScalarRanker {
    pub dim: usize,
}

impl Ranker for ScalarRanker {
    fn rank(&self, q: &[f32], cands: &[f32], n: usize, k: usize) -> Vec<(f32, u32)> {
        debug_assert!(cands.len() >= n * self.dim);
        let mut tk = TopK::new(k);
        for i in 0..n {
            let c = &cands[i * self.dim..(i + 1) * self.dim];
            tk.push(sqdist(q, c), i as u32);
        }
        tk.into_sorted()
    }

    fn rank_rows(
        &self,
        q: &[f32],
        store: &[f32],
        dim: usize,
        rows: &[u32],
        k: usize,
    ) -> (Vec<(f32, u32)>, u64) {
        // Same sqdist/TopK sequence as gather-then-rank, reading each row
        // in place — bit-identical by construction, no copy.
        debug_assert_eq!(dim, self.dim);
        let mut tk = TopK::new(k);
        for (i, &r) in rows.iter().enumerate() {
            let at = r as usize * dim;
            tk.push(sqdist(q, &store[at..at + dim]), i as u32);
        }
        (tk.into_sorted(), 0)
    }
}

/// Hybrid ranker: SIMD heap top-k below `threshold` candidates, compiled
/// PJRT `rank` artifact at or above it.
///
/// §Perf rationale (EXPERIMENTS.md): the artifact path pays a fixed PJRT
/// dispatch plus a full `sort` (the only top-k lowering xla_extension 0.5.1
/// parses), so on the CPU backend the in-process heap wins until candidate
/// tiles are large; on a real TPU the MXU matmul moves the crossover far
/// left. The small-tile path is the SIMD+pruning tier (DESIGN.md
/// §Kernels), so "hybrid" now means SIMD-below / PJRT-above. The
/// threshold is env-tunable (`PARLSH_RANK_THRESHOLD`).
pub struct HybridRanker {
    pub scalar: SimdRanker,
    pub engine: Box<dyn Ranker>,
    pub threshold: usize,
}

impl HybridRanker {
    pub fn threshold_from_env(default: usize) -> usize {
        std::env::var("PARLSH_RANK_THRESHOLD")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

impl Ranker for HybridRanker {
    fn rank(&self, q: &[f32], cands: &[f32], n: usize, k: usize) -> Vec<(f32, u32)> {
        if n < self.threshold {
            self.scalar.rank(q, cands, n, k)
        } else {
            self.engine.rank(q, cands, n, k)
        }
    }

    fn rank_pruned(
        &self,
        q: &[f32],
        cands: &[f32],
        n: usize,
        k: usize,
    ) -> (Vec<(f32, u32)>, u64) {
        if n < self.threshold {
            self.scalar.rank_pruned(q, cands, n, k)
        } else {
            // the artifact ranks the whole tile at once — nothing abandons
            (self.engine.rank(q, cands, n, k), 0)
        }
    }

    fn rank_rows(
        &self,
        q: &[f32],
        store: &[f32],
        dim: usize,
        rows: &[u32],
        k: usize,
    ) -> (Vec<(f32, u32)>, u64) {
        if rows.len() < self.threshold {
            self.scalar.rank_rows(q, store, dim, rows, k)
        } else {
            // the PJRT artifact wants a contiguous tile — gather for it
            let mut gathered = Vec::with_capacity(rows.len() * dim);
            for &r in rows {
                let at = r as usize * dim;
                gathered.extend_from_slice(&store[at..at + dim]);
            }
            (self.engine.rank(q, &gathered, rows.len(), k), 0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::lsh::LshParams;

    fn hasher() -> ScalarHasher {
        ScalarHasher {
            family: HashFamily::sample(
                16,
                LshParams { l: 2, m: 4, w: 4.0, k: 5, t: 1, seed: 3 },
            ),
        }
    }

    #[test]
    fn scalar_hash_matches_family() {
        let h = hasher();
        let x: Vec<f32> = (0..32).map(|i| (i as f32).cos()).collect();
        let batch = h.hash_batch(&x, 2);
        assert_eq!(batch.len(), 16);
        assert_eq!(&batch[..8], h.family.hash_coords(&x[..16]).as_slice());
        assert_eq!(&batch[8..], h.family.hash_coords(&x[16..]).as_slice());
    }

    #[test]
    fn proj_floor_equals_hash() {
        let h = hasher();
        let x: Vec<f32> = (0..16).map(|i| (i as f32).sin() * 3.0).collect();
        let proj = h.proj_batch(&x, 1);
        let hash = h.hash_batch(&x, 1);
        for (p, c) in proj.iter().zip(&hash) {
            assert_eq!(p.floor() as i32, *c);
        }
    }

    #[test]
    fn scalar_ranker_orders() {
        let r = ScalarRanker { dim: 4 };
        let q = [0f32; 4];
        let cands = [
            1.0, 0.0, 0.0, 0.0, // d=1
            3.0, 0.0, 0.0, 0.0, // d=9
            2.0, 0.0, 0.0, 0.0, // d=4
        ];
        let out = r.rank(&q, &cands, 3, 2);
        assert_eq!(out, vec![(1.0, 0), (4.0, 2)]);
    }
}
