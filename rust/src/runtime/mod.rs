//! PJRT runtime: loads the AOT-compiled HLO artifacts and exposes the two
//! compute hot-spots — hashing and candidate ranking — behind the
//! [`Hasher`] / [`Ranker`] traits the stages program against.
//!
//! Two implementations of each trait:
//! * `Scalar*` — pure rust; the differential-testing oracle and the
//!   fallback when `artifacts/` is absent;
//! * [`engine::Engine`] — compiled HLO via `PjRtClient::cpu()`; artifacts
//!   come in fixed shape variants (see `python/compile/aot.py`) and inputs
//!   are padded up to the nearest variant.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod engine;
/// Stub engine when built without the `pjrt` feature (no `xla` crate):
/// `Engine::load` always errors, so every driver falls back to the scalar
/// path. The API surface matches the real engine.
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;

use crate::core::lsh::HashFamily;
use crate::core::topk::TopK;
use crate::data::sqdist;

/// Batched LSH projection/quantization.
pub trait Hasher: Send + Sync {
    fn dim(&self) -> usize;
    /// Projection count P.
    fn p(&self) -> usize;
    /// Quantized coordinates for `rows` vectors (flat `[rows*dim]` input,
    /// flat `[rows*P]` output).
    fn hash_batch(&self, x: &[f32], rows: usize) -> Vec<i32>;
    /// Raw projections (the multi-probe path needs fractional parts).
    fn proj_batch(&self, x: &[f32], rows: usize) -> Vec<f32>;
}

/// Candidate ranking: squared distances + top-k.
pub trait Ranker: Send + Sync {
    /// Rank `n` candidate vectors (flat `[n*dim]`) against query `q`;
    /// return up to `k` `(sqdist, local_index)` pairs ascending.
    fn rank(&self, q: &[f32], cands: &[f32], n: usize, k: usize) -> Vec<(f32, u32)>;
}

/// Scalar hasher backed by the sampled family (same math as the artifact).
pub struct ScalarHasher {
    pub family: HashFamily,
}

impl Hasher for ScalarHasher {
    fn dim(&self) -> usize {
        self.family.dim
    }
    fn p(&self) -> usize {
        self.family.params.projections()
    }
    fn hash_batch(&self, x: &[f32], rows: usize) -> Vec<i32> {
        let dim = self.family.dim;
        let mut out = Vec::with_capacity(rows * self.p());
        for r in 0..rows {
            out.extend(self.family.hash_coords(&x[r * dim..(r + 1) * dim]));
        }
        out
    }
    fn proj_batch(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let dim = self.family.dim;
        let mut out = Vec::with_capacity(rows * self.p());
        for r in 0..rows {
            out.extend(self.family.raw_projections(&x[r * dim..(r + 1) * dim]));
        }
        out
    }
}

/// Scalar ranker (4-way unrolled sqdist + heap top-k).
pub struct ScalarRanker {
    pub dim: usize,
}

impl Ranker for ScalarRanker {
    fn rank(&self, q: &[f32], cands: &[f32], n: usize, k: usize) -> Vec<(f32, u32)> {
        debug_assert!(cands.len() >= n * self.dim);
        let mut tk = TopK::new(k);
        for i in 0..n {
            let c = &cands[i * self.dim..(i + 1) * self.dim];
            tk.push(sqdist(q, c), i as u32);
        }
        tk.into_sorted()
    }
}

/// Hybrid ranker: scalar heap top-k below `threshold` candidates, compiled
/// PJRT `rank` artifact at or above it.
///
/// §Perf rationale (EXPERIMENTS.md): the artifact path pays a fixed PJRT
/// dispatch plus a full `sort` (the only top-k lowering xla_extension 0.5.1
/// parses), so on the CPU backend the scalar heap wins until candidate
/// tiles are large; on a real TPU the MXU matmul moves the crossover far
/// left. The threshold is env-tunable (`PARLSH_RANK_THRESHOLD`).
pub struct HybridRanker {
    pub scalar: ScalarRanker,
    pub engine: Box<dyn Ranker>,
    pub threshold: usize,
}

impl HybridRanker {
    pub fn threshold_from_env(default: usize) -> usize {
        std::env::var("PARLSH_RANK_THRESHOLD")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

impl Ranker for HybridRanker {
    fn rank(&self, q: &[f32], cands: &[f32], n: usize, k: usize) -> Vec<(f32, u32)> {
        if n < self.threshold {
            self.scalar.rank(q, cands, n, k)
        } else {
            self.engine.rank(q, cands, n, k)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::lsh::LshParams;

    fn hasher() -> ScalarHasher {
        ScalarHasher {
            family: HashFamily::sample(
                16,
                LshParams { l: 2, m: 4, w: 4.0, k: 5, t: 1, seed: 3 },
            ),
        }
    }

    #[test]
    fn scalar_hash_matches_family() {
        let h = hasher();
        let x: Vec<f32> = (0..32).map(|i| (i as f32).cos()).collect();
        let batch = h.hash_batch(&x, 2);
        assert_eq!(batch.len(), 16);
        assert_eq!(&batch[..8], h.family.hash_coords(&x[..16]).as_slice());
        assert_eq!(&batch[8..], h.family.hash_coords(&x[16..]).as_slice());
    }

    #[test]
    fn proj_floor_equals_hash() {
        let h = hasher();
        let x: Vec<f32> = (0..16).map(|i| (i as f32).sin() * 3.0).collect();
        let proj = h.proj_batch(&x, 1);
        let hash = h.hash_batch(&x, 1);
        for (p, c) in proj.iter().zip(&hash) {
            assert_eq!(p.floor() as i32, *c);
        }
    }

    #[test]
    fn scalar_ranker_orders() {
        let r = ScalarRanker { dim: 4 };
        let q = [0f32; 4];
        let cands = [
            1.0, 0.0, 0.0, 0.0, // d=1
            3.0, 0.0, 0.0, 0.0, // d=9
            2.0, 0.0, 0.0, 0.0, // d=4
        ];
        let out = r.rank(&q, &cands, 3, 2);
        assert_eq!(out, vec![(1.0, 0), (4.0, 2)]);
    }
}
