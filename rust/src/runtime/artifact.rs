//! Artifact manifest: the shape-variant registry written by
//! `python/compile/aot.py` (`artifacts/manifest.txt`).
//!
//! Format, one artifact per line:
//! ```text
//! hash hash_b64_p256.hlo.txt b=64 d=128 p=256
//! proj proj_b64_p256.hlo.txt b=64 d=128 p=256
//! rank rank_q1_n1024_k16.hlo.txt bq=1 n=1024 d=128 k=16
//! ```

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub kind: String,
    pub file: String,
    pub attrs: HashMap<String, usize>,
}

impl ArtifactEntry {
    pub fn attr(&self, name: &str) -> Result<usize> {
        self.attrs
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("artifact {} missing attr {name}", self.file))
    }
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let kind = parts
                .next()
                .ok_or_else(|| anyhow!("line {}: empty", i + 1))?
                .to_string();
            let file = parts
                .next()
                .ok_or_else(|| anyhow!("line {}: missing file", i + 1))?
                .to_string();
            let mut attrs = HashMap::new();
            for kv in parts {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow!("line {}: bad attr `{kv}`", i + 1))?;
                let v: usize = v
                    .parse()
                    .with_context(|| format!("line {}: attr `{kv}`", i + 1))?;
                attrs.insert(k.to_string(), v);
            }
            entries.push(ArtifactEntry { kind, file, attrs });
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(Manifest { entries })
    }

    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.txt");
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("read {path}"))?;
        Manifest::parse(&text)
    }

    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ArtifactEntry> {
        self.entries.iter().filter(move |e| e.kind == kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
hash hash_b64_p256.hlo.txt b=64 d=128 p=256
proj proj_b64_p256.hlo.txt b=64 d=128 p=256
rank rank_q1_n1024_k16.hlo.txt bq=1 n=1024 d=128 k=16
";

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 3);
        let h = &m.entries[0];
        assert_eq!(h.kind, "hash");
        assert_eq!(h.attr("b").unwrap(), 64);
        assert_eq!(h.attr("p").unwrap(), 256);
        assert!(h.attr("zz").is_err());
    }

    #[test]
    fn filters_by_kind() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.of_kind("rank").count(), 1);
        assert_eq!(m.of_kind("hash").count(), 1);
        assert_eq!(m.of_kind("nope").count(), 0);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Manifest::parse("").is_err());
        assert!(Manifest::parse("hash").is_err());
        assert!(Manifest::parse("hash f.hlo b=x").is_err());
        assert!(Manifest::parse("hash f.hlo b").is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Manifest::parse("# hi\n\nhash f.hlo b=1\n").unwrap();
        assert_eq!(m.entries.len(), 1);
    }
}
