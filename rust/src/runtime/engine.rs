//! The PJRT engine: compiled HLO artifacts on the serving hot path.
//!
//! Loads every artifact listed in `artifacts/manifest.txt`, compiles it once
//! on the CPU PJRT client, and dispatches [`Hasher`]/[`Ranker`] calls to the
//! smallest shape variant that fits (padding inputs as needed; oversized
//! candidate sets are tiled over the largest `rank` variant and merged).
//!
//! The projection bank `(A, b, 1/w)` is uploaded to device **once** per
//! family (`set_family`) and reused across every hash/proj call via
//! `execute_b` — only the data batch crosses the host↔device boundary per
//! call. This is the artifact-path analogue of the paper keeping hash
//! tables resident.

use crate::core::lsh::HashFamily;
use crate::core::topk::TopK;
use crate::runtime::artifact::Manifest;
use crate::runtime::{Hasher, Ranker};
use anyhow::{anyhow, bail, Result};
use std::sync::Mutex;

struct BankBuffers {
    a: xla::PjRtBuffer,
    b: xla::PjRtBuffer,
    inv_w: xla::PjRtBuffer,
    dim: usize,
    p: usize,
}

struct Variants {
    /// (batch rows, executable), ascending by rows.
    hash: Vec<(usize, xla::PjRtLoadedExecutable)>,
    proj: Vec<(usize, xla::PjRtLoadedExecutable)>,
    /// (bq, n, executable), ascending by (bq, n).
    rank: Vec<(usize, usize, xla::PjRtLoadedExecutable)>,
    /// top-k capacity of the rank artifacts.
    k_cap: usize,
    dim: usize,
    p: usize,
}

/// Compiled-artifact engine. Interior mutability via a single mutex: the
/// PJRT CPU client is used from whichever thread holds the lock.
pub struct Engine {
    client: xla::PjRtClient,
    variants: Variants,
    bank: Mutex<Option<BankBuffers>>,
    /// Execution counters (perf accounting).
    pub stats: Mutex<EngineStats>,
}

// SAFETY: the underlying PJRT CPU client is thread-compatible; all mutable
// use is serialized through the `bank`/`stats` mutexes and `&self` execute
// calls do not share unsynchronized host state. The engine is only ever
// driven while wrapped in `Arc<Engine>` with locking on the callers' side
// for anything stateful.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub hash_calls: u64,
    pub hash_rows: u64,
    pub hash_padded_rows: u64,
    pub rank_calls: u64,
    pub rank_rows: u64,
    pub rank_padded_rows: u64,
}

impl Engine {
    /// Load and compile all artifacts from `dir`.
    pub fn load(dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;
        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = format!("{dir}/{file}");
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {path}: {e}"))
        };

        let mut hash = Vec::new();
        let mut proj = Vec::new();
        let mut rank = Vec::new();
        let (mut dim, mut p, mut k_cap) = (0usize, 0usize, 0usize);
        for e in &manifest.entries {
            match e.kind.as_str() {
                "hash" | "proj" => {
                    let b = e.attr("b")?;
                    dim = e.attr("d")?;
                    p = e.attr("p")?;
                    let exe = compile(&e.file)?;
                    if e.kind == "hash" {
                        hash.push((b, exe));
                    } else {
                        proj.push((b, exe));
                    }
                }
                "rank" => {
                    let bq = e.attr("bq")?;
                    let n = e.attr("n")?;
                    dim = e.attr("d")?;
                    k_cap = e.attr("k")?;
                    rank.push((bq, n, compile(&e.file)?));
                }
                other => bail!("unknown artifact kind `{other}`"),
            }
        }
        hash.sort_by_key(|(b, _)| *b);
        proj.sort_by_key(|(b, _)| *b);
        rank.sort_by_key(|(bq, n, _)| (*bq, *n));
        if hash.is_empty() || rank.is_empty() {
            bail!("manifest must contain hash and rank artifacts");
        }
        Ok(Engine {
            client,
            variants: Variants { hash, proj, rank, k_cap, dim, p },
            bank: Mutex::new(None),
            stats: Mutex::new(EngineStats::default()),
        })
    }

    pub fn dim(&self) -> usize {
        self.variants.dim
    }

    pub fn k_cap(&self) -> usize {
        self.variants.k_cap
    }

    /// Upload the projection bank for `family` (device-resident thereafter).
    ///
    /// The family's L·M projections are padded to the artifact bank width P
    /// with zero rows; the extra columns produce garbage coordinates that
    /// callers slice away (`hash_batch` returns only the first L·M columns
    /// per row... actually the full P — the caller indexes `row*P + j`).
    pub fn set_family(&self, family: &HashFamily) -> Result<()> {
        if family.dim != self.variants.dim {
            bail!(
                "family dim {} != artifact dim {}",
                family.dim,
                self.variants.dim
            );
        }
        let p_used = family.params.projections();
        if p_used > self.variants.p {
            bail!("family needs P={} > artifact bank {}", p_used, self.variants.p);
        }
        let p = self.variants.p;
        let dim = self.variants.dim;
        // a_transposed is [dim][p_used]; pad columns to P.
        let at = family.a_transposed();
        let mut a_pad = vec![0f32; dim * p];
        for d in 0..dim {
            a_pad[d * p..d * p + p_used]
                .copy_from_slice(&at[d * p_used..(d + 1) * p_used]);
        }
        let mut b_pad = vec![0f32; p];
        b_pad[..p_used].copy_from_slice(family.offsets());
        let inv_w = [1.0f32 / family.params.w];

        let a = self
            .client
            .buffer_from_host_buffer(&a_pad, &[dim, p], None)
            .map_err(|e| anyhow!("upload A: {e}"))?;
        let b = self
            .client
            .buffer_from_host_buffer(&b_pad, &[p], None)
            .map_err(|e| anyhow!("upload b: {e}"))?;
        let inv_w = self
            .client
            .buffer_from_host_buffer(&inv_w, &[1, 1], None)
            .map_err(|e| anyhow!("upload inv_w: {e}"))?;
        *self.bank.lock().unwrap() = Some(BankBuffers { a, b, inv_w, dim, p });
        Ok(())
    }

    fn pick_batch(variants: &[(usize, xla::PjRtLoadedExecutable)], rows: usize) -> usize {
        for (i, (b, _)) in variants.iter().enumerate() {
            if *b >= rows {
                return i;
            }
        }
        variants.len() - 1
    }

    /// Run one bank kernel (hash or proj) over `rows` vectors, tiling by the
    /// largest variant when needed. `collect` receives (literal, rows_in_tile).
    fn run_bank<T: xla::ArrayElement + Clone + Default>(
        &self,
        proj: bool,
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<T>> {
        let variants = if proj { &self.variants.proj } else { &self.variants.hash };
        if variants.is_empty() {
            bail!("no {} artifacts loaded", if proj { "proj" } else { "hash" });
        }
        let bank = self.bank.lock().unwrap();
        let bank = bank
            .as_ref()
            .ok_or_else(|| anyhow!("set_family() must be called before hashing"))?;
        let dim = bank.dim;
        let p = bank.p;
        debug_assert!(x.len() >= rows * dim);

        let mut out: Vec<T> = Vec::with_capacity(rows * p);
        let mut done = 0usize;
        while done < rows {
            let remaining = rows - done;
            let vi = Self::pick_batch(variants, remaining);
            let (vb, exe) = (&variants[vi].0, &variants[vi].1);
            let take = remaining.min(*vb);
            // Pad the tile to the variant's batch size.
            let mut tile = vec![0f32; vb * dim];
            tile[..take * dim].copy_from_slice(&x[done * dim..(done + take) * dim]);
            let xbuf = self
                .client
                .buffer_from_host_buffer(&tile, &[*vb, dim], None)
                .map_err(|e| anyhow!("upload batch: {e}"))?;
            let res = exe
                .execute_b(&[&xbuf, &bank.a, &bank.b, &bank.inv_w])
                .map_err(|e| anyhow!("execute bank kernel: {e}"))?;
            let lit = res[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e}"))?
                .to_tuple1()
                .map_err(|e| anyhow!("untuple: {e}"))?;
            let vals: Vec<T> = lit.to_vec().map_err(|e| anyhow!("to_vec: {e}"))?;
            out.extend_from_slice(&vals[..take * p]);
            {
                let mut s = self.stats.lock().unwrap();
                s.hash_calls += 1;
                s.hash_rows += take as u64;
                s.hash_padded_rows += (*vb - take) as u64;
            }
            done += take;
        }
        Ok(out)
    }

    /// Rank `n` candidates against one query; `(sqdist, local_idx)` ascending.
    pub fn rank_one(
        &self,
        q: &[f32],
        cands: &[f32],
        n: usize,
        k: usize,
    ) -> Result<Vec<(f32, u32)>> {
        let dim = self.variants.dim;
        if k > self.variants.k_cap {
            bail!("k={k} exceeds artifact top-k capacity {}", self.variants.k_cap);
        }
        // Use bq=1 variants; tile if n exceeds the largest.
        let ones: Vec<&(usize, usize, xla::PjRtLoadedExecutable)> = self
            .variants
            .rank
            .iter()
            .filter(|(bq, _, _)| *bq == 1)
            .collect();
        if ones.is_empty() {
            bail!("no bq=1 rank artifacts");
        }
        let qlit = self
            .client
            .buffer_from_host_buffer(q, &[1, dim], None)
            .map_err(|e| anyhow!("upload q: {e}"))?;

        let mut tk = TopK::new(k);
        let mut done = 0usize;
        while done < n {
            let remaining = n - done;
            let (_, vn, exe) = ones
                .iter()
                .find(|(_, vn, _)| *vn >= remaining)
                .copied()
                .unwrap_or_else(|| *ones.last().unwrap());
            let take = remaining.min(*vn);
            let mut tile = vec![0f32; vn * dim];
            tile[..take * dim]
                .copy_from_slice(&cands[done * dim..(done + take) * dim]);
            let cbuf = self
                .client
                .buffer_from_host_buffer(&tile, &[*vn, dim], None)
                .map_err(|e| anyhow!("upload candidates: {e}"))?;
            let nv = [take as i32];
            let nvbuf = self
                .client
                .buffer_from_host_buffer(&nv, &[1, 1], None)
                .map_err(|e| anyhow!("upload n_valid: {e}"))?;
            let res = exe
                .execute_b(&[&qlit, &cbuf, &nvbuf])
                .map_err(|e| anyhow!("execute rank: {e}"))?;
            let lit = res[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch rank result: {e}"))?;
            let (dl, il) = lit.to_tuple2().map_err(|e| anyhow!("untuple rank: {e}"))?;
            let dists: Vec<f32> = dl.to_vec().map_err(|e| anyhow!("dists: {e}"))?;
            let idx: Vec<i32> = il.to_vec().map_err(|e| anyhow!("idx: {e}"))?;
            for (d, i) in dists.iter().zip(&idx).take(self.variants.k_cap) {
                if d.is_finite() {
                    tk.push(*d, done as u32 + *i as u32);
                }
            }
            {
                let mut s = self.stats.lock().unwrap();
                s.rank_calls += 1;
                s.rank_rows += take as u64;
                s.rank_padded_rows += (*vn - take) as u64;
            }
            done += take;
        }
        Ok(tk.into_sorted())
    }
}

/// [`Hasher`] over the engine (set_family must have been called).
pub struct EngineHasher {
    pub engine: std::sync::Arc<Engine>,
    /// L·M — callers only consume this many of the P bank columns.
    pub p_used: usize,
}

impl Hasher for EngineHasher {
    fn dim(&self) -> usize {
        self.engine.dim()
    }
    fn p(&self) -> usize {
        self.p_used
    }
    fn hash_batch(&self, x: &[f32], rows: usize) -> Vec<i32> {
        let full: Vec<i32> = self
            .engine
            .run_bank(false, x, rows)
            .expect("engine hash failed");
        extract_columns(&full, rows, self.engine.variants.p, self.p_used)
    }
    fn proj_batch(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let full: Vec<f32> = self
            .engine
            .run_bank(true, x, rows)
            .expect("engine proj failed");
        extract_columns(&full, rows, self.engine.variants.p, self.p_used)
    }
}

/// [`Ranker`] over the engine.
pub struct EngineRanker {
    pub engine: std::sync::Arc<Engine>,
}

impl Ranker for EngineRanker {
    fn rank(&self, q: &[f32], cands: &[f32], n: usize, k: usize) -> Vec<(f32, u32)> {
        self.engine
            .rank_one(q, cands, n, k)
            .expect("engine rank failed")
    }
}

fn extract_columns<T: Copy>(full: &[T], rows: usize, p_full: usize, p_used: usize) -> Vec<T> {
    if p_full == p_used {
        return full.to_vec();
    }
    let mut out = Vec::with_capacity(rows * p_used);
    for r in 0..rows {
        out.extend_from_slice(&full[r * p_full..r * p_full + p_used]);
    }
    out
}

#[cfg(test)]
mod tests {
    // Engine tests that need compiled artifacts live in
    // rust/tests/integration_runtime.rs (they require `make artifacts`).
    use super::extract_columns;

    #[test]
    fn extract_columns_slices_rows() {
        let full = vec![1, 2, 3, 4, 5, 6];
        assert_eq!(extract_columns(&full, 2, 3, 2), vec![1, 2, 4, 5]);
        assert_eq!(extract_columns(&full, 2, 3, 3), full);
    }
}
