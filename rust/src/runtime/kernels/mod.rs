//! SIMD kernels for the two compute hot spots — squared-L2 distance and
//! the projection-bank matmul — behind one-time runtime dispatch
//! (DESIGN.md §Kernels).
//!
//! Tiers: AVX2 (detected via `is_x86_feature_detected!`), SSE2 (the
//! x86_64 baseline, always present), NEON (the aarch64 baseline), and the
//! scalar fallback everywhere else. `PARLSH_FORCE_SCALAR=1` pins the
//! scalar tier for differential debugging. The tier is resolved once per
//! process ([`tier`]) so the per-call dispatch is a predictable branch.
//!
//! **Bit-identity contract**: every tier computes *exactly* the same f32
//! results as the scalar oracles, not approximately.
//!
//! * `sqdist` — the scalar loop in [`crate::data::sqdist`] reduces through
//!   4 independent accumulators over 4-element chunks, folded
//!   `((acc0 + acc1) + acc2) + acc3`, then a scalar remainder. SSE2/NEON
//!   keep those 4 accumulators as the 4 lanes of one vector register;
//!   AVX2 processes two 4-lane chunk halves per iteration and folds both
//!   halves into the *same* 4-lane accumulator in chunk order, so the
//!   per-lane addition sequence is unchanged.
//! * projections — [`crate::core::lsh::HashFamily::proj_into`] is a
//!   sequential single-accumulator dot per projection row. The SIMD
//!   kernels iterate the *dimension* outermost over the transposed bank
//!   (`[dim][P]`), broadcasting `v[j]` and accumulating lane-per-
//!   projection with separate mul + add (never FMA — different rounding),
//!   so each lane performs the scalar row's additions in the scalar
//!   row's order.
//!
//! Early-abandon pruning (Jafari et al., arXiv 1912.07101) rides on the
//! same contract: [`sqdist_pruned`] checks the partial sum against the
//! current k-th-best bound only at [`PRUNE_BLOCK`]-element boundaries
//! (a multiple of every tier's lane footprint), so accepted candidates'
//! reduction order — and therefore their distances — never change, and
//! prune decisions are identical across tiers. The check is strict
//! (`partial > bound`): a tie at the bound must survive, because an
//! equal-distance lower-id candidate still displaces under the
//! deterministic `(dist, id)` ordering of [`TopK`].

use crate::core::lsh::HashFamily;
use crate::core::topk::TopK;
use crate::data::sqdist as sqdist_scalar;
use crate::runtime::{Hasher, Ranker};
use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86;

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;

/// Elements per early-abandon check. A multiple of every tier's inner
/// step (scalar/SSE2/NEON: 4, AVX2: 8) so all tiers test the partial sum
/// at the same boundaries and prune identically.
pub const PRUNE_BLOCK: usize = 16;

/// The instruction tier every kernel call dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// x86_64 with AVX2 (runtime-detected).
    Avx2,
    /// x86_64 baseline (SSE2 is architecturally guaranteed).
    Sse2,
    /// aarch64 baseline (NEON is architecturally guaranteed).
    Neon,
    /// Everything else, or `PARLSH_FORCE_SCALAR=1`.
    Scalar,
}

impl Tier {
    pub fn name(self) -> &'static str {
        match self {
            Tier::Avx2 => "avx2",
            Tier::Sse2 => "sse2",
            Tier::Neon => "neon",
            Tier::Scalar => "scalar",
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn detect() -> Tier {
    if is_x86_feature_detected!("avx2") {
        Tier::Avx2
    } else {
        Tier::Sse2
    }
}

#[cfg(target_arch = "aarch64")]
fn detect() -> Tier {
    Tier::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> Tier {
    Tier::Scalar
}

/// The process-wide dispatch tier, resolved once: `PARLSH_FORCE_SCALAR=1`
/// overrides feature detection (differential debugging; DESIGN.md
/// §Kernels).
pub fn tier() -> Tier {
    static TIER: OnceLock<Tier> = OnceLock::new();
    *TIER.get_or_init(|| {
        let forced = std::env::var("PARLSH_FORCE_SCALAR")
            .map(|v| v == "1")
            .unwrap_or(false);
        if forced {
            Tier::Scalar
        } else {
            detect()
        }
    })
}

// ------------------------------------------------------------- sqdist

/// Squared L2 distance, dispatched to the detected tier. Bit-identical to
/// [`crate::data::sqdist`] on every tier.
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { x86::sqdist_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        Tier::Sse2 => unsafe { x86::sqdist_sse2(a, b) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::sqdist_neon(a, b) },
        _ => sqdist_scalar(a, b),
    }
}

/// Squared L2 distance with early abandoning: returns `None` as soon as a
/// [`PRUNE_BLOCK`]-boundary partial sum strictly exceeds `bound` (the
/// caller's current k-th-best distance), `Some(dist)` otherwise —
/// `dist` bit-identical to [`crate::data::sqdist`].
///
/// Safe under NaN (`NaN > bound` is false, so NaN distances always reach
/// the caller exactly as the oracle computes them) and under an under-full
/// top-k (`bound = +inf` never prunes). The partial sum is a monotone
/// lower bound of the final distance — squared differences are
/// non-negative and f32 addition of non-negative terms is monotone — so
/// a prune can only drop candidates the top-k would reject anyway.
#[inline]
pub fn sqdist_pruned(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
    debug_assert_eq!(a.len(), b.len());
    match tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { x86::sqdist_pruned_avx2(a, b, bound) },
        #[cfg(target_arch = "x86_64")]
        Tier::Sse2 => unsafe { x86::sqdist_pruned_sse2(a, b, bound) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::sqdist_pruned_neon(a, b, bound) },
        _ => sqdist_pruned_scalar(a, b, bound),
    }
}

/// Scalar tier of [`sqdist_pruned`]: the [`crate::data::sqdist`] loop with
/// a partial-sum check folded in at every [`PRUNE_BLOCK`] elements. The
/// fold for the check is on a *copy* of the accumulators, so the final
/// value is untouched by how often we check.
pub(crate) fn sqdist_pruned_scalar(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
    let n = a.len();
    let mut acc0 = 0f32;
    let mut acc1 = 0f32;
    let mut acc2 = 0f32;
    let mut acc3 = 0f32;
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        acc0 += d0 * d0;
        acc1 += d1 * d1;
        acc2 += d2 * d2;
        acc3 += d3 * d3;
        if (j + 4) % PRUNE_BLOCK == 0 && ((acc0 + acc1) + acc2) + acc3 > bound {
            return None;
        }
    }
    let mut acc = ((acc0 + acc1) + acc2) + acc3;
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        acc += d * d;
    }
    Some(acc)
}

// -------------------------------------------------------- projections

/// Projection-bank matmul for one vector: `out[p] = (a_p·v + b_p[p]) *
/// inv_w` over the transposed bank `at` (`[dim][P]`, from
/// [`HashFamily::a_transposed`]). Dispatched; bit-identical to
/// [`HashFamily::proj_into`] on every tier.
#[inline]
pub fn proj_into(v: &[f32], at: &[f32], offs: &[f32], inv_w: f32, out: &mut [f32]) {
    debug_assert_eq!(v.len() * out.len(), at.len());
    debug_assert_eq!(offs.len(), out.len());
    match tier() {
        #[cfg(target_arch = "x86_64")]
        Tier::Avx2 => unsafe { x86::proj_into_avx2(v, at, offs, inv_w, out) },
        #[cfg(target_arch = "x86_64")]
        Tier::Sse2 => unsafe { x86::proj_into_sse2(v, at, offs, inv_w, out) },
        #[cfg(target_arch = "aarch64")]
        Tier::Neon => unsafe { neon::proj_into_neon(v, at, offs, inv_w, out) },
        _ => proj_into_scalar(v, at, offs, inv_w, out),
    }
}

/// Scalar tier of [`proj_into`], over the *transposed* bank. Iterating j
/// outermost performs, for each projection lane p, the additions
/// `acc += at[j*P+p] * v[j]` in ascending j — exactly the sequential
/// row-dot order of [`HashFamily::proj_into`], so this is bit-identical
/// to the row-major oracle (and is the shape the SIMD tiers vectorize).
pub(crate) fn proj_into_scalar(
    v: &[f32],
    at: &[f32],
    offs: &[f32],
    inv_w: f32,
    out: &mut [f32],
) {
    let p = out.len();
    out.fill(0.0);
    for (j, &x) in v.iter().enumerate() {
        let row = &at[j * p..(j + 1) * p];
        for (o, &a) in out.iter_mut().zip(row) {
            *o += x * a;
        }
    }
    for (o, &b) in out.iter_mut().zip(offs) {
        *o = (*o + b) * inv_w;
    }
}

// ------------------------------------------------------------ backends

/// SIMD-dispatched [`Hasher`]: the sampled family's projection bank held
/// transposed (`[dim][P]`) so the kernels stream it contiguously, plus
/// write-into-slice batch loops (no per-row allocation). Results are
/// bit-identical to [`crate::runtime::ScalarHasher`] on every tier.
pub struct SimdHasher {
    family: HashFamily,
    /// `family.a_transposed()`: `[dim][P]`.
    at: Vec<f32>,
    /// `family.offsets()` cloned dense for the kernel.
    offs: Vec<f32>,
    inv_w: f32,
}

impl SimdHasher {
    pub fn new(family: HashFamily) -> SimdHasher {
        let at = family.a_transposed();
        let offs = family.offsets().to_vec();
        let inv_w = 1.0 / family.params.w;
        SimdHasher { family, at, offs, inv_w }
    }

    pub fn family(&self) -> &HashFamily {
        &self.family
    }

    /// Project one row into `out` (length P) — the no-alloc primitive both
    /// batch methods loop over.
    #[inline]
    pub fn proj_row_into(&self, v: &[f32], out: &mut [f32]) {
        proj_into(v, &self.at, &self.offs, self.inv_w, out);
    }
}

impl Hasher for SimdHasher {
    fn dim(&self) -> usize {
        self.family.dim
    }
    fn p(&self) -> usize {
        self.family.params.projections()
    }
    fn hash_batch(&self, x: &[f32], rows: usize) -> Vec<i32> {
        let dim = self.dim();
        let p = self.p();
        let mut out = Vec::with_capacity(rows * p);
        let mut scratch = vec![0f32; p];
        for r in 0..rows {
            self.proj_row_into(&x[r * dim..(r + 1) * dim], &mut scratch);
            out.extend(scratch.iter().map(|f| f.floor() as i32));
        }
        out
    }
    fn proj_batch(&self, x: &[f32], rows: usize) -> Vec<f32> {
        let dim = self.dim();
        let p = self.p();
        let mut out = vec![0f32; rows * p];
        for r in 0..rows {
            self.proj_row_into(&x[r * dim..(r + 1) * dim], &mut out[r * p..(r + 1) * p]);
        }
        out
    }
}

/// SIMD-dispatched, pruning-aware [`Ranker`]: SIMD `sqdist` with
/// early abandoning against the running k-th-best bound. `rank` returns
/// exactly what [`crate::runtime::ScalarRanker`] returns (pruning only
/// drops candidates the top-k would reject), and `rank_pruned`
/// additionally reports how many candidates were abandoned early
/// (`WorkStats::dists_pruned`).
pub struct SimdRanker {
    pub dim: usize,
}

impl Ranker for SimdRanker {
    fn rank(&self, q: &[f32], cands: &[f32], n: usize, k: usize) -> Vec<(f32, u32)> {
        self.rank_pruned(q, cands, n, k).0
    }

    fn rank_pruned(
        &self,
        q: &[f32],
        cands: &[f32],
        n: usize,
        k: usize,
    ) -> (Vec<(f32, u32)>, u64) {
        debug_assert!(cands.len() >= n * self.dim);
        let mut tk = TopK::new(k);
        let mut pruned = 0u64;
        for i in 0..n {
            let c = &cands[i * self.dim..(i + 1) * self.dim];
            match sqdist_pruned(q, c, tk.threshold()) {
                Some(d) => tk.push(d, i as u32),
                None => pruned += 1,
            }
        }
        (tk.into_sorted(), pruned)
    }

    fn rank_rows(
        &self,
        q: &[f32],
        store: &[f32],
        dim: usize,
        rows: &[u32],
        k: usize,
    ) -> (Vec<(f32, u32)>, u64) {
        // Identical per-candidate sequence to rank_pruned over a gathered
        // tile — same kernels, same bound evolution — just reading each
        // row out of the flat store in place.
        debug_assert_eq!(dim, self.dim);
        let mut tk = TopK::new(k);
        let mut pruned = 0u64;
        for (i, &r) in rows.iter().enumerate() {
            let at = r as usize * dim;
            match sqdist_pruned(q, &store[at..at + dim], tk.threshold()) {
                Some(d) => tk.push(d, i as u32),
                None => pruned += 1,
            }
        }
        (tk.into_sorted(), pruned)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::lsh::LshParams;
    use crate::runtime::{ScalarHasher, ScalarRanker};
    use crate::util::minitest::check;

    /// All tiers this host can actually execute (Scalar always; SSE2/AVX2
    /// or NEON per arch + detection). Property tests run every kernel
    /// variant against the scalar oracle, not just the dispatched one.
    fn host_sqdist_variants() -> Vec<(&'static str, fn(&[f32], &[f32]) -> f32)> {
        let mut v: Vec<(&'static str, fn(&[f32], &[f32]) -> f32)> =
            vec![("dispatched", sqdist as fn(&[f32], &[f32]) -> f32)];
        #[cfg(target_arch = "x86_64")]
        {
            fn sse2(a: &[f32], b: &[f32]) -> f32 {
                unsafe { x86::sqdist_sse2(a, b) }
            }
            v.push(("sse2", sse2));
            if is_x86_feature_detected!("avx2") {
                fn avx2(a: &[f32], b: &[f32]) -> f32 {
                    unsafe { x86::sqdist_avx2(a, b) }
                }
                v.push(("avx2", avx2));
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            fn neon_f(a: &[f32], b: &[f32]) -> f32 {
                unsafe { neon::sqdist_neon(a, b) }
            }
            v.push(("neon", neon_f));
        }
        v
    }

    type PrunedFn = fn(&[f32], &[f32], f32) -> Option<f32>;

    fn host_pruned_variants() -> Vec<(&'static str, PrunedFn)> {
        let mut v: Vec<(&'static str, PrunedFn)> = vec![
            ("dispatched", sqdist_pruned as PrunedFn),
            ("scalar", sqdist_pruned_scalar as PrunedFn),
        ];
        #[cfg(target_arch = "x86_64")]
        {
            fn sse2(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
                unsafe { x86::sqdist_pruned_sse2(a, b, bound) }
            }
            v.push(("sse2", sse2));
            if is_x86_feature_detected!("avx2") {
                fn avx2(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
                    unsafe { x86::sqdist_pruned_avx2(a, b, bound) }
                }
                v.push(("avx2", avx2));
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            fn neon_f(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
                unsafe { neon::sqdist_pruned_neon(a, b, bound) }
            }
            v.push(("neon", neon_f));
        }
        v
    }

    type ProjFn = fn(&[f32], &[f32], &[f32], f32, &mut [f32]);

    fn host_proj_variants() -> Vec<(&'static str, ProjFn)> {
        let mut v: Vec<(&'static str, ProjFn)> = vec![
            ("dispatched", proj_into as ProjFn),
            ("scalar-transposed", proj_into_scalar as ProjFn),
        ];
        #[cfg(target_arch = "x86_64")]
        {
            fn sse2(v_: &[f32], at: &[f32], o: &[f32], w: f32, out: &mut [f32]) {
                unsafe { x86::proj_into_sse2(v_, at, o, w, out) }
            }
            v.push(("sse2", sse2));
            if is_x86_feature_detected!("avx2") {
                fn avx2(v_: &[f32], at: &[f32], o: &[f32], w: f32, out: &mut [f32]) {
                    unsafe { x86::proj_into_avx2(v_, at, o, w, out) }
                }
                v.push(("avx2", avx2));
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            fn neon_f(v_: &[f32], at: &[f32], o: &[f32], w: f32, out: &mut [f32]) {
                unsafe { neon::proj_into_neon(v_, at, o, w, out) }
            }
            v.push(("neon", neon_f));
        }
        v
    }

    /// Bits, not tolerance: the whole point of the reduction-order
    /// contract is exact equality with the scalar oracle.
    fn assert_bits_eq(name: &str, got: f32, want: f32) {
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "{name}: {got} != {want} (bitwise)"
        );
    }

    #[test]
    fn sqdist_bit_exact_across_dims() {
        // Odd dims cover every remainder tail 1..=7 plus dim < lane width.
        check("kernels-sqdist-bitexact", 80, |g| {
            let n = g.usize_in(0, 3 + g.size);
            let a = g.vec_f32(n, -300.0, 300.0);
            let b = g.vec_f32(n, -300.0, 300.0);
            let want = sqdist_scalar(&a, &b);
            for (name, f) in host_sqdist_variants() {
                assert_bits_eq(name, f(&a, &b), want);
            }
        });
    }

    #[test]
    fn sqdist_bit_exact_small_and_empty() {
        // Deterministic sweep of every tail length below and above one
        // PRUNE_BLOCK, including the empty slice.
        for n in 0..=2 * PRUNE_BLOCK + 1 {
            let a: Vec<f32> = (0..n).map(|i| (i as f32).sin() * 9.0).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32).cos() * 7.0).collect();
            let want = sqdist_scalar(&a, &b);
            for (name, f) in host_sqdist_variants() {
                assert_bits_eq(name, f(&a, &b), want);
            }
        }
    }

    #[test]
    fn sqdist_bit_exact_nan_inf() {
        for n in [1usize, 4, 7, 17, 33] {
            let mut a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5).collect();
            a[n / 2] = f32::NAN;
            let want = sqdist_scalar(&a, &b);
            assert!(want.is_nan());
            for (name, f) in host_sqdist_variants() {
                assert!(f(&a, &b).is_nan(), "{name}: NaN lost");
            }
            a[n / 2] = f32::INFINITY;
            let want = sqdist_scalar(&a, &b);
            for (name, f) in host_sqdist_variants() {
                assert_bits_eq(name, f(&a, &b), want);
            }
        }
    }

    #[test]
    fn pruned_matches_unpruned_when_kept() {
        // A kept candidate's distance is bit-identical to the plain kernel;
        // a pruned one really does exceed the bound. All tiers agree on
        // the prune decision (same block boundaries).
        check("kernels-sqdist-pruned", 80, |g| {
            let n = g.usize_in(0, 3 + g.size);
            let a = g.vec_f32(n, -50.0, 50.0);
            let b = g.vec_f32(n, -50.0, 50.0);
            let full = sqdist_scalar(&a, &b);
            // Bounds straddling the true distance, plus the exact value
            // (equality must NOT prune) and the under-full +inf.
            let bounds =
                [full * 0.25, full * 0.5, full, full * 2.0 + 1.0, f32::INFINITY];
            for bound in bounds {
                let want = sqdist_pruned_scalar(&a, &b, bound);
                for (name, f) in host_pruned_variants() {
                    let got = f(&a, &b, bound);
                    match (got, want) {
                        (Some(x), Some(y)) => {
                            assert_bits_eq(name, x, y);
                            assert_bits_eq(name, x, full);
                        }
                        (None, None) => {}
                        other => panic!("{name}: prune decision diverged: {other:?}"),
                    }
                }
                if bound >= full {
                    // at or above the true distance nothing may be pruned
                    assert_eq!(want, Some(full), "pruned at bound >= dist");
                }
            }
        });
    }

    #[test]
    fn pruned_never_prunes_nan_or_inf_bound() {
        let a: Vec<f32> = (0..40).map(|i| i as f32).collect();
        let mut b = a.clone();
        b[3] = f32::NAN;
        for (name, f) in host_pruned_variants() {
            // NaN partials compare false against any bound — never pruned.
            assert!(f(&a, &b, 0.0).unwrap().is_nan(), "{name}: NaN pruned");
            // +inf bound (under-full top-k) never prunes.
            assert_eq!(
                f(&a, &a, f32::INFINITY),
                Some(0.0),
                "{name}: inf bound pruned"
            );
        }
    }

    fn family(dim: usize, l: usize, m: usize, seed: u64) -> HashFamily {
        HashFamily::sample(
            dim,
            LshParams { l, m, w: 4.0, k: 5, t: 1, seed },
        )
    }

    #[test]
    fn proj_bit_exact_vs_row_oracle() {
        // Odd P (lane remainders 1..=7) and odd dims, vs the row-major
        // scalar oracle in HashFamily.
        check("kernels-proj-bitexact", 60, |g| {
            let dim = g.usize_in(1, 40);
            let l = g.usize_in(1, 3);
            let m = g.usize_in(1, 11);
            let f = family(dim, l, m, g.rng.next_u64());
            let p = f.params.projections();
            let v = g.vec_f32(dim, -10.0, 10.0);
            let want = f.raw_projections(&v);
            let at = f.a_transposed();
            let offs = f.offsets();
            let inv_w = 1.0 / f.params.w;
            let mut out = vec![0f32; p];
            for (name, kf) in host_proj_variants() {
                out.fill(f32::NAN);
                kf(&v, &at, offs, inv_w, &mut out);
                for (i, (&got, &w)) in out.iter().zip(&want).enumerate() {
                    assert_bits_eq(&format!("{name}[{i}]"), got, w);
                }
            }
        });
    }

    #[test]
    fn proj_bit_exact_nan_inf_inputs() {
        let f = family(12, 2, 5, 9);
        let p = f.params.projections();
        let mut v: Vec<f32> = (0..12).map(|i| (i as f32).sin()).collect();
        v[5] = f32::NAN;
        v[7] = f32::INFINITY;
        let want = f.raw_projections(&v);
        let at = f.a_transposed();
        let mut out = vec![0f32; p];
        for (name, kf) in host_proj_variants() {
            kf(&v, &at, f.offsets(), 1.0 / f.params.w, &mut out);
            for (i, (&got, &w)) in out.iter().zip(&want).enumerate() {
                assert_eq!(
                    got.to_bits(),
                    w.to_bits(),
                    "{name}[{i}]: {got} != {w}"
                );
            }
        }
    }

    #[test]
    fn simd_hasher_matches_scalar_hasher_bit_exact() {
        check("kernels-hasher-differential", 30, |g| {
            let dim = g.usize_in(1, 48);
            let f = family(dim, 2, g.usize_in(1, 9), g.rng.next_u64());
            let scalar = ScalarHasher { family: f.clone() };
            let simd = SimdHasher::new(f);
            let rows = g.usize_in(0, 6);
            let x = g.vec_f32(rows * dim, -20.0, 20.0);
            assert_eq!(simd.proj_batch(&x, rows), scalar.proj_batch(&x, rows));
            assert_eq!(simd.hash_batch(&x, rows), scalar.hash_batch(&x, rows));
            assert_eq!(simd.dim(), scalar.dim());
            assert_eq!(simd.p(), scalar.p());
        });
    }

    #[test]
    fn simd_ranker_matches_scalar_oracle_under_ties() {
        // The pruning differential: identical (dist, id) pairs to the
        // non-pruning scalar oracle, including duplicated candidates
        // (exact distance ties) in both orders.
        check("kernels-ranker-differential", 40, |g| {
            let dim = g.usize_in(1, 24);
            let n = g.usize_in(0, 30);
            let k = g.usize_in(0, 12);
            let q = g.vec_f32(dim, -5.0, 5.0);
            let mut cands = g.vec_f32(n * dim, -5.0, 5.0);
            // duplicate a random row to force exact ties at distinct ids
            if n >= 2 {
                let src = g.usize_in(0, n - 1);
                let dst = g.usize_in(0, n - 1);
                let row: Vec<f32> = cands[src * dim..(src + 1) * dim].to_vec();
                cands[dst * dim..(dst + 1) * dim].copy_from_slice(&row);
            }
            let oracle = ScalarRanker { dim }.rank(&q, &cands, n, k);
            let simd = SimdRanker { dim };
            assert_eq!(simd.rank(&q, &cands, n, k), oracle);
            let (hits, pruned) = simd.rank_pruned(&q, &cands, n, k);
            assert_eq!(hits, oracle);
            assert!(pruned <= n as u64);
        });
    }

    #[test]
    fn rank_rows_matches_gathered_rank_pruned() {
        // The SoA DP hot path: ranking row indices in place must be
        // bit-identical — hits AND pruned count — to gathering those rows
        // into a tile and ranking that, on every impl (scattered row
        // order and repeated rows included).
        check("kernels-rank-rows-differential", 40, |g| {
            let dim = g.usize_in(1, 24);
            let stored = g.usize_in(1, 40);
            let store = g.vec_f32(stored * dim, -5.0, 5.0);
            let q = g.vec_f32(dim, -5.0, 5.0);
            let n = g.usize_in(0, 30);
            let rows: Vec<u32> =
                (0..n).map(|_| g.usize_in(0, stored - 1) as u32).collect();
            let k = g.usize_in(0, 12);
            let mut gathered = Vec::with_capacity(n * dim);
            for &r in &rows {
                let at = r as usize * dim;
                gathered.extend_from_slice(&store[at..at + dim]);
            }
            let simd = SimdRanker { dim };
            let want = simd.rank_pruned(&q, &gathered, n, k);
            assert_eq!(simd.rank_rows(&q, &store, dim, &rows, k), want);
            let scalar = ScalarRanker { dim };
            let scalar_want = scalar.rank_pruned(&q, &gathered, n, k);
            assert_eq!(scalar.rank_rows(&q, &store, dim, &rows, k), scalar_want);
            // and the scalar path agrees with SIMD on the hits themselves
            assert_eq!(scalar_want.0, want.0);
        });
    }

    #[test]
    fn ranker_tie_at_the_bound_survives() {
        // Three candidates at exactly the same distance with k=2: after
        // two pushes the bound *equals* the third candidate's distance,
        // and its partial sum at the (single) block boundary equals the
        // bound exactly. The strict `>` check must evaluate it fully
        // (pruned == 0) and let TopK apply the deterministic (dist, id)
        // tie-break, exactly like the non-pruning oracle.
        let dim = PRUNE_BLOCK; // one full block, so the bound check fires
        let base: Vec<f32> = (0..dim).map(|i| i as f32).collect();
        let q = vec![0f32; dim];
        let mut cands = vec![0f32; 3 * dim];
        for slot in 0..3 {
            cands[slot * dim..(slot + 1) * dim].copy_from_slice(&base);
        }
        let oracle = ScalarRanker { dim }.rank(&q, &cands, 3, 2);
        let (got, pruned) = SimdRanker { dim }.rank_pruned(&q, &cands, 3, 2);
        assert_eq!(got, oracle);
        assert_eq!(pruned, 0, "a tie at the bound must be evaluated, not pruned");
        // deterministic tie-break: lowest ids win
        assert_eq!(got.iter().map(|&(_, id)| id).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn ranker_actually_prunes() {
        // One near candidate then many far ones: with k=1 the bound drops
        // to ~0 after the first candidate and every later block-sized
        // distance overshoots it — pruning must engage (on every tier;
        // block boundaries agree), yet results equal the oracle.
        let dim = 4 * PRUNE_BLOCK;
        let q = vec![0f32; dim];
        let n = 64;
        let mut cands = vec![0f32; n * dim];
        for i in 1..n {
            for d in 0..dim {
                cands[i * dim + d] = 100.0 + i as f32;
            }
        }
        let oracle = ScalarRanker { dim }.rank(&q, &cands, n, 1);
        let (hits, pruned) = SimdRanker { dim }.rank_pruned(&q, &cands, n, 1);
        assert_eq!(hits, oracle);
        assert_eq!(hits, vec![(0.0, 0)]);
        assert_eq!(pruned, (n - 1) as u64, "far candidates must early-abandon");
    }

    #[test]
    fn default_rank_pruned_is_the_oracle() {
        // The trait's default keeps every existing Ranker impl valid:
        // plain rank, zero pruned.
        let r = ScalarRanker { dim: 4 };
        let q = [0f32; 4];
        let cands = [1.0, 0.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0];
        let (hits, pruned) = r.rank_pruned(&q, &cands, 2, 1);
        assert_eq!(hits, vec![(1.0, 0)]);
        assert_eq!(pruned, 0);
    }

    #[test]
    fn tier_is_stable_and_named() {
        let t = tier();
        assert_eq!(t, tier(), "tier must be resolved once");
        assert!(!t.name().is_empty());
        if std::env::var("PARLSH_FORCE_SCALAR").as_deref() == Ok("1") {
            assert_eq!(t, Tier::Scalar, "PARLSH_FORCE_SCALAR ignored");
        }
    }
}
