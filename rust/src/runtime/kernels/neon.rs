//! aarch64 NEON kernels (architectural baseline — no runtime detection
//! needed). Same reduction-order contract as the x86 kernels: the scalar
//! oracle's 4 sqdist accumulators are the 4 lanes of one `float32x4_t`,
//! folded `((l0 + l1) + l2) + l3`; projections accumulate
//! lane-per-projection with separate `vmulq`/`vaddq` — never `vfmaq`,
//! whose fused rounding would break bit-identity.

use super::PRUNE_BLOCK;
use core::arch::aarch64::*;

/// Fold a 4-lane accumulator exactly like the scalar oracle.
#[inline]
unsafe fn fold4(acc: float32x4_t) -> f32 {
    let l0 = vgetq_lane_f32::<0>(acc);
    let l1 = vgetq_lane_f32::<1>(acc);
    let l2 = vgetq_lane_f32::<2>(acc);
    let l3 = vgetq_lane_f32::<3>(acc);
    ((l0 + l1) + l2) + l3
}

/// NEON sqdist. Safety: NEON is part of the aarch64 baseline; `a` and `b`
/// must be equal-length (the dispatcher debug-asserts it).
pub(crate) unsafe fn sqdist_neon(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let mut acc = vdupq_n_f32(0.0);
    for i in 0..chunks {
        let j = i * 4;
        let va = vld1q_f32(a.as_ptr().add(j));
        let vb = vld1q_f32(b.as_ptr().add(j));
        let d = vsubq_f32(va, vb);
        acc = vaddq_f32(acc, vmulq_f32(d, d));
    }
    let mut s = fold4(acc);
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// NEON sqdist with early abandoning at [`PRUNE_BLOCK`] boundaries
/// (strict `>`, accumulator untouched by the check fold).
pub(crate) unsafe fn sqdist_pruned_neon(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
    let n = a.len();
    let chunks = n / 4;
    let mut acc = vdupq_n_f32(0.0);
    for i in 0..chunks {
        let j = i * 4;
        let va = vld1q_f32(a.as_ptr().add(j));
        let vb = vld1q_f32(b.as_ptr().add(j));
        let d = vsubq_f32(va, vb);
        acc = vaddq_f32(acc, vmulq_f32(d, d));
        if (j + 4) % PRUNE_BLOCK == 0 && fold4(acc) > bound {
            return None;
        }
    }
    let mut s = fold4(acc);
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    Some(s)
}

/// NEON projection kernel over the transposed bank (`at` is `[dim][P]`);
/// see `x86::proj_into_sse2` for the lane-per-projection layout argument.
pub(crate) unsafe fn proj_into_neon(
    v: &[f32],
    at: &[f32],
    offs: &[f32],
    inv_w: f32,
    out: &mut [f32],
) {
    let p = out.len();
    let groups = p / 4;
    out.fill(0.0);
    for (j, &x) in v.iter().enumerate() {
        let row = at.as_ptr().add(j * p);
        let xv = vdupq_n_f32(x);
        for g in 0..groups {
            let o = out.as_mut_ptr().add(g * 4);
            let acc = vld1q_f32(o);
            let prod = vmulq_f32(xv, vld1q_f32(row.add(g * 4)));
            vst1q_f32(o, vaddq_f32(acc, prod));
        }
        for t in groups * 4..p {
            out[t] += x * *row.add(t);
        }
    }
    for (o, &b) in out.iter_mut().zip(offs) {
        *o = (*o + b) * inv_w;
    }
}
