//! x86_64 kernels: SSE2 (architectural baseline, no detection needed) and
//! AVX2 (called only after `is_x86_feature_detected!("avx2")`).
//!
//! Every function here obeys the reduction-order contract in the module
//! docs (`kernels`): sqdist keeps the scalar oracle's 4 accumulators as
//! the 4 lanes of one `__m128` (AVX2 folds its two 128-bit halves into
//! that same accumulator, low half first — the scalar chunk order), and
//! the projection kernels accumulate lane-per-projection with *separate*
//! mul and add intrinsics — never FMA, whose single rounding would break
//! bit-identity with the scalar oracle.

use super::PRUNE_BLOCK;
use core::arch::x86_64::*;

/// Fold a 4-lane accumulator exactly like the scalar oracle:
/// `((l0 + l1) + l2) + l3`.
#[inline]
unsafe fn fold4(acc: __m128) -> f32 {
    let mut l = [0f32; 4];
    _mm_storeu_ps(l.as_mut_ptr(), acc);
    ((l[0] + l[1]) + l[2]) + l[3]
}

/// SSE2 sqdist. Safety: SSE2 is part of the x86_64 baseline, so this is
/// callable on every x86_64 CPU; `a` and `b` must be equal-length (the
/// dispatcher debug-asserts it; reads are bounds-derived either way).
pub(crate) unsafe fn sqdist_sse2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let mut acc = _mm_setzero_ps();
    for i in 0..chunks {
        let j = i * 4;
        let va = _mm_loadu_ps(a.as_ptr().add(j));
        let vb = _mm_loadu_ps(b.as_ptr().add(j));
        let d = _mm_sub_ps(va, vb);
        acc = _mm_add_ps(acc, _mm_mul_ps(d, d));
    }
    let mut s = fold4(acc);
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// AVX2 sqdist: 8 elements per iteration — two scalar 4-chunks — whose
/// 128-bit halves fold into the *same* 4-lane accumulator in chunk order,
/// so the per-lane addition sequence equals the SSE2/scalar one.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn sqdist_avx2(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let pairs = n / 8;
    let mut acc = _mm_setzero_ps();
    for i in 0..pairs {
        let j = i * 8;
        let va = _mm256_loadu_ps(a.as_ptr().add(j));
        let vb = _mm256_loadu_ps(b.as_ptr().add(j));
        let d = _mm256_sub_ps(va, vb);
        let sq = _mm256_mul_ps(d, d);
        acc = _mm_add_ps(acc, _mm256_castps256_ps128(sq)); // chunk 2i
        acc = _mm_add_ps(acc, _mm256_extractf128_ps::<1>(sq)); // chunk 2i+1
    }
    // odd leftover 4-chunk (n/4 odd), then the scalar tail — same shape
    // as the oracle's remainder handling.
    let mut j = pairs * 8;
    if j + 4 <= n {
        let va = _mm_loadu_ps(a.as_ptr().add(j));
        let vb = _mm_loadu_ps(b.as_ptr().add(j));
        let d = _mm_sub_ps(va, vb);
        acc = _mm_add_ps(acc, _mm_mul_ps(d, d));
        j += 4;
    }
    let mut s = fold4(acc);
    for t in j..n {
        let d = a[t] - b[t];
        s += d * d;
    }
    s
}

/// SSE2 sqdist with early abandoning at [`PRUNE_BLOCK`] boundaries
/// (strict `>`; the fold for the check copies the accumulator, leaving
/// the running reduction untouched).
pub(crate) unsafe fn sqdist_pruned_sse2(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
    let n = a.len();
    let chunks = n / 4;
    let mut acc = _mm_setzero_ps();
    for i in 0..chunks {
        let j = i * 4;
        let va = _mm_loadu_ps(a.as_ptr().add(j));
        let vb = _mm_loadu_ps(b.as_ptr().add(j));
        let d = _mm_sub_ps(va, vb);
        acc = _mm_add_ps(acc, _mm_mul_ps(d, d));
        if (j + 4) % PRUNE_BLOCK == 0 && fold4(acc) > bound {
            return None;
        }
    }
    let mut s = fold4(acc);
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    Some(s)
}

/// AVX2 sqdist with early abandoning. Checks fire after every other
/// 8-wide iteration — the same 16-element boundaries as every other tier,
/// so prune decisions are tier-invariant.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn sqdist_pruned_avx2(a: &[f32], b: &[f32], bound: f32) -> Option<f32> {
    let n = a.len();
    let pairs = n / 8;
    let mut acc = _mm_setzero_ps();
    for i in 0..pairs {
        let j = i * 8;
        let va = _mm256_loadu_ps(a.as_ptr().add(j));
        let vb = _mm256_loadu_ps(b.as_ptr().add(j));
        let d = _mm256_sub_ps(va, vb);
        let sq = _mm256_mul_ps(d, d);
        acc = _mm_add_ps(acc, _mm256_castps256_ps128(sq));
        acc = _mm_add_ps(acc, _mm256_extractf128_ps::<1>(sq));
        if (j + 8) % PRUNE_BLOCK == 0 && fold4(acc) > bound {
            return None;
        }
    }
    let mut j = pairs * 8;
    if j + 4 <= n {
        let va = _mm_loadu_ps(a.as_ptr().add(j));
        let vb = _mm_loadu_ps(b.as_ptr().add(j));
        let d = _mm_sub_ps(va, vb);
        acc = _mm_add_ps(acc, _mm_mul_ps(d, d));
        j += 4;
    }
    let mut s = fold4(acc);
    for t in j..n {
        let d = a[t] - b[t];
        s += d * d;
    }
    Some(s)
}

/// SSE2 projection kernel over the transposed bank (`at` is `[dim][P]`):
/// the dimension loop is outermost, `v[j]` is broadcast, and each group
/// of 4 projections accumulates in `out` with separate mul + add — per
/// lane, exactly the scalar row-dot's addition sequence. The `P % 4`
/// remainder lanes accumulate scalar inside the same `j` loop (same
/// order again).
pub(crate) unsafe fn proj_into_sse2(
    v: &[f32],
    at: &[f32],
    offs: &[f32],
    inv_w: f32,
    out: &mut [f32],
) {
    let p = out.len();
    let groups = p / 4;
    out.fill(0.0);
    for (j, &x) in v.iter().enumerate() {
        let row = at.as_ptr().add(j * p);
        let xv = _mm_set1_ps(x);
        for g in 0..groups {
            let o = out.as_mut_ptr().add(g * 4);
            let acc = _mm_loadu_ps(o);
            let prod = _mm_mul_ps(xv, _mm_loadu_ps(row.add(g * 4)));
            _mm_storeu_ps(o, _mm_add_ps(acc, prod));
        }
        for t in groups * 4..p {
            out[t] += x * *row.add(t);
        }
    }
    for (o, &b) in out.iter_mut().zip(offs) {
        *o = (*o + b) * inv_w;
    }
}

/// AVX2 projection kernel: same shape as the SSE2 one with 8 projection
/// lanes per group.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn proj_into_avx2(
    v: &[f32],
    at: &[f32],
    offs: &[f32],
    inv_w: f32,
    out: &mut [f32],
) {
    let p = out.len();
    let groups = p / 8;
    out.fill(0.0);
    for (j, &x) in v.iter().enumerate() {
        let row = at.as_ptr().add(j * p);
        let xv = _mm256_set1_ps(x);
        for g in 0..groups {
            let o = out.as_mut_ptr().add(g * 8);
            let acc = _mm256_loadu_ps(o);
            let prod = _mm256_mul_ps(xv, _mm256_loadu_ps(row.add(g * 8)));
            _mm256_storeu_ps(o, _mm256_add_ps(acc, prod));
        }
        for t in groups * 8..p {
            out[t] += x * *row.add(t);
        }
    }
    for (o, &b) in out.iter_mut().zip(offs) {
        *o = (*o + b) * inv_w;
    }
}
