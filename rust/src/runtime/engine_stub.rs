//! Stub for [`crate::runtime::engine`] when the `pjrt` feature is off.
//!
//! The real engine compiles AOT HLO artifacts through the `xla` bindings,
//! which are not available in every build environment. This stub keeps the
//! API surface (`Engine`, `EngineHasher`, `EngineRanker`, `EngineStats`)
//! so callers compile unchanged: [`Engine::load`] always returns an error,
//! the drivers print "artifacts unavailable" and use the scalar path, and
//! the artifact-path integration tests skip themselves.

use crate::core::lsh::HashFamily;
use crate::runtime::{Hasher, Ranker};
use anyhow::{bail, Result};
use std::sync::{Arc, Mutex};

/// Execution counters (mirrors the real engine's accounting).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub hash_calls: u64,
    pub hash_rows: u64,
    pub hash_padded_rows: u64,
    pub rank_calls: u64,
    pub rank_rows: u64,
    pub rank_padded_rows: u64,
}

/// Unconstructible stand-in for the PJRT engine.
pub struct Engine {
    pub stats: Mutex<EngineStats>,
    _private: (),
}

impl Engine {
    pub fn load(_dir: &str) -> Result<Engine> {
        bail!("built without the `pjrt` feature: the xla bindings are not vendored here; rebuild with `--features pjrt` on a machine that has them")
    }

    pub fn dim(&self) -> usize {
        unreachable!("stub Engine cannot be constructed")
    }

    pub fn set_family(&self, _family: &HashFamily) -> Result<()> {
        unreachable!("stub Engine cannot be constructed")
    }
}

/// Stub of the artifact-backed [`Hasher`].
pub struct EngineHasher {
    pub engine: Arc<Engine>,
    pub p_used: usize,
}

impl Hasher for EngineHasher {
    fn dim(&self) -> usize {
        unreachable!("stub Engine cannot be constructed")
    }
    fn p(&self) -> usize {
        self.p_used
    }
    fn hash_batch(&self, _x: &[f32], _rows: usize) -> Vec<i32> {
        unreachable!("stub Engine cannot be constructed")
    }
    fn proj_batch(&self, _x: &[f32], _rows: usize) -> Vec<f32> {
        unreachable!("stub Engine cannot be constructed")
    }
}

/// Stub of the artifact-backed [`Ranker`].
pub struct EngineRanker {
    pub engine: Arc<Engine>,
}

impl Ranker for EngineRanker {
    fn rank(&self, _q: &[f32], _cands: &[f32], _n: usize, _k: usize) -> Vec<(f32, u32)> {
        unreachable!("stub Engine cannot be constructed")
    }
}
