//! Configuration system: typed config structs parsed from a TOML-subset file
//! (`parlsh.toml`) plus `--set section.key=value` CLI overrides.

use crate::core::lsh::LshParams;
use crate::util::cli::Args;
use crate::util::configfile::Doc;
use anyhow::{anyhow, Result};

/// Partition strategy for `obj_map` (paper §IV-C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjMapStrategy {
    /// `obj_id mod n_dp` — perfectly balanced, locality-blind.
    Mod,
    /// Z-order curve key, range-scaled onto copies — locality preserving.
    ZOrder,
    /// An independent LSH g-function — hashes co-located points together.
    Lsh,
}

impl ObjMapStrategy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "mod" => Ok(ObjMapStrategy::Mod),
            "zorder" | "z-order" => Ok(ObjMapStrategy::ZOrder),
            "lsh" => Ok(ObjMapStrategy::Lsh),
            _ => Err(anyhow!("unknown obj_map strategy `{s}` (mod|zorder|lsh)")),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            ObjMapStrategy::Mod => "mod",
            ObjMapStrategy::ZOrder => "zorder",
            ObjMapStrategy::Lsh => "lsh",
        }
    }
}

/// Replica-selection strategy for query routing when `replication > 1`
/// (DESIGN.md §Cluster topology).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaRoute {
    /// `qid mod live_replicas` — balanced, content-blind.
    RoundRobin,
    /// Hash of the query vector picks the replica (Bahmani et al.,
    /// arXiv 1210.7057): repeated/near-identical queries pin to one
    /// replica, concentrating its cache while others stay cold.
    Layered,
}

impl ReplicaRoute {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "rr" | "round_robin" | "round-robin" => Ok(ReplicaRoute::RoundRobin),
            "layered" | "entropy" => Ok(ReplicaRoute::Layered),
            _ => Err(anyhow!("unknown replica_route `{s}` (rr|layered)")),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            ReplicaRoute::RoundRobin => "rr",
            ReplicaRoute::Layered => "layered",
        }
    }
}

/// Cluster topology (the paper's testbed shape).
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    /// Nodes hosting Bucket Index copies (paper: 10).
    pub bi_nodes: usize,
    /// Nodes hosting Data Points copies (paper: 40; BI:DP = 1:4).
    pub dp_nodes: usize,
    /// CPU cores per node (paper: 16).
    pub cores_per_node: usize,
    /// Aggregator copies (paper: 1 CPU core).
    pub ag_copies: usize,
    /// Ablation: one stage copy per *core* instead of per node (classic
    /// MPI-style). Multiplies copy counts by `cores_per_node` and removes
    /// intra-stage parallelism.
    pub per_core_copies: bool,
    /// Full-shard replicas of every worker node (1 = no replication).
    /// Writes fan to all replicas; query routing picks one live replica.
    pub replication: usize,
    /// How query traffic picks among live replicas.
    pub replica_route: ReplicaRoute,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            bi_nodes: 10,
            dp_nodes: 40,
            cores_per_node: 16,
            ag_copies: 1,
            per_core_copies: false,
            replication: 1,
            replica_route: ReplicaRoute::RoundRobin,
        }
    }
}

impl ClusterConfig {
    pub fn bi_copies(&self) -> usize {
        if self.per_core_copies {
            self.bi_nodes * self.cores_per_node
        } else {
            self.bi_nodes
        }
    }
    pub fn dp_copies(&self) -> usize {
        if self.per_core_copies {
            self.dp_nodes * self.cores_per_node
        } else {
            self.dp_nodes
        }
    }
    pub fn total_nodes(&self) -> usize {
        // +1 head node hosting QR/IR/AG.
        self.bi_nodes + self.dp_nodes + 1
    }
    pub fn total_cores(&self) -> usize {
        (self.bi_nodes + self.dp_nodes) * self.cores_per_node + self.ag_copies
    }
}

/// Network model constants (FDR InfiniBand defaults, paper §V-A).
#[derive(Clone, Copy, Debug)]
pub struct NetParams {
    /// Per-packet latency, microseconds.
    pub latency_us: f64,
    /// Link bandwidth, GB/s (FDR 4x ≈ 6.8 GB/s payload).
    pub bandwidth_gbps: f64,
}

impl Default for NetParams {
    fn default() -> Self {
        NetParams { latency_us: 1.7, bandwidth_gbps: 6.8 }
    }
}

/// Socket-transport settings (`crate::net`, DESIGN.md §Transports). Shares
/// the `[net]` config section with the simnet model constants above: those
/// describe the *modeled* network, these the *real* one.
#[derive(Clone, Debug)]
pub struct SocketConfig {
    /// Listen address for `parlsh worker` (port 0 = OS-assigned; the worker
    /// prints the bound address so the launcher can connect).
    pub listen: String,
    /// Connection attempts before giving up (driver→worker, worker→worker).
    pub connect_retries: usize,
    /// Backoff between connection attempts, milliseconds.
    pub retry_ms: u64,
    /// Cap on a single decoded frame (corrupted-length guard).
    pub max_frame_bytes: usize,
    /// Bound (in frames) on a worker's internal reader→dispatch queue.
    /// A full queue blocks the connection's reader thread, so backpressure
    /// propagates to the TCP sender instead of growing an unbounded buffer.
    pub queue_frames: usize,
    /// Static worker address table, comma-separated, one entry per slot
    /// (`total_slots()` of them). Non-empty switches `NetSession` from
    /// spawning loopback children to *discovering* out-of-band-started
    /// `parlsh worker` processes at these addresses.
    pub hosts: String,
    /// Streaming-loop liveness probe interval, milliseconds. A replica
    /// silent for 3 intervals while queries are in flight is marked dead.
    pub heartbeat_ms: u64,
    /// Directory for per-slot shard files (`slotNN.shard`). Non-empty
    /// enables `persist_shards` and lets a restarted worker rejoin from
    /// its file (`--shard`) instead of a live sibling's `StateDump`.
    pub shard_dir: String,
}

impl Default for SocketConfig {
    fn default() -> Self {
        SocketConfig {
            listen: "127.0.0.1:0".into(),
            connect_retries: 40,
            retry_ms: 25,
            max_frame_bytes: 64 << 20,
            queue_frames: 1024,
            hosts: String::new(),
            heartbeat_ms: 2000,
            shard_dir: String::new(),
        }
    }
}

impl SocketConfig {
    /// The parsed `[net] hosts` table (empty = spawn loopback workers).
    pub fn host_list(&self) -> Vec<String> {
        self.hosts
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }
}

/// Front-door settings (`crate::net::front`, DESIGN.md §Front door): the
/// poll-based server behind `parlsh serve --listen` that multiplexes
/// external wire clients onto one resident session. The listen address
/// itself comes from `--listen` / `[net] listen` (shared with workers —
/// one key, whichever role the process plays).
#[derive(Clone, Copy, Debug)]
pub struct FrontConfig {
    /// Cap on concurrently connected clients. Accepts beyond the cap are
    /// refused with a typed `Stopped` frame and closed — never queued.
    pub max_conns: usize,
    /// Bound on one connection's egress buffer (bytes). A client that
    /// falls further behind than this is evicted (typed `Stopped`) —
    /// one slow reader must never wedge the event loop or grow the
    /// server's memory without bound.
    pub egress_cap: usize,
}

impl Default for FrontConfig {
    fn default() -> Self {
        FrontConfig { max_conns: 1024, egress_cap: 4 << 20 }
    }
}

/// Multi-tenant QoS settings (`crate::qos`, DESIGN.md §QoS scheduler):
/// weighted fair queueing over tag classes at session admission, plus
/// mmLSH-style adaptive per-query probe budgets. Driver-side policy —
/// none of these keys enter the wire handshake digest.
#[derive(Clone, Debug)]
pub struct QosConfig {
    /// Tag weight classes, `"gold:4,silver:2,*:1"`: wire tag id `i+1` is
    /// the i-th named class; `*` (default weight 1) catches tag 0 and
    /// unknown ids. Empty = QoS off (admission stays tenant-blind).
    pub tags: String,
    /// Resolve `probes = 0` plans adaptively from each query's
    /// perturbation-score profile instead of the config `lsh.t` (Jafari
    /// et al., arXiv 2003.06415). Explicit per-query `probes` values are
    /// always honored as-is.
    pub adaptive_probes: bool,
    /// Fraction of the pooled perturbation score mass the adaptive
    /// budget keeps, in (0, 1]. Higher = deeper probing.
    pub adaptive_quantile: f64,
    /// Per-table ceiling on an adaptive budget (also clamped to the
    /// global 2^16 plan ceiling).
    pub adaptive_max: usize,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            tags: String::new(),
            adaptive_probes: false,
            adaptive_quantile: 0.5,
            adaptive_max: 64,
        }
    }
}

/// Dataset configuration.
#[derive(Clone, Debug)]
pub struct DataConfig {
    /// "synth" or a path to `.fvecs`/`.bvecs`.
    pub source: String,
    pub n: usize,
    pub queries: usize,
    pub dim: usize,
    pub clusters: usize,
    pub cluster_std: f32,
    pub distortion_std: f32,
    pub seed: u64,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            source: "synth".into(),
            n: 100_000,
            queries: 500,
            dim: 128,
            clusters: 2_000,
            cluster_std: 12.0,
            distortion_std: 8.0,
            seed: 1,
        }
    }
}

/// Stream/partition behaviour.
#[derive(Clone, Copy, Debug)]
pub struct StreamConfig {
    pub obj_map: ObjMapStrategy,
    /// Message aggregation buffer per destination (bytes; 0 = off).
    pub agg_bytes: usize,
    /// Dedup duplicate candidates at DP (paper's duplicate elimination).
    pub dedup: bool,
    /// Cap on candidates per query per DP message batch (0 = unlimited).
    pub max_candidates: usize,
    /// Closed-loop admission window for the threaded executor: max queries
    /// in flight at once (0 = open loop, submit everything up front).
    pub inflight: usize,
    /// Session-level backpressure: cap on queries submitted but not yet
    /// completed on a streaming run. At the cap, `IndexSession::submit`
    /// blocks (and `try_submit` declines) until completions drain;
    /// 0 = unbounded (submit never blocks).
    pub pending_cap: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            obj_map: ObjMapStrategy::Mod,
            agg_bytes: 64 * 1024,
            dedup: true,
            max_candidates: 0,
            inflight: 0,
            pending_cap: 0,
        }
    }
}

/// Runtime (PJRT artifact) configuration.
#[derive(Clone, Debug)]
pub struct RuntimeConfig {
    pub artifacts_dir: String,
    /// Use the compiled HLO path when artifacts are present.
    pub use_engine: bool,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig { artifacts_dir: "artifacts".into(), use_engine: true }
    }
}

/// Top-level configuration.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub lsh: LshParams,
    pub cluster: ClusterConfig,
    pub net: NetParams,
    pub sock: SocketConfig,
    pub front: FrontConfig,
    pub qos: QosConfig,
    pub data: DataConfig,
    pub stream: StreamConfig,
    pub runtime: RuntimeConfig,
}

impl Config {
    /// Build from a parsed document (all keys optional, defaults per paper).
    pub fn from_doc(doc: &Doc) -> Result<Config> {
        let mut c = Config::default();
        c.lsh = LshParams {
            l: doc.usize_or("lsh.l", c.lsh.l),
            m: doc.usize_or("lsh.m", c.lsh.m),
            w: doc.f64_or("lsh.w", c.lsh.w as f64) as f32,
            k: doc.usize_or("lsh.k", c.lsh.k),
            t: doc.usize_or("lsh.t", c.lsh.t),
            seed: doc.i64_or("lsh.seed", c.lsh.seed as i64) as u64,
        };
        c.cluster = ClusterConfig {
            bi_nodes: doc.usize_or("cluster.bi_nodes", c.cluster.bi_nodes),
            dp_nodes: doc.usize_or("cluster.dp_nodes", c.cluster.dp_nodes),
            cores_per_node: doc.usize_or("cluster.cores_per_node", c.cluster.cores_per_node),
            ag_copies: doc.usize_or("cluster.ag_copies", c.cluster.ag_copies),
            per_core_copies: doc.bool_or("cluster.per_core_copies", false),
            replication: doc.usize_or("cluster.replication", c.cluster.replication),
            replica_route: ReplicaRoute::parse(&doc.str_or("cluster.replica_route", "rr"))?,
        };
        c.net = NetParams {
            latency_us: doc.f64_or("net.latency_us", c.net.latency_us),
            bandwidth_gbps: doc.f64_or("net.bandwidth_gbps", c.net.bandwidth_gbps),
        };
        c.sock = SocketConfig {
            listen: doc.str_or("net.listen", &c.sock.listen),
            connect_retries: doc.usize_or("net.connect_retries", c.sock.connect_retries),
            retry_ms: doc.usize_or("net.retry_ms", c.sock.retry_ms as usize) as u64,
            max_frame_bytes: doc.usize_or("net.max_frame_bytes", c.sock.max_frame_bytes),
            queue_frames: doc.usize_or("net.queue_frames", c.sock.queue_frames),
            hosts: doc.str_or("net.hosts", &c.sock.hosts),
            heartbeat_ms: doc.usize_or("net.heartbeat_ms", c.sock.heartbeat_ms as usize) as u64,
            shard_dir: doc.str_or("net.shard_dir", &c.sock.shard_dir),
        };
        c.front = FrontConfig {
            max_conns: doc.usize_or("front.max_conns", c.front.max_conns),
            egress_cap: doc.usize_or("front.egress_cap", c.front.egress_cap),
        };
        c.qos = QosConfig {
            tags: doc.str_or("qos.tags", &c.qos.tags),
            adaptive_probes: doc.bool_or("qos.adaptive_probes", c.qos.adaptive_probes),
            adaptive_quantile: doc.f64_or("qos.adaptive_quantile", c.qos.adaptive_quantile),
            adaptive_max: doc.usize_or("qos.adaptive_max", c.qos.adaptive_max),
        };
        c.data = DataConfig {
            source: doc.str_or("data.source", &c.data.source),
            n: doc.usize_or("data.n", c.data.n),
            queries: doc.usize_or("data.queries", c.data.queries),
            dim: doc.usize_or("data.dim", c.data.dim),
            clusters: doc.usize_or("data.clusters", c.data.clusters),
            cluster_std: doc.f64_or("data.cluster_std", c.data.cluster_std as f64) as f32,
            distortion_std: doc.f64_or("data.distortion_std", c.data.distortion_std as f64)
                as f32,
            seed: doc.i64_or("data.seed", c.data.seed as i64) as u64,
        };
        c.stream = StreamConfig {
            obj_map: ObjMapStrategy::parse(&doc.str_or("stream.obj_map", "mod"))?,
            agg_bytes: doc.usize_or("stream.agg_bytes", c.stream.agg_bytes),
            dedup: doc.bool_or("stream.dedup", c.stream.dedup),
            max_candidates: doc.usize_or("stream.max_candidates", 0),
            inflight: doc.usize_or("stream.inflight", c.stream.inflight),
            pending_cap: doc.usize_or("stream.pending_cap", c.stream.pending_cap),
        };
        c.runtime = RuntimeConfig {
            artifacts_dir: doc.str_or("runtime.artifacts_dir", &c.runtime.artifacts_dir),
            use_engine: doc.bool_or("runtime.use_engine", true),
        };
        if c.cluster.replication == 0 {
            return Err(anyhow!("cluster.replication must be >= 1"));
        }
        if c.lsh.projections() > 256 {
            return Err(anyhow!(
                "L*M = {} exceeds the artifact projection bank (256)",
                c.lsh.projections()
            ));
        }
        // [qos] validation: a bad tag spec or quantile should fail at load
        // time, not at the first admission.
        crate::qos::TagTable::parse(&c.qos.tags).map_err(|e| anyhow!(e))?;
        if !(c.qos.adaptive_quantile > 0.0 && c.qos.adaptive_quantile <= 1.0) {
            return Err(anyhow!(
                "qos.adaptive_quantile = {} must be in (0, 1]",
                c.qos.adaptive_quantile
            ));
        }
        if c.qos.adaptive_max == 0 {
            return Err(anyhow!("qos.adaptive_max must be >= 1"));
        }
        Ok(c)
    }

    /// Load from optional file + CLI `--set` overrides.
    pub fn load(args: &Args) -> Result<Config> {
        let mut doc = match args.opt("config") {
            Some(path) => Doc::load(path).map_err(|e| anyhow!(e))?,
            None => Doc::default(),
        };
        for (k, v) in &args.overrides {
            doc.set(k, v).map_err(|e| anyhow!(e))?;
        }
        Config::from_doc(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::default();
        assert_eq!(c.lsh.l, 6);
        assert_eq!(c.lsh.m, 32);
        assert_eq!(c.cluster.bi_nodes, 10);
        assert_eq!(c.cluster.dp_nodes, 40);
        assert_eq!(c.cluster.cores_per_node, 16);
        // 801 = (10+40)*16 + 1 AG core
        assert_eq!(c.cluster.total_cores(), 801);
    }

    #[test]
    fn from_doc_overrides() {
        let doc = Doc::parse(
            "[lsh]\nl = 8\nt = 120\n[stream]\nobj_map = \"lsh\"\nagg_bytes = 0\ninflight = 16\n",
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(c.lsh.l, 8);
        assert_eq!(c.lsh.t, 120);
        assert_eq!(c.stream.obj_map, ObjMapStrategy::Lsh);
        assert_eq!(c.stream.agg_bytes, 0);
        assert_eq!(c.stream.inflight, 16);
        // default stays open loop
        assert_eq!(Config::default().stream.inflight, 0);
    }

    #[test]
    fn backpressure_knobs_parse() {
        // defaults: unbounded session backpressure, bounded worker queues
        let c = Config::default();
        assert_eq!(c.stream.pending_cap, 0);
        assert_eq!(c.sock.queue_frames, 1024);
        let doc = Doc::parse(
            "[stream]\npending_cap = 64\n[net]\nqueue_frames = 256\n",
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(c.stream.pending_cap, 64);
        assert_eq!(c.sock.queue_frames, 256);
    }

    #[test]
    fn socket_config_parses() {
        let c = Config::default();
        assert_eq!(c.sock.listen, "127.0.0.1:0");
        assert_eq!(c.sock.max_frame_bytes, 64 << 20);
        let doc = Doc::parse(
            "[net]\nlisten = \"0.0.0.0:7400\"\nconnect_retries = 5\nmax_frame_bytes = 1024\n",
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(c.sock.listen, "0.0.0.0:7400");
        assert_eq!(c.sock.connect_retries, 5);
        assert_eq!(c.sock.max_frame_bytes, 1024);
        // the simnet model constants share the section and keep their keys
        assert!((c.net.latency_us - 1.7).abs() < 1e-9);
    }

    #[test]
    fn front_config_parses() {
        let c = Config::default();
        assert_eq!(c.front.max_conns, 1024);
        assert_eq!(c.front.egress_cap, 4 << 20);
        let doc = Doc::parse(
            "[front]\nmax_conns = 8\negress_cap = 65536\n[net]\nlisten = \"127.0.0.1:7471\"\n",
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(c.front.max_conns, 8);
        assert_eq!(c.front.egress_cap, 65536);
        // the front door listens on the shared [net] listen key
        assert_eq!(c.sock.listen, "127.0.0.1:7471");
    }

    #[test]
    fn qos_config_parses_and_validates() {
        let c = Config::default();
        assert!(c.qos.tags.is_empty());
        assert!(!c.qos.adaptive_probes);
        assert!((c.qos.adaptive_quantile - 0.5).abs() < 1e-12);
        assert_eq!(c.qos.adaptive_max, 64);
        let doc = Doc::parse(
            "[qos]\ntags = \"gold:4,silver:2,*:1\"\nadaptive_probes = true\nadaptive_quantile = 0.8\nadaptive_max = 32\n",
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(c.qos.tags, "gold:4,silver:2,*:1");
        assert!(c.qos.adaptive_probes);
        assert!((c.qos.adaptive_quantile - 0.8).abs() < 1e-12);
        assert_eq!(c.qos.adaptive_max, 32);
        // hostile specs fail at load time, not at first admission
        let doc = Doc::parse("[qos]\ntags = \"gold:0\"\n").unwrap();
        assert!(Config::from_doc(&doc).is_err());
        let doc = Doc::parse("[qos]\nadaptive_quantile = 0.0\n").unwrap();
        assert!(Config::from_doc(&doc).is_err());
        let doc = Doc::parse("[qos]\nadaptive_quantile = 1.5\n").unwrap();
        assert!(Config::from_doc(&doc).is_err());
        let doc = Doc::parse("[qos]\nadaptive_max = 0\n").unwrap();
        assert!(Config::from_doc(&doc).is_err());
    }

    #[test]
    fn rejects_oversized_bank() {
        let doc = Doc::parse("[lsh]\nl = 10\nm = 32\n").unwrap();
        assert!(Config::from_doc(&doc).is_err());
    }

    #[test]
    fn per_core_ablation_multiplies_copies() {
        let mut c = Config::default();
        assert_eq!(c.cluster.bi_copies(), 10);
        assert_eq!(c.cluster.dp_copies(), 40);
        c.cluster.per_core_copies = true;
        assert_eq!(c.cluster.bi_copies(), 160);
        assert_eq!(c.cluster.dp_copies(), 640);
    }

    #[test]
    fn strategy_parse() {
        assert!(ObjMapStrategy::parse("nope").is_err());
        assert_eq!(ObjMapStrategy::parse("zorder").unwrap().name(), "zorder");
    }

    #[test]
    fn cluster_replication_parses() {
        let c = Config::default();
        assert_eq!(c.cluster.replication, 1);
        assert_eq!(c.cluster.replica_route, ReplicaRoute::RoundRobin);
        let doc = Doc::parse(
            "[cluster]\nreplication = 2\nreplica_route = \"layered\"\n",
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(c.cluster.replication, 2);
        assert_eq!(c.cluster.replica_route, ReplicaRoute::Layered);
        // replication = 0 is meaningless: there would be no shard at all
        let doc = Doc::parse("[cluster]\nreplication = 0\n").unwrap();
        assert!(Config::from_doc(&doc).is_err());
        assert!(ReplicaRoute::parse("nope").is_err());
        assert_eq!(ReplicaRoute::parse("entropy").unwrap().name(), "layered");
    }

    #[test]
    fn net_cluster_knobs_parse() {
        let c = Config::default();
        assert!(c.sock.host_list().is_empty());
        assert_eq!(c.sock.heartbeat_ms, 2000);
        assert!(c.sock.shard_dir.is_empty());
        let doc = Doc::parse(
            "[net]\nhosts = \"10.0.0.1:7500, 10.0.0.2:7500,10.0.0.1:7501\"\nheartbeat_ms = 250\nshard_dir = \"/tmp/shards\"\n",
        )
        .unwrap();
        let c = Config::from_doc(&doc).unwrap();
        assert_eq!(
            c.sock.host_list(),
            vec!["10.0.0.1:7500", "10.0.0.2:7500", "10.0.0.1:7501"]
        );
        assert_eq!(c.sock.heartbeat_ms, 250);
        assert_eq!(c.sock.shard_dir, "/tmp/shards");
    }
}
