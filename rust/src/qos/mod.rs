//! Multi-tenant QoS: tag classes, weighted-fair admission shares, and
//! mmLSH-style adaptive probe budgets (DESIGN.md §QoS scheduler).
//!
//! Every query plan already carries a `tag` (`QueryOptions.tag`, echoed per
//! ticket since the per-query-plan PR); this module is what finally
//! *consumes* it. Three pieces:
//!
//! - [`TagTable`]: the parsed `[qos] tags = "gold:4,silver:2,*:1"` spec.
//!   Named classes get weights; `*` is the catch-all for tag 0 and unknown
//!   ids. An empty spec parses to an **inert** table whose shares are
//!   unbounded — QoS off costs nothing and changes nothing.
//! - [`TagTable::share`]: weighted fair queueing over `stream.pending_cap`.
//!   The share is computed against the *active* classes only (outstanding
//!   work, plus the requester), so an idle class's weight is borrowed by
//!   whoever is running — work-conserving: a lone flooder gets the whole
//!   cap, but the moment a second class shows up the cap re-partitions by
//!   weight and the flooder parks at its share.
//! - [`adaptive_probes`]: the mmLSH budget rule (Jafari et al., arXiv
//!   2003.06415). Instead of a fixed per-table `T`, pick each query's
//!   budget from its own perturbation-score profile: pool the
//!   [`probe_sequence`] set scores across the query's tables, keep the
//!   cheap prefix holding `adaptive_quantile` of the cumulative score
//!   mass, and spread it back over the tables. Queries whose fractional
//!   coordinates sit near bucket boundaries (cheap, promising probes) get
//!   deeper budgets than queries centered in their buckets — a better
//!   recall/latency frontier at the same total work.
//!
//! The scheduler is *driver-side policy*: nothing here rides the wire or
//! the config digest. Adaptive budgets are resolved once at submission and
//! stamped into the wire plan as an explicit `probes` value, so the Query
//! Receiver's resolution — and therefore every transport — stays
//! bit-identical to the inline oracle by construction.

use crate::core::multiprobe::{probe_sequence, set_score};
use crate::dataflow::metrics::WorkStats;
use crate::metrics::LatencySummary;

/// Parsed `[qos] tags` spec: named weight classes plus the `*` catch-all.
///
/// Wire tag ids map to classes positionally: tag `i + 1` is the `i`-th
/// named class in spec order; tag 0 and any id past the named classes fall
/// into the catch-all (class index [`TagTable::n_classes`]` - 1`). The
/// default-constructed table is *inert*: [`TagTable::share`] returns
/// `usize::MAX` so admission gates compile to a no-op comparison.
#[derive(Clone, Debug, Default)]
pub struct TagTable {
    /// Named classes in spec order; wire tag `i + 1` selects `classes[i]`.
    classes: Vec<(String, u32)>,
    /// Weight of the `*` catch-all class (tag 0 / unknown ids).
    default_weight: u32,
    /// True only for a non-empty spec: the WFQ gates engage.
    enabled: bool,
}

impl TagTable {
    /// Parse a `"name:weight,name:weight,*:weight"` spec. Weights are
    /// positive integers (`name` alone means weight 1); `*` sets the
    /// catch-all weight (1 if absent). Empty spec → inert table.
    pub fn parse(spec: &str) -> Result<TagTable, String> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(TagTable::default());
        }
        let mut classes: Vec<(String, u32)> = Vec::new();
        let mut default_weight: Option<u32> = None;
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (name, weight) = match entry.split_once(':') {
                Some((n, w)) => {
                    let w: u32 = w.trim().parse().map_err(|e| {
                        format!("[qos] tags entry `{entry}`: bad weight: {e}")
                    })?;
                    (n.trim(), w)
                }
                None => (entry, 1),
            };
            if weight == 0 {
                return Err(format!("[qos] tags entry `{entry}`: weight must be >= 1"));
            }
            if name == "*" {
                if default_weight.replace(weight).is_some() {
                    return Err("[qos] tags: duplicate `*` entry".into());
                }
            } else {
                if name.is_empty()
                    || !name
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
                {
                    return Err(format!(
                        "[qos] tags entry `{entry}`: class names are alphanumeric/_/- (or `*`)"
                    ));
                }
                if classes.iter().any(|(n, _)| n == name) {
                    return Err(format!("[qos] tags: duplicate class `{name}`"));
                }
                classes.push((name.to_string(), weight));
            }
        }
        Ok(TagTable {
            classes,
            default_weight: default_weight.unwrap_or(1),
            enabled: true,
        })
    }

    /// True when parsed from a non-empty spec (the WFQ gates engage).
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Number of classes including the `*` catch-all (always last).
    pub fn n_classes(&self) -> usize {
        self.classes.len() + 1
    }

    /// Class index of a wire tag id (0 / unknown → the catch-all).
    pub fn class_of(&self, tag: u32) -> usize {
        let i = tag as usize;
        if i >= 1 && i <= self.classes.len() {
            i - 1
        } else {
            self.classes.len()
        }
    }

    /// Display name of a class (`"*"` for the catch-all).
    pub fn class_name(&self, class: usize) -> &str {
        self.classes.get(class).map_or("*", |(n, _)| n.as_str())
    }

    /// Canonical wire tag id of a class (0 for the catch-all).
    pub fn canonical_tag(&self, class: usize) -> u32 {
        if class < self.classes.len() {
            class as u32 + 1
        } else {
            0
        }
    }

    /// Weight of a class.
    pub fn weight(&self, class: usize) -> u32 {
        self.classes
            .get(class)
            .map_or(self.default_weight, |&(_, w)| w)
    }

    /// Resolve a CLI `--tag=NAME` value: numeric ids pass through as-is,
    /// otherwise the name is looked up in the class table.
    pub fn resolve_tag(&self, s: &str) -> Result<u32, String> {
        if let Ok(n) = s.parse::<u32>() {
            return Ok(n);
        }
        if s == "*" {
            return Ok(0);
        }
        match self.classes.iter().position(|(n, _)| n == s) {
            Some(i) => Ok(i as u32 + 1),
            None => {
                let known: Vec<&str> =
                    self.classes.iter().map(|(n, _)| n.as_str()).collect();
                Err(format!(
                    "unknown tag class `{s}` ([qos] tags names: {})",
                    if known.is_empty() { "<none>".into() } else { known.join(", ") }
                ))
            }
        }
    }

    /// The weighted-fair share of `cap` a class may hold outstanding,
    /// given per-class outstanding counts: `max(1, ceil(cap * w(class) /
    /// Σ w(active)))` where the active set is every class with outstanding
    /// work plus the requester itself. Idle weight is borrowed — a lone
    /// active class gets the whole cap — and every class's share is at
    /// least 1, so nobody can be starved outright. Inert table or
    /// uncapped stream (`cap == 0`) → `usize::MAX`.
    pub fn share(&self, cap: usize, class: usize, outstanding: &[u64]) -> usize {
        if !self.enabled || cap == 0 {
            return usize::MAX;
        }
        let w = self.weight(class) as usize;
        let mut sum = 0usize;
        for c in 0..self.n_classes() {
            if c == class || outstanding.get(c).copied().unwrap_or(0) > 0 {
                sum += self.weight(c) as usize;
            }
        }
        (cap * w).div_ceil(sum).max(1)
    }
}

/// Per-class serving account: admission counters plus the latency and
/// work attribution that [`crate::coordinator::session::SessionStats`]
/// surfaces as the per-tag SLO rows.
#[derive(Clone, Debug, Default)]
pub struct TagAccount {
    /// Queries admitted under this class.
    pub submitted: u64,
    /// Tickets completed (orphaned lane tickets count as completed work
    /// but skip the latency summary, mirroring the session-wide rule).
    pub completed: u64,
    /// Pipeline service time per completed ticket (submit → completion
    /// inside the pipeline; admission parking is *not* included — see
    /// DESIGN.md §QoS scheduler on why queueing fairness is asserted by
    /// wall-clock at the client instead).
    pub latency: LatencySummary,
    /// Work counters delta-attributed at completion time from the live
    /// in-process stage slots. Exact under the inline oracle (one query
    /// in flight); an approximation under concurrency, and on the socket
    /// transport remote work only lands at the finish barrier — the
    /// session-wide totals remain the authoritative sum.
    pub work: WorkStats,
}

/// One rendered per-tag SLO row (a snapshot of a [`TagAccount`] plus its
/// identity), as surfaced by `SessionStats::per_tag` / `FrontStats`.
#[derive(Clone, Debug)]
pub struct TagStats {
    /// Class display name (`"*"` for the catch-all).
    pub name: String,
    /// Canonical wire tag id (0 for the catch-all).
    pub tag: u32,
    /// Configured WFQ weight.
    pub weight: u32,
    pub submitted: u64,
    pub completed: u64,
    /// Still in the pipeline when the snapshot was taken.
    pub outstanding: u64,
    pub latency: LatencySummary,
    pub work: WorkStats,
}

/// mmLSH-style adaptive per-table probe budget (Jafari et al., arXiv
/// 2003.06415) from a query's raw projections.
///
/// For each of the query's `tables`, the fractional parts of its `m` raw
/// coordinates (the same `raw - floor(raw)` recipe as
/// `HashFamily::query_probes`) feed [`probe_sequence`]`(fracs, t_max)`;
/// every perturbation set's [`set_score`] — the Lv et al. proxy for the
/// probability the perturbed bucket holds a true neighbor (lower is
/// better) — is pooled across tables and sorted ascending. The budget
/// keeps the cheap prefix whose cumulative score stays within `quantile`
/// of the total mass, spreads it back over the tables, and adds the home
/// bucket: `T = ceil(kept / tables) + 1`, clamped to `[1, t_max]`.
///
/// Deterministic in its inputs (stable sort, fixed f64 accumulation
/// order), so a budget resolved at submission and stamped into the wire
/// plan reproduces exactly on replay.
pub fn adaptive_probes(
    raw: &[f32],
    m: usize,
    tables: usize,
    t_max: usize,
    quantile: f64,
) -> usize {
    let t_max = t_max.max(1);
    let tables = tables.max(1);
    if t_max == 1 {
        return 1;
    }
    debug_assert!(raw.len() >= tables * m, "raw projections shorter than L'*M");
    let mut scores: Vec<f32> = Vec::with_capacity(tables * (t_max - 1));
    for table in 0..tables {
        let raw_t = &raw[table * m..(table + 1) * m];
        // identical fractional-part recipe to HashFamily::query_probes so
        // the scored sets are exactly the sets QR will later walk
        let fracs: Vec<f32> = raw_t.iter().map(|f| f - f.floor() as i32 as f32).collect();
        for set in probe_sequence(&fracs, t_max) {
            scores.push(set_score(&set, &fracs));
        }
    }
    scores.sort_by(|a, b| a.total_cmp(b));
    let total: f64 = scores.iter().map(|&s| s as f64).sum();
    let cutoff = quantile.clamp(0.0, 1.0) * total;
    let mut acc = 0f64;
    let mut kept = 0usize;
    for &s in &scores {
        acc += s as f64;
        if acc > cutoff {
            break;
        }
        kept += 1;
    }
    (kept.div_ceil(tables) + 1).clamp(1, t_max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::check;

    fn gold_silver() -> TagTable {
        TagTable::parse("gold:4,silver:2,*:1").unwrap()
    }

    #[test]
    fn parse_maps_names_weights_and_catchall() {
        let t = gold_silver();
        assert!(t.is_enabled());
        assert_eq!(t.n_classes(), 3);
        assert_eq!((t.class_name(0), t.weight(0)), ("gold", 4));
        assert_eq!((t.class_name(1), t.weight(1)), ("silver", 2));
        assert_eq!((t.class_name(2), t.weight(2)), ("*", 1));
        // wire ids: 1-based into the named classes, everything else → *
        assert_eq!(t.class_of(1), 0);
        assert_eq!(t.class_of(2), 1);
        assert_eq!(t.class_of(0), 2);
        assert_eq!(t.class_of(99), 2);
        assert_eq!(t.canonical_tag(0), 1);
        assert_eq!(t.canonical_tag(2), 0);
    }

    #[test]
    fn parse_rejects_hostile_specs() {
        assert!(TagTable::parse("gold:0").is_err());
        assert!(TagTable::parse("gold:4,gold:2").is_err());
        assert!(TagTable::parse("*:1,*:2").is_err());
        assert!(TagTable::parse("gold:abc").is_err());
        assert!(TagTable::parse("bad name:1").is_err());
        // bare name = weight 1; omitted * = weight 1
        let t = TagTable::parse("gold").unwrap();
        assert_eq!(t.weight(0), 1);
        assert_eq!(t.weight(1), 1);
    }

    #[test]
    fn empty_spec_is_inert() {
        let t = TagTable::parse("").unwrap();
        assert!(!t.is_enabled());
        assert_eq!(t.n_classes(), 1);
        assert_eq!(t.share(4, 0, &[100]), usize::MAX);
        // and so is the uncapped stream even with classes configured
        assert_eq!(gold_silver().share(0, 0, &[1, 1, 1]), usize::MAX);
    }

    #[test]
    fn resolve_tag_accepts_numbers_names_and_star() {
        let t = gold_silver();
        assert_eq!(t.resolve_tag("silver").unwrap(), 2);
        assert_eq!(t.resolve_tag("7").unwrap(), 7);
        assert_eq!(t.resolve_tag("*").unwrap(), 0);
        assert!(t.resolve_tag("bronze").is_err());
        assert!(TagTable::parse("").unwrap().resolve_tag("bronze").is_err());
    }

    #[test]
    fn share_borrows_idle_weight_and_repartitions_on_contention() {
        let t = TagTable::parse("gold:1,silver:1").unwrap();
        // lone active class borrows the whole cap (work-conserving)
        assert_eq!(t.share(4, 0, &[0, 0, 0]), 4);
        assert_eq!(t.share(4, 1, &[0, 0, 0]), 4);
        // both named classes active: equal weights halve the cap
        assert_eq!(t.share(4, 0, &[1, 1, 0]), 2);
        assert_eq!(t.share(4, 1, &[1, 1, 0]), 2);
        // weighted split: gold 3 : silver 1 over cap 4
        let w = TagTable::parse("gold:3,silver:1").unwrap();
        assert_eq!(w.share(4, 0, &[1, 1, 0]), 3);
        assert_eq!(w.share(4, 1, &[1, 1, 0]), 1);
        // the requester counts as active even at 0 outstanding
        assert_eq!(w.share(4, 1, &[4, 0, 0]), 1);
    }

    #[test]
    fn share_never_starves_a_class() {
        check("share-floor", 60, |g| {
            let t = TagTable::parse("a:7,b:3,c:1,*:2").unwrap();
            let cap = g.usize_in(1, 12);
            let out: Vec<u64> = (0..4).map(|_| g.usize_in(0, 5) as u64).collect();
            for class in 0..t.n_classes() {
                let s = t.share(cap, class, &out);
                assert!(s >= 1, "share must be >= 1");
                assert!(s <= cap.max(1), "share {s} exceeds cap {cap}");
            }
        });
    }

    #[test]
    fn shares_of_active_classes_cover_the_cap() {
        // Work conservation: when every class is active, the share sum is
        // at least the cap (ceil rounding may overshoot, never undershoot).
        check("share-cover", 60, |g| {
            let t = TagTable::parse("a:4,b:2,*:1").unwrap();
            let cap = g.usize_in(1, 16);
            let out = [1u64, 1, 1];
            let sum: usize = (0..3).map(|c| t.share(cap, c, &out)).sum();
            assert!(sum >= cap, "active shares {sum} must cover cap {cap}");
        });
    }

    fn ramp_raw(m: usize, tables: usize, spread: f32) -> Vec<f32> {
        // fractional parts walk away from 0.5 (bucket center) as `spread`
        // grows: larger spread → cheaper perturbations near the boundary
        (0..m * tables)
            .map(|i| {
                let phase = (i as f32 * 0.37).sin() * spread;
                3.0 + 0.5 + phase.clamp(-0.49, 0.49)
            })
            .collect()
    }

    #[test]
    fn adaptive_budget_bounds_and_determinism() {
        check("adaptive-bounds", 40, |g| {
            let m = g.usize_in(2, 8);
            let tables = g.usize_in(1, 4);
            let t_max = g.usize_in(1, 40);
            let q = g.f32_in(0.0, 1.0) as f64;
            let raw: Vec<f32> = (0..m * tables).map(|_| g.f32_in(-20.0, 20.0)).collect();
            let t1 = adaptive_probes(&raw, m, tables, t_max, q);
            assert!((1..=t_max.max(1)).contains(&t1));
            assert_eq!(t1, adaptive_probes(&raw, m, tables, t_max, q));
        });
    }

    #[test]
    fn adaptive_budget_is_monotone_in_quantile() {
        let raw = ramp_raw(8, 3, 0.4);
        let mut last = 0usize;
        for q in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
            let t = adaptive_probes(&raw, 8, 3, 32, q);
            assert!(t >= last, "budget shrank as quantile grew: {t} < {last} at q={q}");
            last = t;
        }
        // the full mass keeps every scored perturbation → the ceiling
        assert_eq!(adaptive_probes(&raw, 8, 3, 32, 1.0), 32);
    }

    #[test]
    fn boundary_queries_probe_deeper_than_centered_ones() {
        // A query whose fracs hug the bucket boundary has many low-score
        // perturbations — more of the mass fits under the quantile early,
        // but the *count* kept under a mid quantile is larger for the
        // centered query whose scores are all identical. What matters for
        // the frontier is simply that the two profiles resolve different
        // budgets — the fixed-T client can't express that.
        let boundary = adaptive_probes(&ramp_raw(8, 2, 0.49), 8, 2, 24, 0.5);
        let centered = adaptive_probes(&ramp_raw(8, 2, 0.0), 8, 2, 24, 0.5);
        assert_ne!(boundary, centered, "distinct profiles should resolve distinct budgets");
    }

    #[test]
    fn adaptive_budget_degenerate_inputs_stay_clamped() {
        // t_max = 1 short-circuits to the home bucket
        assert_eq!(adaptive_probes(&[0.5; 8], 4, 2, 1, 0.9), 1);
        // zero tables is treated as 1 (same .max(1) rule as query_probes)
        assert_eq!(adaptive_probes(&[0.5; 4], 4, 0, 1, 0.5), 1);
    }
}
