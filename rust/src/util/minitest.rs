//! Miniature property-testing harness (the proptest substitute).
//!
//! A property is a closure over a [`Gen`]; `check` runs it `cases` times with
//! derived seeds and, on failure, reruns with the failing seed to confirm and
//! reports it so the case can be replayed (`PARLSH_PT_SEED=<seed>`).
//! No shrinking — failing seeds are printed and properties are written to
//! take small sizes, which keeps counterexamples readable in practice.

use super::rng::Rng;

/// Randomized input source handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Size hint: grows over the run so later cases are larger.
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }
    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        lo + self.rng.below((hi as i64 - lo as i64 + 1) as u64) as i32
    }
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }
    pub fn gaussian_vec(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.gaussian_f32()).collect()
    }
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }
}

/// Run `prop` for `cases` randomized cases. Panics with the failing seed.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen)) {
    // Replay mode: PARLSH_PT_SEED pins a single seed.
    if let Ok(s) = std::env::var("PARLSH_PT_SEED") {
        let seed: u64 = s.parse().expect("PARLSH_PT_SEED must be u64");
        let mut g = Gen { rng: Rng::new(seed), size: 100 };
        prop(&mut g);
        return;
    }
    let base = 0xC0FFEE ^ fxhash_str(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 4 + (case * 100) / cases.max(1);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: Rng::new(seed), size };
            prop(&mut g);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed at case {case}/{cases} \
                 (replay with PARLSH_PT_SEED={seed}): {msg}"
            );
        }
    }
}

fn fxhash_str(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |g| {
            let a = g.i32_in(-1000, 1000);
            let b = g.i32_in(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 10, |g| {
            let x = g.usize_in(0, 10);
            assert!(x > 100, "x={x}");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check("gen-ranges", 100, |g| {
            let u = g.usize_in(3, 9);
            assert!((3..=9).contains(&u));
            let i = g.i32_in(-5, 5);
            assert!((-5..=5).contains(&i));
            let f = g.f32_in(1.0, 2.0);
            assert!((1.0..2.0).contains(&f));
        });
    }
}
