//! Wall-clock timing helpers (the criterion substitute's building block).

use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

/// Repeat a closure until at least `min_time` seconds and `min_iters`
/// iterations have elapsed; returns mean seconds/iter. Used by the bench
/// harness for microbenchmarks and cost-model calibration.
pub fn bench_loop(min_time: f64, min_iters: usize, mut f: impl FnMut()) -> f64 {
    // Warmup.
    f();
    let t = Timer::start();
    let mut iters = 0usize;
    loop {
        f();
        iters += 1;
        if iters >= min_iters && t.secs() >= min_time {
            break;
        }
    }
    t.secs() / iters as f64
}

/// Percentile of a sample (nearest-rank, p in [0,100]).
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * samples.len() as f64).ceil().max(1.0) as usize - 1;
    samples[rank.min(samples.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn bench_loop_runs_min_iters() {
        let mut count = 0;
        let per = bench_loop(0.0, 10, || count += 1);
        assert!(count >= 11); // warmup + 10
        assert!(per >= 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut xs = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&mut xs, 50.0), 3.0);
        assert_eq!(percentile(&mut xs, 100.0), 5.0);
        assert_eq!(percentile(&mut xs, 1.0), 1.0);
    }
}
