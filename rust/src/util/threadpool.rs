//! Minimal scoped threadpool — the intra-stage parallelism substrate
//! (the paper's POSIX-thread worker pools inside BI/DP stage copies).
//!
//! `scope_chunks` is the workhorse: split an index range into chunks and run
//! a closure per chunk on `n` worker threads, collecting results in order.
//! Built on `std::thread::scope`, so borrows of stack data are allowed.

/// Run `f(chunk_start, chunk_end)` over `0..len` split into `workers` chunks
/// on that many threads; returns per-chunk results in chunk order.
pub fn scope_chunks<R, F>(len: usize, workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, usize) -> R + Sync,
{
    let workers = workers.max(1).min(len.max(1));
    let chunk = len.div_ceil(workers);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(len);
            let f = &f;
            handles.push(s.spawn(move || f(start, end.max(start))));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Run one closure per item on up to `workers` threads (items are moved in).
pub fn scope_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    // Chunk the items; preserve order of results.
    let chunk = n.div_ceil(workers);
    let mut chunks: Vec<Vec<T>> = Vec::new();
    let mut items = items;
    while !items.is_empty() {
        let rest = items.split_off(items.len().min(chunk));
        chunks.push(items);
        items = rest;
    }
    let results = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for ch in chunks {
            let f = &f;
            handles.push(s.spawn(move || ch.into_iter().map(f).collect::<Vec<R>>()));
        }
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect::<Vec<_>>()
    });
    results.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_range_exactly() {
        let parts = scope_chunks(103, 4, |a, b| (a, b));
        assert_eq!(parts.first().unwrap().0, 0);
        assert_eq!(parts.last().unwrap().1, 103);
        for w in parts.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn sums_match_serial() {
        let total: usize = scope_chunks(1000, 8, |a, b| (a..b).sum::<usize>())
            .into_iter()
            .sum();
        assert_eq!(total, (0..1000).sum::<usize>());
    }

    #[test]
    fn map_preserves_order() {
        let out = scope_map((0..50).collect::<Vec<_>>(), 7, |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert!(scope_map(Vec::<i32>::new(), 4, |x| x).is_empty());
        assert_eq!(scope_chunks(0, 4, |a, b| (a, b)).len(), 1);
        assert_eq!(scope_map(vec![9], 4, |x| x + 1), vec![10]);
    }
}
