//! Deterministic, seedable PRNG: xoshiro256++ with a splitmix64 seeder, plus
//! Box–Muller Gaussian sampling. Replaces the unavailable `rand` crate.
//!
//! Determinism matters here: every experiment in EXPERIMENTS.md is keyed by a
//! seed, and index layouts must be bit-identical across runs for the
//! differential tests (distributed pipeline vs sequential baseline).

/// splitmix64 — used to expand a single `u64` seed into xoshiro state and as
/// a standalone finalizer for bucket keying.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Finalize an arbitrary u64 into a well-mixed hash (splitmix64 core).
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed via splitmix64 expansion (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream (for per-table / per-copy generators).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ mix64(stream))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough for
    /// our workloads; n is tiny relative to 2^64).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (polar-free, exact).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k << n assumed; rejection).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let i = self.below(n as u64) as usize;
            if seen.insert(i) {
                out.push(i);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut hits = [0usize; 10];
        for _ in 0..10_000 {
            hits[r.below(10) as usize] += 1;
        }
        for h in hits {
            assert!(h > 700, "bucket starved: {h}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(1000, 50);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 50);
        assert!(s.iter().all(|&i| i < 1000));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(42);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
