//! Minimal TOML-subset parser (sections, `key = value`, comments) — the
//! config-file substrate replacing serde/toml.
//!
//! Supported values: integers, floats, booleans, quoted strings, and flat
//! arrays of those. Enough for `parlsh.toml`; unsupported syntax is a hard
//! error (never silently ignored).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parsed document: `section.key -> value` (top-level keys live under "").
#[derive(Clone, Debug, Default)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if doc.entries.insert(full.clone(), value).is_some() {
                return Err(format!("line {}: duplicate key `{full}`", lineno + 1));
            }
        }
        Ok(doc)
    }

    pub fn load(path: &str) -> Result<Doc, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Doc::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }

    /// Insert/override (used to apply `--set section.key=value` CLI flags).
    pub fn set(&mut self, key: &str, raw: &str) -> Result<(), String> {
        let value = parse_value(raw.trim())?;
        self.entries.insert(key.to_string(), value);
        Ok(())
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.i64_or(key, default as i64) as usize
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Doc::parse(
            r#"
            top = 1
            [lsh]
            l = 6
            m = 32          # paper default
            w = 4000.0
            name = "bigann-mini"
            multiprobe = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.i64_or("top", 0), 1);
        assert_eq!(doc.i64_or("lsh.l", 0), 6);
        assert_eq!(doc.i64_or("lsh.m", 0), 32);
        assert!((doc.f64_or("lsh.w", 0.0) - 4000.0).abs() < 1e-9);
        assert_eq!(doc.str_or("lsh.name", ""), "bigann-mini");
        assert!(doc.bool_or("lsh.multiprobe", false));
    }

    #[test]
    fn parses_arrays() {
        let doc = Doc::parse("xs = [1, 2, 3]\nys = [1.5, \"a\", true]").unwrap();
        assert_eq!(
            doc.get("xs").unwrap(),
            &Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        match doc.get("ys").unwrap() {
            Value::Array(v) => assert_eq!(v.len(), 3),
            _ => panic!("not an array"),
        }
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = Doc::parse("x = 3").unwrap();
        assert_eq!(doc.f64_or("x", 0.0), 3.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Doc::parse("x").is_err());
        assert!(Doc::parse("[oops").is_err());
        assert!(Doc::parse("x = ").is_err());
        assert!(Doc::parse("x = zz").is_err());
        assert!(Doc::parse("x = 1\nx = 2").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = Doc::parse("s = \"a # b\"").unwrap();
        assert_eq!(doc.str_or("s", ""), "a # b");
    }

    #[test]
    fn set_overrides() {
        let mut doc = Doc::parse("[lsh]\nl = 6").unwrap();
        doc.set("lsh.l", "8").unwrap();
        assert_eq!(doc.i64_or("lsh.l", 0), 8);
    }
}
