//! Hand-rolled substrates: PRNG, CLI parsing, config files, threadpool,
//! timers, and a miniature property-testing harness.
//!
//! This environment has no crate registry access beyond the vendored
//! `xla`/`anyhow` set, so the usual suspects (rand, clap, serde/toml, rayon,
//! criterion, proptest) are implemented here at the scale this project needs.

pub mod cli;
pub mod configfile;
pub mod minitest;
pub mod rng;
pub mod threadpool;
pub mod timer;

pub use cli::Args;
pub use rng::Rng;
pub use timer::Timer;
