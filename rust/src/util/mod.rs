//! Hand-rolled substrates: PRNG, CLI parsing, config files, threadpool,
//! timers, and a miniature property-testing harness.
//!
//! This environment has no crate registry access: `anyhow` is vendored as a
//! path crate (`rust/vendor/anyhow`), the `xla` PJRT bindings are gated
//! behind the `pjrt` feature, and the usual suspects (rand, clap,
//! serde/toml, rayon, criterion, proptest) are implemented here at the
//! scale this project needs.

pub mod cli;
pub mod configfile;
pub mod minitest;
pub mod rng;
pub mod threadpool;
pub mod timer;

pub use cli::Args;
pub use rng::Rng;
pub use timer::Timer;
