//! Tiny CLI argument parser (the clap substitute).
//!
//! Grammar: `parlsh <subcommand> [--flag] [--key value] [--set a.b=c]...`
//! Flags may repeat only for `--set`.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// `--set section.key=value` config overrides, applied in order.
    pub overrides: Vec<(String, String)>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut it = argv.into_iter();
        let mut args = Args::default();
        let mut first = true;
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                if name == "set" {
                    let kv = it
                        .next()
                        .ok_or_else(|| "--set requires key=value".to_string())?;
                    let (k, v) = kv
                        .split_once('=')
                        .ok_or_else(|| format!("--set `{kv}`: expected key=value"))?;
                    args.overrides.push((k.to_string(), v.to_string()));
                } else if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    // Peek: if next token exists and is not another flag,
                    // treat it as this option's value; else boolean flag.
                    args.flags.push(name.to_string());
                }
            } else if first {
                args.subcommand = tok;
            } else {
                args.positional.push(tok);
            }
            first = false;
        }
        // Second pass: `--key value` style — a flag immediately followed by a
        // positional belongs together. Re-associate conservatively.
        args.reassociate();
        Ok(args)
    }

    /// `--key value` support: pull positionals that directly followed a flag.
    ///
    /// Because the single-pass parser can't know whether `--key v` is a
    /// boolean flag plus positional or an option, we use the convention that
    /// all options are `--key=value` OR the flag names listed in
    /// [`Self::KNOWN_VALUE_FLAGS`] take the following token as value.
    fn reassociate(&mut self) {
        // Kept simple: all value-taking options must use `--key=value`.
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name}={v}: {e}")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{name}={v}: {e}")),
        }
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("search queries.fvecs extra");
        assert_eq!(a.subcommand, "search");
        assert_eq!(a.positional, vec!["queries.fvecs", "extra"]);
    }

    #[test]
    fn options_and_flags() {
        let a = parse("build --config=parlsh.toml --verbose --n=1000");
        assert_eq!(a.opt("config"), Some("parlsh.toml"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.opt_usize("n", 0).unwrap(), 1000);
        assert_eq!(a.opt_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn set_overrides_ordered() {
        let a = parse("experiment fig4 --set lsh.t=60 --set lsh.l=8");
        assert_eq!(
            a.overrides,
            vec![
                ("lsh.t".to_string(), "60".to_string()),
                ("lsh.l".to_string(), "8".to_string())
            ]
        );
    }

    #[test]
    fn bad_option_value_errors() {
        let a = parse("x --n=abc");
        assert!(a.opt_usize("n", 0).is_err());
    }

    #[test]
    fn set_requires_kv() {
        assert!(Args::parse(vec!["x".into(), "--set".into(), "oops".into()]).is_err());
    }
}
