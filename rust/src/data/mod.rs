//! Datasets: flat vector storage, synthetic SIFT-like generation, BIGANN
//! file formats, ground truth, and recall.

pub mod groundtruth;
pub mod io;
pub mod recall;
pub mod synth;

pub use groundtruth::ground_truth_scalar;
pub use recall::recall_at_k;
pub use synth::{SynthSpec, synthesize, distorted_queries};

/// A dense f32 dataset stored flat (row-major `[n][dim]`).
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub dim: usize,
    data: Vec<f32>,
}

impl Dataset {
    pub fn new(dim: usize) -> Dataset {
        assert!(dim > 0);
        Dataset { dim, data: Vec::new() }
    }

    pub fn with_capacity(dim: usize, n: usize) -> Dataset {
        assert!(dim > 0);
        Dataset { dim, data: Vec::with_capacity(dim * n) }
    }

    pub fn from_flat(dim: usize, data: Vec<f32>) -> Dataset {
        assert!(dim > 0 && data.len() % dim == 0);
        Dataset { dim, data }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    pub fn push(&mut self, v: &[f32]) {
        assert_eq!(v.len(), self.dim);
        self.data.extend_from_slice(v);
    }

    pub fn as_flat(&self) -> &[f32] {
        &self.data
    }

    /// Rows `[start, end)` as a borrowed sub-dataset view (flat slice).
    pub fn slice_flat(&self, start: usize, end: usize) -> &[f32] {
        &self.data[start * self.dim..end * self.dim]
    }

    /// Squared Euclidean distance between row `i` and an external vector.
    #[inline]
    pub fn sqdist_to(&self, i: usize, v: &[f32]) -> f32 {
        sqdist(self.get(i), v)
    }
}

/// Scalar squared L2 distance, 4-way unrolled — the *reduction-order
/// oracle* for every SIMD tier (DESIGN.md §Kernels): 4 independent
/// accumulators over 4-element chunks, folded left-associatively
/// `((acc0 + acc1) + acc2) + acc3`, then a sequential scalar remainder.
/// `runtime::kernels::sqdist` maps those accumulators onto vector lanes
/// and must stay bit-identical to this function; change one and you must
/// change both (the kernel property tests assert exact equality).
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc0 = 0f32;
    let mut acc1 = 0f32;
    let mut acc2 = 0f32;
    let mut acc3 = 0f32;
    let chunks = n / 4;
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        acc0 += d0 * d0;
        acc1 += d1 * d1;
        acc2 += d2 * d2;
        acc3 += d3 * d3;
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        acc += d * d;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_roundtrip() {
        let mut ds = Dataset::new(3);
        ds.push(&[1.0, 2.0, 3.0]);
        ds.push(&[4.0, 5.0, 6.0]);
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.get(1), &[4.0, 5.0, 6.0]);
        assert_eq!(ds.slice_flat(1, 2), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn sqdist_matches_naive() {
        use crate::util::minitest::check;
        check("sqdist-naive", 50, |g| {
            let n = g.usize_in(1, 200);
            let a = g.vec_f32(n, -10.0, 10.0);
            let b = g.vec_f32(n, -10.0, 10.0);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            let got = sqdist(&a, &b);
            assert!((got - naive).abs() <= 1e-3 * naive.max(1.0));
        });
    }

    #[test]
    fn sqdist_zero_on_self() {
        let v = vec![1.5f32; 128];
        assert_eq!(sqdist(&v, &v), 0.0);
    }

    #[test]
    #[should_panic]
    fn push_wrong_dim_panics() {
        let mut ds = Dataset::new(3);
        ds.push(&[1.0]);
    }
}
