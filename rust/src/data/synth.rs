//! Synthetic SIFT-like data (the BIGANN/Yahoo stand-in; DESIGN.md
//! §Substitutions).
//!
//! Real SIFT descriptors are 128-d, non-negative, bounded (≈[0,255]) and
//! heavily clustered (patches from the same scene/structure). LSH recall
//! behaviour depends on exactly that local density structure, so the
//! generator draws cluster centers uniformly and points as clamped Gaussians
//! around them. Queries follow the Yahoo protocol: *distorted* copies of
//! reference points (geometric/photometric distortion ≈ additive noise) —
//! so each query has near-duplicates in the reference set, like a real CBMR
//! workload.

use crate::data::Dataset;
use crate::util::rng::Rng;

/// Synthetic dataset specification.
#[derive(Clone, Copy, Debug)]
pub struct SynthSpec {
    pub n: usize,
    pub dim: usize,
    /// Number of Gaussian clusters ("scenes").
    pub clusters: usize,
    /// Per-coordinate std-dev within a cluster.
    pub cluster_std: f32,
    /// Value range [0, hi] (SIFT: 255).
    pub hi: f32,
    pub seed: u64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            n: 100_000,
            dim: 128,
            clusters: 2_000,
            cluster_std: 12.0,
            hi: 255.0,
            seed: 1,
        }
    }
}

/// Generate the reference dataset.
pub fn synthesize(spec: SynthSpec) -> Dataset {
    let mut rng = Rng::new(spec.seed);
    let centers = gen_centers(&mut rng, spec);
    let mut ds = Dataset::with_capacity(spec.dim, spec.n);
    let mut v = vec![0f32; spec.dim];
    for _ in 0..spec.n {
        let c = rng.below(spec.clusters as u64) as usize;
        let center = &centers[c * spec.dim..(c + 1) * spec.dim];
        for (slot, &mu) in v.iter_mut().zip(center) {
            *slot = (mu + spec.cluster_std * rng.gaussian_f32()).clamp(0.0, spec.hi);
        }
        ds.push(&v);
    }
    ds
}

fn gen_centers(rng: &mut Rng, spec: SynthSpec) -> Vec<f32> {
    // Real SIFT descriptors are *sparse and bursty*: most of the 128
    // orientation-histogram bins of a patch are near zero and a minority
    // carry the energy. Centers therefore activate each dimension with
    // probability ~0.4 (inactive bins sit near zero), which also keeps the
    // generator honest for partition studies — a fixed-dimension subsample
    // (like the Z-order curve's) often lands on inactive bins, exactly the
    // failure mode real descriptors inflict on space-filling curves.
    let margin = (2.0 * spec.cluster_std).min(spec.hi / 4.0);
    let mut centers = Vec::with_capacity(spec.clusters * spec.dim);
    for _ in 0..spec.clusters * spec.dim {
        if rng.f32() < 0.4 {
            centers.push(rng.range_f32(margin, spec.hi - margin));
        } else {
            centers.push(rng.range_f32(0.0, spec.cluster_std));
        }
    }
    centers
}

/// Generate `q` distorted queries from random reference points.
///
/// Returns `(queries, base_ids)`; `base_ids[i]` is the reference row query
/// `i` was distorted from (its likely — not guaranteed — nearest neighbor).
pub fn distorted_queries(
    reference: &Dataset,
    q: usize,
    distortion_std: f32,
    seed: u64,
) -> (Dataset, Vec<u32>) {
    let mut rng = Rng::new(seed ^ 0xD15707);
    let mut queries = Dataset::with_capacity(reference.dim, q);
    let mut bases = Vec::with_capacity(q);
    let n = reference.len();
    assert!(n > 0, "reference dataset is empty");
    let mut v = vec![0f32; reference.dim];
    for _ in 0..q {
        let base = rng.below(n as u64) as usize;
        let x = reference.get(base);
        for (slot, &val) in v.iter_mut().zip(x) {
            *slot = (val + distortion_std * rng.gaussian_f32()).max(0.0);
        }
        queries.push(&v);
        bases.push(base as u32);
    }
    (queries, bases)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sqdist;

    #[test]
    fn shape_and_range() {
        let spec = SynthSpec { n: 500, clusters: 10, ..Default::default() };
        let ds = synthesize(spec);
        assert_eq!(ds.len(), 500);
        assert_eq!(ds.dim, 128);
        for i in 0..ds.len() {
            for &x in ds.get(i) {
                assert!((0.0..=255.0).contains(&x));
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let spec = SynthSpec { n: 100, ..Default::default() };
        let a = synthesize(spec);
        let b = synthesize(spec);
        assert_eq!(a.as_flat(), b.as_flat());
        let c = synthesize(SynthSpec { seed: 2, ..spec });
        assert_ne!(a.as_flat(), c.as_flat());
    }

    #[test]
    fn clustered_structure_exists() {
        // Same-cluster pairs must be far closer than random pairs: compare
        // a query's distance to its base vs to a random row.
        let spec = SynthSpec { n: 2_000, clusters: 50, ..Default::default() };
        let ds = synthesize(spec);
        let (qs, bases) = distorted_queries(&ds, 50, 4.0, 9);
        let mut rng = Rng::new(123);
        let mut closer = 0;
        for i in 0..qs.len() {
            let d_base = sqdist(qs.get(i), ds.get(bases[i] as usize));
            let d_rand = sqdist(qs.get(i), ds.get(rng.below(2_000) as usize));
            if d_base < d_rand {
                closer += 1;
            }
        }
        assert!(closer >= 48, "distorted queries not near their base: {closer}/50");
    }

    #[test]
    fn distortion_scale_controls_distance() {
        let spec = SynthSpec { n: 1_000, ..Default::default() };
        let ds = synthesize(spec);
        let (q_small, b_small) = distorted_queries(&ds, 20, 1.0, 5);
        let (q_large, b_large) = distorted_queries(&ds, 20, 16.0, 5);
        let mean = |qs: &Dataset, bs: &[u32]| -> f32 {
            (0..qs.len())
                .map(|i| sqdist(qs.get(i), ds.get(bs[i] as usize)))
                .sum::<f32>()
                / qs.len() as f32
        };
        assert!(mean(&q_small, &b_small) * 10.0 < mean(&q_large, &b_large));
    }
}
