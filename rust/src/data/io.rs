//! BIGANN/TEXMEX file formats: `.fvecs`, `.bvecs`, `.ivecs`.
//!
//! Each record is a little-endian `i32` dimensionality followed by `dim`
//! values (f32 / u8 / i32 respectively). When the real BIGANN files are
//! present they plug straight into the experiment harness; otherwise the
//! synthetic generator stands in (DESIGN.md §Substitutions).

use crate::data::Dataset;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};

fn read_exact_opt<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<bool> {
    // Returns Ok(false) on clean EOF at a record boundary.
    let mut filled = 0;
    while filled < buf.len() {
        let n = r.read(&mut buf[filled..])?;
        if n == 0 {
            if filled == 0 {
                return Ok(false);
            }
            bail!("truncated record: got {filled} of {} bytes", buf.len());
        }
        filled += n;
    }
    Ok(true)
}

fn read_dim<R: Read>(r: &mut R) -> Result<Option<usize>> {
    let mut b = [0u8; 4];
    if !read_exact_opt(r, &mut b)? {
        return Ok(None);
    }
    let d = i32::from_le_bytes(b);
    if d <= 0 || d > 1 << 20 {
        bail!("implausible record dimension {d}");
    }
    Ok(Some(d as usize))
}

/// Read at most `limit` vectors from an `.fvecs` file (0 = all).
pub fn read_fvecs(path: &str, limit: usize) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path}"))?;
    let mut r = BufReader::new(f);
    let mut ds: Option<Dataset> = None;
    let mut buf: Vec<u8> = Vec::new();
    let mut row: Vec<f32> = Vec::new();
    let mut count = 0usize;
    while limit == 0 || count < limit {
        let Some(dim) = read_dim(&mut r)? else { break };
        buf.resize(dim * 4, 0);
        if !read_exact_opt(&mut r, &mut buf)? {
            bail!("truncated fvecs record");
        }
        row.clear();
        for c in buf.chunks_exact(4) {
            row.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        let ds = ds.get_or_insert_with(|| Dataset::new(dim));
        if ds.dim != dim {
            bail!("inconsistent dims: {} vs {dim}", ds.dim);
        }
        ds.push(&row);
        count += 1;
    }
    Ok(ds.unwrap_or_else(|| Dataset::new(1)))
}

/// Read at most `limit` vectors from a `.bvecs` file as f32 (0 = all).
pub fn read_bvecs(path: &str, limit: usize) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path}"))?;
    let mut r = BufReader::new(f);
    let mut ds: Option<Dataset> = None;
    let mut buf: Vec<u8> = Vec::new();
    let mut row: Vec<f32> = Vec::new();
    let mut count = 0usize;
    while limit == 0 || count < limit {
        let Some(dim) = read_dim(&mut r)? else { break };
        buf.resize(dim, 0);
        if !read_exact_opt(&mut r, &mut buf)? {
            bail!("truncated bvecs record");
        }
        row.clear();
        row.extend(buf.iter().map(|&b| b as f32));
        let ds = ds.get_or_insert_with(|| Dataset::new(dim));
        if ds.dim != dim {
            bail!("inconsistent dims: {} vs {dim}", ds.dim);
        }
        ds.push(&row);
        count += 1;
    }
    Ok(ds.unwrap_or_else(|| Dataset::new(1)))
}

/// Read `.ivecs` (e.g. BIGANN ground-truth files): rows of i32 ids.
pub fn read_ivecs(path: &str, limit: usize) -> Result<Vec<Vec<i32>>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path}"))?;
    let mut r = BufReader::new(f);
    let mut out = Vec::new();
    let mut buf: Vec<u8> = Vec::new();
    while limit == 0 || out.len() < limit {
        let Some(dim) = read_dim(&mut r)? else { break };
        buf.resize(dim * 4, 0);
        if !read_exact_opt(&mut r, &mut buf)? {
            bail!("truncated ivecs record");
        }
        out.push(
            buf.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        );
    }
    Ok(out)
}

/// Write a dataset as `.fvecs`.
pub fn write_fvecs(path: &str, ds: &Dataset) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path}"))?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.len() {
        w.write_all(&(ds.dim as i32).to_le_bytes())?;
        for &x in ds.get(i) {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Write ground-truth rows as `.ivecs`.
pub fn write_ivecs(path: &str, rows: &[Vec<i32>]) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path}"))?;
    let mut w = BufWriter::new(f);
    for row in rows {
        w.write_all(&(row.len() as i32).to_le_bytes())?;
        for &x in row {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synthesize, SynthSpec};

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("parlsh_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    #[test]
    fn fvecs_roundtrip() {
        let ds = synthesize(SynthSpec { n: 37, dim: 16, clusters: 3, ..Default::default() });
        let p = tmp("round.fvecs");
        write_fvecs(&p, &ds).unwrap();
        let back = read_fvecs(&p, 0).unwrap();
        assert_eq!(back.len(), 37);
        assert_eq!(back.as_flat(), ds.as_flat());
    }

    #[test]
    fn fvecs_limit() {
        let ds = synthesize(SynthSpec { n: 20, dim: 8, clusters: 2, ..Default::default() });
        let p = tmp("limit.fvecs");
        write_fvecs(&p, &ds).unwrap();
        let back = read_fvecs(&p, 5).unwrap();
        assert_eq!(back.len(), 5);
    }

    #[test]
    fn ivecs_roundtrip() {
        let rows = vec![vec![1, 2, 3], vec![7, 8, 9]];
        let p = tmp("round.ivecs");
        write_ivecs(&p, &rows).unwrap();
        assert_eq!(read_ivecs(&p, 0).unwrap(), rows);
    }

    #[test]
    fn bvecs_reads_bytes() {
        let p = tmp("mini.bvecs");
        let mut bytes = Vec::new();
        bytes.extend(4i32.to_le_bytes());
        bytes.extend([10u8, 20, 30, 255]);
        std::fs::write(&p, &bytes).unwrap();
        let ds = read_bvecs(&p, 0).unwrap();
        assert_eq!(ds.len(), 1);
        assert_eq!(ds.get(0), &[10.0, 20.0, 30.0, 255.0]);
    }

    #[test]
    fn truncated_record_errors() {
        let p = tmp("trunc.fvecs");
        let mut bytes = Vec::new();
        bytes.extend(4i32.to_le_bytes());
        bytes.extend(1.0f32.to_le_bytes()); // only 1 of 4 values
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_fvecs(&p, 0).is_err());
    }

    #[test]
    fn implausible_dim_errors() {
        let p = tmp("baddim.fvecs");
        std::fs::write(&p, (-3i32).to_le_bytes()).unwrap();
        assert!(read_fvecs(&p, 0).is_err());
    }
}
