//! Search-quality metric: recall@k (paper §V-A) — the fraction of the true
//! k nearest neighbors the method actually retrieved, averaged over queries.

/// recall@k for one query: |retrieved ∩ truth| / |truth|.
pub fn recall_one(retrieved: &[u32], truth: &[u32]) -> f64 {
    if truth.is_empty() {
        return 1.0;
    }
    let set: std::collections::HashSet<u32> = retrieved.iter().copied().collect();
    truth.iter().filter(|id| set.contains(id)).count() as f64 / truth.len() as f64
}

/// Mean recall@k over a query batch. `retrieved[i]` may be shorter than k
/// (LSH can return fewer candidates than requested).
pub fn recall_at_k(retrieved: &[Vec<u32>], truth: &[Vec<u32>]) -> f64 {
    assert_eq!(retrieved.len(), truth.len());
    if truth.is_empty() {
        return 1.0;
    }
    retrieved
        .iter()
        .zip(truth)
        .map(|(r, t)| recall_one(r, t))
        .sum::<f64>()
        / truth.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_recall() {
        assert_eq!(recall_one(&[1, 2, 3], &[3, 2, 1]), 1.0);
    }

    #[test]
    fn partial_recall() {
        assert!((recall_one(&[1, 2, 9], &[1, 2, 3]) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_retrieved_is_zero() {
        assert_eq!(recall_one(&[], &[1, 2]), 0.0);
    }

    #[test]
    fn batch_mean() {
        let r = vec![vec![1u32], vec![9u32]];
        let t = vec![vec![1u32], vec![1u32]];
        assert!((recall_at_k(&r, &t) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn extra_retrieved_does_not_hurt() {
        assert_eq!(recall_one(&[5, 4, 3, 2, 1], &[1, 2]), 1.0);
    }
}
