//! Exact k-NN ground truth (brute force) with file caching.
//!
//! Experiments need true neighbors to score recall. Brute force over the
//! scaled-down datasets is affordable once and cached as `.ivecs` keyed by a
//! content fingerprint, so repeated experiment runs skip recomputation.

use crate::core::topk::TopK;
use crate::data::{io, sqdist, Dataset};
use crate::util::threadpool::scope_chunks;
use anyhow::Result;

/// Brute-force exact k-NN for every query (scalar path, multithreaded).
pub fn ground_truth_scalar(
    reference: &Dataset,
    queries: &Dataset,
    k: usize,
    workers: usize,
) -> Vec<Vec<u32>> {
    assert_eq!(reference.dim, queries.dim);
    let results = scope_chunks(queries.len(), workers, |start, end| {
        let mut out = Vec::with_capacity(end - start);
        for qi in start..end {
            let q = queries.get(qi);
            let mut tk = TopK::new(k);
            for i in 0..reference.len() {
                tk.push(sqdist(reference.get(i), q), i as u32);
            }
            out.push(tk.into_sorted().into_iter().map(|(_, id)| id).collect());
        }
        out
    });
    results.into_iter().flatten().collect()
}

/// Cheap content fingerprint of the (reference, queries, k) triple.
fn fingerprint(reference: &Dataset, queries: &Dataset, k: usize) -> u64 {
    use crate::util::rng::mix64;
    let mut h = mix64(
        (reference.len() as u64) << 32 ^ queries.len() as u64 ^ (k as u64) << 16,
    );
    // Sample a few rows' bits — enough to key a local cache.
    let sample = |ds: &Dataset, h: &mut u64| {
        let n = ds.len();
        if n == 0 {
            return;
        }
        for i in [0, n / 2, n - 1] {
            for &x in ds.get(i).iter().take(8) {
                *h = mix64(*h ^ x.to_bits() as u64);
            }
        }
    };
    sample(reference, &mut h);
    sample(queries, &mut h);
    h
}

/// Ground truth with `.ivecs` caching under `cache_dir`.
pub fn ground_truth_cached(
    reference: &Dataset,
    queries: &Dataset,
    k: usize,
    workers: usize,
    cache_dir: &str,
) -> Result<Vec<Vec<u32>>> {
    std::fs::create_dir_all(cache_dir)?;
    let key = fingerprint(reference, queries, k);
    let path = format!("{cache_dir}/gt_{key:016x}_k{k}.ivecs");
    if std::path::Path::new(&path).exists() {
        let rows = io::read_ivecs(&path, 0)?;
        if rows.len() == queries.len() {
            return Ok(rows
                .into_iter()
                .map(|r| r.into_iter().map(|x| x as u32).collect())
                .collect());
        }
    }
    let gt = ground_truth_scalar(reference, queries, k, workers);
    let rows: Vec<Vec<i32>> = gt
        .iter()
        .map(|r| r.iter().map(|&x| x as i32).collect())
        .collect();
    io::write_ivecs(&path, &rows)?;
    Ok(gt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{distorted_queries, synthesize, SynthSpec};

    #[test]
    fn finds_exact_neighbors() {
        let ds = synthesize(SynthSpec { n: 300, dim: 16, clusters: 5, ..Default::default() });
        let (qs, bases) = distorted_queries(&ds, 10, 0.01, 3);
        let gt = ground_truth_scalar(&ds, &qs, 3, 2);
        for (i, row) in gt.iter().enumerate() {
            assert_eq!(row.len(), 3);
            // With near-zero distortion the base point must be the 1-NN.
            assert_eq!(row[0], bases[i], "query {i}");
        }
    }

    #[test]
    fn neighbors_sorted_by_distance() {
        let ds = synthesize(SynthSpec { n: 200, dim: 8, clusters: 4, ..Default::default() });
        let (qs, _) = distorted_queries(&ds, 5, 5.0, 7);
        let gt = ground_truth_scalar(&ds, &qs, 5, 1);
        for (qi, row) in gt.iter().enumerate() {
            let q = qs.get(qi);
            let dists: Vec<f32> = row.iter().map(|&id| sqdist(ds.get(id as usize), q)).collect();
            for w in dists.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }

    #[test]
    fn cache_roundtrip() {
        let dir = std::env::temp_dir().join("parlsh_gt_cache");
        let dir = dir.to_string_lossy();
        let ds = synthesize(SynthSpec { n: 100, dim: 8, clusters: 4, ..Default::default() });
        let (qs, _) = distorted_queries(&ds, 4, 2.0, 1);
        let a = ground_truth_cached(&ds, &qs, 3, 1, &dir).unwrap();
        let b = ground_truth_cached(&ds, &qs, 3, 1, &dir).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn workers_do_not_change_result() {
        let ds = synthesize(SynthSpec { n: 150, dim: 8, clusters: 3, ..Default::default() });
        let (qs, _) = distorted_queries(&ds, 6, 2.0, 2);
        assert_eq!(
            ground_truth_scalar(&ds, &qs, 4, 1),
            ground_truth_scalar(&ds, &qs, 4, 4)
        );
    }
}
