//! The experiment harness: one function per paper table/figure, shared by
//! the `cargo bench` targets and the `parlsh experiment <id>` CLI.
//!
//! Every experiment runs the *functional* distributed pipeline (exact
//! routing, messages, recall) on a scaled-down synthetic BIGANN/Yahoo
//! stand-in, then converts the measured per-copy work + per-link traffic
//! into cluster-scale time with the calibrated cost model (DESIGN.md
//! §Substitutions). Scale knobs come from env vars so CI can shrink runs:
//! `PARLSH_N` (reference size), `PARLSH_Q` (queries), `PARLSH_SCALAR=1`
//! (force the scalar compute path instead of PJRT artifacts).

use crate::config::{Config, ObjMapStrategy};
use crate::coordinator::{build_index, search, Cluster, SearchOutput};
use crate::core::lsh::HashFamily;
use crate::data::groundtruth::ground_truth_cached;
use crate::data::recall::recall_at_k;
use crate::data::synth::{distorted_queries, synthesize, SynthSpec};
use crate::data::Dataset;
use crate::metrics::Table;
use crate::runtime::engine::{Engine, EngineHasher, EngineRanker};
use crate::runtime::{Hasher, Ranker, SimdHasher, SimdRanker};
use crate::simnet::cost::{CostModel, MakespanReport};
use std::sync::{Arc, OnceLock};

/// Scale knobs (env-overridable).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn force_scalar() -> bool {
    std::env::var("PARLSH_SCALAR").map(|v| v == "1").unwrap_or(false)
}

static ENGINE: OnceLock<Option<Arc<Engine>>> = OnceLock::new();

/// The process-wide PJRT engine (None if artifacts are unavailable).
pub fn engine() -> Option<Arc<Engine>> {
    ENGINE
        .get_or_init(|| {
            if force_scalar() {
                return None;
            }
            let dir = std::env::var("PARLSH_ARTIFACTS")
                .unwrap_or_else(|_| "artifacts".to_string());
            match Engine::load(&dir) {
                Ok(e) => Some(Arc::new(e)),
                Err(err) => {
                    eprintln!(
                        "[parlsh] artifacts unavailable ({err}); using scalar path"
                    );
                    None
                }
            }
        })
        .clone()
}

/// Compute backends for one run (engine-backed when artifacts exist).
/// The ranker is `Arc`-shared so sessions can move it onto the streaming
/// executors' stage threads.
pub struct Backends {
    pub hasher: Box<dyn Hasher>,
    pub ranker: Arc<dyn Ranker>,
    pub engine_path: bool,
}

pub fn backends(cfg: &Config, dim: usize) -> Backends {
    let family = HashFamily::sample(dim, cfg.lsh);
    match engine() {
        Some(e) if e.dim() == dim => {
            e.set_family(&family).expect("set_family");
            // §Perf: hashing always goes through the compiled artifact (the
            // batched matmul wins by >10x); ranking is hybrid — SIMD heap
            // top-k for small candidate tiles, artifact for large ones (see
            // HybridRanker docs + EXPERIMENTS.md §Perf).
            let ranker = crate::runtime::HybridRanker {
                scalar: SimdRanker { dim },
                engine: Box::new(EngineRanker { engine: e.clone() }),
                threshold: crate::runtime::HybridRanker::threshold_from_env(8192),
            };
            Backends {
                hasher: Box::new(EngineHasher {
                    engine: e.clone(),
                    p_used: cfg.lsh.projections(),
                }),
                ranker: Arc::new(ranker),
                engine_path: true,
            }
        }
        _ => Backends {
            // SIMD tier (runtime-dispatched, bit-identical to the scalar
            // oracle — DESIGN.md §Kernels) with the pruning ranker.
            hasher: Box::new(SimdHasher::new(family)),
            ranker: Arc::new(SimdRanker { dim }),
            engine_path: false,
        },
    }
}

/// A synthetic experiment world: reference set, queries, ground truth.
pub struct World {
    pub data: Dataset,
    pub queries: Dataset,
    pub gt: Vec<Vec<u32>>,
}

/// Build the world for `cfg` (ground truth cached under `.cache/gt`).
pub fn world(cfg: &Config) -> World {
    // `data.source` selects the reference set: "synth" (default) or a path
    // to a real `.fvecs`/`.bvecs` file (e.g. BIGANN base vectors), truncated
    // to `data.n`. Queries are always the distorted-duplicate workload (the
    // Yahoo protocol) so recall is meaningful without an external GT file.
    let data = match cfg.data.source.as_str() {
        "synth" => synthesize(SynthSpec {
            n: cfg.data.n,
            dim: cfg.data.dim,
            clusters: cfg.data.clusters,
            cluster_std: cfg.data.cluster_std,
            hi: 255.0,
            seed: cfg.data.seed,
        }),
        path if path.ends_with(".fvecs") => {
            crate::data::io::read_fvecs(path, cfg.data.n).expect("read fvecs")
        }
        path if path.ends_with(".bvecs") => {
            crate::data::io::read_bvecs(path, cfg.data.n).expect("read bvecs")
        }
        other => panic!("data.source `{other}` is neither synth nor .fvecs/.bvecs"),
    };
    let (queries, _) = distorted_queries(
        &data,
        cfg.data.queries,
        cfg.data.distortion_std,
        cfg.data.seed ^ 0x51EED,
    );
    let gt = ground_truth_cached(&data, &queries, cfg.lsh.k, 4, ".cache/gt")
        .expect("ground truth");
    World { data, queries, gt }
}

/// One full run: build + search + recall + modeled cluster time.
pub struct RunResult {
    pub recall: f64,
    pub search_makespan: MakespanReport,
    pub build_makespan: MakespanReport,
    pub logical_msgs: u64,
    pub packets: u64,
    pub payload_bytes: u64,
    pub local_msgs: u64,
    pub wall_secs: f64,
    pub dists_computed: u64,
    pub dists_pruned: u64,
    pub dup_skipped: u64,
    pub dp_counts: Vec<usize>,
}

pub fn run_once(cfg: &Config, w: &World, cost: &CostModel) -> RunResult {
    let b = backends(cfg, w.data.dim);
    let mut cluster = build_index(cfg, &w.data, b.hasher.as_ref());
    let build_work = build_phase_work(&mut cluster);
    let build_makespan = cost.makespan(
        &cluster.placement,
        cfg.cluster.cores_per_node,
        &build_work,
        &cluster.build_meter,
        cfg.lsh.projections(),
    );
    let out: SearchOutput = search(&mut cluster, &w.queries, b.hasher.as_ref(), b.ranker.as_ref());
    let recall = recall_at_k(&out.retrieved_ids(), &w.gt);
    let search_makespan = cost.makespan(
        &cluster.placement,
        cfg.cluster.cores_per_node,
        &out.work,
        &out.meter,
        cfg.lsh.projections(),
    );
    let dists: u64 = out.work.iter().map(|(_, _, w)| w.dists_computed).sum();
    let pruned: u64 = out.work.iter().map(|(_, _, w)| w.dists_pruned).sum();
    let dups: u64 = out.work.iter().map(|(_, _, w)| w.dup_skipped).sum();
    RunResult {
        recall,
        search_makespan,
        build_makespan,
        logical_msgs: out.meter.logical_msgs,
        packets: out.meter.total_packets(),
        payload_bytes: out.meter.payload_bytes,
        local_msgs: out.meter.local_msgs,
        wall_secs: out.wall_secs,
        dists_computed: dists,
        dists_pruned: pruned,
        dup_skipped: dups,
        dp_counts: cluster.dp_object_counts(),
    }
}

/// Approximate the build phase's per-copy work from state contents
/// (build handlers count into stage state; IR work is tracked separately).
fn build_phase_work(
    cluster: &mut Cluster,
) -> Vec<(crate::dataflow::message::StageKind, u16, crate::dataflow::metrics::WorkStats)> {
    let head = cluster.build_head_work;
    cluster.take_work(&head)
}

// ------------------------------------------------------------------ fig 3

/// Weak scaling (paper Fig. 3): nodes and dataset grow proportionally;
/// efficiency = T(1 unit) / T(N units) with per-node work constant.
pub fn fig3_weak_scaling() -> Table {
    let cost = CostModel::default();
    let per_node_n = env_usize("PARLSH_N", 120_000) / 12;
    let q = env_usize("PARLSH_Q", 150);
    // (BI nodes, DP nodes) preserving the paper's 1:4 ratio; the paper's
    // largest point is (10, 40) = 51 nodes / 801 cores.
    let points = [(1usize, 4usize), (2, 8), (4, 16), (6, 24), (8, 32), (10, 40)];
    let mut table = Table::new(&["nodes", "cores", "n (scaled)", "modeled T(ms)", "efficiency"]);
    let mut t1 = None;
    for (bi, dp) in points {
        let mut cfg = Config::default();
        cfg.cluster.bi_nodes = bi;
        cfg.cluster.dp_nodes = dp;
        cfg.data.n = per_node_n * (bi + dp);
        cfg.data.queries = q;
        cfg.data.clusters = (cfg.data.n / 100).max(50);
        let w = world(&cfg);
        let r = run_once(&cfg, &w, &cost);
        let t = r.search_makespan.makespan_secs;
        let t1v = *t1.get_or_insert(t);
        let eff = t1v / t;
        table.row(&[
            format!("{}", bi + dp + 1),
            format!("{}", cfg.cluster.total_cores()),
            format!("{}", cfg.data.n),
            format!("{:.2}", t * 1e3),
            format!("{eff:.3}"),
        ]);
    }
    table
}

// ------------------------------------------------------- fig 4 + table II

pub struct MultiprobePoint {
    pub t: usize,
    pub recall: f64,
    pub modeled_secs: f64,
    pub payload_gb: f64,
    pub logical_msgs: u64,
    pub dists: u64,
    pub dups: u64,
}

/// Probe sweep (paper Fig. 4 + Table II): recall and time vs T, plus the
/// communication volume and message counts.
pub fn multiprobe_sweep(ts: &[usize]) -> Vec<MultiprobePoint> {
    let cost = CostModel::default();
    let mut cfg = Config::default();
    cfg.data.n = env_usize("PARLSH_N", 200_000);
    cfg.data.queries = env_usize("PARLSH_Q", 200);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    let w = world(&cfg);
    let mut out = Vec::new();
    for &t in ts {
        cfg.lsh.t = t;
        let r = run_once(&cfg, &w, &cost);
        out.push(MultiprobePoint {
            t,
            recall: r.recall,
            modeled_secs: r.search_makespan.makespan_secs,
            payload_gb: r.payload_bytes as f64 / 1e9,
            logical_msgs: r.logical_msgs,
            dists: r.dists_computed,
            dups: r.dup_skipped,
        });
    }
    out
}

pub fn fig4_table(points: &[MultiprobePoint]) -> Table {
    let mut table = Table::new(&["T", "recall", "modeled T(ms)", "time ratio", "probe ratio"]);
    let base = points.first().map(|p| (p.t, p.modeled_secs));
    for p in points {
        let (t0, s0) = base.unwrap();
        table.row(&[
            format!("{}", p.t),
            format!("{:.3}", p.recall),
            format!("{:.2}", p.modeled_secs * 1e3),
            format!("{:.2}x", p.modeled_secs / s0),
            format!("{:.2}x", p.t as f64 / t0 as f64),
        ]);
    }
    table
}

pub fn table2(points: &[MultiprobePoint]) -> Table {
    let mut table = Table::new(&["T", "volume (GB)", "# messages (x10^6)", "dists", "dup skipped"]);
    for p in points {
        table.row(&[
            format!("{}", p.t),
            format!("{:.4}", p.payload_gb),
            format!("{:.4}", p.logical_msgs as f64 / 1e6),
            format!("{}", p.dists),
            format!("{}", p.dups),
        ]);
    }
    table
}

// ----------------------------------------------------------- table III

/// M sweep (paper Table III): selectivity vs time/recall at fixed T, L.
pub fn table3_m_sweep(ms: &[usize]) -> Table {
    let cost = CostModel::default();
    let mut cfg = Config::default();
    cfg.lsh.t = 30;
    cfg.data.n = env_usize("PARLSH_N", 200_000);
    cfg.data.queries = env_usize("PARLSH_Q", 200);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    let w = world(&cfg);
    let mut table = Table::new(&["M", "modeled T(ms)", "recall", "dists/query"]);
    for &m in ms {
        cfg.lsh.m = m;
        let r = run_once(&cfg, &w, &cost);
        table.row(&[
            format!("{m}"),
            format!("{:.2}", r.search_makespan.makespan_secs * 1e3),
            format!("{:.3}", r.recall),
            format!("{:.0}", r.dists_computed as f64 / cfg.data.queries as f64),
        ]);
    }
    table
}

// -------------------------------------------------------------- fig 5

/// L sweep at iso-recall (paper Fig. 5): for each L, grow T until recall
/// reaches `target`, report the modeled time at that point.
pub fn fig5_l_sweep(ls: &[usize], target: f64) -> Table {
    let cost = CostModel::default();
    let mut cfg = Config::default();
    cfg.data.n = env_usize("PARLSH_N", 200_000);
    cfg.data.queries = env_usize("PARLSH_Q", 200);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    let w = world(&cfg);
    let mut table = Table::new(&[
        "L",
        "T (tuned)",
        "bucket visits (LxT)",
        "recall",
        "modeled T(ms)",
        "dists/query",
    ]);
    for &l in ls {
        cfg.lsh.l = l;
        let mut t = 1usize;
        let mut last = None;
        while t <= 512 {
            cfg.lsh.t = t;
            let r = run_once(&cfg, &w, &cost);
            let recall = r.recall;
            last = Some((t, r));
            if recall >= target {
                break;
            }
            t = (t * 2).max(t + 1);
        }
        let (t, r) = last.unwrap();
        table.row(&[
            format!("{l}"),
            format!("{t}"),
            format!("{}", l * t),
            format!("{:.3}", r.recall),
            format!("{:.2}", r.search_makespan.makespan_secs * 1e3),
            format!("{:.0}", r.dists_computed as f64 / cfg.data.queries as f64),
        ]);
    }
    table
}

// -------------------------------------------------------------- fig 6

/// Partition strategies (paper Fig. 6 + §V-E): time, messages, imbalance.
pub fn fig6_partition() -> Table {
    let cost = CostModel::default();
    let mut cfg = Config::default();
    cfg.lsh.t = 60;
    cfg.data.n = env_usize("PARLSH_N", 200_000);
    cfg.data.queries = env_usize("PARLSH_Q", 200);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    let w = world(&cfg);
    let mut table = Table::new(&[
        "obj_map",
        "modeled T(ms)",
        "# messages (x10^6)",
        "volume (GB)",
        "imbalance %",
        "recall",
    ]);
    for strat in [ObjMapStrategy::Mod, ObjMapStrategy::ZOrder, ObjMapStrategy::Lsh] {
        cfg.stream.obj_map = strat;
        let r = run_once(&cfg, &w, &cost);
        let imb = crate::partition::imbalance(&r.dp_counts);
        table.row(&[
            strat.name().to_string(),
            format!("{:.2}", r.search_makespan.makespan_secs * 1e3),
            format!("{:.4}", r.logical_msgs as f64 / 1e6),
            format!("{:.4}", r.payload_bytes as f64 / 1e9),
            format!("{:.2}", imb.max_over_mean_pct),
            format!("{:.3}", r.recall),
        ]);
    }
    table
}

// ------------------------------------------------------------ ablation

/// Intra-stage parallelism ablation (paper §V-B: one multithreaded copy per
/// node vs one process per core → >6× fewer messages).
pub fn ablation_intrastage() -> Table {
    let cost = CostModel::default();
    let mut cfg = Config::default();
    // T=90 and coarser buckets so candidate lists reach paper-scale volume
    // (thousands per query at 10^9 vectors); the partition-count effect on
    // message counts only shows once candidates saturate the 640 per-core
    // partitions.
    cfg.lsh.t = 90;
    cfg.lsh.w = 2000.0;
    cfg.data.n = env_usize("PARLSH_N", 200_000);
    cfg.data.queries = env_usize("PARLSH_Q", 150);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    let w = world(&cfg);
    let mut table = Table::new(&[
        "topology",
        "copies (BI+DP)",
        "# messages (x10^6)",
        "packets (x10^6)",
        "modeled T(ms)",
        "msg ratio",
    ]);
    let mut base_msgs = None;
    for per_core in [false, true] {
        cfg.cluster.per_core_copies = per_core;
        let r = run_once(&cfg, &w, &cost);
        let base = *base_msgs.get_or_insert(r.logical_msgs);
        table.row(&[
            if per_core { "per-core".into() } else { "per-node".to_string() },
            format!(
                "{}",
                cfg.cluster.bi_copies() + cfg.cluster.dp_copies()
            ),
            format!("{:.4}", r.logical_msgs as f64 / 1e6),
            format!("{:.4}", r.packets as f64 / 1e6),
            format!("{:.2}", r.search_makespan.makespan_secs * 1e3),
            format!("{:.2}x", r.logical_msgs as f64 / base as f64),
        ]);
    }
    table
}

/// Ablation: labeled-stream message aggregation (DESIGN.md design choice).
/// Aggregation leaves logical messages/bytes unchanged but collapses
/// network packets — the per-packet latency term in the cluster model.
pub fn ablation_aggregation() -> Table {
    let cost = CostModel::default();
    let mut cfg = Config::default();
    cfg.data.n = env_usize("PARLSH_N", 100_000);
    cfg.data.queries = env_usize("PARLSH_Q", 150);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    let w = world(&cfg);
    let mut table = Table::new(&[
        "agg buffer",
        "logical msgs",
        "packets",
        "modeled T(ms)",
    ]);
    for agg in [0usize, 4 * 1024, 64 * 1024] {
        cfg.stream.agg_bytes = agg;
        let r = run_once(&cfg, &w, &cost);
        table.row(&[
            if agg == 0 { "off".into() } else { format!("{} KiB", agg / 1024) },
            format!("{}", r.logical_msgs),
            format!("{}", r.packets),
            format!("{:.2}", r.search_makespan.makespan_secs * 1e3),
        ]);
    }
    table
}

/// Ablation: asynchronous overlap of communication and computation (the
/// paper's design (iv)) vs a synchronous model (node time = comp + net).
pub fn ablation_async() -> Table {
    let mut cfg = Config::default();
    cfg.data.n = env_usize("PARLSH_N", 100_000);
    cfg.data.queries = env_usize("PARLSH_Q", 150);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    cfg.stream.agg_bytes = 0; // stress the per-packet term
    let w = world(&cfg);
    let mut table = Table::new(&["overlap", "modeled T(ms)"]);
    for overlap in [true, false] {
        let mut cost = CostModel::default();
        cost.async_overlap = overlap;
        let r = run_once(&cfg, &w, &cost);
        table.row(&[
            if overlap { "async (max)".into() } else { "sync (sum)".to_string() },
            format!("{:.2}", r.search_makespan.makespan_secs * 1e3),
        ]);
    }
    table
}

// ---------------------------------------------------- executor comparison

/// Inline vs threaded (open loop) vs threaded (batched admission): the same
/// build + search workload through each transport of the executor seam
/// (DESIGN.md §Executor seam). Reports build wall time, search throughput
/// and completion-latency percentiles; results must agree across rows (the
/// differential tests assert it), only the time axis moves.
pub fn executor_comparison() -> Table {
    use crate::coordinator::{build_index_on, search_on};
    use crate::dataflow::exec::{Executor, InlineExecutor, ThreadedExecutor};
    use crate::metrics::latency_stats;

    let mut cfg = Config::default();
    cfg.cluster.bi_nodes = 2;
    cfg.cluster.dp_nodes = 8;
    cfg.lsh.t = 16;
    cfg.data.n = env_usize("PARLSH_N", 60_000);
    cfg.data.queries = env_usize("PARLSH_Q", 300);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    let window = env_usize("PARLSH_INFLIGHT", 8);
    let w = world(&cfg);
    let b = backends(&cfg, w.data.dim);

    let mut table = Table::new(&[
        "executor",
        "build (s)",
        "search q/s",
        "mean ms",
        "p99 ms",
        "recall",
        "pruned",
        "mem",
    ]);
    let rows: [(&str, &dyn Executor, usize); 3] = [
        ("inline", &InlineExecutor, 0),
        ("threaded (open loop)", &ThreadedExecutor, 0),
        ("threaded (batched)", &ThreadedExecutor, window),
    ];
    for (name, exec, inflight) in rows {
        cfg.stream.inflight = inflight;
        let mut cluster = build_index_on(exec, &cfg, &w.data, b.hasher.as_ref());
        let out = search_on(
            exec,
            &mut cluster,
            &w.queries,
            b.hasher.as_ref(),
            b.ranker.as_ref(),
        );
        let lat = latency_stats(&out.per_query_secs);
        let recall = recall_at_k(&out.retrieved_ids(), &w.gt);
        // Early-abandoned candidates (SimdRanker's partial-sum bound);
        // identical across executors because per-message rank inputs are.
        let pruned: u64 = out.work.iter().map(|(_, _, w)| w.dists_pruned).sum();
        // Exact storage-engine residency: largest single copy (the
        // bytes_resident gauge max-merges, it never sums).
        let mem: u64 = out
            .work
            .iter()
            .map(|(_, _, w)| w.bytes_resident)
            .max()
            .unwrap_or(0);
        let label = if inflight > 0 {
            format!("{name} W={inflight}")
        } else {
            name.to_string()
        };
        table.row(&[
            label,
            format!("{:.2}", cluster.build_wall_secs),
            format!("{:.1}", w.queries.len() as f64 / out.wall_secs),
            format!("{:.2}", lat.mean_ms),
            format!("{:.2}", lat.p99_ms),
            format!("{recall:.3}"),
            format!("{pruned}"),
            format!("{:.1} MiB", mem as f64 / (1024.0 * 1024.0)),
        ]);
    }
    table
}

// ------------------------------------------------------------------ net

/// Socket-transport partition comparison: the same build + search workload
/// per `obj_map` strategy, run twice — in-process inline (the `wire_size`
/// traffic *model*) and across real OS processes on loopback TCP (measured
/// frame bytes from the `net` codec). This is the paper's Fig. 6 claim
/// ("fewer messages") exercised over an actual wire. Returns the table and
/// the `BENCH_net.json` document (table + per-strategy per-link bytes).
///
/// Topology is deliberately tiny (1 BI + 2 DP workers + this driver = 4 OS
/// processes); scale the workload with `PARLSH_N` / `PARLSH_Q`.
pub fn net_comparison() -> anyhow::Result<(Table, String)> {
    use crate::coordinator::{build_index_on, search_on};
    use crate::net::NetSession;

    let mut cfg = Config::default();
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 2;
    cfg.lsh.t = 16;
    cfg.data.n = env_usize("PARLSH_N", 30_000);
    cfg.data.queries = env_usize("PARLSH_Q", 100);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    let w = world(&cfg);
    let b = backends(&cfg, w.data.dim);

    let mut table = Table::new(&[
        "obj_map",
        "wire MB (tcp)",
        "model MB",
        "tcp packets",
        "logical msgs",
        "msgs/query",
        "recall",
    ]);
    let mut strategies_json: Vec<String> = Vec::new();
    for strat in [ObjMapStrategy::Mod, ObjMapStrategy::ZOrder, ObjMapStrategy::Lsh] {
        cfg.stream.obj_map = strat;
        // The wire_size model, for the same workload (inline executor).
        let mut model_cluster = build_index(&cfg, &w.data, b.hasher.as_ref());
        let model_out =
            search(&mut model_cluster, &w.queries, b.hasher.as_ref(), b.ranker.as_ref());
        // The real thing: multi-process over loopback TCP.
        let sess = NetSession::launch(&cfg, w.data.dim)?;
        let mut cluster = build_index_on(sess.executor(), &cfg, &w.data, b.hasher.as_ref());
        let out = search_on(
            sess.executor(),
            &mut cluster,
            &w.queries,
            b.hasher.as_ref(),
            b.ranker.as_ref(),
        );
        sess.shutdown()?;
        let recall = recall_at_k(&out.retrieved_ids(), &w.gt);

        println!("per-link wire bytes, search phase ({}):", strat.name());
        print!("{}", out.meter.link_report());
        let link_objs: Vec<String> = out
            .meter
            .sorted_links()
            .into_iter()
            .map(|((src, dst), l)| {
                format!(
                    "{{\"src\":{src},\"dst\":{dst},\"packets\":{},\"bytes\":{}}}",
                    l.packets, l.bytes
                )
            })
            .collect();
        strategies_json.push(format!(
            "\"{}\":{{\"wire_bytes\":{},\"model_bytes\":{},\"tcp_packets\":{},\"logical_msgs\":{},\"recall\":{:.4},\"links\":[{}]}}",
            strat.name(),
            out.meter.total_bytes(),
            model_out.meter.payload_bytes,
            out.meter.total_packets(),
            out.meter.logical_msgs,
            recall,
            link_objs.join(",")
        ));
        table.row(&[
            strat.name().to_string(),
            format!("{:.3}", out.meter.total_bytes() as f64 / 1e6),
            format!("{:.3}", model_out.meter.payload_bytes as f64 / 1e6),
            format!("{}", out.meter.total_packets()),
            format!("{}", out.meter.logical_msgs),
            format!("{:.1}", out.meter.logical_msgs as f64 / w.queries.len() as f64),
            format!("{recall:.3}"),
        ]);
    }
    // Replicated, self-healing fleet (DESIGN.md §Cluster topology): the
    // same workload with `cluster.replication = 2` (6 worker slots), one
    // replica killed mid-stream, per replica-routing strategy. Measures
    // completed/retargeted queries, whether the dead slot rejoined, the
    // per-replica driver->worker wire bytes (how the route spread query
    // traffic before and after the kill), and recall — which must not
    // care that a replica died.
    use crate::config::ReplicaRoute;
    use crate::coordinator::session::IndexSession;
    let mut rep_table = Table::new(&[
        "replica_route",
        "replicas",
        "completed",
        "retargeted",
        "rejoined",
        "wire MB (tcp)",
        "recall",
    ]);
    let mut rep_json: Vec<String> = Vec::new();
    cfg.stream.obj_map = ObjMapStrategy::Mod;
    cfg.cluster.replication = 2;
    for route in [ReplicaRoute::RoundRobin, ReplicaRoute::Layered] {
        cfg.cluster.replica_route = route;
        let sess = NetSession::launch(&cfg, w.data.dim)?;
        let mut cluster = build_index_on(sess.executor(), &cfg, &w.data, b.hasher.as_ref());
        let head = cluster.placement.head_node;
        let n_slots = cluster.placement.total_slots();
        let (retrieved, stats) = {
            let session = IndexSession::attach(
                sess.executor(),
                &mut cluster,
                b.hasher.as_ref(),
                Some(b.ranker.clone()),
            );
            let half = w.queries.len() / 2;
            for qi in 0..half {
                session.submit(w.queries.get(qi));
            }
            // One replica of logical node 1 dies mid-stream; its sibling
            // slot absorbs the retargeted queries.
            sess.kill_worker(1)?;
            for qi in half..w.queries.len() {
                session.submit(w.queries.get(qi));
            }
            let mut retrieved: Vec<Vec<u32>> = vec![Vec::new(); w.queries.len()];
            for (t, hits) in session.drain() {
                retrieved[t.0 as usize] = hits.into_iter().map(|(_, id)| id).collect();
            }
            (retrieved, session.close())
        };
        let rejoined = sess.heal_worker(1).is_ok();
        let recall = recall_at_k(&retrieved, &w.gt);
        let per_slot: Vec<u64> = (0..n_slots as u16)
            .map(|slot| stats.search_meter.links().get(&(head, slot)).map_or(0, |l| l.bytes))
            .collect();
        println!("per-replica driver->worker wire bytes, search phase ({}):", route.name());
        for (slot, bytes) in per_slot.iter().enumerate() {
            println!("  slot {slot}: {bytes} bytes");
        }
        rep_json.push(format!(
            "\"{}\":{{\"replicas\":2,\"completed\":{},\"retargeted\":{},\"rejoined\":{},\"wire_bytes\":{},\"per_slot_bytes\":[{}],\"recall\":{:.4}}}",
            route.name(),
            stats.queries_completed,
            stats.queries_retargeted,
            rejoined,
            stats.search_meter.total_bytes(),
            per_slot.iter().map(u64::to_string).collect::<Vec<_>>().join(","),
            recall
        ));
        rep_table.row(&[
            route.name().to_string(),
            "2".to_string(),
            format!("{}", stats.queries_completed),
            format!("{}", stats.queries_retargeted),
            format!("{rejoined}"),
            format!("{:.3}", stats.search_meter.total_bytes() as f64 / 1e6),
            format!("{recall:.3}"),
        ]);
        sess.shutdown()?;
    }
    println!("== Replication: kill one replica mid-stream, per routing strategy ==");
    rep_table.print();
    let json = format!(
        "{{\"experiment\":\"net\",\"table\":{},\"strategies\":{{{}}},\"replication\":{{\"table\":{},{}}}}}\n",
        table.to_json(),
        strategies_json.join(","),
        rep_table.to_json(),
        rep_json.join(",")
    );
    Ok((table, json))
}

// ------------------------------------------------------------ streaming

/// How a streaming experiment feeds queries into the session.
#[derive(Clone, Copy, Debug)]
enum AdmissionMode {
    /// Pumped (batch) admission: the whole set is submitted up front and
    /// claimed as it completes — every query's latency includes the
    /// queueing delay of the batch ahead of it (saturation measurement).
    Pumped,
    /// Paced streaming (closed loop): the client claims completions
    /// whenever W submissions are outstanding — the serving loop of a
    /// latency-critical deployment.
    Paced(usize),
    /// Open-loop Poisson arrivals at `lambda` queries/second: arrival
    /// times are drawn up front from an exponential inter-arrival process
    /// (util/rng, deterministic in `seed`) and latency is measured from
    /// the *scheduled* arrival — so queueing under overload is charged to
    /// the queries that suffered it (no coordinated omission). This is
    /// the paper's fixed-offered-load operating point, vs the
    /// at-saturation numbers of the other modes.
    Poisson { lambda: f64, seed: u64 },
}

/// Wall-clock submit→claim latency for every query of `w` through one
/// serving session, under `mode`.
fn streaming_mode_latencies(
    exec: &dyn crate::dataflow::exec::Executor,
    cluster: &mut Cluster,
    w: &World,
    b: &Backends,
    mode: AdmissionMode,
) -> (Vec<f64>, f64) {
    use crate::coordinator::session::IndexSession;
    use crate::util::rng::Rng;
    use std::time::{Duration, Instant};

    let session =
        IndexSession::attach(exec, cluster, b.hasher.as_ref(), Some(b.ranker.clone()));
    let qs = &w.queries;
    let t0 = Instant::now();
    let mut submit_ts: Vec<Instant> = Vec::with_capacity(qs.len());
    let mut lat = vec![0f64; qs.len()];
    match mode {
        AdmissionMode::Pumped => {
            for qi in 0..qs.len() {
                submit_ts.push(Instant::now());
                session.submit(qs.get(qi));
            }
            while let Some((t, _)) = session.recv() {
                lat[t.0 as usize] = submit_ts[t.0 as usize].elapsed().as_secs_f64();
            }
        }
        AdmissionMode::Paced(wdw) => {
            for qi in 0..qs.len() {
                submit_ts.push(Instant::now());
                session.submit(qs.get(qi));
                while session.in_flight() >= wdw {
                    match session.recv() {
                        Some((t, _)) => {
                            lat[t.0 as usize] =
                                submit_ts[t.0 as usize].elapsed().as_secs_f64();
                        }
                        None => break,
                    }
                }
            }
            while let Some((t, _)) = session.recv() {
                lat[t.0 as usize] = submit_ts[t.0 as usize].elapsed().as_secs_f64();
            }
        }
        AdmissionMode::Poisson { lambda, seed } => {
            let lambda = lambda.max(1e-3);
            let mut rng = Rng::new(seed);
            let mut offset = 0f64;
            for qi in 0..qs.len() {
                // exponential inter-arrival at rate lambda (u in (0,1])
                offset += -(1.0 - rng.f64()).ln() / lambda;
                let arrive = t0 + Duration::from_secs_f64(offset);
                // claim completions while waiting out the arrival gap
                loop {
                    let now = Instant::now();
                    if now >= arrive {
                        break;
                    }
                    match session.try_recv() {
                        Some((t, _)) => {
                            lat[t.0 as usize] =
                                submit_ts[t.0 as usize].elapsed().as_secs_f64();
                        }
                        None => std::thread::sleep(
                            arrive.saturating_duration_since(now).min(Duration::from_micros(200)),
                        ),
                    }
                }
                // latency clocks from the *scheduled* arrival, so a late
                // submit (previous arrival still blocking) is charged
                submit_ts.push(arrive);
                session.submit(qs.get(qi));
            }
            while let Some((t, _)) = session.recv() {
                lat[t.0 as usize] = submit_ts[t.0 as usize].elapsed().as_secs_f64();
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    session.close();
    (lat, wall)
}

fn streaming_row(table: &mut Table, transport: &str, label: &str, lat: &[f64], wall: f64) {
    let st = crate::metrics::latency_stats(lat);
    table.row(&[
        transport.to_string(),
        label.to_string(),
        format!("{:.2}", st.mean_ms),
        format!("{:.2}", st.p50_ms),
        format!("{:.2}", st.p99_ms),
        format!("{:.1}", lat.len() as f64 / wall.max(1e-9)),
    ]);
}

/// Streaming vs pumped admission (`parlsh experiment streaming`): the
/// per-query latency argument for the serving regime — a query that
/// enters the pipeline the moment it arrives vs one that waits behind a
/// batch, plus an **open-loop Poisson arrival schedule** (`--lambda`,
/// queries/second; default 200) measuring p50/p99 at *fixed offered load*
/// instead of at saturation (the ROADMAP follow-on: the paper's
/// 90%-efficiency operating point). Runs on the threaded executor and
/// across real worker processes on the socket transport; the index is
/// built once per transport and every admission mode reuses the same
/// resident state. Returns the table and the `BENCH_streaming.json`
/// document.
pub fn streaming_comparison(lambda: Option<f64>) -> anyhow::Result<(Table, String)> {
    use crate::coordinator::build_index_on;
    use crate::dataflow::exec::ThreadedExecutor;
    use crate::net::NetSession;

    let mut cfg = Config::default();
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 2;
    cfg.lsh.t = 16;
    cfg.data.n = env_usize("PARLSH_N", 30_000);
    cfg.data.queries = env_usize("PARLSH_Q", 150);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    let w = world(&cfg);
    let b = backends(&cfg, w.data.dim);

    let lam = lambda.unwrap_or(200.0);
    let modes: Vec<(String, AdmissionMode)> = vec![
        ("pumped (batch)".into(), AdmissionMode::Pumped),
        ("streaming W=1".into(), AdmissionMode::Paced(1)),
        ("streaming W=4".into(), AdmissionMode::Paced(4)),
        (
            format!("poisson {lam:.0}/s (open loop)"),
            AdmissionMode::Poisson { lambda: lam, seed: 0x9D15 },
        ),
    ];
    let mut table =
        Table::new(&["transport", "admission", "mean ms", "p50 ms", "p99 ms", "q/s"]);

    {
        let mut cluster = build_index_on(&ThreadedExecutor, &cfg, &w.data, b.hasher.as_ref());
        for (label, mode) in &modes {
            let (lat, wall) =
                streaming_mode_latencies(&ThreadedExecutor, &mut cluster, &w, &b, *mode);
            streaming_row(&mut table, "threaded", label, &lat, wall);
        }
    }
    {
        let sess = NetSession::launch(&cfg, w.data.dim)?;
        let mut cluster = build_index_on(sess.executor(), &cfg, &w.data, b.hasher.as_ref());
        for (label, mode) in &modes {
            let (lat, wall) =
                streaming_mode_latencies(sess.executor(), &mut cluster, &w, &b, *mode);
            streaming_row(&mut table, "socket", label, &lat, wall);
        }
        sess.shutdown()?;
    }

    let slo = streaming_tag_slo(&cfg, &w, &b);
    println!("== Per-tag SLO: mixed gold/silver tenants on one session ==");
    slo.print();

    let json = format!(
        "{{\"experiment\":\"streaming\",\"table\":{},\"qos\":{{\"tags\":\"gold:2,silver:1\",\"table\":{}}}}}\n",
        table.to_json(),
        slo.to_json()
    );
    Ok((table, json))
}

/// The per-tag SLO table (`[qos] tags`, DESIGN.md §QoS scheduler): two
/// tenants — gold (weight 2) and silver (weight 1) — interleave 2:1 on
/// one resident threaded session under a bounded admission window, and
/// the session's per-tag accounts render as SLO rows: counts, service
/// latency percentiles straight off each class's `LatencySummary`
/// reservoir (`quantile`), and the work attributed to the class.
fn streaming_tag_slo(cfg: &Config, w: &World, b: &Backends) -> Table {
    use crate::coordinator::build_index_on;
    use crate::coordinator::session::IndexSession;
    use crate::dataflow::exec::ThreadedExecutor;
    use crate::dataflow::message::QueryOptions;

    let mut cfg = cfg.clone();
    cfg.qos.tags = "gold:2,silver:1".to_string();
    cfg.stream.pending_cap = 16; // the WFQ shares need a window to split
    let mut cluster = build_index_on(&ThreadedExecutor, &cfg, &w.data, b.hasher.as_ref());
    let mut table = Table::new(&[
        "tag", "weight", "submitted", "completed", "mean ms", "p50 ms", "p99 ms", "dists",
    ]);
    let session = IndexSession::attach(
        &ThreadedExecutor,
        &mut cluster,
        b.hasher.as_ref(),
        Some(b.ranker.clone()),
    );
    for qi in 0..w.queries.len() {
        // 2:1 interleave matching the 2:1 weights
        let tag = if qi % 3 < 2 { 1 } else { 2 };
        session.submit_with(w.queries.get(qi), QueryOptions { tag, ..Default::default() });
        // claim as we go so the run holds O(pending) state
        while session.try_recv().is_some() {}
    }
    let _ = session.drain();
    let stats = session.close();
    for r in &stats.per_tag {
        table.row(&[
            r.name.clone(),
            format!("{}", r.weight),
            format!("{}", r.submitted),
            format!("{}", r.completed),
            format!("{:.2}", r.latency.stats().mean_ms),
            format!("{:.2}", r.latency.quantile(50.0) * 1e3),
            format!("{:.2}", r.latency.quantile(99.0) * 1e3),
            format!("{}", r.work.dists_computed),
        ]);
    }
    table
}

// ------------------------------------------------------------ front door

/// One `experiment front` point: `conns` concurrent [`crate::net::front::Client`]s
/// driving a front-door server over real loopback TCP, the server's event
/// loop and resident session on the calling thread. Each client runs a
/// closed-loop pipelined burst for the time window, with a per-client
/// probe budget (mixed plans). Reports client-measured submit→claim
/// latency and the per-client completion spread — the admission-fairness
/// number: with per-lane shares at the gate, max/min stays bounded even
/// though every client pushes at full rate.
fn front_point(
    exec: &dyn crate::dataflow::exec::Executor,
    backing: &str,
    cfg: &Config,
    w: &World,
    b: &Backends,
    conns: usize,
    secs: f64,
    table: &mut Table,
) -> anyhow::Result<()> {
    use crate::coordinator::session::IndexSession;
    use crate::dataflow::message::QueryOptions;
    use crate::net::front;
    use std::time::{Duration, Instant};

    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let per_client: Vec<(Vec<f64>, usize, f64)> = std::thread::scope(
        |s| -> anyhow::Result<Vec<(Vec<f64>, usize, f64)>> {
            let barrier = std::sync::Barrier::new(conns);
            let barrier = &barrier;
            let addr = &addr;
            let handles: Vec<_> = (0..conns)
                .map(|i| {
                    s.spawn(move || -> anyhow::Result<(Vec<f64>, usize, f64)> {
                        let drive = || -> anyhow::Result<(Vec<f64>, usize, f64)> {
                            let mut client =
                                front::Client::connect_with(addr, 1200, 25, 64 << 20)?;
                            // mixed plans: every client pins its own probe
                            // budget (0 = inherit) and tags itself
                            let opts = QueryOptions {
                                probes: [0u32, 8, 16, 32][i % 4],
                                tag: i as u32 + 1,
                                ..Default::default()
                            };
                            let t0 = Instant::now();
                            let deadline = t0 + Duration::from_secs_f64(secs);
                            let window = 4usize;
                            let mut submitted_at = std::collections::HashMap::new();
                            let mut lats = Vec::new();
                            let mut done = 0usize;
                            let mut outstanding = 0usize;
                            let mut qi = i; // offset so clients diverge
                            loop {
                                let q = w.queries.get(qi % w.queries.len());
                                qi += 1;
                                let qid = client.submit(q, opts)?;
                                submitted_at.insert(qid, Instant::now());
                                outstanding += 1;
                                while outstanding >= window {
                                    let c = client.recv()?;
                                    if let Some(at) = submitted_at.remove(&c.qid) {
                                        lats.push(at.elapsed().as_secs_f64());
                                    }
                                    done += 1;
                                    outstanding -= 1;
                                }
                                if Instant::now() >= deadline {
                                    break;
                                }
                            }
                            while outstanding > 0 {
                                let c = client.recv()?;
                                if let Some(at) = submitted_at.remove(&c.qid) {
                                    lats.push(at.elapsed().as_secs_f64());
                                }
                                done += 1;
                                outstanding -= 1;
                            }
                            Ok((lats, done, t0.elapsed().as_secs_f64()))
                        };
                        let res = drive();
                        // Every client reaches the barrier, error or not,
                        // so the shutdown below can never deadlock the
                        // sweep; the stopper uses a fresh connection in
                        // case its own died.
                        barrier.wait();
                        if i == 0 {
                            let _ = front::Client::connect_with(addr, 40, 25, 64 << 20)
                                .and_then(|c| c.shutdown_server());
                        }
                        res
                    })
                })
                .collect();
            // The server runs on this thread: resident session + event
            // loop; `front::serve` returns when client 0's Shutdown lands.
            let mut cluster = Cluster::empty(cfg, w.data.dim);
            let session = IndexSession::attach(
                exec,
                &mut cluster,
                b.hasher.as_ref(),
                Some(b.ranker.clone()),
            );
            session.insert(&w.data);
            front::serve(listener, &session, cfg, w.data.dim)?;
            session.close();
            let mut out = Vec::with_capacity(conns);
            for h in handles {
                out.push(h.join().expect("front client thread panicked")?);
            }
            Ok(out)
        },
    )?;

    let mut lats: Vec<f64> = Vec::new();
    let mut counts: Vec<usize> = Vec::with_capacity(conns);
    let mut wall: f64 = 0.0;
    for (l, done, w_secs) in &per_client {
        lats.extend_from_slice(l);
        counts.push(*done);
        wall = wall.max(*w_secs);
    }
    let total: usize = counts.iter().sum();
    let st = crate::metrics::latency_stats(&lats);
    let max_c = counts.iter().copied().max().unwrap_or(0);
    let min_c = counts.iter().copied().min().unwrap_or(0);
    table.row(&[
        backing.to_string(),
        format!("{conns}"),
        format!("{:.1}", total as f64 / wall.max(1e-9)),
        format!("{:.2}", st.p50_ms),
        format!("{:.2}", st.p99_ms),
        format!("{max_c}/{min_c}"),
    ]);
    Ok(())
}

/// `parlsh experiment front` (BENCH_front.json): sweep client count
/// {1, 8, 64} × backing executor {threaded, socket} through the real TCP
/// front door. Socket points launch a fresh worker mesh per point (the
/// resident stores live in the workers — reusing one mesh across points
/// would double-insert the dataset). `PARLSH_FRONT_SECS` scales each
/// point's drive window.
pub fn front_comparison() -> anyhow::Result<(Table, String)> {
    use crate::dataflow::exec::ThreadedExecutor;
    use crate::net::NetSession;

    let mut cfg = Config::default();
    cfg.cluster.bi_nodes = 1;
    cfg.cluster.dp_nodes = 2;
    cfg.lsh.t = 16;
    cfg.data.n = env_usize("PARLSH_N", 15_000);
    cfg.data.queries = env_usize("PARLSH_Q", 64);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    // a bounded admission window so per-lane fair shares actually bind
    cfg.stream.pending_cap = 64;
    let w = world(&cfg);
    let b = backends(&cfg, w.data.dim);
    let secs: f64 = std::env::var("PARLSH_FRONT_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.4);

    let mut table = Table::new(&[
        "backing",
        "conns",
        "delivered q/s",
        "p50 ms",
        "p99 ms",
        "fairness max/min",
    ]);
    for &conns in &[1usize, 8, 64] {
        front_point(&ThreadedExecutor, "threaded", &cfg, &w, &b, conns, secs, &mut table)?;
    }
    for &conns in &[1usize, 8, 64] {
        let sess = NetSession::launch(&cfg, w.data.dim)?;
        front_point(sess.executor(), "socket", &cfg, &w, &b, conns, secs, &mut table)?;
        sess.shutdown()?;
    }
    let json = format!("{{\"experiment\":\"front\",\"table\":{}}}\n", table.to_json());
    Ok((table, json))
}

// ------------------------------------------------- resident probe sweep

/// Per-query probe-budget sweep on ONE resident index (`parlsh experiment
/// probes`, `BENCH_probes.json`): the per-query-plan redesign
/// (`QueryOptions`) makes T a request-time knob, so the whole
/// recall-vs-latency curve comes off a single session — no rebuild per
/// point, unlike `multiprobe_sweep` (which also resamples nothing here:
/// same family, same stores). On top of the fixed-T rows, the sweep runs
/// the mmLSH adaptive policy (`[qos] adaptive_probes`, DESIGN.md §QoS
/// scheduler) at several quantiles: each query resolves its own budget
/// from its perturbation-score profile, and the row reports the mean
/// resolved T next to the recall/latency point — the adaptive-vs-fixed
/// frontier.
pub fn probes_sweep_resident(ts: &[usize]) -> (Table, String) {
    use crate::coordinator::build_index_on;
    use crate::coordinator::session::IndexSession;
    use crate::dataflow::exec::ThreadedExecutor;
    use crate::dataflow::message::QueryOptions;

    let mut cfg = Config::default();
    cfg.data.n = env_usize("PARLSH_N", 100_000);
    cfg.data.queries = env_usize("PARLSH_Q", 200);
    cfg.data.clusters = (cfg.data.n / 100).max(50);
    let w = world(&cfg);
    let b = backends(&cfg, w.data.dim);
    let mut cluster = build_index_on(&ThreadedExecutor, &cfg, &w.data, b.hasher.as_ref());
    let mut table =
        Table::new(&["plan", "mean T", "recall", "mean ms", "p99 ms", "q/s"]);

    // One sweep point on a resident session: submit the whole set under
    // `opts`, fold the completions into (recall, latency, mean echoed T).
    let mut point = |cluster: &mut Cluster, opts: QueryOptions, label: String| {
        let session = IndexSession::attach(
            &ThreadedExecutor,
            cluster,
            b.hasher.as_ref(),
            Some(b.ranker.clone()),
        );
        let t0 = std::time::Instant::now();
        let range = session.submit_batch_with(&w.queries, opts);
        let done = session.drain_full();
        let wall = t0.elapsed().as_secs_f64();
        session.close();
        let mut retrieved: Vec<Vec<u32>> = vec![Vec::new(); w.queries.len()];
        let mut lat = Vec::with_capacity(done.len());
        let mut budget_sum = 0u64;
        for (ticket, echo, hits, secs) in &done {
            debug_assert!(echo.probes >= 1, "option echo lost the plan");
            budget_sum += echo.probes as u64;
            let qi = (ticket.0 - range.start) as usize;
            retrieved[qi] = hits.iter().map(|&(_, id)| id).collect();
            lat.push(*secs);
        }
        let mean_t = budget_sum as f64 / done.len().max(1) as f64;
        let recall = recall_at_k(&retrieved, &w.gt);
        let st = crate::metrics::latency_stats(&lat);
        table.row(&[
            label,
            format!("{mean_t:.1}"),
            format!("{recall:.3}"),
            format!("{:.2}", st.mean_ms),
            format!("{:.2}", st.p99_ms),
            format!("{:.1}", w.queries.len() as f64 / wall.max(1e-9)),
        ]);
    };

    // fixed-T frontier: every query runs the same explicit budget
    for &t in ts {
        let opts = QueryOptions { probes: t as u32, ..Default::default() };
        point(&mut cluster, opts, format!("fixed T={t}"));
    }
    // adaptive frontier: probes = 0 + [qos] adaptive_probes resolves a
    // per-query budget; the policy is session-side, so flipping it
    // between sessions reuses the same resident stores
    let t_max = ts.iter().copied().max().unwrap_or(16).max(2);
    for &q in &[25.0f64, 50.0, 75.0] {
        cluster.cfg.qos.adaptive_probes = true;
        cluster.cfg.qos.adaptive_quantile = q / 100.0;
        cluster.cfg.qos.adaptive_max = t_max;
        point(&mut cluster, QueryOptions::default(), format!("adaptive q={q:.0}%"));
    }
    cluster.cfg.qos.adaptive_probes = false;

    let json = format!(
        "{{\"experiment\":\"probes\",\"adaptive_max\":{t_max},\"table\":{}}}\n",
        table.to_json()
    );
    (table, json)
}

// -------------------------------------------------------- bench history

/// `git rev-parse --short HEAD`, or "nogit" outside a repository.
pub fn git_short_sha() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty() && s.chars().all(|c| c.is_ascii_alphanumeric()))
        .unwrap_or_else(|| "nogit".into())
}

/// Archive a freshly written `BENCH_*.json` under `bench_history/`, stamped
/// with the current git SHA and wall-clock time, so bench trajectories are
/// recorded across PRs instead of overwritten per run (`parlsh experiment
/// history` diffs them). Returns the archive path.
pub fn archive_bench(path: &str) -> anyhow::Result<String> {
    let doc = std::fs::read_to_string(path)?;
    let sha = git_short_sha();
    let unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let stamped = match doc.strip_prefix('{') {
        Some(rest) => format!(
            "{{\"sha\":\"{}\",\"recorded_unix\":{unix},{rest}",
            crate::metrics::json_escape(&sha)
        ),
        None => doc,
    };
    std::fs::create_dir_all("bench_history")?;
    let stem = std::path::Path::new(path)
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("bench");
    // Timestamps are second-granular: never overwrite a same-second run.
    let mut out = format!("bench_history/{stem}-{unix}-{sha}.json");
    let mut k = 1u32;
    while std::path::Path::new(&out).exists() {
        k += 1;
        out = format!("bench_history/{stem}-{unix}-{sha}-{k}.json");
    }
    std::fs::write(&out, stamped)?;
    Ok(out)
}

/// The `parlsh experiment history` diff table: for every experiment with
/// archived runs under `bench_history/`, compare the latest run against the
/// previous one, cell by cell (rows aligned on their first column, numeric
/// cells get a relative delta).
pub fn history_table() -> anyhow::Result<Table> {
    use std::collections::BTreeMap;
    use std::time::SystemTime;
    // experiment -> [(recorded_unix, file mtime, sha, document)]
    type Run = (u64, SystemTime, String, String);
    let mut runs: BTreeMap<String, Vec<Run>> = BTreeMap::new();
    let dir = std::path::Path::new("bench_history");
    if dir.is_dir() {
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            let Ok(doc) = std::fs::read_to_string(&path) else { continue };
            // File mtime breaks recorded-second ties between two runs
            // archived within the same wall-clock second.
            let mtime = entry
                .metadata()
                .and_then(|m| m.modified())
                .unwrap_or(SystemTime::UNIX_EPOCH);
            let name = crate::metrics::json_find_string(&doc, "experiment")
                .unwrap_or_else(|| "?".into());
            let sha =
                crate::metrics::json_find_string(&doc, "sha").unwrap_or_else(|| "nogit".into());
            let recorded =
                crate::metrics::json_find_number(&doc, "recorded_unix").unwrap_or(0.0) as u64;
            runs.entry(name).or_default().push((recorded, mtime, sha, doc));
        }
    }
    let mut out = Table::new(&["experiment", "row", "column", "previous", "latest", "delta"]);
    for (name, mut rs) in runs {
        rs.sort_by_key(|(t, mtime, _, _)| (*t, *mtime));
        let (_, _, latest_sha, latest_doc) = rs.last().expect("non-empty run list");
        let Some((headers, rows)) = crate::metrics::table_from_json(latest_doc) else {
            continue;
        };
        let prev = rs
            .len()
            .checked_sub(2)
            .and_then(|i| crate::metrics::table_from_json(&rs[i].3));
        for row in &rows {
            let key = row.first().cloned().unwrap_or_default();
            let prev_row = prev
                .as_ref()
                .and_then(|(_, prows)| prows.iter().find(|r| r.first() == Some(&key)));
            for (ci, col) in headers.iter().enumerate().skip(1) {
                let cur = row.get(ci).cloned().unwrap_or_default();
                let prv = prev_row.and_then(|r| r.get(ci).cloned());
                // Bench cells are numbers, sometimes with an `x` suffix.
                let as_num = |s: &str| s.trim().trim_end_matches('x').parse::<f64>().ok();
                let delta = match (prv.as_deref().and_then(as_num), as_num(&cur)) {
                    (Some(a), Some(b)) if a != 0.0 => {
                        format!("{:+.1}%", (b - a) / a * 100.0)
                    }
                    _ => "-".into(),
                };
                out.row(&[
                    format!("{name}@{latest_sha}"),
                    key.clone(),
                    col.clone(),
                    prv.unwrap_or_else(|| "-".into()),
                    cur,
                    delta,
                ]);
            }
        }
    }
    Ok(out)
}

/// Table I stand-in: the synthetic dataset inventory.
pub fn datasets_table() -> Table {
    let mut table = Table::new(&["name", "reference size", "queries", "dim", "stands in for"]);
    let n = env_usize("PARLSH_N", 200_000);
    let q = env_usize("PARLSH_Q", 200);
    table.row(&[
        "bigann-mini".into(),
        format!("{n}"),
        format!("{q}"),
        "128".into(),
        "BIGANN (10^9 SIFT)".into(),
    ]);
    table.row(&[
        "yahoo-mini".into(),
        format!("{}", n / 2),
        format!("{q}"),
        "128".into(),
        "Yahoo (1.3x10^8 SIFT)".into(),
    ]);
    table
}
