//! `parlsh` CLI — the Layer-3 leader entrypoint.
//!
//! ```text
//! parlsh build   [--config=FILE] [--set k=v]...   build index, print stats
//! parlsh search  [--config=FILE] [--set k=v]...   build + search + recall
//! parlsh serve   [--config=FILE] [--set k=v]...   persistent serving session
//! parlsh serve --net                              multi-process serving session
//! parlsh serve --listen[=ADDR]                    TCP front door for external
//!                                                 clients (poll event loop)
//! parlsh query  --connect=ADDR                    drive a front-door server
//! parlsh worker  --listen=ADDR                    socket-transport worker
//! parlsh experiment <id>                          regenerate a paper table
//!        ids: datasets fig3 fig4 table2 table3 fig5 fig6 ablation
//!             executors probes net streaming front history all
//! parlsh calibrate                                measure cost-model consts
//! ```

use anyhow::{anyhow, bail, Result};
use parlsh::config::Config;
use parlsh::coordinator::{build_index, search};
use parlsh::coordinator::session::IndexSession;
use parlsh::coordinator::Cluster;
use parlsh::data::recall::recall_at_k;
use parlsh::data::Dataset;
use parlsh::dataflow::exec::{Executor, ThreadedExecutor};
use parlsh::experiments as exp;
use parlsh::metrics::latency_stats;
use parlsh::net::NetSession;
use parlsh::simnet::calibrate;
use parlsh::util::cli::Args;
use parlsh::util::timer::Timer;
use parlsh::QueryOptions;
use std::io::{BufRead, IsTerminal};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "build" => cmd_build(args),
        "search" => cmd_search(args),
        "serve" => cmd_serve(args),
        "query" => cmd_query(args),
        "worker" => parlsh::net::worker::run(args),
        "experiment" => cmd_experiment(args),
        "tune" => cmd_tune(args),
        "calibrate" => cmd_calibrate(),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `parlsh help`)"),
    }
}

const HELP: &str = "\
parlsh — distributed multi-probe LSH (Teixeira et al. 2013 reproduction)

USAGE:
  parlsh build      [--config=FILE] [--set section.key=value]...
  parlsh search     [--config=FILE] [--set ...]      inline executor, one-shot
  parlsh serve      [--config=FILE] [--set ...]      persistent IndexSession
                                     on the threaded executor: index stays
                                     resident; queries stream from
                                     --queries=FILE (.fvecs/.bvecs) or piped
                                     stdin (one vector per line), falling
                                     back to the synthetic workload; results
                                     print as tickets complete
  parlsh serve --net [--set ...]     same session over the socket executor:
                                     one OS process per worker slot (BI/DP
                                     nodes x cluster.replication) on
                                     loopback TCP (keep the fleet small!).
                                     cluster.replication=R keeps R live
                                     copies of every shard: queries route
                                     to one replica (cluster.replica_route
                                     = round_robin | layered), a replica
                                     death mid-stream retargets its
                                     in-flight queries to survivors, and a
                                     restarted worker rejoins mid-session
                                     (epoch-fenced, shard reload or live
                                     sibling restore; net.heartbeat_ms
                                     tunes detection, net.shard_dir
                                     enables shard persistence)
  parlsh serve --net --hosts=A,B,..  discovery mode: don't spawn; dial one
                                     out-of-band `parlsh worker --join`
                                     process per slot at these addresses
                                     (shorthand for --set net.hosts=...)
  parlsh serve --listen[=ADDR] [--net]
                                     TCP front door: external clients
                                     multiplex onto the ONE resident
                                     session through a poll-based event
                                     loop (bare --listen uses the config
                                     `[net] listen` address; prints
                                     `PARLSH_FRONT_LISTEN <addr>`; with
                                     --net the session itself runs on
                                     socket workers — two network tiers).
                                     Per-conn fairness: each client gets
                                     an equal share of stream.pending_cap;
                                     slow readers are evicted past
                                     front.egress_cap; runs until a client
                                     sends shutdown (parlsh query
                                     --shutdown)
  parlsh query --connect=ADDR [--synth=N | --queries=FILE.txt | piped stdin]
               [--k/--probes/--tables/--tag=..] [--window=W] [--shutdown]
                                     drive a front-door server: handshake
                                     (config digest checked), stream
                                     queries pipelined W deep (default 32),
                                     print completions with the option
                                     echo; --synth=N sends N deterministic
                                     synthetic queries (--seed=S);
                                     --tag=NAME stamps every query with a
                                     `[qos] tags` class (or a numeric id);
                                     --shutdown asks the server to drain
                                     and exit cleanly afterwards
  parlsh worker --listen=ADDR        host a worker slot's stage copies
               [--shard=FILE]        (spawned by the socket driver; always
                                     prints the OS-resolved bound address
                                     as `PARLSH_WORKER_LISTEN <addr>`, so
                                     port-0 binds work; --shard reloads a
                                     persisted PLSD shard so a restarted
                                     worker can rejoin mid-session)
  parlsh worker --join=ADDR          same, started out of band: bind ADDR
                                     and wait to be discovered by a driver
                                     whose `[net] hosts` table lists it
  parlsh experiment <datasets|fig3|fig4|table2|table3|fig5|fig6|ablation|executors|probes|net|streaming|front|history|all>
                                     (`executors`/`probes`/`net`/
                                     `streaming`/`front` also write
                                     BENCH_*.json and archive them under
                                     bench_history/ keyed by git SHA;
                                     `history` diffs the archived runs;
                                     `probes` sweeps the per-query probe
                                     budget T on ONE resident index — no
                                     rebuild per point — then adds mmLSH
                                     adaptive-budget rows ([qos]
                                     adaptive_probes) for the fixed-vs-
                                     adaptive frontier; `streaming` adds
                                     an open-loop Poisson arrival row,
                                     rate set by --lambda=Q_PER_SEC
                                     (default 200), plus a per-tag SLO
                                     table under mixed gold/silver QoS
                                     tenants; `front` sweeps client count
                                     × backing executor through real TCP
                                     with fairness spread; `net`,
                                     `streaming` and `front` spawn
                                     processes/threads and are not part
                                     of `all`)
  parlsh tune       [--target=0.8] [--set ...]    suggest w, tune T (and M)
  parlsh calibrate

`serve` admission is streaming: a query enters the pipeline the moment it
is submitted. --set stream.inflight=W bounds queries in flight inside the
pipeline (0 = open loop, default); --set stream.pending_cap=P adds
backpressure — submission blocks while P queries are outstanding.

Per-query search plans (`serve`): --k=K / --probes=T / --tables=L' set the
default plan for every query of this serving run (0 = the config value),
and text query sources — piped stdin, or a --queries=FILE.txt file — may
prefix any line with k=.. t=.. l=.. tag=.. tokens to override the plan
for that one query:  `k=3 t=8 0.1 0.2 ...`. Results print with the
per-ticket option echo. (--queries files with any other extension keep
the binary behavior: .bvecs as bytes, everything else as fvecs.)

Multi-tenant QoS: --set qos.tags=\"gold:4,silver:2,*:1\" names weighted
tag classes; admission then partitions stream.pending_cap by weighted
fair queueing over the *active* classes (idle weight is borrowed), and
`serve`/`query` accept --tag=NAME (or a numeric id) to place a run's
queries in a class. Per-tag SLO rows (submitted/completed, latency
percentiles, distance work) print at session close. With --set
qos.adaptive_probes=true, queries that don't pin an explicit probe
budget (probes = 0) resolve a per-query T from their own perturbation-
score profile (mmLSH), tuned by qos.adaptive_quantile / qos.adaptive_max
— the echoed plan records the resolved budget.

Env: PARLSH_N, PARLSH_Q scale experiments; PARLSH_SCALAR=1 forces the
scalar path (no PJRT artifacts); PARLSH_FORCE_SCALAR=1 pins the SIMD
kernel dispatcher to its scalar tier (differential debugging);
PARLSH_BENCH_SECS scales the hotpath_micro measurement window;
PARLSH_FRONT_SECS the per-point client drive window of `experiment
front`; PARLSH_ARTIFACTS points at the AOT artifact dir; PARLSH_INFLIGHT
sets the batched-admission window of `experiment executors`;
PARLSH_WORKER_BIN overrides the worker binary.
";

fn cmd_build(args: &Args) -> Result<()> {
    let cfg = Config::load(args)?;
    let w = exp::world(&cfg);
    let b = exp::backends(&cfg, w.data.dim);
    println!(
        "building index: n={} L={} M={} T={} w={} ({} path)",
        w.data.len(),
        cfg.lsh.l,
        cfg.lsh.m,
        cfg.lsh.t,
        cfg.lsh.w,
        if b.engine_path { "PJRT artifact" } else { "scalar" },
    );
    let t = Timer::start();
    let cluster = build_index(&cfg, &w.data, b.hasher.as_ref());
    println!(
        "built in {:.2}s: {} objects across {} DPs, {} bucket refs across {} BIs",
        t.secs(),
        cluster.stored_objects(),
        cluster.dps.len(),
        cluster.bucket_references(),
        cluster.bis.len(),
    );
    let imb = parlsh::partition::imbalance(&cluster.dp_object_counts());
    println!(
        "partition: {} | load imbalance {:.2}% (cv {:.2}%)",
        cfg.stream.obj_map.name(),
        imb.max_over_mean_pct,
        imb.cv_pct
    );
    println!(
        "build traffic: {} logical msgs, {} packets, {:.3} GB",
        cluster.build_meter.logical_msgs,
        cluster.build_meter.total_packets(),
        cluster.build_meter.payload_bytes as f64 / 1e9,
    );
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let cfg = Config::load(args)?;
    if args.has_flag("net") {
        bail!("--net is a serving transport: use `parlsh serve --net`");
    }
    let w = exp::world(&cfg);
    let b = exp::backends(&cfg, w.data.dim);
    let mut cluster = build_index(&cfg, &w.data, b.hasher.as_ref());
    let t = Timer::start();
    let out = search(&mut cluster, &w.queries, b.hasher.as_ref(), b.ranker.as_ref());
    let secs = t.secs();
    let recall = recall_at_k(&out.retrieved_ids(), &w.gt);
    let lat = latency_stats(&out.per_query_secs);
    println!(
        "searched {} queries in {:.2}s ({:.1} q/s, inline executor, {} path)",
        w.queries.len(),
        secs,
        w.queries.len() as f64 / secs,
        if b.engine_path { "PJRT artifact" } else { "scalar" },
    );
    println!("recall@{} = {recall:.3}", cfg.lsh.k);
    println!(
        "latency ms: mean {:.2} p50 {:.2} p90 {:.2} p99 {:.2} max {:.2}",
        lat.mean_ms, lat.p50_ms, lat.p90_ms, lat.p99_ms, lat.max_ms
    );
    println!(
        "traffic: {} logical msgs ({} local), {} packets, {:.3} GB",
        out.meter.logical_msgs,
        out.meter.local_msgs,
        out.meter.total_packets(),
        out.meter.payload_bytes as f64 / 1e9,
    );
    Ok(())
}

/// `parlsh serve`: the session-oriented serving loop (DESIGN.md §Service
/// API). The index is built once and stays resident in an [`IndexSession`];
/// queries stream in as they arrive — from `--queries=FILE`, from piped
/// stdin, or falling back to the synthetic workload — and results print as
/// their tickets complete.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = Config::load(args)?;
    // --hosts=A,B,... is shorthand for --set net.hosts=A,B,... — one
    // address per worker slot, switching --net from spawning loopback
    // children to discovering out-of-band `parlsh worker --join` peers.
    if let Some(hosts) = args.opt("hosts") {
        cfg.sock.hosts = hosts.to_string();
    }
    let w = exp::world(&cfg);
    let b = exp::backends(&cfg, w.data.dim);
    // --listen=ADDR (or bare --listen for the config `[net] listen`
    // address) swaps the local query sources for the TCP front door.
    let listen: Option<String> = if let Some(a) = args.opt("listen") {
        Some(a.to_string())
    } else if args.has_flag("listen") {
        Some(cfg.sock.listen.clone())
    } else {
        None
    };
    if !cfg.sock.hosts.is_empty() && !args.has_flag("net") {
        bail!("[net] hosts / --hosts names a worker fleet: add --net");
    }
    if args.has_flag("net") {
        let n_slots =
            (cfg.cluster.bi_nodes + cfg.cluster.dp_nodes) * cfg.cluster.replication.max(1);
        if cfg.sock.hosts.is_empty() {
            println!(
                "spawning {n_slots} `parlsh worker` processes on loopback (+ this driver as head node)"
            );
        } else {
            println!("discovering {n_slots} workers at [net] hosts (+ this driver as head node)");
        }
        let net = NetSession::launch(&cfg, w.data.dim)?;
        match &listen {
            Some(addr) => serve_front(net.executor(), &cfg, &w, &b, addr, "socket")?,
            None => serve_session(net.executor(), &cfg, &w, &b, args, "socket")?,
        }
        net.shutdown()?;
        println!("all {n_slots} workers exited cleanly");
        Ok(())
    } else {
        match &listen {
            Some(addr) => serve_front(&ThreadedExecutor, &cfg, &w, &b, addr, "threaded"),
            None => serve_session(&ThreadedExecutor, &cfg, &w, &b, args, "threaded"),
        }
    }
}

/// `parlsh serve --listen`: the poll-based front door (DESIGN.md §Front
/// door). Binds first and announces the resolved address on stdout —
/// `PARLSH_FRONT_LISTEN <addr>`, the same sole-announce contract as the
/// worker — so external clients can connect while the index is still
/// building; the OS holds their connections in the listen backlog and
/// their handshakes are answered the moment the event loop starts.
fn serve_front(
    exec: &dyn Executor,
    cfg: &Config,
    w: &exp::World,
    b: &exp::Backends,
    addr: &str,
    transport: &str,
) -> Result<()> {
    use std::io::Write as _;
    let dim = w.data.dim;
    let listener =
        std::net::TcpListener::bind(addr).map_err(|e| anyhow!("bind {addr}: {e}"))?;
    let local = listener.local_addr()?;
    println!("PARLSH_FRONT_LISTEN {local}");
    std::io::stdout().flush().ok();
    let mut cluster = Cluster::empty(cfg, dim);
    let session =
        IndexSession::attach(exec, &mut cluster, b.hasher.as_ref(), Some(b.ranker.clone()));
    let t = Timer::start();
    session.insert(&w.data);
    eprintln!(
        "front: index resident: {} vectors in {:.2}s ({transport} executor, {} path); serving on {local}",
        w.data.len(),
        t.secs(),
        if b.engine_path { "PJRT artifact" } else { "scalar" },
    );
    let fs = parlsh::net::front::serve(listener, &session, cfg, dim)?;
    let stats = session.close();
    println!(
        "front closed: {} conns accepted ({} refused), {} queries, {} completions, {} evictions",
        fs.accepted, fs.refused, fs.queries, fs.completions, fs.evictions
    );
    let lat = stats.latency.stats();
    println!(
        "latency ms: mean {:.2} p50 {:.2} p90 {:.2} p99 {:.2} max {:.2}",
        lat.mean_ms, lat.p50_ms, lat.p90_ms, lat.p99_ms, lat.max_ms
    );
    print_per_tag(&fs.per_tag);
    Ok(())
}

/// Resolve a `--tag=NAME` flag against the `[qos] tags` spec: numeric ids
/// pass through untouched, `*` is the catch-all (0), and class names map
/// to their 1-based wire id. No flag → tag 0.
fn resolve_tag_flag(args: &Args, tags_spec: &str) -> Result<u32> {
    match args.opt("tag") {
        Some(s) => {
            let tags = parlsh::qos::TagTable::parse(tags_spec).map_err(|e| anyhow!(e))?;
            tags.resolve_tag(s).map_err(|e| anyhow!(e))
        }
        None => Ok(0),
    }
}

/// Print the per-tag SLO rows ([`parlsh::qos::TagStats`]) of a serving
/// run. Quiet when QoS is unconfigured (only the `*` catch-all exists).
fn print_per_tag(per_tag: &[parlsh::qos::TagStats]) {
    if per_tag.len() <= 1 {
        return;
    }
    println!("per-tag SLO ([qos] tags):");
    for r in per_tag {
        let ls = r.latency.stats();
        println!(
            "  {:<10} w={:<3} submitted {:>6} completed {:>6} | ms mean {:.2} p50 {:.2} p99 {:.2} | dists {}",
            r.name,
            r.weight,
            r.submitted,
            r.completed,
            ls.mean_ms,
            ls.p50_ms,
            ls.p99_ms,
            r.work.dists_computed,
        );
    }
}

/// Print one front-door completion with its per-query plan echo (the
/// `query` verb's analogue of [`record_result`]).
fn print_completed(c: &parlsh::net::front::Completed) {
    let head: Vec<String> = c
        .hits
        .iter()
        .take(5)
        .map(|&(d, id)| format!("{id}:{d:.1}"))
        .collect();
    let tag = if c.opts.tag != 0 { format!(" tag={}", c.opts.tag) } else { String::new() };
    println!(
        "query {:>5} [k={} t={} l={}{tag}] -> [{}]",
        c.qid,
        c.opts.k,
        c.opts.probes,
        c.opts.tables,
        head.join(" ")
    );
}

/// `parlsh query --connect=ADDR`: the external-client CLI of the front
/// door. Streams queries pipelined `--window` deep, prints completions as
/// they are claimed, and optionally (`--shutdown`) asks the server to
/// drain and exit afterwards.
fn cmd_query(args: &Args) -> Result<()> {
    let Some(addr) = args.opt("connect") else {
        bail!("`parlsh query` needs --connect=ADDR (a `parlsh serve --listen` server)");
    };
    // --tag=NAME resolves against the *client's* `[qos] tags` spec
    // (--config/--set, defaults otherwise). QoS is driver-side policy and
    // not digest-covered, so pass the server's spec here for names to line
    // up; bare numeric ids always pass through even with no spec at hand.
    let tag_spec = Config::load(args)?.qos.tags;
    let base = QueryOptions {
        k: args.opt_usize("k", 0).map_err(|e| anyhow!(e))? as u32,
        probes: args.opt_usize("probes", 0).map_err(|e| anyhow!(e))? as u32,
        tables: args.opt_usize("tables", 0).map_err(|e| anyhow!(e))? as u32,
        tag: resolve_tag_flag(args, &tag_spec)?,
    };
    let window = args.opt_usize("window", 32).map_err(|e| anyhow!(e))?.max(1);
    let retries = args.opt_usize("retries", 400).map_err(|e| anyhow!(e))?;
    let mut client = parlsh::net::front::Client::connect_with(addr, retries, 25, 64 << 20)?;
    let dim = client.dim();
    let h = client.hello();
    eprintln!(
        "connected to {addr}: dim={dim}, server plan k={} T={} L={} (digest {:#018x})",
        h.lsh.k, h.lsh.t, h.lsh.l, h.digest
    );

    let queries: Vec<(QueryOptions, Vec<f32>)> = if let Some(path) = args.opt("queries") {
        if !path.ends_with(".txt") {
            bail!("--queries for `query` takes a .txt file (one vector per line, optional k=/t=/l=/tag= prefixes)");
        }
        let text = std::fs::read_to_string(path).map_err(|e| anyhow!("read {path}: {e}"))?;
        text.lines()
            .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
            .map(|l| parse_query_line(l, base))
            .collect::<Result<_>>()?
    } else if let Some(n) = args.opt("synth") {
        let n: usize = n.parse().map_err(|e| anyhow!("bad --synth: {e}"))?;
        let seed = args.opt_usize("seed", 12345).map_err(|e| anyhow!(e))? as u64;
        let ds = parlsh::data::synth::synthesize(parlsh::data::synth::SynthSpec {
            n,
            dim,
            seed,
            ..Default::default()
        });
        (0..ds.len()).map(|i| (base, ds.get(i).to_vec())).collect()
    } else if !std::io::stdin().is_terminal() {
        let mut out = Vec::new();
        for line in std::io::stdin().lock().lines() {
            let l = line.map_err(|e| anyhow!("read stdin: {e}"))?;
            if l.trim().is_empty() || l.trim_start().starts_with('#') {
                continue;
            }
            out.push(parse_query_line(&l, base)?);
        }
        out
    } else {
        Vec::new()
    };
    if queries.is_empty() && !args.has_flag("shutdown") {
        bail!(
            "nothing to do: give --queries=FILE.txt, --synth=N, pipe query lines \
             on stdin, or --shutdown"
        );
    }

    let t = Timer::start();
    let mut server_secs = Vec::with_capacity(queries.len());
    let mut outstanding = 0usize;
    for (opts, q) in &queries {
        client.submit(q, *opts)?;
        outstanding += 1;
        while outstanding >= window {
            let c = client.recv()?;
            print_completed(&c);
            server_secs.push(c.secs);
            outstanding -= 1;
        }
    }
    while outstanding > 0 {
        let c = client.recv()?;
        print_completed(&c);
        server_secs.push(c.secs);
        outstanding -= 1;
    }
    if !queries.is_empty() {
        let secs = t.secs();
        let lat = latency_stats(&server_secs);
        eprintln!(
            "{} queries in {secs:.2}s ({:.1} q/s end to end); server-side ms: \
             mean {:.2} p50 {:.2} p99 {:.2}",
            queries.len(),
            queries.len() as f64 / secs.max(1e-9),
            lat.mean_ms,
            lat.p50_ms,
            lat.p99_ms
        );
    }
    if args.has_flag("shutdown") {
        client.shutdown_server()?;
        println!("server shutdown acknowledged");
    }
    Ok(())
}

/// Print one completed ticket — with its per-query plan echo — and record
/// its retrieved ids (for recall scoring when the workload is synthetic).
/// Tickets are dense, so the ticket number doubles as the query index.
fn record_result(
    retrieved: &mut Vec<Vec<u32>>,
    t: parlsh::QueryTicket,
    opts: QueryOptions,
    hits: &[(f32, u32)],
) {
    let i = t.0 as usize;
    if retrieved.len() <= i {
        retrieved.resize(i + 1, Vec::new());
    }
    retrieved[i] = hits.iter().map(|&(_, id)| id).collect();
    let head: Vec<String> = hits
        .iter()
        .take(5)
        .map(|&(d, id)| format!("{id}:{d:.1}"))
        .collect();
    let tag = if opts.tag != 0 { format!(" tag={}", opts.tag) } else { String::new() };
    println!(
        "ticket {:>5} [k={} t={} l={}{tag}] -> [{}]",
        t.0,
        opts.k,
        opts.probes,
        opts.tables,
        head.join(" ")
    );
}

/// Parse one text query line: optional `k=..` / `t=..` (or `probes=..`) /
/// `l=..` (or `tables=..`) / `tag=..` tokens before the vector values
/// override `base` for this one query; the remaining whitespace-separated
/// tokens are the f32 coordinates.
fn parse_query_line(line: &str, base: QueryOptions) -> Result<(QueryOptions, Vec<f32>)> {
    let mut opts = base;
    let mut vals: Vec<f32> = Vec::new();
    for tok in line.split_whitespace() {
        if vals.is_empty() {
            if let Some((key, v)) = tok.split_once('=') {
                let n: u32 = v
                    .parse()
                    .map_err(|e| anyhow!("bad query option `{tok}`: {e}"))?;
                match key {
                    "k" => opts.k = n,
                    "t" | "probes" => opts.probes = n,
                    "l" | "tables" => opts.tables = n,
                    "tag" => opts.tag = n,
                    _ => bail!("unknown query option `{tok}` (k=, t=/probes=, l=/tables=, tag=)"),
                }
                continue;
            }
        }
        vals.push(
            tok.parse::<f32>()
                .map_err(|e| anyhow!("bad query value `{tok}`: {e}"))?,
        );
    }
    Ok((opts, vals))
}

/// Submit queries one at a time — each with its own plan — through
/// `submit_with`; under closed-loop admission (`stream.inflight = W`)
/// block on completions whenever W are in flight, printing them as they
/// finish. Drains the tail before returning.
fn serve_stream(
    session: &IndexSession,
    queries: impl Iterator<Item = Result<(QueryOptions, Vec<f32>)>>,
    dim: usize,
    window: usize,
    retrieved: &mut Vec<Vec<u32>>,
) -> Result<usize> {
    let mut submitted = 0usize;
    for q in queries {
        let (opts, q) = q?;
        if q.len() != dim {
            bail!("query has {} values, index dimensionality is {dim}", q.len());
        }
        session.submit_with(&q, opts);
        submitted += 1;
        if window > 0 {
            while session.in_flight() >= window {
                match session.recv_full() {
                    Some((t, opts, hits, _)) => record_result(retrieved, t, opts, &hits),
                    None => break,
                }
            }
        }
    }
    for (t, opts, hits, _) in session.drain_full() {
        record_result(retrieved, t, opts, &hits);
    }
    Ok(submitted)
}

fn serve_session(
    exec: &dyn Executor,
    cfg: &Config,
    w: &exp::World,
    b: &exp::Backends,
    args: &Args,
    transport: &str,
) -> Result<()> {
    let dim = w.data.dim;
    let window = cfg.stream.inflight;
    // The serving run's default plan: --k/--probes/--tables override the
    // config per run (0 = inherit); per-line prefixes override per query.
    // --tag=NAME resolves against the `[qos] tags` classes (numeric ids
    // pass through) and rides on every query of the run.
    let base = QueryOptions {
        k: args.opt_usize("k", 0).map_err(|e| anyhow!(e))? as u32,
        probes: args.opt_usize("probes", 0).map_err(|e| anyhow!(e))? as u32,
        tables: args.opt_usize("tables", 0).map_err(|e| anyhow!(e))? as u32,
        tag: resolve_tag_flag(args, &cfg.qos.tags)?,
    };
    let mut cluster = Cluster::empty(cfg, dim);
    let session =
        IndexSession::attach(exec, &mut cluster, b.hasher.as_ref(), Some(b.ranker.clone()));
    let t = Timer::start();
    session.insert(&w.data);
    println!(
        "index resident: {} vectors in {:.2}s ({transport} executor, {} path); session open",
        w.data.len(),
        t.secs(),
        if b.engine_path { "PJRT artifact" } else { "scalar" },
    );
    let defaults = session.default_options();
    println!(
        "default plan: k={} probes={} tables={} (override with --k/--probes/--tables or k=/t=/l= line prefixes)",
        if base.k != 0 { base.k } else { defaults.k },
        if base.probes != 0 { base.probes } else { defaults.probes },
        if base.tables != 0 { base.tables } else { defaults.tables },
    );
    let admission = match window {
        0 => "open loop".to_string(),
        win => format!("closed loop W={win}"),
    };

    let t = Timer::start();
    let mut retrieved: Vec<Vec<u32>> = Vec::new();
    let mut synthetic = false;
    let submitted = if let Some(path) = args.opt("queries") {
        if path.ends_with(".txt") {
            // Text query file: one query per line, optional per-line
            // k=/t=/l=/tag= plan prefixes — the submit_with path end to
            // end. Only `.txt` selects this; every other extension keeps
            // the historical binary behavior below.
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("read {path}: {e}"))?;
            println!("streaming text queries from {path} (per-line k=/t=/l= prefixes honored)");
            // lazy: each line is parsed and submitted as the stream
            // reaches it — no second materialization of the whole file
            let lines = text
                .lines()
                .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
                .map(|l| parse_query_line(l, base));
            serve_stream(&session, lines, dim, window, &mut retrieved)?
        } else {
            // Binary vectors: .bvecs as bytes, anything else as fvecs —
            // the pre-plan behavior, unchanged.
            let qs = if path.ends_with(".bvecs") {
                parlsh::data::io::read_bvecs(path, 0)?
            } else {
                parlsh::data::io::read_fvecs(path, 0)?
            };
            println!("streaming {} queries from {path}", qs.len());
            serve_stream(&session, dataset_queries(&qs, base), dim, window, &mut retrieved)?
        }
    } else if !std::io::stdin().is_terminal() {
        println!(
            "reading queries from stdin ({dim} whitespace-separated f32s per line; \
             optional k=/t=/l=/tag= prefixes)..."
        );
        let lines = std::io::stdin().lock().lines().filter_map(|line| match line {
            Err(e) => Some(Err(anyhow!("read stdin: {e}"))),
            // blank and `#` comment lines are skipped — same per-line
            // format as a --queries=FILE.txt file
            Ok(l) if l.trim().is_empty() || l.trim_start().starts_with('#') => None,
            Ok(l) => Some(parse_query_line(&l, base)),
        });
        serve_stream(&session, lines, dim, window, &mut retrieved)?
    } else {
        println!(
            "no --queries file and stdin is a TTY: streaming the {} synthetic workload queries",
            w.queries.len()
        );
        synthetic = true;
        serve_stream(&session, dataset_queries(&w.queries, base), dim, window, &mut retrieved)?
    };
    let secs = t.secs();
    let stats = session.close();

    // bounded accounting: exact mean/max + reservoir percentiles, O(1)
    // per query served — a resident session no longer grows with traffic
    let lat = stats.latency.stats();
    println!(
        "session closed: {submitted} queries in {secs:.2}s ({:.1} q/s, {transport} executor, {admission})",
        submitted as f64 / secs.max(1e-9),
    );
    println!(
        "latency ms: mean {:.2} p50 {:.2} p90 {:.2} p99 {:.2} max {:.2}",
        lat.mean_ms, lat.p50_ms, lat.p90_ms, lat.p99_ms, lat.max_ms
    );
    print_per_tag(&stats.per_tag);
    if transport == "socket" {
        // Socket meters carry measured frame bytes (PR 2), not the model.
        println!(
            "search wire traffic (real codec bytes, not the wire_size model): \
             {} logical msgs ({} local), {} tcp packets, {:.3} MB",
            stats.search_meter.logical_msgs,
            stats.search_meter.local_msgs,
            stats.search_meter.total_packets(),
            stats.search_meter.total_bytes() as f64 / 1e6,
        );
    } else {
        println!(
            "search traffic: {} logical msgs ({} local), {} packets, {:.3} MB",
            stats.search_meter.logical_msgs,
            stats.search_meter.local_msgs,
            stats.search_meter.total_packets(),
            stats.search_meter.payload_bytes as f64 / 1e6,
        );
    }
    if synthetic {
        // The tag only routes QoS accounting — it never changes retrieval,
        // so a --tag-only run still scores recall against ground truth.
        if QueryOptions { tag: 0, ..base } == QueryOptions::default() {
            // Tickets are issued in submission order, so they line up
            // with gt (computed at the config's k).
            let recall = recall_at_k(&retrieved, &w.gt);
            println!("recall@{} = {recall:.3}", cfg.lsh.k);
        } else {
            // A --k/--probes/--tables override changes the retrieved sets;
            // scoring them against ground truth at the config's k would
            // print a mislabeled number.
            println!(
                "(recall suppressed: run plan overrides the config defaults, \
                 ground truth is recall@{})",
                cfg.lsh.k
            );
        }
    }
    if transport == "socket" {
        print!("{}", stats.search_meter.link_report());
    }
    Ok(())
}

/// A dataset's rows as an owned-query iterator for [`serve_stream`], all
/// under one base plan.
fn dataset_queries(
    ds: &Dataset,
    base: QueryOptions,
) -> impl Iterator<Item = Result<(QueryOptions, Vec<f32>)>> + '_ {
    (0..ds.len()).map(move |i| Ok((base, ds.get(i).to_vec())))
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let run = |id: &str| -> Result<()> {
        match id {
            "datasets" => {
                println!("== Table I (datasets, scaled stand-ins) ==");
                exp::datasets_table().print();
            }
            "fig3" => {
                println!("== Fig. 3 (weak-scaling efficiency) ==");
                exp::fig3_weak_scaling().print();
            }
            "fig4" | "table2" => {
                let pts = exp::multiprobe_sweep(&[1, 30, 60, 90, 120]);
                println!("== Fig. 4 (time & recall vs T) ==");
                exp::fig4_table(&pts).print();
                println!("== Table II (traffic vs T) ==");
                exp::table2(&pts).print();
            }
            "table3" => {
                println!("== Table III (M sweep) ==");
                exp::table3_m_sweep(&[28, 30, 32]).print();
            }
            "fig5" => {
                println!("== Fig. 5 (L sweep at iso-recall) ==");
                exp::fig5_l_sweep(&[4, 6, 8], 0.74).print();
            }
            "fig6" => {
                println!("== Fig. 6 (partition strategies) ==");
                exp::fig6_partition().print();
            }
            "ablation" => {
                println!("== §V-B ablation (intra-stage parallelism) ==");
                exp::ablation_intrastage().print();
            }
            "executors" => {
                println!("== Executor comparison (inline / threaded / batched) ==");
                let t = exp::executor_comparison();
                t.print();
                t.write_json("BENCH_executors.json", "executors")?;
                let archived = exp::archive_bench("BENCH_executors.json")?;
                println!("(wrote BENCH_executors.json; archived {archived})");
            }
            "probes" => {
                println!("== Per-query probe sweep on one resident index (fixed T vs adaptive) ==");
                let (t, json) = exp::probes_sweep_resident(&[1, 4, 8, 16, 30, 60]);
                t.print();
                std::fs::write("BENCH_probes.json", json)?;
                let archived = exp::archive_bench("BENCH_probes.json")?;
                println!("(wrote BENCH_probes.json; archived {archived})");
            }
            "net" => {
                println!("== Socket transport: obj_map strategies by real wire bytes ==");
                let (t, json) = exp::net_comparison()?;
                t.print();
                std::fs::write("BENCH_net.json", json)?;
                let archived = exp::archive_bench("BENCH_net.json")?;
                println!("(wrote BENCH_net.json; archived {archived})");
            }
            "streaming" => {
                println!("== Streaming vs pumped admission: per-query latency ==");
                let lambda = args.opt_f64("lambda", 0.0).map_err(|e| anyhow!(e))?;
                let (t, json) =
                    exp::streaming_comparison(if lambda > 0.0 { Some(lambda) } else { None })?;
                t.print();
                std::fs::write("BENCH_streaming.json", json)?;
                let archived = exp::archive_bench("BENCH_streaming.json")?;
                println!("(wrote BENCH_streaming.json; archived {archived})");
            }
            "front" => {
                println!("== Front door: client count × backing executor over real TCP ==");
                let (t, json) = exp::front_comparison()?;
                t.print();
                std::fs::write("BENCH_front.json", json)?;
                let archived = exp::archive_bench("BENCH_front.json")?;
                println!("(wrote BENCH_front.json; archived {archived})");
            }
            "history" => {
                println!("== Bench history (bench_history/, latest two runs per experiment) ==");
                exp::history_table()?.print();
            }
            other => bail!("unknown experiment `{other}`"),
        }
        Ok(())
    };
    if id == "all" {
        for id in [
            "datasets", "fig3", "fig4", "table3", "fig5", "fig6", "ablation",
            "executors", "probes",
        ] {
            run(id)?;
            println!();
        }
        Ok(())
    } else {
        run(id)
    }
}

fn cmd_tune(args: &Args) -> Result<()> {
    // The paper's tuning phase (§V-D): run the sequential baseline over a
    // small partition of the dataset to pick w, T (and inspect M).
    let mut cfg = Config::load(args)?;
    let target = args
        .opt_f64("target", 0.8)
        .map_err(|e| anyhow::anyhow!(e))?;
    cfg.data.n = cfg.data.n.min(20_000); // small partition, as in the paper
    cfg.data.queries = cfg.data.queries.min(100);
    let w = exp::world(&cfg);
    let suggested = parlsh::baseline::suggest_w(&w.data, 256, cfg.lsh.seed);
    println!(
        "suggested w from NN-distance scale: {suggested:.0} (config: {})",
        cfg.lsh.w
    );
    println!("tuning T to recall >= {target} at L={} M={}:", cfg.lsh.l, cfg.lsh.m);
    let trace = parlsh::baseline::tune_t(&w.data, &w.queries, cfg.lsh, target, 512);
    for p in &trace {
        println!("  T={:<4} recall={:.3} dists/query={:.0}", p.t, p.recall, p.dists_per_query);
    }
    let best = trace.last().unwrap();
    println!("-> use T={} (recall {:.3})", best.t, best.recall);
    println!("M scan at T={} (paper Table III decision):", best.t);
    let base = parlsh::core::lsh::LshParams { t: best.t, ..cfg.lsh };
    let ms = [cfg.lsh.m.saturating_sub(4).max(2), cfg.lsh.m, cfg.lsh.m + 4];
    for p in parlsh::baseline::tune_m(&w.data, &w.queries, base, &ms) {
        println!("  M={:<3} recall={:.3} dists/query={:.0}", p.m, p.recall, p.dists_per_query);
    }
    Ok(())
}

fn cmd_calibrate() -> Result<()> {
    println!("calibrating cost model on this host...");
    let m = calibrate();
    println!("ns_per_dist      = {:.1}", m.ns_per_dist);
    println!("ns_per_proj      = {:.1}", m.ns_per_proj);
    println!("ns_per_probe_seq = {:.1}", m.ns_per_probe_seq);
    println!("ns_per_lookup    = {:.1}", m.ns_per_lookup);
    println!("ns_per_cand      = {:.1}", m.ns_per_cand);
    println!("ns_per_store     = {:.1}", m.ns_per_store);
    println!("ns_per_reduce    = {:.1}", m.ns_per_reduce);
    println!("(paste into CostModel::default() to pin; see EXPERIMENTS.md)");
    Ok(())
}
