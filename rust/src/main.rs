//! `parlsh` CLI — the Layer-3 leader entrypoint.
//!
//! ```text
//! parlsh build   [--config=FILE] [--set k=v]...   build index, print stats
//! parlsh search  [--config=FILE] [--set k=v]...   build + search + recall
//! parlsh serve   [--config=FILE] [--set k=v]...   threaded serving run
//! parlsh serve --net                              multi-process serving run
//! parlsh worker  --listen=ADDR                    socket-transport worker
//! parlsh experiment <id>                          regenerate a paper table
//!        ids: datasets fig3 fig4 table2 table3 fig5 fig6 ablation
//!             executors net all
//! parlsh calibrate                                measure cost-model consts
//! ```

use anyhow::{bail, Result};
use parlsh::config::Config;
use parlsh::coordinator::{build_index, build_index_on, search, search_on, threaded::search_threaded};
use parlsh::data::recall::recall_at_k;
use parlsh::experiments as exp;
use parlsh::metrics::latency_stats;
use parlsh::net::NetSession;
use parlsh::simnet::calibrate;
use parlsh::util::cli::Args;
use parlsh::util::timer::Timer;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "build" => cmd_build(args),
        "search" => cmd_search(args, false),
        "serve" => cmd_search(args, true),
        "worker" => parlsh::net::worker::run(args),
        "experiment" => cmd_experiment(args),
        "tune" => cmd_tune(args),
        "calibrate" => cmd_calibrate(),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand `{other}` (try `parlsh help`)"),
    }
}

const HELP: &str = "\
parlsh — distributed multi-probe LSH (Teixeira et al. 2013 reproduction)

USAGE:
  parlsh build      [--config=FILE] [--set section.key=value]...
  parlsh search     [--config=FILE] [--set ...]      inline executor
  parlsh serve      [--config=FILE] [--set ...]      threaded executor
  parlsh serve --net [--set ...]     socket executor: one OS process per
                                     BI/DP node over loopback TCP (keep
                                     cluster.{bi,dp}_nodes small!)
  parlsh worker --listen=ADDR        host a node's stage copies (spawned
                                     by the socket driver; prints
                                     `PARLSH_WORKER_LISTEN <addr>`)
  parlsh experiment <datasets|fig3|fig4|table2|table3|fig5|fig6|ablation|executors|net|all>
                                     (`executors`/`net` also write
                                     BENCH_executors.json / BENCH_net.json;
                                     `net` spawns processes and is not part
                                     of `all`)
  parlsh tune       [--target=0.8] [--set ...]    suggest w, tune T (and M)
  parlsh calibrate

`serve` admission: --set stream.inflight=W bounds in-flight queries
(closed loop); 0 = open loop (default).

Env: PARLSH_N, PARLSH_Q scale experiments; PARLSH_SCALAR=1 forces the
scalar path; PARLSH_ARTIFACTS points at the AOT artifact dir;
PARLSH_INFLIGHT sets the batched-admission window of `experiment
executors`; PARLSH_WORKER_BIN overrides the worker binary.
";

fn cmd_build(args: &Args) -> Result<()> {
    let cfg = Config::load(args)?;
    let w = exp::world(&cfg);
    let b = exp::backends(&cfg, w.data.dim);
    println!(
        "building index: n={} L={} M={} T={} w={} ({} path)",
        w.data.len(),
        cfg.lsh.l,
        cfg.lsh.m,
        cfg.lsh.t,
        cfg.lsh.w,
        if b.engine_path { "PJRT artifact" } else { "scalar" },
    );
    let t = Timer::start();
    let cluster = build_index(&cfg, &w.data, b.hasher.as_ref());
    println!(
        "built in {:.2}s: {} objects across {} DPs, {} bucket refs across {} BIs",
        t.secs(),
        cluster.stored_objects(),
        cluster.dps.len(),
        cluster.bucket_references(),
        cluster.bis.len(),
    );
    let imb = parlsh::partition::imbalance(&cluster.dp_object_counts());
    println!(
        "partition: {} | load imbalance {:.2}% (cv {:.2}%)",
        cfg.stream.obj_map.name(),
        imb.max_over_mean_pct,
        imb.cv_pct
    );
    println!(
        "build traffic: {} logical msgs, {} packets, {:.3} GB",
        cluster.build_meter.logical_msgs,
        cluster.build_meter.total_packets(),
        cluster.build_meter.payload_bytes as f64 / 1e9,
    );
    Ok(())
}

fn cmd_search(args: &Args, threaded: bool) -> Result<()> {
    let cfg = Config::load(args)?;
    let w = exp::world(&cfg);
    let b = exp::backends(&cfg, w.data.dim);
    if args.has_flag("net") {
        if !threaded {
            bail!("--net is a serving transport: use `parlsh serve --net`");
        }
        return cmd_search_net(&cfg, &w, &b);
    }
    let mut cluster = build_index(&cfg, &w.data, b.hasher.as_ref());
    let t = Timer::start();
    let out = if threaded {
        search_threaded(&mut cluster, &w.queries, b.hasher.as_ref(), b.ranker.as_ref())
    } else {
        search(&mut cluster, &w.queries, b.hasher.as_ref(), b.ranker.as_ref())
    };
    let secs = t.secs();
    let recall = recall_at_k(&out.retrieved_ids(), &w.gt);
    let lat = latency_stats(&out.per_query_secs);
    let admission = match (threaded, cfg.stream.inflight) {
        (false, _) => String::new(),
        (true, 0) => ", open loop".to_string(),
        (true, w) => format!(", closed loop W={w}"),
    };
    println!(
        "searched {} queries in {:.2}s ({:.1} q/s, {} executor{admission}, {} path)",
        w.queries.len(),
        secs,
        w.queries.len() as f64 / secs,
        if threaded { "threaded" } else { "inline" },
        if b.engine_path { "PJRT artifact" } else { "scalar" },
    );
    println!("recall@{} = {recall:.3}", cfg.lsh.k);
    println!(
        "latency ms: mean {:.2} p50 {:.2} p90 {:.2} p99 {:.2} max {:.2}",
        lat.mean_ms, lat.p50_ms, lat.p90_ms, lat.p99_ms, lat.max_ms
    );
    println!(
        "traffic: {} logical msgs ({} local), {} packets, {:.3} GB",
        out.meter.logical_msgs,
        out.meter.local_msgs,
        out.meter.total_packets(),
        out.meter.payload_bytes as f64 / 1e9,
    );
    Ok(())
}

/// The acceptance path of DESIGN.md §Transports: the full build + search
/// pipeline across one OS process per BI/DP node on loopback, with
/// per-link wire bytes from the real codec and a typed shutdown.
fn cmd_search_net(cfg: &Config, w: &exp::World, b: &exp::Backends) -> Result<()> {
    let n_workers = cfg.cluster.bi_nodes + cfg.cluster.dp_nodes;
    println!(
        "spawning {n_workers} `parlsh worker` processes on loopback (+ this driver as head node)"
    );
    let sess = NetSession::launch(cfg, w.data.dim)?;
    let mut cluster = build_index_on(sess.executor(), cfg, &w.data, b.hasher.as_ref());
    println!(
        "built in {:.2}s across {n_workers} workers: {} logical msgs, {} tcp packets, {:.3} MB on the wire",
        cluster.build_wall_secs,
        cluster.build_meter.logical_msgs,
        cluster.build_meter.total_packets(),
        cluster.build_meter.total_bytes() as f64 / 1e6,
    );
    let t = Timer::start();
    let out = search_on(
        sess.executor(),
        &mut cluster,
        &w.queries,
        b.hasher.as_ref(),
        b.ranker.as_ref(),
    );
    let secs = t.secs();
    sess.shutdown()?;
    println!("all {n_workers} workers exited cleanly");

    let recall = recall_at_k(&out.retrieved_ids(), &w.gt);
    let lat = latency_stats(&out.per_query_secs);
    let admission = match cfg.stream.inflight {
        0 => "open loop".to_string(),
        win => format!("closed loop W={win}"),
    };
    // Workers always rank with the scalar oracle (DESIGN.md §Transports);
    // only driver-side hashing can take the artifact path.
    println!(
        "searched {} queries in {secs:.2}s ({:.1} q/s, socket executor, {admission}, {} hashing, scalar ranking in workers)",
        w.queries.len(),
        w.queries.len() as f64 / secs,
        if b.engine_path { "PJRT-artifact" } else { "scalar" },
    );
    println!("recall@{} = {recall:.3}", cfg.lsh.k);
    println!(
        "latency ms: mean {:.2} p50 {:.2} p90 {:.2} p99 {:.2} max {:.2}",
        lat.mean_ms, lat.p50_ms, lat.p90_ms, lat.p99_ms, lat.max_ms
    );
    println!(
        "search wire traffic (real codec bytes, not the wire_size model): \
         {} logical msgs ({} local), {} tcp packets, {:.3} MB",
        out.meter.logical_msgs,
        out.meter.local_msgs,
        out.meter.total_packets(),
        out.meter.total_bytes() as f64 / 1e6,
    );
    print!("{}", out.meter.link_report());
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let run = |id: &str| -> Result<()> {
        match id {
            "datasets" => {
                println!("== Table I (datasets, scaled stand-ins) ==");
                exp::datasets_table().print();
            }
            "fig3" => {
                println!("== Fig. 3 (weak-scaling efficiency) ==");
                exp::fig3_weak_scaling().print();
            }
            "fig4" | "table2" => {
                let pts = exp::multiprobe_sweep(&[1, 30, 60, 90, 120]);
                println!("== Fig. 4 (time & recall vs T) ==");
                exp::fig4_table(&pts).print();
                println!("== Table II (traffic vs T) ==");
                exp::table2(&pts).print();
            }
            "table3" => {
                println!("== Table III (M sweep) ==");
                exp::table3_m_sweep(&[28, 30, 32]).print();
            }
            "fig5" => {
                println!("== Fig. 5 (L sweep at iso-recall) ==");
                exp::fig5_l_sweep(&[4, 6, 8], 0.74).print();
            }
            "fig6" => {
                println!("== Fig. 6 (partition strategies) ==");
                exp::fig6_partition().print();
            }
            "ablation" => {
                println!("== §V-B ablation (intra-stage parallelism) ==");
                exp::ablation_intrastage().print();
            }
            "executors" => {
                println!("== Executor comparison (inline / threaded / batched) ==");
                let t = exp::executor_comparison();
                t.print();
                t.write_json("BENCH_executors.json", "executors")?;
                println!("(wrote BENCH_executors.json)");
            }
            "net" => {
                println!("== Socket transport: obj_map strategies by real wire bytes ==");
                let (t, json) = exp::net_comparison()?;
                t.print();
                std::fs::write("BENCH_net.json", json)?;
                println!("(wrote BENCH_net.json)");
            }
            other => bail!("unknown experiment `{other}`"),
        }
        Ok(())
    };
    if id == "all" {
        for id in [
            "datasets", "fig3", "fig4", "table3", "fig5", "fig6", "ablation",
            "executors",
        ] {
            run(id)?;
            println!();
        }
        Ok(())
    } else {
        run(id)
    }
}

fn cmd_tune(args: &Args) -> Result<()> {
    // The paper's tuning phase (§V-D): run the sequential baseline over a
    // small partition of the dataset to pick w, T (and inspect M).
    let mut cfg = Config::load(args)?;
    let target = args
        .opt_f64("target", 0.8)
        .map_err(|e| anyhow::anyhow!(e))?;
    cfg.data.n = cfg.data.n.min(20_000); // small partition, as in the paper
    cfg.data.queries = cfg.data.queries.min(100);
    let w = exp::world(&cfg);
    let suggested = parlsh::baseline::suggest_w(&w.data, 256, cfg.lsh.seed);
    println!(
        "suggested w from NN-distance scale: {suggested:.0} (config: {})",
        cfg.lsh.w
    );
    println!("tuning T to recall >= {target} at L={} M={}:", cfg.lsh.l, cfg.lsh.m);
    let trace = parlsh::baseline::tune_t(&w.data, &w.queries, cfg.lsh, target, 512);
    for p in &trace {
        println!("  T={:<4} recall={:.3} dists/query={:.0}", p.t, p.recall, p.dists_per_query);
    }
    let best = trace.last().unwrap();
    println!("-> use T={} (recall {:.3})", best.t, best.recall);
    println!("M scan at T={} (paper Table III decision):", best.t);
    let base = parlsh::core::lsh::LshParams { t: best.t, ..cfg.lsh };
    let ms = [cfg.lsh.m.saturating_sub(4).max(2), cfg.lsh.m, cfg.lsh.m + 4];
    for p in parlsh::baseline::tune_m(&w.data, &w.queries, base, &ms) {
        println!("  M={:<3} recall={:.3} dists/query={:.0}", p.m, p.recall, p.dists_per_query);
    }
    Ok(())
}

fn cmd_calibrate() -> Result<()> {
    println!("calibrating cost model on this host...");
    let m = calibrate();
    println!("ns_per_dist      = {:.1}", m.ns_per_dist);
    println!("ns_per_proj      = {:.1}", m.ns_per_proj);
    println!("ns_per_probe_seq = {:.1}", m.ns_per_probe_seq);
    println!("ns_per_lookup    = {:.1}", m.ns_per_lookup);
    println!("ns_per_cand      = {:.1}", m.ns_per_cand);
    println!("ns_per_store     = {:.1}", m.ns_per_store);
    println!("ns_per_reduce    = {:.1}", m.ns_per_reduce);
    println!("(paste into CostModel::default() to pin; see EXPERIMENTS.md)");
    Ok(())
}
