//! Query-directed multi-probe sequence generation (Lv et al., VLDB'07, §4).
//!
//! For one table, a query lands at coordinates `h_i = floor(f_i)`; its
//! distance (in units of w) to the adjacent bucket in dimension `i` is
//! `x_i(-1) = frac(f_i)` downward and `x_i(+1) = 1 - frac(f_i)` upward. A
//! *perturbation set* picks a δ ∈ {−1,+1} for a subset of dimensions; its
//! score is `Σ x_i(δ)²` — a monotone proxy for the probability the perturbed
//! bucket holds near neighbors. Sets are enumerated in non-decreasing score
//! order with the shift/expand min-heap over the 2M sorted boundary
//! distances.
//!
//! Two consumers share this enumeration: `HashFamily::query_probes` walks
//! the sets to produce the actual probe bucket keys, and the QoS
//! scheduler's [`crate::qos::adaptive_probes`] pools the same
//! [`set_score`]s across a query's tables to pick a *per-query* probe
//! budget from its score profile (mmLSH; DESIGN.md §QoS scheduler) — so
//! the budget policy and the probe walk always agree on what a
//! perturbation costs.

use crate::core::topk::OrderedF32;
use std::collections::BinaryHeap;

/// One perturbation set: `(dimension, δ)` pairs, δ ∈ {−1, +1}.
pub type PerturbationSet = Vec<(u16, i8)>;

/// Candidate boundary move used during enumeration.
#[derive(Clone, Copy, Debug)]
struct Move {
    dim: u16,
    delta: i8,
    score: f32, // x_i(δ)²
}

#[derive(Clone, Debug)]
struct HeapSet {
    /// Indices into the sorted move array; last element is the maximum.
    idx: Vec<u16>,
    score: f32,
}

impl PartialEq for HeapSet {
    fn eq(&self, other: &Self) -> bool {
        self.score == other.score && self.idx == other.idx
    }
}
impl Eq for HeapSet {}
impl PartialOrd for HeapSet {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapSet {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; we want the *smallest* score on top.
        OrderedF32(other.score)
            .cmp(&OrderedF32(self.score))
            .then_with(|| other.idx.cmp(&self.idx))
    }
}

/// Generate up to `t - 1` perturbation sets (the home bucket is probe #0) in
/// non-decreasing score order for one table.
///
/// `fracs[i]` must be the fractional part of the raw projection `f_i` for
/// each of the table's M dimensions.
pub fn probe_sequence(fracs: &[f32], t: usize) -> Vec<PerturbationSet> {
    let m = fracs.len();
    if t <= 1 || m == 0 {
        return Vec::new();
    }
    // Build the 2M candidate moves, sorted ascending by score.
    let mut moves = Vec::with_capacity(2 * m);
    for (i, &fr) in fracs.iter().enumerate() {
        let fr = fr.clamp(0.0, 1.0);
        moves.push(Move { dim: i as u16, delta: -1, score: fr * fr });
        moves.push(Move { dim: i as u16, delta: 1, score: (1.0 - fr) * (1.0 - fr) });
    }
    moves.sort_unstable_by(|a, b| {
        a.score
            .total_cmp(&b.score)
            .then(a.dim.cmp(&b.dim))
            .then(a.delta.cmp(&b.delta))
    });
    let n = moves.len() as u16;

    let mut heap = BinaryHeap::new();
    heap.push(HeapSet { idx: vec![0], score: moves[0].score });
    let mut out = Vec::with_capacity(t - 1);

    while let Some(set) = heap.pop() {
        if out.len() >= t - 1 {
            break;
        }
        let max = *set.idx.last().unwrap();
        if is_valid(&set.idx, &moves) {
            out.push(
                set.idx
                    .iter()
                    .map(|&j| (moves[j as usize].dim, moves[j as usize].delta))
                    .collect(),
            );
        }
        // shift: replace the max element with its successor.
        // expand: additionally include the successor.
        // (§Perf: the popped Vec is reused for the expand child — one
        // allocation per pop instead of two.)
        if max + 1 < n {
            let mut shift_idx = Vec::with_capacity(set.idx.len());
            shift_idx.extend_from_slice(&set.idx[..set.idx.len() - 1]);
            shift_idx.push(max + 1);
            let succ = moves[max as usize + 1].score;
            heap.push(HeapSet {
                idx: shift_idx,
                score: set.score - moves[max as usize].score + succ,
            });
            let mut expand_idx = set.idx;
            expand_idx.push(max + 1);
            heap.push(HeapSet { idx: expand_idx, score: set.score + succ });
        }
    }
    out
}

/// A set is valid iff it never perturbs the same dimension twice
/// (i.e. never contains both (i,−1) and (i,+1)).
fn is_valid(idx: &[u16], moves: &[Move]) -> bool {
    for (a, &i) in idx.iter().enumerate() {
        for &j in &idx[a + 1..] {
            if moves[i as usize].dim == moves[j as usize].dim {
                return false;
            }
        }
    }
    true
}

/// Score of a perturbation set against the fractional parts (test helper and
/// the quantity the enumeration orders by).
pub fn set_score(set: &PerturbationSet, fracs: &[f32]) -> f32 {
    set.iter()
        .map(|&(dim, delta)| {
            let fr = fracs[dim as usize].clamp(0.0, 1.0);
            let x = if delta < 0 { fr } else { 1.0 - fr };
            x * x
        })
        .sum()
}

/// Apply a perturbation set to a table's home coordinates.
pub fn apply_set(coords_t: &[i32], set: &PerturbationSet) -> Vec<i32> {
    let mut out = coords_t.to_vec();
    for &(dim, delta) in set {
        out[dim as usize] += delta as i32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::check;

    fn fracs_from_gen(g: &mut crate::util::minitest::Gen, m: usize) -> Vec<f32> {
        (0..m).map(|_| g.f32_in(0.001, 0.999)).collect()
    }

    #[test]
    fn t1_yields_no_perturbations() {
        assert!(probe_sequence(&[0.5, 0.5], 1).is_empty());
        assert!(probe_sequence(&[], 10).is_empty());
    }

    #[test]
    fn first_probe_is_single_closest_boundary() {
        let fracs = vec![0.9, 0.4, 0.05];
        let seq = probe_sequence(&fracs, 2);
        assert_eq!(seq.len(), 1);
        // dim 2 lower boundary at distance 0.05 is the closest move.
        assert_eq!(seq[0], vec![(2u16, -1i8)]);
    }

    #[test]
    fn scores_nondecreasing_property() {
        check("mp-scores-sorted", 50, |g| {
            let m = g.usize_in(2, 12);
            let t = g.usize_in(2, 40);
            let fracs = fracs_from_gen(g, m);
            let seq = probe_sequence(&fracs, t);
            let scores: Vec<f32> = seq.iter().map(|s| set_score(s, &fracs)).collect();
            for w in scores.windows(2) {
                assert!(
                    w[0] <= w[1] + 1e-5,
                    "scores not sorted: {:?}",
                    scores
                );
            }
        });
    }

    #[test]
    fn sets_are_valid_and_unique_property() {
        check("mp-sets-valid-unique", 50, |g| {
            let m = g.usize_in(2, 10);
            let t = g.usize_in(2, 60);
            let fracs = fracs_from_gen(g, m);
            let seq = probe_sequence(&fracs, t);
            let mut seen = std::collections::HashSet::new();
            for set in &seq {
                // no dim perturbed twice
                let dims: std::collections::HashSet<_> =
                    set.iter().map(|&(d, _)| d).collect();
                assert_eq!(dims.len(), set.len(), "dim repeated in {set:?}");
                // canonical form for uniqueness
                let mut canon = set.clone();
                canon.sort();
                assert!(seen.insert(canon), "duplicate set {set:?}");
                // deltas are ±1 and dims in range
                for &(d, delta) in set {
                    assert!((d as usize) < m);
                    assert!(delta == 1 || delta == -1);
                }
            }
        });
    }

    #[test]
    fn matches_bruteforce_enumeration_for_small_m() {
        check("mp-matches-bruteforce", 20, |g| {
            let m = g.usize_in(2, 5);
            let fracs = fracs_from_gen(g, m);
            let t = 16usize;
            let seq = probe_sequence(&fracs, t);
            // Brute force: all 3^m - 1 nonempty δ assignments, sorted by score.
            let mut all: Vec<(f32, PerturbationSet)> = Vec::new();
            let mut stack: Vec<(usize, PerturbationSet)> = vec![(0, vec![])];
            while let Some((i, cur)) = stack.pop() {
                if i == m {
                    if !cur.is_empty() {
                        all.push((set_score(&cur, &fracs), cur));
                    }
                    continue;
                }
                for opt in [None, Some(-1i8), Some(1i8)] {
                    let mut next = cur.clone();
                    if let Some(d) = opt {
                        next.push((i as u16, d));
                    }
                    stack.push((i + 1, next));
                }
            }
            all.sort_by(|a, b| OrderedF32(a.0).cmp(&OrderedF32(b.0)));
            let want: Vec<f32> = all
                .iter()
                .take(seq.len())
                .map(|(s, _)| *s)
                .collect();
            let got: Vec<f32> = seq.iter().map(|s| set_score(s, &fracs)).collect();
            for (a, b) in got.iter().zip(&want) {
                assert!(
                    (a - b).abs() < 1e-5,
                    "probe scores diverge: got {got:?} want {want:?}"
                );
            }
        });
    }

    #[test]
    fn apply_set_perturbs_coords() {
        let coords = vec![10, 20, 30];
        let set = vec![(0u16, -1i8), (2u16, 1i8)];
        assert_eq!(apply_set(&coords, &set), vec![9, 20, 31]);
    }

    #[test]
    fn requested_count_or_exhaustion() {
        // For m dims there are finitely many valid sets; asking for more
        // returns what exists, asking for few returns exactly t-1.
        let fracs = vec![0.3, 0.7];
        let seq = probe_sequence(&fracs, 5);
        assert_eq!(seq.len(), 4);
        let seq_all = probe_sequence(&fracs, 100);
        // 3^2 - 1 = 8 valid nonempty sets
        assert_eq!(seq_all.len(), 8);
    }
}
