//! p-stable LSH hash family (Datar et al., SoCG'04) and bucket keying.
//!
//! A family member is `h_{a,b}(v) = floor((a·v + b) / w)` with `a ~ N(0, I)`
//! and `b ~ U(0, w)`. An index uses `L` tables of `M` concatenated functions;
//! all `P = L·M` projections are stored as one bank so a single matmul (the
//! Pallas `lsh_hash` kernel) hashes a vector for every table at once.

use crate::util::rng::{mix64, Rng};

/// LSH index parameters (paper notation).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LshParams {
    /// Number of hash tables (paper: L, default 6).
    pub l: usize,
    /// Hash functions concatenated per table (paper: M, default 32).
    pub m: usize,
    /// Quantization width w of the p-stable family.
    pub w: f32,
    /// Neighbors to retrieve (paper: k = 10).
    pub k: usize,
    /// Probes per table for multi-probe LSH (paper: T; 1 = home bucket only).
    pub t: usize,
    /// Seed for sampling the family.
    pub seed: u64,
}

impl Default for LshParams {
    fn default() -> Self {
        // w tuned on the synthetic SIFT stand-in so the default operating
        // point (L=6, M=32, T=30) lands at recall ≈ 0.7 — the regime the
        // paper's Table III / Fig. 4 explore (see EXPERIMENTS.md).
        LshParams { l: 6, m: 32, w: 1200.0, k: 10, t: 30, seed: 42 }
    }
}

impl LshParams {
    pub fn projections(&self) -> usize {
        self.l * self.m
    }
}

/// A sampled p-stable family: the projection bank for all L tables.
#[derive(Clone, Debug)]
pub struct HashFamily {
    pub dim: usize,
    pub params: LshParams,
    /// Projection directions, row-major `[P][dim]` (row p = a_p).
    a: Vec<f32>,
    /// Offsets `b_p ~ U(0, w)`, length P.
    b: Vec<f32>,
    /// Per-projection odd multipliers for bucket keying.
    r: Vec<u64>,
}

impl HashFamily {
    /// Sample a family; deterministic in `(dim, params.seed)`.
    pub fn sample(dim: usize, params: LshParams) -> HashFamily {
        let p = params.projections();
        assert!(p > 0, "L*M must be positive");
        let mut rng = Rng::new(params.seed);
        let mut a = Vec::with_capacity(p * dim);
        for _ in 0..p * dim {
            a.push(rng.gaussian_f32());
        }
        let mut b = Vec::with_capacity(p);
        for _ in 0..p {
            b.push(rng.range_f32(0.0, params.w));
        }
        let r = (0..p).map(|_| rng.next_u64() | 1).collect();
        HashFamily { dim, params, a, b, r }
    }

    /// Projection bank transposed to `[dim][P]` column-major-for-v layout —
    /// the layout the AOT `hash` artifact expects (`X @ A`).
    pub fn a_transposed(&self) -> Vec<f32> {
        let p = self.params.projections();
        let mut out = vec![0f32; p * self.dim];
        for row in 0..p {
            for d in 0..self.dim {
                out[d * p + row] = self.a[row * self.dim + d];
            }
        }
        out
    }

    pub fn offsets(&self) -> &[f32] {
        &self.b
    }

    /// Raw (un-floored) projections `(a_p·v + b_p) / w` for all P functions.
    /// The fractional parts drive the multi-probe sequence.
    pub fn raw_projections(&self, v: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; self.params.projections()];
        self.proj_into(v, &mut out);
        out
    }

    /// Write-into-slice variant of [`Self::raw_projections`] — the batched
    /// hasher paths reuse one output buffer across rows instead of
    /// allocating a fresh `Vec` per vector.
    ///
    /// Reduction-order contract (DESIGN.md §Kernels): each projection is a
    /// *sequential* single-accumulator dot product over `dim` — the SIMD
    /// kernels reproduce exactly this order (lane-per-projection over the
    /// transposed bank, no FMA) so their outputs are bit-identical.
    pub fn proj_into(&self, v: &[f32], out: &mut [f32]) {
        debug_assert_eq!(v.len(), self.dim);
        let p = self.params.projections();
        debug_assert_eq!(out.len(), p);
        let inv_w = 1.0 / self.params.w;
        for row in 0..p {
            let a_row = &self.a[row * self.dim..(row + 1) * self.dim];
            let mut acc = 0f32;
            for (x, y) in a_row.iter().zip(v) {
                acc += x * y;
            }
            out[row] = (acc + self.b[row]) * inv_w;
        }
    }

    /// Quantized hash coordinates `h_p(v)` for all P functions (scalar path;
    /// the PJRT artifact computes the same thing batched).
    pub fn hash_coords(&self, v: &[f32]) -> Vec<i32> {
        self.raw_projections(v)
            .into_iter()
            .map(|f| f.floor() as i32)
            .collect()
    }

    /// Write-into-slice variant of [`Self::hash_coords`]: projects into
    /// `scratch` (length P, reused by callers across rows) and floors into
    /// `out` — zero allocations on the batched hot path.
    pub fn coords_into(&self, v: &[f32], scratch: &mut [f32], out: &mut [i32]) {
        debug_assert_eq!(out.len(), scratch.len());
        self.proj_into(v, scratch);
        for (c, f) in out.iter_mut().zip(scratch.iter()) {
            *c = f.floor() as i32;
        }
    }

    /// Bucket key for table `t` from the full P-length coordinate vector.
    ///
    /// The key folds the M coordinates of table `t` with per-projection odd
    /// multipliers and finalizes with splitmix64 (a strong 64-bit identity;
    /// collisions are ~2^-64, standing in for E2LSH's two-level scheme).
    /// The table id is salted in so identical coordinate tuples in different
    /// tables never alias.
    #[inline]
    pub fn bucket_key(&self, table: usize, coords: &[i32]) -> u64 {
        let m = self.params.m;
        debug_assert_eq!(coords.len(), self.params.projections());
        self.bucket_key_of_slice(table, &coords[table * m..(table + 1) * m])
    }

    /// Bucket key from just the table's own M coordinates.
    #[inline]
    pub fn bucket_key_of_slice(&self, table: usize, coords_t: &[i32]) -> u64 {
        let m = self.params.m;
        debug_assert_eq!(coords_t.len(), m);
        let mut acc = 0x9E3779B97F4A7C15u64 ^ (table as u64) << 56;
        for (j, &c) in coords_t.iter().enumerate() {
            acc = acc
                .wrapping_add((c as i64 as u64).wrapping_mul(self.r[table * m + j]));
            acc = acc.rotate_left(7);
        }
        mix64(acc)
    }

    /// All L bucket keys of a vector (home buckets).
    pub fn bucket_keys(&self, v: &[f32]) -> Vec<u64> {
        let coords = self.hash_coords(v);
        (0..self.params.l)
            .map(|t| self.bucket_key(t, &coords))
            .collect()
    }

    /// All probe bucket keys for a query given its raw projections: for
    /// each of the first `tables` (≤ L) hash tables, the home bucket
    /// followed by the `t-1` best multi-probe perturbations (Lv et al.
    /// score order). Both knobs are *per call* — the per-query search-plan
    /// redesign (DESIGN.md §Service API) routes each query's own `T`/`L'`
    /// here instead of freezing `family.params` at build time. Shared by
    /// the distributed Query Receiver and the sequential baseline so both
    /// visit *exactly* the same buckets.
    pub fn query_probes(&self, raw: &[f32], t_probes: usize, tables: usize) -> Vec<(u8, u64)> {
        use crate::core::multiprobe::{apply_set, probe_sequence};
        // `.max(1)` keeps clamp's min<=max invariant even for a degenerate
        // family (L=0 cannot be sampled, but stay panic-free regardless).
        let l = tables.clamp(1, self.params.l.max(1));
        let m = self.params.m;
        let t_probes = t_probes.max(1);
        let mut probes = Vec::with_capacity(l * t_probes);
        for table in 0..l {
            let raw_t = &raw[table * m..(table + 1) * m];
            let coords_t: Vec<i32> = raw_t.iter().map(|f| f.floor() as i32).collect();
            let fracs: Vec<f32> = raw_t
                .iter()
                .zip(&coords_t)
                .map(|(f, c)| f - *c as f32)
                .collect();
            probes.push((table as u8, self.bucket_key_of_slice(table, &coords_t)));
            for set in probe_sequence(&fracs, t_probes) {
                let perturbed = apply_set(&coords_t, &set);
                probes.push((table as u8, self.bucket_key_of_slice(table, &perturbed)));
            }
        }
        probes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::check;

    fn small_family() -> HashFamily {
        HashFamily::sample(
            16,
            LshParams { l: 3, m: 4, w: 4.0, k: 5, t: 1, seed: 7 },
        )
    }

    #[test]
    fn deterministic_in_seed() {
        let f1 = small_family();
        let f2 = small_family();
        let v: Vec<f32> = (0..16).map(|i| i as f32).collect();
        assert_eq!(f1.hash_coords(&v), f2.hash_coords(&v));
        assert_eq!(f1.bucket_keys(&v), f2.bucket_keys(&v));
    }

    #[test]
    fn different_seed_different_family() {
        let f1 = small_family();
        let f2 = HashFamily::sample(
            16,
            LshParams { seed: 8, ..f1.params },
        );
        let v: Vec<f32> = (0..16).map(|i| i as f32).collect();
        assert_ne!(f1.bucket_keys(&v), f2.bucket_keys(&v));
    }

    #[test]
    fn coords_match_raw_floor() {
        let f = small_family();
        let v: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let raw = f.raw_projections(&v);
        let coords = f.hash_coords(&v);
        for (r, c) in raw.iter().zip(&coords) {
            assert_eq!(r.floor() as i32, *c);
        }
    }

    #[test]
    fn nearby_points_collide_more() {
        // LSH property smoke: near pairs share more per-table buckets than
        // far pairs, averaged over samples.
        let f = HashFamily::sample(
            32,
            LshParams { l: 8, m: 4, w: 4.0, k: 5, t: 1, seed: 3 },
        );
        let mut rng = Rng::new(11);
        let (mut near_hits, mut far_hits) = (0usize, 0usize);
        let trials = 200;
        for _ in 0..trials {
            let x: Vec<f32> = (0..32).map(|_| rng.gaussian_f32() * 5.0).collect();
            let near: Vec<f32> = x.iter().map(|v| v + 0.05 * rng.gaussian_f32()).collect();
            let far: Vec<f32> = (0..32).map(|_| rng.gaussian_f32() * 5.0).collect();
            let kx = f.bucket_keys(&x);
            let kn = f.bucket_keys(&near);
            let kf = f.bucket_keys(&far);
            near_hits += kx.iter().zip(&kn).filter(|(a, b)| a == b).count();
            far_hits += kx.iter().zip(&kf).filter(|(a, b)| a == b).count();
        }
        assert!(
            near_hits > far_hits * 3,
            "near {near_hits} vs far {far_hits}"
        );
    }

    #[test]
    fn table_salt_prevents_cross_table_alias() {
        let f = small_family();
        let coords = vec![0i32; 12];
        let k0 = f.bucket_key(0, &coords);
        let k1 = f.bucket_key(1, &coords);
        assert_ne!(k0, k1);
    }

    #[test]
    fn bucket_key_slice_agrees_with_full() {
        check("bucket-key-slice", 40, |g| {
            let f = small_family();
            let coords: Vec<i32> = (0..12).map(|_| g.i32_in(-100, 100)).collect();
            for t in 0..3 {
                assert_eq!(
                    f.bucket_key(t, &coords),
                    f.bucket_key_of_slice(t, &coords[t * 4..(t + 1) * 4])
                );
            }
        });
    }

    #[test]
    fn query_probes_honors_per_call_table_limit() {
        let f = small_family();
        let v: Vec<f32> = (0..16).map(|i| (i as f32).cos() * 3.0).collect();
        let raw = f.raw_projections(&v);
        let all = f.query_probes(&raw, 4, f.params.l);
        let first_two = f.query_probes(&raw, 4, 2);
        // the L'-limited sequence is exactly the prefix tables of the full one
        assert!(first_two.iter().all(|&(t, _)| t < 2));
        let want: Vec<(u8, u64)> =
            all.iter().copied().filter(|&(t, _)| t < 2).collect();
        assert_eq!(first_two, want);
        // out-of-range requests clamp into 1..=L
        assert_eq!(f.query_probes(&raw, 4, 99), all);
        assert!(f.query_probes(&raw, 4, 0).iter().all(|&(t, _)| t == 0));
    }

    #[test]
    fn into_variants_match_allocating_api() {
        check("proj-into-matches", 40, |g| {
            let f = small_family();
            let v = g.vec_f32(16, -8.0, 8.0);
            let p = f.params.projections();
            let mut proj = vec![0f32; p];
            f.proj_into(&v, &mut proj);
            // bit-exact, not tolerance: the into-variant is the same loop
            assert_eq!(proj, f.raw_projections(&v));
            let mut scratch = vec![0f32; p];
            let mut coords = vec![0i32; p];
            f.coords_into(&v, &mut scratch, &mut coords);
            assert_eq!(coords, f.hash_coords(&v));
        });
    }

    #[test]
    fn transpose_roundtrip() {
        let f = small_family();
        let at = f.a_transposed();
        let p = f.params.projections();
        for row in 0..p {
            for d in 0..f.dim {
                assert_eq!(at[d * p + row], f.a[row * f.dim + d]);
            }
        }
    }

    use crate::util::rng::Rng;
}
