//! Core LSH machinery: p-stable hash families, bucket keying, the
//! query-directed multi-probe sequence (Lv et al., VLDB'07), Z-order curves,
//! and top-k selection.
//!
//! Everything here is deterministic given a seed and shared between the
//! distributed pipeline, the sequential baseline, and the PJRT artifact path
//! (the projection bank is uploaded to the runtime so scalar and compiled
//! hashing agree bit-for-bit up to f32 boundary ties).

pub mod lsh;
pub mod multiprobe;
pub mod topk;
pub mod zorder;

pub use lsh::{HashFamily, LshParams};
pub use multiprobe::{probe_sequence, PerturbationSet};
pub use topk::{OrderedF32, TopK};
pub use zorder::zorder_key;
