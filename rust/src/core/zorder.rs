//! Z-order (Morton) curve keys — the locality-preserving fractal mapping the
//! paper evaluates as an `obj_map` partition strategy (§IV-C).
//!
//! A 128-d SIFT vector cannot be fully bit-interleaved into 64 bits, so we
//! subsample `ZDIMS` evenly spaced dimensions, quantize each to
//! `64 / ZDIMS` bits over a fixed value range, and interleave bit-planes
//! MSB-first. Nearby vectors (which agree in their coarse coordinates) map to
//! nearby z-values, which the partitioner then range-scales onto copies.

/// Number of dimensions folded into the key.
pub const ZDIMS: usize = 8;
/// Bits per dimension (ZDIMS * ZBITS = 64).
pub const ZBITS: usize = 8;

/// Z-order key of a vector over `[lo, hi]` per-coordinate value range.
///
/// Uses dimensions `0, dim/ZDIMS, 2·dim/ZDIMS, …` so the subsample spans the
/// descriptor. Quantization clamps out-of-range values.
pub fn zorder_key(v: &[f32], lo: f32, hi: f32) -> u64 {
    let dim = v.len();
    debug_assert!(dim >= ZDIMS, "vector shorter than ZDIMS");
    let stride = dim / ZDIMS;
    let scale = (1u32 << ZBITS) as f32 / (hi - lo);
    let mut q = [0u32; ZDIMS];
    for (j, slot) in q.iter_mut().enumerate() {
        let x = v[j * stride];
        let t = ((x - lo) * scale) as i64;
        *slot = t.clamp(0, (1 << ZBITS) - 1) as u32;
    }
    interleave(&q)
}

/// Interleave ZDIMS coordinates of ZBITS each, MSB-first, into one u64 whose
/// high bits are the highest-order bit-plane (so numeric order on the key is
/// Z-order on the coordinates).
fn interleave(q: &[u32; ZDIMS]) -> u64 {
    let mut key = 0u64;
    for bit in (0..ZBITS).rev() {
        for &c in q.iter() {
            key = (key << 1) | ((c >> bit) & 1) as u64;
        }
    }
    key
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::check;

    fn vec_of(val: f32) -> Vec<f32> {
        vec![val; 128]
    }

    #[test]
    fn monotone_on_diagonal() {
        // Along the main diagonal, z-order equals plain numeric order.
        let mut prev = zorder_key(&vec_of(0.0), 0.0, 256.0);
        for i in 1..=255 {
            let k = zorder_key(&vec_of(i as f32), 0.0, 256.0);
            assert!(k > prev, "not monotone at {i}");
            prev = k;
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let below = zorder_key(&vec_of(-100.0), 0.0, 256.0);
        let above = zorder_key(&vec_of(1e9), 0.0, 256.0);
        assert_eq!(below, 0);
        assert_eq!(above, u64::MAX);
    }

    #[test]
    fn quadrant_separation() {
        // All points in the "low" half-space sort before all in the "high"
        // half-space when they differ in every sampled dimension's MSB.
        let lo = zorder_key(&vec_of(10.0), 0.0, 256.0);
        let hi = zorder_key(&vec_of(200.0), 0.0, 256.0);
        assert!(lo < hi);
    }

    #[test]
    fn locality_property() {
        // Small perturbations (within one quantization cell) rarely change
        // the key by more than a low-order-bit amount; far jumps change high
        // bits. Statistical: compare average key XOR-distance.
        check("zorder-locality", 30, |g| {
            let base: Vec<f32> = (0..128).map(|_| g.f32_in(16.0, 240.0)).collect();
            let near: Vec<f32> = base.iter().map(|x| x + g.f32_in(-0.4, 0.4)).collect();
            let far: Vec<f32> = (0..128).map(|_| g.f32_in(0.0, 256.0)).collect();
            let kb = zorder_key(&base, 0.0, 256.0);
            let kn = zorder_key(&near, 0.0, 256.0);
            let kf = zorder_key(&far, 0.0, 256.0);
            let near_bits = 64 - (kb ^ kn).leading_zeros();
            let far_bits = 64 - (kb ^ kf).leading_zeros();
            // near perturbation must not flip strictly higher bit-planes
            // than a complete resample does (ties allowed).
            assert!(near_bits <= far_bits.max(16));
        });
    }

    #[test]
    fn interleave_bit_layout() {
        // dim 0 owns the MSB of the key.
        let mut q = [0u32; ZDIMS];
        q[0] = 1 << (ZBITS - 1);
        assert_eq!(interleave(&q), 1u64 << 63);
        // last dim owns the LSB.
        let mut q2 = [0u32; ZDIMS];
        q2[ZDIMS - 1] = 1;
        assert_eq!(interleave(&q2), 1);
    }
}
