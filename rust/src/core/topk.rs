//! Bounded top-k selection (k nearest by distance) over streaming candidates.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Total-ordered f32 wrapper (NaN sorts last; distances are never NaN on the
/// hot path but robustness is cheap).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OrderedF32(pub f32);

impl Eq for OrderedF32 {}

impl PartialOrd for OrderedF32 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrderedF32 {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.partial_cmp(&other.0).unwrap_or_else(|| {
            match (self.0.is_nan(), other.0.is_nan()) {
                (true, true) => Ordering::Equal,
                (true, false) => Ordering::Greater,
                (false, true) => Ordering::Less,
                _ => unreachable!(),
            }
        })
    }
}

/// Keep the `k` smallest `(distance, id)` pairs seen so far.
///
/// Ties on distance are broken by id so results are deterministic across the
/// distributed pipeline (where candidates arrive in arbitrary order) and the
/// sequential baseline.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    heap: BinaryHeap<(OrderedF32, u32)>, // max-heap: root = current worst
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        TopK { k, heap: BinaryHeap::with_capacity(k + 1) }
    }

    #[inline]
    pub fn push(&mut self, dist: f32, id: u32) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push((OrderedF32(dist), id));
        } else {
            // SAFETY of unwrap: heap non-empty because k > 0 and len == k.
            let worst = *self.heap.peek().unwrap();
            if (OrderedF32(dist), id) < worst {
                self.heap.pop();
                self.heap.push((OrderedF32(dist), id));
            }
        }
    }

    /// Current admission threshold (distance of the worst kept candidate),
    /// or +inf while under-full. Lets callers skip work early.
    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap.peek().map(|(d, _)| d.0).unwrap_or(f32::INFINITY)
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Merge another TopK into this one.
    pub fn merge(&mut self, other: &TopK) {
        for &(d, id) in other.heap.iter() {
            self.push(d.0, id);
        }
    }

    /// Extract results sorted ascending by (distance, id).
    pub fn into_sorted(self) -> Vec<(f32, u32)> {
        let mut v: Vec<(OrderedF32, u32)> = self.heap.into_vec();
        v.sort_unstable();
        v.into_iter().map(|(d, id)| (d.0, id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minitest::check;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_k_smallest() {
        let mut tk = TopK::new(3);
        for (i, d) in [9.0, 1.0, 5.0, 3.0, 7.0, 2.0].iter().enumerate() {
            tk.push(*d, i as u32);
        }
        let out = tk.into_sorted();
        assert_eq!(out.iter().map(|x| x.0).collect::<Vec<_>>(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn tie_break_by_id() {
        let mut tk = TopK::new(2);
        tk.push(1.0, 7);
        tk.push(1.0, 3);
        tk.push(1.0, 5);
        let out = tk.into_sorted();
        assert_eq!(out, vec![(1.0, 3), (1.0, 5)]);
    }

    #[test]
    fn tie_break_is_order_independent() {
        // Equal distances fed in both arrival orders must produce the same
        // output — the heap keeps the lower ids either way. This is the
        // invariant the pruning ranker's strict bound check leans on.
        let feed = |ids: &[u32]| {
            let mut tk = TopK::new(2);
            for &id in ids {
                tk.push(1.0, id);
            }
            tk.into_sorted()
        };
        let fwd = feed(&[2, 9, 4]);
        let rev = feed(&[4, 9, 2]);
        assert_eq!(fwd, rev);
        assert_eq!(fwd, vec![(1.0, 2), (1.0, 4)]);
    }

    #[test]
    fn k_zero_is_noop() {
        let mut tk = TopK::new(0);
        tk.push(1.0, 1);
        assert!(tk.is_empty());
        assert!(tk.into_sorted().is_empty());
    }

    #[test]
    fn threshold_tracks_worst() {
        let mut tk = TopK::new(2);
        assert_eq!(tk.threshold(), f32::INFINITY);
        tk.push(4.0, 0);
        assert_eq!(tk.threshold(), f32::INFINITY);
        tk.push(2.0, 1);
        assert_eq!(tk.threshold(), 4.0);
        tk.push(1.0, 2);
        assert_eq!(tk.threshold(), 2.0);
    }

    #[test]
    fn matches_full_sort_property() {
        check("topk-matches-sort", 60, |g| {
            let n = g.usize_in(0, 200);
            let k = g.usize_in(1, 20);
            let mut rng = Rng::new(g.rng.next_u64());
            let items: Vec<(f32, u32)> =
                (0..n).map(|i| (rng.f32() * 100.0, i as u32)).collect();
            let mut tk = TopK::new(k);
            for &(d, id) in &items {
                tk.push(d, id);
            }
            let got = tk.into_sorted();
            let mut want = items.clone();
            want.sort_by(|a, b| (OrderedF32(a.0), a.1).cmp(&(OrderedF32(b.0), b.1)));
            want.truncate(k);
            assert_eq!(got, want);
        });
    }

    #[test]
    fn merge_equals_combined_stream() {
        check("topk-merge", 40, |g| {
            let k = g.usize_in(1, 10);
            let n1 = g.usize_in(0, 50);
            let n2 = g.usize_in(0, 50);
            let mut rng = Rng::new(g.rng.next_u64());
            let xs: Vec<(f32, u32)> =
                (0..n1 + n2).map(|i| (rng.f32(), i as u32)).collect();
            let (a_items, b_items) = xs.split_at(n1);
            let mut a = TopK::new(k);
            let mut b = TopK::new(k);
            for &(d, id) in a_items {
                a.push(d, id);
            }
            for &(d, id) in b_items {
                b.push(d, id);
            }
            a.merge(&b);
            let mut combined = TopK::new(k);
            for &(d, id) in &xs {
                combined.push(d, id);
            }
            assert_eq!(a.into_sorted(), combined.into_sorted());
        });
    }

    #[test]
    fn nan_sorts_last() {
        assert!(OrderedF32(f32::NAN) > OrderedF32(f32::INFINITY));
        assert_eq!(OrderedF32(f32::NAN).cmp(&OrderedF32(f32::NAN)), std::cmp::Ordering::Equal);
    }
}
