//! Exact brute-force search (the quality upper bound).

use crate::core::topk::TopK;
use crate::data::{sqdist, Dataset};

/// Linear-scan exact k-NN.
pub struct ExactSearch<'a> {
    pub data: &'a Dataset,
}

impl<'a> ExactSearch<'a> {
    pub fn new(data: &'a Dataset) -> Self {
        ExactSearch { data }
    }

    pub fn search(&self, q: &[f32], k: usize) -> Vec<(f32, u32)> {
        let mut tk = TopK::new(k);
        for i in 0..self.data.len() {
            tk.push(sqdist(q, self.data.get(i)), i as u32);
        }
        tk.into_sorted()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{synthesize, SynthSpec};

    #[test]
    fn exact_is_exact() {
        let ds = synthesize(SynthSpec { n: 300, dim: 16, clusters: 5, ..Default::default() });
        let ex = ExactSearch::new(&ds);
        let q = ds.get(7).to_vec();
        let res = ex.search(&q, 3);
        assert_eq!(res[0], (0.0, 7)); // itself
        // monotone distances
        assert!(res[0].0 <= res[1].0 && res[1].0 <= res[2].0);
    }
}
