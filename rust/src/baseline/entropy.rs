//! Entropy-based probing (Panigrahy, SODA'06) — the precursor the paper's
//! §III-C describes: instead of deriving probe buckets from boundary
//! distances (multi-probe), sample random points in the query's
//! neighborhood and visit the buckets *they* hash to.
//!
//! Multi-probe LSH (Lv et al.) was introduced precisely because it reaches
//! the same recall with fewer bucket accesses; `rust/tests` +
//! `examples/multiprobe_sweep.rs` reproduce that comparison on this
//! implementation.

use crate::core::lsh::HashFamily;
use crate::util::rng::Rng;

/// Entropy prober: perturbation sampling around the query.
pub struct EntropyProber<'a> {
    pub family: &'a HashFamily,
    /// Std-dev of the Gaussian neighborhood samples (≈ target NN radius).
    pub perturb_std: f32,
    /// Cap on sampling attempts per requested probe (distinct buckets can
    /// be slow to find once the neighborhood is exhausted).
    pub max_attempts_factor: usize,
}

impl<'a> EntropyProber<'a> {
    pub fn new(family: &'a HashFamily, perturb_std: f32) -> Self {
        EntropyProber { family, perturb_std, max_attempts_factor: 16 }
    }

    /// Up to `t` distinct probe buckets per table (home bucket first),
    /// derived from hashed neighborhood samples. Deterministic in `seed`.
    pub fn probes(&self, q: &[f32], t: usize, seed: u64) -> Vec<(u8, u64)> {
        let l = self.family.params.l;
        let mut rng = Rng::new(seed ^ 0xE17120);
        let mut out = Vec::with_capacity(l * t);
        let home = self.family.bucket_keys(q);
        let mut per_table: Vec<Vec<u64>> = home.iter().map(|&k| vec![k]).collect();
        let mut need: usize = per_table.iter().map(|v| t.saturating_sub(v.len())).sum();
        let mut attempts = 0usize;
        let budget = self.max_attempts_factor * l * t;
        let mut sample = vec![0f32; q.len()];
        while need > 0 && attempts < budget {
            attempts += 1;
            for (slot, &x) in sample.iter_mut().zip(q) {
                *slot = x + self.perturb_std * rng.gaussian_f32();
            }
            let keys = self.family.bucket_keys(&sample);
            for (table, key) in keys.into_iter().enumerate() {
                let bucket_list = &mut per_table[table];
                if bucket_list.len() < t && !bucket_list.contains(&key) {
                    bucket_list.push(key);
                    need -= 1;
                }
            }
        }
        for (table, keys) in per_table.into_iter().enumerate() {
            for key in keys {
                out.push((table as u8, key));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::lsh::LshParams;
    use crate::util::rng::Rng;

    fn family() -> HashFamily {
        HashFamily::sample(
            32,
            LshParams { l: 4, m: 6, w: 8.0, k: 5, t: 1, seed: 5 },
        )
    }

    fn query(seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..32).map(|_| rng.gaussian_f32() * 10.0).collect()
    }

    #[test]
    fn includes_home_buckets_and_distinct_keys() {
        let fam = family();
        let prober = EntropyProber::new(&fam, 1.0);
        let q = query(3);
        let probes = prober.probes(&q, 8, 7);
        let home = fam.bucket_keys(&q);
        for (t, &h) in home.iter().enumerate() {
            assert!(probes.contains(&(t as u8, h)), "home bucket missing");
        }
        // distinct within each table
        for t in 0..4u8 {
            let keys: Vec<u64> = probes
                .iter()
                .filter(|(tt, _)| *tt == t)
                .map(|&(_, k)| k)
                .collect();
            let set: std::collections::HashSet<_> = keys.iter().collect();
            assert_eq!(set.len(), keys.len());
            assert!(keys.len() <= 8);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let fam = family();
        let prober = EntropyProber::new(&fam, 1.0);
        let q = query(5);
        assert_eq!(prober.probes(&q, 6, 1), prober.probes(&q, 6, 1));
        assert_ne!(prober.probes(&q, 6, 1), prober.probes(&q, 6, 2));
    }

    #[test]
    fn larger_std_reaches_more_buckets() {
        let fam = family();
        let q = query(9);
        let near = EntropyProber::new(&fam, 0.01).probes(&q, 16, 3).len();
        let far = EntropyProber::new(&fam, 4.0).probes(&q, 16, 3).len();
        assert!(far >= near, "far {far} < near {near}");
    }
}
