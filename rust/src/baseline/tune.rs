//! LSH parameter tuning (the paper's §V-D "tuning phase": M and L are tuned
//! on the sequential version over a small partition of the dataset before
//! large-scale runs; w likewise needs to match the data scale).
//!
//! * [`suggest_w`] picks a quantization width from the data's near-neighbor
//!   distance scale (sampled, no ground truth needed).
//! * [`tune_t`] finds the smallest probe count T reaching a target recall
//!   on a sample, using the sequential baseline.
//! * [`tune_m`] scans M around a starting point and reports the best
//!   (time-proxy, recall) trade-off subject to a recall floor.

use crate::baseline::sequential::SequentialLsh;
use crate::core::lsh::LshParams;
use crate::data::groundtruth::ground_truth_scalar;
use crate::data::recall::recall_at_k;
use crate::data::{sqdist, Dataset};
use crate::util::rng::Rng;

/// Suggest w from the sampled distance scale: the median distance between a
/// point and its nearest neighbor within a random sample, scaled so an
/// M-function concatenation keeps near pairs co-bucketed with useful
/// probability (empirically ≈ 3× the median sampled NN distance / √M...
/// the constant is calibrated on the synthetic stand-in; treat as a
/// starting point, then refine with [`tune_t`]).
pub fn suggest_w(data: &Dataset, sample: usize, seed: u64) -> f32 {
    assert!(data.len() >= 2);
    let mut rng = Rng::new(seed);
    let n = data.len();
    let s = sample.clamp(2, n).min(512);
    let idx = rng.sample_indices(n, s);
    // NN distance within the sample (upper bound of the true NN distance).
    let mut nn = Vec::with_capacity(s);
    for (a, &i) in idx.iter().enumerate() {
        let mut best = f32::INFINITY;
        for (b, &j) in idx.iter().enumerate() {
            if a != b {
                best = best.min(sqdist(data.get(i), data.get(j)));
            }
        }
        nn.push(best.sqrt());
    }
    nn.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = nn[nn.len() / 2];
    (median * 2.0).max(1.0)
}

/// Result of a tuning sweep step.
#[derive(Clone, Copy, Debug)]
pub struct TunePoint {
    pub t: usize,
    pub m: usize,
    pub recall: f64,
    /// Distance computations per query (the execution-time proxy).
    pub dists_per_query: f64,
}

/// Smallest T (doubling search, capped) whose recall on the sample reaches
/// `target`. Returns the full sweep trace; the last point is the answer.
pub fn tune_t(
    data: &Dataset,
    queries: &Dataset,
    params: LshParams,
    target: f64,
    t_cap: usize,
) -> Vec<TunePoint> {
    let gt = ground_truth_scalar(data, queries, params.k, 2);
    let index = SequentialLsh::build(data, params);
    let mut out = Vec::new();
    let mut t = 1usize;
    loop {
        let mut retrieved = Vec::with_capacity(queries.len());
        let mut dists = 0usize;
        for qi in 0..queries.len() {
            let (res, d) = index.search(queries.get(qi), t, params.k);
            dists += d;
            retrieved.push(res.into_iter().map(|(_, id)| id).collect::<Vec<_>>());
        }
        let recall = recall_at_k(&retrieved, &gt);
        out.push(TunePoint {
            t,
            m: params.m,
            recall,
            dists_per_query: dists as f64 / queries.len() as f64,
        });
        if recall >= target || t >= t_cap {
            return out;
        }
        t *= 2;
    }
}

/// Scan M over `ms` at fixed T; return points (caller picks the cheapest
/// one above its recall floor — the paper's Table III decision).
pub fn tune_m(
    data: &Dataset,
    queries: &Dataset,
    base: LshParams,
    ms: &[usize],
) -> Vec<TunePoint> {
    let gt = ground_truth_scalar(data, queries, base.k, 2);
    let mut out = Vec::new();
    for &m in ms {
        let params = LshParams { m, ..base };
        let index = SequentialLsh::build(data, params);
        let mut retrieved = Vec::with_capacity(queries.len());
        let mut dists = 0usize;
        for qi in 0..queries.len() {
            let (res, d) = index.search(queries.get(qi), params.t, params.k);
            dists += d;
            retrieved.push(res.into_iter().map(|(_, id)| id).collect::<Vec<_>>());
        }
        out.push(TunePoint {
            t: params.t,
            m,
            recall: recall_at_k(&retrieved, &gt),
            dists_per_query: dists as f64 / queries.len() as f64,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{distorted_queries, synthesize, SynthSpec};

    fn world() -> (Dataset, Dataset) {
        let ds = synthesize(SynthSpec { n: 3_000, clusters: 60, ..Default::default() });
        let (qs, _) = distorted_queries(&ds, 25, 5.0, 7);
        (ds, qs)
    }

    #[test]
    fn suggest_w_positive_and_scales() {
        let (ds, _) = world();
        let w = suggest_w(&ds, 256, 1);
        assert!(w > 10.0 && w < 10_000.0, "w={w}");
        // doubling the data scale roughly doubles w
        let mut scaled = Dataset::new(ds.dim);
        for i in 0..500 {
            let v: Vec<f32> = ds.get(i).iter().map(|x| x * 2.0).collect();
            scaled.push(&v);
        }
        let w2 = suggest_w(&scaled, 256, 1);
        assert!(w2 > w * 1.3, "w={w} w2={w2}");
    }

    #[test]
    fn tune_t_reaches_target_monotonically() {
        let (ds, qs) = world();
        let params = LshParams { l: 4, m: 8, w: 700.0, k: 5, t: 1, seed: 3 };
        let trace = tune_t(&ds, &qs, params, 0.8, 256);
        for w in trace.windows(2) {
            assert!(w[1].t > w[0].t);
            assert!(w[1].recall >= w[0].recall - 0.05, "recall regressed: {trace:?}");
        }
        let last = trace.last().unwrap();
        assert!(
            last.recall >= 0.8 || last.t >= 256,
            "tuning neither converged nor hit the cap: {trace:?}"
        );
    }

    #[test]
    fn tune_m_tradeoff_direction() {
        let (ds, qs) = world();
        let base = LshParams { l: 4, m: 8, w: 700.0, k: 5, t: 8, seed: 3 };
        let pts = tune_m(&ds, &qs, base, &[6, 8, 10]);
        assert_eq!(pts.len(), 3);
        // higher M → higher selectivity → fewer distance computations
        assert!(pts[0].dists_per_query >= pts[2].dists_per_query);
    }
}
