//! Comparators: the sequential in-memory multi-probe LSH the paper
//! parallelizes (§III), and exact brute-force search.
//!
//! The sequential baseline shares the hash family, bucket keying, and probe
//! generation with the distributed pipeline, so a distributed search must
//! return *identical* results — the strongest correctness signal we have
//! (`rust/tests/integration_pipeline.rs`). It is also the reference point
//! for the ablation benches.

pub mod entropy;
pub mod exact;
pub mod sequential;
pub mod tune;

pub use entropy::EntropyProber;
pub use exact::ExactSearch;
pub use sequential::SequentialLsh;
pub use tune::{suggest_w, tune_m, tune_t};
