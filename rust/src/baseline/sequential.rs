//! Sequential multi-probe LSH (the algorithm of §III in one address space).

use crate::core::lsh::{HashFamily, LshParams};
use crate::core::topk::TopK;
use crate::data::{sqdist, Dataset};
use std::collections::HashMap;

/// Classic single-process LSH index: L hash tables over one dataset copy.
pub struct SequentialLsh {
    pub family: HashFamily,
    /// One bucket map per table: key → object ids.
    tables: Vec<HashMap<u64, Vec<u32>>>,
    data: Dataset,
}

impl SequentialLsh {
    /// Build the index (hashes every object into all L tables).
    pub fn build(dataset: &Dataset, params: LshParams) -> SequentialLsh {
        let family = HashFamily::sample(dataset.dim, params);
        let mut tables: Vec<HashMap<u64, Vec<u32>>> =
            (0..params.l).map(|_| HashMap::new()).collect();
        for i in 0..dataset.len() {
            let coords = family.hash_coords(dataset.get(i));
            for (t, table) in tables.iter_mut().enumerate() {
                let key = family.bucket_key(t, &coords);
                table.entry(key).or_default().push(i as u32);
            }
        }
        SequentialLsh { family, tables, data: dataset.clone() }
    }

    /// Total stored references (n · L).
    pub fn reference_count(&self) -> usize {
        self.tables
            .iter()
            .map(|t| t.values().map(|v| v.len()).sum::<usize>())
            .sum()
    }

    /// Search with `t_probes` probes per table; returns global top-k
    /// `(sqdist, id)` ascending, plus the number of distance computations.
    pub fn search(&self, q: &[f32], t_probes: usize, k: usize) -> (Vec<(f32, u32)>, usize) {
        let raw = self.family.raw_projections(q);
        let probes = self.family.query_probes(&raw, t_probes, self.family.params.l);
        let mut seen = std::collections::HashSet::new();
        let mut tk = TopK::new(k);
        let mut dists = 0usize;
        for (table, key) in probes {
            if let Some(ids) = self.tables[table as usize].get(&key) {
                for &id in ids {
                    if !seen.insert(id) {
                        continue;
                    }
                    tk.push(sqdist(q, self.data.get(id as usize)), id);
                    dists += 1;
                }
            }
        }
        (tk.into_sorted(), dists)
    }

    /// Search over an explicit probe set (prober comparisons: multi-probe
    /// vs entropy-based probing share this ranking path).
    pub fn search_with_probes(
        &self,
        q: &[f32],
        probes: &[(u8, u64)],
        k: usize,
    ) -> (Vec<(f32, u32)>, usize) {
        let mut seen = std::collections::HashSet::new();
        let mut tk = TopK::new(k);
        let mut dists = 0usize;
        for &(table, key) in probes {
            if let Some(ids) = self.tables[table as usize].get(&key) {
                for &id in ids {
                    if !seen.insert(id) {
                        continue;
                    }
                    tk.push(sqdist(q, self.data.get(id as usize)), id);
                    dists += 1;
                }
            }
        }
        (tk.into_sorted(), dists)
    }

    /// Candidate ids a query retrieves (pre-ranking) — used to compare
    /// bucket-visit behaviour with the distributed version.
    pub fn candidates(&self, q: &[f32], t_probes: usize) -> Vec<u32> {
        let raw = self.family.raw_projections(q);
        let probes = self.family.query_probes(&raw, t_probes, self.family.params.l);
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for (table, key) in probes {
            if let Some(ids) = self.tables[table as usize].get(&key) {
                for &id in ids {
                    if seen.insert(id) {
                        out.push(id);
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{distorted_queries, synthesize, SynthSpec};

    fn params() -> LshParams {
        LshParams { l: 4, m: 8, w: 600.0, k: 5, t: 8, seed: 3 }
    }

    #[test]
    fn indexes_all_objects() {
        let ds = synthesize(SynthSpec { n: 500, clusters: 20, ..Default::default() });
        let idx = SequentialLsh::build(&ds, params());
        assert_eq!(idx.reference_count(), 500 * 4);
    }

    #[test]
    fn finds_near_duplicates() {
        let ds = synthesize(SynthSpec { n: 3_000, clusters: 60, ..Default::default() });
        let idx = SequentialLsh::build(&ds, params());
        let (qs, bases) = distorted_queries(&ds, 40, 2.0, 5);
        let mut hits = 0;
        for i in 0..qs.len() {
            let (res, _) = idx.search(qs.get(i), 8, 5);
            if res.iter().any(|&(_, id)| id == bases[i]) {
                hits += 1;
            }
        }
        assert!(hits >= 28, "sequential recall too low: {hits}/40");
    }

    #[test]
    fn more_probes_never_fewer_candidates() {
        let ds = synthesize(SynthSpec { n: 2_000, clusters: 40, ..Default::default() });
        let idx = SequentialLsh::build(&ds, params());
        let (qs, _) = distorted_queries(&ds, 10, 4.0, 9);
        for i in 0..qs.len() {
            let c1 = idx.candidates(qs.get(i), 1).len();
            let c8 = idx.candidates(qs.get(i), 8).len();
            let c32 = idx.candidates(qs.get(i), 32).len();
            assert!(c8 >= c1);
            assert!(c32 >= c8);
        }
    }

    #[test]
    fn results_sorted_and_deduped() {
        let ds = synthesize(SynthSpec { n: 1_000, clusters: 10, ..Default::default() });
        let idx = SequentialLsh::build(&ds, params());
        let (qs, _) = distorted_queries(&ds, 5, 4.0, 1);
        for i in 0..qs.len() {
            let (res, _) = idx.search(qs.get(i), 16, 10);
            for w in res.windows(2) {
                assert!(w[0].0 <= w[1].0);
            }
            let ids: std::collections::HashSet<u32> =
                res.iter().map(|&(_, id)| id).collect();
            assert_eq!(ids.len(), res.len(), "duplicate ids in results");
        }
    }
}
