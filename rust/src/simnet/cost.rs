//! Makespan computation from work counters + traffic.

use crate::config::NetParams;
use crate::dataflow::message::StageKind;
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::dataflow::Placement;

/// Calibrated per-operation costs (nanoseconds) + network constants.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// One full d-dimensional squared-distance computation.
    pub ns_per_dist: f64,
    /// One projection (d MACs) of the hash bank.
    pub ns_per_proj: f64,
    /// One multi-probe sequence generation (per table).
    pub ns_per_probe_seq: f64,
    /// One bucket hash-table lookup.
    pub ns_per_lookup: f64,
    /// Routing one candidate reference at BI (dedup+group).
    pub ns_per_cand: f64,
    /// Storing one object at DP (copy + map insert).
    pub ns_per_store: f64,
    /// One top-k push at AG.
    pub ns_per_reduce: f64,
    pub net: NetParams,
    /// Overlap communication with computation (the paper's asynchronous
    /// design). `false` models a synchronous implementation (ablation).
    pub async_overlap: bool,
}

impl Default for CostModel {
    fn default() -> Self {
        // Constants measured on the dev host via `parlsh calibrate`
        // (see EXPERIMENTS.md §Calibration); FDR-IB network.
        CostModel {
            ns_per_dist: 112.5,
            ns_per_proj: 77.7,
            ns_per_probe_seq: 4983.0,
            ns_per_lookup: 16.8,
            ns_per_cand: 37.2,
            ns_per_store: 48.4,
            ns_per_reduce: 2.0,
            net: NetParams::default(),
            async_overlap: true,
        }
    }
}

/// Modeled execution-time breakdown.
#[derive(Clone, Debug, Default)]
pub struct MakespanReport {
    /// Modeled wall time, seconds.
    pub makespan_secs: f64,
    /// Slowest node's compute seconds.
    pub max_compute_secs: f64,
    /// Slowest node's network seconds.
    pub max_network_secs: f64,
    /// Node id of the bottleneck.
    pub bottleneck_node: usize,
    /// Per-node modeled seconds.
    pub node_secs: Vec<f64>,
}

impl CostModel {
    /// Service time (ns) for one copy's work.
    pub fn work_ns(&self, w: &WorkStats, projections: usize) -> f64 {
        w.hash_vectors as f64 * projections as f64 * self.ns_per_proj
            + w.probe_seqs as f64 * self.ns_per_probe_seq
            + w.bucket_lookups as f64 * self.ns_per_lookup
            + w.candidates_routed as f64 * self.ns_per_cand
            + w.dists_computed as f64 * self.ns_per_dist
            + w.objects_stored as f64 * self.ns_per_store
            + w.reduce_pushes as f64 * self.ns_per_reduce
    }

    /// Modeled makespan for a phase.
    ///
    /// `per_copy` work is mapped onto nodes via `placement`; copies on a
    /// node share its cores (one multi-threaded copy per node uses all
    /// `cores_per_node`; per-core mode gives each copy one core). The AG
    /// stage is pinned to a single core (paper §V-B). The head node also
    /// runs QR/IR work on its remaining cores.
    pub fn makespan(
        &self,
        placement: &Placement,
        cores_per_node: usize,
        per_copy: &[(StageKind, u16, WorkStats)],
        meter: &TrafficMeter,
        projections: usize,
    ) -> MakespanReport {
        let nodes = placement.total_nodes();
        // Copies per node for each stage (per-core packing).
        let bi_per_node = placement.bi_copies.div_ceil(placement.bi_nodes.max(1));
        let dp_per_node = placement.dp_copies.div_ceil(placement.dp_nodes.max(1));
        let mut compute_ns = vec![0f64; nodes];
        for &(stage, copy, ref w) in per_copy {
            let node = placement.node_of(stage, copy) as usize;
            let service = self.work_ns(w, projections);
            let cores = match stage {
                // One copy per node → all cores; k copies per node → split.
                StageKind::Bi => (cores_per_node / bi_per_node).max(1),
                StageKind::Dp => (cores_per_node / dp_per_node).max(1),
                // AG is pinned to one core; QR/IR use the head's remainder.
                StageKind::Ag => 1,
                StageKind::Qr | StageKind::Ir => (cores_per_node - 1).max(1),
            };
            compute_ns[node] += service / cores as f64;
        }

        let traffic = meter.per_node(nodes);
        let alpha_s = self.net.latency_us * 1e-6;
        let beta = self.net.bandwidth_gbps * 1e9; // bytes/sec
        let mut report = MakespanReport {
            node_secs: vec![0f64; nodes],
            ..Default::default()
        };
        for node in 0..nodes {
            let comp = compute_ns[node] * 1e-9;
            let t = &traffic[node];
            let net = (t.tx_bytes + t.rx_bytes) as f64 / beta
                + (t.tx_packets + t.rx_packets) as f64 * alpha_s;
            let total = if self.async_overlap { comp.max(net) } else { comp + net };
            report.node_secs[node] = total;
            if total > report.makespan_secs {
                report.makespan_secs = total;
                report.bottleneck_node = node;
            }
            report.max_compute_secs = report.max_compute_secs.max(comp);
            report.max_network_secs = report.max_network_secs.max(net);
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn placement(bi: usize, dp: usize) -> Placement {
        Placement::new(&ClusterConfig {
            bi_nodes: bi,
            dp_nodes: dp,
            cores_per_node: 4,
            ag_copies: 1,
            per_core_copies: false,
            ..Default::default()
        })
    }

    fn dp_work(dists: u64) -> WorkStats {
        WorkStats { dists_computed: dists, ..Default::default() }
    }

    #[test]
    fn work_scales_with_ops() {
        let m = CostModel::default();
        let w1 = dp_work(1000);
        let w2 = dp_work(2000);
        assert!((m.work_ns(&w2, 192) - 2.0 * m.work_ns(&w1, 192)).abs() < 1e-6);
    }

    #[test]
    fn intra_stage_parallelism_divides_by_cores() {
        let m = CostModel::default();
        let p = placement(1, 2);
        let per_copy = vec![(StageKind::Dp, 0u16, dp_work(1_000_000))];
        let meter = TrafficMeter::new(0);
        let r4 = m.makespan(&p, 4, &per_copy, &meter, 192);
        let r1 = m.makespan(&p, 1, &per_copy, &meter, 192);
        assert!((r1.makespan_secs / r4.makespan_secs - 4.0).abs() < 0.01);
    }

    #[test]
    fn network_bottleneck_dominates_when_async() {
        let mut m = CostModel::default();
        m.async_overlap = true;
        let p = placement(1, 1);
        let mut meter = TrafficMeter::new(0);
        // 1 GB from node 0 to node 1 ≈ 0.147 s at 6.8 GB/s
        meter.send(0, 1, 1_000_000_000);
        let per_copy = vec![(StageKind::Bi, 0u16, dp_work(10))];
        let r = m.makespan(&p, 4, &per_copy, &meter, 192);
        assert!(r.makespan_secs > 0.1);
        assert!(r.max_network_secs > r.max_compute_secs);
    }

    #[test]
    fn sync_mode_adds_instead_of_max() {
        let p = placement(1, 1);
        let mut meter = TrafficMeter::new(0);
        meter.send(0, 1, 680_000_000); // 0.1 s serialization
        let per_copy = vec![(StageKind::Bi, 0u16, {
            let mut w = WorkStats::default();
            // 0.1 s of compute on 4 cores => 4*0.1s service
            w.dists_computed = (0.4e9 / CostModel::default().ns_per_dist) as u64;
            w
        })];
        let mut m = CostModel::default();
        m.async_overlap = true;
        let r_async = m.makespan(&p, 4, &per_copy, &meter, 192);
        m.async_overlap = false;
        let r_sync = m.makespan(&p, 4, &per_copy, &meter, 192);
        assert!(r_sync.makespan_secs > r_async.makespan_secs * 1.7);
    }

    #[test]
    fn ag_is_serial() {
        let m = CostModel::default();
        let p = placement(1, 1);
        let meter = TrafficMeter::new(0);
        let w = WorkStats { reduce_pushes: 1_000_000, ..Default::default() };
        let per_copy = vec![(StageKind::Ag, 0u16, w)];
        let r = m.makespan(&p, 16, &per_copy, &meter, 192);
        // 1e6 * ns_per_reduce regardless of node cores (AG is 1 core)
        let want = 1e6 * m.ns_per_reduce * 1e-9;
        assert!((r.makespan_secs - want).abs() < want * 0.01);
    }
}
