//! Cost-model calibration: measures the per-op constants on this host so
//! the cluster model's compute:communication ratio tracks real hardware.

use crate::core::lsh::{HashFamily, LshParams};
use crate::core::topk::TopK;
use crate::data::sqdist;
use crate::simnet::cost::CostModel;
use crate::util::rng::Rng;
use crate::util::timer::bench_loop;
use std::collections::HashMap;

/// Measure per-op costs (takes ~1 s). Network constants stay at their
/// configured values (they describe the modeled fabric, not this host).
pub fn calibrate() -> CostModel {
    let mut model = CostModel::default();
    let mut rng = Rng::new(0xCA11B);
    let dim = 128;

    // Distance: 128-d sqdist over a pool (defeats cache-resident best case).
    let pool: Vec<f32> = (0..256 * dim).map(|_| rng.gaussian_f32()).collect();
    let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
    let mut i = 0usize;
    let mut acc = 0f32;
    let per = bench_loop(0.08, 64, || {
        for c in 0..64 {
            let row = (i + c) % 256;
            acc += sqdist(&q, &pool[row * dim..(row + 1) * dim]);
        }
        i += 64;
    });
    model.ns_per_dist = per * 1e9 / 64.0;
    std::hint::black_box(acc);

    // Projection: one row of the bank (dim MACs) via raw_projections/P.
    let family = HashFamily::sample(
        dim,
        LshParams { l: 6, m: 32, w: 1000.0, k: 10, t: 1, seed: 1 },
    );
    let p = family.params.projections();
    let per = bench_loop(0.08, 16, || {
        std::hint::black_box(family.raw_projections(&q));
    });
    model.ns_per_proj = per * 1e9 / p as f64;

    // Probe-sequence generation (M=32, T=30).
    let fracs: Vec<f32> = (0..32).map(|_| rng.f32()).collect();
    let per = bench_loop(0.05, 16, || {
        std::hint::black_box(crate::core::multiprobe::probe_sequence(&fracs, 30));
    });
    model.ns_per_probe_seq = per * 1e9;

    // Bucket lookup: HashMap<u64, Vec<..>> hit.
    let mut buckets: HashMap<u64, Vec<(u32, u16)>> = HashMap::new();
    for k in 0..10_000u64 {
        buckets.insert(crate::util::rng::mix64(k), vec![(k as u32, 0)]);
    }
    let keys: Vec<u64> = (0..10_000u64).map(crate::util::rng::mix64).collect();
    let mut j = 0usize;
    let per = bench_loop(0.05, 64, || {
        for c in 0..64 {
            std::hint::black_box(buckets.get(&keys[(j + c) % keys.len()]));
        }
        j += 64;
    });
    model.ns_per_lookup = per * 1e9 / 64.0;

    // Candidate routing: HashSet insert + Vec push.
    let per = bench_loop(0.05, 16, || {
        let mut seen = std::collections::HashSet::new();
        let mut v = Vec::new();
        for id in 0..1000u32 {
            if seen.insert(id) {
                v.push(id);
            }
        }
        std::hint::black_box(v);
    });
    model.ns_per_cand = per * 1e9 / 1000.0;

    // Store: vector copy + map insert.
    let src = vec![0f32; dim];
    let per = bench_loop(0.05, 16, || {
        let mut store: Vec<f32> = Vec::with_capacity(1000 * dim);
        let mut map = HashMap::new();
        for id in 0..1000u32 {
            store.extend_from_slice(&src);
            map.insert(id, id);
        }
        std::hint::black_box((store, map));
    });
    model.ns_per_store = per * 1e9 / 1000.0;

    // Reduce: top-k push.
    let vals: Vec<f32> = (0..1000).map(|_| rng.f32()).collect();
    let per = bench_loop(0.05, 16, || {
        let mut tk = TopK::new(10);
        for (i, &v) in vals.iter().enumerate() {
            tk.push(v, i as u32);
        }
        std::hint::black_box(tk.len());
    });
    model.ns_per_reduce = per * 1e9 / 1000.0;

    model
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_produces_sane_constants() {
        let m = calibrate();
        // All positive, none absurd (< 1 µs per scalar op on any host).
        for (name, v) in [
            ("dist", m.ns_per_dist),
            ("proj", m.ns_per_proj),
            ("lookup", m.ns_per_lookup),
            ("cand", m.ns_per_cand),
            ("store", m.ns_per_store),
            ("reduce", m.ns_per_reduce),
        ] {
            assert!(v > 0.0 && v < 100_000.0, "{name} = {v} ns");
        }
        assert!(m.ns_per_probe_seq > 0.0 && m.ns_per_probe_seq < 1e8);
        // a distance (128 subs+mults) must cost more than a topk push
        assert!(m.ns_per_dist > m.ns_per_reduce * 0.5);
    }
}
