//! The cluster cost model — the stand-in for the paper's 60-node FDR
//! InfiniBand testbed (DESIGN.md §Substitutions).
//!
//! The functional pipeline runs for real in this process and produces
//! *exact* per-copy work counters and per-link traffic. This module converts
//! those into cluster-scale time: each stage copy is a server whose service
//! time is `Σ op_count · cost(op)`, divided by the cores available to it
//! (intra-stage parallelism); each node pays `α` per packet plus
//! `bytes / β` of serialization. The paper's asynchronous design overlaps
//! communication with computation, so a node's time is
//! `max(compute, network)` (an ablation flag models the synchronous
//! alternative as the sum).
//!
//! Per-op costs are measured on this host (`calibrate`), so the modeled
//! compute:communication ratio — which is what the efficiency and crossover
//! *shapes* depend on — tracks real hardware.

pub mod calibrate;
pub mod cost;

pub use calibrate::calibrate;
pub use cost::{CostModel, MakespanReport};
