//! Inter-stage messages (paper Figure 2), per-query search plans
//! ([`QueryOptions`]) and the wire-size model.
//!
//! The five message kinds mirror the paper's i–v. Vectors travel by `Arc` in
//! process, but `wire_size` charges the full serialized payload so traffic
//! accounting matches what MPI would move.
//!
//! Since the per-query-plan redesign (DESIGN.md §Service API), every query
//! carries its own [`QueryOptions`] on the ingress [`Msg::QueryVec`]; the
//! Query Receiver resolves them against the index's configured `LshParams`
//! and threads the resolved `k` through the downstream messages
//! ([`Msg::Query`] → [`Msg::CandidateReq`] → [`Msg::QueryMeta`]) so BI, DP
//! and AG all honor the *query's* plan, not one frozen global.

use crate::config::Config;
use crate::core::lsh::LshParams;
use std::sync::Arc;

/// The five dataflow stages.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StageKind {
    Ir,
    Qr,
    Bi,
    Dp,
    Ag,
}

/// A destination: stage + copy index.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Dest {
    pub stage: StageKind,
    pub copy: u16,
}

impl StageKind {
    /// Canonical one-byte code used by the socket wire format (`net::wire`).
    pub fn code(self) -> u8 {
        match self {
            StageKind::Ir => 0,
            StageKind::Qr => 1,
            StageKind::Bi => 2,
            StageKind::Dp => 3,
            StageKind::Ag => 4,
        }
    }

    pub fn from_code(code: u8) -> Option<StageKind> {
        match code {
            0 => Some(StageKind::Ir),
            1 => Some(StageKind::Qr),
            2 => Some(StageKind::Bi),
            3 => Some(StageKind::Dp),
            4 => Some(StageKind::Ag),
            _ => None,
        }
    }
}

impl Dest {
    pub fn bi(copy: u16) -> Dest {
        Dest { stage: StageKind::Bi, copy }
    }
    pub fn dp(copy: u16) -> Dest {
        Dest { stage: StageKind::Dp, copy }
    }
    pub fn ag(copy: u16) -> Dest {
        Dest { stage: StageKind::Ag, copy }
    }
}

/// A per-query search plan: how many neighbors to return, how much probe
/// effort to spend, and how many tables to consult — the recall/latency
/// knob a serving system turns per *request*, not per index build.
///
/// Every field uses `0` as the "inherit the index's configured value"
/// sentinel, so `QueryOptions::default()` is exactly "the config defaults"
/// and the wire codec can elide unset fields (wire v3 default-elision).
/// Resolution against the index's [`LshParams`] happens once, in the Query
/// Receiver (`k_or` / `probes_or` / `tables_in`); downstream messages carry
/// the resolved `k` explicitly.
///
/// `tag` is an opaque caller label: it never influences the computation and
/// is echoed back with the completion (`IndexSession::recv_full`), so
/// callers multiplexing heterogeneous traffic classes over one session can
/// attribute completions without a side table.
/// Ceiling on an explicitly-requested per-query `k` (resolution clamps
/// to it). Far above any sensible top-k, small enough that the per-query
/// reducer heap it sizes stays trivial.
pub const MAX_QUERY_K: usize = 1 << 16;
/// Ceiling on an explicitly-requested per-query probe budget `T`.
/// Generous next to the paper's largest sweeps (T ≤ 512) while bounding
/// the probe-vector allocations a hostile request could demand.
pub const MAX_QUERY_PROBES: usize = 1 << 16;

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct QueryOptions {
    /// Neighbors to return for this query (0 = config `lsh.k`).
    pub k: u32,
    /// Multi-probe probes per table, the paper's T (0 = config `lsh.t`).
    pub probes: u32,
    /// Consult only the first `tables` hash tables, L' ≤ L (0 = all L).
    pub tables: u32,
    /// Opaque caller tag, echoed on the completion. Never interpreted.
    pub tag: u32,
}

impl QueryOptions {
    /// The config-derived defaults as *explicit* values (every field
    /// non-zero where the params are non-zero). `submit_with(q, default_from(cfg))`
    /// is bit-identical to `submit(q)` by construction: both resolve to the
    /// same plan at the Query Receiver.
    pub fn from_params(p: &LshParams) -> QueryOptions {
        QueryOptions {
            k: p.k as u32,
            probes: p.t as u32,
            tables: p.l as u32,
            tag: 0,
        }
    }

    /// [`QueryOptions::from_params`] over the config's LSH section.
    pub fn default_from(cfg: &Config) -> QueryOptions {
        QueryOptions::from_params(&cfg.lsh)
    }

    /// Resolved k: the query's (capped at [`MAX_QUERY_K`]), or `default`
    /// when inherited; never 0. The cap exists because plans arrive from
    /// *untrusted* inputs — serve stdin/text lines, the wire — and `k`
    /// sizes upfront allocations (the AG's per-query `TopK` heap): one
    /// absurd request must degrade, not abort the resident process. The
    /// inherited `default` comes from validated config and is not capped.
    pub fn k_or(&self, default: usize) -> usize {
        if self.k == 0 {
            default.max(1)
        } else {
            (self.k as usize).min(MAX_QUERY_K)
        }
    }

    /// Resolved probes-per-table T (explicit values capped at
    /// [`MAX_QUERY_PROBES`] — T sizes the probe-sequence allocations, see
    /// [`QueryOptions::k_or`] for the trust argument); never 0.
    pub fn probes_or(&self, default: usize) -> usize {
        if self.probes == 0 {
            default.max(1)
        } else {
            (self.probes as usize).min(MAX_QUERY_PROBES)
        }
    }

    /// Resolved table count, clamped into `1..=l`.
    pub fn tables_in(&self, l: usize) -> usize {
        if self.tables == 0 {
            l.max(1)
        } else {
            (self.tables as usize).clamp(1, l.max(1))
        }
    }

    /// Serialized size under the wire-v3 default-elision encoding: one
    /// flags byte plus 4 bytes per explicitly-set field.
    pub fn wire_size(&self) -> usize {
        1 + [self.k, self.probes, self.tables, self.tag]
            .iter()
            .filter(|&&v| v != 0)
            .count()
            * 4
    }
}

/// Inter-stage message payloads.
///
/// The first two variants are *ingress* messages: the executor delivers
/// them to the head stage (IR for build, QR for search) straight from the
/// workload, so they never cross the network and are not metered.
#[derive(Clone, Debug)]
pub enum Msg {
    /// Driver → IR: index a block of `rows` vectors (flat `[rows*dim]`)
    /// with global ids starting at `id_base`.
    IndexBlock { id_base: u32, rows: u32, flat: Arc<[f32]> },
    /// Driver → QR: dispatch one query. `raw` holds the precomputed raw
    /// projections (the drivers hash the whole query set through one
    /// batched artifact call); `v` is the query vector itself; `opts` is
    /// the per-query search plan (0-fields inherit the config — QR
    /// resolves them).
    QueryVec { qid: u32, raw: Arc<[f32]>, v: Arc<[f32]>, opts: QueryOptions },
    /// (i) IR → DP: store one reference object. No replication: exactly one
    /// DP copy ever receives a given object.
    StoreObject { id: u32, v: Arc<[f32]> },
    /// (ii) IR → BI: index a reference `(bucket key, object id, dp copy)`.
    IndexRef { table: u8, key: u64, id: u32, dp: u16 },
    /// (iii) QR → BI: visit `probes` buckets for query `qid`. Only the
    /// probes owned by the destination BI copy are included; the query
    /// vector rides along for the downstream distance phase, and `k` is
    /// the query's resolved top-k (forwarded to DP).
    Query { qid: u32, probes: Vec<(u8, u64)>, v: Arc<[f32]>, k: u32 },
    /// (iv) BI → DP: rank `ids` against the query, keeping the best `k`.
    CandidateReq { qid: u32, ids: Vec<u32>, v: Arc<[f32]>, k: u32 },
    /// QR → AG control: how many BI copies were contacted for `qid`, and
    /// the query's resolved top-k (the AG reduces to exactly `k`).
    QueryMeta { qid: u32, n_bi: u32, k: u32 },
    /// BI → AG control: how many DP messages this BI emitted for `qid`.
    BiMeta { qid: u32, n_dp: u32 },
    /// (v) DP → AG: the DP-local k nearest `(sqdist, id)` pairs.
    LocalTopK { qid: u32, hits: Vec<(f32, u32)> },
}

impl Msg {
    /// Serialized payload size in bytes (4-byte ids/floats/k, 8-byte keys,
    /// 1-byte table ids, options under default-elision; headers charged by
    /// the packet layer).
    pub fn wire_size(&self) -> usize {
        match self {
            Msg::IndexBlock { flat, .. } => 8 + 4 * flat.len(),
            Msg::QueryVec { raw, v, opts, .. } => {
                4 + 4 * raw.len() + 4 * v.len() + opts.wire_size()
            }
            Msg::StoreObject { v, .. } => 4 + 4 * v.len(),
            Msg::IndexRef { .. } => 1 + 8 + 4 + 2,
            Msg::Query { probes, v, .. } => 4 + 4 + probes.len() * 9 + 4 * v.len(),
            Msg::CandidateReq { ids, v, .. } => 4 + 4 + 4 * ids.len() + 4 * v.len(),
            Msg::QueryMeta { .. } => 12,
            Msg::BiMeta { .. } => 8,
            Msg::LocalTopK { hits, .. } => 4 + 8 * hits.len(),
        }
    }

    /// Query id if this message belongs to a query computation.
    pub fn qid(&self) -> Option<u32> {
        match self {
            Msg::QueryVec { qid, .. }
            | Msg::Query { qid, .. }
            | Msg::CandidateReq { qid, .. }
            | Msg::QueryMeta { qid, .. }
            | Msg::BiMeta { qid, .. }
            | Msg::LocalTopK { qid, .. } => Some(*qid),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arcv(n: usize) -> Arc<[f32]> {
        vec![0f32; n].into()
    }

    #[test]
    fn wire_sizes_scale_with_payload() {
        let small = Msg::CandidateReq { qid: 0, ids: vec![1], v: arcv(128), k: 10 };
        let big = Msg::CandidateReq { qid: 0, ids: vec![1; 100], v: arcv(128), k: 10 };
        assert_eq!(big.wire_size() - small.wire_size(), 99 * 4);
        assert_eq!(Msg::StoreObject { id: 0, v: arcv(128) }.wire_size(), 4 + 512);
        assert_eq!(
            Msg::IndexRef { table: 0, key: 0, id: 0, dp: 0 }.wire_size(),
            15
        );
        assert_eq!(Msg::QueryMeta { qid: 0, n_bi: 1, k: 5 }.wire_size(), 12);
    }

    #[test]
    fn ingress_messages_carry_qid_only_for_queries() {
        let ib = Msg::IndexBlock { id_base: 0, rows: 2, flat: arcv(8) };
        assert_eq!(ib.qid(), None);
        assert_eq!(ib.wire_size(), 8 + 32);
        let qv = Msg::QueryVec {
            qid: 4,
            raw: arcv(2),
            v: arcv(4),
            opts: QueryOptions::default(),
        };
        assert_eq!(qv.qid(), Some(4));
        // default (all-inherit) options cost exactly the one flags byte
        assert_eq!(qv.wire_size(), 4 + 8 + 16 + 1);
    }

    #[test]
    fn options_resolution_and_clamping() {
        let p = LshParams { l: 6, m: 32, w: 1200.0, k: 10, t: 30, seed: 42 };
        let inherit = QueryOptions::default();
        assert_eq!(inherit.k_or(p.k), 10);
        assert_eq!(inherit.probes_or(p.t), 30);
        assert_eq!(inherit.tables_in(p.l), 6);
        assert_eq!(QueryOptions::from_params(&p), QueryOptions { k: 10, probes: 30, tables: 6, tag: 0 });
        // both spellings of "the defaults" resolve identically
        let explicit = QueryOptions::from_params(&p);
        assert_eq!(explicit.k_or(p.k), inherit.k_or(p.k));
        assert_eq!(explicit.probes_or(p.t), inherit.probes_or(p.t));
        assert_eq!(explicit.tables_in(p.l), inherit.tables_in(p.l));
        // explicit values win; tables clamp into 1..=L
        let custom = QueryOptions { k: 3, probes: 4, tables: 99, tag: 7 };
        assert_eq!(custom.k_or(p.k), 3);
        assert_eq!(custom.probes_or(p.t), 4);
        assert_eq!(custom.tables_in(p.l), 6);
        assert_eq!(QueryOptions { tables: 2, ..Default::default() }.tables_in(6), 2);
        // hostile values clamp instead of sizing absurd allocations
        let hostile = QueryOptions { k: u32::MAX, probes: u32::MAX, ..Default::default() };
        assert_eq!(hostile.k_or(p.k), MAX_QUERY_K);
        assert_eq!(hostile.probes_or(p.t), MAX_QUERY_PROBES);
    }

    #[test]
    fn options_wire_size_elides_defaults() {
        assert_eq!(QueryOptions::default().wire_size(), 1);
        assert_eq!(QueryOptions { k: 5, ..Default::default() }.wire_size(), 5);
        assert_eq!(
            QueryOptions { k: 5, probes: 2, tables: 1, tag: 9 }.wire_size(),
            17
        );
    }

    #[test]
    fn stage_codes_roundtrip() {
        for s in [StageKind::Ir, StageKind::Qr, StageKind::Bi, StageKind::Dp, StageKind::Ag] {
            assert_eq!(StageKind::from_code(s.code()), Some(s));
        }
        assert_eq!(StageKind::from_code(5), None);
    }

    #[test]
    fn qid_extraction() {
        assert_eq!(Msg::StoreObject { id: 3, v: arcv(4) }.qid(), None);
        assert_eq!(Msg::QueryMeta { qid: 9, n_bi: 1, k: 5 }.qid(), Some(9));
        assert_eq!(
            Msg::LocalTopK { qid: 7, hits: vec![] }.qid(),
            Some(7)
        );
    }
}
