//! Inter-stage messages (paper Figure 2) and their wire-size model.
//!
//! The five message kinds mirror the paper's i–v. Vectors travel by `Arc` in
//! process, but `wire_size` charges the full serialized payload so traffic
//! accounting matches what MPI would move.

use std::sync::Arc;

/// The five dataflow stages.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StageKind {
    Ir,
    Qr,
    Bi,
    Dp,
    Ag,
}

/// A destination: stage + copy index.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Dest {
    pub stage: StageKind,
    pub copy: u16,
}

impl StageKind {
    /// Canonical one-byte code used by the socket wire format (`net::wire`).
    pub fn code(self) -> u8 {
        match self {
            StageKind::Ir => 0,
            StageKind::Qr => 1,
            StageKind::Bi => 2,
            StageKind::Dp => 3,
            StageKind::Ag => 4,
        }
    }

    pub fn from_code(code: u8) -> Option<StageKind> {
        match code {
            0 => Some(StageKind::Ir),
            1 => Some(StageKind::Qr),
            2 => Some(StageKind::Bi),
            3 => Some(StageKind::Dp),
            4 => Some(StageKind::Ag),
            _ => None,
        }
    }
}

impl Dest {
    pub fn bi(copy: u16) -> Dest {
        Dest { stage: StageKind::Bi, copy }
    }
    pub fn dp(copy: u16) -> Dest {
        Dest { stage: StageKind::Dp, copy }
    }
    pub fn ag(copy: u16) -> Dest {
        Dest { stage: StageKind::Ag, copy }
    }
}

/// Inter-stage message payloads.
///
/// The first two variants are *ingress* messages: the executor delivers
/// them to the head stage (IR for build, QR for search) straight from the
/// workload, so they never cross the network and are not metered.
#[derive(Clone, Debug)]
pub enum Msg {
    /// Driver → IR: index a block of `rows` vectors (flat `[rows*dim]`)
    /// with global ids starting at `id_base`.
    IndexBlock { id_base: u32, rows: u32, flat: Arc<[f32]> },
    /// Driver → QR: dispatch one query. `raw` holds the precomputed raw
    /// projections (the drivers hash the whole query set through one
    /// batched artifact call); `v` is the query vector itself.
    QueryVec { qid: u32, raw: Arc<[f32]>, v: Arc<[f32]> },
    /// (i) IR → DP: store one reference object. No replication: exactly one
    /// DP copy ever receives a given object.
    StoreObject { id: u32, v: Arc<[f32]> },
    /// (ii) IR → BI: index a reference `(bucket key, object id, dp copy)`.
    IndexRef { table: u8, key: u64, id: u32, dp: u16 },
    /// (iii) QR → BI: visit `probes` buckets for query `qid`. Only the
    /// probes owned by the destination BI copy are included; the query
    /// vector rides along for the downstream distance phase.
    Query { qid: u32, probes: Vec<(u8, u64)>, v: Arc<[f32]> },
    /// (iv) BI → DP: rank `ids` against the query.
    CandidateReq { qid: u32, ids: Vec<u32>, v: Arc<[f32]> },
    /// QR → AG control: how many BI copies were contacted for `qid`.
    QueryMeta { qid: u32, n_bi: u32 },
    /// BI → AG control: how many DP messages this BI emitted for `qid`.
    BiMeta { qid: u32, n_dp: u32 },
    /// (v) DP → AG: the DP-local k nearest `(sqdist, id)` pairs.
    LocalTopK { qid: u32, hits: Vec<(f32, u32)> },
}

impl Msg {
    /// Serialized payload size in bytes (MPI wire model: 4-byte ids/floats,
    /// 8-byte keys, 1-byte table ids; headers charged by the packet layer).
    pub fn wire_size(&self) -> usize {
        match self {
            Msg::IndexBlock { flat, .. } => 8 + 4 * flat.len(),
            Msg::QueryVec { raw, v, .. } => 4 + 4 * raw.len() + 4 * v.len(),
            Msg::StoreObject { v, .. } => 4 + 4 * v.len(),
            Msg::IndexRef { .. } => 1 + 8 + 4 + 2,
            Msg::Query { probes, v, .. } => 4 + probes.len() * 9 + 4 * v.len(),
            Msg::CandidateReq { ids, v, .. } => 4 + 4 * ids.len() + 4 * v.len(),
            Msg::QueryMeta { .. } => 8,
            Msg::BiMeta { .. } => 8,
            Msg::LocalTopK { hits, .. } => 4 + 8 * hits.len(),
        }
    }

    /// Query id if this message belongs to a query computation.
    pub fn qid(&self) -> Option<u32> {
        match self {
            Msg::QueryVec { qid, .. }
            | Msg::Query { qid, .. }
            | Msg::CandidateReq { qid, .. }
            | Msg::QueryMeta { qid, .. }
            | Msg::BiMeta { qid, .. }
            | Msg::LocalTopK { qid, .. } => Some(*qid),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arcv(n: usize) -> Arc<[f32]> {
        vec![0f32; n].into()
    }

    #[test]
    fn wire_sizes_scale_with_payload() {
        let small = Msg::CandidateReq { qid: 0, ids: vec![1], v: arcv(128) };
        let big = Msg::CandidateReq { qid: 0, ids: vec![1; 100], v: arcv(128) };
        assert_eq!(big.wire_size() - small.wire_size(), 99 * 4);
        assert_eq!(Msg::StoreObject { id: 0, v: arcv(128) }.wire_size(), 4 + 512);
        assert_eq!(
            Msg::IndexRef { table: 0, key: 0, id: 0, dp: 0 }.wire_size(),
            15
        );
    }

    #[test]
    fn ingress_messages_carry_qid_only_for_queries() {
        let ib = Msg::IndexBlock { id_base: 0, rows: 2, flat: arcv(8) };
        assert_eq!(ib.qid(), None);
        assert_eq!(ib.wire_size(), 8 + 32);
        let qv = Msg::QueryVec { qid: 4, raw: arcv(2), v: arcv(4) };
        assert_eq!(qv.qid(), Some(4));
    }

    #[test]
    fn stage_codes_roundtrip() {
        for s in [StageKind::Ir, StageKind::Qr, StageKind::Bi, StageKind::Dp, StageKind::Ag] {
            assert_eq!(StageKind::from_code(s.code()), Some(s));
        }
        assert_eq!(StageKind::from_code(5), None);
    }

    #[test]
    fn qid_extraction() {
        assert_eq!(Msg::StoreObject { id: 3, v: arcv(4) }.qid(), None);
        assert_eq!(Msg::QueryMeta { qid: 9, n_bi: 1 }.qid(), Some(9));
        assert_eq!(
            Msg::LocalTopK { qid: 7, hits: vec![] }.qid(),
            Some(7)
        );
    }
}
