//! Exact traffic and work accounting.
//!
//! [`TrafficMeter`] implements the labeled-stream buffering/aggregation
//! policy: logical messages to the same destination node accumulate in a
//! per-link buffer and are flushed as one network *packet* when the buffer
//! reaches `agg_bytes` (or at phase end); a message that would overflow
//! the buffer closes the buffered packet first, so packets respect the
//! budget unless a single message exceeds it. Local (same-node) deliveries are
//! counted separately and cost no network traffic — this is the mechanism
//! behind the paper's >6× message reduction from intra-stage parallelism.
//!
//! [`WorkStats`] counts the per-copy compute operations the cluster cost
//! model (simnet) converts into time.

use std::collections::HashMap;

/// Per-link (src node → dst node) counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    pub packets: u64,
    pub bytes: u64,
}

/// Network traffic meter with message aggregation.
#[derive(Clone, Debug)]
pub struct TrafficMeter {
    /// Aggregation threshold in bytes (0 disables aggregation: every
    /// logical message is its own packet).
    pub agg_bytes: usize,
    /// Per-packet header overhead charged on flush (MPI envelope).
    pub header_bytes: usize,
    links: HashMap<(u16, u16), LinkStats>,
    pending: HashMap<(u16, u16), usize>,
    /// Logical message count (pre-aggregation, network-crossing only).
    pub logical_msgs: u64,
    /// Same-node deliveries (no network cost).
    pub local_msgs: u64,
    /// Total payload bytes crossing the network.
    pub payload_bytes: u64,
}

impl TrafficMeter {
    pub fn new(agg_bytes: usize) -> TrafficMeter {
        TrafficMeter {
            agg_bytes,
            header_bytes: 64,
            links: HashMap::new(),
            pending: HashMap::new(),
            logical_msgs: 0,
            local_msgs: 0,
            payload_bytes: 0,
        }
    }

    /// Record one logical message of `size` bytes from node `src` to `dst`.
    ///
    /// Packet model: a message that would push the aggregation buffer past
    /// `agg_bytes` closes the buffered packet *first*, so no packet ever
    /// exceeds the budget unless a single message does. `net::PeerConn`
    /// batches its writes with exactly the same rule, so meter packets
    /// track TCP write batches (control frames aside).
    pub fn send(&mut self, src: u16, dst: u16, size: usize) {
        if src == dst {
            self.local_msgs += 1;
            return;
        }
        self.logical_msgs += 1;
        self.payload_bytes += size as u64;
        if self.agg_bytes == 0 {
            let link = self.links.entry((src, dst)).or_default();
            link.packets += 1;
            link.bytes += (size + self.header_bytes) as u64;
            return;
        }
        let header = self.header_bytes;
        let pend = self.pending.entry((src, dst)).or_default();
        if *pend > 0 && *pend + size > self.agg_bytes {
            let full = *pend;
            *pend = 0;
            let link = self.links.entry((src, dst)).or_default();
            link.packets += 1;
            link.bytes += (full + header) as u64;
        }
        *pend += size;
        if *pend >= self.agg_bytes {
            let full = *pend;
            *pend = 0;
            let link = self.links.entry((src, dst)).or_default();
            link.packets += 1;
            link.bytes += (full + header) as u64;
        }
    }

    /// Flush all partially filled aggregation buffers (phase boundary).
    pub fn flush(&mut self) {
        let pending = std::mem::take(&mut self.pending);
        for ((src, dst), size) in pending {
            if size == 0 {
                continue;
            }
            let link = self.links.entry((src, dst)).or_default();
            link.packets += 1;
            link.bytes += (size + self.header_bytes) as u64;
        }
    }

    pub fn total_packets(&self) -> u64 {
        self.links.values().map(|l| l.packets).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.links.values().map(|l| l.bytes).sum()
    }

    pub fn links(&self) -> &HashMap<(u16, u16), LinkStats> {
        &self.links
    }

    /// Per-node (tx, rx) byte and packet totals — the cost-model inputs.
    pub fn per_node(&self, nodes: usize) -> Vec<NodeTraffic> {
        let mut out = vec![NodeTraffic::default(); nodes];
        for (&(src, dst), l) in &self.links {
            let s = &mut out[src as usize];
            s.tx_bytes += l.bytes;
            s.tx_packets += l.packets;
            let d = &mut out[dst as usize];
            d.rx_bytes += l.bytes;
            d.rx_packets += l.packets;
        }
        out
    }

    /// Add an externally-measured per-link total (the socket transport
    /// decodes worker meters from `FlushAck` frames into these).
    pub fn add_link(&mut self, src: u16, dst: u16, packets: u64, bytes: u64) {
        let l = self.links.entry((src, dst)).or_default();
        l.packets += packets;
        l.bytes += bytes;
    }

    /// Links in deterministic (src, dst) order — for reports and JSON.
    pub fn sorted_links(&self) -> Vec<((u16, u16), LinkStats)> {
        let mut out: Vec<_> = self.links.iter().map(|(&k, &v)| (k, v)).collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }

    /// Human-readable per-link breakdown (one line per link), shared by
    /// every surface that reports real wire bytes.
    pub fn link_report(&self) -> String {
        let mut out = String::new();
        for ((src, dst), l) in self.sorted_links() {
            out.push_str(&format!(
                "  link node {src:>2} -> node {dst:>2}: {:>12} bytes in {:>6} packets\n",
                l.bytes, l.packets
            ));
        }
        out
    }

    pub fn merge(&mut self, other: &TrafficMeter) {
        for (&k, l) in &other.links {
            let e = self.links.entry(k).or_default();
            e.packets += l.packets;
            e.bytes += l.bytes;
        }
        self.logical_msgs += other.logical_msgs;
        self.local_msgs += other.local_msgs;
        self.payload_bytes += other.payload_bytes;
    }
}

/// Per-node traffic totals.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeTraffic {
    pub tx_bytes: u64,
    pub rx_bytes: u64,
    pub tx_packets: u64,
    pub rx_packets: u64,
}

/// Per-stage-copy compute counters (inputs to the simnet cost model).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkStats {
    /// Vectors pushed through the hash bank (P projections each).
    pub hash_vectors: u64,
    /// Multi-probe sequences generated.
    pub probe_seqs: u64,
    /// Bucket hash-table lookups.
    pub bucket_lookups: u64,
    /// Candidate references scanned/grouped at BI.
    pub candidates_routed: u64,
    /// Full distance computations at DP.
    pub dists_computed: u64,
    /// Candidates abandoned early by the pruning ranker (partial-sum
    /// exceeded the running k-th-best bound; see DESIGN.md §Kernels).
    pub dists_pruned: u64,
    /// Candidates skipped by duplicate elimination.
    pub dup_skipped: u64,
    /// Whole buckets skipped at BI without scanning their references —
    /// revisited probe keys plus bitmap chunk-saturation skips (see
    /// DESIGN.md §Storage engine). The references they would have scanned
    /// are charged to `dup_skipped`, so that counter stays comparable
    /// across transports and across the skip being on or off.
    pub bucket_skipped: u64,
    /// Vectors stored (index build).
    pub objects_stored: u64,
    /// Top-k reduction pushes at AG.
    pub reduce_pushes: u64,
    /// Bytes resident in this copy's storage engine (BI directory +
    /// filter, DP flat store + row index). A *gauge*, not a counter:
    /// [`WorkStats::add`] merges it by max, so summing per-copy stats
    /// reports the largest single copy, and repeated flushes from the
    /// same copy don't double-count.
    pub bytes_resident: u64,
}

impl WorkStats {
    pub fn add(&mut self, other: &WorkStats) {
        self.hash_vectors += other.hash_vectors;
        self.probe_seqs += other.probe_seqs;
        self.bucket_lookups += other.bucket_lookups;
        self.candidates_routed += other.candidates_routed;
        self.dists_computed += other.dists_computed;
        self.dists_pruned += other.dists_pruned;
        self.dup_skipped += other.dup_skipped;
        self.bucket_skipped += other.bucket_skipped;
        self.objects_stored += other.objects_stored;
        self.reduce_pushes += other.reduce_pushes;
        // gauge: the high-water mark survives, sums would double-count
        self.bytes_resident = self.bytes_resident.max(other.bytes_resident);
    }

    /// Counter growth since an earlier snapshot — the per-tag attribution
    /// primitive (DESIGN.md §QoS scheduler): the session snapshots the
    /// merged live work at each completion and charges the delta to the
    /// completing ticket's tag class. Saturating, so a reset between
    /// snapshots (`take_work`) degrades to zero instead of wrapping.
    /// `bytes_resident` is a gauge and has no meaningful delta: the
    /// current value is carried through unchanged.
    pub fn delta_since(&self, prev: &WorkStats) -> WorkStats {
        WorkStats {
            hash_vectors: self.hash_vectors.saturating_sub(prev.hash_vectors),
            probe_seqs: self.probe_seqs.saturating_sub(prev.probe_seqs),
            bucket_lookups: self.bucket_lookups.saturating_sub(prev.bucket_lookups),
            candidates_routed: self.candidates_routed.saturating_sub(prev.candidates_routed),
            dists_computed: self.dists_computed.saturating_sub(prev.dists_computed),
            dists_pruned: self.dists_pruned.saturating_sub(prev.dists_pruned),
            dup_skipped: self.dup_skipped.saturating_sub(prev.dup_skipped),
            bucket_skipped: self.bucket_skipped.saturating_sub(prev.bucket_skipped),
            objects_stored: self.objects_stored.saturating_sub(prev.objects_stored),
            reduce_pushes: self.reduce_pushes.saturating_sub(prev.reduce_pushes),
            bytes_resident: self.bytes_resident,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_since_subtracts_counters_and_carries_the_gauge() {
        let mut prev = WorkStats { dists_computed: 10, dup_skipped: 3, ..Default::default() };
        prev.bytes_resident = 500;
        let mut cur = prev;
        cur.dists_computed += 7;
        cur.bucket_skipped += 2;
        cur.bytes_resident = 800;
        let d = cur.delta_since(&prev);
        assert_eq!(d.dists_computed, 7);
        assert_eq!(d.bucket_skipped, 2);
        assert_eq!(d.dup_skipped, 0);
        // gauge carried, not differenced
        assert_eq!(d.bytes_resident, 800);
        // a reset between snapshots saturates to zero instead of wrapping
        let z = prev.delta_since(&cur);
        assert_eq!(z.dists_computed, 0);
    }

    #[test]
    fn local_messages_are_free() {
        let mut m = TrafficMeter::new(0);
        m.send(3, 3, 1000);
        assert_eq!(m.local_msgs, 1);
        assert_eq!(m.logical_msgs, 0);
        assert_eq!(m.total_packets(), 0);
    }

    #[test]
    fn no_aggregation_one_packet_per_msg() {
        let mut m = TrafficMeter::new(0);
        for _ in 0..10 {
            m.send(0, 1, 100);
        }
        assert_eq!(m.total_packets(), 10);
        assert_eq!(m.logical_msgs, 10);
        assert_eq!(m.total_bytes(), 10 * (100 + 64));
    }

    #[test]
    fn aggregation_coalesces() {
        let mut m = TrafficMeter::new(1000);
        for _ in 0..10 {
            m.send(0, 1, 100);
        }
        // exactly one flush at 1000 bytes
        assert_eq!(m.total_packets(), 1);
        assert_eq!(m.logical_msgs, 10);
        m.flush(); // nothing pending
        assert_eq!(m.total_packets(), 1);
        m.send(0, 1, 50);
        m.flush();
        assert_eq!(m.total_packets(), 2);
    }

    #[test]
    fn aggregation_never_overflows_the_budget() {
        let mut m = TrafficMeter::new(1000);
        m.header_bytes = 0;
        m.send(0, 1, 900);
        assert_eq!(m.total_packets(), 0);
        // would overflow: the buffered 900 bytes go out first
        m.send(0, 1, 200);
        assert_eq!(m.total_packets(), 1);
        assert_eq!(m.total_bytes(), 900);
        m.flush();
        assert_eq!(m.total_packets(), 2);
        assert_eq!(m.total_bytes(), 1100);
        // a single message larger than the budget is one oversized packet
        m.send(0, 1, 5000);
        assert_eq!(m.total_packets(), 3);
        assert_eq!(m.total_bytes(), 6100);
    }

    #[test]
    fn flush_preserves_payload_total() {
        let mut a = TrafficMeter::new(0);
        let mut b = TrafficMeter::new(4096);
        for i in 0..57 {
            a.send(0, 1, 100 + i);
            b.send(0, 1, 100 + i);
        }
        b.flush();
        assert_eq!(a.payload_bytes, b.payload_bytes);
        assert!(b.total_packets() < a.total_packets());
    }

    #[test]
    fn per_node_totals() {
        let mut m = TrafficMeter::new(0);
        m.send(0, 1, 100);
        m.send(0, 2, 100);
        m.send(2, 0, 100);
        let per = m.per_node(3);
        assert_eq!(per[0].tx_packets, 2);
        assert_eq!(per[0].rx_packets, 1);
        assert_eq!(per[1].rx_packets, 1);
        assert_eq!(per[2].tx_packets, 1);
    }

    #[test]
    fn merge_adds() {
        let mut a = TrafficMeter::new(0);
        a.send(0, 1, 10);
        let mut b = TrafficMeter::new(0);
        b.send(1, 0, 20);
        b.send(2, 2, 5);
        a.merge(&b);
        assert_eq!(a.logical_msgs, 2);
        assert_eq!(a.local_msgs, 1);
        assert_eq!(a.total_packets(), 2);
    }

    #[test]
    fn add_link_accumulates() {
        let mut m = TrafficMeter::new(0);
        m.add_link(0, 1, 2, 300);
        m.add_link(0, 1, 1, 100);
        m.add_link(1, 0, 1, 50);
        assert_eq!(m.total_packets(), 4);
        assert_eq!(m.total_bytes(), 450);
        assert_eq!(m.links()[&(0, 1)].bytes, 400);
        let sorted = m.sorted_links();
        assert_eq!(sorted[0].0, (0, 1));
        assert_eq!(sorted[1].0, (1, 0));
        let report = m.link_report();
        assert!(report.contains("node  0 -> node  1"));
        assert_eq!(report.lines().count(), 2);
    }

    #[test]
    fn workstats_add() {
        let mut w = WorkStats::default();
        w.dists_computed = 5;
        w.bucket_skipped = 1;
        w.bytes_resident = 900;
        let mut o = WorkStats::default();
        o.dists_computed = 7;
        o.dists_pruned = 3;
        o.hash_vectors = 2;
        o.bucket_skipped = 4;
        o.bytes_resident = 300;
        w.add(&o);
        assert_eq!(w.dists_computed, 12);
        assert_eq!(w.dists_pruned, 3);
        assert_eq!(w.hash_vectors, 2);
        assert_eq!(w.bucket_skipped, 5);
        // bytes_resident is a gauge: max, not sum
        assert_eq!(w.bytes_resident, 900);
        w.add(&o);
        assert_eq!(w.bytes_resident, 900, "re-adding must not inflate the gauge");
    }
}
