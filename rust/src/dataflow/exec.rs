//! The transport-agnostic dataflow runtime (DESIGN.md §Executor seam).
//!
//! The paper's five stages (IR/QR/BI/DP/AG) are *message handlers*; how
//! messages move between them — inline FIFO, threads and channels, or real
//! TCP sockets across OS processes (`crate::net::SocketExecutor`, DESIGN.md
//! §Transports) — is an [`Executor`]. Every driver
//! (index build, search, online insert, experiments, benches) goes through
//! this one seam, so stage-routing logic exists exactly once.
//!
//! * [`StageHandler`] — uniform `on_msg(&mut self, Msg, &mut Emit)` handler
//!   bound to each stage state ([`IrHandler`], [`QrHandler`], [`BiHandler`],
//!   [`DpHandler`], [`AgHandler`]). Completion signalling (AG → executor)
//!   and per-query teardown (executor → DP dedup state) are part of the
//!   trait so no executor needs stage-specific knowledge.
//! * [`InlineExecutor`] — deterministic single-threaded FIFO: each workload
//!   item is delivered to the head stage and the message queue drained to
//!   completion before the next item. Bit-identical to the sequential
//!   baseline; the differential-testing oracle.
//! * [`ThreadedExecutor`] — the paper's widely-asynchronous design: one
//!   thread per BI/DP/AG copy consuming an mpsc channel, head stage and
//!   admission on the calling thread. Supports *closed-loop batched
//!   admission*: with `Workload::window = W`, at most W queries are
//!   in flight at once (open loop when 0), so queueing delay no longer
//!   dominates per-query latency under load.
//!
//! Traffic accounting is executor-owned: a delivery from stage copy A to
//! stage copy B is charged on the meter from `placement.node_of(A)` to
//! `placement.node_of(B)` (same-node deliveries are free). The threaded
//! executor meters per thread and merges at join, so counters match the
//! inline executor's (aggregation flush boundaries aside). Workload ingress
//! (driver → head stage) and control deliveries (shutdown, query teardown)
//! are not metered — they never cross the modeled network.
//!
//! Shutdown in the threaded executor is typed, not panicking: a send to a
//! dropped receiver makes the sender *stop and drain* (and every thread
//! owns a drop-guard that notifies the admission loop), so a dying stage
//! copy cascades into a clean join instead of aborting the process; the
//! original panic, if any, is resurfaced at join.
//!
//! Besides one-shot phase runs ([`Executor::run`]), the seam exposes
//! *long-lived streaming runs* ([`Executor::open_stream`] →
//! [`StreamRun`]): ingress is a channel, so a submission enters the
//! pipeline the moment it arrives instead of waiting for the next pump;
//! completions stream out through a `recv`/`try_recv` egress; and
//! `finish` is a typed quiescence barrier. `StreamConfig::pending_cap`
//! adds bounded backpressure — `submit` blocks (and `try_submit`
//! declines) while `pending_cap` submissions are outstanding
//! (DESIGN.md §Service API).

use crate::dataflow::message::{Dest, Msg, StageKind};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::dataflow::Placement;
use crate::runtime::{Hasher, Ranker};
use crate::stages::aggregator::QueryResult;
use crate::stages::{AgState, BiState, DpState, Emit, InputReader, QueryReceiver};
use crate::util::timer::Timer;
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Uniform message-handling seam implemented by every stage binding.
///
/// A handler owns (a mutable borrow of) one stage copy's state. It must be
/// `Send` so the threaded executor can move it onto the copy's thread.
pub trait StageHandler: Send {
    /// Handle one message, pushing emitted `(Dest, Msg)` pairs onto `out`.
    /// A message the stage cannot handle is a routing-invariant violation
    /// and panics loudly (never silently wrong answers).
    fn on_msg(&mut self, msg: Msg, out: Emit);

    /// Drain queries completed since the last call (AG only).
    fn take_completions(&mut self, _out: &mut Vec<QueryResult>) {}

    /// A query has fully completed downstream (DP drops its per-query
    /// dedup state). Delivered out-of-band; never metered.
    fn on_query_done(&mut self, _qid: u32) {}

    /// A query was cancelled mid-flight (AG only: drop any partial
    /// reduction state for `qid` so the id can be reused by a later run).
    /// The socket stream loop calls this when a replica death retargets a
    /// query to a fresh retry id.
    fn abort_query(&mut self, _qid: u32) {}
}

/// IR bound to a hasher: consumes [`Msg::IndexBlock`] ingress items.
pub struct IrHandler<'a, 'f> {
    pub ir: &'a mut InputReader<'f>,
    pub hasher: &'a dyn Hasher,
}

impl StageHandler for IrHandler<'_, '_> {
    fn on_msg(&mut self, msg: Msg, out: Emit) {
        match msg {
            Msg::IndexBlock { id_base, rows, flat } => {
                self.ir.index_block(self.hasher, &flat, rows as usize, id_base, out)
            }
            other => panic!("IR got unexpected {other:?}"),
        }
    }
}

/// QR: consumes [`Msg::QueryVec`] ingress items (raw projections are
/// precomputed by the driver's batched hash call).
pub struct QrHandler<'a, 'f> {
    pub qr: &'a mut QueryReceiver<'f>,
}

impl StageHandler for QrHandler<'_, '_> {
    fn on_msg(&mut self, msg: Msg, out: Emit) {
        match msg {
            Msg::QueryVec { qid, raw, v, opts } => {
                // The driver hashed this vector in its batched proj call;
                // account for it here so work totals match either way.
                self.qr.work.hash_vectors += 1;
                self.qr.dispatch_query_arc(&raw, qid, v, opts, out);
            }
            other => panic!("QR got unexpected {other:?}"),
        }
    }
}

/// BI: index references during build, probe visits during search.
pub struct BiHandler<'a> {
    pub bi: &'a mut BiState,
}

impl StageHandler for BiHandler<'_> {
    fn on_msg(&mut self, msg: Msg, out: Emit) {
        match msg {
            Msg::IndexRef { key, id, dp, .. } => self.bi.on_index_ref(key, id, dp),
            Msg::Query { qid, probes, v, k } => self.bi.on_query(qid, &probes, &v, k, out),
            other => panic!("BI {} got unexpected {other:?}", self.bi.copy),
        }
    }
}

/// DP: object stores during build, candidate ranking during search. The
/// ranker is optional because the build phase never ranks.
pub struct DpHandler<'a> {
    pub dp: &'a mut DpState,
    pub ranker: Option<&'a dyn Ranker>,
}

impl StageHandler for DpHandler<'_> {
    fn on_msg(&mut self, msg: Msg, out: Emit) {
        match msg {
            Msg::StoreObject { id, v } => self.dp.on_store(id, &v),
            Msg::CandidateReq { qid, ids, v, k } => {
                let ranker = self
                    .ranker
                    .expect("DP received CandidateReq in a phase started without a ranker");
                self.dp.on_candidates(qid, &ids, &v, k as usize, ranker, out);
            }
            other => panic!("DP {} got unexpected {other:?}", self.dp.copy),
        }
    }

    fn on_query_done(&mut self, qid: u32) {
        self.dp.finish_query(qid);
    }
}

/// AG: reduces LocalTopK streams; completed queries surface through
/// [`StageHandler::take_completions`].
pub struct AgHandler<'a> {
    pub ag: &'a mut AgState,
}

impl StageHandler for AgHandler<'_> {
    fn on_msg(&mut self, msg: Msg, _out: Emit) {
        match msg {
            Msg::QueryMeta { qid, n_bi, k } => self.ag.on_query_meta(qid, n_bi, k),
            Msg::BiMeta { qid, n_dp } => self.ag.on_bi_meta(qid, n_dp),
            Msg::LocalTopK { qid, hits } => self.ag.on_local_topk(qid, &hits),
            other => panic!("AG {} got unexpected {other:?}", self.ag.copy),
        }
    }

    fn take_completions(&mut self, out: &mut Vec<QueryResult>) {
        out.append(&mut self.ag.results);
    }

    fn abort_query(&mut self, qid: u32) {
        self.ag.abort_query(qid);
    }
}

/// The stage copies of one pipeline run, as boxed handlers. The head slot
/// holds the ingress stage (IR for build, QR for search) living on the
/// head node; `bis`/`dps`/`ags` are indexed by copy id.
pub struct StageHandlers<'a> {
    pub head: Box<dyn StageHandler + 'a>,
    pub bis: Vec<Box<dyn StageHandler + 'a>>,
    pub dps: Vec<Box<dyn StageHandler + 'a>>,
    pub ags: Vec<Box<dyn StageHandler + 'a>>,
}

/// Bind a cluster's stage states (plus the head stage) into handlers.
pub fn bind_stages<'a>(
    head: Box<dyn StageHandler + 'a>,
    bis: &'a mut [BiState],
    dps: &'a mut [DpState],
    ags: &'a mut [AgState],
    ranker: Option<&'a dyn Ranker>,
) -> StageHandlers<'a> {
    StageHandlers {
        head,
        bis: bis
            .iter_mut()
            .map(|bi| Box::new(BiHandler { bi }) as Box<dyn StageHandler + 'a>)
            .collect(),
        dps: dps
            .iter_mut()
            .map(|dp| Box::new(DpHandler { dp, ranker }) as Box<dyn StageHandler + 'a>)
            .collect(),
        ags: ags
            .iter_mut()
            .map(|ag| Box::new(AgHandler { ag }) as Box<dyn StageHandler + 'a>)
            .collect(),
    }
}

/// One phase's worth of ingress messages plus its admission policy.
pub struct Workload<'a> {
    /// Ingress messages, delivered to the head stage in order (not metered).
    pub items: &'a mut dyn Iterator<Item = Msg>,
    /// How many items carry a qid (i.e. expect an AG completion). Results
    /// and latencies are indexed by qid, which drivers assign as `0..n`.
    pub n_queries: usize,
    /// Closed-loop admission window: max queries in flight (0 = open loop).
    /// Items without a qid (index blocks) are never windowed.
    pub window: usize,
    /// Traffic-meter aggregation buffer (from `Config::stream.agg_bytes`).
    pub agg_bytes: usize,
}

/// What an executor hands back: per-qid results and latencies, plus the
/// merged traffic meter for the phase. (Phase wall time is the driver's to
/// measure — it includes work outside the executor, e.g. batch hashing.)
pub struct ExecReport {
    /// Global top-k per qid (empty for build phases).
    pub results: Vec<Vec<(f32, u32)>>,
    /// Admission-to-completion seconds per qid.
    pub per_query_secs: Vec<f64>,
    pub meter: TrafficMeter,
    /// Per-copy work counters for stage copies the executor hosts *outside*
    /// this process (the socket transport decodes them from `FlushAck`
    /// barriers). Empty for in-process executors, whose work counters
    /// accumulate directly in the local stage states.
    pub work: Vec<(StageKind, u16, WorkStats)>,
}

/// Knobs of a long-lived streaming run (the `stream.*` config section
/// distilled to what an executor needs; see [`Executor::open_stream`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamConfig {
    /// Closed-loop admission window: max queries in flight inside the
    /// pipeline at once (0 = open loop). Same meaning as
    /// [`Workload::window`] / `Config::stream.inflight`.
    pub window: usize,
    /// Traffic-meter / write-batch aggregation buffer (bytes, 0 = off).
    pub agg_bytes: usize,
    /// Backpressure cap on queries submitted but not yet completed
    /// (pending in the ingress queue + in flight in the pipeline).
    /// [`StreamRun::submit`] blocks at the cap; 0 = unbounded.
    pub pending_cap: usize,
}

/// One completed query delivered through a streaming run's egress.
#[derive(Clone, Debug)]
pub struct StreamCompletion {
    /// The qid the caller stamped on the ingress [`Msg`].
    pub qid: u32,
    /// Global top-k `(sqdist, id)` ascending.
    pub hits: Vec<(f32, u32)>,
    /// Pipeline-admission-to-completion seconds.
    pub secs: f64,
}

/// What [`StreamRun::finish`] hands back — the streaming rendition of
/// [`ExecReport`]: completions were already delivered through the egress,
/// so the barrier carries only the stragglers plus the run's accounting.
pub struct StreamReport {
    /// Completions that were never claimed through `recv`/`try_recv`
    /// (qid order follows completion order).
    pub unclaimed: Vec<StreamCompletion>,
    /// Merged traffic of the whole run (flushed).
    pub meter: TrafficMeter,
    /// Remote per-copy work counters (socket transport; empty in-process —
    /// same contract as [`ExecReport::work`]).
    pub work: Vec<(StageKind, u16, WorkStats)>,
    /// Queries re-dispatched to a surviving replica after their first
    /// dispatch hit a dead worker (socket transport; 0 in-process).
    pub retargeted: u64,
}

/// A long-lived streaming run: ingress is a channel (a submission enters
/// the pipeline the moment it arrives, no per-pump workload), completions
/// stream out through `recv`/`try_recv`, and `finish` is a typed barrier
/// that waits for quiescence and returns the run's accounting.
///
/// Failure surfaces loudly, mirroring [`Executor::run`]: a dying stage
/// (thread or worker process) makes subsequent calls panic instead of
/// wedging the caller, and a submitter blocked on backpressure is woken
/// rather than left hanging.
pub trait StreamRun: Send {
    /// Admit one ingress message. Query messages (those with a qid) block
    /// while `pending_cap` submissions are outstanding; items without a
    /// qid are never gated (same policy as [`Workload::window`]).
    fn submit(&mut self, msg: Msg);

    /// Non-blocking [`StreamRun::submit`]: hands the message back when the
    /// backpressure window is full.
    fn try_submit(&mut self, msg: Msg) -> Result<(), Msg>;

    /// Cheap capacity probe: `false` when a blocking submit of a query
    /// would currently wait on the backpressure window. Advisory — the
    /// window can fill or drain between a probe and the submit; callers
    /// use it to skip per-query preparation (hashing) on the decline
    /// path. Dead runs report `true` so the next call fails loudly.
    fn can_submit(&self) -> bool {
        true
    }

    /// Next completion, waiting up to `timeout`. `None` means nothing
    /// completed within the timeout — the pipeline keeps running.
    fn recv(&mut self, timeout: Duration) -> Option<StreamCompletion>;

    /// Pop a completion if one is already buffered.
    fn try_recv(&mut self) -> Option<StreamCompletion>;

    /// Typed barrier: waits until every admitted message is fully
    /// processed, tears the run down, and returns unclaimed completions
    /// plus the run's merged meter and remote work counters.
    fn finish(self: Box<Self>) -> StreamReport;
}

/// A transport for the five-stage dataflow.
///
/// `Sync` is part of the contract: a [`crate::coordinator::session::IndexSession`]
/// holds an executor across phases and accepts submissions from multiple
/// threads, so every transport must be shareable by reference.
pub trait Executor: Sync {
    fn run(
        &self,
        placement: &Placement,
        stages: StageHandlers<'_>,
        workload: Workload<'_>,
    ) -> ExecReport;

    /// Open a long-lived streaming run over owned (`'static`) stage
    /// handlers. The default delegates to a deterministic per-item drain
    /// on the calling thread — the [`InlineExecutor`] semantics, also a
    /// correct (if transport-unfaithful) fallback for custom executors.
    /// [`ThreadedExecutor`] overrides it with parked stage threads and a
    /// dedicated admission thread; the socket transport keeps its worker
    /// connections hot and admits without per-pump barrier round-trips.
    fn open_stream<'e>(
        &'e self,
        placement: &Placement,
        stages: StageHandlers<'static>,
        cfg: StreamConfig,
    ) -> Box<dyn StreamRun + 'e> {
        Box::new(DrainStreamRun::new(placement.clone(), stages, cfg))
    }
}

// ------------------------------------------------------------- stream gate

/// The backpressure window of a streaming run: a counting gate acquired at
/// submission, released at completion, and killed (opened with a `dead`
/// flag) when the run goes down so blocked submitters never hang on a dead
/// pipeline.
pub(crate) struct StreamGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    n: usize,
    cap: usize,
    dead: bool,
}

impl StreamGate {
    pub(crate) fn new(cap: usize) -> StreamGate {
        StreamGate {
            state: Mutex::new(GateState { n: 0, cap, dead: false }),
            cv: Condvar::new(),
        }
    }

    /// Blocks while the window is full. `false` means the run died.
    pub(crate) fn acquire(&self) -> bool {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if g.dead {
                return false;
            }
            if g.cap == 0 || g.n < g.cap {
                g.n += 1;
                return true;
            }
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// `Ok(true)` acquired, `Ok(false)` window full, `Err(())` run died.
    pub(crate) fn try_acquire(&self) -> Result<bool, ()> {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        if g.dead {
            return Err(());
        }
        if g.cap == 0 || g.n < g.cap {
            g.n += 1;
            return Ok(true);
        }
        Ok(false)
    }

    /// Advisory capacity probe (no acquisition). Dead gates report room
    /// so the caller proceeds into the loud failure path.
    pub(crate) fn has_room(&self) -> bool {
        let g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        g.dead || g.cap == 0 || g.n < g.cap
    }

    pub(crate) fn release(&self) {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        g.n = g.n.saturating_sub(1);
        drop(g);
        self.cv.notify_all();
    }

    /// Mark the run dead and wake every blocked submitter.
    pub(crate) fn kill(&self) {
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        g.dead = true;
        drop(g);
        self.cv.notify_all();
    }
}

/// Opens the gate when dropped — placed in admission loops so an unwind
/// (or any exit path) can never leave submitters blocked forever.
pub(crate) struct GateGuard(pub(crate) Arc<StreamGate>);

impl Drop for GateGuard {
    fn drop(&mut self) {
        self.0.kill();
    }
}

// ------------------------------------------------- per-item drain stream

/// The default [`StreamRun`]: deterministic per-item drain on the calling
/// thread (no concurrency, so completions are available the moment
/// `submit` returns and the backpressure window can never fill). This is
/// the [`InlineExecutor`]'s streaming semantics — the differential oracle
/// for the threaded and socket streaming runs.
pub struct DrainStreamRun {
    placement: Placement,
    stages: StageHandlers<'static>,
    meter: TrafficMeter,
    done: VecDeque<StreamCompletion>,
}

impl DrainStreamRun {
    pub fn new(
        placement: Placement,
        stages: StageHandlers<'static>,
        cfg: StreamConfig,
    ) -> DrainStreamRun {
        DrainStreamRun {
            placement,
            stages,
            meter: TrafficMeter::new(cfg.agg_bytes),
            done: VecDeque::new(),
        }
    }

    fn process(&mut self, item: Msg) {
        let qt = Timer::start();
        let head_node = self.placement.head_node;
        let mut queue: VecDeque<(Dest, Msg)> = VecDeque::new();
        let mut emitted: Vec<(Dest, Msg)> = Vec::new();
        let mut comps: Vec<QueryResult> = Vec::new();
        self.stages.head.on_msg(item, &mut emitted);
        for (dest, msg) in emitted.drain(..) {
            self.meter.send(
                head_node,
                self.placement.node_of(dest.stage, dest.copy),
                msg.wire_size(),
            );
            queue.push_back((dest, msg));
        }
        while let Some((dest, msg)) = queue.pop_front() {
            let handler_node = self.placement.node_of(dest.stage, dest.copy);
            stage_mut(&mut self.stages, dest).on_msg(msg, &mut emitted);
            for (d2, m2) in emitted.drain(..) {
                self.meter.send(
                    handler_node,
                    self.placement.node_of(d2.stage, d2.copy),
                    m2.wire_size(),
                );
                queue.push_back((d2, m2));
            }
        }
        for ag in self.stages.ags.iter_mut() {
            ag.take_completions(&mut comps);
        }
        let secs = qt.secs();
        for (qid, hits) in comps.drain(..) {
            for dp in self.stages.dps.iter_mut() {
                dp.on_query_done(qid);
            }
            self.done.push_back(StreamCompletion { qid, hits, secs });
        }
    }
}

impl StreamRun for DrainStreamRun {
    fn submit(&mut self, msg: Msg) {
        self.process(msg);
    }

    fn try_submit(&mut self, msg: Msg) -> Result<(), Msg> {
        self.process(msg);
        Ok(())
    }

    fn recv(&mut self, _timeout: Duration) -> Option<StreamCompletion> {
        self.done.pop_front()
    }

    fn try_recv(&mut self) -> Option<StreamCompletion> {
        self.done.pop_front()
    }

    fn finish(mut self: Box<Self>) -> StreamReport {
        self.meter.flush();
        StreamReport {
            unclaimed: self.done.into_iter().collect(),
            meter: self.meter,
            work: Vec::new(),
            retargeted: 0,
        }
    }
}

// ------------------------------------------------------------------ inline

/// Deterministic single-threaded FIFO executor: delivers one workload item,
/// drains the message queue to quiescence, then admits the next. The
/// differential-testing oracle — results are bit-identical to the
/// sequential baseline.
pub struct InlineExecutor;

fn stage_mut<'x, 'a>(
    stages: &'x mut StageHandlers<'a>,
    dest: Dest,
) -> &'x mut (dyn StageHandler + 'a) {
    match dest.stage {
        StageKind::Bi => stages.bis[dest.copy as usize].as_mut(),
        StageKind::Dp => stages.dps[dest.copy as usize].as_mut(),
        StageKind::Ag => stages.ags[dest.copy as usize].as_mut(),
        // The head stage is fed by workload ingress only; an emission
        // addressed upstream is a routing bug (same invariant as the
        // threaded router).
        StageKind::Ir | StageKind::Qr => {
            panic!("message routed upstream to {:?}", dest.stage)
        }
    }
}

impl Executor for InlineExecutor {
    fn run(
        &self,
        placement: &Placement,
        mut stages: StageHandlers<'_>,
        workload: Workload<'_>,
    ) -> ExecReport {
        let mut meter = TrafficMeter::new(workload.agg_bytes);
        let head_node = placement.head_node;
        let mut results: Vec<Vec<(f32, u32)>> = vec![Vec::new(); workload.n_queries];
        let mut per_query_secs = vec![0f64; workload.n_queries];
        let mut queue: VecDeque<(Dest, Msg)> = VecDeque::new();
        let mut emitted: Vec<(Dest, Msg)> = Vec::new();
        let mut comps: Vec<QueryResult> = Vec::new();

        for item in workload.items {
            let qt = Timer::start();
            let item_qid = item.qid();
            stages.head.on_msg(item, &mut emitted);
            for (dest, msg) in emitted.drain(..) {
                meter.send(
                    head_node,
                    placement.node_of(dest.stage, dest.copy),
                    msg.wire_size(),
                );
                queue.push_back((dest, msg));
            }
            // Drain to quiescence (FIFO, deterministic). Messages a handler
            // emits are charged from its node.
            while let Some((dest, msg)) = queue.pop_front() {
                let handler_node = placement.node_of(dest.stage, dest.copy);
                stage_mut(&mut stages, dest).on_msg(msg, &mut emitted);
                for (d2, m2) in emitted.drain(..) {
                    meter.send(
                        handler_node,
                        placement.node_of(d2.stage, d2.copy),
                        m2.wire_size(),
                    );
                    queue.push_back((d2, m2));
                }
            }
            for ag in stages.ags.iter_mut() {
                ag.take_completions(&mut comps);
            }
            for (qid, hits) in comps.drain(..) {
                for dp in stages.dps.iter_mut() {
                    dp.on_query_done(qid);
                }
                results[qid as usize] = hits;
            }
            if let Some(qid) = item_qid {
                per_query_secs[qid as usize] = qt.secs();
            }
        }
        meter.flush();
        ExecReport { results, per_query_secs, meter, work: Vec::new() }
    }
}

// ---------------------------------------------------------------- threaded

/// What travels over a stage copy's channel: a routed message or the
/// out-of-band per-query teardown control.
enum Delivery {
    Msg(Msg),
    Done(u32),
}

/// Events flowing back to the admission loop. `Ingress`/`Finish` are the
/// streaming run's additions (one unified channel stands in for a select
/// over ingress + completions); phase runs never see them.
enum Event {
    /// AG finished a query (completion instant taken on the AG thread).
    Done(u32, Vec<(f32, u32)>, Instant),
    /// A stage thread exited (normal cascade *or* unwind — sent from a
    /// drop guard). Seeing this mid-phase means the pipeline is dying;
    /// the admission loop stops and drains instead of blocking forever.
    Stopped,
    /// Streaming submission (from [`StreamRun::submit`]).
    Ingress(Msg),
    /// Streaming barrier: no further ingress; wind down at quiescence.
    Finish,
}

/// Downstream senders available to one stage copy. Following the dataflow
/// DAG (head → BI → DP → AG) keeps sender ownership acyclic, which is what
/// makes shutdown a clean cascade of channel closures.
#[derive(Default)]
struct Router {
    bi: Vec<mpsc::Sender<Delivery>>,
    dp: Vec<mpsc::Sender<Delivery>>,
    ag: Vec<mpsc::Sender<Delivery>>,
}

impl Router {
    /// Deliver to a stage copy. `false` means the receiver is gone
    /// (shutdown or a died thread): the caller stops and drains.
    fn send(&self, dest: Dest, d: Delivery) -> bool {
        let txs = match dest.stage {
            StageKind::Bi => &self.bi,
            StageKind::Dp => &self.dp,
            StageKind::Ag => &self.ag,
            StageKind::Ir | StageKind::Qr => {
                panic!("message routed upstream to {:?}", dest.stage)
            }
        };
        match txs.get(dest.copy as usize) {
            Some(tx) => tx.send(d).is_ok(),
            None => panic!("no channel for {:?} copy {}", dest.stage, dest.copy),
        }
    }
}

/// Notifies the admission loop when its thread exits — including by panic,
/// since `Drop` runs during unwinding.
struct StopGuard {
    tx: mpsc::Sender<Event>,
}

impl Drop for StopGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(Event::Stopped);
    }
}

/// Per-thread context for one stage copy.
struct StageCtx {
    rx: mpsc::Receiver<Delivery>,
    router: Router,
    events: mpsc::Sender<Event>,
    my_node: u16,
    agg_bytes: usize,
}

fn stage_thread(
    handler: &mut (dyn StageHandler + '_),
    placement: &Placement,
    ctx: StageCtx,
) -> TrafficMeter {
    let _guard = StopGuard { tx: ctx.events.clone() };
    let mut meter = TrafficMeter::new(ctx.agg_bytes);
    let mut out: Vec<(Dest, Msg)> = Vec::new();
    let mut comps: Vec<QueryResult> = Vec::new();
    'recv: while let Ok(d) = ctx.rx.recv() {
        match d {
            Delivery::Msg(msg) => {
                handler.on_msg(msg, &mut out);
                for (dest, m) in out.drain(..) {
                    meter.send(
                        ctx.my_node,
                        placement.node_of(dest.stage, dest.copy),
                        m.wire_size(),
                    );
                    if !ctx.router.send(dest, Delivery::Msg(m)) {
                        break 'recv;
                    }
                }
                handler.take_completions(&mut comps);
                for (qid, hits) in comps.drain(..) {
                    if ctx
                        .events
                        .send(Event::Done(qid, hits, Instant::now()))
                        .is_err()
                    {
                        break 'recv;
                    }
                }
            }
            Delivery::Done(qid) => handler.on_query_done(qid),
        }
    }
    meter.flush();
    meter
}

/// One thread per BI/DP/AG copy; head stage + admission on the calling
/// thread. `Workload::window` selects closed-loop batched admission.
pub struct ThreadedExecutor;

impl Executor for ThreadedExecutor {
    fn run(
        &self,
        placement: &Placement,
        stages: StageHandlers<'_>,
        workload: Workload<'_>,
    ) -> ExecReport {
        let agg = workload.agg_bytes;
        let n_queries = workload.n_queries;
        let window = workload.window;
        let StageHandlers { mut head, bis, dps, ags } = stages;

        let (bi_tx, bi_rx): (Vec<_>, Vec<_>) =
            bis.iter().map(|_| mpsc::channel::<Delivery>()).unzip();
        let (dp_tx, dp_rx): (Vec<_>, Vec<_>) =
            dps.iter().map(|_| mpsc::channel::<Delivery>()).unzip();
        let (ag_tx, ag_rx): (Vec<_>, Vec<_>) =
            ags.iter().map(|_| mpsc::channel::<Delivery>()).unzip();
        let (ev_tx, ev_rx) = mpsc::channel::<Event>();

        let mut results: Vec<Vec<(f32, u32)>> = vec![Vec::new(); n_queries];
        let mut per_query_secs = vec![0f64; n_queries];
        let mut merged = TrafficMeter::new(agg);

        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (copy, (mut h, rx)) in ags.into_iter().zip(ag_rx).enumerate() {
                let ctx = StageCtx {
                    rx,
                    router: Router::default(),
                    events: ev_tx.clone(),
                    my_node: placement.node_of(StageKind::Ag, copy as u16),
                    agg_bytes: agg,
                };
                handles.push(s.spawn(move || stage_thread(h.as_mut(), placement, ctx)));
            }
            for (copy, (mut h, rx)) in dps.into_iter().zip(dp_rx).enumerate() {
                let ctx = StageCtx {
                    rx,
                    router: Router { ag: ag_tx.clone(), ..Router::default() },
                    events: ev_tx.clone(),
                    my_node: placement.node_of(StageKind::Dp, copy as u16),
                    agg_bytes: agg,
                };
                handles.push(s.spawn(move || stage_thread(h.as_mut(), placement, ctx)));
            }
            for (copy, (mut h, rx)) in bis.into_iter().zip(bi_rx).enumerate() {
                let ctx = StageCtx {
                    rx,
                    router: Router {
                        dp: dp_tx.clone(),
                        ag: ag_tx.clone(),
                        ..Router::default()
                    },
                    events: ev_tx.clone(),
                    my_node: placement.node_of(StageKind::Bi, copy as u16),
                    agg_bytes: agg,
                };
                handles.push(s.spawn(move || stage_thread(h.as_mut(), placement, ctx)));
            }
            drop(ev_tx);

            // --- head stage + admission on this thread ---
            let router = Router { bi: bi_tx, dp: dp_tx, ag: ag_tx };
            let mut meter = TrafficMeter::new(agg);
            let head_node = placement.head_node;
            let mut emitted: Vec<(Dest, Msg)> = Vec::new();
            let mut dispatch_ts: Vec<Instant> = vec![Instant::now(); n_queries];
            let mut items = workload.items.peekable();
            let mut items_done = false;
            let mut in_flight = 0usize;
            let mut completed = 0usize;
            let mut dying = false;

            'admission: loop {
                // Admit while the window allows. Items without a qid (index
                // blocks) bypass the window entirely — only queries are
                // throttled by the closed loop.
                while !items_done && !dying {
                    let next_is_query = match items.peek() {
                        None => {
                            items_done = true;
                            break;
                        }
                        Some(m) => m.qid().is_some(),
                    };
                    if next_is_query && window != 0 && in_flight >= window {
                        break; // wait for a completion before admitting
                    }
                    let item = items.next().expect("peeked non-empty");
                    let item_qid = item.qid();
                    head.on_msg(item, &mut emitted);
                    if let Some(qid) = item_qid {
                        dispatch_ts[qid as usize] = Instant::now();
                        in_flight += 1;
                    }
                    for (dest, msg) in emitted.drain(..) {
                        meter.send(
                            head_node,
                            placement.node_of(dest.stage, dest.copy),
                            msg.wire_size(),
                        );
                        if !router.send(dest, Delivery::Msg(msg)) {
                            dying = true;
                            break;
                        }
                    }
                }
                if dying || (items_done && completed >= n_queries) {
                    break 'admission;
                }
                match ev_rx.recv() {
                    Ok(Event::Done(qid, hits, at)) => {
                        per_query_secs[qid as usize] = at
                            .duration_since(dispatch_ts[qid as usize])
                            .as_secs_f64();
                        results[qid as usize] = hits;
                        completed += 1;
                        in_flight = in_flight.saturating_sub(1);
                        // Per-query teardown: DPs drop their dedup state.
                        // Closed channels are fine here — those DPs are
                        // already gone along with their state.
                        for tx in &router.dp {
                            let _ = tx.send(Delivery::Done(qid));
                        }
                    }
                    Ok(Event::Stopped) => dying = true,
                    // streaming-only events; nothing sends them in a phase run
                    Ok(Event::Ingress(_)) | Ok(Event::Finish) => {
                        unreachable!("streaming event on a phase run")
                    }
                    Err(_) => break 'admission,
                }
            }
            meter.flush();
            merged.merge(&meter);

            // Cascade shutdown: dropping the head's senders closes BI
            // channels; BI exits drop DP senders; DP exits drop AG senders.
            drop(router);

            // Drain late completions while threads wind down.
            while let Ok(ev) = ev_rx.recv() {
                if let Event::Done(qid, hits, at) = ev {
                    per_query_secs[qid as usize] = at
                        .duration_since(dispatch_ts[qid as usize])
                        .as_secs_f64();
                    results[qid as usize] = hits;
                }
            }

            for h in handles {
                match h.join() {
                    Ok(m) => merged.merge(&m),
                    Err(panic) => std::panic::resume_unwind(panic),
                }
            }
        });

        ExecReport { results, per_query_secs, meter: merged, work: Vec::new() }
    }

    fn open_stream<'e>(
        &'e self,
        placement: &Placement,
        stages: StageHandlers<'static>,
        cfg: StreamConfig,
    ) -> Box<dyn StreamRun + 'e> {
        let StageHandlers { head, bis, dps, ags } = stages;
        let (ev_tx, ev_rx) = mpsc::channel::<Event>();
        let (eg_tx, eg_rx) = mpsc::channel::<StreamCompletion>();
        let gate = Arc::new(StreamGate::new(cfg.pending_cap));

        // One parked thread per BI/DP/AG copy, exactly the phase-run
        // topology — but plain (non-scoped) threads, since the run
        // outlives this call: handlers must be owned (`'static`).
        let (bi_tx, bi_rx): (Vec<_>, Vec<_>) =
            bis.iter().map(|_| mpsc::channel::<Delivery>()).unzip();
        let (dp_tx, dp_rx): (Vec<_>, Vec<_>) =
            dps.iter().map(|_| mpsc::channel::<Delivery>()).unzip();
        let (ag_tx, ag_rx): (Vec<_>, Vec<_>) =
            ags.iter().map(|_| mpsc::channel::<Delivery>()).unzip();

        let mut handles = Vec::new();
        let agg = cfg.agg_bytes;
        for (copy, (mut h, rx)) in ags.into_iter().zip(ag_rx).enumerate() {
            let ctx = StageCtx {
                rx,
                router: Router::default(),
                events: ev_tx.clone(),
                my_node: placement.node_of(StageKind::Ag, copy as u16),
                agg_bytes: agg,
            };
            let p = placement.clone();
            handles.push(std::thread::spawn(move || stage_thread(h.as_mut(), &p, ctx)));
        }
        for (copy, (mut h, rx)) in dps.into_iter().zip(dp_rx).enumerate() {
            let ctx = StageCtx {
                rx,
                router: Router { ag: ag_tx.clone(), ..Router::default() },
                events: ev_tx.clone(),
                my_node: placement.node_of(StageKind::Dp, copy as u16),
                agg_bytes: agg,
            };
            let p = placement.clone();
            handles.push(std::thread::spawn(move || stage_thread(h.as_mut(), &p, ctx)));
        }
        for (copy, (mut h, rx)) in bis.into_iter().zip(bi_rx).enumerate() {
            let ctx = StageCtx {
                rx,
                router: Router {
                    dp: dp_tx.clone(),
                    ag: ag_tx.clone(),
                    ..Router::default()
                },
                events: ev_tx.clone(),
                my_node: placement.node_of(StageKind::Bi, copy as u16),
                agg_bytes: agg,
            };
            let p = placement.clone();
            handles.push(std::thread::spawn(move || stage_thread(h.as_mut(), &p, ctx)));
        }

        let router = Router { bi: bi_tx, dp: dp_tx, ag: ag_tx };
        let g = gate.clone();
        let p = placement.clone();
        let admission = std::thread::spawn(move || {
            stream_admission(head, router, ev_rx, eg_tx, g, p, cfg, handles)
        });

        Box::new(ThreadedStreamRun {
            ev_tx,
            gate,
            egress_rx: eg_rx,
            admission: Some(admission),
        })
    }
}

/// What the streaming admission thread hands back at join.
struct StreamJoin {
    meter: TrafficMeter,
    /// A stage thread's panic payload, resurfaced to the caller.
    panic: Option<Box<dyn std::any::Any + Send>>,
    /// Typed failure description when the run died without a panic.
    error: Option<String>,
}

/// The threaded transport's [`StreamRun`]: stage threads stay parked on
/// their channels between submissions; a dedicated admission thread owns
/// the head stage and applies the closed-loop window + backpressure gate.
struct ThreadedStreamRun {
    ev_tx: mpsc::Sender<Event>,
    gate: Arc<StreamGate>,
    egress_rx: mpsc::Receiver<StreamCompletion>,
    admission: Option<std::thread::JoinHandle<StreamJoin>>,
}

impl ThreadedStreamRun {
    /// The run died: join the admission thread and resurface the failure.
    fn die(&mut self) -> ! {
        let join = self
            .admission
            .take()
            .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)));
        match join {
            Some(StreamJoin { panic: Some(p), .. }) => std::panic::resume_unwind(p),
            Some(StreamJoin { error: Some(e), .. }) => {
                panic!("threaded stream run died: {e}")
            }
            _ => panic!("threaded stream run died"),
        }
    }

    fn wind_down(&mut self) -> StreamJoin {
        let _ = self.ev_tx.send(Event::Finish);
        let handle = self.admission.take().expect("stream already finished");
        handle
            .join()
            .unwrap_or_else(|p| std::panic::resume_unwind(p))
    }
}

impl StreamRun for ThreadedStreamRun {
    fn submit(&mut self, msg: Msg) {
        let gated = msg.qid().is_some();
        if gated && !self.gate.acquire() {
            self.die();
        }
        if self.ev_tx.send(Event::Ingress(msg)).is_err() {
            self.die();
        }
    }

    fn try_submit(&mut self, msg: Msg) -> Result<(), Msg> {
        if msg.qid().is_some() {
            match self.gate.try_acquire() {
                Ok(true) => {}
                Ok(false) => return Err(msg),
                Err(()) => self.die(),
            }
        }
        if self.ev_tx.send(Event::Ingress(msg)).is_err() {
            self.die();
        }
        Ok(())
    }

    fn can_submit(&self) -> bool {
        self.gate.has_room()
    }

    fn recv(&mut self, timeout: Duration) -> Option<StreamCompletion> {
        match self.egress_rx.recv_timeout(timeout) {
            Ok(c) => Some(c),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => self.die(),
        }
    }

    fn try_recv(&mut self) -> Option<StreamCompletion> {
        match self.egress_rx.try_recv() {
            Ok(c) => Some(c),
            Err(mpsc::TryRecvError::Empty) => None,
            // The admission thread is gone but completions may still be
            // buffered ahead of the disconnect — Empty+gone means death.
            Err(mpsc::TryRecvError::Disconnected) => self.die(),
        }
    }

    fn finish(mut self: Box<Self>) -> StreamReport {
        let join = self.wind_down();
        if let Some(p) = join.panic {
            std::panic::resume_unwind(p);
        }
        if let Some(e) = join.error {
            panic!("threaded stream run died: {e}");
        }
        let mut unclaimed = Vec::new();
        while let Ok(c) = self.egress_rx.try_recv() {
            unclaimed.push(c);
        }
        StreamReport { unclaimed, meter: join.meter, work: Vec::new(), retargeted: 0 }
    }
}

impl Drop for ThreadedStreamRun {
    fn drop(&mut self) {
        // Dropped without `finish` (caller unwound): wind the threads down
        // instead of leaking them. Failures are swallowed — the caller is
        // already on an error path, and panicking here would abort.
        if let Some(handle) = self.admission.take() {
            let _ = self.ev_tx.send(Event::Finish);
            let _ = handle.join();
        }
    }
}

/// The streaming admission loop (its own thread): pulls ingress and
/// completion events off one unified channel, defers ingress while the
/// closed-loop window is full, releases the backpressure gate and fans
/// out per-query teardown on every completion, and winds the stage
/// threads down at the `Finish` barrier (or on a died stage).
#[allow(clippy::too_many_arguments)]
fn stream_admission(
    mut head: Box<dyn StageHandler>,
    router: Router,
    ev_rx: mpsc::Receiver<Event>,
    egress: mpsc::Sender<StreamCompletion>,
    gate: Arc<StreamGate>,
    placement: Placement,
    cfg: StreamConfig,
    handles: Vec<std::thread::JoinHandle<TrafficMeter>>,
) -> StreamJoin {
    // Opens the gate on every exit path (including unwind) so blocked
    // submitters wake instead of hanging on a dead run.
    let _gg = GateGuard(gate.clone());
    let mut meter = TrafficMeter::new(cfg.agg_bytes);
    let head_node = placement.head_node;
    let mut emitted: Vec<(Dest, Msg)> = Vec::new();
    let mut pending: VecDeque<Msg> = VecDeque::new();
    let mut dispatch_ts: HashMap<u32, Instant> = HashMap::new();
    let mut in_flight = 0usize;
    let mut finishing = false;
    let mut error: Option<String> = None;

    'run: loop {
        // Admit deferred ingress while the window allows (non-query items
        // are never windowed — same policy as the phase run).
        while error.is_none() {
            let next_is_query = match pending.front() {
                None => break,
                Some(m) => m.qid().is_some(),
            };
            if next_is_query && cfg.window != 0 && in_flight >= cfg.window {
                break;
            }
            let item = pending.pop_front().expect("peeked non-empty");
            let item_qid = item.qid();
            head.on_msg(item, &mut emitted);
            if let Some(qid) = item_qid {
                dispatch_ts.insert(qid, Instant::now());
                in_flight += 1;
            }
            for (dest, msg) in emitted.drain(..) {
                meter.send(
                    head_node,
                    placement.node_of(dest.stage, dest.copy),
                    msg.wire_size(),
                );
                if !router.send(dest, Delivery::Msg(msg)) {
                    error = Some("a stage channel closed mid-stream".into());
                    break;
                }
            }
        }
        if error.is_some() || (finishing && pending.is_empty() && in_flight == 0) {
            break 'run;
        }
        match ev_rx.recv() {
            Ok(Event::Ingress(m)) => pending.push_back(m),
            Ok(Event::Done(qid, hits, at)) => {
                let secs = dispatch_ts
                    .remove(&qid)
                    .map(|t| at.duration_since(t).as_secs_f64())
                    .unwrap_or(0.0);
                in_flight = in_flight.saturating_sub(1);
                for tx in &router.dp {
                    let _ = tx.send(Delivery::Done(qid));
                }
                gate.release();
                let _ = egress.send(StreamCompletion { qid, hits, secs });
            }
            Ok(Event::Stopped) => {
                error = Some("a stage thread stopped mid-stream".into());
            }
            Ok(Event::Finish) => finishing = true,
            // Every ev sender gone (run handle dropped mid-unwind and the
            // stage threads already exited): treat as a wind-down.
            Err(_) => break 'run,
        }
    }
    meter.flush();

    // Cascade shutdown exactly like the phase run: dropping the head's
    // senders closes BI channels, BI exits close DP, DP exits close AG.
    drop(router);
    // Late events (completions racing the shutdown on a dying run) are
    // drained non-blockingly — the ingress sender half may still be alive
    // in the run handle, so a blocking recv could hang here.
    while let Ok(ev) = ev_rx.try_recv() {
        if let Event::Done(qid, hits, at) = ev {
            let secs = dispatch_ts
                .remove(&qid)
                .map(|t| at.duration_since(t).as_secs_f64())
                .unwrap_or(0.0);
            gate.release();
            let _ = egress.send(StreamCompletion { qid, hits, secs });
        }
    }

    let mut merged = meter;
    let mut panic = None;
    for h in handles {
        match h.join() {
            Ok(m) => merged.merge(&m),
            Err(p) => panic = Some(p),
        }
    }
    StreamJoin { meter: merged, panic, error }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::message::QueryOptions;
    use std::sync::Arc;

    fn tiny_placement() -> Placement {
        Placement {
            bi_copies: 1,
            dp_copies: 1,
            ag_copies: 1,
            bi_nodes: 1,
            dp_nodes: 1,
            replication: 1,
            head_node: 2,
        }
    }

    fn qv(qid: u32) -> Msg {
        let a: Arc<[f32]> = vec![0f32; 1].into();
        Msg::QueryVec { qid, raw: a.clone(), v: a, opts: QueryOptions::default() }
    }

    /// Head that fans each query out to DP 0 (payload) and AG 0 (trigger).
    struct RelayHead;
    impl StageHandler for RelayHead {
        fn on_msg(&mut self, msg: Msg, out: Emit) {
            let qid = msg.qid().expect("RelayHead only takes queries");
            let v: Arc<[f32]> = vec![0f32; 1].into();
            out.push((Dest::dp(0), Msg::CandidateReq { qid, ids: Vec::new(), v, k: 1 }));
            out.push((Dest::ag(0), Msg::QueryMeta { qid, n_bi: 0, k: 1 }));
        }
    }

    /// DP that tracks how many queries are in flight (msg seen, no Done yet).
    struct CountingDp {
        in_flight: usize,
        max_in_flight: usize,
        done_seen: usize,
    }
    impl StageHandler for CountingDp {
        fn on_msg(&mut self, _msg: Msg, _out: Emit) {
            self.in_flight += 1;
            self.max_in_flight = self.max_in_flight.max(self.in_flight);
        }
        fn on_query_done(&mut self, _qid: u32) {
            self.in_flight -= 1;
            self.done_seen += 1;
        }
    }

    /// AG that completes every query on sight.
    struct InstantAg {
        finished: Vec<QueryResult>,
    }
    impl StageHandler for InstantAg {
        fn on_msg(&mut self, msg: Msg, _out: Emit) {
            let qid = msg.qid().unwrap();
            self.finished.push((qid, vec![(0.0, qid)]));
        }
        fn take_completions(&mut self, out: &mut Vec<QueryResult>) {
            out.append(&mut self.finished);
        }
    }

    /// BI that dies on the first message (shutdown-path test).
    struct PanicBi;
    impl StageHandler for PanicBi {
        fn on_msg(&mut self, _msg: Msg, _out: Emit) {
            panic!("injected BI failure");
        }
    }

    struct NoopStage;
    impl StageHandler for NoopStage {
        fn on_msg(&mut self, _msg: Msg, _out: Emit) {}
    }

    /// Forwarding impl so tests can keep ownership of a handler's state
    /// while the executor drives it.
    impl<H: StageHandler> StageHandler for &mut H {
        fn on_msg(&mut self, msg: Msg, out: Emit) {
            (**self).on_msg(msg, out)
        }
        fn take_completions(&mut self, out: &mut Vec<QueryResult>) {
            (**self).take_completions(out)
        }
        fn on_query_done(&mut self, qid: u32) {
            (**self).on_query_done(qid)
        }
    }

    fn boxed<'a, H: StageHandler + 'a>(h: H) -> Box<dyn StageHandler + 'a> {
        Box::new(h)
    }

    fn run_counting(
        exec: &dyn Executor,
        n: usize,
        window: usize,
    ) -> (usize, usize, ExecReport) {
        let placement = tiny_placement();
        let mut dp = CountingDp { in_flight: 0, max_in_flight: 0, done_seen: 0 };
        let mut items = (0..n as u32).map(qv);
        let report = {
            let stages = StageHandlers {
                head: boxed(RelayHead),
                bis: vec![boxed(NoopStage)],
                dps: vec![boxed(&mut dp)],
                ags: vec![boxed(InstantAg { finished: Vec::new() })],
            };
            exec.run(
                &placement,
                stages,
                Workload { items: &mut items, n_queries: n, window, agg_bytes: 0 },
            )
        };
        (dp.max_in_flight, dp.done_seen, report)
    }

    #[test]
    fn batched_admission_bounds_in_flight_queries() {
        for window in [1usize, 3] {
            let (max_if, done, report) = run_counting(&ThreadedExecutor, 12, window);
            assert_eq!(done, 12, "window {window}: all queries torn down");
            assert!(
                max_if <= window,
                "window {window}: {max_if} queries were in flight"
            );
            assert_eq!(report.results.len(), 12);
            for (qid, r) in report.results.iter().enumerate() {
                assert_eq!(r.as_slice(), &[(0.0, qid as u32)]);
            }
            assert!(report.per_query_secs.iter().all(|&s| s > 0.0));
        }
    }

    #[test]
    fn open_loop_and_inline_complete_everything() {
        let (_, done, report) = run_counting(&ThreadedExecutor, 8, 0);
        assert_eq!(done, 8);
        assert_eq!(report.results.len(), 8);
        let (max_if, done, report) = run_counting(&InlineExecutor, 8, 0);
        // Inline drains each query before admitting the next.
        assert_eq!((max_if, done), (1, 8));
        assert_eq!(report.results.len(), 8);
    }

    #[test]
    fn threaded_empty_workload_shuts_down_cleanly() {
        let placement = tiny_placement();
        let mut items = std::iter::empty::<Msg>();
        let stages = StageHandlers {
            head: boxed(RelayHead),
            bis: vec![boxed(NoopStage)],
            dps: vec![boxed(NoopStage)],
            ags: vec![boxed(NoopStage)],
        };
        let report = ThreadedExecutor.run(
            &placement,
            stages,
            Workload { items: &mut items, n_queries: 0, window: 4, agg_bytes: 0 },
        );
        assert_eq!(report.meter.logical_msgs, 0);
        assert!(report.results.is_empty());
    }

    #[test]
    #[should_panic(expected = "injected BI failure")]
    fn dead_stage_thread_resurfaces_its_panic_instead_of_hanging() {
        struct BiHead;
        impl StageHandler for BiHead {
            fn on_msg(&mut self, msg: Msg, out: Emit) {
                let qid = msg.qid().unwrap();
                let v: Arc<[f32]> = vec![0f32; 1].into();
                out.push((Dest::bi(0), Msg::Query { qid, probes: Vec::new(), v, k: 1 }));
            }
        }
        let placement = tiny_placement();
        // Window 1 forces the admission loop to *wait* on a completion that
        // can never arrive; the StopGuard event is what unblocks it.
        let mut items = (0..4u32).map(qv);
        let stages = StageHandlers {
            head: boxed(BiHead),
            bis: vec![boxed(PanicBi)],
            dps: vec![boxed(NoopStage)],
            ags: vec![boxed(NoopStage)],
        };
        ThreadedExecutor.run(
            &placement,
            stages,
            Workload { items: &mut items, n_queries: 4, window: 1, agg_bytes: 0 },
        );
    }

    #[test]
    fn non_query_items_bypass_the_admission_window() {
        // Head: queries register at AG; non-qid items tell AG to flush
        // (complete) everything pending. A query can therefore only
        // complete after the non-qid item *behind it* is admitted — under
        // a window that wrongly gated non-qid items this would deadlock.
        struct FlushHead;
        impl StageHandler for FlushHead {
            fn on_msg(&mut self, msg: Msg, out: Emit) {
                match msg.qid() {
                    Some(qid) => out.push((Dest::ag(0), Msg::QueryMeta { qid, n_bi: 0, k: 1 })),
                    None => out.push((Dest::ag(0), Msg::BiMeta { qid: 0, n_dp: 0 })),
                }
            }
        }
        struct GatedAg {
            pending: Vec<u32>,
            finished: Vec<QueryResult>,
        }
        impl StageHandler for GatedAg {
            fn on_msg(&mut self, msg: Msg, _out: Emit) {
                match msg {
                    Msg::QueryMeta { qid, .. } => self.pending.push(qid),
                    Msg::BiMeta { .. } => {
                        for qid in self.pending.drain(..) {
                            self.finished.push((qid, Vec::new()));
                        }
                    }
                    other => panic!("GatedAg got {other:?}"),
                }
            }
            fn take_completions(&mut self, out: &mut Vec<QueryResult>) {
                out.append(&mut self.finished);
            }
        }

        let placement = tiny_placement();
        let flush = || {
            let flat: Arc<[f32]> = Vec::new().into();
            Msg::IndexBlock { id_base: 0, rows: 0, flat }
        };
        let mut items = vec![qv(0), flush(), qv(1), flush()].into_iter();
        let stages = StageHandlers {
            head: boxed(FlushHead),
            bis: vec![boxed(NoopStage)],
            dps: vec![boxed(NoopStage)],
            ags: vec![boxed(GatedAg { pending: Vec::new(), finished: Vec::new() })],
        };
        let report = ThreadedExecutor.run(
            &placement,
            stages,
            Workload { items: &mut items, n_queries: 2, window: 1, agg_bytes: 0 },
        );
        assert_eq!(report.results.len(), 2);
        assert!(report.per_query_secs.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn inline_meters_cross_node_traffic_only() {
        // RelayHead emits from the head node to DP node 1 and AG on the
        // head node itself: one metered hop + one local delivery per query.
        let (_, _, report) = run_counting(&InlineExecutor, 5, 0);
        assert_eq!(report.meter.logical_msgs, 5);
        assert_eq!(report.meter.local_msgs, 5);
    }

    // ------------------------------------------------------- stream runs

    fn stream_cfg(window: usize, pending_cap: usize) -> StreamConfig {
        StreamConfig { window, agg_bytes: 0, pending_cap }
    }

    fn relay_stages() -> StageHandlers<'static> {
        StageHandlers {
            head: boxed(RelayHead),
            bis: vec![boxed(NoopStage)],
            dps: vec![boxed(NoopStage)],
            ags: vec![boxed(InstantAg { finished: Vec::new() })],
        }
    }

    #[test]
    fn threaded_stream_completes_submissions_as_they_arrive() {
        let placement = tiny_placement();
        let exec = ThreadedExecutor;
        let mut run = exec.open_stream(&placement, relay_stages(), stream_cfg(0, 0));
        for qid in 0..8u32 {
            run.submit(qv(qid));
            let c = run.recv(Duration::from_secs(10)).expect("completion");
            assert_eq!(c.qid, qid);
            assert_eq!(c.hits, vec![(0.0, qid)]);
        }
        assert!(run.try_recv().is_none());
        let report = run.finish();
        assert!(report.unclaimed.is_empty());
        // one metered head→DP hop + one local head→AG delivery per query
        assert_eq!(report.meter.logical_msgs, 8);
        assert_eq!(report.meter.local_msgs, 8);
    }

    #[test]
    fn inline_stream_is_a_per_item_drain() {
        let placement = tiny_placement();
        let exec = InlineExecutor;
        let mut run = exec.open_stream(&placement, relay_stages(), stream_cfg(0, 4));
        for qid in 0..5u32 {
            run.submit(qv(qid));
            let c = run.try_recv().expect("inline completes synchronously");
            assert_eq!(c.qid, qid);
            assert!(c.secs > 0.0);
        }
        let report = run.finish();
        assert!(report.unclaimed.is_empty());
        assert_eq!(report.meter.logical_msgs, 5);
        assert_eq!(report.meter.local_msgs, 5);
    }

    #[test]
    fn stream_finish_waits_for_in_flight_and_returns_unclaimed() {
        let placement = tiny_placement();
        let exec = ThreadedExecutor;
        // window 2 exercises the deferred ingress queue as well
        let mut run = exec.open_stream(&placement, relay_stages(), stream_cfg(2, 0));
        for qid in 0..6u32 {
            run.submit(qv(qid));
        }
        let report = run.finish();
        let mut qids: Vec<u32> = report.unclaimed.iter().map(|c| c.qid).collect();
        qids.sort_unstable();
        assert_eq!(qids, vec![0, 1, 2, 3, 4, 5]);
    }

    /// Head that forwards every query to DP 0 only.
    struct HeadToDp;
    impl StageHandler for HeadToDp {
        fn on_msg(&mut self, msg: Msg, out: Emit) {
            let qid = msg.qid().expect("HeadToDp only takes queries");
            let v: Arc<[f32]> = vec![0f32; 1].into();
            out.push((Dest::dp(0), Msg::CandidateReq { qid, ids: Vec::new(), v, k: 1 }));
        }
    }

    /// DP that parks on a shared latch before answering via AG — holds
    /// queries in flight deterministically (no timing probes).
    struct LatchedDp {
        open: Arc<(Mutex<bool>, Condvar)>,
    }
    impl StageHandler for LatchedDp {
        fn on_msg(&mut self, msg: Msg, out: Emit) {
            let qid = msg.qid().unwrap();
            let (m, cv) = &*self.open;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            drop(open);
            out.push((Dest::ag(0), Msg::LocalTopK { qid, hits: Vec::new() }));
        }
    }

    #[test]
    fn stream_backpressure_declines_submissions_at_pending_cap() {
        let placement = tiny_placement();
        let latch = Arc::new((Mutex::new(false), Condvar::new()));
        let stages = StageHandlers {
            head: boxed(HeadToDp),
            bis: vec![boxed(NoopStage)],
            dps: vec![boxed(LatchedDp { open: latch.clone() })],
            ags: vec![boxed(InstantAg { finished: Vec::new() })],
        };
        let exec = ThreadedExecutor;
        let mut run = exec.open_stream(&placement, stages, stream_cfg(0, 2));
        run.submit(qv(0));
        run.submit(qv(1)); // pending+in-flight now at the cap
        match run.try_submit(qv(2)) {
            Err(m) => assert_eq!(m.qid(), Some(2)),
            Ok(()) => panic!("try_submit succeeded past pending_cap"),
        }
        // open the latch: the parked DP answers both, draining the window
        {
            let (m, cv) = &*latch;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        let a = run.recv(Duration::from_secs(10)).expect("first completion");
        let b = run.recv(Duration::from_secs(10)).expect("second completion");
        let mut got = [a.qid, b.qid];
        got.sort_unstable();
        assert_eq!(got, [0, 1]);
        run.try_submit(qv(2)).expect("window drained");
        let c = run.recv(Duration::from_secs(10)).expect("third completion");
        assert_eq!(c.qid, 2);
        let report = run.finish();
        assert!(report.unclaimed.is_empty());
    }

    #[test]
    #[should_panic(expected = "injected BI failure")]
    fn dead_stage_stream_resurfaces_its_panic() {
        struct ToBiHead;
        impl StageHandler for ToBiHead {
            fn on_msg(&mut self, msg: Msg, out: Emit) {
                let qid = msg.qid().unwrap();
                let v: Arc<[f32]> = vec![0f32; 1].into();
                out.push((Dest::bi(0), Msg::Query { qid, probes: Vec::new(), v, k: 1 }));
            }
        }
        let placement = tiny_placement();
        let stages = StageHandlers {
            head: boxed(ToBiHead),
            bis: vec![boxed(PanicBi)],
            dps: vec![boxed(NoopStage)],
            ags: vec![boxed(NoopStage)],
        };
        let exec = ThreadedExecutor;
        let mut run = exec.open_stream(&placement, stages, stream_cfg(0, 1));
        run.submit(qv(0));
        // cap 1 + no completion: this blocks until the dying run opens the
        // gate, then resurfaces the BI panic instead of hanging.
        run.submit(qv(1));
        run.finish();
    }
}
