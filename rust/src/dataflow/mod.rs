//! The dataflow substrate (paper §IV-A): stages connected by labeled
//! streams, with message buffering/aggregation and exact traffic accounting.
//!
//! The five stages are IR, QR, BI, DP, AG. Messages between stage *copies*
//! carry a label (tag); a mapping function (`partition::{ObjMapper,
//! bucket_map, ag_map}`) turns the tag into a destination copy. Copies are
//! placed on cluster nodes by [`Placement`]; only messages crossing a node
//! boundary count as network traffic, and the stream layer aggregates small
//! messages into packets exactly as the paper's buffered labeled-streams do.
//!
//! How messages *move* between copies is the [`exec`] module's concern: the
//! transport-agnostic [`exec::Executor`] seam with its inline (deterministic
//! FIFO) and threaded (channels + batched admission) implementations; the
//! multi-process TCP transport lives in [`crate::net`] behind the same seam.

pub mod exec;
pub mod message;
pub mod metrics;

pub use exec::{
    Executor, InlineExecutor, StageHandler, StreamCompletion, StreamConfig, StreamReport,
    StreamRun, ThreadedExecutor,
};
pub use message::{Dest, Msg, StageKind};
pub use metrics::{LinkStats, TrafficMeter, WorkStats};

/// Maps each (stage, copy) to the cluster node hosting it.
///
/// Default topology mirrors the paper: dedicated BI nodes, dedicated DP
/// nodes (1:4), and a head node hosting IR/QR/AG. In per-core-copies mode
/// (the ablation of §V-B) several copies of a stage share each node.
///
/// Under the socket transport (`crate::net`) each non-head node is a real
/// OS process (`parlsh worker`), so this mapping doubles as the process
/// assignment table; `PartialEq` lets the socket driver check that the
/// placement a phase runs with matches the one the workers were launched
/// with.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Placement {
    pub bi_copies: usize,
    pub dp_copies: usize,
    pub ag_copies: usize,
    pub bi_nodes: usize,
    pub dp_nodes: usize,
    /// Full-shard replicas of every worker node (1 = no replication).
    /// Each *logical* node then occupies `replication` worker *slots*;
    /// slot `r * n_logical + node` is replica `r` of `node`, so `node_of`
    /// doubles as the slot id of replica 0.
    pub replication: usize,
    /// Node id of the head node (IR/QR/AG) — one past the last worker
    /// slot, i.e. `total_slots()`.
    pub head_node: u16,
}

impl Placement {
    pub fn new(cluster: &crate::config::ClusterConfig) -> Placement {
        let replication = cluster.replication.max(1);
        Placement {
            bi_copies: cluster.bi_copies(),
            dp_copies: cluster.dp_copies(),
            ag_copies: cluster.ag_copies,
            bi_nodes: cluster.bi_nodes,
            dp_nodes: cluster.dp_nodes,
            replication,
            head_node: ((cluster.bi_nodes + cluster.dp_nodes) * replication) as u16,
        }
    }

    /// Node hosting a stage copy. Copies are striped across their stage's
    /// nodes so per-core mode packs `cores_per_node` copies on each node.
    /// With replication this is the *logical* node — also replica 0's slot.
    pub fn node_of(&self, stage: StageKind, copy: u16) -> u16 {
        match stage {
            StageKind::Bi => (copy as usize % self.bi_nodes) as u16,
            StageKind::Dp => (self.bi_nodes + copy as usize % self.dp_nodes) as u16,
            StageKind::Ir | StageKind::Qr | StageKind::Ag => self.head_node,
        }
    }

    /// Logical worker nodes (ignoring replication).
    pub fn n_logical(&self) -> usize {
        self.bi_nodes + self.dp_nodes
    }

    /// Worker slots: every replica of every logical node is one slot
    /// (one `parlsh worker` process). Slot layout is replica-major so
    /// replication = 1 degenerates to slot == node, bit-identical to the
    /// unreplicated topology.
    pub fn total_slots(&self) -> usize {
        self.n_logical() * self.replication
    }

    /// The slot hosting replica `r` of logical node `node`.
    pub fn slot_of(&self, node: u16, r: usize) -> u16 {
        (r * self.n_logical() + node as usize) as u16
    }

    /// The logical node a slot replicates.
    pub fn node_of_slot(&self, slot: u16) -> u16 {
        (slot as usize % self.n_logical()) as u16
    }

    /// Which replica of its logical node a slot is.
    pub fn replica_of_slot(&self, slot: u16) -> usize {
        slot as usize / self.n_logical()
    }

    pub fn total_nodes(&self) -> usize {
        self.total_slots() + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    #[test]
    fn paper_topology() {
        let p = Placement::new(&ClusterConfig::default());
        assert_eq!(p.bi_copies, 10);
        assert_eq!(p.dp_copies, 40);
        assert_eq!(p.node_of(StageKind::Bi, 3), 3);
        assert_eq!(p.node_of(StageKind::Dp, 0), 10);
        assert_eq!(p.node_of(StageKind::Dp, 39), 49);
        assert_eq!(p.node_of(StageKind::Ag, 0), 50);
        assert_eq!(p.total_nodes(), 51);
    }

    #[test]
    fn per_core_mode_packs_copies() {
        let mut c = ClusterConfig::default();
        c.per_core_copies = true;
        let p = Placement::new(&c);
        assert_eq!(p.bi_copies, 160);
        // copies 0, 10, 20... share node 0
        assert_eq!(p.node_of(StageKind::Bi, 0), 0);
        assert_eq!(p.node_of(StageKind::Bi, 10), 0);
        assert_eq!(p.node_of(StageKind::Dp, 40), 10);
        assert_eq!(p.total_nodes(), 51);
    }

    #[test]
    fn replica_major_slot_layout() {
        let mut c = ClusterConfig::default();
        c.bi_nodes = 2;
        c.dp_nodes = 3;
        c.replication = 2;
        let p = Placement::new(&c);
        assert_eq!(p.n_logical(), 5);
        assert_eq!(p.total_slots(), 10);
        assert_eq!(p.head_node, 10);
        assert_eq!(p.total_nodes(), 11);
        // replica 0's slot is the logical node itself
        for node in 0..5u16 {
            assert_eq!(p.slot_of(node, 0), node);
            assert_eq!(p.slot_of(node, 1), node + 5);
        }
        for slot in 0..10u16 {
            assert_eq!(p.node_of_slot(slot), slot % 5);
            assert_eq!(p.replica_of_slot(slot), (slot / 5) as usize);
            assert_eq!(p.slot_of(p.node_of_slot(slot), p.replica_of_slot(slot)), slot);
        }
        // node_of is untouched by replication: still the logical node
        assert_eq!(p.node_of(StageKind::Dp, 0), 2);
        // replication = 1 degenerates exactly to the unreplicated layout
        c.replication = 1;
        let p1 = Placement::new(&c);
        assert_eq!(p1.total_slots(), 5);
        assert_eq!(p1.head_node, 5);
        assert_eq!(p1.total_nodes(), 6);
    }
}
