//! The `parlsh worker --listen <addr>` process: hosts one cluster node's
//! set of stage copies (paper: node = set of copies) behind the socket
//! transport.
//!
//! Lifecycle: bind, print `PARLSH_WORKER_LISTEN <addr>` on stdout (the one
//! and only stdout write — the launcher reads it to learn the bound port),
//! accept connections, then dispatch. The first frame on each accepted
//! connection identifies the sender: `Hello` (the driver — carries node
//! assignment, placement, config and digest) or `PeerHello` (another
//! worker). Per-connection reader threads decode frames into one internal
//! *bounded* channel (`net.queue_frames`: a full queue blocks the reader,
//! pushing backpressure onto the TCP sender instead of buffering an
//! unbounded backlog); the main thread owns all stage state and processes
//! events in
//! arrival order, which preserves the per-connection FIFO that the build
//! state-identity contract relies on (each BI/DP copy sees the single IR
//! source in emission order, exactly like the in-process executors).
//!
//! Emissions route by `Placement`: same-node → local queue (a free
//! delivery, like the in-process meters), head node → driver connection,
//! other nodes → lazily-dialed peer connections. All outgoing frames are
//! aggregated per peer (`stream.agg_bytes`) and flushed at idle, and the
//! worker's `TrafficMeter` is charged with real encoded frame bytes —
//! shipped back on every `FlushReq` barrier.
//!
//! Shutdown is typed both ways: a `Shutdown` frame exits cleanly; any
//! failure path fires a drop-guard that sends the driver a `Stopped` frame
//! (the socket rendition of the threaded executor's drop-guard), so the
//! driver's admission loop can never hang on a dead worker.

use crate::config::{Config, SocketConfig};
use crate::dataflow::exec::{BiHandler, DpHandler, StageHandler};
use crate::dataflow::message::{Dest, Msg, StageKind};
use crate::dataflow::metrics::{TrafficMeter, WorkStats};
use crate::dataflow::Placement;
use crate::net::peer::{connect_retry, PeerConn};
use crate::net::wire::{self, FrameKind, Hello};
use crate::runtime::{Ranker, SimdRanker};
use crate::stages::{BiState, DpState};
use crate::util::cli::Args;
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{self, Receiver, SyncSender, TryRecvError};
use std::time::Duration;

/// Writes that stall past this horizon fail the worker loudly (typed IO
/// error → `Stopped` drop-guard) instead of hanging: mirrors the
/// driver-side write timeout guarding the bounded-queue backpressure
/// cycle (see `net::driver` module docs).
const WRITE_STALL_TIMEOUT: Duration = Duration::from_secs(120);

/// Events the reader threads feed the dispatch loop.
enum Ev {
    Hello(Box<Hello>, TcpStream),
    Msg(Dest, Msg),
    Done(u32),
    Flush(u32),
    StateReq,
    Shutdown,
    Closed { driver: bool, err: String },
    Fatal(String),
}

/// CLI entry: `parlsh worker [--listen=ADDR] [--set net.*=...]`.
pub fn run(args: &Args) -> Result<()> {
    let cfg = Config::load(args)?;
    let listen = args
        .opt("listen")
        .map(str::to_string)
        .unwrap_or_else(|| cfg.sock.listen.clone());
    serve(&listen, &cfg.sock)
}

/// Bind, announce, and dispatch until `Shutdown` (or a fatal error).
pub fn serve(listen: &str, sock: &SocketConfig) -> Result<()> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("worker bind {listen}"))?;
    let addr = listener.local_addr()?;
    // The launcher parses this line; everything else goes to stderr.
    println!("PARLSH_WORKER_LISTEN {addr}");
    std::io::stdout().flush().ok();

    // Bounded reader→dispatch queue (`net.queue_frames`): a full queue
    // blocks the connection's reader thread, which stops draining its TCP
    // socket, which backpressures the sender — instead of buffering an
    // unbounded frame backlog in worker memory. The dataflow is a DAG
    // (driver → BI → DP → driver) and the driver always drains its side,
    // so bounded queues here cannot deadlock the pipeline.
    let (tx, rx) = mpsc::sync_channel::<Ev>(sock.queue_frames.max(1));
    let max_frame = sock.max_frame_bytes;
    std::thread::spawn(move || accept_loop(listener, tx, max_frame));
    dispatch(rx, sock.clone())
}

fn accept_loop(listener: TcpListener, tx: SyncSender<Ev>, max_frame: usize) {
    for conn in listener.incoming() {
        let Ok(stream) = conn else { continue };
        stream.set_nodelay(true).ok();
        let tx = tx.clone();
        std::thread::spawn(move || conn_reader(stream, tx, max_frame));
    }
}

/// One reader per accepted connection: identify the sender by its first
/// frame, then translate frames into events until EOF.
fn conn_reader(mut stream: TcpStream, tx: SyncSender<Ev>, max_frame: usize) {
    let first = match wire::read_frame(&mut stream, max_frame) {
        Ok(f) => f,
        // A connection that closes before identifying itself (e.g. a
        // port probe) is not worth killing the worker over.
        Err(_) => return,
    };
    let from_driver = match first.kind {
        FrameKind::Hello => match wire::decode_hello(&first.payload) {
            Ok(h) => {
                let writer = match stream.try_clone() {
                    Ok(w) => w,
                    Err(e) => {
                        let _ = tx.send(Ev::Fatal(format!("clone driver conn: {e}")));
                        return;
                    }
                };
                if tx.send(Ev::Hello(Box::new(h), writer)).is_err() {
                    return;
                }
                true
            }
            Err(e) => {
                let _ = tx.send(Ev::Fatal(format!("bad handshake: {e}")));
                return;
            }
        },
        FrameKind::PeerHello => {
            if let Err(e) = wire::decode_peer_hello(&first.payload) {
                let _ = tx.send(Ev::Fatal(format!("bad peer hello: {e}")));
                return;
            }
            false
        }
        other => {
            let _ = tx.send(Ev::Fatal(format!("unexpected first frame {other:?}")));
            return;
        }
    };
    reader_rest(stream, tx, max_frame, from_driver)
}

fn reader_rest(mut stream: TcpStream, tx: SyncSender<Ev>, max_frame: usize, from_driver: bool) {
    loop {
        match wire::read_frame(&mut stream, max_frame) {
            Ok(f) => {
                let ev = match f.kind {
                    FrameKind::Stage => match wire::decode_stage(&f.payload) {
                        Ok((d, m)) => Ev::Msg(d, m),
                        Err(e) => Ev::Fatal(format!("bad stage frame: {e}")),
                    },
                    FrameKind::Done => match wire::decode_qid(&f.payload) {
                        Ok(qid) => Ev::Done(qid),
                        Err(e) => Ev::Fatal(format!("bad done frame: {e}")),
                    },
                    FrameKind::FlushReq => match wire::decode_qid(&f.payload) {
                        Ok(seq) => Ev::Flush(seq),
                        Err(e) => Ev::Fatal(format!("bad flush frame: {e}")),
                    },
                    FrameKind::StateReq => Ev::StateReq,
                    FrameKind::Shutdown => Ev::Shutdown,
                    other => Ev::Fatal(format!("unexpected frame {other:?}")),
                };
                let last = matches!(ev, Ev::Fatal(_) | Ev::Shutdown);
                if tx.send(ev).is_err() || last {
                    return;
                }
            }
            Err(e) => {
                let _ = tx.send(Ev::Closed { driver: from_driver, err: e.to_string() });
                return;
            }
        }
    }
}

/// Drop-guard: tells the driver this worker is dying (fires on unwind and
/// on error returns; disarmed only by a clean `Shutdown`).
struct StopGuard {
    conn: Option<TcpStream>,
}

impl StopGuard {
    fn disarm(&mut self) {
        self.conn = None;
    }
}

impl Drop for StopGuard {
    fn drop(&mut self) {
        if let Some(conn) = &mut self.conn {
            let frame = wire::encode_frame(
                FrameKind::Stopped,
                &wire::encode_stopped("worker dispatch terminated"),
            );
            let _ = conn.write_all(&frame);
        }
    }
}

fn dispatch(rx: Receiver<Ev>, sock: SocketConfig) -> Result<()> {
    // Await the handshake before anything else; the driver holds the
    // workload back until every worker replied HelloOk, so no peer can
    // reach us with messages before our state exists.
    let (hello, driver_stream) = match rx.recv().context("events closed before handshake")? {
        Ev::Hello(h, w) => (*h, w),
        Ev::Fatal(e) => bail!("{e}"),
        Ev::Closed { err, .. } => bail!("connection closed before handshake: {err}"),
        _ => bail!("frame before handshake"),
    };

    let placement = Placement::new(&hello.cluster);
    let my = hello.node;
    let n_workers = placement.total_nodes() - 1;
    if (my as usize) >= n_workers {
        bail!("assigned node {my} out of range (0..{n_workers})");
    }
    if hello.peers.len() != n_workers {
        bail!("peer table has {} entries, expected {n_workers}", hello.peers.len());
    }
    let dim = hello.dim as usize;
    let agg = hello.stream.agg_bytes;

    // The set of stage copies this node hosts, per the shared placement.
    let mut bis: Vec<BiState> = Vec::new();
    let mut bi_idx: HashMap<u16, usize> = HashMap::new();
    for c in 0..placement.bi_copies as u16 {
        if placement.node_of(StageKind::Bi, c) == my {
            bi_idx.insert(c, bis.len());
            bis.push(BiState::new(c, placement.ag_copies, hello.stream.max_candidates));
        }
    }
    let mut dps: Vec<DpState> = Vec::new();
    let mut dp_idx: HashMap<u16, usize> = HashMap::new();
    for c in 0..placement.dp_copies as u16 {
        if placement.node_of(StageKind::Dp, c) == my {
            dp_idx.insert(c, dps.len());
            // Per-query plans: the ranking depth k now arrives on every
            // CandidateReq (wire v3), so the DP store needs no frozen k.
            dps.push(DpState::new(c, dim, placement.ag_copies, hello.stream.dedup));
        }
    }
    // Workers rank with the SIMD tier — bit-identical to the scalar
    // oracle and therefore to the inline differential baseline
    // (DESIGN.md §Transports, §Kernels).
    let ranker = SimdRanker { dim };

    let mut guard = StopGuard { conn: driver_stream.try_clone().ok() };
    driver_stream.set_write_timeout(Some(WRITE_STALL_TIMEOUT)).ok();
    let mut driver = PeerConn::new(driver_stream, agg);
    driver.send_now(&wire::encode_frame(
        FrameKind::HelloOk,
        &wire::encode_hello_ok(my, hello.digest),
    ))?;

    let mut peers: Vec<Option<PeerConn>> = (0..n_workers).map(|_| None).collect();
    let mut meter = fresh_meter(agg);
    let mut queue: VecDeque<(Dest, Msg)> = VecDeque::new();
    let mut scratch: Vec<(Dest, Msg)> = Vec::new();

    loop {
        let ev = match rx.try_recv() {
            Ok(ev) => ev,
            Err(TryRecvError::Empty) => {
                // Idle: everything queued so far must reach the wire before
                // we block, or closed-loop admission would deadlock.
                driver.flush()?;
                for p in peers.iter_mut().flatten() {
                    p.flush()?;
                }
                match rx.recv() {
                    Ok(ev) => ev,
                    Err(_) => bail!("event channel closed"),
                }
            }
            Err(TryRecvError::Disconnected) => bail!("event channel closed"),
        };
        match ev {
            Ev::Msg(dest, msg) => {
                queue.push_back((dest, msg));
                drain(
                    &mut queue,
                    &mut bis,
                    &bi_idx,
                    &mut dps,
                    &dp_idx,
                    &ranker,
                    &placement,
                    my,
                    &hello.peers,
                    &sock,
                    agg,
                    &mut driver,
                    &mut peers,
                    &mut meter,
                    &mut scratch,
                )?;
            }
            Ev::Done(qid) => {
                for dp in dps.iter_mut() {
                    dp.finish_query(qid);
                }
            }
            Ev::Flush(seq) => {
                for p in peers.iter_mut().flatten() {
                    p.flush()?;
                }
                meter.flush();
                // Ship (and reset) the phase work counters of every hosted
                // copy alongside the meter, so driver-side work accounting
                // is complete per phase — not head-only (DESIGN.md
                // §Transports; the simnet cost model consumes these).
                let mut work: Vec<(StageKind, u16, WorkStats)> = Vec::new();
                for bi in bis.iter_mut() {
                    work.push((StageKind::Bi, bi.copy, std::mem::take(&mut bi.work)));
                }
                for dp in dps.iter_mut() {
                    work.push((StageKind::Dp, dp.copy, std::mem::take(&mut dp.work)));
                }
                driver.send_now(&wire::encode_frame(
                    FrameKind::FlushAck,
                    &wire::encode_flush_ack(seq, &meter, &work),
                ))?;
                meter = fresh_meter(agg);
            }
            Ev::StateReq => {
                driver.send_now(&wire::encode_frame(
                    FrameKind::StateDump,
                    &wire::encode_state_dump(&bis, &dps),
                ))?;
            }
            Ev::Shutdown => {
                driver.flush()?;
                for p in peers.iter_mut().flatten() {
                    p.flush()?;
                }
                guard.disarm();
                return Ok(());
            }
            Ev::Closed { driver: true, err } => bail!("driver connection lost: {err}"),
            // A peer closing its sending side is normal wind-down; a peer
            // *crash* is detected by the driver on its own connection.
            Ev::Closed { driver: false, .. } => {}
            Ev::Fatal(e) => bail!("{e}"),
            Ev::Hello(..) => bail!("duplicate handshake"),
        }
    }
}

fn fresh_meter(agg: usize) -> TrafficMeter {
    // header_bytes = 0: each frame already carries its real 12-byte header
    // in its encoded length, so link bytes equal actual bytes-on-wire.
    let mut m = TrafficMeter::new(agg);
    m.header_bytes = 0;
    m
}

/// Process queued local deliveries to quiescence, routing emissions by
/// placement (local re-queue / driver / lazily-dialed peer).
#[allow(clippy::too_many_arguments)]
fn drain(
    queue: &mut VecDeque<(Dest, Msg)>,
    bis: &mut [BiState],
    bi_idx: &HashMap<u16, usize>,
    dps: &mut [DpState],
    dp_idx: &HashMap<u16, usize>,
    ranker: &dyn Ranker,
    placement: &Placement,
    my: u16,
    addrs: &[String],
    sock: &SocketConfig,
    agg: usize,
    driver: &mut PeerConn,
    peers: &mut [Option<PeerConn>],
    meter: &mut TrafficMeter,
    scratch: &mut Vec<(Dest, Msg)>,
) -> Result<()> {
    while let Some((dest, msg)) = queue.pop_front() {
        match dest.stage {
            StageKind::Bi => {
                let &i = bi_idx
                    .get(&dest.copy)
                    .with_context(|| format!("BI copy {} not hosted on node {my}", dest.copy))?;
                BiHandler { bi: &mut bis[i] }.on_msg(msg, scratch);
            }
            StageKind::Dp => {
                let &i = dp_idx
                    .get(&dest.copy)
                    .with_context(|| format!("DP copy {} not hosted on node {my}", dest.copy))?;
                DpHandler { dp: &mut dps[i], ranker: Some(ranker) }.on_msg(msg, scratch);
            }
            other => bail!("stage {other:?} routed to worker node {my}"),
        }
        for (d, m) in scratch.drain(..) {
            let node = placement.node_of(d.stage, d.copy);
            if node == my {
                // Same-node delivery: free, like the in-process executors.
                meter.send(my, my, 0);
                queue.push_back((d, m));
            } else {
                let frame = wire::stage_frame(d, &m);
                meter.send(my, node, frame.len());
                if node == placement.head_node {
                    driver.send(&frame)?;
                } else {
                    peer_conn(peers, node, my, addrs, sock, agg)?.send(&frame)?;
                }
            }
        }
    }
    Ok(())
}

/// Fetch (dialing on first use) the connection to another worker node.
fn peer_conn<'p>(
    peers: &'p mut [Option<PeerConn>],
    node: u16,
    my: u16,
    addrs: &[String],
    sock: &SocketConfig,
    agg: usize,
) -> Result<&'p mut PeerConn> {
    let slot = &mut peers[node as usize];
    if slot.is_none() {
        let stream = connect_retry(&addrs[node as usize], sock.connect_retries, sock.retry_ms)
            .with_context(|| format!("node {my} dialing node {node} at {}", addrs[node as usize]))?;
        stream.set_write_timeout(Some(WRITE_STALL_TIMEOUT)).ok();
        let mut pc = PeerConn::new(stream, agg);
        pc.send_now(&wire::encode_frame(
            FrameKind::PeerHello,
            &wire::encode_peer_hello(my),
        ))?;
        *slot = Some(pc);
    }
    Ok(slot.as_mut().unwrap())
}
